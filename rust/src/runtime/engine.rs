//! Compiled-executable wrappers: typed entry points over the PJRT CPU
//! client for the three artifact families (density / delta / mc).

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::artifacts::{ArtifactSpec, Manifest};

/// The PJRT client + compiled executable cache. One `Runtime` per
/// process; executables are compiled lazily per artifact and reused.
pub struct Runtime {
    client: xla::PjRtClient,
    /// The parsed artifact manifest this runtime serves.
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Self { client, manifest })
    }

    /// PJRT platform name (`cpu`, `tpu`, ...).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, spec: &ArtifactSpec) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("artifact path utf8")?,
        )
        .with_context(|| format!("parse HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile artifact {}", spec.name))
    }

    /// Compile the named density artifact.
    pub fn density(&self, name: &str) -> Result<DensityExecutable> {
        let spec = self
            .manifest
            .find(name)
            .with_context(|| format!("no artifact {name}"))?
            .clone();
        anyhow::ensure!(spec.graph == "density", "{name} is not a density graph");
        Ok(DensityExecutable {
            exe: self.compile(&spec)?,
            tile: spec.tile.context("tile")?,
            k: spec.k.context("k")?,
        })
    }

    /// Compile the best-fitting density artifact for edge `n`, batch `b`.
    pub fn best_density(&self, n: usize, b: usize) -> Result<DensityExecutable> {
        let spec = self
            .manifest
            .best_density(n, b)
            .context("no density artifacts in manifest")?
            .clone();
        Ok(DensityExecutable {
            exe: self.compile(&spec)?,
            tile: spec.tile.context("tile")?,
            k: spec.k.context("k")?,
        })
    }

    /// Compile the named δ artifact.
    pub fn delta(&self, name: &str) -> Result<DeltaExecutable> {
        let spec = self
            .manifest
            .find(name)
            .with_context(|| format!("no artifact {name}"))?
            .clone();
        anyhow::ensure!(spec.graph == "delta", "{name} is not a delta graph");
        Ok(DeltaExecutable {
            exe: self.compile(&spec)?,
            k: spec.k.context("k")?,
            l: spec.l.context("l")?,
        })
    }

    /// Compile the named Monte-Carlo artifact.
    pub fn mc(&self, name: &str) -> Result<McExecutable> {
        let spec = self
            .manifest
            .find(name)
            .with_context(|| format!("no artifact {name}"))?
            .clone();
        anyhow::ensure!(spec.graph == "mc", "{name} is not an mc graph");
        Ok(McExecutable {
            exe: self.compile(&spec)?,
            tile: spec.tile.context("tile")?,
            samples: spec.samples.context("samples")?,
        })
    }
}

fn literal_3d(data: &[f32], d: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), d * d * d);
    Ok(xla::Literal::vec1(data).reshape(&[d as i64, d as i64, d as i64])?)
}

fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Compiled `density_g{T}_k{K}`: counts+volumes for K cluster masks over
/// one T³ tile.
pub struct DensityExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Tile edge the kernel was compiled for.
    pub tile: usize,
    /// Cluster-batch size the kernel was compiled for.
    pub k: usize,
}

impl DensityExecutable {
    /// Execute one tile: `tensor` is T³ (row-major g,m,b), masks are K×T.
    /// Returns (counts, volumes), each length K.
    pub fn run(
        &self,
        tensor: &[f32],
        xmask: &[f32],
        ymask: &[f32],
        zmask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let t = self.tile;
        let args = [
            literal_3d(tensor, t)?,
            literal_2d(xmask, self.k, t)?,
            literal_2d(ymask, self.k, t)?,
            literal_2d(zmask, self.k, t)?,
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (counts, volumes) = result.to_tuple2()?;
        Ok((counts.to_vec::<f32>()?, volumes.to_vec::<f32>()?))
    }
}

/// Compiled `delta_k{K}_l{L}`: δ-band masks + cardinalities for a slab of
/// K fibers of padded length L.
pub struct DeltaExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Fiber-batch size K.
    pub k: usize,
    /// Padded fiber length L.
    pub l: usize,
}

impl DeltaExecutable {
    /// Returns (masks K×L row-major, cards length K).
    pub fn run(
        &self,
        delta: f32,
        values: &[f32],
        present: &[f32],
        centers: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let args = [
            xla::Literal::vec1(&[delta]),
            literal_2d(values, self.k, self.l)?,
            literal_2d(present, self.k, self.l)?,
            xla::Literal::vec1(centers),
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (masks, cards) = result.to_tuple2()?;
        Ok((masks.to_vec::<f32>()?, cards.to_vec::<f32>()?))
    }
}

/// Compiled `mc_g{T}_s{S}`: Monte-Carlo density estimate over one tile.
pub struct McExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Tile edge the kernel was compiled for.
    pub tile: usize,
    /// Samples per cluster.
    pub samples: usize,
}

impl McExecutable {
    /// `coords` is S×3 row-major i32. Returns ρ̂.
    pub fn run(&self, tensor: &[f32], coords: &[i32]) -> Result<f32> {
        debug_assert_eq!(coords.len(), self.samples * 3);
        let t = self.tile;
        let coords_lit = xla::Literal::vec1(coords)
            .reshape(&[self.samples as i64, 3])?;
        let args = [literal_3d(tensor, t)?, coords_lit];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let rho = result.to_tuple1()?;
        Ok(rho.get_first_element::<f32>()?)
    }
}
