//! Hierarchical span guards and their Chrome-trace events.
//!
//! A [`Span`] is an RAII guard: construction pushes a `B`(egin) event
//! on the calling thread's shard, drop pushes the matching `E`(nd)
//! event carrying the accumulated records-in/out and bytes, plus a
//! `{name}.calls` counter and a `{name}.us` duration histogram into
//! the metrics plane. Nesting is per thread and purely positional —
//! exactly the Chrome `trace_event` duration-event model, so the JSONL
//! written by [`super::export::write_trace`] loads directly in
//! `chrome://tracing` / Perfetto.
//!
//! Spans opened inside `util::pool` worker closures land on the
//! worker's own `tid` as root spans; for a fixed seed the span
//! multiset (names, per-thread nesting, counts) is deterministic even
//! though `tid` assignment is not (asserted by
//! `rust/tests/obs_equivalence.rs`).

use super::recorder::recorder;

/// One Chrome-trace duration event (`ph: B` or `ph: E`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Span name (dotted taxonomy, e.g. `exec.cluster.task`).
    pub name: String,
    /// `true` = `B` (begin), `false` = `E` (end).
    pub begin: bool,
    /// Microseconds since the recorder epoch.
    pub ts_us: u64,
    /// Recording thread's stable id.
    pub tid: u32,
    /// Records entering the span (carried on the `E` event).
    pub records_in: u64,
    /// Records leaving the span (carried on the `E` event).
    pub records_out: u64,
    /// Bytes moved/processed by the span (carried on the `E` event).
    pub bytes: u64,
}

impl TraceEvent {
    fn begin(name: String, ts_us: u64) -> Self {
        Self {
            name,
            begin: true,
            ts_us,
            tid: 0,
            records_in: 0,
            records_out: 0,
            bytes: 0,
        }
    }
}

/// The live half of an enabled span.
#[derive(Debug)]
struct Active {
    name: String,
    start_us: u64,
    records_in: u64,
    records_out: u64,
    bytes: u64,
}

/// RAII span guard — see the [module docs](self). Build one with the
/// [`span!`](crate::span) macro (zero-cost when the recorder is off) or
/// [`Span::begin`] directly.
#[derive(Debug)]
pub struct Span {
    inner: Option<Active>,
}

impl Span {
    /// Open a span NOW: pushes the `B` event. Callers should normally
    /// go through [`span!`](crate::span), which skips name formatting
    /// when the recorder is disabled.
    pub fn begin(name: String) -> Span {
        let r = recorder();
        let start_us = r.now_us();
        r.push_event(TraceEvent::begin(name.clone(), start_us));
        Span {
            inner: Some(Active {
                name,
                start_us,
                records_in: 0,
                records_out: 0,
                bytes: 0,
            }),
        }
    }

    /// A span that records nothing (the disabled arm of
    /// [`span!`](crate::span)).
    pub fn disabled() -> Span {
        Span { inner: None }
    }

    /// Add `n` to the span's records-in tally.
    #[inline]
    pub fn records_in(&mut self, n: u64) {
        if let Some(a) = &mut self.inner {
            a.records_in += n;
        }
    }

    /// Add `n` to the span's records-out tally.
    #[inline]
    pub fn records_out(&mut self, n: u64) {
        if let Some(a) = &mut self.inner {
            a.records_out += n;
        }
    }

    /// Add `n` to the span's bytes tally.
    #[inline]
    pub fn bytes(&mut self, n: u64) {
        if let Some(a) = &mut self.inner {
            a.bytes += n;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        // An opened span ALWAYS closes (even if the recorder was
        // disabled mid-span), so per-tid B/E pairs stay balanced.
        let Some(a) = self.inner.take() else { return };
        let r = recorder();
        let end_us = r.now_us();
        r.push_event(TraceEvent {
            name: a.name.clone(),
            begin: false,
            ts_us: end_us.max(a.start_us),
            tid: 0,
            records_in: a.records_in,
            records_out: a.records_out,
            bytes: a.bytes,
        });
        r.counter(&format!("{}.calls", a.name), 1);
        r.observe(&format!("{}.us", a.name), end_us.saturating_sub(a.start_us));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_inert() {
        let mut s = Span::disabled();
        s.records_in(5);
        s.records_out(5);
        s.bytes(5);
        drop(s); // must not touch the recorder
    }

    #[test]
    fn open_span_closes_even_after_disable() {
        let _g = crate::obs::tests::lock();
        crate::obs::reset();
        crate::obs::enable();
        let s = crate::span!("t.cross");
        crate::obs::disable();
        drop(s);
        let events = crate::obs::take_trace();
        assert_eq!(events.len(), 2);
        assert!(events[0].begin && !events[1].begin);
        crate::obs::reset();
    }
}
