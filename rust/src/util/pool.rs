//! Scoped worker pool — the thread-level parallelism substrate.
//!
//! The paper's §6 parallel NOAC uses C# `Parallel` ("each triple from the
//! context is processed in a separate thread"); no rayon is available
//! offline, so this module implements the equivalent: a fixed pool of OS
//! threads pulling chunked work items from a shared atomic cursor
//! (work-stealing degenerates to work-sharing for uniform loops, which is
//! exactly the per-triple workload here).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use by default: the detected parallelism of the
/// machine (≥1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel indexed map: computes `f(i)` for `i in 0..n` on `workers`
/// threads and returns results in index order.
///
/// Chunked dynamic scheduling: workers claim `chunk`-sized index ranges
/// from an atomic cursor, so skewed per-item costs (dense vs sparse
/// generating triples) still balance.
pub fn parallel_map<T, F>(n: usize, workers: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(chunk > 0);
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let slots = Mutex::new(&mut out);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut local: Vec<(usize, Vec<T>)> = Vec::new();
                loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    let vals: Vec<T> = (start..end).map(&f).collect();
                    local.push((start, vals));
                }
                // single write-back per worker to keep contention off the
                // hot loop
                let mut guard = slots.lock().unwrap();
                for (start, vals) in local {
                    for (off, v) in vals.into_iter().enumerate() {
                        guard[start + off] = Some(v);
                    }
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker missed slot")).collect()
}

/// Parallel fold: workers reduce one accumulator per claimed chunk with
/// `fold`; the per-chunk partials are merged with `merge` in CHUNK-INDEX
/// order, never in worker-finish order.
///
/// Determinism contract: for fixed `(n, chunk)` the merge tree is
/// identical for every worker count (including 1) and every scheduling
/// interleave, so a float-accumulating fold (a density sum, a timing
/// aggregation) built on this primitive is bit-reproducible run-to-run.
/// The price is one accumulator per chunk instead of one per worker;
/// callers pick `chunk` large enough that `make_acc`/`merge` stay off
/// the hot path.
pub fn parallel_fold<A, F, M>(
    n: usize,
    workers: usize,
    chunk: usize,
    make_acc: impl Fn() -> A + Sync,
    fold: F,
    merge: M,
) -> A
where
    A: Send,
    F: Fn(&mut A, usize) + Sync,
    M: Fn(A, A) -> A,
{
    assert!(chunk > 0);
    if n == 0 {
        return make_acc();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        // same per-chunk fold/merge shape as the parallel path, so the
        // result is identical for any worker count
        let mut acc = make_acc();
        let mut start = 0;
        while start < n {
            let mut part = make_acc();
            for i in start..(start + chunk).min(n) {
                fold(&mut part, i);
            }
            acc = merge(acc, part);
            start += chunk;
        }
        return acc;
    }
    let cursor = AtomicUsize::new(0);
    let partials: Mutex<Vec<(usize, A)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut local: Vec<(usize, A)> = Vec::new();
                loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let mut acc = make_acc();
                    for i in start..(start + chunk).min(n) {
                        fold(&mut acc, i);
                    }
                    local.push((start, acc));
                }
                partials.lock().unwrap().extend(local);
            });
        }
    });
    let mut partials = partials.into_inner().unwrap();
    partials.sort_unstable_by_key(|&(start, _)| start);
    partials.into_iter().fold(make_acc(), |acc, (_, p)| merge(acc, p))
}

/// Deterministic partitioned grouping — the merge shape shared by the
/// parallel fingerprint dedup (`oac::online`) and the in-process exec
/// stage 3.
///
/// Groups the indices `0..keys.len()` by key equality: each returned
/// entry is `(first_index, members)` for one distinct key, members in
/// ascending index order, entries ordered by first occurrence — exactly
/// what a sequential first-seen scan produces.
///
/// Determinism contract: equal keys hash equally, so a key's whole group
/// lands in one hash partition; partitions build their groups
/// independently on the pool and the merge sorts by `first_index`, which
/// is unique. The output is therefore bit-identical for ANY
/// `workers`/`partitions` combination, including `(1, 1)`.
pub fn group_indices<K: std::hash::Hash + Eq + Sync>(
    keys: &[K],
    partitions: usize,
    workers: usize,
) -> Vec<(usize, Vec<usize>)> {
    use crate::util::hash::{fxhash, FxHashMap};
    let n = keys.len();
    let partitions = partitions.max(1);
    if n == 0 {
        return Vec::new();
    }
    // first-seen scan over one partition's indices (the whole range for
    // the single-partition fast path)
    let scan = |take: &dyn Fn(usize) -> bool| {
        let mut by_key: FxHashMap<&K, usize> = FxHashMap::default();
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for (i, k) in keys.iter().enumerate() {
            if !take(i) {
                continue;
            }
            match by_key.get(k) {
                Some(&g) => groups[g].1.push(i),
                None => {
                    by_key.insert(k, groups.len());
                    groups.push((i, vec![i]));
                }
            }
        }
        groups
    };
    if partitions == 1 {
        return scan(&|_| true);
    }
    // route pass: one hash per key, chunked across the pool
    let chunk = n.div_ceil(workers.max(1) * 4).max(1024);
    let chunks = n.div_ceil(chunk);
    let route: Vec<u32> = parallel_map(chunks, workers, 1, |c| {
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(n);
        keys[lo..hi]
            .iter()
            .map(|k| (fxhash(k) % partitions as u64) as u32)
            .collect::<Vec<u32>>()
    })
    .into_iter()
    .flatten()
    .collect();
    // per-partition grouping, then the unique-first-index merge
    let mut merged: Vec<(usize, Vec<usize>)> =
        parallel_map(partitions, workers, 1, |p| scan(&|i| route[i] as usize == p))
            .into_iter()
            .flatten()
            .collect();
    merged.sort_unstable_by_key(|&(first, _)| first);
    merged
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(1000, 4, 7, |i| i * 2);
        assert_eq!(out, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_worker_matches() {
        let a = parallel_map(100, 1, 13, |i| i + 1);
        let b = parallel_map(100, 4, 13, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, 8, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn fold_sums() {
        let total = parallel_fold(
            10_000,
            4,
            64,
            || 0u64,
            |acc, i| *acc += i as u64,
            |a, b| a + b,
        );
        assert_eq!(total, 9999 * 10_000 / 2);
    }

    #[test]
    fn fold_deterministic_across_worker_counts() {
        // float accumulation order is fixed by the chunk grid, so every
        // worker count produces the exact same bits
        let run = |workers| {
            parallel_fold(
                10_000,
                workers,
                7,
                || 0.0f64,
                |acc, i| *acc += (i as f64) * 0.1,
                |a, b| a + b,
            )
        };
        let baseline = run(1);
        for workers in [2, 3, 4, 8] {
            assert_eq!(baseline.to_bits(), run(workers).to_bits(), "workers={workers}");
        }
    }

    #[test]
    fn fold_merges_in_chunk_index_order() {
        // each chunk's partial holds consecutive indices; chunk-ordered
        // merging must reproduce 0..n exactly, without sorting
        let out = parallel_fold(
            100,
            4,
            9,
            Vec::new,
            |acc: &mut Vec<usize>, i| acc.push(i),
            |mut a, b| {
                a.extend(b);
                a
            },
        );
        assert_eq!(out, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn group_indices_matches_sequential_scan_for_any_split() {
        // skewed keys: heavy duplicates plus singletons
        let keys: Vec<u32> = (0..997u32).map(|i| (i * i) % 37).collect();
        let baseline = group_indices(&keys, 1, 1);
        // baseline sanity: first-seen order, members ascending
        assert!(baseline.windows(2).all(|w| w[0].0 < w[1].0));
        for &(first, ref members) in &baseline {
            assert_eq!(members[0], first);
            assert!(members.windows(2).all(|w| w[0] < w[1]));
        }
        for partitions in [1, 2, 3, 7, 64] {
            for workers in [1, 2, 5] {
                assert_eq!(
                    group_indices(&keys, partitions, workers),
                    baseline,
                    "partitions={partitions} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn group_indices_empty_and_distinct() {
        assert!(group_indices::<u32>(&[], 4, 4).is_empty());
        let keys = [10u32, 20, 30];
        let groups = group_indices(&keys, 2, 2);
        assert_eq!(groups, vec![(0, vec![0]), (1, vec![1]), (2, vec![2])]);
    }

    #[test]
    fn fold_collects_everything_once() {
        let mut seen = parallel_fold(
            500,
            3,
            11,
            Vec::new,
            |acc: &mut Vec<usize>, i| acc.push(i),
            |mut a, b| {
                a.extend(b);
                a
            },
        );
        seen.sort_unstable();
        assert_eq!(seen, (0..500).collect::<Vec<_>>());
    }
}
