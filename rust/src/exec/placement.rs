//! Task placement policies + adaptive task sizing for the simulated
//! cluster backend ([`crate::exec::ClusterSim`]).
//!
//! The paper's scalability argument (§4) rests on map/reduce tasks being
//! independent, so *where* a task runs is a free variable. This module
//! makes it a first-class, pluggable one: a [`Placement`] policy maps a
//! task (index, shuffle-key partition, estimated cost) onto a node given
//! the nodes' simulated load, and [`adaptive_task_count`] picks the task
//! granularity for a stage from the input size and the previous stage's
//! measured skew (§1: "the number of tasks should be larger than the
//! number of working nodes" — how much larger depends on how skewed the
//! last stage was).

use anyhow::Result;

/// What a placement policy may know about a task before it runs.
#[derive(Debug, Clone, Copy)]
pub struct TaskMeta {
    /// Task index within its phase (submission order).
    pub index: usize,
    /// Shuffle-key partition affinity: the input-split index for map
    /// tasks, the hash partition of the task's first key for reduce
    /// tasks. Locality-aware placement keys off this when no measured
    /// `affinity` is available.
    pub partition: u64,
    /// Estimated cost in simulated ms (records × per-record estimate).
    pub est_cost_ms: f64,
    /// MEASURED input locality, when the scheduler knows it: the node
    /// currently holding the largest share of this task's input bytes
    /// (the serve layer tracks per-shard input provenance; generic M/R
    /// phases pass `None`). [`LocalityAware`] prefers this over the
    /// `partition` hash — moving the task to its data instead of hoping
    /// the hash lands there.
    pub affinity: Option<usize>,
}

impl TaskMeta {
    /// Meta with no measured affinity (the generic M/R case).
    pub fn new(index: usize, partition: u64, est_cost_ms: f64) -> Self {
        Self { index, partition, est_cost_ms, affinity: None }
    }
}

/// What a placement policy may know about a node: its earliest available
/// worker slot and cumulative assigned work, both in simulated ms.
#[derive(Debug, Clone, Copy)]
pub struct NodeView {
    /// Node id (index into the cluster's node list).
    pub id: usize,
    /// Simulated time at which the node's earliest slot frees up.
    pub free_at_ms: f64,
    /// Total simulated work assigned to the node so far this phase.
    pub busy_ms: f64,
}

/// A pluggable node-selection policy. Implementations must be pure
/// functions of `(task, nodes)` so a fixed seed reproduces the exact
/// schedule (the determinism contract of the cluster simulation).
pub trait Placement: Send + Sync {
    /// Policy id (`round-robin` / `locality` / `least-loaded`).
    fn name(&self) -> &'static str;
    /// Pick the node for `task`. `nodes` is never empty.
    fn place(&self, task: &TaskMeta, nodes: &[NodeView]) -> usize;

    /// Pick the node for `task` when it belongs to tenant `tenant` of a
    /// multi-tenant pool ([`crate::serve::tenant::MultiTenantSim`]).
    ///
    /// The default salts the task's index and partition with the tenant
    /// id before delegating to [`Self::place`], so index- and hash-keyed
    /// policies interleave tenants across the pool instead of stacking
    /// every tenant's shard 0 on node 0 (round-robin becomes
    /// tenant-striped; the locality hash decorrelates per tenant).
    /// MEASURED affinity is deliberately left untouched — a tenant's
    /// shard still chases its data, which is exactly the
    /// fairness-vs-locality trade-off the tenant sim measures. Like
    /// `place`, this must stay a pure function of its inputs.
    fn place_tenant(&self, tenant: usize, task: &TaskMeta, nodes: &[NodeView]) -> usize {
        let salted = TaskMeta {
            index: task.index + tenant,
            partition: task.partition
                ^ (tenant as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ..*task
        };
        self.place(&salted, nodes)
    }
}

/// Cycle through nodes in task order — the zero-information baseline.
#[derive(Debug, Default, Clone, Copy)]
pub struct RoundRobin;

impl Placement for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&self, task: &TaskMeta, nodes: &[NodeView]) -> usize {
        task.index % nodes.len()
    }
}

/// Send a task to the node that owns its input: the MEASURED
/// input-majority node when the scheduler knows it (`TaskMeta::affinity`
/// — the serve layer's shard placement), otherwise the shuffle-key
/// partition hash (`partition % nodes`), so reduce tasks land where the
/// map output for their keys was partitioned — Hadoop's rack-locality
/// analogue in a world without racks. Minimises bytes moved at the price
/// of compute balance: under heavy source skew it piles work onto the
/// data-heavy node, which is exactly the communication-vs-balance
/// trade-off the serve-cluster bench measures.
#[derive(Debug, Default, Clone, Copy)]
pub struct LocalityAware;

impl Placement for LocalityAware {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn place(&self, task: &TaskMeta, nodes: &[NodeView]) -> usize {
        match task.affinity {
            Some(node) => node.min(nodes.len().saturating_sub(1)),
            None => (task.partition % nodes.len() as u64) as usize,
        }
    }
}

/// Greedy list scheduling: the node whose earliest slot frees first
/// (ties broken by total assigned work, then node id — total order, so
/// the schedule is deterministic).
#[derive(Debug, Default, Clone, Copy)]
pub struct LeastLoaded;

impl Placement for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn place(&self, _task: &TaskMeta, nodes: &[NodeView]) -> usize {
        nodes
            .iter()
            .min_by(|a, b| {
                (a.free_at_ms, a.busy_ms, a.id)
                    .partial_cmp(&(b.free_at_ms, b.busy_ms, b.id))
                    .expect("simulated clocks are finite")
            })
            .expect("at least one node")
            .id
    }
}

/// Resolve a policy from its CLI name.
pub fn by_name(name: &str) -> Result<Box<dyn Placement>> {
    match name {
        "rr" | "round-robin" => Ok(Box::new(RoundRobin)),
        "locality" => Ok(Box::new(LocalityAware)),
        "least" | "least-loaded" => Ok(Box::new(LeastLoaded)),
        other => anyhow::bail!(
            "unknown placement {other:?} (expected rr|locality|least)"
        ),
    }
}

/// Place `replicas` read replicas on a `nodes`-node cluster with the
/// same pluggable `policy` that places shards and M/R tasks.
///
/// `node_load` is the per-node primary-shard count (or any comparable
/// load measure): replicas are steered AWAY from the hottest node —
/// the one already doing the most primary work — by offsetting the
/// task index/partition past it, and each chosen node's virtual load
/// is bumped by the maximum observed load so greedy policies spread
/// replicas across distinct nodes instead of stacking them.
///
/// Like [`Placement::place`], this is a pure function of its inputs —
/// the same policy, loads, and replica count always yield the same
/// placement (the determinism contract of the simulation).
pub fn place_replicas(
    policy: &dyn Placement,
    nodes: usize,
    replicas: usize,
    node_load: &[usize],
) -> Vec<usize> {
    let n = nodes.max(1);
    let hottest = (0..node_load.len().min(n))
        .max_by_key(|&i| (node_load[i], std::cmp::Reverse(i)))
        .unwrap_or(0);
    let spread = node_load.iter().copied().max().unwrap_or(0).max(1) as f64;
    let mut virt: Vec<f64> =
        (0..n).map(|i| node_load.get(i).copied().unwrap_or(0) as f64).collect();
    let mut placed = Vec::with_capacity(replicas);
    for r in 0..replicas {
        let views: Vec<NodeView> = virt
            .iter()
            .enumerate()
            .map(|(id, &b)| NodeView { id, free_at_ms: b, busy_ms: b })
            .collect();
        let slot = hottest + 1 + r;
        let meta = TaskMeta::new(slot, slot as u64, 1.0);
        let node = policy.place(&meta, &views).min(n - 1);
        virt[node] += spread;
        placed.push(node);
    }
    placed
}

/// Per-stage adaptive task count: enough tasks to keep every worker slot
/// busy for ~2 waves, scaled up (smaller tasks) when the previous stage
/// measured high skew — a skewed stage means per-item costs vary, and
/// finer tasks let list scheduling and speculation absorb the tail.
///
/// `prev_skew` is max/mean of the previous stage's task costs (1.0 =
/// perfectly uniform; the first stage of a pipeline passes 1.0). The
/// result is clamped to `[1, items]` so tiny inputs never produce empty
/// tasks.
pub fn adaptive_task_count(items: usize, slots: usize, prev_skew: f64) -> usize {
    if items == 0 {
        return 1;
    }
    let slots = slots.max(1) as f64;
    let skew = if prev_skew.is_finite() { prev_skew.clamp(1.0, 4.0) } else { 1.0 };
    ((slots * 2.0 * skew).ceil() as usize).clamp(1, items)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(free: &[f64]) -> Vec<NodeView> {
        free.iter()
            .enumerate()
            .map(|(id, &f)| NodeView { id, free_at_ms: f, busy_ms: f })
            .collect()
    }

    fn task(index: usize, partition: u64) -> TaskMeta {
        TaskMeta::new(index, partition, 1.0)
    }

    #[test]
    fn round_robin_cycles() {
        let ns = nodes(&[0.0, 0.0, 0.0]);
        let p = RoundRobin;
        assert_eq!(p.place(&task(0, 9), &ns), 0);
        assert_eq!(p.place(&task(1, 9), &ns), 1);
        assert_eq!(p.place(&task(5, 9), &ns), 2);
    }

    #[test]
    fn locality_follows_partition_not_index() {
        let ns = nodes(&[0.0, 5.0, 0.0]);
        let p = LocalityAware;
        assert_eq!(p.place(&task(0, 4), &ns), 1);
        assert_eq!(p.place(&task(7, 4), &ns), 1, "same partition, same node");
    }

    #[test]
    fn locality_prefers_measured_affinity_over_partition_hash() {
        let ns = nodes(&[0.0, 5.0, 0.0]);
        let p = LocalityAware;
        let with_affinity = TaskMeta { affinity: Some(2), ..task(0, 4) };
        assert_eq!(p.place(&with_affinity, &ns), 2, "affinity wins");
        // an affinity pointing past the cluster (node died and the view
        // shrank) is clamped, never out of range
        let stale = TaskMeta { affinity: Some(9), ..task(0, 4) };
        assert_eq!(p.place(&stale, &ns), 2);
        // round-robin and least-loaded ignore affinity entirely
        assert_eq!(RoundRobin.place(&with_affinity, &ns), 0);
        assert_eq!(LeastLoaded.place(&with_affinity, &ns), 0);
    }

    #[test]
    fn tenant_placement_interleaves_and_keeps_affinity() {
        let ns = nodes(&[0.0, 0.0, 0.0]);
        // round-robin: tenant t's shard 0 lands on node t % n — tenants
        // stripe across the pool instead of stacking on node 0
        assert_eq!(RoundRobin.place_tenant(0, &task(0, 0), &ns), 0);
        assert_eq!(RoundRobin.place_tenant(1, &task(0, 0), &ns), 1);
        assert_eq!(RoundRobin.place_tenant(2, &task(0, 0), &ns), 2);
        // tenant 0 is the un-salted case: identical to plain place()
        assert_eq!(
            LocalityAware.place_tenant(0, &task(3, 7), &ns),
            LocalityAware.place(&task(3, 7), &ns)
        );
        // measured affinity survives the tenant salt — data still wins
        let with_affinity = TaskMeta { affinity: Some(2), ..task(0, 4) };
        assert_eq!(LocalityAware.place_tenant(5, &with_affinity, &ns), 2);
        // pure: same inputs, same node
        assert_eq!(
            LocalityAware.place_tenant(3, &task(1, 9), &ns),
            LocalityAware.place_tenant(3, &task(1, 9), &ns)
        );
    }

    #[test]
    fn least_loaded_picks_earliest_slot_deterministically() {
        let p = LeastLoaded;
        assert_eq!(p.place(&task(0, 0), &nodes(&[3.0, 1.0, 2.0])), 1);
        // tie on free_at → lowest id
        assert_eq!(p.place(&task(0, 0), &nodes(&[2.0, 2.0, 5.0])), 0);
    }

    #[test]
    fn by_name_resolves_and_rejects() {
        for (name, want) in
            [("rr", "round-robin"), ("locality", "locality"), ("least", "least-loaded")]
        {
            assert_eq!(by_name(name).unwrap().name(), want);
        }
        assert!(by_name("yarn").is_err());
    }

    #[test]
    fn replica_placement_avoids_the_hottest_node_and_spreads() {
        // node 0 hosts 5 primary shards — the hot node to steer around
        let load = [5usize, 0, 1];
        assert_eq!(place_replicas(&RoundRobin, 3, 2, &load), vec![1, 2]);
        assert_eq!(place_replicas(&LeastLoaded, 3, 2, &load), vec![1, 2]);
        // locality keys off the offset partition hash when no affinity
        assert_eq!(place_replicas(&LocalityAware, 3, 3, &load), vec![1, 2, 0]);
        // more replicas than nodes wraps but stays in range
        for node in place_replicas(&RoundRobin, 3, 7, &load) {
            assert!(node < 3);
        }
        // degenerate inputs: no replicas, single node, empty loads
        assert!(place_replicas(&LeastLoaded, 3, 0, &load).is_empty());
        assert_eq!(place_replicas(&LeastLoaded, 1, 2, &load), vec![0, 0]);
        assert_eq!(place_replicas(&RoundRobin, 2, 1, &[]), vec![1]);
    }

    #[test]
    fn adaptive_count_scales_with_skew_and_clamps() {
        // uniform: 2 waves over all slots
        assert_eq!(adaptive_task_count(10_000, 8, 1.0), 16);
        // skewed: finer tasks, capped at 4x
        assert_eq!(adaptive_task_count(10_000, 8, 3.0), 48);
        assert_eq!(adaptive_task_count(10_000, 8, 100.0), 64);
        // never more tasks than items, never zero
        assert_eq!(adaptive_task_count(5, 8, 1.0), 5);
        assert_eq!(adaptive_task_count(0, 8, 1.0), 1);
    }
}
