//! Formal-context data model: interned entities, N-ary tuples, triadic /
//! polyadic / many-valued contexts, patterns, and TSV / paper-format I/O.

pub mod context;
pub mod interner;
pub mod io;
pub mod pattern;
pub mod tuple;

pub use context::{ManyValuedTriContext, PolyContext, TriContext};
pub use pattern::{tricluster, Cluster};
pub use tuple::{NTuple, SubRelation, MAX_ARITY};
