//! Writable-style record serialization.
//!
//! The paper's Java implementation makes every key/value class implement
//! Hadoop's `Writable` / `WritableComparable`; this module is the Rust
//! equivalent. Records encode to a compact byte form; **keys are compared
//! by their encoded bytes** during the sort-shuffle (the raw-comparator
//! idiom), so `encode` must be injective and prefix-free per type, which
//! the length-prefixed / fixed-width encodings below guarantee.

use crate::core::pattern::Cluster;
use crate::core::tuple::{NTuple, SubRelation};

/// Serializable record. `decode` must consume exactly the bytes `encode`
/// produced (records are concatenated in shuffle buffers).
pub trait Record: Sized {
    /// Append this record's bytes to `out`.
    fn encode(&self, out: &mut Vec<u8>);
    /// Read one record from the front of `buf`, advancing it.
    fn decode(buf: &mut &[u8]) -> Self;

    /// Encode into a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut v = Vec::new();
        self.encode(&mut v);
        v
    }

    /// Decode a record that occupies the WHOLE buffer.
    fn from_bytes(mut bytes: &[u8]) -> Self {
        let v = Self::decode(&mut bytes);
        debug_assert!(bytes.is_empty(), "trailing bytes after decode");
        v
    }
}

#[inline]
fn take<'a>(buf: &mut &'a [u8], n: usize) -> &'a [u8] {
    let (head, tail) = buf.split_at(n);
    *buf = tail;
    head
}

impl Record for u32 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_be_bytes());
    }

    fn decode(buf: &mut &[u8]) -> Self {
        u32::from_be_bytes(take(buf, 4).try_into().unwrap())
    }
}

impl Record for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_be_bytes());
    }

    fn decode(buf: &mut &[u8]) -> Self {
        u64::from_be_bytes(take(buf, 8).try_into().unwrap())
    }
}

impl Record for f64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_be_bytes());
    }

    fn decode(buf: &mut &[u8]) -> Self {
        f64::from_bits(u64::from_be_bytes(take(buf, 8).try_into().unwrap()))
    }
}

impl Record for () {
    fn encode(&self, _out: &mut Vec<u8>) {}

    fn decode(_buf: &mut &[u8]) -> Self {}
}

impl Record for String {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u32).encode(out);
        out.extend_from_slice(self.as_bytes());
    }

    fn decode(buf: &mut &[u8]) -> Self {
        let n = u32::decode(buf) as usize;
        String::from_utf8(take(buf, n).to_vec()).expect("utf8 record")
    }
}

impl<T: Record> Record for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u32).encode(out);
        for x in self {
            x.encode(out);
        }
    }

    fn decode(buf: &mut &[u8]) -> Self {
        let n = u32::decode(buf) as usize;
        (0..n).map(|_| T::decode(buf)).collect()
    }
}

impl<A: Record, B: Record> Record for (A, B) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
    }

    fn decode(buf: &mut &[u8]) -> Self {
        (A::decode(buf), B::decode(buf))
    }
}

impl Record for NTuple {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.arity() as u8);
        for &e in self.as_slice() {
            e.encode(out);
        }
    }

    fn decode(buf: &mut &[u8]) -> Self {
        let n = take(buf, 1)[0] as usize;
        let elems: Vec<u32> = (0..n).map(|_| u32::decode(buf)).collect();
        NTuple::new(&elems)
    }
}

impl Record for SubRelation {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.original_arity() as u8);
        out.push(self.dropped() as u8);
        for &e in self.as_slice() {
            e.encode(out);
        }
    }

    fn decode(buf: &mut &[u8]) -> Self {
        let n = take(buf, 1)[0] as usize;
        let k = take(buf, 1)[0] as usize;
        let elems: Vec<u32> = (0..n - 1).map(|_| u32::decode(buf)).collect();
        // rebuild via NTuple with a placeholder at position k, then re-drop
        let mut full = Vec::with_capacity(n);
        let mut j = 0;
        for i in 0..n {
            if i == k {
                full.push(0);
            } else {
                full.push(elems[j]);
                j += 1;
            }
        }
        NTuple::new(&full).subrelation(k)
    }
}

/// The `FormalConcept` analogue: components only; support travels
/// separately through stage 3.
impl Record for Cluster {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.arity() as u8);
        for c in &self.components {
            c.encode(out);
        }
    }

    fn decode(buf: &mut &[u8]) -> Self {
        let n = take(buf, 1)[0] as usize;
        let components: Vec<Vec<u32>> = (0..n).map(|_| Vec::decode(buf)).collect();
        Cluster::new(components)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;
    use crate::util::proptest_lite::assert_prop;

    fn roundtrip<T: Record + PartialEq + std::fmt::Debug>(x: T) {
        let bytes = x.to_bytes();
        assert_eq!(T::from_bytes(&bytes), x);
    }

    #[test]
    fn scalars() {
        roundtrip(42u32);
        roundtrip(u64::MAX);
        roundtrip(-1.5f64);
        roundtrip(String::from("Comedy, Драма"));
        roundtrip(());
    }

    #[test]
    fn containers() {
        roundtrip(vec![1u32, 2, 3]);
        roundtrip((7u32, String::from("x")));
        roundtrip(Vec::<u32>::new());
    }

    #[test]
    fn tuples_and_subrelations() {
        roundtrip(NTuple::triple(1, 2, 3));
        roundtrip(NTuple::new(&[9, 8, 7, 6]));
        roundtrip(NTuple::triple(1, 2, 3).subrelation(1));
        roundtrip(NTuple::new(&[4, 5, 6, 7]).subrelation(3));
    }

    #[test]
    fn clusters() {
        roundtrip(tricluster(vec![1, 2], vec![3], vec![4, 5, 6]));
    }

    #[test]
    fn u32_byte_order_matches_numeric_order() {
        // keys sort by encoded bytes: big-endian must preserve order
        let pairs = [(0u32, 1u32), (1, 256), (65535, 65536), (7, 8)];
        for (a, b) in pairs {
            assert!(a.to_bytes() < b.to_bytes(), "{a} vs {b}");
        }
    }

    #[test]
    fn concatenated_stream_decodes() {
        let mut buf = Vec::new();
        NTuple::triple(1, 2, 3).encode(&mut buf);
        NTuple::triple(4, 5, 6).encode(&mut buf);
        let mut slice = buf.as_slice();
        assert_eq!(NTuple::decode(&mut slice), NTuple::triple(1, 2, 3));
        assert_eq!(NTuple::decode(&mut slice), NTuple::triple(4, 5, 6));
        assert!(slice.is_empty());
    }

    #[test]
    fn prop_ntuple_roundtrip() {
        assert_prop(128, |g| {
            let n = 2 + g.usize_below(4);
            let elems: Vec<u32> = (0..n).map(|_| g.u32_below(u32::MAX)).collect();
            let t = NTuple::new(&elems);
            if NTuple::from_bytes(&t.to_bytes()) == t {
                Ok(())
            } else {
                Err(format!("{t:?}"))
            }
        });
    }
}
