//! The binary segment: payload layout, encode/decode, and the on-disk
//! log directory.
//!
//! ## Layout (all little-endian, trailing `u64` checksum)
//!
//! ```text
//! ┌───────────────────────────── header ─────────────────────────────┐
//! │ magic u64 │ version u32 │ page_size u32 │ arity u32 │ shards u32 │
//! │ seq u64   │ epoch u64   │ kind u8 (0 = full, 1 = delta)          │
//! │ config: max_pending u64, workers u32, min_density f64-bits,      │
//! │         min_support u64                                          │
//! ├──────────────────────── per shard (×shards) ─────────────────────┤
//! │ epoch u64 │ n_tuples u64 │ tuples: arity × u32 each              │
//! │ n_cumuli u64 │ cumuli: dropped u8, kept (arity−1) × u32,         │
//! │               page run (len u32 + values zero-padded to          │
//! │               PAGE-word frames — raw arena page frames)          │
//! ├──────────────────────────── clusters ────────────────────────────┤
//! │ n u64 │ each: modalities u8, per modality len u32 + ids u32…,    │
//! │         support u64                                              │
//! ├──────────────────────────── interners ───────────────────────────┤
//! │ modalities u8 │ per modality: n u64 + length-prefixed strings    │
//! ├──────────────────────────────────────────────────────────────────┤
//! │ checksum u64 (chained mix64 over everything above)               │
//! └──────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Decode order is magic → version → checksum → body: a wrong magic is
//! [`SegmentError::BadMagic`], a future version [`SegmentError::BadVersion`],
//! and ANY other malformation — flipped byte, truncation, impossible
//! count — is [`SegmentError::Corrupt`]. The body is only parsed after
//! the checksum passes, so parse code never runs on damaged bytes.
//!
//! A **full** segment carries complete shard state (tuple history +
//! every cumulus's sorted contents); a **delta** segment carries only
//! what changed since the previous segment (new tuples + the values
//! appended per touched key, exactly a [`crate::serve::ShardDelta`]).
//! Entity interner tables (id → name, one per modality) are
//! length-prefixed string records; the serve layer keys everything by
//! `u32` today, so it writes empty tables — the format carries them so
//! named datasets can persist their vocabularies without a version bump.

use std::path::{Path, PathBuf};

use crate::core::pattern::Cluster;
use crate::core::tuple::{NTuple, SubRelation, MAX_ARITY};
use crate::oac::primes::PAGE;

use super::codec::{checksum, Reader, Writer};
use super::restore::{fold, LogImage};
use super::SegmentError;

/// Segment file magic: `"TRICSEG1"` as a little-endian `u64`.
pub const MAGIC: u64 = u64::from_le_bytes(*b"TRICSEG1");

/// On-disk format version this build reads and writes.
pub const FORMAT_VERSION: u32 = 1;

/// Segment file extension.
const EXT: &str = "tseg";

/// Whether a segment carries complete state or only changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// Complete shard state as of this segment's epoch (replaces
    /// everything folded so far on replay).
    Full,
    /// Only the state added since the previous segment.
    Delta,
}

/// Service configuration persisted in every segment header — enough to
/// rebuild a [`crate::serve::ServeConfig`] without a side channel.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentConfig {
    /// Router backpressure high-water mark.
    pub max_pending: usize,
    /// Drain-wave worker threads.
    pub workers: usize,
    /// Density constraint (bit-exact through the f64 bit pattern).
    pub min_density: f64,
    /// Support constraint.
    pub min_support: usize,
}

/// One shard's contribution to a segment.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardRecord {
    /// The shard's ingest epoch as of this segment.
    pub epoch: u64,
    /// Generating tuples (full: entire history; delta: new since last).
    pub tuples: Vec<NTuple>,
    /// Cumuli as `⟨subrelation, values⟩` (full: complete sorted
    /// contents; delta: raw appended values with multiplicity).
    pub cumuli: Vec<(SubRelation, Vec<u32>)>,
}

/// Everything one segment holds.
#[derive(Debug, Clone)]
pub struct SegmentPayload {
    /// Position in the log (assigned by [`SegmentLog::append`]).
    pub seq: u64,
    /// Service epoch this segment was cut at.
    pub epoch: u64,
    /// Full or delta.
    pub kind: SegmentKind,
    /// Relation arity.
    pub arity: usize,
    /// Persisted service configuration.
    pub config: SegmentConfig,
    /// One record per shard.
    pub shards: Vec<ShardRecord>,
    /// The compacted cluster index at this epoch (may be empty on
    /// deltas; replay keeps the last non-empty one as an integrity
    /// cross-check).
    pub clusters: Vec<Cluster>,
    /// Entity-name interner per modality (length-prefixed strings;
    /// empty today — see the module docs).
    pub interners: Vec<Vec<String>>,
}

impl SegmentPayload {
    /// Encode to the framed byte layout (header + body + checksum).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u64(MAGIC);
        w.u32(FORMAT_VERSION);
        w.u32(PAGE as u32);
        w.u32(self.arity as u32);
        w.u32(self.shards.len() as u32);
        w.u64(self.seq);
        w.u64(self.epoch);
        w.u8(match self.kind {
            SegmentKind::Full => 0,
            SegmentKind::Delta => 1,
        });
        w.u64(self.config.max_pending as u64);
        w.u32(self.config.workers as u32);
        w.f64(self.config.min_density);
        w.u64(self.config.min_support as u64);
        for rec in &self.shards {
            w.u64(rec.epoch);
            w.u64(rec.tuples.len() as u64);
            for t in &rec.tuples {
                w.words(t.as_slice());
            }
            w.u64(rec.cumuli.len() as u64);
            for (sub, values) in &rec.cumuli {
                w.u8(sub.dropped() as u8);
                w.words(sub.as_slice());
                w.page_run(values);
            }
        }
        w.u64(self.clusters.len() as u64);
        for c in &self.clusters {
            w.u8(c.components.len() as u8);
            for comp in &c.components {
                w.u32(comp.len() as u32);
                w.words(comp);
            }
            w.u64(c.support as u64);
        }
        w.u8(self.interners.len() as u8);
        for table in &self.interners {
            w.u64(table.len() as u64);
            for name in table {
                w.str(name);
            }
        }
        w.finish()
    }

    /// Decode a framed segment. `name` labels errors (usually the file
    /// name). See the module docs for the magic/version/checksum order.
    pub fn decode(bytes: &[u8], name: &str) -> Result<Self, SegmentError> {
        // the magic + version prefix is readable even on a torn tail —
        // distinguish "not a segment" / "future format" from damage
        if bytes.len() < 12 {
            return Err(SegmentError::corrupt(format!("{name}: shorter than the header")));
        }
        let mut head = Reader::new(bytes);
        if head.u64() != Some(MAGIC) {
            return Err(SegmentError::BadMagic);
        }
        let version = head.u32().expect("length checked");
        if version != FORMAT_VERSION {
            return Err(SegmentError::BadVersion(version));
        }
        if bytes.len() < 12 + 8 {
            return Err(SegmentError::corrupt(format!("{name}: no room for a checksum")));
        }
        let body = &bytes[..bytes.len() - 8];
        let stored =
            u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().expect("8 bytes"));
        if checksum(body) != stored {
            return Err(SegmentError::corrupt(format!("{name}: checksum mismatch")));
        }
        Self::parse(&body[12..], name)
    }

    /// Parse the checksummed body after magic + version (never called on
    /// bytes that failed the checksum).
    fn parse(body: &[u8], name: &str) -> Result<Self, SegmentError> {
        let bad = || SegmentError::corrupt(format!("{name}: malformed body"));
        let mut r = Reader::new(body);
        let page_size = r.u32().ok_or_else(bad)? as usize;
        if page_size != PAGE {
            return Err(SegmentError::corrupt(format!(
                "{name}: page size {page_size} (this build frames {PAGE})"
            )));
        }
        let arity = r.u32().ok_or_else(bad)? as usize;
        if !(2..=MAX_ARITY).contains(&arity) {
            return Err(SegmentError::corrupt(format!("{name}: arity {arity} out of range")));
        }
        let n_shards = r.u32().ok_or_else(bad)? as usize;
        let seq = r.u64().ok_or_else(bad)?;
        let epoch = r.u64().ok_or_else(bad)?;
        let kind = match r.u8().ok_or_else(bad)? {
            0 => SegmentKind::Full,
            1 => SegmentKind::Delta,
            k => {
                return Err(SegmentError::corrupt(format!("{name}: unknown segment kind {k}")))
            }
        };
        let config = SegmentConfig {
            max_pending: r.u64().ok_or_else(bad)? as usize,
            workers: r.u32().ok_or_else(bad)? as usize,
            min_density: r.f64().ok_or_else(bad)?,
            min_support: r.u64().ok_or_else(bad)? as usize,
        };
        let mut shards = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let shard_epoch = r.u64().ok_or_else(bad)?;
            let n_tuples = r.u64().ok_or_else(bad)? as usize;
            let mut tuples = Vec::with_capacity(n_tuples.min(r.remaining() / 4));
            for _ in 0..n_tuples {
                tuples.push(NTuple::new(&r.words(arity).ok_or_else(bad)?));
            }
            let n_cumuli = r.u64().ok_or_else(bad)? as usize;
            let mut cumuli = Vec::with_capacity(n_cumuli.min(r.remaining() / 4));
            for _ in 0..n_cumuli {
                let dropped = r.u8().ok_or_else(bad)? as usize;
                if dropped >= arity {
                    return Err(SegmentError::corrupt(format!(
                        "{name}: dropped modality {dropped} ≥ arity {arity}"
                    )));
                }
                let kept = r.words(arity - 1).ok_or_else(bad)?;
                let values = r.page_run().ok_or_else(bad)?;
                cumuli.push((SubRelation::from_parts(&kept, dropped), values));
            }
            shards.push(ShardRecord { epoch: shard_epoch, tuples, cumuli });
        }
        let n_clusters = r.u64().ok_or_else(bad)? as usize;
        let mut clusters = Vec::with_capacity(n_clusters.min(r.remaining() / 8));
        for _ in 0..n_clusters {
            let n_comp = r.u8().ok_or_else(bad)? as usize;
            let mut components = Vec::with_capacity(n_comp);
            for _ in 0..n_comp {
                let len = r.u32().ok_or_else(bad)? as usize;
                components.push(r.words(len).ok_or_else(bad)?);
            }
            let support = r.u64().ok_or_else(bad)? as usize;
            let mut c = Cluster::from_sorted(components);
            c.support = support;
            clusters.push(c);
        }
        let n_tables = r.u8().ok_or_else(bad)? as usize;
        let mut interners = Vec::with_capacity(n_tables);
        for _ in 0..n_tables {
            let n = r.u64().ok_or_else(bad)? as usize;
            let mut table = Vec::with_capacity(n.min(r.remaining() / 4));
            for _ in 0..n {
                table.push(r.str().ok_or_else(bad)?);
            }
            interners.push(table);
        }
        if r.remaining() != 0 {
            return Err(SegmentError::corrupt(format!(
                "{name}: {} trailing bytes after the body",
                r.remaining()
            )));
        }
        Ok(Self { seq, epoch, kind, arity, config, shards, clusters, interners })
    }
}

/// A directory of `seg-NNNNNN.tseg` files, appended in sequence order.
#[derive(Debug)]
pub struct SegmentLog {
    dir: PathBuf,
    next_seq: u64,
}

fn seg_file_name(seq: u64) -> String {
    format!("seg-{seq:06}.{EXT}")
}

impl SegmentLog {
    /// Start a FRESH log at `dir`: the directory is created and any
    /// existing segment files are removed, so reruns are deterministic
    /// (a stale tail from a previous run cannot leak into this one).
    pub fn create(dir: &Path) -> Result<Self, SegmentError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| SegmentError::io(&format!("create {}", dir.display()), e))?;
        for (_, path) in Self::segment_paths(dir)? {
            std::fs::remove_file(&path)
                .map_err(|e| SegmentError::io(&format!("clear {}", path.display()), e))?;
        }
        Ok(Self { dir: dir.to_path_buf(), next_seq: 0 })
    }

    /// Open an existing log for appending (next sequence = highest
    /// present + 1; an empty or missing directory starts at 0).
    pub fn open(dir: &Path) -> Result<Self, SegmentError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| SegmentError::io(&format!("create {}", dir.display()), e))?;
        let next_seq = Self::segment_paths(dir)?
            .last()
            .map(|&(seq, _)| seq + 1)
            .unwrap_or(0);
        Ok(Self { dir: dir.to_path_buf(), next_seq })
    }

    /// The log directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Sequence the next [`Self::append`] will write.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Segment files under `dir`, sorted by sequence number.
    pub fn segment_paths(dir: &Path) -> Result<Vec<(u64, PathBuf)>, SegmentError> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(dir) {
            Ok(entries) => entries,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
            Err(e) => return Err(SegmentError::io(&format!("list {}", dir.display()), e)),
        };
        for entry in entries {
            let entry =
                entry.map_err(|e| SegmentError::io(&format!("list {}", dir.display()), e))?;
            let path = entry.path();
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(seq) = name
                .strip_prefix("seg-")
                .and_then(|rest| rest.strip_suffix(&format!(".{EXT}")))
                .and_then(|digits| digits.parse::<u64>().ok())
            {
                out.push((seq, path));
            }
        }
        out.sort_unstable_by_key(|&(seq, _)| seq);
        Ok(out)
    }

    /// Stamp the payload with the next sequence, encode, and write it.
    /// Returns the encoded byte count (what the sims charge as REAL
    /// delta MiB instead of a model estimate). Emits the `persist.flush`
    /// span (bytes = segment size) and `persist.segment.flush`.
    pub fn append(&mut self, payload: &mut SegmentPayload) -> Result<u64, SegmentError> {
        let mut span = crate::span!("persist.flush");
        payload.seq = self.next_seq;
        let bytes = payload.encode();
        span.records_in(payload.shards.iter().map(|s| s.tuples.len() as u64).sum());
        span.bytes(bytes.len() as u64);
        let path = self.dir.join(seg_file_name(self.next_seq));
        std::fs::write(&path, &bytes)
            .map_err(|e| SegmentError::io(&format!("write {}", path.display()), e))?;
        self.next_seq += 1;
        crate::obs::counter("persist.segment.flush", 1);
        Ok(bytes.len() as u64)
    }

    /// Decode every segment under `dir` in sequence order and fold them
    /// into one [`LogImage`]. A FINAL segment that fails to decode is a
    /// torn tail — dropped, and the retained prefix is returned
    /// (`persist.segment.torn` counts it); a non-final failure is an
    /// error. Emits `persist.segment.restore` per decoded segment.
    pub fn replay(dir: &Path) -> Result<LogImage, SegmentError> {
        let paths = Self::segment_paths(dir)?;
        if paths.is_empty() {
            return Err(SegmentError::Io(format!(
                "no segments under {}",
                dir.display()
            )));
        }
        let last = paths.len() - 1;
        let mut payloads = Vec::with_capacity(paths.len());
        let mut bytes_read = 0u64;
        for (i, (_, path)) in paths.iter().enumerate() {
            let name = path.display().to_string();
            let decoded = std::fs::read(path)
                .map_err(|e| SegmentError::io(&format!("read {name}"), e))
                .and_then(|raw| {
                    let n = raw.len() as u64;
                    SegmentPayload::decode(&raw, &name).map(|p| (p, n))
                });
            match decoded {
                Ok((payload, n)) => {
                    bytes_read += n;
                    payloads.push(payload);
                    crate::obs::counter("persist.segment.restore", 1);
                }
                Err(SegmentError::Corrupt { .. }) if i == last && i > 0 => {
                    // torn final segment: restore the retained prefix
                    crate::obs::counter("persist.segment.torn", 1);
                    break;
                }
                Err(e) => return Err(e),
            }
        }
        fold(payloads, bytes_read)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oac::post::Constraints;

    fn sample_payload() -> SegmentPayload {
        let tuples = vec![NTuple::triple(1, 2, 3), NTuple::triple(1, 2, 4)];
        let cumuli = vec![
            (NTuple::triple(1, 2, 3).subrelation(2), vec![3, 4]),
            (NTuple::triple(1, 2, 3).subrelation(0), vec![1]),
        ];
        let mut cluster = Cluster::from_sorted(vec![vec![1], vec![2], vec![3, 4]]);
        cluster.support = 2;
        SegmentPayload {
            seq: 0,
            epoch: 7,
            kind: SegmentKind::Full,
            arity: 3,
            config: SegmentConfig {
                max_pending: 65536,
                workers: 4,
                min_density: 0.25,
                min_support: 2,
            },
            shards: vec![
                ShardRecord { epoch: 3, tuples, cumuli },
                ShardRecord::default(),
            ],
            clusters: vec![cluster],
            interners: vec![vec!["alice".into(), "bob".into()], vec![], vec![]],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let p = sample_payload();
        let bytes = p.encode();
        let q = SegmentPayload::decode(&bytes, "mem").unwrap();
        assert_eq!(q.seq, p.seq);
        assert_eq!(q.epoch, p.epoch);
        assert_eq!(q.kind, p.kind);
        assert_eq!(q.arity, p.arity);
        assert_eq!(q.config, p.config);
        assert_eq!(q.shards, p.shards);
        assert_eq!(q.clusters, p.clusters);
        assert_eq!(q.interners, p.interners);
    }

    #[test]
    fn every_flipped_byte_is_detected() {
        let bytes = sample_payload().encode();
        // flip each byte in turn: decode must FAIL (typed) every time —
        // magic/version damage included, never a panic, never silence
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(
                SegmentPayload::decode(&bad, "mem").is_err(),
                "flip at byte {i} went undetected"
            );
        }
    }

    #[test]
    fn truncation_is_corrupt() {
        let bytes = sample_payload().encode();
        for keep in [0, 1, 11, 12, 19, bytes.len() / 2, bytes.len() - 1] {
            match SegmentPayload::decode(&bytes[..keep], "mem") {
                Err(SegmentError::Corrupt { .. }) => {}
                other => panic!("keep={keep}: expected Corrupt, got {other:?}"),
            }
        }
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let mut bytes = sample_payload().encode();
        bytes[0] ^= 0xFF;
        assert!(matches!(
            SegmentPayload::decode(&bytes, "mem"),
            Err(SegmentError::BadMagic)
        ));
        let mut bytes = sample_payload().encode();
        bytes[8] = 99; // version field
        assert!(matches!(
            SegmentPayload::decode(&bytes, "mem"),
            Err(SegmentError::BadVersion(_))
        ));
    }

    #[test]
    fn log_appends_and_replays_in_order() {
        let dir = std::env::temp_dir().join("tricluster_segment_log_test");
        let mut log = SegmentLog::create(&dir).unwrap();
        let mut full = sample_payload();
        let n1 = log.append(&mut full).unwrap();
        assert_eq!(full.seq, 0);
        let mut delta = SegmentPayload {
            kind: SegmentKind::Delta,
            epoch: 8,
            clusters: Vec::new(),
            shards: vec![
                ShardRecord {
                    epoch: 4,
                    tuples: vec![NTuple::triple(9, 9, 9)],
                    cumuli: vec![(NTuple::triple(9, 9, 9).subrelation(0), vec![9])],
                },
                ShardRecord::default(),
            ],
            ..sample_payload()
        };
        let n2 = log.append(&mut delta).unwrap();
        assert_eq!(delta.seq, 1);
        assert!(n1 > 0 && n2 > 0);
        let image = SegmentLog::replay(&dir).unwrap();
        assert_eq!(image.segments, 2);
        assert_eq!(image.epoch, 8);
        assert_eq!(image.bytes, n1 + n2);
        // full history + the delta tuple
        assert_eq!(image.shards[0].tuples.len(), 3);
        // re-open continues the sequence; create() clears it
        assert_eq!(SegmentLog::open(&dir).unwrap().next_seq(), 2);
        let fresh = SegmentLog::create(&dir).unwrap();
        assert_eq!(fresh.next_seq(), 0);
        assert!(SegmentLog::segment_paths(&dir).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_final_segment_is_dropped_midlog_corruption_is_fatal() {
        let dir = std::env::temp_dir().join("tricluster_segment_torn_test");
        let mut log = SegmentLog::create(&dir).unwrap();
        for _ in 0..3 {
            log.append(&mut sample_payload()).unwrap();
        }
        let paths = SegmentLog::segment_paths(&dir).unwrap();
        // truncate the FINAL segment mid-body: replay keeps the prefix
        let raw = std::fs::read(&paths[2].1).unwrap();
        std::fs::write(&paths[2].1, &raw[..raw.len() / 2]).unwrap();
        let image = SegmentLog::replay(&dir).unwrap();
        assert_eq!(image.segments, 2);
        // corrupt a MIDDLE segment: replay must refuse
        let raw = std::fs::read(&paths[1].1).unwrap();
        let mut bad = raw.clone();
        let at = bad.len() - 9; // inside the body, not the magic
        bad[at] ^= 0x01;
        std::fs::write(&paths[1].1, &bad).unwrap();
        assert!(matches!(
            SegmentLog::replay(&dir),
            Err(SegmentError::Corrupt { .. })
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn constraints_roundtrip_bit_exact() {
        let cons = Constraints { min_density: 0.1 + 0.2, min_support: 3 };
        let mut p = sample_payload();
        p.config.min_density = cons.min_density;
        let q = SegmentPayload::decode(&p.encode(), "mem").unwrap();
        // f64 bit pattern survives exactly (0.1 + 0.2 ≠ 0.3 in IEEE-754)
        assert_eq!(q.config.min_density.to_bits(), cons.min_density.to_bits());
    }
}
