//! Spark-like execution engine (paper §7 future work): in-memory
//! partitioned datasets with narrow/wide transformations, plus the
//! multimodal clustering pipeline ported to it. Compared against the
//! Hadoop-style engine in ablation A4.

pub mod mmc_spark;
pub mod rdd;

pub use mmc_spark::{run_mmc_spark, SparkMmcResult};
pub use rdd::{Rdd, SparkContext};
