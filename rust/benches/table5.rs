//! Bench: regenerate paper Table 5 + Figure 3 — NOAC regular vs parallel
//! over the tri-frames sweep for both parameter settings
//! NOAC(100, 0.8, 2) and NOAC(100, 0.5, 0).

use tricluster::coordinator::{experiments, ExpConfig};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let workers = std::env::var("TRICLUSTER_BENCH_WORKERS")
        .ok()
        .and_then(|w| w.parse().ok())
        .unwrap_or_else(|| tricluster::util::pool::default_workers().max(2));
    let cfg = ExpConfig { full, nodes: 10, theta: 0.0, runs: 1, seed: 42 };
    eprintln!("table5/fig3 bench (full={full}, workers={workers}) ...");
    let report = experiments::table5(&cfg, workers)?;
    println!("{}", report.render());
    println!();
    println!("paper reference (i7-8750H, C# Parallel): parallel ≈ 35% faster on average;");
    println!("  runtime does not depend on (ρ, minsup) — only the tricluster count does.");
    println!("NOTE: this container exposes {} CPU(s); with 1 CPU the parallel version",
             tricluster::util::pool::default_workers());
    println!("  measures scheduling overhead only — see EXPERIMENTS.md for interpretation.");
    let csv = report.write_csv()?;
    eprintln!("(csv: {})", csv.display());
    Ok(())
}
