//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from the Rust request path. Python is never invoked here.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactSpec, Manifest};
pub use engine::{DensityExecutable, DeltaExecutable, McExecutable, Runtime};

use std::path::PathBuf;

/// Default artifact directory: `$TRICLUSTER_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("TRICLUSTER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if the artifacts (manifest) are present — integration tests skip
/// gracefully when `make artifacts` has not run.
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}
