//! Many-valued triclustering of semantic tri-frames (paper §6): NOAC
//! with δ-operators over subject-verb-object triples weighted by corpus
//! frequency, sequential vs parallel, plus the Layer-1 δ-kernel
//! (AOT Pallas) evaluating fiber slabs through PJRT.
//!
//! Run: `cargo run --release --example noac_frames [-- --triples N]`

use tricluster::datasets::{triframes, TriframesParams};
use tricluster::noac::{mine_noac, DeltaOperator, NoacParams};
use tricluster::oac::generic::TriOperator;
use tricluster::util::cli::Args;
use tricluster::util::stats::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n: usize = args.parse_or("triples", 20_000);
    let workers: usize =
        args.parse_or("workers", tricluster::util::pool::default_workers().max(2));
    let ctx = triframes(&TriframesParams::with_triples(n));
    println!("tri-frames context: {} valued triples\n", ctx.len());

    for (label, params) in [
        ("NOAC(100, 0.8, 2)", NoacParams::table5_strict()),
        ("NOAC(100, 0.5, 0)", NoacParams::table5_loose()),
    ] {
        let t = Timer::start();
        let seq = mine_noac(&ctx, &params, n, 1);
        let seq_ms = t.elapsed_ms();
        let t = Timer::start();
        let par = mine_noac(&ctx, &params, n, workers);
        let par_ms = t.elapsed_ms();
        assert_eq!(seq.len(), par.len());
        println!(
            "{label}: regular {seq_ms:.0} ms | parallel(x{workers}) {par_ms:.0} ms | {} triclusters",
            seq.len()
        );
    }

    // Layer-1 δ-kernel: evaluate a slab of 64 fibers through the AOT
    // artifact and cross-check against the host operator.
    if tricluster::runtime::artifacts_available() {
        let rt = tricluster::runtime::Runtime::load(
            &tricluster::runtime::default_artifact_dir(),
        )?;
        let exe = rt.delta("delta_k64_l512")?;
        let op = DeltaOperator::build(&ctx, 100.0);
        let (k, l) = (exe.k, exe.l);
        let mut values = vec![0f32; k * l];
        let mut present = vec![0f32; k * l];
        let mut centers = vec![0f32; k];
        let mut hosts: Vec<Vec<u32>> = Vec::with_capacity(k);
        // pack the extent fibers of the first k triples into the slab
        for (j, t) in ctx.triples().iter().take(k).enumerate() {
            let v0 = ctx.value(t.get(0), t.get(1), t.get(2)).unwrap();
            centers[j] = v0 as f32;
            // fiber along G for fixed (m, b): host ground truth
            hosts.push(op.extent(t));
            let mut i = 0;
            for g in ctx.triples().iter().filter(|x| {
                x.get(1) == t.get(1) && x.get(2) == t.get(2)
            }) {
                if i >= l {
                    break;
                }
                values[j * l + i] =
                    ctx.value(g.get(0), g.get(1), g.get(2)).unwrap() as f32;
                present[j * l + i] = 1.0;
                i += 1;
            }
        }
        let t = Timer::start();
        let (_masks, cards) = exe.run(100.0, &values, &present, &centers)?;
        println!(
            "\nδ-kernel slab (64 fibers × {l}) through PJRT in {:.1} ms",
            t.elapsed_ms()
        );
        let mut agree = 0;
        for j in 0..k {
            if cards[j] as usize == hosts[j].len() {
                agree += 1;
            }
        }
        println!("kernel vs host δ-operator cardinality agreement: {agree}/{k}");
        assert_eq!(agree, k);
    } else {
        println!("\n(artifacts not built — run `make artifacts` for the δ-kernel demo)");
    }
    Ok(())
}
