"""Layer-1 Pallas kernel: batched tricluster density counts.

The paper's density ρ(T) = |G_T×M_T×B_T ∩ I| / (|G_T||M_T||B_T|) is the
single numeric hot spot of OAC-triclustering post-processing (§2 and the
third M/R reduce of §4.1). For a 64³ Boolean tile of the incidence cuboid
and a batch of K cluster membership masks, the numerator is the contraction

    count[k] = Σ_{g,m,b} T[g,m,b] · X[k,g] · Y[k,m] · Z[k,b]

which we factor into three chained contractions so the big one (over the
G×(M·B) tile) lands on the MXU:

    S1[k, m·b] = X[k, :] @ T.reshape(G, M·B)      # MXU matmul
    S2[k, b]   = Σ_m Y[k, m] · S1[k, m, b]        # VPU fused multiply-add
    count[k]   = Σ_b Z[k, b] · S2[k, b]           # VPU reduction

TPU mapping (see DESIGN.md §Hardware-Adaptation): the tile T is the
VMEM-resident block (64³ f32 = 1 MiB ≪ 16 MiB VMEM); the grid runs over
K-blocks of clusters so arbitrarily large cluster batches stream through
while T stays resident. On this image the kernel always runs with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls); numerics
are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT tile geometry. G/M/B must match the tiles Layer 3 feeds;
# K_BLOCK is the cluster-batch block each grid step processes.
TILE_G = 64
TILE_M = 64
TILE_B = 64
K_BLOCK = 8


def _density_kernel(t_ref, x_ref, y_ref, z_ref, o_ref):
    """One grid step: counts for a K_BLOCK slab of clusters.

    Refs (all VMEM blocks):
      t_ref: f32[G, M, B]       — whole incidence tile (grid-invariant).
      x_ref: f32[K_BLOCK, G]    — extent masks slab.
      y_ref: f32[K_BLOCK, M]    — intent masks slab.
      z_ref: f32[K_BLOCK, B]    — modus masks slab.
      o_ref: f32[K_BLOCK]       — output counts slab.
    """
    t = t_ref[...]
    g, m, b = t.shape
    # (K, G) @ (G, M*B) -> (K, M*B): the MXU-shaped contraction.
    s1 = jnp.dot(x_ref[...], t.reshape(g, m * b),
                 preferred_element_type=jnp.float32)
    s1 = s1.reshape(-1, m, b)
    # Σ_m Y[k,m] * S1[k,m,b] -> (K, B)
    s2 = jnp.sum(y_ref[...][:, :, None] * s1, axis=1)
    # Σ_b Z[k,b] * S2[k,b] -> (K,)
    o_ref[...] = jnp.sum(z_ref[...] * s2, axis=1)


@functools.partial(jax.jit, static_argnames=("k_block",))
def density_counts(tensor, xmask, ymask, zmask, *, k_block=K_BLOCK):
    """Batched tricluster triple-counts over one tile (Pallas).

    Shapes: tensor f32[G,M,B]; xmask f32[K,G]; ymask f32[K,M];
    zmask f32[K,B]; K must be a multiple of ``k_block``. Returns f32[K].
    """
    k = xmask.shape[0]
    g, m, b = tensor.shape
    if k % k_block != 0:
        raise ValueError(f"K={k} not a multiple of k_block={k_block}")
    grid = (k // k_block,)
    return pl.pallas_call(
        _density_kernel,
        grid=grid,
        in_specs=[
            # The tile is grid-invariant: same block for every step.
            pl.BlockSpec((g, m, b), lambda i: (0, 0, 0)),
            pl.BlockSpec((k_block, g), lambda i: (i, 0)),
            pl.BlockSpec((k_block, m), lambda i: (i, 0)),
            pl.BlockSpec((k_block, b), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(tensor, xmask, ymask, zmask)


def vmem_bytes(g=TILE_G, m=TILE_M, b=TILE_B, k_block=K_BLOCK):
    """Static VMEM footprint estimate of one grid step (for DESIGN §Perf)."""
    tile = g * m * b * 4
    masks = k_block * (g + m + b) * 4
    inter = k_block * (m * b + b + 1) * 4  # s1 + s2 + out
    return tile + masks + inter


def mxu_flops(g=TILE_G, m=TILE_M, b=TILE_B, k_block=K_BLOCK):
    """MACs per grid step routed to the MXU (the s1 matmul)."""
    return k_block * g * m * b
