//! CI gate: docs freshness. Path-checks every repo file referenced by
//! `docs/ARCHITECTURE.md` and `docs/PAPER_MAP.md` (no network): if a
//! module a doc points at no longer exists — a rename, a deletion, a
//! moved bench — the build fails with the stale references listed, so
//! the paper-to-code map can never silently rot.
//!
//! Run as a bench target so it shares the library build:
//!
//! ```text
//! cargo bench --bench check_docs
//! cargo bench --bench check_docs -- --docs docs/PAPER_MAP.md
//! ```
//!
//! What counts as a reference: a token containing `/` and ending in a
//! known source extension, rooted at one of the repo's tracked
//! directories (`rust/`, `docs/`, `ci/`, `python/`, `examples/`,
//! `.github/`) or a root-level manifest. `{a,b}` brace groups expand
//! (so `serve/{router,shard}.rs` checks both), `:line` suffixes are
//! stripped (PAPER_MAP uses `file.rs:line` anchors — only the FILE is
//! checked, lines may drift), and generated artefacts (`BENCH_*.json`,
//! `target/`, `artifacts/`) are ignored. Paths written relative to the
//! crate source root also resolve via a `rust/` prefix retry (docs say
//! `benches/fig2.rs` for `rust/benches/fig2.rs`).

use std::path::Path;
use std::process::exit;

use tricluster::util::cli::Args;

const DEFAULT_DOCS: [&str; 2] = ["docs/ARCHITECTURE.md", "docs/PAPER_MAP.md"];
const EXTENSIONS: [&str; 6] = [".rs", ".md", ".py", ".json", ".toml", ".yml"];
const ROOTS: [&str; 6] = ["rust/", "docs/", "ci/", "python/", "examples/", ".github/"];

/// Expand one `{a,b,c}` group (the docs never nest them).
fn expand_braces(token: &str) -> Vec<String> {
    let (Some(open), Some(close)) = (token.find('{'), token.find('}')) else {
        return vec![token.to_string()];
    };
    if close < open {
        return vec![token.to_string()];
    }
    let (head, rest) = token.split_at(open);
    let body = &rest[1..close - open];
    let tail = &rest[close - open + 1..];
    body.split(',')
        .map(|alt| format!("{head}{}{tail}", alt.trim()))
        .collect()
}

/// Strip wrapping punctuation and a trailing `:line` anchor. Iterates
/// to a fixpoint: `` `path.rs`). `` needs the sentence dot removed
/// before the closing backtick/paren become trailing and strippable.
fn clean(token: &str) -> &str {
    let mut token = token;
    loop {
        let stripped = token
            .trim_matches(|c: char| "`*()[],;\"'".contains(c))
            .trim_end_matches('.');
        let stripped = match stripped.rfind(':') {
            Some(at) if !stripped[at + 1..].is_empty()
                && stripped[at + 1..].chars().all(|c| c.is_ascii_digit()) =>
            {
                &stripped[..at]
            }
            _ => stripped.trim_end_matches(':'),
        };
        if stripped == token {
            return token;
        }
        token = stripped;
    }
}

/// Does this token look like a repo file reference worth checking?
fn is_candidate(token: &str) -> bool {
    if !token.contains('/') || token.contains("://") {
        return false;
    }
    if !EXTENSIONS.iter().any(|ext| token.ends_with(ext)) {
        return false;
    }
    // generated artefacts and build output are not tracked files
    let name = token.rsplit('/').next().unwrap_or(token);
    if name.starts_with("BENCH_") || token.starts_with("target/") || token.contains("artifacts/")
    {
        return false;
    }
    true
}

/// Resolve a reference against the repo root, retrying under `rust/` for
/// crate-root-relative spellings.
fn resolves(repo: &Path, reference: &str) -> bool {
    if repo.join(reference).is_file() {
        return true;
    }
    if ROOTS.iter().any(|r| reference.starts_with(r)) {
        return false; // explicitly rooted: no retry
    }
    repo.join("rust").join(reference).is_file()
}

fn main() {
    let args = Args::from_env();
    let repo = Path::new(".");
    let docs: Vec<String> = match args.get("docs") {
        Some(doc) => vec![doc.to_string()],
        None => DEFAULT_DOCS.iter().map(|d| d.to_string()).collect(),
    };
    let mut checked = 0usize;
    let mut stale: Vec<String> = Vec::new();
    for doc in &docs {
        let text = match std::fs::read_to_string(repo.join(doc)) {
            Ok(text) => text,
            Err(e) => {
                eprintln!("check_docs: cannot read {doc}: {e}");
                exit(1);
            }
        };
        for (lineno, line) in text.lines().enumerate() {
            for raw in line.split_whitespace() {
                for token in expand_braces(clean(raw)) {
                    let token = clean(&token).to_string();
                    if !is_candidate(&token) {
                        continue;
                    }
                    checked += 1;
                    if !resolves(repo, &token) {
                        stale.push(format!("{doc}:{}: {token}", lineno + 1));
                    }
                }
            }
        }
    }
    if stale.is_empty() {
        println!("check_docs: OK — {checked} file references across {} docs resolve", docs.len());
    } else {
        for s in &stale {
            eprintln!("check_docs: STALE: {s}");
        }
        eprintln!(
            "check_docs: {} stale reference(s) — update the doc or restore the file",
            stale.len()
        );
        exit(1);
    }
}
