//! Bench: serve-on-cluster — the sharded serving layer placed on a
//! simulated N-node cluster (`serve::cluster::ServeSim`), swept over
//! placement policy × churn under a skewed ingress. Writes
//! `BENCH_serve_cluster.json` (repo root).
//!
//! Every number is a deterministic function of the stream and the seed
//! (per-record costs, seeded source skew and churn), so the trajectory is
//! machine-independent and `ci/check_bench.rs` gates it against
//! `ci/bench_baseline.json`.
//!
//! Doubles as an acceptance gate, enforced at the source:
//!
//! 1. every configuration's compacted index — including under churn with
//!    snapshot replay — must equal the `oac::mine_online` reference
//!    exactly (components + supports);
//! 2. on the skewed ingress, shuffle-aware `locality` placement must
//!    both move fewer drain-path bytes AND finish sooner than
//!    round-robin (the Arifuzzaman-style communication/balance
//!    trade-off, network-dominated regime);
//! 3. a 3-tenant mix on the shared pool: every tenant equals its solo
//!    `mine_online`, and the per-tenant fairness spread lands under the
//!    `serve_cluster.max_fairness_spread` ceiling in
//!    `ci/bench_baseline.json`.
//!
//! `TRICLUSTER_BENCH_FULL=1` for the paper-sized stream.

use std::collections::BTreeMap;
use std::time::Instant;

use tricluster::core::context::PolyContext;
use tricluster::core::pattern::{diff_cluster_sets, sort_clusters, Cluster};
use tricluster::core::tuple::NTuple;
use tricluster::datasets::{movielens, MovielensParams};
use tricluster::exec::cluster_sim::{ChurnConfig, ShuffleModel};
use tricluster::oac::{mine_online, Constraints};
use tricluster::serve::cluster::{ServeSim, ServeSimConfig};
use tricluster::serve::tenant::{MultiTenantSim, TenantPoolConfig, TenantSpec};
use tricluster::serve::{LocalBackend, QueryBackend, ServeConfig, TriclusterService};
use tricluster::util::json::Json;
use tricluster::util::rng::Rng;

const NODES: usize = 4;
const SHARDS: usize = 16;
const SLOTS_PER_NODE: usize = 8;
/// Skewed ingress: node 0 sources ~78% of the stream.
const SOURCE_SKEW: f64 = 2.5;
/// Network-dominated regime: ~0.047 ms/record of transfer at 64 B
/// records vs 0.002 ms/record of mining — the setting where placement
/// decides the makespan (a fast network shrinks the gap, it never flips
/// the bytes-moved ordering). The stream is cut into many small waves
/// compacted every wave, so locality's one-time migration bubble (it
/// re-places shards onto the hot ingress node at the FIRST compaction,
/// paying snapshot transfer + rebuild) is amortised over ~19 steady
/// post-rebalance waves of saved transfer.
const SHUFFLE: ShuffleModel = ShuffleModel { bytes_per_record: 64.0, ms_per_mib: 768.0 };
const CHURN_RATES: [f64; 2] = [0.0, 0.3];
const PLACEMENTS: [&str; 3] = ["rr", "locality", "least"];
const SEED: u64 = 0x5E7E_C105;

fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
    sort_clusters(&mut cs);
    cs
}

fn num(n: f64) -> Json {
    Json::Num(n)
}

/// The same seeded query rotation the CLI's `--query-mix` drives:
/// top-k, membership, entity-stats, and whole-index stats. The digest
/// folds every answer, so two backends over the same epoch produce the
/// SAME bits iff their answers agree — cache transparency, measured.
fn query_mix(backend: &mut dyn QueryBackend, queries: usize, seed: u64, arity: usize) -> f64 {
    let mut rng = Rng::new(seed);
    let mut digest = 0.0f64;
    for _ in 0..queries {
        match rng.below(4) {
            0 => digest += backend.top_k(1 + rng.usize_below(8)).len() as f64,
            1 => {
                digest += backend
                    .containing(rng.usize_below(arity), rng.below(16) as u32)
                    .len() as f64;
            }
            2 => {
                digest += backend
                    .entity_stats(rng.usize_below(arity), rng.below(16) as u32)
                    .map_or(0.0, |s| s.mean_density);
            }
            _ => digest += backend.stats().mean_density,
        }
    }
    digest
}

/// Wall-clock a query mix, best-of-`rounds`, returning (ms, digest).
/// The cache is rebuilt per round (fresh backend) so every round pays
/// the same cold misses — we measure steady behaviour, not luck.
fn time_query_mix(
    svc: &TriclusterService,
    cache: bool,
    queries: usize,
    seed: u64,
    arity: usize,
    rounds: usize,
) -> (f64, f64) {
    let mut best = f64::INFINITY;
    let mut digest = 0.0;
    for _ in 0..rounds {
        let mut backend = LocalBackend::with_cache(svc.snapshot_cell(), cache);
        let t = Instant::now();
        digest = query_mix(&mut backend, queries, seed, arity);
        best = best.min(t.elapsed().as_secs_f64() * 1e3);
    }
    (best, digest)
}

/// Query-plane throughput: one compacted epoch served through
/// [`LocalBackend`] with the result cache on vs off. The cached run
/// must answer bit-identically (digest equality — epoch-keyed cache
/// entries are clones of the uncached computation) and faster: the
/// `cached_query_speedup` ratio is gated by `ci/check_bench.rs`
/// against `serve_cluster.min_cached_query_speedup`.
fn bench_query_plane(ctx: &PolyContext, queries: usize, doc: &mut BTreeMap<String, Json>) {
    let mut svc = TriclusterService::new(
        ServeConfig::builder()
            .arity(ctx.arity())
            .shards(8)
            .build()
            .expect("static bench config is valid"),
    );
    svc.ingest(ctx.tuples());
    svc.compact();
    let arity = ctx.arity();
    let (uncached_ms, uncached_digest) =
        time_query_mix(&svc, false, queries, SEED, arity, 3);
    let (cached_ms, cached_digest) = time_query_mix(&svc, true, queries, SEED, arity, 3);
    let matches = cached_digest.to_bits() == uncached_digest.to_bits();
    assert!(
        matches,
        "cached digest {cached_digest} != uncached {uncached_digest}: \
         the cache changed an answer"
    );
    let speedup = uncached_ms / cached_ms;
    eprintln!(
        "  query-plane: {queries} queries over {} clusters — uncached {uncached_ms:.2} ms, \
         cached {cached_ms:.2} ms ({speedup:.2}x), digests agree",
        svc.snapshot().len()
    );
    doc.insert("query_mix_queries".to_string(), num(queries as f64));
    doc.insert("cache_matches_uncached".to_string(), Json::Bool(matches));
    doc.insert("cached_query_speedup".to_string(), num(speedup));
}

/// Multi-tenant fairness on the shared pool: the movielens stream dealt
/// round-robin across identical tenants on one node pool. Enforced at
/// the source: every tenant's compacted index equals its solo
/// `mine_online`; measured: `fairness_spread` (max/min per-tenant
/// service-ms per accepted tuple — 1.0 is perfect fairness), gated by
/// `ci/check_bench.rs` against `serve_cluster.max_fairness_spread`.
fn bench_tenants(ctx: &PolyContext, doc: &mut BTreeMap<String, Json>) {
    const TENANTS: usize = 3;
    let mut cfg = TenantPoolConfig::new(NODES);
    cfg.slots_per_node = SLOTS_PER_NODE;
    cfg.shuffle = SHUFFLE;
    cfg.seed = SEED;
    for t in 0..TENANTS {
        let mut spec = TenantSpec::new(&format!("tenant-{t}"), ctx.arity());
        spec.shards = (SHARDS / TENANTS).max(1);
        cfg = cfg.tenant(spec);
    }
    let streams: Vec<Vec<NTuple>> = (0..TENANTS)
        .map(|t| ctx.tuples().iter().skip(t).step_by(TENANTS).copied().collect())
        .collect();
    let mut sim = MultiTenantSim::new(cfg).expect("static pool config is valid");
    sim.run(&streams, 1_024, 1, &[]);
    for (t, stream) in streams.iter().enumerate() {
        let mut solo = PolyContext::new(ctx.arity());
        for tuple in stream {
            solo.add_ids(tuple.as_slice());
        }
        let reference = sorted(mine_online(&solo, &Constraints::none()));
        let clusters = sorted(sim.clusters(t).to_vec());
        if let Some(diff) = diff_cluster_sets(&reference, &clusters) {
            panic!("tenant {t} diverged from its solo mine_online: {diff}");
        }
    }
    let spread = sim.fairness_spread();
    assert!(spread >= 1.0, "spread is a max/min ratio: {spread}");
    let stats = sim.stats().clone();
    eprintln!(
        "  tenants: {TENANTS} on {NODES} nodes — fairness spread {spread:.3}, \
         makespan {:.1} ms, accepted {:?} (all matched solo mine_online)",
        sim.sim_makespan_ms(),
        stats.accepted
    );
    doc.insert("tenants".to_string(), num(TENANTS as f64));
    doc.insert("fairness_spread".to_string(), num(spread));
    doc.insert("tenant_makespan_ms".to_string(), num(sim.sim_makespan_ms()));
}

fn main() {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let tuples = if full { 200_000 } else { 20_000 };
    let ctx = movielens(&MovielensParams::with_tuples(tuples));
    let reference = sorted(mine_online(&ctx, &Constraints::none()));
    eprintln!(
        "serve_cluster bench (full={full}): {} tuples, {NODES} nodes x {SHARDS} shards, \
         placements {PLACEMENTS:?} x churn {CHURN_RATES:?}",
        ctx.len()
    );

    let mut entries: Vec<Json> = Vec::new();
    // makespan/bytes of the churn-free runs, for the locality-vs-rr gate
    let mut clean: BTreeMap<&str, (f64, f64)> = BTreeMap::new();
    for placement in PLACEMENTS {
        for &churn in &CHURN_RATES {
            let mut cfg = ServeSimConfig::new(ctx.arity(), SHARDS, NODES);
            cfg.placement = placement.into();
            cfg.slots_per_node = SLOTS_PER_NODE;
            cfg.batch = 1_024;
            cfg.route_chunk = 1_024;
            cfg.compact_every = 1;
            cfg.source_skew = SOURCE_SKEW;
            cfg.shuffle = SHUFFLE;
            cfg.churn = ChurnConfig { kill_prob: churn, restart_ms: 50.0 };
            cfg.seed = SEED;
            let mut sim = ServeSim::new(cfg).expect("known placement");
            sim.run(ctx.tuples());
            let clusters = sorted(sim.clusters().to_vec());
            if let Some(diff) = diff_cluster_sets(&reference, &clusters) {
                panic!(
                    "serve-cluster diverged from mine_online \
                     (placement={placement}, churn={churn}): {diff}"
                );
            }
            let makespan = sim.sim_makespan_ms();
            let s = sim.stats().clone();
            if churn == 0.0 {
                clean.insert(placement, (makespan, s.shuffle_mib));
            } else {
                assert!(s.kills > 0, "churn at p={churn} over many waves must kill");
                // only rr is guaranteed to keep shards on EVERY node, so
                // only there must a kill always hit live shard state
                // (locality may concentrate everything away from the
                // killed node — zero replay is then correct)
                if placement == "rr" {
                    assert!(s.replayed_tuples > 0, "rr kills must replay snapshots");
                }
            }
            eprintln!(
                "  {placement:<8} churn={churn:.2}: makespan {makespan:9.1} ms  \
                 shuffle {:8.2} MiB  recovery {:7.2} MiB  kills {:2}  replayed {:6}",
                s.shuffle_mib, s.recovery_mib, s.kills, s.replayed_tuples
            );
            let mut o = BTreeMap::new();
            o.insert("placement".to_string(), Json::Str(placement.into()));
            o.insert("churn".to_string(), num(churn));
            o.insert("sim_makespan_ms".to_string(), num(makespan));
            o.insert("shuffle_mib".to_string(), num(s.shuffle_mib));
            o.insert("recovery_mib".to_string(), num(s.recovery_mib));
            o.insert("kills".to_string(), num(s.kills as f64));
            o.insert("replayed_tuples".to_string(), num(s.replayed_tuples as f64));
            o.insert("migrations".to_string(), num(s.migrations as f64));
            o.insert("clusters".to_string(), num(clusters.len() as f64));
            entries.push(Json::Obj(o));
        }
    }

    // the headline acceptance property, enforced at the source: on a
    // skewed ingress, locality placement beats round-robin on bytes
    // moved AND on simulated makespan
    let (rr_ms, rr_mib) = clean["rr"];
    let (loc_ms, loc_mib) = clean["locality"];
    assert!(
        loc_mib < rr_mib,
        "locality must move fewer drain bytes than rr: {loc_mib} !< {rr_mib}"
    );
    assert!(
        loc_ms < rr_ms,
        "locality must beat rr on the skewed ingress: {loc_ms} !< {rr_ms}"
    );

    let mut doc = BTreeMap::new();
    bench_query_plane(&ctx, if full { 8_192 } else { 2_048 }, &mut doc);
    bench_tenants(&ctx, &mut doc);
    doc.insert("bench".to_string(), Json::Str("serve_cluster".into()));
    doc.insert("full".to_string(), Json::Bool(full));
    doc.insert("tuples".to_string(), num(ctx.len() as f64));
    doc.insert("nodes".to_string(), num(NODES as f64));
    doc.insert("shards".to_string(), num(SHARDS as f64));
    doc.insert("source_skew".to_string(), num(SOURCE_SKEW));
    doc.insert("shuffle_ms_per_mib".to_string(), num(SHUFFLE.ms_per_mib));
    doc.insert(
        "locality_speedup_vs_rr".to_string(),
        num(rr_ms / loc_ms),
    );
    doc.insert("entries".to_string(), Json::Arr(entries));
    std::fs::write("BENCH_serve_cluster.json", Json::Obj(doc).to_string())
        .expect("write BENCH_serve_cluster.json");
    eprintln!(
        "wrote BENCH_serve_cluster.json (all configurations agreed with mine_online; \
         locality beat rr: {:.2}x makespan, {:.1} vs {:.1} MiB moved)",
        rr_ms / loc_ms,
        loc_mib,
        rr_mib
    );
}
