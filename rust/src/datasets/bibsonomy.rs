//! BibSonomy-like tricontext generator (paper §5.1 / Table 2).
//!
//! The paper's sample of the ECML PKDD 2008 discovery-challenge data:
//! 2,337 users × 67,464 tags × 28,920 bookmarks, 816,197 triples,
//! density 1.8·10⁻⁷. The defining feature is extreme sparsity with
//! Zipfian tag reuse and bursty per-bookmark tagging (a user tags one
//! bookmark with several tags at once). This generator reproduces those
//! marginals; it is the "only the M/R version finishes" workload.

use crate::core::context::TriContext;
use crate::util::rng::{Rng, Zipf};

#[derive(Debug, Clone)]
/// Generation parameters for the BibSonomy-like tagging stream.
pub struct BibsonomyParams {
    /// Distinct users.
    pub users: usize,
    /// Distinct tags.
    pub tags: usize,
    /// Distinct bookmarks.
    pub bookmarks: usize,
    /// Triples to generate.
    pub triples: usize,
    /// Stream seed.
    pub seed: u64,
}

impl Default for BibsonomyParams {
    fn default() -> Self {
        Self {
            users: 2_337,
            tags: 67_464,
            bookmarks: 28_920,
            triples: 816_197,
            seed: 0xB1B50,
        }
    }
}

impl BibsonomyParams {
    /// Scaled instance: modality sizes shrink with the cube root of the
    /// triple fraction so the density stays at the original 1.8·10⁻⁷
    /// order (scaling all three dims linearly would cube the density).
    pub fn scaled(triples: usize) -> Self {
        let f = (triples as f64 / 816_197.0).min(1.0).cbrt();
        Self {
            users: ((2_337.0 * f) as usize).max(10),
            tags: ((67_464.0 * f) as usize).max(50),
            bookmarks: ((28_920.0 * f) as usize).max(20),
            triples,
            ..Self::default()
        }
    }
}

/// Generate the BibSonomy-like `(user, tag, bookmark)` context.
pub fn bibsonomy(params: &BibsonomyParams) -> TriContext {
    let mut ctx = TriContext::new();
    for u in 0..params.users {
        ctx.inner.interners[0].intern(&format!("user{u}"));
    }
    for t in 0..params.tags {
        ctx.inner.interners[1].intern(&format!("tag{t}"));
    }
    for b in 0..params.bookmarks {
        ctx.inner.interners[2].intern(&format!("url{b}"));
    }

    let mut rng = Rng::new(params.seed);
    let user_zipf = Zipf::new(params.users as u64, 1.0);
    let tag_zipf = Zipf::new(params.tags as u64, 1.15);
    let bm_zipf = Zipf::new(params.bookmarks as u64, 1.05);

    // posting model: a (user, bookmark) post carries 1..10 tags
    while ctx.len() < params.triples {
        let u = user_zipf.sample(&mut rng) as u32;
        let b = bm_zipf.sample(&mut rng) as u32;
        let n_tags = 1 + rng.usize_below(10);
        for _ in 0..n_tags {
            let t = tag_zipf.sample(&mut rng) as u32;
            ctx.add(u, t, b);
            if ctx.len() >= params.triples {
                break;
            }
        }
    }
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_instance_matches_target() {
        let p = BibsonomyParams::scaled(5_000);
        let ctx = bibsonomy(&p);
        assert_eq!(ctx.len(), 5_000);
        // hyper-sparse like the original
        assert!(ctx.inner.density() < 1e-3);
    }

    #[test]
    fn tag_reuse_is_zipfian() {
        let ctx = bibsonomy(&BibsonomyParams::scaled(20_000));
        let mut counts =
            vec![0usize; ctx.inner.modality_size(1)];
        for t in ctx.triples() {
            counts[t.get(1) as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // head tag used far more than median tag
        assert!(counts[0] >= 20);
        assert!(counts[0] > 10 * counts[counts.len() / 2].max(1) / 2);
    }

    #[test]
    fn deterministic() {
        let a = bibsonomy(&BibsonomyParams::scaled(2_000));
        let b = bibsonomy(&BibsonomyParams::scaled(2_000));
        assert_eq!(a.triples(), b.triples());
    }
}
