//! The `App` (paper §4.2): the full distributed multimodal clustering
//! pipeline on the Hadoop-style engine, plus the per-stage statistics
//! Table 4 reports.
//!
//! The stage logic itself (Algorithms 2–7) lives in its single
//! backend-generic form in [`crate::exec::stages`]; this module binds it
//! to the [`crate::exec::HadoopSim`] backend and retains each fused
//! job's [`JobStats`] for the virtual cluster clock.

use anyhow::Result;

use crate::core::context::PolyContext;
use crate::core::pattern::Cluster;
use crate::exec::{run_pipeline, HadoopSim};
use crate::hadoop::dfs::{Dfs, DfsConfig};
use crate::hadoop::job::{JobConfig, JobStats};

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct MmcConfig {
    /// Density threshold θ of the third reduce (Alg. 7).
    pub theta: f64,
    /// Map/reduce task counts per stage (JobTracker granularity).
    pub map_tasks: usize,
    /// Reduce tasks per stage.
    pub reduce_tasks: usize,
    /// OS threads executing tasks on this machine.
    pub executor_threads: usize,
    /// Map-task retry probability (duplicate injection).
    pub fault_prob: f64,
    /// Seed for fault injection.
    pub seed: u64,
    /// Materialise intermediates through the replicated DFS.
    pub use_dfs: bool,
    /// DFS replication factor (HDFS default 3).
    pub replication: u32,
    /// Use the stage-1 map-side combiner (dedup entities before shuffle).
    pub combiner: bool,
}

impl Default for MmcConfig {
    fn default() -> Self {
        let threads = crate::util::pool::default_workers();
        Self {
            theta: 0.0,
            map_tasks: (threads * 4).max(8),
            reduce_tasks: (threads * 4).max(8),
            executor_threads: threads,
            fault_prob: 0.0,
            seed: 0xAD00,
            use_dfs: true,
            replication: 3,
            combiner: false,
        }
    }
}

/// Result of a pipeline run: the clusters plus per-stage stats.
#[derive(Debug)]
pub struct MmcResult {
    /// The final deduplicated, θ-filtered cluster set.
    pub clusters: Vec<Cluster>,
    /// Per-stage job stats (cumuli, assembly, dedup+density).
    pub stages: [JobStats; 3],
    /// Total wall time, ms.
    pub wall_ms: f64,
}

impl MmcResult {
    /// Simulated r-node makespan: stages are barriers, so the pipeline
    /// makespan is the sum of stage makespans.
    pub fn makespan_ms(&self, r: usize) -> f64 {
        self.stages.iter().map(|s| s.makespan_ms(r)).sum()
    }

    /// Total shuffle traffic (logical bytes).
    pub fn shuffle_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffle_bytes).sum()
    }
}

/// Run the full three-stage pipeline on a context.
pub fn run_mmc(ctx: &PolyContext, cfg: &MmcConfig) -> Result<MmcResult> {
    let timer = crate::util::stats::Timer::start();
    let backend = HadoopSim::new(
        JobConfig {
            name: "mmc".into(),
            map_tasks: cfg.map_tasks,
            reduce_tasks: cfg.reduce_tasks,
            executor_threads: cfg.executor_threads,
            fault_prob: cfg.fault_prob,
            seed: cfg.seed,
            use_dfs: cfg.use_dfs,
        },
        Dfs::new(DfsConfig { replication: cfg.replication, ..DfsConfig::default() }),
    );
    let clusters = run_pipeline(&backend, ctx, cfg.theta, cfg.combiner)?;
    let stages = backend.take_stats();
    anyhow::ensure!(stages.len() == 3, "pipeline ran {} stage jobs, expected 3", stages.len());
    let stages: [JobStats; 3] = stages.try_into().expect("length checked above");
    Ok(MmcResult { clusters, stages, wall_ms: timer.elapsed_ms() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::context::TriContext;
    use crate::datasets::synthetic::{k1, k2, k3};
    use crate::oac::{mine_online, Constraints};

    fn small_cfg() -> MmcConfig {
        MmcConfig { map_tasks: 4, reduce_tasks: 4, ..MmcConfig::default() }
    }

    #[test]
    fn table1_example_merges_across_slices() {
        // the §1 motivating example: triples split by label must still
        // produce the merged ({u2},{i1,i2},{l1,l2})
        let mut ctx = TriContext::new();
        ctx.add_named("u2", "i1", "l1");
        ctx.add_named("u2", "i2", "l1");
        ctx.add_named("u2", "i1", "l2");
        ctx.add_named("u2", "i2", "l2");
        let res = run_mmc(&ctx.inner, &small_cfg()).unwrap();
        assert_eq!(res.clusters.len(), 1);
        let c = &res.clusters[0];
        assert_eq!(c.components, vec![vec![0], vec![0, 1], vec![0, 1]]);
        assert_eq!(c.support, 4);
    }

    #[test]
    fn k2_three_blocks() {
        let res = run_mmc(&k2(4).inner, &small_cfg()).unwrap();
        assert_eq!(res.clusters.len(), 3);
        for c in &res.clusters {
            assert_eq!(c.support, 64);
            assert!((c.support_density() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn k3_single_cluster() {
        // paper: "our algorithm correctly assembles the only one
        // tricluster (A1, A2, A3, A4)"
        let res = run_mmc(&k3(5), &small_cfg()).unwrap();
        assert_eq!(res.clusters.len(), 1);
        assert_eq!(res.clusters[0].components.len(), 4);
        assert_eq!(res.clusters[0].support, 625);
    }

    #[test]
    fn matches_online_miner_on_k1() {
        let ctx = k1(6);
        let mr = run_mmc(&ctx.inner, &small_cfg()).unwrap();
        let mut online = mine_online(&ctx.inner, &Constraints::none());
        online.sort_by(|a, b| a.components.cmp(&b.components));
        assert_eq!(mr.clusters.len(), online.len());
        for (a, b) in mr.clusters.iter().zip(online.iter()) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
    }

    #[test]
    fn fault_injection_does_not_change_output() {
        // duplicates from task retries must be absorbed (the paper's K1-K3
        // robustness argument)
        let ctx = k2(3);
        let clean = run_mmc(&ctx.inner, &small_cfg()).unwrap();
        let faulty = run_mmc(
            &ctx.inner,
            &MmcConfig { fault_prob: 1.0, ..small_cfg() },
        )
        .unwrap();
        assert_eq!(clean.clusters.len(), faulty.clusters.len());
        for (a, b) in clean.clusters.iter().zip(faulty.clusters.iter()) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
    }

    #[test]
    fn density_threshold_filters() {
        // K1(4): full cluster has density (n³-n)/n³ ≈ 0.94; partial-
        // diagonal clusters are denser; θ = 0.99 keeps only those
        let ctx = k1(4);
        let all = run_mmc(&ctx.inner, &small_cfg()).unwrap();
        let filtered = run_mmc(
            &ctx.inner,
            &MmcConfig { theta: 0.95, ..small_cfg() },
        )
        .unwrap();
        assert!(filtered.clusters.len() < all.clusters.len());
    }

    #[test]
    fn combiner_preserves_output_and_cuts_shuffle() {
        // K1 has massive per-subrelation duplication across map tasks?
        // No — within a map task, duplicate (subrel, entity) pairs only
        // arise from retries; with fault injection the combiner absorbs
        // them map-side. Output must be identical either way.
        let ctx = k1(6).inner;
        let base = run_mmc(
            &ctx,
            &MmcConfig { fault_prob: 1.0, ..small_cfg() },
        )
        .unwrap();
        let combined = run_mmc(
            &ctx,
            &MmcConfig { fault_prob: 1.0, combiner: true, ..small_cfg() },
        )
        .unwrap();
        assert_eq!(base.clusters.len(), combined.clusters.len());
        for (a, b) in base.clusters.iter().zip(&combined.clusters) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
        // retried duplicates are folded before the shuffle
        assert!(
            combined.stages[0].shuffle_bytes < base.stages[0].shuffle_bytes,
            "{} !< {}",
            combined.stages[0].shuffle_bytes,
            base.stages[0].shuffle_bytes
        );
    }

    #[test]
    fn reduce_retries_do_not_change_output() {
        let ctx = k2(4).inner;
        let clean = run_mmc(&ctx, &small_cfg()).unwrap();
        // fault_prob drives BOTH map and reduce retries
        let noisy = run_mmc(
            &ctx,
            &MmcConfig { fault_prob: 1.0, seed: 7, ..small_cfg() },
        )
        .unwrap();
        assert_eq!(clean.clusters.len(), noisy.clusters.len());
        let retries: u64 = noisy
            .stages
            .iter()
            .map(|s| s.counters.get(crate::hadoop::counters::names::TASK_RETRIES))
            .sum();
        // every map task AND reduce task retried
        assert!(retries as usize >= noisy.stages[0].reduce_task_ms.len());
    }

    #[test]
    fn stage_stats_populated() {
        let res = run_mmc(&k2(3).inner, &small_cfg()).unwrap();
        for s in &res.stages {
            assert!(!s.map_task_ms.is_empty());
            assert!(s.shuffle_bytes > 0);
        }
        assert!(res.makespan_ms(4) <= res.makespan_ms(1) + 1e-9);
    }
}
