//! Replica shards: read-only copies of the published snapshot on other
//! sim nodes, fed by delta streaming from the primary.
//!
//! The primary publishes every compacted epoch to the [`ReplicaSet`];
//! each replica applies it after a seeded per-replica delivery lag of
//! at most `retained` epochs (the retained window — the primary keeps
//! the last `retained` published snapshots streamable, so a replica can
//! never fall further behind than that without a full resync, which the
//! sim never needs). This gives the staleness bound the equivalence
//! suite enforces:
//!
//! ```text
//! primary_epoch - replica_epoch  <=  retained      (for every replica)
//! ```
//!
//! [`SimRemoteBackend`] is the remote arm of
//! [`crate::serve::QueryBackend`]: constructed for a client node, it
//! routes to the nearest replica (ring distance over node ids) and
//! answers from that replica's applied snapshot — same answer path as
//! [`crate::serve::LocalBackend`], just a possibly-older epoch.

use std::collections::VecDeque;
use std::sync::{Arc, RwLock};

use crate::core::pattern::Cluster;
use crate::serve::backend::{answer_via, Answer, QueryBackend, QueryCache, QueryKey};
use crate::serve::epoch::{EpochSnapshot, IndexStats};
use crate::util::rng::Rng;

/// The replica set as shared between the sim's publisher (compaction)
/// and any number of [`SimRemoteBackend`] readers.
pub type SharedReplicas = Arc<RwLock<ReplicaSet>>;

/// Replica placement + per-replica applied/pending snapshot state.
#[derive(Debug)]
pub struct ReplicaSet {
    /// Node id hosting each replica.
    nodes: Vec<usize>,
    /// Total nodes in the cluster (for ring-distance routing).
    total_nodes: usize,
    /// Retained window: the staleness bound, in epochs.
    retained: u64,
    /// Snapshot each replica currently serves.
    applied: Vec<Arc<EpochSnapshot>>,
    /// Published-but-undelivered snapshots per replica (≤ `retained`).
    pending: Vec<VecDeque<Arc<EpochSnapshot>>>,
    /// Epoch of the last snapshot the primary published.
    primary_epoch: u64,
    /// Seeded delivery-lag stream (deterministic per sim seed).
    rng: Rng,
    publishes: u64,
}

impl ReplicaSet {
    /// Replicas on `nodes` (of a `total_nodes` cluster), lag-bounded by
    /// `retained`, all starting from the empty epoch-0 snapshot.
    pub fn new(nodes: Vec<usize>, total_nodes: usize, retained: u64, seed: u64) -> Self {
        let n = nodes.len();
        Self {
            nodes,
            total_nodes: total_nodes.max(1),
            retained,
            applied: (0..n).map(|_| EpochSnapshot::empty()).collect(),
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            primary_epoch: 0,
            rng: Rng::new(seed ^ 0x5245_504C_4943_41u64),
            publishes: 0,
        }
    }

    /// Number of replicas.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when no replicas are configured.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Node ids hosting the replicas.
    pub fn nodes(&self) -> &[usize] {
        &self.nodes
    }

    /// The retained window (staleness bound, in epochs).
    pub fn retained(&self) -> u64 {
        self.retained
    }

    /// Epoch of the last published snapshot.
    pub fn primary_epoch(&self) -> u64 {
        self.primary_epoch
    }

    /// Snapshots published so far.
    pub fn publishes(&self) -> u64 {
        self.publishes
    }

    /// The snapshot replica `r` currently serves.
    pub fn applied(&self, r: usize) -> Arc<EpochSnapshot> {
        Arc::clone(&self.applied[r])
    }

    /// How many epochs replica `r` trails the primary.
    pub fn staleness(&self, r: usize) -> u64 {
        self.primary_epoch - self.applied[r].epoch()
    }

    /// Largest staleness across the set (0 when empty).
    pub fn max_staleness(&self) -> u64 {
        (0..self.len()).map(|r| self.staleness(r)).max().unwrap_or(0)
    }

    /// Stream a newly published snapshot to every replica. Each replica
    /// applies queued snapshots until its delivery lag (seeded, at most
    /// `retained`) is restored — so after every publish, every replica's
    /// staleness is within the retained window.
    pub fn publish(&mut self, snap: Arc<EpochSnapshot>) {
        self.primary_epoch = snap.epoch();
        self.publishes += 1;
        crate::obs::counter("serve.replica.publishes", 1);
        for r in 0..self.nodes.len() {
            self.pending[r].push_back(Arc::clone(&snap));
            let lag = self.rng.below(self.retained + 1) as usize;
            while self.pending[r].len() > lag {
                let next = self.pending[r].pop_front().expect("len checked");
                self.applied[r] = next;
            }
            debug_assert!(
                self.staleness(r) <= self.retained,
                "replica {r} staleness {} exceeds retained window {}",
                self.staleness(r),
                self.retained
            );
        }
        crate::obs::gauge("serve.replica.staleness", self.max_staleness() as f64);
    }

    /// The replica nearest to `client` by ring distance over node ids
    /// (ties: lower node id, then lower replica index). Returns the
    /// replica INDEX, not the node id.
    pub fn nearest(&self, client: usize) -> Option<usize> {
        let n = self.total_nodes;
        let dist = |node: usize| {
            let d = node.abs_diff(client) % n;
            d.min(n - d)
        };
        (0..self.nodes.len())
            .min_by_key(|&r| (dist(self.nodes[r]), self.nodes[r], r))
    }
}

/// The simulated-remote arm of [`QueryBackend`]: answers from the
/// nearest replica's applied snapshot. Epoch may trail the primary by
/// up to the retained window; within one snapshot, answers are
/// bit-identical to a [`crate::serve::LocalBackend`] over the same
/// epoch (property-tested in `query_plane_equivalence`).
#[derive(Debug)]
pub struct SimRemoteBackend {
    set: SharedReplicas,
    /// Index of the replica this client reads (chosen at construction).
    replica: usize,
    /// The client's node id (kept for display/debugging).
    client_node: usize,
    cache: QueryCache,
}

impl SimRemoteBackend {
    /// Backend for a client on `client_node`, routed to the nearest
    /// replica. None if the set has no replicas.
    pub fn new(set: SharedReplicas, client_node: usize) -> Option<Self> {
        Self::with_cache(set, client_node, true)
    }

    /// Same, with the result cache explicitly on or off.
    pub fn with_cache(set: SharedReplicas, client_node: usize, cache: bool) -> Option<Self> {
        let replica = set.read().expect("replica set poisoned").nearest(client_node)?;
        Some(Self { set, replica, client_node, cache: QueryCache::new(cache) })
    }

    /// The replica index this backend reads.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// The node id this backend's replica lives on.
    pub fn replica_node(&self) -> usize {
        self.set.read().expect("replica set poisoned").nodes()[self.replica]
    }

    /// The client's node id.
    pub fn client_node(&self) -> usize {
        self.client_node
    }

    fn answer(&mut self, key: QueryKey) -> Answer {
        let snap = self.snapshot();
        answer_via(&snap, &mut self.cache, key)
    }
}

impl QueryBackend for SimRemoteBackend {
    fn name(&self) -> &'static str {
        "sim-remote"
    }

    fn snapshot(&self) -> Arc<EpochSnapshot> {
        crate::obs::counter("serve.replica.reads", 1);
        self.set.read().expect("replica set poisoned").applied(self.replica)
    }

    fn top_k(&mut self, k: usize) -> Vec<Cluster> {
        match self.answer(QueryKey::TopK(k)) {
            Answer::Clusters(cs) => cs,
            _ => unreachable!("top_k answers are clusters"),
        }
    }

    fn containing(&mut self, modality: usize, entity: u32) -> Vec<u32> {
        match self.answer(QueryKey::Containing(modality as u8, entity)) {
            Answer::Ids(ids) => ids,
            _ => unreachable!("containing answers are ids"),
        }
    }

    fn entity_stats(&mut self, modality: usize, entity: u32) -> Option<IndexStats> {
        match self.answer(QueryKey::EntityStats(modality as u8, entity)) {
            Answer::Stats(s) => s,
            _ => unreachable!("entity_stats answers are stats"),
        }
    }

    fn stats(&mut self) -> IndexStats {
        match self.answer(QueryKey::Stats) {
            Answer::Stats(Some(s)) => s,
            _ => unreachable!("stats answers are stats"),
        }
    }

    fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;

    fn snap(epoch: u64, support: usize) -> Arc<EpochSnapshot> {
        let mut c = tricluster(vec![0], vec![0], vec![0]);
        c.support = support;
        EpochSnapshot::build(epoch, vec![c], support)
    }

    #[test]
    fn staleness_never_exceeds_retained_window() {
        let retained = 3u64;
        let mut set = ReplicaSet::new(vec![0, 2, 4], 6, retained, 0xABCD);
        for e in 1..=40 {
            set.publish(snap(e, e as usize));
            for r in 0..set.len() {
                assert!(set.staleness(r) <= retained, "replica {r} too stale");
            }
        }
        assert_eq!(set.primary_epoch(), 40);
        assert_eq!(set.publishes(), 40);
    }

    #[test]
    fn retained_zero_means_always_fresh() {
        let mut set = ReplicaSet::new(vec![1], 4, 0, 7);
        for e in 1..=10 {
            set.publish(snap(e, 1));
            assert_eq!(set.staleness(0), 0);
            assert_eq!(set.applied(0).epoch(), e);
        }
    }

    #[test]
    fn nearest_uses_ring_distance() {
        let set = ReplicaSet::new(vec![1, 5], 8, 1, 0);
        // node 0 → node 1 is distance 1; node 5 is distance 3
        assert_eq!(set.nearest(0), Some(0));
        // node 7 → node 5 is distance 2; node 1 is distance 2 — tie
        // breaks to the lower node id (1), replica index 0
        assert_eq!(set.nearest(7), Some(0));
        // node 6 → node 5 is distance 1
        assert_eq!(set.nearest(6), Some(1));
        assert_eq!(ReplicaSet::new(vec![], 8, 1, 0).nearest(0), None);
    }

    #[test]
    fn remote_backend_reads_applied_snapshot() {
        let set: SharedReplicas =
            Arc::new(RwLock::new(ReplicaSet::new(vec![0], 2, 0, 1)));
        let mut be = SimRemoteBackend::new(Arc::clone(&set), 1).expect("one replica");
        assert_eq!(be.epoch(), 0);
        set.write().unwrap().publish(snap(1, 5));
        assert_eq!(be.epoch(), 1, "retained=0 applies immediately");
        assert_eq!(be.top_k(1)[0].support, 5);
        assert_eq!(be.containing(0, 0), vec![0]);
        assert_eq!(be.replica_node(), 0);
        assert_eq!(be.client_node(), 1);
    }
}
