//! The BASIC (offline) prime OAC-triclustering algorithm of [9] (paper
//! §2): precompute all prime sets, generate one tricluster per triple,
//! hash-dedup, optionally check an exact minimal-density threshold.
//!
//! Phase 1 is the stage-1 ingest kernel of [`crate::exec::stages`]
//! (Algs. 2/3 by shared-memory cumulus ingest — output-identical to the
//! backend-generic `stage1_cumuli`, unit-tested there). Phase 2 applies the
//! stage-2 assembly kernel per generating triple — looking its N cumuli
//! up instead of shuffling them, so the wall-clock budget can interrupt
//! between triples — fused with the dedup and the exact density check
//! that makes the basic algorithm the paper's slow baseline (stage 3's
//! support density is NOT the basic algorithm's measure).
//!
//! Complexity (paper §2): `O(|G||M||B| + |I|(|G|+|M|+|B|))` without a
//! density threshold and `O(|I||G||M||B|)` with one — this is the
//! ">3000 s on large contexts" competitor that motivates the online and
//! M/R versions. The budget is checked every 1024 triples, so the
//! blow-up stays observable without hanging the benches.

use std::time::Duration;

use crate::core::context::TriContext;
use crate::core::pattern::{combine_set_fingerprints, Cluster};
use crate::core::tuple::SubRelation;
use crate::exec::stage1_cumuli_ingest;
use crate::util::hash::{set_fingerprint, FxHashMap, FxHashSet};
use crate::util::stats::Timer;

/// Outcome of a budgeted run.
#[derive(Debug)]
pub enum BasicOutcome {
    /// Finished within budget.
    Done {
        /// The deduplicated, density-checked cluster set.
        clusters: Vec<Cluster>,
        /// Wall time spent, ms.
        elapsed_ms: f64,
    },
    /// The time budget expired (the paper reports these as ">3000 s").
    TimedOut {
        /// Triples processed before the budget ran out.
        processed_triples: usize,
        /// Wall time spent, ms.
        elapsed_ms: f64,
    },
}

/// Exact density of a tricluster cuboid: |X×Y×Z ∩ I| / |X||Y||Z| — the
/// `O(|G||M||B|)`-per-cluster check of the basic algorithm.
pub fn exact_density(ctx: &TriContext, c: &Cluster) -> f64 {
    let vol = c.volume();
    if vol == 0.0 {
        return 0.0;
    }
    let mut hit = 0u64;
    for &g in &c.components[0] {
        for &m in &c.components[1] {
            for &b in &c.components[2] {
                if ctx.contains(g, m, b) {
                    hit += 1;
                }
            }
        }
    }
    hit as f64 / vol
}

/// Run the basic algorithm with an optional exact density threshold and a
/// wall-clock budget.
pub fn mine_basic(
    ctx: &TriContext,
    min_density: f64,
    budget: Duration,
) -> BasicOutcome {
    let timer = Timer::start();
    // Phase 1 = stage 1 (Algs. 2/3): cumuli per subrelation key, one
    // linear pass (no budget risk — the expensive part comes next).
    // Sequential kernel: the basic algorithm is the paper's single-thread
    // baseline, so no parallel workers here.
    let cumuli = stage1_cumuli_ingest(ctx.triples(), 3, 1);
    if timer.elapsed() > budget {
        return BasicOutcome::TimedOut { processed_triples: 0, elapsed_ms: timer.elapsed_ms() };
    }
    let index: FxHashMap<SubRelation, usize> =
        cumuli.iter().enumerate().map(|(i, (sub, _))| (*sub, i)).collect();
    // each cumulus is fingerprinted once, not once per sharing triple
    let cum_fp: Vec<u64> = cumuli.iter().map(|(_, c)| set_fingerprint(c)).collect();
    // Phase 2: per-triple assembly (the stage-2 kernel restricted to one
    // generating tuple, via lookup instead of shuffle) + hash dedup + the
    // exact density check. Cumuli are only cloned for first-seen clusters.
    let mut seen: FxHashSet<u64> = FxHashSet::default();
    let mut clusters = Vec::new();
    for (i, t) in ctx.triples().iter().enumerate() {
        if i % 1024 == 0 && timer.elapsed() > budget {
            return BasicOutcome::TimedOut {
                processed_triples: i,
                elapsed_ms: timer.elapsed_ms(),
            };
        }
        let mut comp_at = [0usize; 3];
        for (k, slot) in comp_at.iter_mut().enumerate() {
            *slot = index[&t.subrelation(k)];
        }
        // content fingerprint over the three cumuli — the same scheme as
        // `Cluster::fingerprint` (stage-1 cumuli are already sorted sets)
        let fp =
            combine_set_fingerprints(3, comp_at.iter().map(|&ci| cum_fp[ci]));
        if !seen.insert(fp) {
            continue;
        }
        let comps: Vec<Vec<u32>> =
            comp_at.iter().map(|&ci| cumuli[ci].1.clone()).collect();
        // stage-1 cumuli are sorted + deduped: skip the re-sort
        let mut c = Cluster::from_sorted(comps);
        if min_density > 0.0 {
            // the expensive exact check — the basic algorithm's downfall
            if exact_density(ctx, &c) < min_density {
                continue;
            }
        }
        c.support = 1;
        clusters.push(c);
    }
    BasicOutcome::Done { clusters, elapsed_ms: timer.elapsed_ms() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synthetic::{k1, k2};

    #[test]
    fn k2_blocks_found() {
        let ctx = k2(4);
        match mine_basic(&ctx, 0.0, Duration::from_secs(30)) {
            BasicOutcome::Done { clusters, .. } => {
                // 3 disjoint dense blocks → exactly 3 distinct triclusters
                assert_eq!(clusters.len(), 3);
                for c in &clusters {
                    assert_eq!(c.components[0].len(), 4);
                    assert!((exact_density(&ctx, c) - 1.0).abs() < 1e-12);
                }
            }
            BasicOutcome::TimedOut { .. } => panic!("should finish"),
        }
    }

    #[test]
    fn k1_clusters_with_density() {
        let n = 6usize;
        let ctx = k1(n);
        match mine_basic(&ctx, 0.5, Duration::from_secs(30)) {
            BasicOutcome::Done { clusters, .. } => {
                // 3n + 1 distinct clusters (full cuboid + 3 per diagonal
                // value); all have density ≥ (n²-1)/n² > 0.5 so none are
                // filtered
                assert_eq!(clusters.len(), 3 * n + 1);
                let full = clusters
                    .iter()
                    .find(|c| c.components.iter().all(|comp| comp.len() == n))
                    .expect("full cluster");
                let d = exact_density(&ctx, full);
                assert!((d - (216.0 - 6.0) / 216.0).abs() < 1e-9);
            }
            BasicOutcome::TimedOut { .. } => panic!("should finish"),
        }
    }

    #[test]
    fn budget_expires() {
        let ctx = k1(25); // 15k triples, exact density over 25³ each
        match mine_basic(&ctx, 0.9, Duration::from_millis(1)) {
            BasicOutcome::TimedOut { processed_triples, .. } => {
                assert!(processed_triples < ctx.len());
            }
            BasicOutcome::Done { elapsed_ms, .. } => {
                // extremely fast machines may finish; accept but verify the
                // time was tiny
                assert!(elapsed_ms < 10_000.0);
            }
        }
    }

    #[test]
    fn exact_density_empty_cluster() {
        let ctx = k1(3);
        let c = Cluster::new(vec![vec![], vec![0], vec![0]]);
        assert_eq!(exact_density(&ctx, &c), 0.0);
    }

    #[test]
    fn basic_components_match_online() {
        use crate::oac::{mine_online, Constraints};
        let ctx = k1(5);
        let mut online = mine_online(&ctx.inner, &Constraints::none());
        online.sort_by(|a, b| a.components.cmp(&b.components));
        match mine_basic(&ctx, 0.0, Duration::from_secs(30)) {
            BasicOutcome::Done { mut clusters, .. } => {
                clusters.sort_by(|a, b| a.components.cmp(&b.components));
                assert_eq!(clusters.len(), online.len());
                for (a, b) in clusters.iter().zip(&online) {
                    assert_eq!(a.components, b.components);
                }
            }
            BasicOutcome::TimedOut { .. } => panic!("should finish"),
        }
    }
}
