//! String interning: entity values (movie titles, tags, user names, …) are
//! interned once per modality into dense `u32` ids. The whole pipeline
//! (prime sets, cumuli, shuffle keys) operates on ids; strings only
//! reappear when patterns are printed (paper §5.2 output format).
//!
//! Each name is allocated ONCE: the forward map and the reverse table
//! share the same `Arc<str>` backing, so interning a fresh name costs one
//! string allocation (plus two pointer-sized refs), not two copies.

use std::sync::Arc;

use crate::util::hash::FxHashMap;

/// Bidirectional string↔id map for one modality.
#[derive(Debug, Default, Clone)]
pub struct Interner {
    by_name: FxHashMap<Arc<str>, u32>,
    names: Vec<Arc<str>>,
}

impl Interner {
    /// Empty interner.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-sized for a bulk load of roughly `capacity` distinct names
    /// (dataset generators / TSV ingest), avoiding rehash-and-grow churn.
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            by_name: FxHashMap::with_capacity_and_hasher(capacity, Default::default()),
            names: Vec::with_capacity(capacity),
        }
    }

    /// Intern `name`, returning its stable id.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = self.names.len() as u32;
        let shared: Arc<str> = Arc::from(name);
        self.names.push(Arc::clone(&shared));
        self.by_name.insert(shared, id);
        id
    }

    /// Id of `name`, if it was interned.
    pub fn get(&self, name: &str) -> Option<u32> {
        self.by_name.get(name).copied()
    }

    /// Name of `id` (panics on an id this interner never produced).
    pub fn name(&self, id: u32) -> &str {
        &self.names[id as usize]
    }

    /// Number of interned names.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when nothing was interned.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// All names, in id order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.names.iter().map(|s| &**s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut i = Interner::new();
        let a = i.intern("Toy Story (1995)");
        let b = i.intern("WALL-E (2008)");
        assert_eq!(i.intern("Toy Story (1995)"), a);
        assert_ne!(a, b);
        assert_eq!(i.len(), 2);
        assert_eq!(i.name(a), "Toy Story (1995)");
        assert_eq!(i.get("WALL-E (2008)"), Some(b));
        assert_eq!(i.get("missing"), None);
    }

    #[test]
    fn ids_are_dense() {
        let mut i = Interner::new();
        for k in 0..100 {
            assert_eq!(i.intern(&format!("e{k}")), k);
        }
    }

    #[test]
    fn forward_and_reverse_share_one_allocation() {
        let mut i = Interner::new();
        let id = i.intern("shared");
        let by_id: &str = i.name(id);
        let key = i.by_name.keys().next().unwrap();
        assert!(std::ptr::eq(by_id, &**key), "one backing allocation");
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut i = Interner::with_capacity(1000);
        assert!(i.is_empty());
        assert_eq!(i.intern("x"), 0);
        assert_eq!(i.intern("y"), 1);
        assert_eq!(i.names().collect::<Vec<_>>(), vec!["x", "y"]);
    }
}
