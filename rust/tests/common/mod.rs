//! Shared fixtures for the integration-test suites.
//!
//! Every equivalence/invariant suite used to carry its own copy of the
//! same three helpers; they live here once now. Pulled in per test
//! crate with `mod common;` (the test targets are path-declared in
//! Cargo.toml, so each file is its own crate and sees this module
//! relative to `rust/tests/`).
//!
//! Not every suite uses every helper — hence the file-level
//! `dead_code` allow.
#![allow(dead_code)]

use tricluster::core::context::PolyContext;
use tricluster::core::pattern::{diff_cluster_sets, sort_clusters, Cluster};
use tricluster::core::tuple::NTuple;
use tricluster::exec::cluster_sim::ChurnConfig;
use tricluster::util::proptest_lite::Gen;
use tricluster::util::rng::Rng;

/// A random polyadic context: `n` tuples with ids drawn uniformly below
/// `universe` in each of `arity` modalities. Small universes force
/// heavy cumulus sharing — the regime where merging/dedup goes wrong.
pub fn random_ctx(g: &mut Gen, arity: usize, universe: u32, n: usize) -> PolyContext {
    let mut ctx = PolyContext::new(arity);
    for _ in 0..n {
        let ids: Vec<u32> = (0..arity).map(|_| g.u32_below(universe)).collect();
        ctx.add_ids(&ids);
    }
    ctx
}

/// A DISTINCT-tuple seeded triadic context: exactly `n` distinct random
/// triples below `universe` (asserts the universe can hold them). Use
/// when a test's bookkeeping assumes no duplicate tuples; replayable
/// from the seed.
pub fn distinct_ctx(seed: u64, n: usize, universe: u64) -> PolyContext {
    assert!(universe * universe * universe > n as u64, "universe too small");
    let mut ctx = PolyContext::new(3);
    let mut rng = Rng::new(seed);
    while ctx.len() < n {
        ctx.add_ids(&[
            rng.below(universe) as u32,
            rng.below(universe) as u32,
            rng.below(universe) as u32,
        ]);
    }
    ctx
}

/// Canonical order for cluster-set comparison (sorted component sets
/// make the order of generation irrelevant).
pub fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
    sort_clusters(&mut cs);
    cs
}

/// THE equivalence predicate: canonically-ordered cluster sets must
/// match on components and supports (density is derived from both, so
/// it cannot diverge independently).
pub fn assert_same(a: &[Cluster], b: &[Cluster], label: &str) -> Result<(), String> {
    match diff_cluster_sets(a, b) {
        Some(diff) => Err(format!("{label}: {diff}")),
        None => Ok(()),
    }
}

/// A seeded churn schedule (kill probability per wave, restart delay).
pub fn churn(kill_prob: f64, restart_ms: f64) -> ChurnConfig {
    ChurnConfig { kill_prob, restart_ms }
}

/// Split a context's tuples into one stream per tenant, dealt
/// round-robin — the default way multi-tenant tests share one dataset.
pub fn deal_streams(ctx: &PolyContext, tenants: usize) -> Vec<Vec<NTuple>> {
    (0..tenants)
        .map(|t| ctx.tuples().iter().skip(t).step_by(tenants).copied().collect())
        .collect()
}
