//! Cross-cutting substrates: PRNG, hashing, stats, thread pool, CLI/JSON
//! parsing, table rendering, and a property-testing harness.
//!
//! Everything in this module exists because the offline crate set has no
//! rand/rayon/clap/serde_json/proptest — see DESIGN.md §Substitutions.

pub mod cli;
pub mod hash;
pub mod json;
pub mod pool;
pub mod proptest_lite;
pub mod rng;
pub mod stats;
pub mod table;
