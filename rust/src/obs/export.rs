//! Surfacing: Chrome-trace JSONL, the JSON metrics snapshot, and the
//! stderr text table.
//!
//! * [`write_trace`] — one `trace_event` JSON object per line
//!   (`name`/`ph`/`ts`/`pid`/`tid`, `E` lines carry
//!   `args.{records_in,records_out,bytes}`). Load it in
//!   `chrome://tracing` or <https://ui.perfetto.dev> ("Open trace
//!   file"); both accept newline-delimited event objects.
//! * [`write_metrics`] — `{"schema":"tricluster-metrics-v1", counters,
//!   gauges, histograms}` on a single line via [`crate::util::json`].
//! * [`render_table`] — the `MetricsReport` text table `main.rs`
//!   prints to stderr when telemetry is on.
//!
//! Schema validity of both files is CI-gated by `ci/check_trace.rs`.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

use super::recorder::Snapshot;
use super::span::TraceEvent;

/// Schema tag stamped into every metrics snapshot.
pub const METRICS_SCHEMA: &str = "tricluster-metrics-v1";

/// All events in one simulated process for the trace viewer.
pub const TRACE_PID: u64 = 1;

/// Render one event as a compact Chrome `trace_event` JSON object.
pub fn event_json(ev: &TraceEvent) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("name".into(), Json::Str(ev.name.clone()));
    obj.insert("ph".into(), Json::Str(if ev.begin { "B" } else { "E" }.into()));
    obj.insert("ts".into(), Json::Num(ev.ts_us as f64));
    obj.insert("pid".into(), Json::Num(TRACE_PID as f64));
    obj.insert("tid".into(), Json::Num(ev.tid as f64));
    if !ev.begin && (ev.records_in | ev.records_out | ev.bytes) != 0 {
        let mut args = BTreeMap::new();
        args.insert("records_in".into(), Json::Num(ev.records_in as f64));
        args.insert("records_out".into(), Json::Num(ev.records_out as f64));
        args.insert("bytes".into(), Json::Num(ev.bytes as f64));
        obj.insert("args".into(), Json::Obj(args));
    }
    Json::Obj(obj)
}

/// Write `events` as Chrome-trace JSONL (one event object per line).
pub fn write_trace(path: &Path, events: &[TraceEvent]) -> std::io::Result<()> {
    let mut out = String::new();
    for ev in events {
        out.push_str(&event_json(ev).to_string());
        out.push('\n');
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(out.as_bytes())
}

/// The JSON form of a metrics snapshot
/// (`schema = `[`METRICS_SCHEMA`]).
pub fn snapshot_json(snap: &Snapshot) -> Json {
    let counters: BTreeMap<String, Json> = snap
        .counters
        .iter()
        .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
        .collect();
    let gauges: BTreeMap<String, Json> =
        snap.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect();
    let hists: BTreeMap<String, Json> = snap
        .hists
        .iter()
        .map(|(k, h)| {
            let mut o = BTreeMap::new();
            o.insert("count".into(), Json::Num(h.count as f64));
            o.insert("sum".into(), Json::Num(h.sum as f64));
            o.insert(
                "min".into(),
                Json::Num(if h.count == 0 { 0.0 } else { h.min as f64 }),
            );
            o.insert("max".into(), Json::Num(h.max as f64));
            o.insert("p50".into(), Json::Num(h.quantile(0.5) as f64));
            o.insert("p95".into(), Json::Num(h.quantile(0.95) as f64));
            o.insert(
                "buckets".into(),
                Json::Arr(h.buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
            );
            (k.clone(), Json::Obj(o))
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str(METRICS_SCHEMA.into()));
    root.insert("counters".into(), Json::Obj(counters));
    root.insert("gauges".into(), Json::Obj(gauges));
    root.insert("histograms".into(), Json::Obj(hists));
    Json::Obj(root)
}

/// Write the metrics snapshot JSON to `path`.
pub fn write_metrics(path: &Path, snap: &Snapshot) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", snapshot_json(snap)))
}

/// The `MetricsReport` text table: counters, gauges, and histogram
/// summaries, aligned, one section each — printed to stderr by the CLI
/// when telemetry is on.
pub fn render_table(snap: &Snapshot) -> String {
    let mut out = String::from("== metrics report ==\n");
    if snap.is_empty() {
        out.push_str("(nothing recorded)\n");
        return out;
    }
    let key_w = snap
        .counters
        .keys()
        .chain(snap.gauges.keys())
        .chain(snap.hists.keys())
        .map(String::len)
        .max()
        .unwrap_or(0);
    if !snap.counters.is_empty() {
        out.push_str("counters:\n");
        for (k, v) in &snap.counters {
            out.push_str(&format!("  {k:<key_w$}  {v}\n"));
        }
    }
    if !snap.gauges.is_empty() {
        out.push_str("gauges:\n");
        for (k, v) in &snap.gauges {
            out.push_str(&format!("  {k:<key_w$}  {v:.3}\n"));
        }
    }
    if !snap.hists.is_empty() {
        out.push_str("histograms:            count        mean         p50         p95         max\n");
        for (k, h) in &snap.hists {
            out.push_str(&format!(
                "  {k:<key_w$}  {:>7}  {:>10.1}  {:>10}  {:>10}  {:>10}\n",
                h.count,
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.95),
                h.max
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    #[test]
    fn trace_jsonl_lines_parse_and_balance() {
        let _g = obs::tests::lock();
        obs::reset();
        obs::enable();
        {
            let _a = crate::span!("t.exp.outer");
            let mut b = crate::span!("t.exp.inner");
            b.records_in(2);
            b.bytes(128);
        }
        let events = obs::take_trace();
        obs::disable();
        obs::reset();
        let mut depth = 0i64;
        for ev in &events {
            let j = Json::parse(&event_json(ev).to_string()).unwrap();
            assert!(j.get("name").unwrap().as_str().is_some());
            let ph = j.get("ph").unwrap().as_str().unwrap();
            assert!(ph == "B" || ph == "E");
            assert!(j.get("ts").unwrap().as_f64().is_some());
            assert_eq!(j.get("pid").unwrap().as_usize(), Some(TRACE_PID as usize));
            assert!(j.get("tid").unwrap().as_f64().is_some());
            depth += if ph == "B" { 1 } else { -1 };
            assert!(depth >= 0);
        }
        assert_eq!(depth, 0, "B/E balanced");
        // the inner E carries its args
        let inner_end = events
            .iter()
            .find(|e| !e.begin && e.name == "t.exp.inner")
            .unwrap();
        let j = Json::parse(&event_json(inner_end).to_string()).unwrap();
        let args = j.get("args").unwrap();
        assert_eq!(args.get("records_in").unwrap().as_usize(), Some(2));
        assert_eq!(args.get("bytes").unwrap().as_usize(), Some(128));
    }

    #[test]
    fn snapshot_json_schema_and_table() {
        let _g = obs::tests::lock();
        obs::reset();
        obs::enable();
        obs::counter("t.exp.count", 9);
        obs::gauge("t.exp.gauge", 2.5);
        obs::observe("t.exp.lat.us", 300);
        let snap = obs::snapshot();
        obs::disable();
        obs::reset();
        let j = Json::parse(&snapshot_json(&snap).to_string()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(METRICS_SCHEMA));
        assert_eq!(
            j.get("counters").unwrap().get("t.exp.count").unwrap().as_usize(),
            Some(9)
        );
        let h = j.get("histograms").unwrap().get("t.exp.lat.us").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize(), Some(1));
        assert_eq!(
            h.get("buckets").unwrap().as_arr().unwrap().len(),
            crate::obs::recorder::HIST_BUCKETS
        );
        let table = render_table(&snap);
        assert!(table.contains("t.exp.count"));
        assert!(table.contains("t.exp.gauge"));
        assert!(table.contains("t.exp.lat.us"));
        assert!(render_table(&Snapshot::default()).contains("nothing recorded"));
    }
}
