//! END-TO-END DRIVER (see DESIGN.md / EXPERIMENTS.md §E2E): the full
//! system on a real-shaped workload — a MovieLens-scale 4-ary relation
//! pushed through the three-stage MapReduce pipeline on the simulated
//! cluster, with DFS replication accounting, fault injection, and the
//! paper's headline metric: M/R speedup over the online baseline as
//! data grows.
//!
//! Run: `cargo run --release --example movielens_pipeline [-- --tuples N]`

use tricluster::datasets::{movielens, MovielensParams};
use tricluster::hadoop::counters::names;
use tricluster::mmc::{run_mmc, MmcConfig};
use tricluster::oac::{mine_online, Constraints};
use tricluster::util::cli::Args;
use tricluster::util::stats::Timer;
use tricluster::util::table::fmt_ms;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let max: usize = args.parse_or("tuples", 100_000);
    let nodes: usize = args.parse_or("nodes", 10);
    println!("== MovieLens end-to-end pipeline (up to {max} tuples, {nodes} sim nodes) ==\n");

    let mut prev_speedup = 0.0;
    for n in [max / 10, max / 4, max / 2, max] {
        let ctx = movielens(&MovielensParams::with_tuples(n));

        // online baseline
        let t = Timer::start();
        let online = mine_online(&ctx, &Constraints::none());
        let online_ms = t.elapsed_ms();

        // distributed pipeline with realistic imperfections:
        // 5% task retry probability, replication factor 3
        let cfg = MmcConfig {
            map_tasks: nodes * 4,
            reduce_tasks: nodes * 4,
            fault_prob: 0.05,
            replication: 3,
            ..MmcConfig::default()
        };
        let res = run_mmc(&ctx, &cfg)?;
        assert_eq!(
            res.clusters.len(),
            online.len(),
            "distributed result must match the online baseline"
        );

        let makespan = res.makespan_ms(nodes);
        let speedup = online_ms / makespan.max(1e-9);
        let retries: u64 = res
            .stages
            .iter()
            .map(|s| s.counters.get(names::TASK_RETRIES))
            .sum();
        let repl_bytes: u64 = res
            .stages
            .iter()
            .map(|s| s.counters.get(names::REPLICATED_BYTES))
            .sum();
        println!(
            "{n:>8} tuples | online {o:>8} ms | M/R wall {w:>8} ms | {nodes}-node makespan {m:>8} ms | speedup {s:>5.2}x",
            o = fmt_ms(online_ms),
            w = fmt_ms(res.wall_ms),
            m = fmt_ms(makespan),
            s = speedup,
        );
        println!(
            "          stages {a} / {b} / {c} ms | {k} clusters | {r} retries | shuffle {sb} MiB (x3 repl: {rb} MiB)",
            a = fmt_ms(res.stages[0].wall_ms),
            b = fmt_ms(res.stages[1].wall_ms),
            c = fmt_ms(res.stages[2].wall_ms),
            k = res.clusters.len(),
            r = retries,
            sb = res.shuffle_bytes() >> 20,
            rb = repl_bytes >> 20,
        );
        prev_speedup = speedup;
    }

    println!(
        "\nheadline: simulated {nodes}-node M/R reaches {prev_speedup:.1}x over online at {max} tuples"
    );
    println!("paper shape: speedup grows with |I| (Table 4 / Fig. 2) — reproduced above.");
    Ok(())
}
