//! Experiment runners — one per paper table/figure (see DESIGN.md §5).
//!
//! Each runner regenerates the corresponding artefact's rows: same
//! methods, same workloads (paper-size with `full`, scaled-down for quick
//! runs), and reports both measured wall time on this machine and the
//! virtual r-node cluster makespan (see hadoop::task).

use anyhow::Result;

use crate::coordinator::report::Report;
use crate::core::context::PolyContext;
use crate::datasets;
use crate::exec::{run_named, run_pipeline, ExecTuning, BACKENDS};
use crate::mmc::{run_mmc, MmcConfig, MmcResult};
use crate::noac::{mine_noac, NoacParams};
use crate::oac::{mine_online, Constraints};
use crate::obs::time_ms;
use crate::row;
use crate::util::table::fmt_ms;

/// Experiment scaling + cluster-simulation knobs.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Paper-scale workloads (false = ~10× smaller quick mode).
    pub full: bool,
    /// Simulated cluster size for virtual makespans.
    pub nodes: usize,
    /// Threshold θ for the third reduce.
    pub theta: f64,
    /// Repetitions (the paper averages 5 runs).
    pub runs: usize,
    /// Seed for dataset generation and fault injection.
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        Self { full: false, nodes: 10, theta: 0.0, runs: 1, seed: 42 }
    }
}

impl ExpConfig {
    fn mmc(&self) -> MmcConfig {
        MmcConfig {
            theta: self.theta,
            seed: self.seed,
            // enough tasks for the JobTracker to balance r nodes (§1:
            // "the number of tasks should be larger than the number of
            // working nodes")
            map_tasks: (self.nodes * 4).max(8),
            reduce_tasks: (self.nodes * 4).max(8),
            ..MmcConfig::default()
        }
    }

    /// The Table-3 dataset list (name → context).
    pub fn table3_datasets(&self) -> Vec<(&'static str, PolyContext)> {
        use datasets::*;
        if self.full {
            vec![
                ("IMDB", imdb(&ImdbParams::default()).inner),
                ("MovieLens100k", movielens(&MovielensParams::with_tuples(100_000))),
                ("K1", k1(60).inner),
                ("K2", k2(50).inner),
                ("K3", k3(30)),
            ]
        } else {
            vec![
                ("IMDB", imdb(&ImdbParams::default()).inner),
                ("MovieLens100k~", movielens(&MovielensParams::with_tuples(10_000))),
                ("K1~", k1(26).inner),
                ("K2~", k2(22).inner),
                ("K3~", k3(14)),
            ]
        }
    }

    /// The Table-4 series (name → context).
    pub fn table4_datasets(&self) -> Vec<(&'static str, PolyContext)> {
        use datasets::*;
        if self.full {
            vec![
                ("MovieLens100k", movielens(&MovielensParams::with_tuples(100_000))),
                ("MovieLens250k", movielens(&MovielensParams::with_tuples(250_000))),
                ("MovieLens500k", movielens(&MovielensParams::with_tuples(500_000))),
                ("MovieLens1M", movielens(&MovielensParams::with_tuples(1_000_000))),
                ("Bibsonomy", bibsonomy(&BibsonomyParams::default()).inner),
            ]
        } else {
            vec![
                ("MovieLens10k", movielens(&MovielensParams::with_tuples(10_000))),
                ("MovieLens25k", movielens(&MovielensParams::with_tuples(25_000))),
                ("MovieLens50k", movielens(&MovielensParams::with_tuples(50_000))),
                ("MovieLens100k", movielens(&MovielensParams::with_tuples(100_000))),
                ("Bibsonomy~", bibsonomy(&BibsonomyParams::scaled(80_000)).inner),
            ]
        }
    }
}

/// Measured pair of methods on one dataset.
pub struct Measured {
    /// Online OAC wall time, averaged over `runs`, ms.
    pub online_ms: f64,
    /// The M/R run (clusters + per-stage stats).
    pub mr: MmcResult,
    /// Cluster count of the online run (must match the M/R count).
    pub online_clusters: usize,
}

/// Run online OAC and M/R multimodal clustering on one context,
/// averaging `runs` repetitions of the timing.
pub fn measure_both(ctx: &PolyContext, cfg: &ExpConfig) -> Result<Measured> {
    let mut online_ms = 0.0;
    let mut online_clusters = 0;
    for _ in 0..cfg.runs.max(1) {
        // time_ms measures with or without the recorder; with telemetry
        // on, each repetition also lands as an `exp.online` span
        let (out, ms) = time_ms("exp.online", || {
            mine_online(
                ctx,
                &Constraints { min_density: cfg.theta, min_support: 0 },
            )
        });
        online_ms += ms;
        online_clusters = out.len();
    }
    online_ms /= cfg.runs.max(1) as f64;
    let mr = run_mmc(ctx, &cfg.mmc())?;
    Ok(Measured { online_ms, mr, online_clusters })
}

/// Table 3: online OAC vs three-stage M/R runtime per dataset.
pub fn table3(cfg: &ExpConfig) -> Result<Report> {
    let sets = cfg.table3_datasets();
    let mut header = vec!["Method".to_string()];
    header.extend(sets.iter().map(|(n, _)| n.to_string()));
    let mut online_row = vec!["Online OAC prime clustering".to_string()];
    let mut mr_row = vec!["MapReduce multimodal clustering".to_string()];
    let mut mr_sim = vec![format!("M/R virtual {}-node makespan", cfg.nodes)];
    let mut sizes = vec!["#tuples".to_string()];
    for (_name, ctx) in &sets {
        let m = measure_both(ctx, cfg)?;
        online_row.push(fmt_ms(m.online_ms));
        mr_row.push(fmt_ms(m.mr.wall_ms));
        mr_sim.push(fmt_ms(m.mr.makespan_ms(cfg.nodes)));
        sizes.push(ctx.len().to_string());
    }
    let mut r = Report::new("Table 3: multimodal clustering time, ms", header);
    r.push(sizes);
    r.push(online_row);
    r.push(mr_row);
    r.push(mr_sim);
    Ok(r)
}

/// Table 4: the MovieLens scaling series + BibSonomy, with the per-stage
/// breakdown and cluster counts.
pub fn table4(cfg: &ExpConfig) -> Result<Report> {
    let mut r = Report::new(
        "Table 4: M/R stages and cluster counts",
        vec![
            "Dataset".into(),
            "#tuples".into(),
            "Online ms".into(),
            "M/R total ms".into(),
            "1st".into(),
            "2nd".into(),
            "3rd".into(),
            "#clusters".into(),
            format!("M/R {}-node ms", cfg.nodes),
        ],
    );
    for (name, ctx) in cfg.table4_datasets() {
        let m = measure_both(&ctx, cfg)?;
        r.push(row![
            name,
            ctx.len(),
            fmt_ms(m.online_ms),
            fmt_ms(m.mr.wall_ms),
            fmt_ms(m.mr.stages[0].wall_ms),
            fmt_ms(m.mr.stages[1].wall_ms),
            fmt_ms(m.mr.stages[2].wall_ms),
            m.mr.clusters.len(),
            fmt_ms(m.mr.makespan_ms(cfg.nodes))
        ]);
    }
    Ok(r)
}

/// Figure 2: performance curves — relative speedup of M/R (virtual
/// r-node) over online per dataset size.
pub fn fig2(cfg: &ExpConfig) -> Result<Report> {
    use datasets::*;
    let sizes: &[usize] = if cfg.full {
        &[3_818, 100_000, 250_000, 500_000, 1_000_000]
    } else {
        &[3_818, 10_000, 25_000, 50_000, 100_000]
    };
    let mut r = Report::new(
        "Figure 2: performance curves (series)",
        vec![
            "Dataset".into(),
            "#tuples".into(),
            "Online ms".into(),
            "M/R wall ms".into(),
            format!("M/R {}-node ms", cfg.nodes),
            "speedup (online / M/R nodes)".into(),
        ],
    );
    // the IMDB point (I in Fig. 2)
    let imdb_ctx = imdb(&ImdbParams::default()).inner;
    let m = measure_both(&imdb_ctx, cfg)?;
    let sim = m.mr.makespan_ms(cfg.nodes);
    r.push(row![
        "I",
        imdb_ctx.len(),
        fmt_ms(m.online_ms),
        fmt_ms(m.mr.wall_ms),
        fmt_ms(sim),
        format!("{:.2}", m.online_ms / sim.max(1e-9))
    ]);
    // the MovieLens curve (M100K … M)
    for &n in &sizes[1..] {
        let ctx = movielens(&MovielensParams::with_tuples(n));
        let m = measure_both(&ctx, cfg)?;
        let sim = m.mr.makespan_ms(cfg.nodes);
        r.push(row![
            format!("M{}k", n / 1000),
            n,
            fmt_ms(m.online_ms),
            fmt_ms(m.mr.wall_ms),
            fmt_ms(sim),
            format!("{:.2}", m.online_ms / sim.max(1e-9))
        ]);
    }
    Ok(r)
}

/// Table 5 + Figure 3: NOAC regular vs parallel over the tri-frames
/// sweep, for both parameter settings.
pub fn table5(cfg: &ExpConfig, workers: usize) -> Result<Report> {
    use datasets::triframes::{triframes, TriframesParams};
    let sizes: Vec<usize> = if cfg.full {
        vec![1_000, 10_000, 20_000, 30_000, 40_000, 50_000,
             60_000, 70_000, 80_000, 90_000, 100_000]
    } else {
        vec![1_000, 2_000, 5_000, 10_000, 15_000, 20_000]
    };
    let max = *sizes.last().unwrap();
    let ctx = triframes(&TriframesParams::with_triples(max));
    let settings = [
        ("NOAC(100, 0.8, 2)", NoacParams::table5_strict()),
        ("NOAC(100, 0.5, 0)", NoacParams::table5_loose()),
    ];
    let mut r = Report::new(
        "Table 5: NOAC regular vs parallel",
        vec![
            "Experiment".into(),
            "Time, ms (regular)".into(),
            format!("Time, ms (parallel x{workers})"),
            "# Triclusters".into(),
        ],
    );
    for (label, params) in settings {
        for &n in &sizes {
            if label.contains("0.5") && !cfg.full && n > 10_000 {
                continue; // loose setting is denser; cap quick runs
            }
            if label.contains("0.5")
                && cfg.full
                && ![1_000, 10_000, 50_000, 100_000].contains(&n)
            {
                continue; // the paper reports 4 sizes for the loose setting
            }
            let (out_seq, seq_ms) =
                time_ms("exp.noac.seq", || mine_noac(&ctx, &params, n, 1));
            let (out_par, par_ms) =
                time_ms("exp.noac.par", || mine_noac(&ctx, &params, n, workers));
            assert_eq!(out_seq.len(), out_par.len(), "parallel must match");
            r.push(row![
                format!("{label} {}k", n / 1000),
                fmt_ms(seq_ms),
                fmt_ms(par_ms),
                out_seq.len()
            ]);
        }
    }
    Ok(r)
}

/// Backend matrix: the identical cumuli → assembly → dedup+density
/// pipeline across all four `exec::` backends — the Tables 3–5 regime
/// comparison (§2 sequential vs §4 MapReduce vs §6 threads vs §7 Spark)
/// as one sweep over the unified layer.
pub fn backends(cfg: &ExpConfig, workers: usize) -> Result<Report> {
    use datasets::*;
    let sets: Vec<(&'static str, PolyContext)> = if cfg.full {
        vec![
            ("K1", k1(26).inner),
            ("K2", k2(22).inner),
            ("MovieLens50k", movielens(&MovielensParams::with_tuples(50_000))),
        ]
    } else {
        vec![
            ("K1~", k1(12).inner),
            ("K2~", k2(8).inner),
            ("MovieLens10k~", movielens(&MovielensParams::with_tuples(10_000))),
        ]
    };
    let tune = ExecTuning {
        workers,
        tasks: (cfg.nodes * 4).max(8),
        seed: cfg.seed,
        ..ExecTuning::default()
    };
    let mut header = vec!["Backend".to_string()];
    header.extend(sets.iter().map(|(n, _)| n.to_string()));
    let mut r = Report::new(
        &format!("Backend matrix: pipeline time, ms (x{workers} workers)"),
        header,
    );
    let mut sizes = vec!["#tuples".to_string()];
    for (_name, ctx) in &sets {
        sizes.push(ctx.len().to_string());
    }
    r.push(sizes);
    // reference cluster set per dataset (components + supports), filled by
    // the first backend; every later backend must reproduce it exactly
    let mut reference: Vec<Option<Vec<crate::core::pattern::Cluster>>> =
        (0..sets.len()).map(|_| None).collect();
    for backend in BACKENDS {
        let mut row = vec![backend.to_string()];
        for (i, (name, ctx)) in sets.iter().enumerate() {
            let mut best = f64::INFINITY;
            let mut clusters = Vec::new();
            for _ in 0..cfg.runs.max(1) {
                let run = run_named(backend, ctx, cfg.theta, &tune)?;
                best = best.min(run.wall_ms);
                clusters = run.clusters;
            }
            match &reference[i] {
                Some(expected) => {
                    if let Some(diff) =
                        crate::core::pattern::diff_cluster_sets(expected, &clusters)
                    {
                        anyhow::bail!(
                            "backend {backend} changed the {name} cluster set: {diff}"
                        );
                    }
                }
                None => reference[i] = Some(clusters),
            }
            row.push(fmt_ms(best));
        }
        r.push(row);
    }
    Ok(r)
}

/// Cluster-scaling: the simulated N-node sweep (mirrors the paper's
/// Fig. 2 regime, but with distribution itself as the variable) —
/// simulated makespan and speedup vs 1 node, speculation on and off,
/// under `straggler_prob` stragglers. Uses the per-record cost model so
/// the numbers are machine-independent, and checks every configuration
/// against `oac::mine_online`.
pub fn cluster_scaling(cfg: &ExpConfig, straggler_prob: f64) -> Result<Report> {
    use crate::core::pattern::{diff_cluster_sets, sort_clusters};
    let ctx = if cfg.full {
        datasets::movielens(&datasets::MovielensParams::with_tuples(100_000))
    } else {
        datasets::movielens(&datasets::MovielensParams::with_tuples(10_000))
    };
    let mut reference = crate::oac::mine_online(
        &ctx,
        &Constraints { min_density: cfg.theta, min_support: 0 },
    );
    sort_clusters(&mut reference);
    let mut node_counts = vec![1usize, 2, 4, 8];
    if !node_counts.contains(&cfg.nodes) {
        node_counts.push(cfg.nodes);
        node_counts.sort_unstable();
    }
    let mut r = Report::new(
        &format!(
            "Cluster scaling: simulated makespan, {} tuples, {:.0}% stragglers",
            ctx.len(),
            straggler_prob * 100.0
        ),
        vec![
            "Nodes".into(),
            "Makespan ms (spec on)".into(),
            "Speedup (spec on)".into(),
            "Makespan ms (spec off)".into(),
            "Speedup (spec off)".into(),
            "Spec launched/won".into(),
        ],
    );
    let mut base = [f64::NAN; 2]; // 1-node makespan per speculation mode
    for &nodes in &node_counts {
        let mut cells: Vec<String> = vec![nodes.to_string()];
        let mut spec_cell = String::new();
        for (mode, speculation) in [(0usize, true), (1usize, false)] {
            let tune = ExecTuning {
                nodes,
                straggler_prob,
                speculation,
                seed: cfg.seed,
                cost_ms_per_record: Some(0.002),
                ..ExecTuning::default()
            };
            let backend = tune.cluster_backend()?;
            let mut clusters = run_pipeline(&backend, &ctx, cfg.theta, false)?;
            sort_clusters(&mut clusters);
            if let Some(diff) = diff_cluster_sets(&reference, &clusters) {
                anyhow::bail!("cluster backend diverged at {nodes} nodes: {diff}");
            }
            let makespan = backend.sim_makespan_ms();
            if nodes == node_counts[0] {
                base[mode] = makespan;
            }
            cells.push(fmt_ms(makespan));
            cells.push(format!("{:.2}x", base[mode] / makespan));
            if speculation {
                let stats = backend.take_stats();
                let launched: usize = stats.iter().map(|s| s.spec_launched).sum();
                let won: usize = stats.iter().map(|s| s.spec_wins).sum();
                spec_cell = format!("{launched}/{won}");
            }
        }
        cells.push(spec_cell);
        r.push(cells);
    }
    Ok(r)
}

/// Serve-on-cluster: the sharded serving layer placed on a simulated
/// N-node cluster (`serve::cluster::ServeSim`) — placement policy ×
/// churn sweep under a skewed ingress, reporting simulated makespan,
/// drain-path shuffle volume, recovery traffic, and kill/replay
/// counters. Every configuration is checked against `oac::mine_online`,
/// so a divergence (e.g. a broken churn replay) fails the experiment.
pub fn serve_cluster(cfg: &ExpConfig, churn_prob: f64) -> Result<Report> {
    use crate::core::pattern::{diff_cluster_sets, sort_clusters};
    use crate::exec::cluster_sim::ChurnConfig;
    use crate::serve::cluster::{ServeSim, ServeSimConfig};

    let ctx = if cfg.full {
        datasets::movielens(&datasets::MovielensParams::with_tuples(100_000))
    } else {
        datasets::movielens(&datasets::MovielensParams::with_tuples(10_000))
    };
    let mut reference = mine_online(
        &ctx,
        &Constraints { min_density: cfg.theta, min_support: 0 },
    );
    sort_clusters(&mut reference);
    let nodes = cfg.nodes.clamp(2, 8);
    let shards = nodes * 4;
    let mut r = Report::new(
        &format!(
            "Serve-on-cluster: {} tuples, {nodes} nodes x {shards} shards, skewed ingress",
            ctx.len()
        ),
        vec![
            "Placement".into(),
            "Churn".into(),
            "Makespan ms".into(),
            "Shuffle MiB".into(),
            "Recovery MiB".into(),
            "Kills".into(),
            "Replayed".into(),
            "Migrations".into(),
            "#clusters".into(),
        ],
    );
    for placement in ["rr", "locality", "least"] {
        for churn in [0.0, churn_prob] {
            let mut sim_cfg = ServeSimConfig::new(ctx.arity(), shards, nodes);
            sim_cfg.placement = placement.into();
            sim_cfg.slots_per_node = 8;
            sim_cfg.batch = 2_048;
            sim_cfg.compact_every = 2;
            sim_cfg.source_skew = 2.0;
            sim_cfg.churn = ChurnConfig { kill_prob: churn, restart_ms: 50.0 };
            sim_cfg.seed = cfg.seed;
            sim_cfg.constraints =
                Constraints { min_density: cfg.theta, min_support: 0 };
            let mut sim = ServeSim::new(sim_cfg)?;
            sim.run(ctx.tuples());
            let mut clusters = sim.clusters().to_vec();
            sort_clusters(&mut clusters);
            if let Some(diff) = diff_cluster_sets(&reference, &clusters) {
                anyhow::bail!(
                    "serve-cluster diverged from mine_online \
                     ({placement}, churn={churn}): {diff}"
                );
            }
            let clusters = clusters.len();
            let s = sim.stats().clone();
            r.push(row![
                placement,
                format!("{churn:.2}"),
                fmt_ms(sim.sim_makespan_ms()),
                format!("{:.2}", s.shuffle_mib),
                format!("{:.2}", s.recovery_mib),
                s.kills,
                s.replayed_tuples,
                s.migrations,
                clusters
            ]);
        }
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig { full: false, nodes: 4, theta: 0.0, runs: 1, seed: 1 }
    }

    #[test]
    fn measure_both_counts_match() {
        let cfg = tiny();
        let ctx = datasets::k2(4).inner;
        let m = measure_both(&ctx, &cfg).unwrap();
        // M/R after dedup and online after post-processing agree
        assert_eq!(m.mr.clusters.len(), m.online_clusters);
        assert_eq!(m.mr.clusters.len(), 3);
    }

    #[test]
    fn table3_report_shape() {
        let mut cfg = tiny();
        // shrink further for test speed: swap in micro datasets
        cfg.runs = 1;
        let sets = cfg.table3_datasets();
        assert_eq!(sets.len(), 5);
        // just exercise the report structure on the two smallest
        let m = measure_both(&sets[0].1, &cfg).unwrap();
        assert!(m.online_ms >= 0.0);
        assert_eq!(m.mr.stages.len(), 3);
    }

    #[test]
    fn backend_matrix_report_shape() {
        let r = backends(&tiny(), 2).unwrap();
        // header row + sizes row + one row per backend
        assert_eq!(r.rows.len(), 2 + BACKENDS.len());
        assert_eq!(r.rows[1][0], "#tuples");
        assert_eq!(r.rows[2][0], "seq");
    }

    #[test]
    fn serve_cluster_sweeps_policies_and_checks_equivalence() {
        let r = serve_cluster(&tiny(), 0.3).unwrap();
        // header + 3 placements × 2 churn settings
        assert_eq!(r.rows.len(), 7);
        assert_eq!(r.rows[1][0], "rr");
        assert_eq!(r.rows[3][0], "locality");
    }

    #[test]
    fn table5_quick_runs() {
        let mut cfg = tiny();
        cfg.full = false;
        // micro sweep via the public API: 1k only
        let r = table5(&cfg, 2).unwrap();
        assert!(r.rows.len() > 2);
    }
}
