//! String interning: entity values (movie titles, tags, user names, …) are
//! interned once per modality into dense `u32` ids. The whole pipeline
//! (prime sets, cumuli, shuffle keys) operates on ids; strings only
//! reappear when patterns are printed (paper §5.2 output format).

use crate::util::hash::FxHashMap;

/// Bidirectional string↔id map for one modality.
#[derive(Debug, Default, Clone)]
pub struct Interner {
    by_name: FxHashMap<String, u32>,
    names: Vec<String>,
}

impl Interner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern `name`, returning its stable id.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = self.names.len() as u32;
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), id);
        id
    }

    pub fn get(&self, name: &str) -> Option<u32> {
        self.by_name.get(name).copied()
    }

    pub fn name(&self, id: u32) -> &str {
        &self.names[id as usize]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.names.iter().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut i = Interner::new();
        let a = i.intern("Toy Story (1995)");
        let b = i.intern("WALL-E (2008)");
        assert_eq!(i.intern("Toy Story (1995)"), a);
        assert_ne!(a, b);
        assert_eq!(i.len(), 2);
        assert_eq!(i.name(a), "Toy Story (1995)");
        assert_eq!(i.get("WALL-E (2008)"), Some(b));
        assert_eq!(i.get("missing"), None);
    }

    #[test]
    fn ids_are_dense() {
        let mut i = Interner::new();
        for k in 0..100 {
            assert_eq!(i.intern(&format!("e{k}")), k);
        }
    }
}
