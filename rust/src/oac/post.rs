//! Post-processing (paper §2): duplicate elimination by content hashing
//! and user-constraint filtering — `O(|I|)`, no extra passes over data.

use crate::core::pattern::Cluster;
use crate::core::tuple::NTuple;
use crate::util::hash::FxHashMap;

/// User-specified pattern constraints (paper §2 and §4.3).
#[derive(Debug, Clone)]
pub struct Constraints {
    /// Minimal density ρ_min; compared against the cluster's
    /// support-density (distinct generating tuples / volume — the measure
    /// the paper's third reduce computes).
    pub min_density: f64,
    /// Minimal cardinality per modality (minsup).
    pub min_support: usize,
}

impl Default for Constraints {
    fn default() -> Self {
        Self { min_density: 0.0, min_support: 0 }
    }
}

impl Constraints {
    /// No constraints: keep every cluster.
    pub fn none() -> Self {
        Self::default()
    }

    /// True when `c` passes the support and density thresholds.
    pub fn satisfied_by(&self, c: &Cluster) -> bool {
        if self.min_support > 0
            && c.components.iter().any(|comp| comp.len() < self.min_support)
        {
            return false;
        }
        self.min_density <= 0.0 || c.support_density() >= self.min_density
    }
}

/// Merge duplicate clusters (same components, different generating
/// tuples), accumulate support = number of DISTINCT generating tuples,
/// then filter by `constraints`. Returns deduplicated clusters in
/// first-seen order — the order contract every dedup in the repo
/// shares, including the memoized
/// [`crate::oac::online::dedup_generated`] oracle and its partitioned
/// [`crate::oac::online::dedup_generated_parallel`] twin.
pub fn dedup_and_filter(
    materialized: Vec<(Cluster, NTuple)>,
    constraints: &Constraints,
) -> Vec<Cluster> {
    let mut by_fp: FxHashMap<u64, usize> = FxHashMap::default();
    let mut uniq: Vec<(Cluster, Vec<NTuple>)> = Vec::new();
    for (c, t) in materialized {
        let fp = c.fingerprint();
        match by_fp.get(&fp) {
            Some(&i) => {
                debug_assert_eq!(uniq[i].0.components, c.components);
                uniq[i].1.push(t);
            }
            None => {
                by_fp.insert(fp, uniq.len());
                uniq.push((c, vec![t]));
            }
        }
    }
    uniq.into_iter()
        .filter_map(|(mut c, mut gens)| {
            gens.sort_unstable();
            gens.dedup();
            c.support = gens.len();
            constraints.satisfied_by(&c).then_some(c)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;
    use crate::oac::online::OnlineMiner;

    #[test]
    fn duplicates_merge_with_support() {
        let a = tricluster(vec![0], vec![0, 1], vec![0, 1]);
        let mats = vec![
            (a.clone(), NTuple::triple(0, 0, 0)),
            (a.clone(), NTuple::triple(0, 1, 0)),
            (a.clone(), NTuple::triple(0, 0, 1)),
            (a.clone(), NTuple::triple(0, 1, 1)),
        ];
        let out = dedup_and_filter(mats, &Constraints::none());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].support, 4);
        assert!((out[0].support_density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn replayed_generating_tuples_counted_once() {
        let a = tricluster(vec![0], vec![0], vec![0]);
        let mats = vec![
            (a.clone(), NTuple::triple(0, 0, 0)),
            (a.clone(), NTuple::triple(0, 0, 0)), // M/R retry duplicate
        ];
        let out = dedup_and_filter(mats, &Constraints::none());
        assert_eq!(out[0].support, 1);
    }

    #[test]
    fn density_filter() {
        // volume 8, support 1 → ρ = 0.125
        let c = tricluster(vec![0, 1], vec![0, 1], vec![0, 1]);
        let mats = vec![(c, NTuple::triple(0, 0, 0))];
        assert_eq!(
            dedup_and_filter(mats.clone(), &Constraints { min_density: 0.2, min_support: 0 })
                .len(),
            0
        );
        assert_eq!(
            dedup_and_filter(mats, &Constraints { min_density: 0.1, min_support: 0 }).len(),
            1
        );
    }

    #[test]
    fn minsup_filter() {
        let c = tricluster(vec![0], vec![0, 1], vec![0, 1]);
        let mats = vec![(c, NTuple::triple(0, 0, 0))];
        let cons = Constraints { min_density: 0.0, min_support: 2 };
        assert_eq!(dedup_and_filter(mats, &cons).len(), 0);
    }

    #[test]
    fn end_to_end_table1() {
        let mut miner = OnlineMiner::new(3);
        miner.add_batch(&[
            NTuple::triple(0, 0, 0),
            NTuple::triple(0, 1, 0),
            NTuple::triple(0, 0, 1),
            NTuple::triple(0, 1, 1),
        ]);
        let out = dedup_and_filter(miner.materialize_all(), &Constraints::none());
        // all four triples generate the SAME tricluster ({u2},{i1,i2},{l1,l2})
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].components[1], vec![0, 1]);
        assert_eq!(out[0].components[2], vec![0, 1]);
        assert_eq!(out[0].support, 4);
    }
}
