//! Job counters — the Hadoop counter facility: named u64 metrics
//! incremented by tasks and merged at job completion.

use std::collections::BTreeMap;

/// Well-known counter names used across the pipeline.
pub mod names {
    /// Records read by map tasks.
    pub const MAP_INPUT_RECORDS: &str = "map.input.records";
    /// Records emitted by map tasks.
    pub const MAP_OUTPUT_RECORDS: &str = "map.output.records";
    /// Distinct keys seen by reduce tasks.
    pub const REDUCE_INPUT_GROUPS: &str = "reduce.input.groups";
    /// Values consumed by reduce tasks.
    pub const REDUCE_INPUT_RECORDS: &str = "reduce.input.records";
    /// Records emitted by reduce tasks.
    pub const REDUCE_OUTPUT_RECORDS: &str = "reduce.output.records";
    /// Logical bytes moved through the shuffle.
    pub const SHUFFLE_BYTES: &str = "shuffle.bytes";
    /// Bytes spilled to disk by the DFS.
    pub const SPILLED_BYTES: &str = "dfs.spilled.bytes";
    /// Bytes written including DFS replication.
    pub const REPLICATED_BYTES: &str = "dfs.replicated.bytes";
    /// Task attempts that were retried (fault injection).
    pub const TASK_RETRIES: &str = "task.retries";
    /// Duplicate task inputs observed (retry idempotence check).
    pub const DUPLICATE_INPUTS: &str = "task.duplicate.inputs";
    /// Records entering the map-side combiner.
    pub const COMBINE_INPUT_RECORDS: &str = "combine.input.records";
    /// Records leaving the map-side combiner.
    pub const COMBINE_OUTPUT_RECORDS: &str = "combine.output.records";
}

/// A set of named counters (BTreeMap so reports are deterministic).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters {
    values: BTreeMap<String, u64>,
}

impl Counters {
    /// Empty counter set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to counter `name` (creating it at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.values.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current value of counter `name` (0 if never incremented).
    pub fn get(&self, name: &str) -> u64 {
        self.values.get(name).copied().unwrap_or(0)
    }

    /// Merge another counter set into this one (job ← task).
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.values {
            *self.values.entry(k.clone()).or_insert(0) += v;
        }
    }

    /// All counters in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.values.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// True when no counter was ever incremented.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_get_merge() {
        let mut a = Counters::new();
        a.inc(names::MAP_INPUT_RECORDS, 10);
        a.inc(names::MAP_INPUT_RECORDS, 5);
        assert_eq!(a.get(names::MAP_INPUT_RECORDS), 15);
        assert_eq!(a.get("missing"), 0);

        let mut b = Counters::new();
        b.inc(names::MAP_INPUT_RECORDS, 1);
        b.inc(names::SHUFFLE_BYTES, 100);
        a.merge(&b);
        assert_eq!(a.get(names::MAP_INPUT_RECORDS), 16);
        assert_eq!(a.get(names::SHUFFLE_BYTES), 100);
    }

    #[test]
    fn deterministic_iteration() {
        let mut c = Counters::new();
        c.inc("z", 1);
        c.inc("a", 2);
        let keys: Vec<&str> = c.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["a", "z"]);
    }
}
