//! Out-of-core persistence: the binary segment log.
//!
//! The paper's setting is *big data* — contexts that outgrow one
//! machine's memory — so durability cannot round-trip pretty-printed
//! JSON and restore cannot re-mine every tuple. This module replaces
//! the JSON snapshot path with a compact, versioned, checksummed
//! **binary segment log**:
//!
//! * [`codec`] — little-endian primitives, length-prefixed records, and
//!   the chained-[`crate::util::hash::mix64`] checksum (the repo's own
//!   seeded hash utilities; no new dependencies);
//! * [`segment`] — the segment payload (header, per-shard tuple log,
//!   cumulus page frames, cluster index, interner tables) and the
//!   [`SegmentLog`] directory of `seg-NNNNNN.tseg` files;
//! * [`restore`] — folds a replayed segment sequence into one
//!   [`LogImage`]: full segments replace state, delta segments append,
//!   and each shard's cumuli come out sealed (sorted + deduplicated)
//!   ready for bulk adoption via [`crate::oac::primes::PrimeStore::adopt`]
//!   — no per-tuple re-ingest.
//!
//! Invariants (property-tested in `rust/tests/persist_roundtrip.rs`):
//!
//! * **Equivalence-preserving**: write → restore reproduces the live
//!   service's observable state bit-for-bit (cluster components,
//!   supports, epochs) for any arity, θ, and shard count.
//! * **Corruption-safe**: a flipped byte anywhere in a segment fails the
//!   checksum and surfaces as [`SegmentError::Corrupt`] — typed, never a
//!   panic. An unknown magic or format version is [`SegmentError::BadMagic`]
//!   / [`SegmentError::BadVersion`].
//! * **Torn-tail tolerant**: replay drops a final segment that fails to
//!   decode (the torn write of a crash) and restores the prefix; a
//!   NON-final corrupt segment is an error, because silently skipping it
//!   would resurrect a wrong history.
//!
//! Telemetry: `persist.segment.flush` / `persist.segment.restore`
//! counters and the `persist.flush` span (bytes = encoded segment size);
//! the spill tier it pairs with emits `oac.arena.{spill,reload}`.

pub mod codec;
pub mod restore;
pub mod segment;

pub use restore::{LogImage, ShardImage};
pub use segment::{
    SegmentConfig, SegmentKind, SegmentLog, SegmentPayload, ShardRecord, FORMAT_VERSION,
};

/// Typed persistence failure. Everything the segment layer can hit maps
/// onto one of these — corruption is a VALUE, never a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SegmentError {
    /// Filesystem failure (create/read/write), with context.
    Io(String),
    /// The file does not start with the segment magic — not a segment
    /// file at all (as opposed to a damaged one).
    BadMagic,
    /// A segment written by an incompatible format version.
    BadVersion(u32),
    /// Checksum mismatch or malformed body: the segment is damaged.
    Corrupt {
        /// Which segment (file name or description) failed.
        segment: String,
    },
}

impl std::fmt::Display for SegmentError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(msg) => write!(f, "segment io: {msg}"),
            Self::BadMagic => write!(f, "not a segment file (bad magic)"),
            Self::BadVersion(v) => write!(
                f,
                "segment format version {v} unsupported (this build reads {FORMAT_VERSION})"
            ),
            Self::Corrupt { segment } => write!(f, "segment corrupt: {segment}"),
        }
    }
}

impl std::error::Error for SegmentError {}

impl SegmentError {
    pub(crate) fn io(context: &str, e: std::io::Error) -> Self {
        Self::Io(format!("{context}: {e}"))
    }

    pub(crate) fn corrupt(segment: impl Into<String>) -> Self {
        Self::Corrupt { segment: segment.into() }
    }
}
