//! Mini-Hadoop: the MapReduce substrate the paper's §4 algorithm runs on.
//!
//! Reproduces the parts of the Hadoop stack the paper's evaluation
//! depends on — typed Writable records, hash partitioning, raw-byte key
//! sort, DFS-materialised intermediates with replication accounting,
//! task retry (duplicate) injection, counters, and a virtual cluster
//! clock that replays measured task times onto r simulated nodes (the
//! paper itself benchmarked Hadoop in single-node emulation mode).

pub mod counters;
pub mod dfs;
pub mod job;
pub mod record;
pub mod task;

pub use counters::Counters;
pub use dfs::{Dfs, DfsConfig};
pub use job::{run_job, Emitter, JobConfig, JobStats, Mapper, Reducer};
pub use record::Record;
