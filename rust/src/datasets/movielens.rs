//! MovieLens-like 4-ary context generator (paper §5.1 / Table 4).
//!
//! The paper's MovieLens-1M: 1,000,000 tuples relating 6,040 users,
//! 3,952 movies, 5-star ratings, and timestamps. We generate a matched
//! 4-ary relation (user, movie, rating, time-bucket) with power-law user
//! activity and movie popularity (the defining skew of the real data);
//! Table 4's 100k/250k/500k/1M series are prefixes of one deterministic
//! stream, exactly like sampling the real dataset.

use crate::core::context::PolyContext;
use crate::util::rng::{Rng, Zipf};

#[derive(Debug, Clone)]
/// Generation parameters for the MovieLens-like rating stream.
pub struct MovielensParams {
    /// Distinct users.
    pub users: usize,
    /// Distinct movies.
    pub movies: usize,
    /// Distinct star ratings.
    pub ratings: usize,
    /// timestamp buckets (the raw seconds are binned; the paper's 4th
    /// modality would otherwise be almost all-distinct and meaningless
    /// for clustering)
    pub time_buckets: usize,
    /// Tuples to generate.
    pub tuples: usize,
    /// Stream seed.
    pub seed: u64,
}

impl Default for MovielensParams {
    fn default() -> Self {
        Self {
            users: 6_040,
            movies: 3_952,
            ratings: 5,
            time_buckets: 36, // ~3 years of monthly buckets
            tuples: 1_000_000,
            seed: 0x10E15,
        }
    }
}

impl MovielensParams {
    /// The Table-4 series: same stream, first `n` tuples.
    pub fn with_tuples(n: usize) -> Self {
        Self { tuples: n, ..Self::default() }
    }
}

/// Generate the MovieLens-like `(user, movie, rating, time)` context.
pub fn movielens(params: &MovielensParams) -> PolyContext {
    // users dominate the modality sizes; one hint fits all four
    let mut ctx = PolyContext::with_capacity(4, params.users.max(params.movies), params.tuples);
    for u in 0..params.users {
        ctx.interners[0].intern(&format!("user{u}"));
    }
    for m in 0..params.movies {
        ctx.interners[1].intern(&format!("movie{m}"));
    }
    for r in 1..=params.ratings {
        ctx.interners[2].intern(&format!("{r}*"));
    }
    for t in 0..params.time_buckets {
        ctx.interners[3].intern(&format!("2000-{:02}", t + 1));
    }

    let mut rng = Rng::new(params.seed);
    let user_zipf = Zipf::new(params.users as u64, 0.9);
    let movie_zipf = Zipf::new(params.movies as u64, 0.95);
    // ratings follow the familiar J-shape (4 ≻ 5 ≻ 3 ≻ 2 ≻ 1)
    let rating_cdf = [0.06, 0.17, 0.43, 0.78, 1.0];

    while ctx.len() < params.tuples {
        let u = user_zipf.sample(&mut rng) as u32;
        let m = movie_zipf.sample(&mut rng) as u32;
        let x = rng.f64();
        let r = rating_cdf.iter().position(|&c| x < c).unwrap() as u32;
        // users rate in sessions: time bucket correlates with the user
        let t = ((u as usize + rng.usize_below(6)) % params.time_buckets) as u32;
        ctx.add_ids(&[u, m, r.min(params.ratings as u32 - 1), t]);
    }
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_exact_tuple_count() {
        let ctx = movielens(&MovielensParams::with_tuples(10_000));
        assert_eq!(ctx.len(), 10_000);
        assert_eq!(ctx.arity(), 4);
        assert!(ctx.modality_size(0) <= 6_040);
        assert_eq!(ctx.modality_size(2), 5);
    }

    #[test]
    fn prefix_property() {
        // the 1k stream is a prefix of the 5k stream (Table 4 series)
        let a = movielens(&MovielensParams::with_tuples(1_000));
        let b = movielens(&MovielensParams::with_tuples(5_000));
        assert_eq!(&b.tuples()[..1_000], a.tuples());
    }

    #[test]
    fn user_activity_is_skewed() {
        let ctx = movielens(&MovielensParams::with_tuples(20_000));
        let mut counts = vec![0usize; 6_040];
        for t in ctx.tuples() {
            counts[t.get(0) as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top_decile: usize = counts[..604].iter().sum();
        assert!(
            top_decile as f64 > 0.3 * 20_000.0,
            "top decile only {top_decile}"
        );
    }
}
