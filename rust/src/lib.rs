// Style lints that fight the paper-faithful shape of this code (index
// loops mirroring the algorithm pseudo-code, wide M/R type signatures);
// correctness lints stay denied in CI via `cargo clippy -- -D warnings`.
#![allow(
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::needless_range_loop,
    clippy::len_without_is_empty
)]
// Every public item carries rustdoc: the crate is the reference
// implementation of the paper (docs/PAPER_MAP.md maps each algorithm to
// its item), so an undocumented public surface is a defect.
#![warn(missing_docs)]

//! # tricluster — Triclustering in a Big Data Setting
//!
//! A production-style reproduction of Egurnov, Ignatov & Tochilkin,
//! *"Triclustering in Big Data Setting"* (2020): prime OAC-triclustering,
//! its multimodal (N-ary) generalisation, the three-stage MapReduce
//! algorithm, and parallel many-valued (NOAC) triclustering — implemented
//! as a three-layer Rust + JAX/Pallas stack (see DESIGN.md).
//!
//! Layer 3 (this crate) owns the full pipeline: mini-Hadoop M/R engine,
//! online/basic OAC algorithms, the 3-stage multimodal clustering, NOAC,
//! dataset generators, density engines, and the PJRT runtime that executes
//! the AOT-compiled JAX/Pallas density kernels from `artifacts/`.
//!
//! The three M/R triclustering stages exist in ONE backend-generic form
//! in [`exec`]: a [`exec::Backend`] trait with five implementations
//! (Sequential, Pooled, HadoopSim, SparkSim, ClusterSim) executes the
//! identical stage functions, so the paper's regime comparison (§4 vs
//! §6 vs §7) is a backend sweep rather than five pipeline copies —
//! and the simulated N-node ClusterSim makes distribution itself
//! (placement, stragglers, speculative execution) a testable variable.
//!
//! On top of the batch pipeline sits the [`serve`] layer — a sharded,
//! incrementally-updatable triclustering SERVICE (ingest → shard → merge
//! → query, see docs/ARCHITECTURE.md): hash-routed ingest with
//! pipelined backpressure drains, per-shard online miners, a compactor
//! that merges partial cumuli into a globally-correct index, a
//! top-k/membership query API, and durable snapshots via the [`persist`]
//! binary segment log (JSON kept as a debug fallback). The two
//! layers fuse in [`serve::cluster`]: shards placed on the simulated
//! cluster via [`exec::Placement`], with shuffle-cost accounting and
//! node churn + snapshot replay.
//!
//! The serve layer is exercised beyond friendly uniform streams by two
//! PR-9 additions: [`serve::tenant`] multiplexes many independent
//! tenant contexts (per-tenant θ, arity, quotas) onto one shared
//! simulated node pool with measured fairness, and [`workload`]
//! generates seeded, bit-replayable adversarial scenarios — key skew,
//! temporal drift, burst ingress, correlated node failures — that the
//! per-tenant isolation/equivalence suites run against
//! (`rust/tests/workload_invariants.rs`).
//!
//! Every layer reports through the zero-dependency [`obs`] telemetry
//! plane — counters, gauges, log2 histograms, and hierarchical spans
//! behind a no-op-by-default global handle, exported as a JSON metrics
//! snapshot and a Chrome-trace (`trace_event`) JSONL that loads in
//! Perfetto (CLI: `--metrics-out` / `--trace-out`; schema gated by
//! `ci/check_trace.rs`, overhead gated by `ci/check_bench.rs`).
//!
//! docs/PAPER_MAP.md maps every algorithm, complexity claim, and
//! experiment in the paper to the module implementing it and the
//! invariant guarding it (CI path-checks the map via `ci/check_docs.rs`).

pub mod coordinator;
pub mod core;
pub mod datasets;
pub mod density;
pub mod exec;
pub mod hadoop;
pub mod mmc;
pub mod noac;
pub mod oac;
pub mod obs;
pub mod persist;
pub mod runtime;
pub mod serve;
pub mod spark;
pub mod util;
pub mod workload;
