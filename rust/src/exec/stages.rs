//! The three M/R triclustering stages (paper §4.1, Algorithms 2–7) in
//! their ONE backend-generic form. Every execution path — sequential,
//! thread-pooled, Hadoop-sim, Spark-sim, cluster-sim — runs exactly
//! these functions; the backends differ only in how a `map_reduce`
//! round is executed. Because each stage is a separate labelled round,
//! per-stage adaptivity threads through without the stage functions
//! knowing: [`crate::exec::ClusterSim`] picks every phase's task count
//! from its input size and the PREVIOUS stage's measured cost skew
//! ([`crate::exec::placement::adaptive_task_count`]).
//!
//! Stage 1 — cumuli: tuples fan out to N ⟨subrelation, entity⟩ pairs
//!   (Alg. 2); the reducer accumulates each subrelation's cumulus
//!   (Alg. 3 — we emit the final cumulus once; emitting the running
//!   prefix per value, as the pseudo-code literally reads, produces the
//!   same final stage-2 input with strictly more traffic).
//! Stage 2 — assembly: each ⟨subrelation, cumulus⟩ is expanded back to
//!   its generating tuples (Alg. 4); the reducer zips the N cumuli into
//!   a multimodal cluster per generating tuple (Alg. 5), keyed by its
//!   components — Alg. 6's key swap, fused.
//! Stage 3 — dedup + density: group by components, count distinct
//!   generating tuples, keep clusters with support/volume ≥ θ (Alg. 7).

use anyhow::Result;

use super::backend::{no_combine, Backend};
use crate::core::context::PolyContext;
use crate::core::pattern::Cluster;
use crate::core::tuple::{NTuple, SubRelation};

/// A cluster's component sets — the stage-3 grouping key.
pub type Components = Vec<Vec<u32>>;

/// Alg. 2: `(e_1..e_N)` → `⟨subrelation_k, e_k⟩` for every k.
pub fn s1_map(t: &NTuple) -> Vec<(SubRelation, u32)> {
    (0..t.arity()).map(|k| (t.subrelation(k), t.get(k))).collect()
}

/// Optional map-side combiner for stage 1: deduplicate a map task's
/// local entity emissions per subrelation before the shuffle. Safe
/// because the stage-1 reduce is a set union — associative and
/// idempotent. Shuffle-byte savings are measured by the combiner
/// ablation (HadoopSim is the only backend that materialises it).
pub fn s1_combine(_key: &SubRelation, mut values: Vec<u32>) -> Vec<u32> {
    values.sort_unstable();
    values.dedup();
    values
}

/// Alg. 3: accumulate the cumulus of each subrelation. Values may repeat
/// (task retries); the cumulus is a set.
pub fn s1_reduce(key: &SubRelation, mut values: Vec<u32>) -> Vec<(SubRelation, Vec<u32>)> {
    values.sort_unstable();
    values.dedup();
    vec![(*key, values)]
}

/// Alg. 4: re-insert each cumulus element at the dropped position to
/// recover the generating tuples; the cumulus travels with each, tagged
/// by the dropped modality so the stage-2 reduce can order the N cumuli.
pub fn s2_map(input: &(SubRelation, Vec<u32>)) -> Vec<(NTuple, (u32, Vec<u32>))> {
    let (sub, cumulus) = input;
    let k = sub.dropped() as u32;
    cumulus
        .iter()
        .map(|&e| (NTuple::from_subrelation(sub, e), (k, cumulus.clone())))
        .collect()
}

/// Alg. 5: zip the N cumuli of one generating tuple into a cluster,
/// keyed by its components (Alg. 6's key swap, fused into the emit).
pub fn s2_reduce(
    generating: &NTuple,
    values: Vec<(u32, Vec<u32>)>,
) -> Vec<(Components, NTuple)> {
    let n = generating.arity();
    let mut comps: Vec<Option<Vec<u32>>> = vec![None; n];
    for (k, cumulus) in values {
        let slot = &mut comps[k as usize];
        // duplicates from retries carry identical cumuli; keep first
        if slot.is_none() {
            *slot = Some(cumulus);
        }
    }
    // every position must be present: tuple (e_1..e_N) ∈ I implies all
    // N subrelations emitted a cumulus containing e_k
    let comps: Components = comps
        .into_iter()
        .map(|c| c.expect("missing cumulus for a generating tuple"))
        .collect();
    vec![(comps, *generating)]
}

/// Stage 1 on any backend: tuples → ⟨subrelation, cumulus⟩.
pub fn stage1_cumuli<B: Backend>(
    backend: &B,
    tuples: Vec<NTuple>,
    combiner: bool,
) -> Result<Vec<(SubRelation, Vec<u32>)>> {
    let combine: Option<fn(&SubRelation, Vec<u32>) -> Vec<u32>> =
        if combiner { Some(s1_combine) } else { None };
    backend.map_reduce("s1", tuples, s1_map, combine, s1_reduce)
}

/// Stage 1 computed by the shared-memory ingest kernel instead of a
/// map→shuffle→reduce round: [`crate::oac::primes::PrimeStore::par_add_batch`]
/// (merge-based parallel ingest over `util::pool`) builds the cumulus
/// dictionaries with zero per-tuple allocation, then exports them as the
/// exact ⟨subrelation, cumulus⟩ pairs [`stage1_cumuli`] produces on any
/// backend, canonically ordered by key (unit-tested equal). This is the
/// §Perf path for the in-process backends (`seq`, `pool`) — the
/// simulated engines (`hadoop`, `spark`, `cluster`) keep their shuffle,
/// because modelling that shuffle is what they are for.
pub fn stage1_cumuli_ingest(
    tuples: &[NTuple],
    arity: usize,
    workers: usize,
) -> Vec<(SubRelation, Vec<u32>)> {
    let mut span = crate::span!("exec.ingest.s1");
    span.records_in(tuples.len() as u64);
    let mut store = crate::oac::primes::PrimeStore::new(arity);
    store.par_add_batch(tuples, workers);
    let cumuli = store.cumuli();
    span.records_out(cumuli.len() as u64);
    cumuli
}

/// Stage 2 on any backend: cumuli → one ⟨components, generating tuple⟩
/// per generating tuple.
pub fn stage2_assembly<B: Backend>(
    backend: &B,
    cumuli: Vec<(SubRelation, Vec<u32>)>,
) -> Result<Vec<(Components, NTuple)>> {
    backend.map_reduce("s2", cumuli, s2_map, no_combine::<NTuple, (u32, Vec<u32>)>(), s2_reduce)
}

/// Stage 3 on any backend: dedup by components, support = |distinct
/// generating tuples|, keep clusters with support/volume ≥ `theta`
/// (Alg. 7). Alg. 6's map is pure key swap and [`s2_reduce`] already
/// emits ⟨components, generating tuple⟩, so this round is shuffle →
/// reduce over the pre-keyed pairs (no identity map phase).
pub fn stage3_dedup_density<B: Backend>(
    backend: &B,
    assembled: Vec<(Components, NTuple)>,
    theta: f64,
) -> Result<Vec<Cluster>> {
    backend.group_reduce(
        "s3",
        assembled,
        move |comps: &Components, mut gens: Vec<NTuple>| {
            gens.sort_unstable();
            gens.dedup();
            // stage-1 cumuli arrive sorted + deduped (s1_reduce / the
            // ingest kernel), so the components need no re-sort
            let mut c = Cluster::from_sorted(comps.clone());
            c.support = gens.len();
            let vol = c.volume();
            if vol > 0.0 && c.support as f64 / vol >= theta {
                vec![c]
            } else {
                Vec::new()
            }
        },
    )
}

/// Stage 3 computed by the shared-memory partitioned grouper instead of
/// a backend `group_reduce` round: [`crate::util::pool::group_indices`]
/// hash-partitions the component keys across `workers` threads, then
/// each group's distinct-support count and θ filter run in parallel.
/// Same contract as [`stage3_dedup_density`] up to group order (the
/// pipeline canonicalises with `sort_clusters` anyway) — unit-tested
/// equal, and the backend round stays the reference.
pub fn stage3_dedup_density_par(
    assembled: Vec<(Components, NTuple)>,
    theta: f64,
    workers: usize,
    partitions: usize,
) -> Vec<Cluster> {
    use crate::util::pool;
    let mut span = crate::span!("exec.dedup.s3");
    span.records_in(assembled.len() as u64);
    let (comps, gens): (Vec<Components>, Vec<NTuple>) =
        assembled.into_iter().unzip();
    let groups = pool::group_indices(&comps, partitions.max(1), workers.max(1));
    let out: Vec<Option<Cluster>> =
        pool::parallel_map(groups.len(), workers.max(1), 1, |gi| {
            let (first, members) = &groups[gi];
            let mut g: Vec<NTuple> = members.iter().map(|&i| gens[i]).collect();
            g.sort_unstable();
            g.dedup();
            // stage-1 cumuli arrive sorted + deduped, as in the backend
            // round
            let mut c = Cluster::from_sorted(comps[*first].clone());
            c.support = g.len();
            let vol = c.volume();
            (vol > 0.0 && c.support as f64 / vol >= theta).then_some(c)
        });
    let clusters: Vec<Cluster> = out.into_iter().flatten().collect();
    span.records_out(clusters.len() as u64);
    clusters
}

/// The full pipeline: cumuli → assembly → dedup+density, with the output
/// canonicalised by component order (reduce partition/group order is
/// backend-dependent).
pub fn run_pipeline<B: Backend>(
    backend: &B,
    ctx: &PolyContext,
    theta: f64,
    combiner: bool,
) -> Result<Vec<Cluster>> {
    let mut span = crate::span!("exec.pipeline.{}", backend.name());
    span.records_in(ctx.tuples().len() as u64);
    let cumuli = stage1_cumuli(backend, ctx.tuples().to_vec(), combiner)?;
    let assembled = stage2_assembly(backend, cumuli)?;
    let mut clusters = stage3_dedup_density(backend, assembled, theta)?;
    crate::core::pattern::sort_clusters(&mut clusters);
    span.records_out(clusters.len() as u64);
    Ok(clusters)
}

/// [`run_pipeline`] with stage 1 on the parallel ingest kernel
/// ([`stage1_cumuli_ingest`], `workers` threads) and stages 2–3 on the
/// given backend — the [`crate::exec::ExecTuning::parallel_ingest`]
/// fast path for the in-process backends.
pub fn run_pipeline_ingest<B: Backend>(
    backend: &B,
    ctx: &PolyContext,
    theta: f64,
    workers: usize,
) -> Result<Vec<Cluster>> {
    run_pipeline_ingest_tuned(backend, ctx, theta, workers, 0)
}

/// [`run_pipeline_ingest`] with stage 3 also lifted off the backend:
/// `dedup_partitions ≥ 1` runs the partitioned in-process grouper
/// ([`stage3_dedup_density_par`]) instead of a `group_reduce` round;
/// `0` keeps the backend round ([`crate::exec::ExecTuning::dedup_partitions`]).
pub fn run_pipeline_ingest_tuned<B: Backend>(
    backend: &B,
    ctx: &PolyContext,
    theta: f64,
    workers: usize,
    dedup_partitions: usize,
) -> Result<Vec<Cluster>> {
    let mut span = crate::span!("exec.pipeline.{}-ingest", backend.name());
    span.records_in(ctx.tuples().len() as u64);
    let cumuli = stage1_cumuli_ingest(ctx.tuples(), ctx.arity(), workers);
    let assembled = stage2_assembly(backend, cumuli)?;
    let mut clusters = if dedup_partitions > 0 {
        stage3_dedup_density_par(assembled, theta, workers, dedup_partitions)
    } else {
        stage3_dedup_density(backend, assembled, theta)?
    };
    crate::core::pattern::sort_clusters(&mut clusters);
    span.records_out(clusters.len() as u64);
    Ok(clusters)
}

#[cfg(test)]
mod tests {
    use super::super::Sequential;
    use super::*;

    #[test]
    fn s1_map_fans_out_n_pairs() {
        let t = NTuple::triple(1, 2, 3);
        let out = s1_map(&t);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], (t.subrelation(0), 1));
        assert_eq!(out[2], (t.subrelation(2), 3));
    }

    #[test]
    fn s1_reduce_dedups_cumulus() {
        let sub = NTuple::triple(0, 1, 2).subrelation(0);
        let out = s1_reduce(&sub, vec![5, 3, 5, 3, 1]);
        assert_eq!(out, vec![(sub, vec![1, 3, 5])]);
    }

    #[test]
    fn s2_map_rebuilds_generating_tuples() {
        let t = NTuple::triple(7, 1, 2);
        let sub = t.subrelation(0);
        let out = s2_map(&(sub, vec![7, 9]));
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, NTuple::triple(7, 1, 2));
        assert_eq!(out[1].0, NTuple::triple(9, 1, 2));
        assert_eq!(out[0].1, (0, vec![7, 9]));
    }

    #[test]
    fn s2_reduce_zips_cumuli_in_modality_order() {
        let t = NTuple::triple(0, 1, 2);
        let out = s2_reduce(
            &t,
            vec![
                (2, vec![2, 9]), // modus arrives first
                (0, vec![0]),
                (1, vec![1, 4]),
                (1, vec![1, 4]), // retry duplicate — ignored
            ],
        );
        assert_eq!(out, vec![(vec![vec![0], vec![1, 4], vec![2, 9]], t)]);
    }

    #[test]
    fn stage3_counts_distinct_and_filters() {
        let comps = vec![vec![0], vec![1, 4], vec![2]];
        // volume 2; 2 distinct generating tuples (one duplicated) → ρ = 1
        let assembled = vec![
            (comps.clone(), NTuple::triple(0, 1, 2)),
            (comps.clone(), NTuple::triple(0, 4, 2)),
            (comps.clone(), NTuple::triple(0, 1, 2)),
        ];
        let kept = stage3_dedup_density(&Sequential, assembled.clone(), 0.9).unwrap();
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].support, 2);
        // θ = 1.1 rejects everything
        let none = stage3_dedup_density(&Sequential, assembled, 1.1).unwrap();
        assert!(none.is_empty());
    }

    #[test]
    fn ingest_kernel_stage1_equals_backend_stage1() {
        let mut ctx = crate::core::context::PolyContext::new(3);
        let mut rng = crate::util::rng::Rng::new(17);
        for _ in 0..600 {
            let t =
                [rng.below(7) as u32, rng.below(7) as u32, rng.below(7) as u32];
            ctx.add_ids(&t);
        }
        let mut reference =
            stage1_cumuli(&Sequential, ctx.tuples().to_vec(), false).unwrap();
        reference.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        for workers in [1, 4] {
            let fast = stage1_cumuli_ingest(ctx.tuples(), 3, workers);
            assert_eq!(fast, reference, "workers={workers}");
        }
    }

    #[test]
    fn ingest_pipeline_equals_map_reduce_pipeline() {
        let ctx = crate::datasets::synthetic::k1(5).inner;
        for theta in [0.0, 0.9] {
            let mr = run_pipeline(&Sequential, &ctx, theta, false).unwrap();
            let fast = run_pipeline_ingest(&Sequential, &ctx, theta, 4).unwrap();
            assert_eq!(mr.len(), fast.len(), "theta={theta}");
            for (a, b) in mr.iter().zip(&fast) {
                assert_eq!(a.components, b.components);
                assert_eq!(a.support, b.support);
            }
        }
    }

    #[test]
    fn parallel_stage3_equals_backend_round() {
        let ctx = crate::datasets::synthetic::k1(5).inner;
        let cumuli = stage1_cumuli_ingest(ctx.tuples(), 3, 2);
        let assembled = stage2_assembly(&Sequential, cumuli).unwrap();
        for theta in [0.0, 0.9] {
            let mut reference =
                stage3_dedup_density(&Sequential, assembled.clone(), theta).unwrap();
            crate::core::pattern::sort_clusters(&mut reference);
            for (workers, partitions) in [(1, 1), (4, 3), (2, 16)] {
                let mut got = stage3_dedup_density_par(
                    assembled.clone(),
                    theta,
                    workers,
                    partitions,
                );
                crate::core::pattern::sort_clusters(&mut got);
                assert_eq!(reference.len(), got.len(), "theta={theta}");
                for (a, b) in reference.iter().zip(&got) {
                    assert_eq!(a.components, b.components);
                    assert_eq!(a.support, b.support);
                }
            }
        }
    }

    #[test]
    fn pipeline_merges_table1_example_on_sequential() {
        // the §1 motivating example: triples split by label must still
        // produce the merged ({u2},{i1,i2},{l1,l2})
        let mut ctx = crate::core::context::TriContext::new();
        ctx.add_named("u2", "i1", "l1");
        ctx.add_named("u2", "i2", "l1");
        ctx.add_named("u2", "i1", "l2");
        ctx.add_named("u2", "i2", "l2");
        let out = run_pipeline(&Sequential, &ctx.inner, 0.0, false).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].components, vec![vec![0], vec![0, 1], vec![0, 1]]);
        assert_eq!(out[0].support, 4);
    }
}
