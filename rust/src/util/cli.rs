//! Minimal CLI argument parser (no clap offline).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / `--switch`
//! grammar used by the `tricluster` binary and the bench/ example drivers.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
/// Parsed command line: subcommand + `--flag value` pairs + switches.
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional tokens after the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable without a process).
    pub fn parse_from<I, S>(tokens: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let toks: Vec<String> = tokens.into_iter().map(Into::into).collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.flags.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.switches.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Value of `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Value of `--name` parsed as `T`, if given and well-formed.
    pub fn parse<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    /// Value of `--name` parsed as `T`, or `default`.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.parse(name).unwrap_or(default)
    }

    /// True when `--switch` was given (with or without a value).
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.flags.contains_key(switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_flags_switches() {
        // NOTE: flags consume the following token greedily, so bare
        // switches must come last or use `--switch` at the end.
        let a = Args::parse_from([
            "mr", "--dataset", "k1", "--workers=8", "extra", "--verbose",
        ]);
        assert_eq!(a.command.as_deref(), Some("mr"));
        assert_eq!(a.get("dataset"), Some("k1"));
        assert_eq!(a.parse::<usize>("workers"), Some(8));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse_from(["run", "--fast"]);
        assert!(a.has("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from(["x"]);
        assert_eq!(a.parse_or("n", 5usize), 5);
        assert_eq!(a.get_or("name", "d"), "d");
        assert!(!a.has("quiet"));
    }
}
