//! Bench: out-of-core persistence — the binary segment log vs the JSON
//! debug snapshot on the same compacted service. Writes
//! `BENCH_persist.json` (repo root).
//!
//! Measures, per arm: snapshot bytes on disk, save wall time, and
//! restore wall time (best-of-3). The headline figure is
//! `binary_restore_vs_json` — how many times faster the page-adoption
//! restore ([`tricluster::serve::Shard::restore`]) is than parsing the
//! JSON document and re-mining every tuple through Alg. 1. The floor is
//! gated by `ci/check_bench.rs` against
//! `persist.min_binary_restore_ratio` in `ci/bench_baseline.json`.
//!
//! Doubles as an acceptance gate, enforced at the source: both restores
//! must reproduce the live index EXACTLY (components + supports), else
//! the bench panics and the ratio never reaches the baseline file.
//!
//! `TRICLUSTER_BENCH_FULL=1` for the paper-sized stream.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use tricluster::core::pattern::{diff_cluster_sets, sort_clusters, Cluster};
use tricluster::datasets::{movielens, MovielensParams};
use tricluster::serve::{snapshot, ServeConfig, TriclusterService};
use tricluster::util::json::Json;

const SHARDS: usize = 8;

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
    sort_clusters(&mut cs);
    cs
}

/// Total bytes of every regular file directly under `dir`.
fn dir_bytes(dir: &Path) -> u64 {
    std::fs::read_dir(dir)
        .expect("segment dir exists")
        .filter_map(|e| e.ok())
        .filter_map(|e| e.metadata().ok())
        .filter(|m| m.is_file())
        .map(|m| m.len())
        .sum()
}

/// Best-of-`rounds` wall time of `restore`, asserting each round's
/// index equals `reference`.
fn time_restore(
    label: &str,
    rounds: usize,
    reference: &[Cluster],
    mut restore: impl FnMut() -> TriclusterService,
) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..rounds {
        let t = Instant::now();
        let mut svc = restore();
        best = best.min(t.elapsed().as_secs_f64() * 1e3);
        let got = sorted(svc.clusters().to_vec());
        if let Some(diff) = diff_cluster_sets(reference, &got) {
            panic!("{label} restore diverged from the live index: {diff}");
        }
    }
    best
}

fn main() {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let tuples = if full { 200_000 } else { 30_000 };
    let ctx = movielens(&MovielensParams::with_tuples(tuples));
    let scratch = std::env::temp_dir().join("tricluster_bench_persist");
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(&scratch).expect("create bench scratch dir");
    let json_path = scratch.join("snapshot.json");
    let seg_dir = scratch.join("segments");

    let mut svc = TriclusterService::new(
        ServeConfig::builder()
            .arity(ctx.arity())
            .shards(SHARDS)
            .build()
            .expect("static bench config is valid"),
    );
    for chunk in ctx.tuples().chunks(4_096) {
        svc.ingest(chunk);
    }
    svc.compact();
    let reference = sorted(svc.clusters().to_vec());
    eprintln!(
        "persist bench (full={full}): {} tuples over {SHARDS} shards, \
         {} clusters",
        ctx.len(),
        reference.len()
    );

    let t = Instant::now();
    snapshot::save(&mut svc, &json_path).expect("json save");
    let json_save_ms = t.elapsed().as_secs_f64() * 1e3;
    let json_bytes = std::fs::metadata(&json_path).expect("json written").len();

    let t = Instant::now();
    snapshot::save_segments(&mut svc, &seg_dir).expect("segment save");
    let seg_save_ms = t.elapsed().as_secs_f64() * 1e3;
    let seg_bytes = dir_bytes(&seg_dir);

    let json_restore_ms = time_restore("json", 3, &reference, || {
        snapshot::load(&json_path).expect("json restore")
    });
    let seg_restore_ms = time_restore("segment", 3, &reference, || {
        snapshot::load_segments(&seg_dir).expect("segment restore")
    });

    let ratio = json_restore_ms / seg_restore_ms;
    let seg_mib = seg_bytes as f64 / (1 << 20) as f64;
    let restore_mib_s = seg_mib / (seg_restore_ms / 1e3);
    eprintln!(
        "  json:    {json_bytes:>9} B  save {json_save_ms:8.2} ms  \
         restore {json_restore_ms:8.2} ms (parse + re-mine)"
    );
    eprintln!(
        "  segment: {seg_bytes:>9} B  save {seg_save_ms:8.2} ms  \
         restore {seg_restore_ms:8.2} ms ({restore_mib_s:.1} MiB/s, page adoption)"
    );
    eprintln!("  binary_restore_vs_json: {ratio:.1}x (both restores bit-equal)");

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("persist".into()));
    doc.insert("full".to_string(), Json::Bool(full));
    doc.insert("tuples".to_string(), num(ctx.len() as f64));
    doc.insert("shards".to_string(), num(SHARDS as f64));
    doc.insert("clusters".to_string(), num(reference.len() as f64));
    doc.insert("snapshot_bytes_json".to_string(), num(json_bytes as f64));
    doc.insert("snapshot_bytes_segment".to_string(), num(seg_bytes as f64));
    doc.insert("json_save_ms".to_string(), num(json_save_ms));
    doc.insert("segment_save_ms".to_string(), num(seg_save_ms));
    doc.insert("json_restore_ms".to_string(), num(json_restore_ms));
    doc.insert("segment_restore_ms".to_string(), num(seg_restore_ms));
    doc.insert("segment_restore_mib_s".to_string(), num(restore_mib_s));
    doc.insert("binary_restore_vs_json".to_string(), num(ratio));
    // true by construction: time_restore panics on any divergence
    doc.insert("restore_equivalent".to_string(), Json::Bool(true));
    std::fs::write("BENCH_persist.json", Json::Obj(doc).to_string())
        .expect("write BENCH_persist.json");
    let _ = std::fs::remove_dir_all(&scratch);
    eprintln!("wrote BENCH_persist.json (binary restore {ratio:.1}x faster than JSON)");
}
