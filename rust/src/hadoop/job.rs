//! The MapReduce job engine: typed Mapper/Reducer traits, hash
//! partitioning, sort-shuffle with DFS-materialised intermediates, fault
//! injection, counters, and per-task timing for the virtual cluster clock.
//!
//! This is the Rust analogue of the paper's Hadoop setup (§4.2): a job is
//! configured (JobConfigurator), mappers emit key-value pairs, keys are
//! raw-byte-compared in the sort phase (WritableComparable), reducers see
//! each key with all its values, and stages chain by feeding one job's
//! output to the next (App).

use std::marker::PhantomData;

use anyhow::Result;

use crate::hadoop::counters::{names, Counters};
use crate::hadoop::dfs::Dfs;
use crate::hadoop::record::Record;
use crate::hadoop::task;
use crate::util::hash::fxhash;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

/// Typed map function. One mapper instance is shared by all map tasks
/// (must be `Sync`); per-record state lives in the emitter.
pub trait Mapper: Sync {
    /// Input key type.
    type InK: Record + Send + Sync + Clone;
    /// Input value type.
    type InV: Record + Send + Sync + Clone;
    /// Emitted key type.
    type OutK: Record + Send + Sync;
    /// Emitted value type.
    type OutV: Record + Send + Sync;

    /// Map one input record, emitting any number of pairs.
    fn map(
        &self,
        key: Self::InK,
        value: Self::InV,
        emit: &mut Emitter<Self::OutK, Self::OutV>,
    );
}

/// Typed reduce function: sees one key with all shuffled values.
pub trait Reducer: Sync {
    /// Shuffle key type.
    type InK: Record + Send;
    /// Shuffled value type.
    type InV: Record + Send;
    /// Emitted key type.
    type OutK: Record + Send;
    /// Emitted value type.
    type OutV: Record + Send;

    /// Reduce one key group, emitting any number of pairs.
    fn reduce(
        &self,
        key: Self::InK,
        values: Vec<Self::InV>,
        emit: &mut Emitter<Self::OutK, Self::OutV>,
    );
}

/// Map-side combiner: merges the values of one key within a single map
/// task's output before the shuffle (Hadoop's `setCombinerClass`). Must
/// be algebraically safe to apply 0..n times (associative + idempotent
/// w.r.t. the reducer), which holds for the stage-1 cumulus union.
pub trait Combiner: Sync {
    /// Key type.
    type K: Record + Send;
    /// Value type.
    type V: Record + Send;

    /// Fold `values` (≥2 entries of one key) into fewer entries.
    fn combine(&self, key: &Self::K, values: Vec<Self::V>) -> Vec<Self::V>;
}

/// No-op combiner used when a job doesn't configure one.
pub struct NoCombiner<K, V>(PhantomData<(K, V)>);

impl<K, V> Default for NoCombiner<K, V> {
    fn default() -> Self {
        Self(PhantomData)
    }
}

impl<K, V> Combiner for NoCombiner<K, V>
where
    K: Record + Send + Sync,
    V: Record + Send + Sync,
{
    type K = K;
    type V = V;

    fn combine(&self, _key: &K, values: Vec<V>) -> Vec<V> {
        values
    }
}

/// Collects emitted pairs; the engine encodes and partitions them.
pub struct Emitter<K, V> {
    pairs: Vec<(K, V)>,
}

impl<K, V> Emitter<K, V> {
    fn new() -> Self {
        Self { pairs: Vec::new() }
    }

    #[inline]
    /// Emit one key/value pair into the task output buffer.
    pub fn emit(&mut self, key: K, value: V) {
        self.pairs.push((key, value));
    }

    /// Standalone emitter for unit-testing mappers/reducers directly.
    pub fn new_for_test() -> Self {
        Self::new()
    }

    /// Drain collected pairs (test helper).
    pub fn into_pairs(self) -> Vec<(K, V)> {
        self.pairs
    }
}

/// Job configuration — the `JobConfigurator` analogue.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Job name (used in stats and DFS block names).
    pub name: String,
    /// Number of map tasks the input is split into.
    pub map_tasks: usize,
    /// Number of reduce tasks (= shuffle partitions).
    pub reduce_tasks: usize,
    /// OS threads actually used to execute tasks on this machine.
    pub executor_threads: usize,
    /// Probability that a map task fails after completion and is retried,
    /// re-emitting its outputs (duplicate tuples — the paper's K1–K3
    /// robustness scenario).
    pub fault_prob: f64,
    /// Seed for fault injection.
    pub seed: u64,
    /// Materialise intermediates through the (replicated) DFS.
    pub use_dfs: bool,
}

impl Default for JobConfig {
    fn default() -> Self {
        let threads = pool::default_workers();
        Self {
            name: "job".into(),
            map_tasks: threads.max(4),
            reduce_tasks: threads.max(4),
            executor_threads: threads,
            fault_prob: 0.0,
            seed: 0x5EED,
            use_dfs: true,
        }
    }
}

impl JobConfig {
    /// Default config with the given job name.
    pub fn named(name: &str) -> Self {
        Self { name: name.into(), ..Self::default() }
    }
}

/// Everything measured about one job run.
#[derive(Debug, Clone, Default)]
pub struct JobStats {
    /// Name of the job these stats describe.
    pub name: String,
    /// Wall-clock per map task (ms) — feeds the virtual cluster clock.
    pub map_task_ms: Vec<f64>,
    /// Wall-clock per reduce task (ms).
    pub reduce_task_ms: Vec<f64>,
    /// Total wall time of the job on this machine (ms).
    pub wall_ms: f64,
    /// Bytes moved through the shuffle (logical).
    pub shuffle_bytes: u64,
    /// Counter values accumulated across all tasks.
    pub counters: Counters,
}

impl JobStats {
    /// Simulated makespan on an `r`-node cluster: map barrier + reduce
    /// barrier, LPT list scheduling per phase (see task.rs).
    pub fn makespan_ms(&self, r: usize) -> f64 {
        task::lpt_makespan(&self.map_task_ms, r)
            + task::lpt_makespan(&self.reduce_task_ms, r)
    }

    /// Sequential (1-node) virtual time.
    pub fn sequential_ms(&self) -> f64 {
        self.map_task_ms.iter().sum::<f64>()
            + self.reduce_task_ms.iter().sum::<f64>()
    }
}

/// Run a MapReduce job: `input` → map → shuffle → reduce → typed output.
///
/// Output pairs are returned grouped by reduce partition then key order
/// (deterministic given the config).
pub fn run_job<M, R>(
    cfg: &JobConfig,
    mapper: &M,
    reducer: &R,
    input: Vec<(M::InK, M::InV)>,
    dfs: &Dfs,
) -> Result<(Vec<(R::OutK, R::OutV)>, JobStats)>
where
    M: Mapper,
    R: Reducer<InK = M::OutK, InV = M::OutV>,
{
    run_job_with_combiner(
        cfg,
        mapper,
        None::<&NoCombiner<M::OutK, M::OutV>>,
        reducer,
        input,
        dfs,
    )
}

/// `run_job` with an optional map-side combiner (Hadoop
/// `setCombinerClass`): each map task sorts and combines its own output
/// per partition before the shuffle, trading map CPU for shuffle bytes.
pub fn run_job_with_combiner<M, C, R>(
    cfg: &JobConfig,
    mapper: &M,
    combiner: Option<&C>,
    reducer: &R,
    input: Vec<(M::InK, M::InV)>,
    dfs: &Dfs,
) -> Result<(Vec<(R::OutK, R::OutV)>, JobStats)>
where
    M: Mapper,
    C: Combiner<K = M::OutK, V = M::OutV>,
    R: Reducer<InK = M::OutK, InV = M::OutV>,
{
    let job_timer = Timer::start();
    let mut stats = JobStats { name: cfg.name.clone(), ..Default::default() };
    let n_input = input.len();
    let map_tasks = cfg.map_tasks.max(1).min(n_input.max(1));
    let r = cfg.reduce_tasks.max(1);

    // ---- split input into map task slices -------------------------------
    let mut splits: Vec<Vec<(M::InK, M::InV)>> = Vec::with_capacity(map_tasks);
    {
        let per = n_input.div_ceil(map_tasks);
        let mut it = input.into_iter();
        for _ in 0..map_tasks {
            let chunk: Vec<_> = it.by_ref().take(per).collect();
            if !chunk.is_empty() {
                splits.push(chunk);
            }
        }
    }

    // ---- map phase -------------------------------------------------------
    // Map outputs are encoded DIRECTLY into one length-framed byte blob
    // per partition (§Perf: no per-record Vec allocations; the same blob
    // format travels through the DFS and into the reduce sort).
    struct MapOut {
        partitions: Vec<Vec<u8>>,
        ms: f64,
        counters: Counters,
    }
    let fault_prob = cfg.fault_prob;
    let seed = cfg.seed;
    let map_results: Vec<MapOut> =
        pool::parallel_map(splits.len(), cfg.executor_threads, 1, |t| {
            let split = &splits[t];
            let timer = Timer::start();
            let mut counters = Counters::new();
            let mut partitions: Vec<Vec<u8>> = (0..r).map(|_| Vec::new()).collect();
            let mut kbuf: Vec<u8> = Vec::new();
            let mut vbuf: Vec<u8> = Vec::new();
            // fault injection: a retried task reprocesses its whole split,
            // duplicating every emitted pair (paper §5.1 rationale).
            let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E3779B9));
            let attempts = if fault_prob > 0.0 && rng.chance(fault_prob) {
                counters.inc(names::TASK_RETRIES, 1);
                counters.inc(names::DUPLICATE_INPUTS, split.len() as u64);
                2
            } else {
                1
            };
            for _ in 0..attempts {
                for (k, v) in split.iter() {
                    counters.inc(names::MAP_INPUT_RECORDS, 1);
                    let mut emitter = Emitter::new();
                    mapper.map(k.clone(), v.clone(), &mut emitter);
                    for (ok, ov) in emitter.pairs {
                        kbuf.clear();
                        ok.encode(&mut kbuf);
                        vbuf.clear();
                        ov.encode(&mut vbuf);
                        let part = (fxhash(&kbuf) % r as u64) as usize;
                        counters.inc(names::MAP_OUTPUT_RECORDS, 1);
                        let blob = &mut partitions[part];
                        (kbuf.len() as u32).encode(blob);
                        blob.extend_from_slice(&kbuf);
                        (vbuf.len() as u32).encode(blob);
                        blob.extend_from_slice(&vbuf);
                    }
                }
            }
            // map-side combine: sort+group this task's blob per partition
            // and fold values before they hit the shuffle
            if let Some(comb) = combiner {
                for blob in partitions.iter_mut() {
                    if blob.is_empty() {
                        continue;
                    }
                    let mut pairs: Vec<(&[u8], &[u8])> = Vec::new();
                    let mut s = blob.as_slice();
                    while !s.is_empty() {
                        let kl = u32::decode(&mut s) as usize;
                        let (kb, rest) = s.split_at(kl);
                        s = rest;
                        let vl = u32::decode(&mut s) as usize;
                        let (vb, rest) = s.split_at(vl);
                        s = rest;
                        pairs.push((kb, vb));
                    }
                    pairs.sort_unstable();
                    let mut out_blob: Vec<u8> = Vec::with_capacity(blob.len());
                    let mut i = 0;
                    while i < pairs.len() {
                        let mut j = i + 1;
                        while j < pairs.len() && pairs[j].0 == pairs[i].0 {
                            j += 1;
                        }
                        let kb = pairs[i].0;
                        let combined = if j - i > 1 {
                            let key = M::OutK::from_bytes(kb);
                            let values: Vec<M::OutV> = pairs[i..j]
                                .iter()
                                .map(|(_, vb)| M::OutV::from_bytes(vb))
                                .collect();
                            counters.inc(
                                names::COMBINE_INPUT_RECORDS,
                                (j - i) as u64,
                            );
                            let folded = comb.combine(&key, values);
                            counters.inc(
                                names::COMBINE_OUTPUT_RECORDS,
                                folded.len() as u64,
                            );
                            Some(folded)
                        } else {
                            None
                        };
                        match combined {
                            Some(folded) => {
                                for v in folded {
                                    (kb.len() as u32).encode(&mut out_blob);
                                    out_blob.extend_from_slice(kb);
                                    let mut vb = Vec::new();
                                    v.encode(&mut vb);
                                    (vb.len() as u32).encode(&mut out_blob);
                                    out_blob.extend_from_slice(&vb);
                                }
                            }
                            None => {
                                let (kb, vb) = pairs[i];
                                (kb.len() as u32).encode(&mut out_blob);
                                out_blob.extend_from_slice(kb);
                                (vb.len() as u32).encode(&mut out_blob);
                                out_blob.extend_from_slice(vb);
                            }
                        }
                        i = j;
                    }
                    *blob = out_blob;
                }
            }
            MapOut { partitions, ms: timer.elapsed_ms(), counters }
        });

    for m in &map_results {
        stats.map_task_ms.push(m.ms);
        stats.counters.merge(&m.counters);
    }

    // ---- shuffle: materialise per (map task, partition) through DFS ------
    if cfg.use_dfs {
        for (t, m) in map_results.iter().enumerate() {
            for (p, blob) in m.partitions.iter().enumerate() {
                if blob.is_empty() {
                    continue;
                }
                stats.shuffle_bytes += blob.len() as u64;
                dfs.put(&format!("{}/m{}/p{}", cfg.name, t, p), blob.clone())?;
            }
        }
        stats
            .counters
            .inc(names::SHUFFLE_BYTES, stats.shuffle_bytes);
        stats.counters.inc(
            names::REPLICATED_BYTES,
            stats.shuffle_bytes * dfs.replication() as u64,
        );
    } else {
        for m in &map_results {
            for blob in &m.partitions {
                stats.shuffle_bytes += blob.len() as u64;
            }
        }
        stats
            .counters
            .inc(names::SHUFFLE_BYTES, stats.shuffle_bytes);
    }

    // gather partition p across all map tasks: returns the raw blobs;
    // the reduce task sorts borrowed slices into them (§Perf: zero-copy
    // shuffle — no per-record Vec allocations)
    // blocks stay in the DFS until the job completes (Hadoop keeps map
    // outputs for re-fetch on reduce-task retry); deleted after the
    // reduce phase below
    let gather = |p: usize| -> Vec<Vec<u8>> {
        if cfg.use_dfs {
            let mut blobs = Vec::new();
            for t in 0..map_results.len() {
                let name = format!("{}/m{}/p{}", cfg.name, t, p);
                if let Ok(blob) = dfs.get(&name) {
                    blobs.push(blob);
                }
            }
            blobs
        } else {
            map_results.iter().map(|m| m.partitions[p].clone()).collect()
        }
    };

    // ---- reduce phase ----------------------------------------------------
    struct ReduceOut<K, V> {
        out: Vec<(K, V)>,
        ms: f64,
        counters: Counters,
    }
    let reduce_results: Vec<ReduceOut<R::OutK, R::OutV>> =
        pool::parallel_map(r, cfg.executor_threads, 1, |p| {
            let timer = Timer::start();
            let mut counters = Counters::new();
            // reduce-task retry: the first attempt's work (including the
            // shuffle re-fetch) is discarded and redone — wasted wall
            // time, never duplicated output (Hadoop's commit protocol)
            let mut rng =
                Rng::new(seed ^ 0x5ED0C3 ^ (p as u64).wrapping_mul(0x85EB_CA6B));
            if fault_prob > 0.0 && rng.chance(fault_prob) {
                counters.inc(names::TASK_RETRIES, 1);
                let blobs = gather(p);
                std::hint::black_box(blobs.iter().map(Vec::len).sum::<usize>());
            }
            let blobs = gather(p);
            // borrow (key, value) slices out of the blobs — zero copies
            let mut pairs: Vec<(&[u8], &[u8])> = Vec::new();
            for blob in &blobs {
                let mut s = blob.as_slice();
                while !s.is_empty() {
                    let kl = u32::decode(&mut s) as usize;
                    let (kb, rest) = s.split_at(kl);
                    s = rest;
                    let vl = u32::decode(&mut s) as usize;
                    let (vb, rest) = s.split_at(vl);
                    s = rest;
                    pairs.push((kb, vb));
                }
            }
            // the sort phase: raw byte comparison of encoded keys
            pairs.sort_unstable();
            let mut out = Vec::new();
            let mut i = 0;
            while i < pairs.len() {
                let mut j = i + 1;
                while j < pairs.len() && pairs[j].0 == pairs[i].0 {
                    j += 1;
                }
                counters.inc(names::REDUCE_INPUT_GROUPS, 1);
                counters.inc(names::REDUCE_INPUT_RECORDS, (j - i) as u64);
                let key = R::InK::from_bytes(pairs[i].0);
                let values: Vec<R::InV> = pairs[i..j]
                    .iter()
                    .map(|(_, vb)| R::InV::from_bytes(vb))
                    .collect();
                let mut emitter = Emitter::new();
                reducer.reduce(key, values, &mut emitter);
                counters
                    .inc(names::REDUCE_OUTPUT_RECORDS, emitter.pairs.len() as u64);
                out.extend(emitter.pairs);
                i = j;
            }
            ReduceOut { out, ms: timer.elapsed_ms(), counters }
        });

    // job complete: release the materialised map outputs
    if cfg.use_dfs {
        for t in 0..map_results.len() {
            for p in 0..r {
                dfs.delete(&format!("{}/m{}/p{}", cfg.name, t, p));
            }
        }
    }

    let mut output = Vec::new();
    for rr in reduce_results {
        stats.reduce_task_ms.push(rr.ms);
        stats.counters.merge(&rr.counters);
        output.extend(rr.out);
    }
    stats.wall_ms = job_timer.elapsed_ms();
    Ok((output, stats))
}

/// Identity mapper — handy for reduce-only stages and tests.
pub struct IdentityMapper<K, V>(pub PhantomData<(K, V)>);

impl<K, V> Default for IdentityMapper<K, V> {
    fn default() -> Self {
        Self(PhantomData)
    }
}

impl<K, V> Mapper for IdentityMapper<K, V>
where
    K: Record + Send + Sync + Clone,
    V: Record + Send + Sync + Clone,
{
    type InK = K;
    type InV = V;
    type OutK = K;
    type OutV = V;

    fn map(&self, key: K, value: V, emit: &mut Emitter<K, V>) {
        emit.emit(key, value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Word-count style: tokens → (token, 1) → (token, sum).
    struct TokenMapper;

    impl Mapper for TokenMapper {
        type InK = ();
        type InV = String;
        type OutK = String;
        type OutV = u64;

        fn map(&self, _k: (), v: String, emit: &mut Emitter<String, u64>) {
            for tok in v.split_whitespace() {
                emit.emit(tok.to_string(), 1);
            }
        }
    }

    struct SumReducer;

    impl Reducer for SumReducer {
        type InK = String;
        type InV = u64;
        type OutK = String;
        type OutV = u64;

        fn reduce(&self, k: String, vs: Vec<u64>, emit: &mut Emitter<String, u64>) {
            emit.emit(k, vs.iter().sum());
        }
    }

    fn wordcount(cfg: &JobConfig) -> Vec<(String, u64)> {
        let input: Vec<((), String)> = vec![
            ((), "a b a".into()),
            ((), "b c".into()),
            ((), "a".into()),
        ];
        let dfs = Dfs::in_memory();
        let (mut out, stats) =
            run_job(cfg, &TokenMapper, &SumReducer, input, &dfs).unwrap();
        out.sort();
        assert_eq!(stats.counters.get(names::MAP_INPUT_RECORDS) >= 3, true);
        out
    }

    #[test]
    fn wordcount_basic() {
        let cfg = JobConfig::named("wc");
        let out = wordcount(&cfg);
        assert_eq!(
            out,
            vec![("a".into(), 3), ("b".into(), 2), ("c".into(), 1)]
        );
    }

    #[test]
    fn wordcount_without_dfs_matches() {
        let cfg = JobConfig { use_dfs: false, ..JobConfig::named("wc2") };
        let out = wordcount(&cfg);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], ("a".into(), 3));
    }

    #[test]
    fn many_partitions_and_tasks() {
        let cfg = JobConfig {
            map_tasks: 7,
            reduce_tasks: 5,
            ..JobConfig::named("wc3")
        };
        let out = wordcount(&cfg);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn fault_injection_duplicates_are_visible_in_counts() {
        // With fault_prob = 1 every map task retries: sums double.
        let cfg = JobConfig {
            fault_prob: 1.0,
            map_tasks: 2,
            ..JobConfig::named("wc4")
        };
        let input: Vec<((), String)> =
            vec![((), "x".into()), ((), "x y".into())];
        let dfs = Dfs::in_memory();
        let (mut out, stats) =
            run_job(&cfg, &TokenMapper, &SumReducer, input, &dfs).unwrap();
        out.sort();
        assert_eq!(out, vec![("x".into(), 4), ("y".into(), 2)]);
        assert!(stats.counters.get(names::TASK_RETRIES) >= 1);
    }

    #[test]
    fn stats_have_task_timings() {
        let cfg = JobConfig { map_tasks: 3, ..JobConfig::named("wc5") };
        let input: Vec<((), String)> =
            (0..30).map(|i| ((), format!("w{} w{}", i % 5, i % 3))).collect();
        let dfs = Dfs::in_memory();
        let (_, stats) =
            run_job(&cfg, &TokenMapper, &SumReducer, input, &dfs).unwrap();
        assert_eq!(stats.map_task_ms.len(), 3);
        assert!(stats.makespan_ms(2) <= stats.sequential_ms() + 1e-9);
        assert!(stats.shuffle_bytes > 0);
    }

    #[test]
    fn identity_mapper_passthrough() {
        let cfg = JobConfig::named("id");
        let dfs = Dfs::in_memory();
        let input: Vec<(u32, u64)> = vec![(1, 10), (2, 20), (1, 30)];
        let (out, _) = run_job(
            &cfg,
            &IdentityMapper::<u32, u64>::default(),
            &SumU32Reducer,
            input,
            &dfs,
        )
        .unwrap();
        let mut out = out;
        out.sort();
        assert_eq!(out, vec![(1, 40), (2, 20)]);
    }

    struct SumU32Reducer;

    impl Reducer for SumU32Reducer {
        type InK = u32;
        type InV = u64;
        type OutK = u32;
        type OutV = u64;

        fn reduce(&self, k: u32, vs: Vec<u64>, emit: &mut Emitter<u32, u64>) {
            emit.emit(k, vs.iter().sum());
        }
    }
}
