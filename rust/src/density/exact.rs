//! Exact density: the scalar hash-membership oracle and the bitset
//! kernel that replaces it on the hot path.
//!
//! The scalar path probes the context's tuple hash set once per cuboid
//! cell — `O(volume)` probes per cluster, each a full tuple hash. The
//! bitset kernel ([`densities_bitset`]) instead builds per-(g, m) `u64`
//! rows over the third modality ONCE per call ([`BitRows`]) and reduces
//! each cluster to `popcount(row & modus_mask)` sums — 64 cells per
//! word-AND, no hashing, sequential row reads. Both count exactly, so
//! they return bit-identical densities (property-tested in
//! `rust/tests/proptests.rs`); the scalar path remains the reference
//! oracle and the fallback when the row table would not fit
//! [`BITSET_MAX_BYTES`] or the workload is too small to amortise the
//! build.

use crate::core::context::TriContext;
use crate::core::pattern::Cluster;
use crate::density::tiling::{bit_mask, BitRows};
use crate::density::DensityEngine;

/// Byte cap on the bitset row table (|G|·|M|·⌈|B|/64⌉·8); above it the
/// engine falls back to scalar counting.
pub const BITSET_MAX_BYTES: usize = 64 << 20;

/// Minimum total cuboid cells below which the row-table build costs more
/// than the scalar probes it replaces.
const BITSET_MIN_CELLS: f64 = 4096.0;

#[derive(Default)]
/// Exact per-cluster density over the raw tuple set (the reference
/// the sampled and compiled engines are validated against). Dispatches
/// to the bitset kernel when profitable; the result is identical either
/// way.
pub struct ExactEngine;

/// The scalar reference: one hash membership probe per cuboid cell.
pub fn densities_scalar(ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64> {
    clusters
        .iter()
        .map(|c| {
            let vol = c.volume();
            if vol == 0.0 {
                return 0.0;
            }
            let mut hit = 0u64;
            for &g in &c.components[0] {
                for &m in &c.components[1] {
                    for &b in &c.components[2] {
                        if ctx.contains(g, m, b) {
                            hit += 1;
                        }
                    }
                }
            }
            hit as f64 / vol
        })
        .collect()
}

/// The bitset kernel: build the per-(g, m) row table once, then count
/// every cluster with word-AND + popcount. Returns `None` when the table
/// would exceed `max_bytes` (the caller falls back to
/// [`densities_scalar`]). Exact — equal to the scalar oracle bit for
/// bit.
pub fn densities_bitset(
    ctx: &TriContext,
    clusters: &[Cluster],
    max_bytes: usize,
) -> Option<Vec<f64>> {
    let rows = BitRows::build(ctx, max_bytes)?;
    let words = rows.words();
    let mut mask: Vec<u64> = Vec::new();
    Some(
        clusters
            .iter()
            .map(|c| {
                let vol = c.volume();
                if vol == 0.0 {
                    return 0.0;
                }
                bit_mask(&c.components[2], words, &mut mask);
                let mut hit = 0u64;
                for &g in &c.components[0] {
                    for &m in &c.components[1] {
                        if let Some(row) = rows.row(g, m) {
                            for (w, &bits) in row.iter().enumerate() {
                                hit += (bits & mask[w]).count_ones() as u64;
                            }
                        }
                    }
                }
                hit as f64 / vol
            })
            .collect(),
    )
}

impl DensityEngine for ExactEngine {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn densities(&mut self, ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64> {
        let cells: f64 = clusters.iter().map(Cluster::volume).sum();
        if cells >= BITSET_MIN_CELLS {
            if let Some(out) = densities_bitset(ctx, clusters, BITSET_MAX_BYTES) {
                crate::obs::counter("density.dispatch.bitset", 1);
                return out;
            }
            // the row table would not fit BITSET_MAX_BYTES
            crate::obs::counter("density.dispatch.scalar_fallback", 1);
        } else {
            // too few cuboid cells to amortise the row-table build
            crate::obs::counter("density.dispatch.scalar_small", 1);
        }
        densities_scalar(ctx, clusters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;
    use crate::datasets::synthetic::{k1, k2};

    #[test]
    fn dense_block_is_one() {
        let ctx = k2(3);
        let mut e = ExactEngine;
        let c = tricluster(vec![0, 1, 2], vec![0, 1, 2], vec![0, 1, 2]);
        assert_eq!(e.densities(&ctx, &[c]), vec![1.0]);
    }

    #[test]
    fn cross_block_is_sparse() {
        let ctx = k2(3);
        let mut e = ExactEngine;
        // spanning two blocks: only the two diagonal blocks hit → 2·27 of
        // 6³ = 216 cells
        let c = tricluster(
            vec![0, 1, 2, 3, 4, 5],
            vec![0, 1, 2, 3, 4, 5],
            vec![0, 1, 2, 3, 4, 5],
        );
        let d = e.densities(&ctx, &[c])[0];
        assert!((d - 54.0 / 216.0).abs() < 1e-12);
    }

    #[test]
    fn bitset_matches_scalar_oracle() {
        use crate::oac::{mine_online, Constraints};
        for ctx in [k1(7), k2(5)] {
            let mut clusters = mine_online(&ctx.inner, &Constraints::none());
            // a cluster reaching past every extent: rows must treat the
            // missing (g, m) pairs and high b bits as empty, not panic
            clusters.push(tricluster(vec![0, 90], vec![1, 80], vec![0, 63, 200]));
            clusters.push(tricluster(vec![], vec![0], vec![0])); // zero volume
            let scalar = densities_scalar(&ctx, &clusters);
            let bits = densities_bitset(&ctx, &clusters, usize::MAX)
                .expect("small contexts always fit");
            assert_eq!(scalar, bits);
        }
    }

    #[test]
    fn byte_cap_falls_back_to_scalar() {
        let ctx = k2(3);
        let c = tricluster(vec![0, 1, 2], vec![0, 1, 2], vec![0, 1, 2]);
        assert!(densities_bitset(&ctx, &[c.clone()], 8).is_none());
        // the engine still answers (scalar fallback)
        assert_eq!(ExactEngine.densities(&ctx, &[c]), vec![1.0]);
    }
}
