//! The three MapReduce stages of distributed multimodal clustering
//! (paper §4.1, Algorithms 2–7).
//!
//! Stage 1 — cumuli: tuples fan out to N ⟨subrelation, entity⟩ pairs
//!   (Alg. 2); the reducer accumulates each subrelation's cumulus
//!   (Alg. 3 — we emit the final cumulus once; emitting the running
//!   prefix per value, as the pseudo-code literally reads, produces the
//!   same final stage-2 input with strictly more traffic).
//! Stage 2 — assembly: each ⟨subrelation, cumulus⟩ is expanded back to
//!   its generating tuples (Alg. 4); the reducer zips the N cumuli into
//!   a multimodal cluster per generating tuple (Alg. 5).
//! Stage 3 — dedup + density: key/value swap to ⟨cluster, generating
//!   tuple⟩ (Alg. 6); the reducer counts distinct generating tuples,
//!   computes density support/volume and keeps clusters above θ
//!   (Alg. 7).

use crate::core::pattern::Cluster;
use crate::core::tuple::{NTuple, SubRelation};
use crate::hadoop::job::{Emitter, Mapper, Reducer};

// --------------------------------------------------------------------------
// Stage 1
// --------------------------------------------------------------------------

/// Alg. 2: `(e_1..e_N)` → `⟨subrelation_k, e_k⟩` for every k.
pub struct FirstMapper;

impl Mapper for FirstMapper {
    type InK = ();
    type InV = NTuple;
    type OutK = SubRelation;
    type OutV = u32;

    fn map(&self, _k: (), t: NTuple, emit: &mut Emitter<SubRelation, u32>) {
        for k in 0..t.arity() {
            emit.emit(t.subrelation(k), t.get(k));
        }
    }
}

/// Optional map-side combiner for stage 1 (Hadoop `setCombinerClass`):
/// deduplicates a map task's local entity emissions per subrelation
/// before the shuffle. Safe because the stage-1 reduce is a set union —
/// associative and idempotent. Shuffle-byte savings are measured by the
/// combiner ablation.
pub struct FirstCombiner;

impl crate::hadoop::job::Combiner for FirstCombiner {
    type K = SubRelation;
    type V = u32;

    fn combine(&self, _key: &SubRelation, mut values: Vec<u32>) -> Vec<u32> {
        values.sort_unstable();
        values.dedup();
        values
    }
}

/// Alg. 3: accumulate the cumulus of each subrelation. Values may repeat
/// (task retries); the cumulus is a set.
pub struct FirstReducer;

impl Reducer for FirstReducer {
    type InK = SubRelation;
    type InV = u32;
    type OutK = SubRelation;
    type OutV = Vec<u32>;

    fn reduce(
        &self,
        key: SubRelation,
        mut values: Vec<u32>,
        emit: &mut Emitter<SubRelation, Vec<u32>>,
    ) {
        values.sort_unstable();
        values.dedup();
        emit.emit(key, values);
    }
}

// --------------------------------------------------------------------------
// Stage 2
// --------------------------------------------------------------------------

/// Alg. 4: re-insert each cumulus element at the dropped position to
/// recover the generating tuples; the cumulus travels with each
/// (tagged by the dropped modality so the stage-2 reducer can order the
/// N cumuli).
pub struct SecondMapper;

impl Mapper for SecondMapper {
    type InK = SubRelation;
    type InV = Vec<u32>;
    type OutK = NTuple;
    type OutV = (u32, Vec<u32>);

    fn map(
        &self,
        sub: SubRelation,
        cumulus: Vec<u32>,
        emit: &mut Emitter<NTuple, (u32, Vec<u32>)>,
    ) {
        let k = sub.dropped() as u32;
        for &e in &cumulus {
            let generating = NTuple::from_subrelation(&sub, e);
            emit.emit(generating, (k, cumulus.clone()));
        }
    }
}

/// Alg. 5: zip the N cumuli of one generating tuple into a cluster.
pub struct SecondReducer;

impl Reducer for SecondReducer {
    type InK = NTuple;
    type InV = (u32, Vec<u32>);
    type OutK = NTuple;
    type OutV = Cluster;

    fn reduce(
        &self,
        generating: NTuple,
        values: Vec<(u32, Vec<u32>)>,
        emit: &mut Emitter<NTuple, Cluster>,
    ) {
        let n = generating.arity();
        let mut comps: Vec<Option<Vec<u32>>> = vec![None; n];
        for (k, cumulus) in values {
            let slot = &mut comps[k as usize];
            // duplicates from retries carry identical cumuli; keep first
            if slot.is_none() {
                *slot = Some(cumulus);
            }
        }
        // every position must be present: tuple (e_1..e_N) ∈ I implies all
        // N subrelations emitted a cumulus containing e_k
        let comps: Vec<Vec<u32>> = comps
            .into_iter()
            .map(|c| c.expect("missing cumulus for a generating tuple"))
            .collect();
        emit.emit(generating, Cluster::new(comps));
    }
}

// --------------------------------------------------------------------------
// Stage 3
// --------------------------------------------------------------------------

/// Alg. 6: swap to ⟨cluster, generating tuple⟩ so dedup happens in the
/// reducer's key grouping.
pub struct ThirdMapper;

impl Mapper for ThirdMapper {
    type InK = NTuple;
    type InV = Cluster;
    type OutK = Cluster;
    type OutV = NTuple;

    fn map(&self, t: NTuple, c: Cluster, emit: &mut Emitter<Cluster, NTuple>) {
        emit.emit(c, t);
    }
}

/// Alg. 7: support = |distinct generating tuples|; keep clusters with
/// support/volume ≥ θ.
pub struct ThirdReducer {
    pub theta: f64,
}

impl Reducer for ThirdReducer {
    type InK = Cluster;
    type InV = NTuple;
    type OutK = Cluster;
    type OutV = u64;

    fn reduce(
        &self,
        mut cluster: Cluster,
        mut gens: Vec<NTuple>,
        emit: &mut Emitter<Cluster, u64>,
    ) {
        gens.sort_unstable();
        gens.dedup();
        cluster.support = gens.len();
        let vol = cluster.volume();
        if vol > 0.0 && cluster.support as f64 / vol >= self.theta {
            let support = cluster.support as u64;
            emit.emit(cluster, support);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_map<M: Mapper>(m: &M, k: M::InK, v: M::InV) -> Vec<(M::OutK, M::OutV)> {
        let mut e = Emitter::new_for_test();
        m.map(k, v, &mut e);
        e.into_pairs()
    }

    fn run_reduce<R: Reducer>(
        r: &R,
        k: R::InK,
        vs: Vec<R::InV>,
    ) -> Vec<(R::OutK, R::OutV)> {
        let mut e = Emitter::new_for_test();
        r.reduce(k, vs, &mut e);
        e.into_pairs()
    }

    #[test]
    fn first_mapper_fans_out_n_pairs() {
        let t = NTuple::triple(1, 2, 3);
        let out = run_map(&FirstMapper, (), t);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], (t.subrelation(0), 1));
        assert_eq!(out[2], (t.subrelation(2), 3));
    }

    #[test]
    fn first_reducer_dedups_cumulus() {
        let sub = NTuple::triple(0, 1, 2).subrelation(0);
        let out = run_reduce(&FirstReducer, sub, vec![5, 3, 5, 3, 1]);
        assert_eq!(out, vec![(sub, vec![1, 3, 5])]);
    }

    #[test]
    fn second_mapper_rebuilds_generating_tuples() {
        let t = NTuple::triple(7, 1, 2);
        let sub = t.subrelation(0);
        let out = run_map(&SecondMapper, sub, vec![7, 9]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, NTuple::triple(7, 1, 2));
        assert_eq!(out[1].0, NTuple::triple(9, 1, 2));
        assert_eq!(out[0].1, (0, vec![7, 9]));
    }

    #[test]
    fn second_reducer_zips_cumuli_in_modality_order() {
        let t = NTuple::triple(0, 1, 2);
        let out = run_reduce(
            &SecondReducer,
            t,
            vec![
                (2, vec![2, 9]),       // modus arrives first
                (0, vec![0]),
                (1, vec![1, 4]),
                (1, vec![1, 4]),       // retry duplicate — ignored
            ],
        );
        assert_eq!(out.len(), 1);
        let c = &out[0].1;
        assert_eq!(c.components, vec![vec![0], vec![1, 4], vec![2, 9]]);
    }

    #[test]
    fn third_reducer_counts_distinct_and_filters() {
        let c = Cluster::new(vec![vec![0], vec![1, 4], vec![2]]);
        // volume 2; 2 distinct generating tuples (one duplicated) → ρ = 1
        let gens = vec![
            NTuple::triple(0, 1, 2),
            NTuple::triple(0, 4, 2),
            NTuple::triple(0, 1, 2),
        ];
        let out = run_reduce(&ThirdReducer { theta: 0.9 }, c.clone(), gens.clone());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1, 2);
        // θ = 1.1 rejects everything
        let out = run_reduce(&ThirdReducer { theta: 1.1 }, c, gens);
        assert!(out.is_empty());
    }
}
