//! `exec` — the backend-agnostic execution layer.
//!
//! The paper's central claim is a *comparison* of one OAC/NOAC pipeline
//! under different execution regimes: the MapReduce model (§4) versus
//! language-level parallelism (§6), with Spark as the projected third
//! regime (§7). This layer makes that comparison structural instead of
//! copy-based: the three M/R triclustering stages (cumuli → assembly →
//! dedup+density, Algorithms 2–7) are written ONCE as backend-generic
//! functions in [`stages`], and a [`Backend`] supplies the execution
//! substrate:
//!
//! * [`Sequential`] — single-threaded reference semantics;
//! * [`Pooled`] — `util::pool` thread-level parallelism (§6);
//! * [`HadoopSim`] — the fused mini-Hadoop job engine (§4), with DFS
//!   materialisation, fault injection, combiners, and per-stage stats;
//! * [`SparkSim`] — the in-memory RDD engine (§7);
//! * [`ClusterSim`] — the simulated N-node cluster (§4's distribution
//!   claim made testable): pluggable task [`placement`], per-node worker
//!   slots, straggler/failure injection, speculative execution with
//!   first-result-wins, per-stage adaptive task counts, a shuffle-cost
//!   model ([`ShuffleModel`]: bytes moved × per-MiB latency between
//!   non-colocated tasks), and seeded node churn ([`ChurnConfig`]:
//!   kill/restart mid-phase).
//!
//! The serving layer rides the same abstractions:
//! [`crate::serve::cluster::ServeSim`] places serve shards on the
//! simulated nodes via the [`Placement`] trait.
//!
//! `tricluster mr --backend {seq,pool,hadoop,spark,cluster}` selects a
//! backend from the CLI, `benches/backend_matrix.rs` sweeps the full
//! matrix (writing `BENCH_backends.json`),
//! `benches/cluster_scaling.rs` sweeps the simulated cluster
//! (nodes × straggler rate × speculation, writing `BENCH_cluster.json`),
//! and `rust/tests/backend_equivalence.rs` property-tests that every
//! backend reproduces `oac::mine_online` exactly — including
//! [`ClusterSim`] under randomized straggler/failure schedules.

pub mod backend;
pub mod cluster_sim;
pub mod hadoop_sim;
pub mod placement;
pub mod pooled;
pub mod sequential;
pub mod spark_sim;
pub mod stages;

pub use backend::{
    group_pairs_presorted, no_combine, sorted_by_key, Backend, Data, Key,
};
pub use cluster_sim::{
    ChurnConfig, ClusterConfig, ClusterSim, ClusterStats, CostModel, ShuffleModel,
};
pub use hadoop_sim::HadoopSim;
pub use placement::Placement;
pub use pooled::Pooled;
pub use sequential::Sequential;
pub use spark_sim::SparkSim;
pub use stages::{
    run_pipeline, run_pipeline_ingest, run_pipeline_ingest_tuned, stage1_cumuli,
    stage1_cumuli_ingest, stage2_assembly, stage3_dedup_density,
    stage3_dedup_density_par, Components,
};

use anyhow::Result;

use crate::core::context::PolyContext;
use crate::core::pattern::Cluster;
use crate::hadoop::dfs::{Dfs, DfsConfig};
use crate::hadoop::job::JobConfig;
use crate::spark::rdd::SparkContext;
use crate::util::pool;
use crate::util::stats::Timer;

/// The five backend names, in canonical comparison order.
pub const BACKENDS: [&str; 5] = ["seq", "pool", "hadoop", "spark", "cluster"];

/// Tuning knobs shared by every backend (each uses the subset it
/// understands).
#[derive(Debug, Clone)]
pub struct ExecTuning {
    /// Worker threads (Pooled; executor threads for HadoopSim/SparkSim;
    /// REAL task-closure threads for ClusterSim).
    pub workers: usize,
    /// Task granularity: map/reduce tasks (HadoopSim), RDD partitions
    /// (SparkSim), fixed per-phase task count for ClusterSim when
    /// `adaptive_tasks` is off.
    pub tasks: usize,
    /// HadoopSim task-retry probability; ClusterSim first-attempt task
    /// failure probability.
    pub fault_prob: f64,
    /// Seed for fault/straggler/churn schedules.
    pub seed: u64,
    /// HadoopSim: materialise intermediates through the replicated DFS.
    pub use_dfs: bool,
    /// ClusterSim: simulated node count.
    pub nodes: usize,
    /// ClusterSim: worker slots per simulated node.
    pub node_slots: usize,
    /// ClusterSim: per-attempt straggler probability.
    pub straggler_prob: f64,
    /// ClusterSim: straggler slowdown multiplier.
    pub straggler_factor: f64,
    /// ClusterSim: race speculative duplicates against stragglers.
    pub speculation: bool,
    /// ClusterSim: placement policy name (`rr` | `locality` | `least`).
    pub placement: String,
    /// ClusterSim: per-phase adaptive task counts (input size × previous
    /// stage's measured skew).
    pub adaptive_tasks: bool,
    /// ClusterSim: simulated per-record task cost (ms); `None` uses the
    /// measured wall time of each task closure.
    pub cost_ms_per_record: Option<f64>,
    /// ClusterSim: wire size of one shuffled record, bytes (0 disables
    /// the shuffle-cost model).
    pub shuffle_bytes_per_record: f64,
    /// ClusterSim: transfer latency per MiB moved between two different
    /// nodes, ms (0 disables the shuffle-cost model).
    pub shuffle_ms_per_mib: f64,
    /// ClusterSim: per-phase probability that each node is killed
    /// mid-phase (0 disables churn).
    pub churn_prob: f64,
    /// ClusterSim: downtime of a killed node before restart, ms.
    pub churn_restart_ms: f64,
    /// In-process backends (`seq`, `pool`): run stage 1 via the
    /// allocation-free merge-based ingest kernel
    /// ([`stages::stage1_cumuli_ingest`]) instead of a generic
    /// map→shuffle→reduce round. Output-equivalent (property-tested);
    /// the simulated engines keep their shuffle — modelling it is their
    /// job. `seq` uses one worker, `pool` uses `workers`.
    pub parallel_ingest: bool,
    /// In-process backends with `parallel_ingest`: hash partitions for
    /// the in-process stage-3 grouper
    /// ([`stages::stage3_dedup_density_par`]); `0` keeps stage 3 as a
    /// backend `group_reduce` round. Output-equivalent either way
    /// (property-tested across random values).
    pub dedup_partitions: usize,
}

impl Default for ExecTuning {
    fn default() -> Self {
        let workers = pool::default_workers();
        Self {
            workers,
            tasks: (workers * 4).max(8),
            fault_prob: 0.0,
            seed: 0x5EED,
            use_dfs: false,
            nodes: 4,
            node_slots: 2,
            straggler_prob: 0.0,
            straggler_factor: 6.0,
            speculation: true,
            placement: "least".into(),
            adaptive_tasks: true,
            cost_ms_per_record: None,
            shuffle_bytes_per_record: 0.0,
            shuffle_ms_per_mib: 0.0,
            churn_prob: 0.0,
            churn_restart_ms: 50.0,
            parallel_ingest: true,
            dedup_partitions: workers.min(16),
        }
    }
}

impl ExecTuning {
    /// Build the ClusterSim config encoded in these knobs.
    pub fn cluster_config(&self) -> ClusterConfig {
        ClusterConfig {
            nodes: self.nodes.max(1),
            slots_per_node: self.node_slots.max(1),
            straggler_prob: self.straggler_prob,
            straggler_factor: self.straggler_factor,
            failure_prob: self.fault_prob,
            speculation: self.speculation,
            cost: match self.cost_ms_per_record {
                Some(ms) => CostModel::PerRecord(ms),
                None => CostModel::Measured,
            },
            tasks: self.tasks,
            adaptive_tasks: self.adaptive_tasks,
            shuffle: ShuffleModel {
                bytes_per_record: self.shuffle_bytes_per_record,
                ms_per_mib: self.shuffle_ms_per_mib,
            },
            churn: ChurnConfig {
                kill_prob: self.churn_prob,
                restart_ms: self.churn_restart_ms,
            },
            workers: self.workers,
            seed: self.seed,
            ..ClusterConfig::default()
        }
    }

    /// Build the ClusterSim backend encoded in these knobs.
    pub fn cluster_backend(&self) -> Result<ClusterSim> {
        Ok(ClusterSim::new(self.cluster_config(), placement::by_name(&self.placement)?))
    }
}

/// Result of [`run_named`]: the canonical (component-sorted) cluster set
/// plus wall time.
#[derive(Debug)]
pub struct PipelineRun {
    /// Backend id the pipeline ran on.
    pub backend: &'static str,
    /// Component-sorted cluster set.
    pub clusters: Vec<Cluster>,
    /// Wall time of the full pipeline, ms.
    pub wall_ms: f64,
}

/// Run the full cumuli → assembly → dedup+density pipeline on the
/// backend named by the CLI `--backend` flag (`seq`, `pool`, `hadoop`,
/// `spark`, or `cluster`).
pub fn run_named(
    name: &str,
    ctx: &PolyContext,
    theta: f64,
    tune: &ExecTuning,
) -> Result<PipelineRun> {
    let timer = Timer::start();
    let mut span = crate::span!("exec.run.{}", name);
    span.records_in(ctx.tuples().len() as u64);
    let (backend, clusters) = match name {
        "seq" if tune.parallel_ingest => (
            "seq",
            run_pipeline_ingest_tuned(&Sequential, ctx, theta, 1, tune.dedup_partitions)?,
        ),
        "seq" => ("seq", run_pipeline(&Sequential, ctx, theta, false)?),
        "pool" if tune.parallel_ingest => (
            "pool",
            run_pipeline_ingest_tuned(
                &Pooled::new(tune.workers),
                ctx,
                theta,
                tune.workers,
                tune.dedup_partitions,
            )?,
        ),
        "pool" => ("pool", run_pipeline(&Pooled::new(tune.workers), ctx, theta, false)?),
        "hadoop" => {
            let backend = HadoopSim::new(
                JobConfig {
                    name: "exec".into(),
                    map_tasks: tune.tasks,
                    reduce_tasks: tune.tasks,
                    executor_threads: tune.workers,
                    fault_prob: tune.fault_prob,
                    seed: tune.seed,
                    use_dfs: tune.use_dfs,
                },
                Dfs::new(DfsConfig::default()),
            );
            ("hadoop", run_pipeline(&backend, ctx, theta, false)?)
        }
        "spark" => {
            let sc = SparkContext::new(tune.tasks.max(1), tune.workers);
            ("spark", run_pipeline(&SparkSim::new(&sc), ctx, theta, false)?)
        }
        "cluster" => {
            let backend = tune.cluster_backend()?;
            ("cluster", run_pipeline(&backend, ctx, theta, false)?)
        }
        other => anyhow::bail!(
            "unknown backend {other:?} (expected seq|pool|hadoop|spark|cluster)"
        ),
    };
    span.records_out(clusters.len() as u64);
    drop(span);
    Ok(PipelineRun { backend, clusters, wall_ms: timer.elapsed_ms() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::{diff_cluster_sets, sort_clusters};
    use crate::datasets::synthetic::{k1, k2};
    use crate::oac::{mine_online, Constraints};

    fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
        sort_clusters(&mut cs);
        cs
    }

    fn assert_same(a: &[Cluster], b: &[Cluster], label: &str) {
        if let Some(diff) = diff_cluster_sets(a, b) {
            panic!("{label}: {diff}");
        }
    }

    #[test]
    fn all_backends_match_online_on_k1() {
        let ctx = k1(6).inner;
        let reference = sorted(mine_online(&ctx, &Constraints::none()));
        let tune = ExecTuning { workers: 4, tasks: 4, ..ExecTuning::default() };
        for name in BACKENDS {
            let run = run_named(name, &ctx, 0.0, &tune).unwrap();
            assert_same(&run.clusters, &reference, name);
        }
    }

    #[test]
    fn all_backends_agree_under_theta() {
        let ctx = k1(5).inner;
        let theta = 0.9;
        let reference = sorted(mine_online(
            &ctx,
            &Constraints { min_density: theta, min_support: 0 },
        ));
        let tune = ExecTuning { workers: 2, tasks: 3, ..ExecTuning::default() };
        for name in BACKENDS {
            let run = run_named(name, &ctx, theta, &tune).unwrap();
            assert_same(&run.clusters, &reference, name);
        }
    }

    #[test]
    fn hadoop_combiner_and_faults_leave_output_unchanged() {
        let ctx = k2(4).inner;
        let clean = run_pipeline(&HadoopSim::with_defaults(), &ctx, 0.0, false).unwrap();
        let backend = HadoopSim::new(
            JobConfig {
                name: "faulty".into(),
                fault_prob: 1.0,
                use_dfs: false,
                ..JobConfig::default()
            },
            Dfs::new(DfsConfig::default()),
        );
        let noisy = run_pipeline(&backend, &ctx, 0.0, true).unwrap();
        assert_same(&clean, &noisy, "faulty+combiner");
        let stats = backend.take_stats();
        assert_eq!(stats.len(), 3, "three fused stage jobs");
    }

    #[test]
    fn unknown_backend_is_an_error() {
        let ctx = k2(2).inner;
        assert!(run_named("flink", &ctx, 0.0, &ExecTuning::default()).is_err());
        assert!(run_named(
            "cluster",
            &ctx,
            0.0,
            &ExecTuning { placement: "yarn".into(), ..ExecTuning::default() }
        )
        .is_err());
    }

    #[test]
    fn cluster_backend_matches_online_under_faults_and_stragglers() {
        let ctx = k2(4).inner;
        let reference = sorted(mine_online(&ctx, &Constraints::none()));
        for placement in ["rr", "locality", "least"] {
            let tune = ExecTuning {
                workers: 2,
                nodes: 3,
                straggler_prob: 0.5,
                fault_prob: 0.5,
                placement: placement.into(),
                cost_ms_per_record: Some(0.01),
                ..ExecTuning::default()
            };
            let run = run_named("cluster", &ctx, 0.0, &tune).unwrap();
            assert_same(&run.clusters, &reference, &format!("cluster/{placement}"));
        }
    }
}
