//! Incremental compaction: merge per-shard partial cumuli into the
//! globally-correct cluster index.
//!
//! A tuple routed to shard s contributes to N cumuli *inside s*; tuples
//! sharing a subrelation key but routed to different shards leave each
//! shard with a PARTIAL cumulus for that key. This stage is the
//! incremental analogue of the §4.1 first reduce: it unions partial
//! cumuli by `(dropped modality, subrelation)` key into one global
//! [`SetArena`], and records every generating tuple as N pointers into
//! that arena — the exact state a single global [`crate::oac::OnlineMiner`]
//! would have built, so deduplication can reuse the miner's dedup
//! verbatim ([`crate::oac::online::dedup_generated_parallel`], bit-equal
//! to the sequential `dedup_generated` oracle) and sharded output
//! provably equals `mine_online`.
//!
//! Deltas arrive map-side-combined (one `(key, values)` group per
//! touched key — [`super::shard::Shard::take_delta`]), so applying a
//! delta probes the global key dictionary once per DISTINCT key, not once
//! per tuple-position; generating tuples then resolve their N set ids
//! against a small delta-local view.

use crate::core::pattern::Cluster;
use crate::core::tuple::SubRelation;
use crate::oac::online::{dedup_degree, dedup_generated_parallel, Generated};
use crate::oac::post::Constraints;
use crate::oac::primes::{SetArena, SetId, SetIds};
use crate::util::hash::FxHashMap;

use super::shard::{Shard, ShardDelta};

/// The global, incrementally-maintained cluster index.
#[derive(Debug)]
pub struct Compactor {
    /// Global cumulus dictionary: subrelation key → arena set id. The
    /// dropped-position tag inside [`SubRelation`] keeps e.g. (a,b) with
    /// modality 0 dropped distinct from (a,b) with modality 1 dropped.
    keys: FxHashMap<SubRelation, SetId>,
    arena: SetArena,
    /// Every generating tuple seen, as N global set pointers (the same
    /// shape `OnlineMiner` keeps).
    generated: Vec<Generated>,
    /// Last epoch merged from each shard.
    epochs: Vec<u64>,
    /// Materialised cluster cache, invalidated by `apply`.
    cache: Option<Vec<Cluster>>,
    /// Constraints the cache was built under: (min_density, min_support).
    cached_for: Option<(f64, usize)>,
}

impl Compactor {
    /// Empty global index expecting deltas from `n_shards` shards.
    pub fn new(n_shards: usize) -> Self {
        Self {
            keys: FxHashMap::default(),
            arena: SetArena::default(),
            generated: Vec::new(),
            epochs: vec![0; n_shards.max(1)],
            cache: None,
            cached_for: None,
        }
    }

    /// Merge one shard delta into the global index.
    pub fn apply(&mut self, delta: &ShardDelta) {
        self.epochs[delta.shard] = delta.epoch;
        if delta.is_empty() {
            return;
        }
        // delta-local key view: the only keys this delta's tuples can
        // reference are the ones in its own appends
        let mut local: FxHashMap<SubRelation, SetId> = FxHashMap::default();
        local.reserve(delta.appends.len());
        for (sub, values) in &delta.appends {
            let id = match self.keys.get(sub) {
                Some(&id) => id,
                None => {
                    let id = self.arena.alloc();
                    self.keys.insert(*sub, id);
                    id
                }
            };
            for &v in values {
                self.arena.push(id, v);
            }
            local.insert(*sub, id);
        }
        for &t in &delta.tuples {
            let mut set_ids = SetIds::default();
            for k in 0..t.arity() {
                set_ids.push(local[&t.subrelation(k)]);
            }
            self.generated.push(Generated { set_ids, tuple: t });
        }
        self.cache = None;
    }

    /// Pull + apply the pending delta of every shard.
    pub fn pull(&mut self, shards: &mut [Shard]) {
        for shard in shards {
            let delta = shard.take_delta();
            self.apply(&delta);
        }
    }

    /// The compacted cluster index under `constraints` — rebuilt lazily
    /// via the same dedup the online miner uses
    /// ([`dedup_generated_parallel`], auto-sized by [`dedup_degree`]).
    pub fn clusters(&mut self, constraints: &Constraints) -> &[Cluster] {
        let key = (constraints.min_density, constraints.min_support);
        let fresh = self.cache.is_some() && self.cached_for == Some(key);
        if !fresh {
            // seal the arena: cumuli untouched since the previous
            // compaction keep their cached sorted view, so an
            // incremental re-compaction only re-sorts the sets the new
            // deltas actually appended to (§Perf watermark)
            self.arena.ensure_sorted_all();
            let (workers, partitions) = dedup_degree(self.generated.len());
            self.cache = Some(dedup_generated_parallel(
                &self.arena,
                &self.generated,
                constraints,
                workers,
                partitions,
            ));
            self.cached_for = Some(key);
        }
        self.cache.as_deref().expect("cache just built")
    }

    /// Cluster count if the cache is warm (None after un-compacted
    /// ingests).
    pub fn cached_len(&self) -> Option<usize> {
        self.cache.as_ref().map(Vec::len)
    }

    /// Materialise the compacted index under `constraints` and package
    /// it as an immutable epoch snapshot ready to publish to a
    /// [`crate::serve::SnapshotCell`]. The clusters are copied out of
    /// the compactor's lazy cache — the snapshot must own them so
    /// readers survive later compactions — and `merged_tuples` records
    /// the generating-tuple watermark at this epoch (the torn-read
    /// canary the equivalence suite checks).
    pub fn snapshot(
        &mut self,
        constraints: &Constraints,
        epoch: u64,
    ) -> std::sync::Arc<crate::serve::EpochSnapshot> {
        let merged = self.generated.len();
        let clusters = self.clusters(constraints).to_vec();
        crate::serve::EpochSnapshot::build(epoch, clusters, merged)
    }

    /// Distinct subrelation keys across all modalities (global cumuli).
    pub fn distinct_keys(&self) -> usize {
        self.keys.len()
    }

    /// Generating tuples merged so far.
    pub fn generated_len(&self) -> usize {
        self.generated.len()
    }

    /// Last merged epoch per shard.
    pub fn epochs(&self) -> &[u64] {
        &self.epochs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::tuple::NTuple;
    use crate::oac::mine_online;

    fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
        cs.sort_by(|a, b| a.components.cmp(&b.components));
        cs
    }

    /// Shard the table-1 context two ways and check the compacted index
    /// equals the single-miner result.
    #[test]
    fn cross_shard_cumuli_union() {
        let data = [
            NTuple::triple(0, 0, 0),
            NTuple::triple(0, 1, 0),
            NTuple::triple(0, 0, 1),
            NTuple::triple(0, 1, 1),
        ];
        // adversarial partition: alternate tuples across two shards, so
        // every cumulus is split
        let mut s0 = Shard::new(0, 3);
        let mut s1 = Shard::new(1, 3);
        s0.ingest(&[data[0], data[2]]);
        s1.ingest(&[data[1], data[3]]);
        let mut comp = Compactor::new(2);
        comp.pull(&mut [s0, s1]);
        let out = comp.clusters(&Constraints::none());
        // all four triples generate the SAME global tricluster
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].components[1], vec![0, 1]);
        assert_eq!(out[0].components[2], vec![0, 1]);
        assert_eq!(out[0].support, 4);
    }

    #[test]
    fn incremental_pulls_match_one_shot_mining() {
        let mut ctx = crate::core::context::PolyContext::new(3);
        let mut rng = crate::util::rng::Rng::new(42);
        for _ in 0..400 {
            let t = [
                rng.below(9) as u32,
                rng.below(9) as u32,
                rng.below(9) as u32,
            ];
            ctx.add_ids(&t);
        }
        let reference = sorted(mine_online(&ctx, &Constraints::none()));

        let mut shards = vec![Shard::new(0, 3), Shard::new(1, 3), Shard::new(2, 3)];
        let mut comp = Compactor::new(3);
        for chunk in ctx.tuples().chunks(37) {
            for t in chunk {
                let s = (crate::util::hash::fxhash(t) % 3) as usize;
                shards[s].ingest(std::slice::from_ref(t));
            }
            // compact mid-stream every chunk: must stay correct at every
            // epoch boundary, not just at the end
            comp.pull(&mut shards);
        }
        let got = sorted(comp.clusters(&Constraints::none()).to_vec());
        assert_eq!(got.len(), reference.len());
        for (a, b) in got.iter().zip(&reference) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
    }

    #[test]
    fn constraints_cache_invalidation() {
        let mut s = Shard::new(0, 3);
        s.ingest(&[NTuple::triple(0, 0, 0), NTuple::triple(1, 1, 1)]);
        let mut comp = Compactor::new(1);
        comp.pull(&mut [s]);
        let all = comp.clusters(&Constraints::none()).len();
        assert_eq!(all, 2);
        // tighter constraints must rebuild, not serve the stale cache
        let dense = comp
            .clusters(&Constraints { min_density: 0.0, min_support: 2 })
            .len();
        assert_eq!(dense, 0);
        assert_eq!(comp.cached_len(), Some(0));
    }
}
