//! IMDB movies × tags × genres triclustering — the paper's §5.1/§5.2
//! qualitative experiment: mine the Top-250-shaped context, show
//! paper-style patterns, and verify densities with both the exact and
//! the XLA/Pallas engines.
//!
//! Run: `cargo run --release --example imdb_tags`

use tricluster::core::context::TriContext;
use tricluster::core::io::format_cluster;
use tricluster::datasets::{imdb, ImdbParams};
use tricluster::density::{DensityEngine, ExactEngine, XlaEngine};
use tricluster::oac::{mine_online, Constraints};
use tricluster::util::stats::Timer;

fn main() -> anyhow::Result<()> {
    let ctx: TriContext = imdb(&ImdbParams::default());
    let (g, m, b) = ctx.sizes();
    println!(
        "IMDB-like context: {} movies × {} tags × {} genres, {} triples (density {:.5})\n",
        g, m, b, ctx.len(), ctx.inner.density()
    );

    let t = Timer::start();
    let clusters = mine_online(
        &ctx.inner,
        &Constraints { min_density: 0.0, min_support: 2 },
    );
    println!(
        "online OAC-prime: {} triclusters with ≥2 entities per modality in {:.0} ms\n",
        clusters.len(),
        t.elapsed_ms()
    );

    // the §5.2-style pattern dump: movies sharing tags across genres
    println!("sample patterns (movies / tags / genres):");
    for c in clusters
        .iter()
        .filter(|c| c.components[0].len() >= 2 && c.components[2].len() >= 2)
        .take(4)
    {
        println!("{}", format_cluster(&ctx.inner, c));
    }

    // density verification: exact vs the AOT Pallas kernel through PJRT
    let sample: Vec<_> = clusters.iter().take(64).cloned().collect();
    let exact = ExactEngine.densities(&ctx, &sample);
    if tricluster::runtime::artifacts_available() {
        let rt = tricluster::runtime::Runtime::load(
            &tricluster::runtime::default_artifact_dir(),
        )?;
        // tags dimension is ~900 wide → multi-tile execution
        let mut xla = XlaEngine::new(&rt, 900, sample.len())?;
        let t = Timer::start();
        let got = xla.densities(&ctx, &sample);
        let max_err = exact
            .iter()
            .zip(&got)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        println!(
            "\nXLA/Pallas density check on {} clusters: max |err| = {:.2e} ({:.0} ms)",
            sample.len(),
            max_err,
            t.elapsed_ms()
        );
        assert!(max_err < 1e-6);
    } else {
        println!("\n(artifacts not built — run `make artifacts` for the XLA check)");
    }
    println!(
        "exact ρ range: [{:.4}, {:.4}]",
        exact.iter().cloned().fold(f64::INFINITY, f64::min),
        exact.iter().cloned().fold(0.0, f64::max)
    );
    Ok(())
}
