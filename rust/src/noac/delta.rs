//! δ-operators over fiber indexes (paper §3.2).
//!
//! `(m̃, b̃)^δ = {g | (g, m̃, b̃) ∈ I ∧ |V(g, m̃, b̃) − V(g̃, m̃, b̃)| ≤ δ}`
//! and symmetrically for the other two modalities. The operator
//! pre-indexes the context's fibers once (`O(|I|)`), so each application
//! is a scan of one fiber — the same access pattern the Layer-1 Pallas
//! δ-kernel evaluates in bulk for slabs of fibers (see
//! python/compile/kernels/delta.py and density::XlaEngine).

use crate::core::context::ManyValuedTriContext;
use crate::core::tuple::NTuple;
use crate::oac::generic::TriOperator;
use crate::util::hash::FxHashMap;

/// Fiber indexes: for each pair of fixed modalities, the list of
/// (varying-entity, value) along the third.
pub struct DeltaOperator {
    delta: f64,
    /// (m, b) → [(g, V(g,m,b))]
    mb: FxHashMap<(u32, u32), Vec<(u32, f64)>>,
    /// (g, b) → [(m, V(g,m,b))]
    gb: FxHashMap<(u32, u32), Vec<(u32, f64)>>,
    /// (g, m) → [(b, V(g,m,b))]
    gm: FxHashMap<(u32, u32), Vec<(u32, f64)>>,
    /// triple → value (to find v₀ of the generating triple)
    values: FxHashMap<NTuple, f64>,
}

impl DeltaOperator {
    /// Index the context's fibers. `O(|I|)` time and memory.
    pub fn build(ctx: &ManyValuedTriContext, delta: f64) -> Self {
        assert!(delta >= 0.0, "δ must be non-negative");
        let mut mb: FxHashMap<(u32, u32), Vec<(u32, f64)>> = FxHashMap::default();
        let mut gb: FxHashMap<(u32, u32), Vec<(u32, f64)>> = FxHashMap::default();
        let mut gm: FxHashMap<(u32, u32), Vec<(u32, f64)>> = FxHashMap::default();
        let mut values: FxHashMap<NTuple, f64> = FxHashMap::default();
        for t in ctx.triples() {
            let (g, m, b) = (t.get(0), t.get(1), t.get(2));
            let v = ctx.value(g, m, b).expect("valued triple");
            mb.entry((m, b)).or_default().push((g, v));
            gb.entry((g, b)).or_default().push((m, v));
            gm.entry((g, m)).or_default().push((b, v));
            values.insert(*t, v);
        }
        Self { delta, mb, gb, gm, values }
    }

    #[inline]
    fn v0(&self, t: &NTuple) -> f64 {
        *self.values.get(t).expect("generating triple must be in I")
    }

    #[inline]
    fn band(&self, fiber: &[(u32, f64)], v0: f64) -> Vec<u32> {
        fiber
            .iter()
            .filter(|(_, v)| (v - v0).abs() <= self.delta)
            .map(|(e, _)| *e)
            .collect()
    }
}

impl TriOperator for DeltaOperator {
    fn extent(&self, t: &NTuple) -> Vec<u32> {
        let fiber = &self.mb[&(t.get(1), t.get(2))];
        self.band(fiber, self.v0(t))
    }

    fn intent(&self, t: &NTuple) -> Vec<u32> {
        let fiber = &self.gb[&(t.get(0), t.get(2))];
        self.band(fiber, self.v0(t))
    }

    fn modus(&self, t: &NTuple) -> Vec<u32> {
        let fiber = &self.gm[&(t.get(0), t.get(1))];
        self.band(fiber, self.v0(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ManyValuedTriContext {
        let mut c = ManyValuedTriContext::new();
        c.add(0, 0, 0, 100.0);
        c.add(1, 0, 0, 150.0);
        c.add(2, 0, 0, 300.0);
        c.add(0, 1, 0, 90.0);
        c.add(0, 0, 1, 101.0);
        c
    }

    #[test]
    fn extent_band() {
        let op = DeltaOperator::build(&ctx(), 60.0);
        let t = NTuple::triple(0, 0, 0); // v0 = 100
        // fiber (m=0,b=0): g=0@100, g=1@150, g=2@300 → band keeps 0,1
        assert_eq!(op.extent(&t), vec![0, 1]);
        // from g=2's perspective (v0=300) only itself is within 60
        assert_eq!(op.extent(&NTuple::triple(2, 0, 0)), vec![2]);
    }

    #[test]
    fn intent_and_modus_bands() {
        let op = DeltaOperator::build(&ctx(), 15.0);
        let t = NTuple::triple(0, 0, 0);
        // fiber (g=0,b=0): m=0@100, m=1@90 → both within 15
        assert_eq!(op.intent(&t), vec![0, 1]);
        // fiber (g=0,m=0): b=0@100, b=1@101 → both
        assert_eq!(op.modus(&t), vec![0, 1]);
    }

    #[test]
    fn delta_zero_keeps_exact_equal_values_only() {
        let op = DeltaOperator::build(&ctx(), 0.0);
        let t = NTuple::triple(0, 0, 0);
        assert_eq!(op.extent(&t), vec![0]);
        assert_eq!(op.modus(&t), vec![0]);
    }

    #[test]
    fn generating_triple_always_in_its_own_sets() {
        let c = ctx();
        let op = DeltaOperator::build(&c, 0.0);
        for t in c.triples() {
            assert!(op.extent(t).contains(&t.get(0)), "{t:?}");
            assert!(op.intent(t).contains(&t.get(1)), "{t:?}");
            assert!(op.modus(t).contains(&t.get(2)), "{t:?}");
        }
    }
}
