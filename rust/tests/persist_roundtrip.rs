//! Property + integration suite for the binary segment log
//! (`tricluster::persist`): write→restore equivalence across random
//! shapes, corruption safety (typed errors, never a panic), torn-tail
//! recovery, the JSON↔segment interconversion, and the spill-budgeted
//! ingest path end to end.

mod common;

use tricluster::oac::{mine_online, Constraints};
use tricluster::persist::{SegmentError, SegmentLog};
use tricluster::serve::{snapshot, ServeConfig, SnapshotFormat, TriclusterService};
use tricluster::util::proptest_lite::assert_prop;

use common::{assert_same, distinct_ctx, random_ctx, sorted};

/// Fresh scratch directory under the OS temp root; wiped first so a
/// crashed previous run cannot leak segments into this one.
fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tricluster_persist_rt_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn service(arity: usize, shards: usize, cons: &Constraints) -> TriclusterService {
    let cfg = ServeConfig::builder()
        .arity(arity)
        .shards(shards)
        .constraints(cons.clone())
        .build()
        .expect("valid config");
    TriclusterService::new(cfg)
}

/// The tentpole property: for ANY random context shape, θ, and shard
/// count, a segment write followed by a page-adoption restore yields a
/// bit-equal cluster index — and the restored service keeps ingesting
/// exactly like the live one (restore is a serving point, not a grave).
#[test]
fn random_write_restore_is_bit_equal_and_keeps_serving() {
    let case = std::cell::Cell::new(0u32);
    assert_prop(24, |g| {
        let dir = scratch(&format!("prop_{}", case.get()));
        case.set(case.get() + 1);
        let arity = 2 + g.usize_below(3); // 2..=4
        let universe = 3 + g.u32_below(6);
        let n = 20 + g.usize_below(g.size * 8 + 1);
        let cons = Constraints {
            min_density: if g.bool(0.5) { 0.0 } else { g.f64() },
            min_support: g.usize_below(3),
        };
        let shards = 1 + g.usize_below(4);
        let ctx = random_ctx(g, arity, universe, n);
        let extra = random_ctx(g, arity, universe, n / 2);

        let mut live = service(arity, shards, &cons);
        for chunk in ctx.tuples().chunks(17) {
            live.ingest(chunk);
        }
        snapshot::save_segments(&mut live, &dir).map_err(|e| e.to_string())?;
        let mut restored =
            snapshot::load_segments(&dir).map_err(|e| e.to_string())?;
        assert_same(
            &sorted(live.clusters().to_vec()),
            &sorted(restored.clusters().to_vec()),
            "restored index",
        )?;

        // continued ingest: both sides absorb the same extra stream and
        // must stay identical — adoption reproduced the miner state, not
        // just the materialised index
        live.ingest(extra.tuples());
        live.compact();
        restored.ingest(extra.tuples());
        restored.compact();
        assert_same(
            &sorted(live.clusters().to_vec()),
            &sorted(restored.clusters().to_vec()),
            "post-restore ingest",
        )?;
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// Corruption safety: flipping ANY byte of a segment surfaces a typed
/// [`SegmentError`] from replay — never a panic, never a silently
/// adopted wrong page.
#[test]
fn every_flipped_byte_is_a_typed_error_never_a_panic() {
    let dir = scratch("flip");
    let ctx = distinct_ctx(11, 120, 8);
    let mut svc = service(3, 2, &Constraints::none());
    svc.ingest(ctx.tuples());
    snapshot::save_segments(&mut svc, &dir).unwrap();
    let path = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "tseg"))
        .expect("one segment written");
    let clean = std::fs::read(&path).unwrap();
    for i in (0..clean.len()).step_by(7) {
        let mut bytes = clean.clone();
        bytes[i] ^= 0x41;
        std::fs::write(&path, &bytes).unwrap();
        match SegmentLog::replay(&dir) {
            Err(
                SegmentError::Corrupt { .. }
                | SegmentError::BadMagic
                | SegmentError::BadVersion(_),
            ) => {}
            Err(other) => panic!("byte {i}: unexpected error class {other}"),
            Ok(_) => panic!("byte {i}: corruption went undetected"),
        }
    }
    // the pristine bytes still replay — the loop's failures were real
    std::fs::write(&path, &clean).unwrap();
    assert!(SegmentLog::replay(&dir).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Torn-tail recovery: truncating the FINAL segment mid-write drops
/// exactly that segment; the retained prefix restores to the state the
/// earlier serving point captured — verified against `mine_online` over
/// the tuples that serving point held.
#[test]
fn truncated_tail_drops_only_the_torn_final_segment() {
    let dir = scratch("torn");
    let ctx = distinct_ctx(12, 300, 9);
    let (early, late) = ctx.tuples().split_at(200);
    let mut svc = service(3, 3, &Constraints::none());
    svc.ingest(early);
    snapshot::save_segments(&mut svc, &dir).unwrap(); // serving point 1
    svc.ingest(late);
    snapshot::save_segments(&mut svc, &dir).unwrap(); // serving point 2
    let mut segs: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "tseg"))
        .collect();
    segs.sort();
    assert_eq!(segs.len(), 2, "two serving points journalled");
    let last = segs.last().unwrap();
    let bytes = std::fs::read(last).unwrap();
    std::fs::write(last, &bytes[..bytes.len() / 2]).unwrap();

    let mut restored = snapshot::load_segments(&dir).unwrap();
    let mut expect = tricluster::core::context::PolyContext::new(3);
    for t in early {
        expect.add_ids(t.as_slice());
    }
    let reference = sorted(mine_online(&expect, &Constraints::none()));
    assert_same(
        &sorted(restored.clusters().to_vec()),
        &reference,
        "prefix serving point",
    )
    .unwrap();

    // a NON-final segment with the same damage is an error, not a skip:
    // dropping history out of the middle would corrupt everything after
    let first_bytes = std::fs::read(&segs[0]).unwrap();
    std::fs::write(&segs[0], &first_bytes[..first_bytes.len() / 2]).unwrap();
    assert!(snapshot::load_segments(&dir).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The debug fallback stays interconvertible with the binary arm:
/// JSON → segment → JSON reproduces the original document BYTE FOR BYTE
/// (same tuples, same order, same epochs, same config header).
#[test]
fn json_to_segment_to_json_is_bit_identical() {
    let dir = scratch("convert");
    std::fs::create_dir_all(&dir).unwrap();
    let json_a = dir.join("a.json");
    let json_b = dir.join("b.json");
    let seg_dir = dir.join("segments");
    let ctx = distinct_ctx(13, 400, 9);
    let cons = Constraints { min_density: 0.25, min_support: 2 };
    let mut svc = service(3, 3, &cons);
    for chunk in ctx.tuples().chunks(64) {
        svc.ingest(chunk);
    }
    svc.compact();
    snapshot::save(&mut svc, &json_a).unwrap();

    let mut via_json = snapshot::load(&json_a).unwrap();
    snapshot::save_segments(&mut via_json, &seg_dir).unwrap();
    let mut via_segments = snapshot::load_segments(&seg_dir).unwrap();
    snapshot::save(&mut via_segments, &json_b).unwrap();

    let a = std::fs::read(&json_a).unwrap();
    let b = std::fs::read(&json_b).unwrap();
    assert_eq!(a, b, "JSON → segment → JSON must be bit-identical");
    assert_same(
        &sorted(svc.clusters().to_vec()),
        &sorted(via_segments.clusters().to_vec()),
        "index through both arms",
    )
    .unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The config surface: `snapshot_format` drives `snapshot_to`, and a
/// restored service is format-agnostic (`restore_from` dispatches on
/// the path shape).
#[test]
fn snapshot_to_dispatches_on_the_configured_format() {
    let dir = scratch("dispatch");
    std::fs::create_dir_all(&dir).unwrap();
    let ctx = distinct_ctx(14, 150, 8);

    let seg_path = dir.join("seg");
    let mut seg_svc = service(3, 2, &Constraints::none());
    seg_svc.ingest(ctx.tuples());
    seg_svc.snapshot_to(&seg_path).unwrap();
    assert!(seg_path.is_dir(), "segment format writes a log directory");

    let json_path = dir.join("snap.json");
    let cfg = ServeConfig::builder()
        .arity(3)
        .shards(2)
        .snapshot_format(SnapshotFormat::Json)
        .build()
        .unwrap();
    let mut json_svc = TriclusterService::new(cfg);
    json_svc.ingest(ctx.tuples());
    json_svc.snapshot_to(&json_path).unwrap();
    assert!(json_path.is_file(), "json format writes a single document");

    let mut from_seg = TriclusterService::restore_from(&seg_path).unwrap();
    let mut from_json = TriclusterService::restore_from(&json_path).unwrap();
    assert_same(
        &sorted(from_seg.clusters().to_vec()),
        &sorted(from_json.clusters().to_vec()),
        "both formats restore the same index",
    )
    .unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Out-of-core config path, end to end: a service built with a resident
/// budget + spill directory must produce exactly the unbudgeted index.
/// (Binding budgets — where pages actually spill and reload — are
/// property-tested at page granularity in `oac::primes`; the CI trace
/// gate proves `oac.arena.spill > 0` on a real dataset.)
#[test]
fn spill_budgeted_service_matches_unbudgeted() {
    let dir = scratch("spill");
    let ctx = distinct_ctx(15, 2_000, 16);
    let cons = Constraints::none();

    let mut plain = service(3, 2, &cons);
    plain.ingest(ctx.tuples());
    plain.compact();

    let cfg = ServeConfig::builder()
        .arity(3)
        .shards(2)
        .segment_dir(&dir)
        .resident_mib(1)
        .build()
        .unwrap();
    let mut budgeted = TriclusterService::new(cfg);
    budgeted.ingest(ctx.tuples());
    budgeted.compact();

    assert_same(
        &sorted(plain.clusters().to_vec()),
        &sorted(budgeted.clusters().to_vec()),
        "spill tier must be invisible to results",
    )
    .unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
