//! Prime-set / cumulus dictionaries — the state of the online algorithm.
//!
//! Paper Alg. 1 keeps three hash dictionaries (PrimesOA, PrimesOC,
//! PrimesAC) mapping entity pairs to prime sets; triclusters hold
//! *pointers* into those dictionaries so a later triple updating a set is
//! visible to every tricluster sharing it. The N-ary generalisation
//! (§3.1) keys by `SubRelation` and the sets are cumuli.
//!
//! Here "pointer" = arena index (`SetId`); the arena owns the sets and
//! materialisation resolves ids → sorted contents once, at the end.

use crate::core::tuple::{NTuple, SubRelation, MAX_ARITY};
use crate::util::hash::FxHashMap;

/// Index of a prime set / cumulus in the arena.
pub type SetId = u32;

/// Arena of grow-only entity-id sets, addressed by `SetId`.
///
/// Appends may contain duplicates when the input stream replays tuples
/// (M/R task retries); `materialize` sorts + dedups, preserving set
/// semantics without paying a per-insert hash probe on the hot path.
#[derive(Debug, Default, Clone)]
pub struct SetArena {
    sets: Vec<Vec<u32>>,
}

impl SetArena {
    /// Allocate a fresh empty set, returning its id.
    pub fn alloc(&mut self) -> SetId {
        self.sets.push(Vec::new());
        (self.sets.len() - 1) as SetId
    }

    #[inline]
    /// Append `value` to set `id` (duplicates dedup on materialise).
    pub fn push(&mut self, id: SetId, value: u32) {
        self.sets[id as usize].push(value);
    }

    /// Number of allocated sets.
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    /// True before the first allocation.
    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Raw (possibly duplicated, unsorted) contents.
    pub fn raw(&self, id: SetId) -> &[u32] {
        &self.sets[id as usize]
    }

    /// Sorted, deduplicated contents.
    pub fn materialize(&self, id: SetId) -> Vec<u32> {
        let mut v = Vec::new();
        self.materialize_into(id, &mut v);
        v
    }

    /// [`Self::materialize`] into a caller-owned buffer (clear + fill +
    /// sort + dedup). Hot per-triple loops (the online dedup, the basic
    /// algorithm) reuse one buffer across lookups instead of allocating a
    /// fresh `Vec` per set.
    pub fn materialize_into(&self, id: SetId, out: &mut Vec<u32>) {
        out.clear();
        out.extend_from_slice(&self.sets[id as usize]);
        out.sort_unstable();
        out.dedup();
    }
}

/// Pack up to 4 entity ids into a `u128` key, 32 bits each, low-to-high.
/// The ONE packing rule shared by the tuple-side fast path ([`pack_key`])
/// and the subrelation-side lookup ([`PrimeStore::get`]).
#[inline]
fn pack_elems(elems: &[u32]) -> u128 {
    debug_assert!(elems.len() <= 4, "packed keys hold ≤ 4 elements");
    let mut key: u128 = 0;
    let mut shift = 0;
    for &e in elems {
        key |= (e as u128) << shift;
        shift += 32;
    }
    key
}

/// Packed key of the subrelation of `t` with position `k` dropped —
/// valid for original arity ≤ 5 (4 × 32-bit elements); the dict index
/// already encodes the dropped position, so only the elements matter.
#[inline]
fn pack_key(t: &NTuple, k: usize) -> u128 {
    let mut buf = [0u32; MAX_ARITY];
    let mut j = 0;
    for (i, &e) in t.as_slice().iter().enumerate() {
        if i != k {
            buf[j] = e;
            j += 1;
        }
    }
    pack_elems(&buf[..j])
}

/// The cumulus dictionaries for an N-ary context: one map per modality,
/// keyed by the subrelation with that modality dropped.
///
/// §Perf: for arity ≤ 5 the subrelation key is packed into a `u128`
/// (one FxHash word-mix instead of hashing a 26-byte struct); wider
/// relations fall back to `SubRelation` keys.
#[derive(Debug)]
pub struct PrimeStore {
    arity: usize,
    /// fast path (arity ≤ 5): dicts[k]: packed subrelation → set id
    packed: Vec<FxHashMap<u128, SetId>>,
    /// general path: dicts[k]: subrelation → set id
    general: Vec<FxHashMap<SubRelation, SetId>>,
    /// The arena holding every prime set's contents.
    pub arena: SetArena,
}

impl PrimeStore {
    /// Empty store over `arity` modalities.
    pub fn new(arity: usize) -> Self {
        let fast = arity <= 5;
        Self {
            arity,
            packed: if fast {
                (0..arity).map(|_| FxHashMap::default()).collect()
            } else {
                Vec::new()
            },
            general: if fast {
                Vec::new()
            } else {
                (0..arity).map(|_| FxHashMap::default()).collect()
            },
            arena: SetArena::default(),
        }
    }

    /// Number of modalities.
    pub fn arity(&self) -> usize {
        self.arity
    }

    /// Process one tuple (Alg. 1 lines 2–4 generalised): for each
    /// modality k, append `e_k` to the cumulus of the k-dropped
    /// subrelation. Returns the N set ids — the "pointers" stored in the
    /// generated cluster.
    pub fn add(&mut self, t: &NTuple) -> Vec<SetId> {
        debug_assert_eq!(t.arity(), self.arity);
        let mut ids = Vec::with_capacity(self.arity);
        if !self.packed.is_empty() {
            for k in 0..self.arity {
                let key = pack_key(t, k);
                let id = match self.packed[k].get(&key) {
                    Some(&id) => id,
                    None => {
                        let id = self.arena.alloc();
                        self.packed[k].insert(key, id);
                        id
                    }
                };
                self.arena.push(id, t.get(k));
                ids.push(id);
            }
        } else {
            for k in 0..self.arity {
                let sub = t.subrelation(k);
                let id = match self.general[k].get(&sub) {
                    Some(&id) => id,
                    None => {
                        let id = self.arena.alloc();
                        self.general[k].insert(sub, id);
                        id
                    }
                };
                self.arena.push(id, t.get(k));
                ids.push(id);
            }
        }
        ids
    }

    /// Look up the cumulus id for a subrelation (None if never touched).
    pub fn get(&self, sub: &SubRelation) -> Option<SetId> {
        let k = sub.dropped();
        if !self.packed.is_empty() {
            self.packed[k].get(&pack_elems(sub.as_slice())).copied()
        } else {
            self.general[k].get(sub).copied()
        }
    }

    /// Number of distinct subrelation keys across all modalities.
    pub fn total_keys(&self) -> usize {
        if !self.packed.is_empty() {
            self.packed.iter().map(FxHashMap::len).sum()
        } else {
            self.general.iter().map(FxHashMap::len).sum()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_sets_accumulate() {
        // Table 1: (u2,i1,l1),(u2,i2,l1),(u2,i1,l2),(u2,i2,l2)
        let mut ps = PrimeStore::new(3);
        let t = |g, m, b| NTuple::triple(g, m, b);
        let ids1 = ps.add(&t(0, 0, 0));
        let _ = ps.add(&t(0, 1, 0));
        let _ = ps.add(&t(0, 0, 1));
        let _ = ps.add(&t(0, 1, 1));
        // the modus set PrimesOA[u2, i1] should now be {l1, l2}
        assert_eq!(ps.arena.materialize(ids1[2]), vec![0, 1]);
        // the intent set PrimesOC[u2, l1] is {i1, i2}
        assert_eq!(ps.arena.materialize(ids1[1]), vec![0, 1]);
        // the extent set PrimesAC[i1, l1] is {u2}
        assert_eq!(ps.arena.materialize(ids1[0]), vec![0]);
    }

    #[test]
    fn duplicate_tuples_do_not_change_materialized_sets() {
        let mut ps = PrimeStore::new(3);
        let t = NTuple::triple(1, 2, 3);
        let a = ps.add(&t);
        let b = ps.add(&t); // replayed (task retry)
        assert_eq!(a, b);
        assert_eq!(ps.arena.materialize(a[0]), vec![1]);
        assert_eq!(ps.arena.materialize(a[2]), vec![3]);
    }

    #[test]
    fn four_ary_cumuli() {
        let mut ps = PrimeStore::new(4);
        ps.add(&NTuple::new(&[0, 1, 2, 3]));
        let ids = ps.add(&NTuple::new(&[4, 1, 2, 3]));
        // cum(i, 0) over subrelation (1,2,3) = {0, 4}
        assert_eq!(ps.arena.materialize(ids[0]), vec![0, 4]);
        assert_eq!(ps.total_keys(), 1 + 2 + 2 + 2);
    }

    #[test]
    fn materialize_into_reuses_buffer() {
        let mut ps = PrimeStore::new(3);
        let ids = ps.add(&NTuple::triple(0, 0, 0));
        ps.add(&NTuple::triple(5, 0, 0));
        ps.add(&NTuple::triple(5, 0, 0)); // duplicate append
        let mut buf = vec![99, 98, 97]; // stale contents must be cleared
        ps.arena.materialize_into(ids[0], &mut buf);
        assert_eq!(buf, vec![0, 5]);
        assert_eq!(ps.arena.materialize(ids[0]), buf);
    }

    #[test]
    fn get_by_subrelation() {
        let mut ps = PrimeStore::new(3);
        let t = NTuple::triple(5, 6, 7);
        let ids = ps.add(&t);
        assert_eq!(ps.get(&t.subrelation(1)), Some(ids[1]));
        assert_eq!(ps.get(&NTuple::triple(9, 9, 9).subrelation(0)), None);
    }
}
