//! Summary statistics for the experiment harness and benches.
//!
//! The crate's single wall-clock primitive lives in [`crate::obs`]
//! (spans and benches share it); `Timer` is re-exported here for the
//! older call sites.

pub use crate::obs::Timer;

/// Summary of a sample of measurements (times in ms, counts, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Standard deviation (population).
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median.
    pub median: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Summarise a non-empty sample.
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (rank - lo as f64)
    }
}

/// Measure `f` with `warmup` discarded runs and `n` recorded runs,
/// returning per-run milliseconds. The mini-criterion used by `benches/`.
pub fn measure_ms<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..n)
        .map(|_| {
            let t = Timer::start();
            f();
            t.elapsed_ms()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - 1.5811388).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn measure_runs_n_times() {
        let mut count = 0;
        let ms = measure_ms(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(ms.len(), 5);
        assert!(ms.iter().all(|&m| m >= 0.0));
    }
}
