"""Layer-1 Pallas kernel: δ-operator band masks for many-valued contexts.

Paper §3.2: for a generating triple (g̃, m̃, b̃) with value v0 = V(g̃, m̃, b̃),
the δ-prime set along a fiber keeps the elements that are present in the
relation and whose value lies within δ of v0:

    mask[k, l] = present[k, l] · [ |values[k, l] - v0[k]| ≤ δ ]

Layer 3 gathers fibers (rows of the value cuboid along one modality) into
dense (K, L) slabs; this kernel evaluates the band test for a whole slab.
Pure VPU (elementwise) work — the point of keeping it in Pallas is that it
fuses into the same lowered module as the density contraction, and on real
TPU it expresses the HBM→VMEM streaming of fiber slabs via the grid.

δ is passed as a scalar *array* (shape f32[1]) rather than a static python
float so one AOT artifact serves every δ the NOAC sweep (Table 5) uses.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT slab geometry.
FIBER_K = 64   # fibers per slab
FIBER_L = 128  # fiber length (padded)
L_BLOCK = 128  # grid block along the fiber axis


def _delta_kernel(delta_ref, v_ref, p_ref, c_ref, o_ref):
    """One grid step: band mask for an (K, L_BLOCK) slab column.

    Refs:
      delta_ref: f32[1]           — δ threshold (grid-invariant).
      v_ref:     f32[K, L_BLOCK]  — fiber values.
      p_ref:     f32[K, L_BLOCK]  — 0/1 incidence along the fiber.
      c_ref:     f32[K]           — generating-triple values v0.
      o_ref:     f32[K, L_BLOCK]  — output 0/1 mask.
    """
    d = delta_ref[0]
    band = (jnp.abs(v_ref[...] - c_ref[...][:, None]) <= d)
    o_ref[...] = band.astype(jnp.float32) * p_ref[...]


@jax.jit
def delta_masks(delta, values, present, centers):
    """δ-band masks for a slab of gathered fibers (Pallas).

    Shapes: delta f32[1]; values/present f32[K,L]; centers f32[K].
    L must be a multiple of L_BLOCK. Returns f32[K,L].
    """
    k, l = values.shape
    if l % L_BLOCK != 0:
        raise ValueError(f"L={l} not a multiple of {L_BLOCK}")
    grid = (l // L_BLOCK,)
    return pl.pallas_call(
        _delta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((k, L_BLOCK), lambda i: (0, i)),
            pl.BlockSpec((k, L_BLOCK), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((k, L_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, l), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(delta, values, present, centers)
