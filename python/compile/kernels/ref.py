"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must agree with its oracle to float32 tolerance for all shapes/dtypes the
hypothesis sweep generates (see python/tests/).

The three numeric hot spots of the paper (Egurnov et al., "Triclustering in
Big Data Setting") that we lift to Layer 1:

* ``density_ref``      — batched tricluster density counts over a Boolean
                         cuboid tile: count_k = Σ_{g,m,b} T[g,m,b] X[k,g]
                         Y[k,m] Z[k,b] (§2, ρ(T) numerator).
* ``delta_ref``        — δ-operator band masks over gathered fibers
                         (§3.2 many-valued triclustering).
* ``mc_density_ref``   — Monte-Carlo density estimate from sampled
                         coordinates (§7, proposed extension).
"""

import jax.numpy as jnp


def density_ref(tensor, xmask, ymask, zmask):
    """Batched tricluster triple-counts over a Boolean tensor tile.

    Args:
      tensor: f32[G, M, B] 0/1 incidence cuboid tile.
      xmask:  f32[K, G] 0/1 extent  (object)    membership per cluster.
      ymask:  f32[K, M] 0/1 intent  (attribute) membership per cluster.
      zmask:  f32[K, B] 0/1 modus   (condition) membership per cluster.

    Returns:
      f32[K] — number of incidence triples inside each cluster's cuboid
      restricted to this tile. The caller sums tile counts and divides by
      |X||Y||Z| (host-side) to obtain the paper's density ρ.
    """
    return jnp.einsum("gmb,kg,km,kb->k", tensor, xmask, ymask, zmask)


def volumes_ref(xmask, ymask, zmask):
    """Per-cluster cuboid volumes |X_k| * |Y_k| * |Z_k| (f32[K])."""
    return xmask.sum(axis=1) * ymask.sum(axis=1) * zmask.sum(axis=1)


def delta_ref(values, present, centers, delta):
    """δ-operator band mask over gathered fibers.

    For the generating triple with value ``centers[k]``, an element of the
    fiber belongs to the δ-prime set iff it is present in the relation and
    its value lies within δ of the centre (paper §3.2).

    Args:
      values:  f32[K, L] fiber values V(·) (garbage where absent).
      present: f32[K, L] 0/1 incidence along the fiber.
      centers: f32[K]    V(g̃, m̃, b̃) of the generating triple.
      delta:   python float ≥ 0 (static).

    Returns:
      f32[K, L] 0/1 mask.
    """
    band = (jnp.abs(values - centers[:, None]) <= delta).astype(jnp.float32)
    return band * present


def mc_density_ref(tensor, coords):
    """Monte-Carlo density estimate: mean of T at sampled in-cluster coords.

    Args:
      tensor: f32[G, M, B] incidence tile.
      coords: i32[S, 3] sampled (g, m, b) coordinates, host-sampled
              uniformly from the cluster cuboid X×Y×Z.

    Returns:
      f32[] — fraction of sampled cells present in I (unbiased ρ̂).
    """
    vals = tensor[coords[:, 0], coords[:, 1], coords[:, 2]]
    return jnp.mean(vals)
