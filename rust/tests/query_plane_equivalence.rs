//! The concurrent query plane's load-bearing invariants, tested end to
//! end:
//!
//! 1. **Backend equivalence** — every `QueryBackend` impl (local,
//!    cache on/off, simulated-remote) answers identically to a plain
//!    `QueryEngine` over `oac::mine_online`'s clusters at the same
//!    epoch, for random contexts and service schedules.
//! 2. **Replica staleness** — under seeded churn and arbitrary
//!    compaction schedules, a replica never trails the primary by more
//!    than the retained window, and what it serves at epoch `e` is
//!    exactly the epoch-`e` index (the prefix of the stream merged by
//!    compaction `e`).
//! 3. **Cache transparency** — a cache hit is bit-equal to the miss
//!    that populated it (including `f64` payloads), and a cache-off
//!    backend answers the same.
//! 4. **No torn reads** — snapshots loaded concurrently with ingest
//!    and compaction are internally consistent (epoch, clusters,
//!    membership index, and merged-tuples watermark from ONE
//!    publication) and epochs observed per reader are monotone.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use common::{random_ctx, sorted};
use tricluster::core::context::PolyContext;
use tricluster::core::pattern::Cluster;
use tricluster::exec::ChurnConfig;
use tricluster::oac::{mine_online, Constraints};
use tricluster::serve::{
    EpochSnapshot, QueryBackend, QueryEngine, ServeConfig, ServeSim, TriclusterService,
};
use tricluster::util::proptest_lite::{assert_prop, Gen};

/// Resolve membership ids against `snap` and sort by components, so two
/// indexes over the same cluster SET compare equal regardless of their
/// internal cluster order (ids are index-order-dependent; clusters are
/// not).
fn resolved(snap: &EpochSnapshot, ids: &[u32]) -> Vec<Cluster> {
    sorted(ids.iter().map(|&i| snap.resolve(i).clone()).collect())
}

/// Compare a backend's four answers against the reference engine.
/// Counts and extrema are exact; `mean_density` gets a summation-order
/// tolerance (the two indexes may hold equal clusters in different
/// order).
fn assert_backend_matches(
    backend: &mut dyn QueryBackend,
    reference: &QueryEngine,
    ks: &[usize],
    probes: &[(usize, u32)],
    label: &str,
) -> Result<(), String> {
    let snap = backend.snapshot();
    for &k in ks {
        let got = backend.top_k(k);
        let want: Vec<Cluster> =
            reference.top_k_by_density(k).into_iter().cloned().collect();
        if got != want {
            return Err(format!("{label}: top_k({k}) differs"));
        }
    }
    for &(m, e) in probes {
        let got = resolved(&snap, &backend.containing(m, e));
        let want = resolved(reference.snapshot(), reference.containing(m, e));
        if got != want {
            return Err(format!("{label}: containing({m}, {e}) differs"));
        }
        let gs = backend.entity_stats(m, e);
        let ws = reference.entity_stats(m, e);
        match (gs, ws) {
            (None, None) => {}
            (Some(gs), Some(ws)) => {
                if gs.clusters != ws.clusters
                    || gs.total_support != ws.total_support
                    || gs.max_component != ws.max_component
                    || gs.max_density.to_bits() != ws.max_density.to_bits()
                    || (gs.mean_density - ws.mean_density).abs() > 1e-9
                {
                    return Err(format!(
                        "{label}: entity_stats({m}, {e}) differs: {gs:?} vs {ws:?}"
                    ));
                }
            }
            (gs, ws) => {
                return Err(format!(
                    "{label}: entity_stats({m}, {e}) presence differs: \
                     {gs:?} vs {ws:?}"
                ))
            }
        }
    }
    let gs = backend.stats();
    let ws = reference.stats();
    if gs.clusters != ws.clusters
        || gs.total_support != ws.total_support
        || gs.max_component != ws.max_component
        || gs.max_density.to_bits() != ws.max_density.to_bits()
        || (gs.mean_density - ws.mean_density).abs() > 1e-9
    {
        return Err(format!("{label}: stats differs: {gs:?} vs {ws:?}"));
    }
    Ok(())
}

/// Random context + schedule: the service's local backends (cache on
/// and off) answer exactly like a `QueryEngine` over `mine_online` at
/// the same epoch.
#[test]
fn prop_local_backends_equal_engine_over_mine_online() {
    assert_prop(48, |g: &mut Gen| {
        let arity = 3 + g.usize_below(2);
        let universe = 2 + g.u32_below(8);
        let n = 1 + g.usize_below(250);
        let ctx = random_ctx(g, arity, universe, n);
        let constraints = if g.bool(0.5) {
            Constraints::none()
        } else {
            Constraints { min_density: g.f64(), min_support: g.usize_below(3) }
        };

        let mut svc = TriclusterService::new(
            ServeConfig::builder()
                .arity(arity)
                .shards(1 + g.usize_below(5))
                .constraints(constraints.clone())
                .build()
                .expect("generated config is valid"),
        );
        let batch = 1 + g.usize_below(64);
        for chunk in ctx.tuples().chunks(batch) {
            svc.ingest(chunk);
        }
        svc.compact();

        // the reference: a detached snapshot over mine_online's
        // clusters at the same epoch
        let epoch = svc.snapshot().epoch();
        let reference = QueryEngine::from_snapshot(EpochSnapshot::build(
            epoch,
            mine_online(&ctx, &constraints),
            ctx.len(),
        ));

        let ks = [1, 3, 1 + g.usize_below(20)];
        let probes: Vec<(usize, u32)> = (0..8)
            .map(|_| (g.usize_below(arity), g.u32_below(universe + 2)))
            .collect();
        for cache in [true, false] {
            let mut backend = tricluster::serve::LocalBackend::with_cache(
                svc.snapshot_cell(),
                cache,
            );
            if backend.epoch() != epoch {
                return Err(format!(
                    "local backend epoch {} != published {epoch}",
                    backend.epoch()
                ));
            }
            assert_backend_matches(
                &mut backend,
                &reference,
                &ks,
                &probes,
                &format!("local cache={cache} arity={arity} n={}", ctx.len()),
            )?;
            // run the probes again through the cache: hits must change
            // nothing
            assert_backend_matches(
                &mut backend,
                &reference,
                &ks,
                &probes,
                &format!("local(repeat) cache={cache}"),
            )?;
            let (hits, misses) = backend.cache_stats();
            if cache && hits == 0 {
                return Err("cache on but no hits on repeat pass".into());
            }
            if !cache && (hits, misses) != (0, 0) {
                return Err("cache off but counted traffic".into());
            }
        }
        Ok(())
    });
}

/// Random serve-on-cluster runs with replicas and churn: staleness
/// stays within the retained window at every compaction, and each
/// replica's answers equal `mine_online` over the stream prefix its
/// epoch corresponds to.
#[test]
fn prop_replica_staleness_bounded_and_answers_match_their_epoch() {
    assert_prop(24, |g: &mut Gen| {
        let universe = 2 + g.u32_below(8);
        let n = 50 + g.usize_below(300);
        let ctx = random_ctx(g, 3, universe, n);
        // the builder rejects retained == 0 and replicas > nodes (typed
        // ServeConfigError), so generate within the legal envelope; the
        // retained-0 extreme is covered by serve::cluster's unit test,
        // which constructs the config directly
        let retained = 1 + g.usize_below(3) as u64;
        let nodes = 1 + g.usize_below(4);
        let replicas = 1 + g.usize_below(nodes);
        let cfg = ServeConfig::builder()
            .arity(3)
            .shards(1 + g.usize_below(5))
            .nodes(nodes)
            .replicas(replicas)
            .retained(retained)
            .placement(["rr", "locality", "least"][g.usize_below(3)])
            .batch(8 + g.usize_below(48))
            .churn(if g.bool(0.5) {
                ChurnConfig { kill_prob: 0.3, restart_ms: 20.0 }
            } else {
                ChurnConfig::off()
            })
            .seed(g.rng.next_u64())
            .build_sim()
            .expect("generated config is valid");
        let batch = cfg.batch;
        let compact_every = 1 + g.usize_below(3);
        let mut sim = ServeSim::new(cfg).map_err(|e| e.to_string())?;
        let set = sim.replica_set().expect("replicas configured");

        // drive manually, recording the stream prefix each epoch merged
        let mut prefix_at_epoch = vec![0usize]; // epoch 0 = empty
        let mut ingested = 0usize;
        for (i, wave) in ctx.tuples().chunks(batch).enumerate() {
            sim.ingest(wave);
            ingested += wave.len();
            if (i + 1) % compact_every == 0 {
                sim.compact();
                prefix_at_epoch.push(ingested);
                let s = set.read().unwrap();
                if s.max_staleness() > retained {
                    return Err(format!(
                        "staleness {} > retained {retained}",
                        s.max_staleness()
                    ));
                }
            }
        }
        if ingested > *prefix_at_epoch.last().unwrap() {
            sim.compact();
            prefix_at_epoch.push(ingested);
        }

        // every replica serves exactly the index of its epoch's prefix
        for client in 0..nodes {
            let mut remote = sim.remote_backend(client).expect("replicas");
            let epoch = remote.epoch() as usize;
            if epoch + (retained as usize) < prefix_at_epoch.len() - 1 {
                return Err(format!(
                    "replica for client {client} at epoch {epoch}, primary at {}",
                    prefix_at_epoch.len() - 1
                ));
            }
            let mut prefix = PolyContext::new(3);
            for t in &ctx.tuples()[..prefix_at_epoch[epoch]] {
                prefix.add_ids(t.as_slice());
            }
            let reference = QueryEngine::from_snapshot(EpochSnapshot::build(
                remote.epoch(),
                mine_online(&prefix, &Constraints::none()),
                prefix.len(),
            ));
            let probes: Vec<(usize, u32)> =
                (0..6).map(|_| (g.usize_below(3), g.u32_below(universe))).collect();
            assert_backend_matches(
                &mut remote,
                &reference,
                &[1, 5],
                &probes,
                &format!("replica client={client} epoch={epoch}"),
            )?;
        }
        Ok(())
    });
}

/// A cache hit must be BIT-equal to the miss that populated it, and a
/// cache-off backend must produce the same bits.
#[test]
fn cache_hit_is_bit_equal_to_miss() {
    let ctx = tricluster::datasets::synthetic::k2(4).inner;
    let mut svc = TriclusterService::new(ServeConfig::new(3, 3));
    svc.ingest(ctx.tuples());
    svc.compact();
    let mut on = svc.backend();
    let mut off = tricluster::serve::LocalBackend::with_cache(svc.snapshot_cell(), false);
    for k in [1, 4, 100] {
        let miss = on.top_k(k);
        let hit = on.top_k(k);
        assert_eq!(miss, hit, "top_k({k}) hit differs from miss");
        assert_eq!(off.top_k(k), miss, "cache-off top_k({k}) differs");
    }
    for (m, e) in [(0, 0), (1, 3), (2, 99)] {
        let miss = on.containing(m, e);
        assert_eq!(on.containing(m, e), miss);
        assert_eq!(off.containing(m, e), miss);
        let s_miss = on.entity_stats(m, e);
        let s_hit = on.entity_stats(m, e);
        match (&s_miss, &s_hit) {
            (Some(a), Some(b)) => {
                assert_eq!(a.mean_density.to_bits(), b.mean_density.to_bits());
                assert_eq!(a.max_density.to_bits(), b.max_density.to_bits());
                assert_eq!(a.clusters, b.clusters);
                assert_eq!(a.total_support, b.total_support);
            }
            (None, None) => {}
            other => panic!("hit/miss presence differs: {other:?}"),
        }
        assert_eq!(off.entity_stats(m, e), s_miss);
    }
    let miss = on.stats();
    let hit = on.stats();
    assert_eq!(miss.mean_density.to_bits(), hit.mean_density.to_bits());
    assert_eq!(off.stats(), miss);
    let (hits, misses) = on.cache_stats();
    assert!(hits > 0 && misses > 0, "exercised both paths: {hits}/{misses}");
}

/// Readers loading snapshots concurrently with ingest + compaction
/// never observe a torn publication: every loaded snapshot satisfies
/// Σ support == merged-tuples watermark (both stamped at the same
/// publish), membership ids resolve in range, and epochs are monotone
/// per reader.
#[test]
fn concurrent_reads_see_consistent_epochs() {
    let ctx = tricluster::datasets::movielens(
        &tricluster::datasets::MovielensParams::with_tuples(4_000),
    );
    let mut svc = TriclusterService::new(ServeConfig::new(ctx.arity(), 4));
    let cell = svc.snapshot_cell();
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..3)
        .map(|_| {
            let (cell, stop) = (Arc::clone(&cell), Arc::clone(&stop));
            std::thread::spawn(move || {
                let mut last_epoch = 0u64;
                let mut loads = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let snap = cell.load();
                    // the torn-read canary: support mass and watermark
                    // are stamped by the SAME publication
                    assert_eq!(
                        snap.stats().total_support,
                        snap.merged_tuples(),
                        "epoch {}: support mass != merged watermark",
                        snap.epoch()
                    );
                    assert!(snap.epoch() >= last_epoch, "epoch went backwards");
                    last_epoch = snap.epoch();
                    // membership ids must resolve within this snapshot
                    for c in snap.clusters().iter().take(3) {
                        for (m, comp) in c.components.iter().enumerate() {
                            if let Some(&e) = comp.first() {
                                for &id in snap.containing(m, e) {
                                    assert!((id as usize) < snap.len());
                                }
                            }
                        }
                    }
                    loads += 1;
                }
                loads
            })
        })
        .collect();
    // writer: ingest + compact while the readers hammer the cell
    for chunk in ctx.tuples().chunks(257) {
        svc.ingest(chunk);
        svc.compact();
    }
    stop.store(true, Ordering::Relaxed);
    let mut total_loads = 0usize;
    for r in readers {
        total_loads += r.join().expect("reader observed a torn snapshot");
    }
    assert!(total_loads > 0, "readers ran");
    let final_epoch = svc.snapshot().epoch();
    assert_eq!(final_epoch, ctx.tuples().chunks(257).count() as u64);
    assert_eq!(svc.snapshot().merged_tuples(), ctx.len());
}
