//! Simulated HDFS: a replicated block store with byte accounting.
//!
//! The paper (§4.1) notes that HDFS's default replication factor 3 triples
//! the stored intermediate data; this module makes that cost observable.
//! Blocks live in memory with an optional disk-spill threshold so the
//! BibSonomy-scale intermediates (hundreds of MB once cumuli are
//! replicated per generating tuple) don't blow the heap.

use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::hash::FxHashMap;

/// Configuration of the simulated file system.
#[derive(Debug, Clone)]
pub struct DfsConfig {
    /// Replication factor (HDFS default: 3). Physical bytes =
    /// logical bytes × replication.
    pub replication: u32,
    /// Spill files larger than this to disk (bytes). `None` = never spill.
    pub spill_threshold: Option<usize>,
    /// Directory for spilled blocks.
    pub spill_dir: PathBuf,
}

impl Default for DfsConfig {
    fn default() -> Self {
        Self {
            replication: 3,
            spill_threshold: Some(64 << 20),
            spill_dir: std::env::temp_dir().join("tricluster-dfs"),
        }
    }
}

enum Block {
    Mem(Vec<u8>),
    Disk(PathBuf, usize),
}

/// The block store. Thread-safe: map/reduce tasks write concurrently.
pub struct Dfs {
    cfg: DfsConfig,
    blocks: Mutex<FxHashMap<String, Block>>,
    logical_bytes: AtomicU64,
    seq: AtomicU64,
}

impl Dfs {
    /// A DFS with the given spill/replication configuration.
    pub fn new(cfg: DfsConfig) -> Self {
        Self {
            cfg,
            blocks: Mutex::new(FxHashMap::default()),
            logical_bytes: AtomicU64::new(0),
            seq: AtomicU64::new(0),
        }
    }

    /// A DFS that never spills to disk (pure in-memory blocks).
    pub fn in_memory() -> Self {
        Self::new(DfsConfig { spill_threshold: None, ..DfsConfig::default() })
    }

    /// Store a block under `name`, honouring the spill threshold.
    pub fn put(&self, name: &str, data: Vec<u8>) -> Result<()> {
        self.logical_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
        let block = match self.cfg.spill_threshold {
            Some(thr) if data.len() > thr => {
                std::fs::create_dir_all(&self.cfg.spill_dir)?;
                let id = self.seq.fetch_add(1, Ordering::Relaxed);
                let path = self
                    .cfg
                    .spill_dir
                    .join(format!("blk-{id}-{}", sanitize(name)));
                let mut f = std::fs::File::create(&path)
                    .with_context(|| format!("spill {}", path.display()))?;
                f.write_all(&data)?;
                Block::Disk(path, data.len())
            }
            _ => Block::Mem(data),
        };
        self.blocks.lock().unwrap().insert(name.to_string(), block);
        Ok(())
    }

    /// Fetch a block's contents.
    pub fn get(&self, name: &str) -> Result<Vec<u8>> {
        let guard = self.blocks.lock().unwrap();
        match guard.get(name) {
            Some(Block::Mem(v)) => Ok(v.clone()),
            Some(Block::Disk(path, len)) => {
                let mut out = Vec::with_capacity(*len);
                std::fs::File::open(path)?.read_to_end(&mut out)?;
                Ok(out)
            }
            None => anyhow::bail!("dfs: no block named {name:?}"),
        }
    }

    /// Remove a block (and its on-disk spill file, if any).
    pub fn delete(&self, name: &str) {
        if let Some(Block::Disk(path, _)) =
            self.blocks.lock().unwrap().remove(name)
        {
            let _ = std::fs::remove_file(path);
        }
    }

    /// True when a block with this name exists.
    pub fn exists(&self, name: &str) -> bool {
        self.blocks.lock().unwrap().contains_key(name)
    }

    /// Logical bytes written over the store's lifetime.
    pub fn logical_bytes(&self) -> u64 {
        self.logical_bytes.load(Ordering::Relaxed)
    }

    /// Physical bytes after replication — the paper's 3× overhead.
    pub fn physical_bytes(&self) -> u64 {
        self.logical_bytes() * self.cfg.replication as u64
    }

    /// Configured replication factor.
    pub fn replication(&self) -> u32 {
        self.cfg.replication
    }
}

impl Drop for Dfs {
    fn drop(&mut self) {
        for (_, b) in self.blocks.lock().unwrap().drain() {
            if let Block::Disk(path, _) = b {
                let _ = std::fs::remove_file(path);
            }
        }
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let dfs = Dfs::in_memory();
        dfs.put("a/b", vec![1, 2, 3]).unwrap();
        assert_eq!(dfs.get("a/b").unwrap(), vec![1, 2, 3]);
        assert!(dfs.exists("a/b"));
        assert!(dfs.get("missing").is_err());
    }

    #[test]
    fn replication_accounting() {
        let dfs = Dfs::in_memory();
        dfs.put("x", vec![0u8; 1000]).unwrap();
        dfs.put("y", vec![0u8; 500]).unwrap();
        assert_eq!(dfs.logical_bytes(), 1500);
        assert_eq!(dfs.physical_bytes(), 4500); // ×3
    }

    #[test]
    fn spills_large_blocks_to_disk() {
        let dir = std::env::temp_dir().join("tricluster-dfs-test-spill");
        let dfs = Dfs::new(DfsConfig {
            replication: 3,
            spill_threshold: Some(10),
            spill_dir: dir.clone(),
        });
        let data: Vec<u8> = (0..100u8).collect();
        dfs.put("big block!", data.clone()).unwrap();
        assert_eq!(dfs.get("big block!").unwrap(), data);
        // the spill file exists on disk
        assert!(std::fs::read_dir(&dir).unwrap().count() >= 1);
        drop(dfs); // cleanup removes spill files
    }

    #[test]
    fn delete_removes() {
        let dfs = Dfs::in_memory();
        dfs.put("t", vec![9]).unwrap();
        dfs.delete("t");
        assert!(!dfs.exists("t"));
    }
}
