//! Exact density: the scalar hash-membership oracle and the row-table
//! kernels that replace it on the hot path.
//!
//! The scalar path probes the context's tuple hash set once per cuboid
//! cell — `O(volume)` probes per cluster, each a full tuple hash. The
//! flat bitset kernel ([`densities_bitset`]) instead builds per-(g, m)
//! `u64` rows over the third modality ([`BitRows`]) and reduces each
//! cluster to `popcount(row & modus_mask)` sums — 64 cells per word-AND,
//! no hashing, sequential row reads. When the flat table would exceed
//! its byte cap (dense, wide-id contexts), the engine drops to the
//! compressed row table ([`CompressedRows`]) — `O(|I|)` memory, same
//! word-AND counting per non-empty row — instead of regressing to the
//! scalar loop. All three count exactly, so they return bit-identical
//! densities (property-tested in `rust/tests/proptests.rs`); the scalar
//! path remains the reference oracle and still serves workloads too
//! small to amortise any build.
//!
//! The engine is stateful (§Perf round 2): the row table it builds is
//! cached and keyed by the context's mutation revision
//! ([`crate::core::context::PolyContext::revision`]), so repeated
//! density calls against an unchanged context — the serve loop's steady
//! state — skip the rebuild entirely.

use crate::core::context::TriContext;
use crate::core::pattern::Cluster;
use crate::density::compressed::CompressedRows;
use crate::density::tiling::{bit_mask, BitRows};
use crate::density::DensityEngine;

/// Byte cap on the flat bitset row table (|G|·|M|·⌈|B|/64⌉·8); above it
/// the engine switches to the compressed row table.
pub const BITSET_MAX_BYTES: usize = 64 << 20;

/// Minimum total cuboid cells below which a row-table build costs more
/// than the scalar probes it replaces.
const BITSET_MIN_CELLS: f64 = 4096.0;

/// Exact per-cluster density over the raw tuple set (the reference the
/// sampled and compiled engines are validated against). Dispatch ladder:
/// tiny workloads count scalar; otherwise the flat bitset table when it
/// fits the byte cap, else the compressed table — identical results on
/// every rung. The built table is cached across calls and invalidated by
/// the context's revision stamp.
#[derive(Default)]
pub struct ExactEngine {
    /// Flat-table byte cap override (None → [`BITSET_MAX_BYTES`]).
    max_bitset_bytes: Option<usize>,
    /// Row table of the last counted context, revision-stamped.
    cache: Option<RowCache>,
}

/// A built row table plus the context revision it reflects.
struct RowCache {
    revision: u64,
    rows: Rows,
}

/// Which rung of the ladder the cached table lives on.
enum Rows {
    Bit(BitRows),
    Compressed(CompressedRows),
}

impl ExactEngine {
    /// Engine with a custom flat-table byte cap — `ExactEngine::default()`
    /// uses [`BITSET_MAX_BYTES`]. A tiny cap forces the compressed rung
    /// (the `--bitset-cap` CLI knob and the CI trace check use this).
    pub fn with_bitset_cap(max_bytes: usize) -> Self {
        Self { max_bitset_bytes: Some(max_bytes), cache: None }
    }

    /// Revision stamp of the cached row table, if any (test hook for the
    /// reuse/invalidation contract).
    pub fn cached_revision(&self) -> Option<u64> {
        self.cache.as_ref().map(|c| c.revision)
    }

    fn cap(&self) -> usize {
        self.max_bitset_bytes.unwrap_or(BITSET_MAX_BYTES)
    }
}

/// The scalar reference: one hash membership probe per cuboid cell.
pub fn densities_scalar(ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64> {
    clusters
        .iter()
        .map(|c| {
            let vol = c.volume();
            if vol == 0.0 {
                return 0.0;
            }
            let mut hit = 0u64;
            for &g in &c.components[0] {
                for &m in &c.components[1] {
                    for &b in &c.components[2] {
                        if ctx.contains(g, m, b) {
                            hit += 1;
                        }
                    }
                }
            }
            hit as f64 / vol
        })
        .collect()
}

/// Count `clusters` against a built flat row table with word-AND +
/// popcount. Exact — equal to the scalar oracle bit for bit.
pub fn count_bitset(rows: &BitRows, clusters: &[Cluster]) -> Vec<f64> {
    let words = rows.words();
    let mut mask: Vec<u64> = Vec::new();
    clusters
        .iter()
        .map(|c| {
            let vol = c.volume();
            if vol == 0.0 {
                return 0.0;
            }
            bit_mask(&c.components[2], words, &mut mask);
            let mut hit = 0u64;
            for &g in &c.components[0] {
                for &m in &c.components[1] {
                    if let Some(row) = rows.row(g, m) {
                        for (w, &bits) in row.iter().enumerate() {
                            hit += (bits & mask[w]).count_ones() as u64;
                        }
                    }
                }
            }
            hit as f64 / vol
        })
        .collect()
}

/// The flat bitset kernel: build the per-(g, m) row table once, then
/// count every cluster. Returns `None` when the table would exceed
/// `max_bytes` (callers fall through to [`CompressedRows`] or
/// [`densities_scalar`]).
pub fn densities_bitset(
    ctx: &TriContext,
    clusters: &[Cluster],
    max_bytes: usize,
) -> Option<Vec<f64>> {
    Some(count_bitset(&BitRows::build(ctx, max_bytes)?, clusters))
}

impl DensityEngine for ExactEngine {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn densities(&mut self, ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64> {
        let cells: f64 = clusters.iter().map(Cluster::volume).sum();
        if cells < BITSET_MIN_CELLS {
            // too few cuboid cells to amortise any row-table build (and
            // not worth caching one either)
            crate::obs::counter("density.dispatch.scalar_small", 1);
            return densities_scalar(ctx, clusters);
        }
        let revision = ctx.revision();
        let hit = self.cache.as_ref().is_some_and(|c| c.revision == revision);
        if hit {
            crate::obs::counter("density.rows.cache_hit", 1);
        } else {
            let rows = match BitRows::build(ctx, self.cap()) {
                Some(bits) => Rows::Bit(bits),
                // flat table over the byte cap: compressed rows, not the
                // O(volume) scalar loop
                None => Rows::Compressed(CompressedRows::build(ctx)),
            };
            crate::obs::counter("density.rows.build", 1);
            self.cache = Some(RowCache { revision, rows });
        }
        let cache = self.cache.as_ref().expect("cache just ensured");
        match &cache.rows {
            Rows::Bit(rows) => {
                crate::obs::counter("density.dispatch.bitset", 1);
                count_bitset(rows, clusters)
            }
            Rows::Compressed(rows) => {
                crate::obs::counter("density.dispatch.compressed", 1);
                rows.densities(clusters)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;
    use crate::datasets::synthetic::{k1, k2};

    #[test]
    fn dense_block_is_one() {
        let ctx = k2(3);
        let mut e = ExactEngine::default();
        let c = tricluster(vec![0, 1, 2], vec![0, 1, 2], vec![0, 1, 2]);
        assert_eq!(e.densities(&ctx, &[c]), vec![1.0]);
    }

    #[test]
    fn cross_block_is_sparse() {
        let ctx = k2(3);
        let mut e = ExactEngine::default();
        // spanning two blocks: only the two diagonal blocks hit → 2·27 of
        // 6³ = 216 cells
        let c = tricluster(
            vec![0, 1, 2, 3, 4, 5],
            vec![0, 1, 2, 3, 4, 5],
            vec![0, 1, 2, 3, 4, 5],
        );
        let d = e.densities(&ctx, &[c])[0];
        assert!((d - 54.0 / 216.0).abs() < 1e-12);
    }

    #[test]
    fn bitset_matches_scalar_oracle() {
        use crate::oac::{mine_online, Constraints};
        for ctx in [k1(7), k2(5)] {
            let mut clusters = mine_online(&ctx.inner, &Constraints::none());
            // a cluster reaching past every extent: rows must treat the
            // missing (g, m) pairs and high b bits as empty, not panic
            clusters.push(tricluster(vec![0, 90], vec![1, 80], vec![0, 63, 200]));
            clusters.push(tricluster(vec![], vec![0], vec![0])); // zero volume
            let scalar = densities_scalar(&ctx, &clusters);
            let bits = densities_bitset(&ctx, &clusters, usize::MAX)
                .expect("small contexts always fit");
            assert_eq!(scalar, bits);
        }
    }

    #[test]
    fn byte_cap_routes_to_compressed_not_scalar() {
        let ctx = k1(16); // 16³ = 4096 cells/cluster ≥ BITSET_MIN_CELLS
        let c = tricluster(
            (0..16).collect(),
            (0..16).collect(),
            (0..16).collect(),
        );
        // the flat kernel refuses the 1-byte cap...
        assert!(densities_bitset(&ctx, std::slice::from_ref(&c), 1).is_none());
        // ...but the capped engine still answers, via compressed rows,
        // and exactly
        let mut capped = ExactEngine::with_bitset_cap(1);
        let got = capped.densities(&ctx, std::slice::from_ref(&c));
        assert_eq!(got, densities_scalar(&ctx, std::slice::from_ref(&c)));
        assert!(capped.cached_revision().is_some());
    }

    #[test]
    fn row_cache_reused_until_context_mutates() {
        let mut ctx = k1(16);
        let c = tricluster(
            (0..16).collect(),
            (0..16).collect(),
            (0..16).collect(),
        );
        let mut e = ExactEngine::default();
        let d1 = e.densities(&ctx, std::slice::from_ref(&c));
        let rev = e.cached_revision().expect("table cached");
        let d2 = e.densities(&ctx, std::slice::from_ref(&c));
        assert_eq!(d1, d2);
        assert_eq!(e.cached_revision(), Some(rev)); // reused, not rebuilt
        // mutation bumps the revision → next call rebuilds and stays exact
        ctx.add(0, 0, 0);
        let d3 = e.densities(&ctx, std::slice::from_ref(&c));
        assert_ne!(e.cached_revision(), Some(rev));
        assert_eq!(d3, densities_scalar(&ctx, std::slice::from_ref(&c)));
    }
}
