//! Walkthrough of the serving layer: stream a MovieLens-like rating feed
//! into a sharded `TriclusterService`, compact mid-stream, answer
//! queries, and survive a restart via snapshot/restore.
//!
//! Run: `cargo run --release --example streaming_service`

use tricluster::core::io::format_cluster;
use tricluster::datasets::{movielens, MovielensParams};
use tricluster::oac::{mine_online, Constraints};
use tricluster::serve::{ServeConfig, TriclusterService};

fn main() -> anyhow::Result<()> {
    // A 20k-tuple prefix of the deterministic MovieLens stream:
    // (user, movie, rating, month) with power-law user/movie skew.
    let ctx = movielens(&MovielensParams::with_tuples(20_000));
    println!(
        "stream: {} tuples, arity {} (users x movies x ratings x months)\n",
        ctx.len(),
        ctx.arity()
    );

    // --- ingest: batches hash-route to 4 shards, drains are automatic ---
    let mut svc = TriclusterService::new(ServeConfig::new(ctx.arity(), 4));
    for (i, chunk) in ctx.tuples().chunks(2_048).enumerate() {
        svc.ingest(chunk);
        // compact every 4 batches: the service stays queryable WHILE the
        // stream keeps arriving
        if (i + 1) % 4 == 0 {
            svc.compact();
            let s = svc.stats();
            println!(
                "after batch {:>2}: {:>6} tuples merged, {:>6} cumulus keys, epochs {:?}",
                i + 1,
                s.merged,
                s.distinct_keys,
                s.epochs
            );
        }
    }
    svc.compact();

    // --- query: top-k by density + membership lookup -------------------
    let q = svc.query();
    println!("\nindex holds {} clusters; densest 3:", q.len());
    for c in q.top_k_by_density(3) {
        println!(
            "  {}  (support {}, rho {:.3})",
            format_cluster(&ctx, c),
            c.support,
            c.support_density()
        );
    }
    let hot_user = 0; // zipf makes user0 the most active
    let hits = q.containing(0, hot_user);
    println!(
        "\nuser {:?} appears in {} clusters",
        ctx.interners[0].name(hot_user),
        hits.len()
    );

    // --- the invariant the whole layer rests on ------------------------
    let reference = mine_online(&ctx, &Constraints::none());
    assert_eq!(svc.clusters().len(), reference.len());
    println!(
        "\nsharded index == sequential mine_online: {} clusters both ways",
        reference.len()
    );

    // --- restart recovery ----------------------------------------------
    let path = std::env::temp_dir().join("streaming_service_snapshot.json");
    svc.snapshot_to(&path)?;
    let mut restored = TriclusterService::restore_from(&path)?;
    assert_eq!(restored.clusters().len(), reference.len());
    println!("snapshot -> restore verified at {}", path.display());
    std::fs::remove_file(&path).ok();
    Ok(())
}
