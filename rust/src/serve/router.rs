//! Hash routing + bounded in-flight batching for the serving layer.
//!
//! `submit` stages incoming [`NTuple`] batches; when the staged volume
//! crosses the `max_pending` high-water mark the router drains them as an
//! ASYNC WAVE PIPELINE on [`crate::util::pool`]: the staged stream is cut
//! into waves (at least [`WAVE_TUPLES`], scaled up with the worker count
//! so each route-split saturates the pool), and while wave `w` is mined (one
//! task per shard), wave `w+1`'s route-split (chunks hashed to per-shard
//! bins in parallel) runs concurrently on a scoped thread — the
//! route-split never sits on the serial path OR behind the miners.
//! Waves are mined strictly in order, so per-shard arrival order still
//! equals stream order. A submitter is blocked inside `submit` while its
//! drain runs — that is the backpressure contract: queues cannot grow
//! without bound.
//!
//! [`crate::serve::cluster::ServeSim`] models exactly this overlap in
//! simulated time (its `pipeline` flag), so the virtual serve-on-cluster
//! numbers and the real drain share one execution shape.
//!
//! Routing hashes the whole tuple, so replays of the same tuple always
//! land on the same shard, preserving the retry-idempotence the M/R
//! pipeline relies on, and per-shard arrival order equals stream order
//! (chunk splits are re-concatenated in index order).
//!
//! The route-split is the stage-1 `map → group_by_key` shape, so it runs
//! on the [`crate::exec::Pooled`] backend — the same substrate the
//! unified pipeline uses. Only the mining wave stays on the raw pool:
//! it mutates long-lived shards in place, which is outside the pure
//! data-flow contract of [`crate::exec::Backend`].

use crate::core::tuple::NTuple;
use crate::exec::{Backend, Pooled};
use crate::util::hash::fxhash;
use crate::util::pool;

use super::shard::Shard;

/// Tuples hashed per route-split task in a drain wave.
const SPLIT_CHUNK: usize = 4096;

/// MINIMUM tuples per pipeline wave: while one wave mines, the next one
/// routes. The actual wave size is `SPLIT_CHUNK × workers` when that is
/// larger, so a single wave's route-split always has enough chunk tasks
/// to saturate the pool.
pub const WAVE_TUPLES: usize = 4 * SPLIT_CHUNK;

/// Ingest counters, exposed through `TriclusterService::stats`.
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    /// `submit` calls.
    pub batches: usize,
    /// Tuples routed.
    pub tuples: usize,
    /// Drains (backpressure or explicit flush).
    pub drains: usize,
    /// Pipeline waves executed across all drains (> `drains` when a
    /// drain was large enough to overlap route-split with mining).
    pub waves: usize,
    /// High-water mark of a single shard's per-wave queue, in tuples.
    pub max_queue: usize,
}

/// The shard owner: stages, routes, and drains.
#[derive(Debug)]
pub struct Router {
    shards: Vec<Shard>,
    /// Staged (not yet routed) tuples, in arrival order.
    staged: Vec<NTuple>,
    max_pending: usize,
    /// Execution substrate for drain-wave data flow (route-split).
    backend: Pooled,
    stats: RouterStats,
}

impl Router {
    /// Router over `n_shards` fresh shards.
    ///
    /// Deprecated shim (positional-argument API): prefer
    /// [`Self::from_config`] with a [`crate::serve::ServeConfig`] built
    /// via [`crate::serve::ServeConfig::builder`] — see the
    /// ARCHITECTURE.md migration map.
    pub fn new(arity: usize, n_shards: usize, max_pending: usize, workers: usize) -> Self {
        let n = n_shards.max(1);
        Self {
            shards: (0..n).map(|i| Shard::new(i, arity)).collect(),
            staged: Vec::new(),
            max_pending: max_pending.max(1),
            backend: Pooled::new(workers),
            stats: RouterStats::default(),
        }
    }

    /// Router configured from a [`crate::serve::ServeConfig`] — the one
    /// construction path the service and its builder share.
    pub fn from_config(cfg: &crate::serve::ServeConfig) -> Self {
        let mut router = Self::new(cfg.arity, cfg.shards, cfg.max_pending, cfg.workers);
        if cfg.resident_mib > 0 {
            let pages =
                crate::oac::primes::resident_pages(cfg.resident_mib, router.num_shards());
            let spill_dir = cfg.segment_dir.as_ref().map(|d| d.join("spill"));
            for shard in &mut router.shards {
                shard.set_resident_budget(pages, spill_dir.clone());
            }
        }
        router
    }

    /// Shard count.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shards (read-only).
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// The shards (the compactor pulls deltas through this).
    pub fn shards_mut(&mut self) -> &mut [Shard] {
        &mut self.shards
    }

    /// Ingest counters.
    pub fn stats(&self) -> &RouterStats {
        &self.stats
    }

    /// Tuples staged but not yet mined.
    pub fn pending(&self) -> usize {
        self.staged.len()
    }

    /// Deterministic shard assignment for a tuple (the same function the
    /// drain wave's parallel split applies).
    #[inline]
    pub fn route(&self, t: &NTuple) -> usize {
        (fxhash(t) % self.shards.len() as u64) as usize
    }

    /// Stage a batch; drains automatically when the high-water mark is
    /// reached (bounded in-flight ingestion).
    pub fn submit(&mut self, batch: &[NTuple]) {
        self.stats.batches += 1;
        self.stats.tuples += batch.len();
        self.staged.extend_from_slice(batch);
        if self.staged.len() >= self.max_pending {
            self.drain();
        }
    }

    /// Synchronously mine every staged tuple as a pipeline of waves:
    /// wave `w+1`'s parallel route-split runs on a scoped thread WHILE
    /// wave `w` is mined (one task per shard), so routing and mining
    /// overlap; waves complete in order, preserving per-shard stream
    /// order.
    pub fn drain(&mut self) {
        if self.staged.is_empty() {
            return;
        }
        let mut dspan = crate::span!("serve.drain");
        dspan.records_in(self.staged.len() as u64);
        self.stats.drains += 1;
        crate::obs::counter("serve.drains", 1);
        let staged = std::mem::take(&mut self.staged);
        let n = self.shards.len();
        // disjoint field borrows: the route-split closure reads the
        // backend, the mining path mutates the shards
        let backend = &self.backend;
        let workers = self.backend.workers;
        let shards = &mut self.shards;
        let stats = &mut self.stats;
        // route-split off the serial path: map chunk INDICES of one wave
        // (no upfront copy) to per-shard BINS on the Pooled backend —
        // binning runs inside the parallel map tasks, so only the
        // per-shard concat is serial. Chunk-major map output order makes
        // per-shard order equal stream order. The concat's direct
        // indexing is the degenerate case of
        // `exec::group_pairs_presorted`, whose general fast path the
        // default `Backend::group_reduce` applies for sorted pair
        // streams (no hash map, no O(n log n) key sort).
        let route_split = |wave: &[NTuple]| -> Vec<Vec<NTuple>> {
            let mut rspan = crate::span!("serve.route_split");
            rspan.records_in(wave.len() as u64);
            let n_chunks = wave.len().div_ceil(SPLIT_CHUNK) as u32;
            let routed: Vec<(u32, Vec<NTuple>)> = backend
                .map_partitions("route-split", (0..n_chunks).collect(), |&ci: &u32| {
                    let lo = ci as usize * SPLIT_CHUNK;
                    let hi = (lo + SPLIT_CHUNK).min(wave.len());
                    let mut bins: Vec<Vec<NTuple>> = vec![Vec::new(); n];
                    for t in &wave[lo..hi] {
                        bins[(fxhash(t) % n as u64) as usize].push(*t);
                    }
                    bins.into_iter()
                        .enumerate()
                        .filter(|(_, bin)| !bin.is_empty())
                        .map(|(s, bin)| (s as u32, bin))
                        .collect()
                })
                .expect("the pooled backend is infallible");
            let mut queues: Vec<Vec<NTuple>> =
                (0..n).map(|_| Vec::with_capacity(wave.len() / n + 1)).collect();
            for (s, bin) in routed {
                queues[s as usize].extend_from_slice(&bin);
            }
            rspan.records_out(wave.len() as u64);
            queues
        };
        // wave size: big enough that one wave's route-split saturates
        // the worker pool (one SPLIT_CHUNK task per worker), never
        // smaller than the pipelining floor
        let wave_tuples = (SPLIT_CHUNK * workers).max(WAVE_TUPLES);
        let waves: Vec<&[NTuple]> = staged.chunks(wave_tuples).collect();
        let mut current = route_split(waves[0]);
        for next_idx in 1..=waves.len() {
            stats.waves += 1;
            crate::obs::counter("serve.waves", 1);
            for q in &current {
                stats.max_queue = stats.max_queue.max(q.len());
            }
            crate::obs::gauge("serve.router.max_queue", stats.max_queue as f64);
            // overlap: the NEXT wave routes on a scoped thread while the
            // CURRENT wave mines here (waves stay ordered — wave w+1 is
            // never mined before wave w finished)
            let next = std::thread::scope(|scope| {
                let handle = (next_idx < waves.len())
                    .then(|| scope.spawn(|| route_split(waves[next_idx])));
                mine_wave(shards, std::mem::take(&mut current), workers);
                handle.map(|h| h.join().expect("route-split thread"))
            });
            match next {
                Some(queues) => current = queues,
                None => break,
            }
        }
    }
}

/// One mining task per shard over one wave's queues (each task owns its
/// shard for the wave). Workers left over after one-per-shard are split
/// across the shards and parallelise ingest INSIDE each shard
/// ([`Shard::ingest_par`] — the merge-based kernel), so a deployment
/// with fewer shards than cores still saturates the pool; with shards ≥
/// workers each shard mines sequentially, exactly as before.
fn mine_wave(shards: &mut [Shard], queues: Vec<Vec<NTuple>>, workers: usize) {
    let mut wspan = crate::span!("serve.mine_wave");
    wspan.records_in(queues.iter().map(|q| q.len() as u64).sum());
    let per_shard = (workers / shards.len().max(1)).max(1);
    let jobs: Vec<std::sync::Mutex<Option<(&mut Shard, Vec<NTuple>)>>> = shards
        .iter_mut()
        .zip(queues)
        .map(|job| std::sync::Mutex::new(Some(job)))
        .collect();
    pool::parallel_map(jobs.len(), workers, 1, |i| {
        let (shard, queue) = jobs[i].lock().unwrap().take().expect("taken once");
        let mut sspan = crate::span!("serve.shard.ingest");
        sspan.records_in(queue.len() as u64);
        if crate::obs::enabled() && !queue.is_empty() {
            crate::obs::counter(
                &format!("serve.shard{}.tuples", shard.id()),
                queue.len() as u64,
            );
        }
        shard.ingest_par(&queue, per_shard);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(n: u32) -> Vec<NTuple> {
        (0..n).map(|i| NTuple::triple(i % 7, i % 5, i % 3)).collect()
    }

    #[test]
    fn routing_is_deterministic_and_total() {
        let r = Router::new(3, 4, 1024, 2);
        for t in batch(100) {
            let s = r.route(&t);
            assert!(s < 4);
            assert_eq!(s, r.route(&t));
        }
    }

    #[test]
    fn submit_below_watermark_stages() {
        let mut r = Router::new(3, 2, 1_000, 2);
        r.submit(&batch(50));
        assert_eq!(r.pending(), 50);
        assert_eq!(r.stats().drains, 0);
        assert!(r.shards().iter().all(Shard::is_empty));
        r.drain();
        assert_eq!(r.pending(), 0);
        assert_eq!(r.stats().drains, 1);
        let mined: usize = r.shards().iter().map(Shard::len).sum();
        assert_eq!(mined, 50);
    }

    #[test]
    fn watermark_triggers_backpressure_drain() {
        let mut r = Router::new(3, 2, 64, 2);
        r.submit(&batch(100)); // crosses the high-water mark
        assert_eq!(r.pending(), 0, "drained inside submit");
        assert_eq!(r.stats().drains, 1);
        assert!(r.stats().max_queue <= 100);
    }

    #[test]
    fn every_tuple_lands_on_its_routed_shard_in_order() {
        let mut r = Router::new(3, 3, 1, 2); // drain every submit
        let data = batch(60); // lcm(7,5,3) = 105 > 60: all distinct
        let expected: Vec<usize> = data.iter().map(|t| r.route(t)).collect();
        r.submit(&data);
        let mut per_shard = vec![0usize; 3];
        for s in &expected {
            per_shard[*s] += 1;
        }
        for (shard, &want) in r.shards().iter().zip(&per_shard) {
            assert_eq!(shard.len(), want);
        }
        // per-shard arrival order must equal stream order
        for (i, shard) in r.shards().iter().enumerate() {
            let got = shard.ingested_tuples();
            let want: Vec<NTuple> = data
                .iter()
                .zip(&expected)
                .filter(|(_, &s)| s == i)
                .map(|(t, _)| *t)
                .collect();
            assert_eq!(got, want, "shard {i} order");
        }
    }

    #[test]
    fn pipelined_waves_preserve_stream_order_and_mine_everything() {
        // > 2 waves, so route-split of wave w+1 really overlaps mining of
        // wave w; per-shard order must still equal stream order
        let data: Vec<NTuple> = (0..(2 * super::WAVE_TUPLES as u32 + 999))
            .map(|i| NTuple::triple(i % 1009, i % 911, i % 773))
            .collect();
        let mut r = Router::new(3, 4, usize::MAX, 4);
        r.submit(&data);
        r.drain();
        assert_eq!(r.stats().drains, 1);
        assert!(r.stats().waves >= 3, "large drain must pipeline in waves");
        let mined: usize = r.shards().iter().map(Shard::len).sum();
        assert_eq!(mined, data.len());
        for (i, shard) in r.shards().iter().enumerate() {
            let got = shard.ingested_tuples();
            let want: Vec<NTuple> =
                data.iter().filter(|t| r.route(t) == i).copied().collect();
            assert_eq!(got, want, "shard {i} stream order across waves");
        }
    }

    #[test]
    fn multi_chunk_split_preserves_order() {
        // > SPLIT_CHUNK tuples so the parallel split really runs multi-task
        let data: Vec<NTuple> = (0..(2 * super::SPLIT_CHUNK as u32 + 123))
            .map(|i| NTuple::triple(i, i / 3, i / 7))
            .collect();
        let mut r = Router::new(3, 4, usize::MAX, 4);
        r.submit(&data);
        r.drain();
        let mined: usize = r.shards().iter().map(Shard::len).sum();
        assert_eq!(mined, data.len());
        for (i, shard) in r.shards().iter().enumerate() {
            let got = shard.ingested_tuples();
            let want: Vec<NTuple> =
                data.iter().filter(|t| r.route(t) == i).copied().collect();
            assert_eq!(got, want, "shard {i} stream order across chunks");
        }
    }
}
