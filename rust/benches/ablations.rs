//! Bench: ablations A1 (hash-slicing skew vs task-balanced 3-stage) and
//! A3 (task-retry duplicate injection) — the design arguments of §1.

use tricluster::coordinator::ablations;

fn main() -> anyhow::Result<()> {
    eprintln!("ablation benches ...");
    let skew = ablations::partition_skew(10)?;
    println!("{}", skew.render());
    skew.write_csv()?;
    println!();
    let faults = ablations::fault_injection()?;
    println!("{}", faults.render());
    faults.write_csv()?;
    println!();
    let memory = ablations::dfs_vs_memory()?;
    println!("{}", memory.render());
    memory.write_csv()?;
    println!();
    println!("shape: slicing by a small modality leaves nodes idle / skewed (the");
    println!("[43] bottleneck); retries inflate wall time but never change output.");
    Ok(())
}
