//! Seeded, replayable adversarial workload generators.
//!
//! The paper's scalability comparison (§4–§7) is run on well-behaved
//! synthetic contexts; the failure modes that actually decide whether a
//! distributed triclustering SERVICE holds up — heavy-hitter key skew,
//! distribution drift mid-stream, bursty ingress colliding with a steady
//! query mix, and correlated (not independent) node failures — need
//! workloads designed to trigger them. This module produces those
//! scenarios for the sim layers ([`crate::serve::cluster::ServeSim`],
//! [`crate::serve::tenant::MultiTenantSim`], [`crate::exec::ClusterSim`])
//! to injure.
//!
//! Every generator is a PURE function of its configuration and a `u64`
//! seed over the repo PRNG ([`crate::util::rng::Rng`]): the same
//! `(config, seed)` pair replays the workload bit-identically, on any
//! machine — the property `rust/tests/workload_invariants.rs` pins for
//! all four generators, and the precondition for using an adversarial
//! scenario inside a deterministic equivalence test at all.
//!
//! | generator            | scenario it injures                          |
//! |----------------------|----------------------------------------------|
//! | [`SkewedStream`]     | heavy-hitter key skew → hot shards/cumuli    |
//! | [`DriftingStream`]   | temporal drift → incremental re-compaction   |
//! | [`BurstMix`]         | burst ingress against a steady query mix     |
//! | [`correlated_kills`] | placement-correlated node-set failures       |

use crate::core::tuple::NTuple;
use crate::util::rng::{Rng, Zipf};

/// Heavy-hitter key skew: component 0 of every tuple is drawn from a
/// Zipf(`exponent`) over `universe` ids (rank 0 = the heavy hitter), the
/// remaining components uniformly. Routing hashes the whole tuple, so
/// the hot KEY concentrates into hot CUMULI (many tuples sharing
/// subrelations with the heavy hitter) rather than one hot shard — the
/// skew stresses the compactor's shared-set merge, and under
/// [`crate::serve::cluster::ServeSim`]'s skewed sources it stresses
/// placement too.
#[derive(Debug, Clone)]
pub struct SkewedStream {
    /// Tuples to generate.
    pub tuples: usize,
    /// Id universe per modality (ids are `0..universe`).
    pub universe: u64,
    /// Zipf exponent for component 0 (0.0 = uniform; 2.0+ = one id
    /// dominates).
    pub exponent: f64,
    /// Relation arity (≥ 2).
    pub arity: usize,
}

impl SkewedStream {
    /// Generate the stream for `seed` (bit-identical per `(self, seed)`).
    pub fn generate(&self, seed: u64) -> Vec<NTuple> {
        let mut rng = Rng::new(seed);
        let zipf = Zipf::new(self.universe.max(1), self.exponent.max(0.0));
        let mut out = Vec::with_capacity(self.tuples);
        let mut elems = vec![0u32; self.arity.max(2)];
        for _ in 0..self.tuples {
            elems[0] = zipf.sample(&mut rng) as u32;
            for e in elems.iter_mut().skip(1) {
                *e = rng.below(self.universe.max(1)) as u32;
            }
            out.push(NTuple::new(&elems));
        }
        out
    }
}

/// Temporal drift: the stream is cut into `segments` equal spans, and
/// segment `i` draws every component uniformly from the WINDOW
/// `[i·shift, i·shift + universe)` — the tuple distribution the miners
/// saw early in the stream stops arriving, and each compaction after a
/// segment boundary must fold in cumuli the previous compactions never
/// touched (the incremental re-compaction path: the watermarked
/// sorted-set cache in [`crate::oac::primes::SetArena`] is what drift
/// stresses).
#[derive(Debug, Clone)]
pub struct DriftingStream {
    /// Tuples to generate.
    pub tuples: usize,
    /// Width of each segment's id window.
    pub universe: u64,
    /// Number of distribution segments (≥ 1).
    pub segments: usize,
    /// Id-window offset added per segment; `shift >= universe` makes
    /// consecutive segments fully disjoint.
    pub shift: u32,
    /// Relation arity (≥ 2).
    pub arity: usize,
}

impl DriftingStream {
    /// Generate the stream for `seed` (bit-identical per `(self, seed)`).
    pub fn generate(&self, seed: u64) -> Vec<NTuple> {
        let mut rng = Rng::new(seed);
        let segments = self.segments.max(1);
        let seg_len = self.tuples.div_ceil(segments).max(1);
        let mut out = Vec::with_capacity(self.tuples);
        let mut elems = vec![0u32; self.arity.max(2)];
        for i in 0..self.tuples {
            let base = (i / seg_len) as u32 * self.shift;
            for e in elems.iter_mut() {
                *e = base + rng.below(self.universe.max(1)) as u32;
            }
            out.push(NTuple::new(&elems));
        }
        out
    }
}

/// One step of a [`BurstMix`] timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// Ingest this batch (a burst wave carries `burst_batch` tuples, a
    /// steady wave `steady_batch`).
    Ingest(Vec<NTuple>),
    /// Answer one read from the query plane.
    Query(QueryOp),
}

/// The read operations a [`BurstMix`] interleaves with ingest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryOp {
    /// Top-k clusters by density.
    TopK(usize),
    /// Clusters containing `entity` in `modality`.
    Containing {
        /// Modality index of the probe.
        modality: usize,
        /// Entity id of the probe.
        entity: u32,
    },
    /// Aggregate index statistics.
    Stats,
}

/// Burst ingress against a steady query mix: every wave ingests a batch
/// (`burst_batch` tuples on every `burst_every`-th wave, `steady_batch`
/// otherwise) followed by `queries_per_wave` seeded reads. The reads
/// arrive at the SAME rate through the burst — the scenario where an
/// ingest spike must not perturb query results (epoch snapshots) or
/// starve the query plane (the fairness the tenant sim measures).
#[derive(Debug, Clone)]
pub struct BurstMix {
    /// Ingest waves to generate.
    pub waves: usize,
    /// Tuples per steady wave.
    pub steady_batch: usize,
    /// Tuples per burst wave (the spike; ≥ `steady_batch` to be one).
    pub burst_batch: usize,
    /// Every `burst_every`-th wave is a burst (0 = never).
    pub burst_every: usize,
    /// Seeded reads appended after every wave.
    pub queries_per_wave: usize,
    /// Id universe per modality.
    pub universe: u64,
    /// Relation arity (≥ 2).
    pub arity: usize,
}

impl BurstMix {
    /// True when wave `w` (0-based) is a burst wave.
    pub fn is_burst(&self, wave: usize) -> bool {
        self.burst_every > 0 && (wave + 1) % self.burst_every == 0
    }

    /// Generate the op timeline for `seed` (bit-identical per
    /// `(self, seed)`).
    pub fn generate(&self, seed: u64) -> Vec<Op> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        let mut elems = vec![0u32; self.arity.max(2)];
        for w in 0..self.waves {
            let n = if self.is_burst(w) { self.burst_batch } else { self.steady_batch };
            let mut batch = Vec::with_capacity(n);
            for _ in 0..n {
                for e in elems.iter_mut() {
                    *e = rng.below(self.universe.max(1)) as u32;
                }
                batch.push(NTuple::new(&elems));
            }
            out.push(Op::Ingest(batch));
            for _ in 0..self.queries_per_wave {
                let q = match rng.usize_below(3) {
                    0 => QueryOp::TopK(1 + rng.usize_below(8)),
                    1 => QueryOp::Containing {
                        modality: rng.usize_below(self.arity.max(2)),
                        entity: rng.below(self.universe.max(1)) as u32,
                    },
                    _ => QueryOp::Stats,
                };
                out.push(Op::Query(q));
            }
        }
        out
    }
}

/// One correlated kill: at the start of ingest wave `wave`, take down
/// every node in `victims` together.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KillEvent {
    /// 0-based ingest wave the kill lands on.
    pub wave: usize,
    /// The placement-correlated node set killed as one event.
    pub victims: Vec<usize>,
}

/// Correlated node failures: kill a PLACEMENT-correlated node set, not
/// independent draws. Nodes are ranked by how many shards the current
/// `assignment` (shard → node) puts on them (descending, ties by id),
/// and each event's victims are `set_size` ADJACENT nodes in that
/// ranking — a seeded window start rotates which stratum dies, but the
/// set always falls together in placement-load order, the way a rack or
/// AZ failure takes out co-located primaries. Pure in
/// `(assignment, nodes, set_size, kills, waves, seed)`.
pub fn correlated_kills(
    assignment: &[usize],
    nodes: usize,
    set_size: usize,
    kills: usize,
    waves: usize,
    seed: u64,
) -> Vec<KillEvent> {
    let n = nodes.max(1);
    let set_size = set_size.clamp(1, n);
    let mut load = vec![0usize; n];
    for &node in assignment {
        if node < n {
            load[node] += 1;
        }
    }
    let mut ranking: Vec<usize> = (0..n).collect();
    ranking.sort_by_key(|&i| (std::cmp::Reverse(load[i]), i));
    let mut rng = Rng::new(seed ^ 0x4641_494C_5321); // "FAIL!" salt
    let mut events = Vec::with_capacity(kills);
    for _ in 0..kills {
        let wave = rng.usize_below(waves.max(1));
        let start = rng.usize_below(n);
        let victims: Vec<usize> =
            (0..set_size).map(|k| ranking[(start + k) % n]).collect();
        events.push(KillEvent { wave, victims });
    }
    events.sort_by_key(|e| e.wave);
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_concentrates_on_rank_zero() {
        let cfg = SkewedStream { tuples: 4000, universe: 50, exponent: 2.0, arity: 3 };
        let stream = cfg.generate(7);
        assert_eq!(stream.len(), 4000);
        let hot = stream.iter().filter(|t| t.get(0) == 0).count();
        // uniform share would be 80; Zipf(2.0) gives rank 0 ~61%
        assert!(hot > 800, "heavy hitter got {hot}/4000");
    }

    #[test]
    fn drift_moves_the_id_window() {
        let cfg =
            DriftingStream { tuples: 300, universe: 10, segments: 3, shift: 100, arity: 3 };
        let stream = cfg.generate(1);
        assert!(stream[..100].iter().all(|t| t.get(0) < 10));
        assert!(stream[200..].iter().all(|t| (200..210).contains(&t.get(0))));
    }

    #[test]
    fn burst_waves_follow_the_cadence() {
        let cfg = BurstMix {
            waves: 6,
            steady_batch: 10,
            burst_batch: 50,
            burst_every: 3,
            queries_per_wave: 2,
            universe: 9,
            arity: 3,
        };
        let ops = cfg.generate(3);
        let sizes: Vec<usize> = ops
            .iter()
            .filter_map(|op| match op {
                Op::Ingest(b) => Some(b.len()),
                Op::Query(_) => None,
            })
            .collect();
        assert_eq!(sizes, vec![10, 10, 50, 10, 10, 50]);
        let queries = ops.iter().filter(|op| matches!(op, Op::Query(_))).count();
        assert_eq!(queries, 12);
    }

    #[test]
    fn kills_are_adjacent_in_the_load_ranking() {
        // node 1 hosts 3 shards, node 0 hosts 1, nodes 2/3 are idle:
        // ranking is [1, 0, 2, 3]
        let assignment = [1, 1, 1, 0];
        let events = correlated_kills(&assignment, 4, 2, 5, 10, 42);
        assert_eq!(events.len(), 5);
        let ranking = [1usize, 0, 2, 3];
        for e in &events {
            assert!(e.wave < 10);
            assert_eq!(e.victims.len(), 2);
            let start = ranking
                .iter()
                .position(|&n| n == e.victims[0])
                .expect("victim is a node");
            assert_eq!(e.victims[1], ranking[(start + 1) % 4], "adjacent stratum");
        }
    }

    #[test]
    fn generators_replay_bit_identically() {
        let skew = SkewedStream { tuples: 500, universe: 20, exponent: 1.5, arity: 4 };
        assert_eq!(skew.generate(9), skew.generate(9));
        let drift =
            DriftingStream { tuples: 500, universe: 16, segments: 4, shift: 16, arity: 3 };
        assert_eq!(drift.generate(9), drift.generate(9));
    }
}
