//! Quickstart: mine triclusters from a tiny hand-written context with
//! both the online algorithm and the 3-stage MapReduce pipeline, and
//! print the patterns in the paper's output format.
//!
//! Run: `cargo run --release --example quickstart`

use tricluster::core::context::TriContext;
use tricluster::core::io::format_cluster;
use tricluster::mmc::{run_mmc, MmcConfig};
use tricluster::oac::{mine_online, Constraints};

fn main() -> anyhow::Result<()> {
    // The users × items × labels example of the paper's Table 1.
    let mut ctx = TriContext::new();
    for (u, i, l) in [
        ("u1", "i1", "l1"),
        ("u2", "i1", "l1"),
        ("u2", "i2", "l1"),
        ("u2", "i1", "l2"),
        ("u2", "i2", "l2"),
        ("u3", "i3", "l2"),
    ] {
        ctx.add_named(u, i, l);
    }
    println!("context: {} triples over {:?}\n", ctx.len(), ctx.sizes());

    // --- online OAC-prime (one pass, O(|I|)) ---------------------------
    let clusters = mine_online(&ctx.inner, &Constraints::none());
    println!("online OAC-prime found {} triclusters:", clusters.len());
    for c in &clusters {
        println!(
            "{}  (support {}, ρ̂ {:.2})",
            format_cluster(&ctx.inner, c),
            c.support,
            c.support_density()
        );
    }

    // --- three-stage MapReduce (the paper's contribution) --------------
    let res = run_mmc(&ctx.inner, &MmcConfig::default())?;
    println!(
        "\n3-stage M/R found {} clusters in {:.1} ms (virtual 10-node makespan {:.1} ms)",
        res.clusters.len(),
        res.wall_ms,
        res.makespan_ms(10)
    );
    assert_eq!(res.clusters.len(), clusters.len());

    // --- with a density threshold θ -------------------------------------
    let dense = run_mmc(&ctx.inner, &MmcConfig { theta: 0.99, ..MmcConfig::default() })?;
    println!("\nθ = 0.99 keeps {} clusters:", dense.clusters.len());
    for c in &dense.clusters {
        println!("{}", format_cluster(&ctx.inner, c));
    }
    Ok(())
}
