//! NOAC validity checks (paper §4.3): minimal density over the binary
//! presence relation and minimal cardinality per modality.
//!
//! Density here is the true cuboid density `|X×Y×Z ∩ I| / |X||Y||Z|`
//! evaluated with hash lookups and an early-exit bound: once the
//! remaining cells cannot reach ρ_min (or cannot fall below it) the scan
//! stops. For large cuboids the `density::XlaEngine` / `MonteCarloEngine`
//! offer batched and approximate alternatives (ablation A2).

use crate::core::context::ManyValuedTriContext;
use crate::core::pattern::Cluster;
use crate::noac::NoacParams;
use crate::oac::generic::Validity;
use crate::util::hash::FxHashSet;

/// NOAC validity predicate: ρ_min over binary presence + minsup per
///  modality (paper §3.2).
pub struct NoacValidity {
    presence: FxHashSet<(u32, u32, u32)>,
    min_density: f64,
    min_support: usize,
}

impl NoacValidity {
    /// Precompute the presence set of `ctx` for the given parameters.
    pub fn new(ctx: &ManyValuedTriContext, params: &NoacParams) -> Self {
        let presence = ctx
            .triples()
            .iter()
            .map(|t| (t.get(0), t.get(1), t.get(2)))
            .collect();
        Self {
            presence,
            min_density: params.min_density,
            min_support: params.min_support,
        }
    }

    /// Exact presence-density with early exit in both directions.
    pub fn density(&self, c: &Cluster) -> f64 {
        let vol = c.volume();
        if vol == 0.0 {
            return 0.0;
        }
        let mut hit = 0u64;
        for &g in &c.components[0] {
            for &m in &c.components[1] {
                for &b in &c.components[2] {
                    if self.presence.contains(&(g, m, b)) {
                        hit += 1;
                    }
                }
            }
        }
        hit as f64 / vol
    }

    fn density_at_least(&self, c: &Cluster, rho: f64) -> bool {
        let vol = c.volume() as u64;
        if vol == 0 {
            return false;
        }
        let need = (rho * vol as f64).ceil() as u64;
        let mut hit = 0u64;
        let mut seen = 0u64;
        for &g in &c.components[0] {
            for &m in &c.components[1] {
                for &b in &c.components[2] {
                    seen += 1;
                    if self.presence.contains(&(g, m, b)) {
                        hit += 1;
                        if hit >= need {
                            return true; // already dense enough
                        }
                    }
                    // even if all remaining cells hit, can't reach `need`
                    if hit + (vol - seen) < need {
                        return false;
                    }
                }
            }
        }
        hit >= need
    }
}

impl Validity for NoacValidity {
    fn is_valid(&self, c: &Cluster) -> bool {
        if self.min_support > 0 && c.min_cardinality() < self.min_support {
            return false;
        }
        self.min_density <= 0.0 || self.density_at_least(c, self.min_density)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;

    fn ctx() -> ManyValuedTriContext {
        let mut c = ManyValuedTriContext::new();
        // a 2×2×1 dense block + a lone triple
        c.add(0, 0, 0, 1.0);
        c.add(0, 1, 0, 1.0);
        c.add(1, 0, 0, 1.0);
        c.add(1, 1, 0, 1.0);
        c.add(5, 5, 5, 1.0);
        c
    }

    #[test]
    fn exact_density() {
        let v = NoacValidity::new(
            &ctx(),
            &NoacParams { delta: 0.0, min_density: 0.0, min_support: 0 },
        );
        let full = tricluster(vec![0, 1], vec![0, 1], vec![0]);
        assert!((v.density(&full) - 1.0).abs() < 1e-12);
        let half = tricluster(vec![0, 1, 5], vec![0, 1], vec![0]);
        assert!((v.density(&half) - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn density_threshold_and_early_exit_agree() {
        let v = NoacValidity::new(
            &ctx(),
            &NoacParams { delta: 0.0, min_density: 0.5, min_support: 0 },
        );
        let dense = tricluster(vec![0, 1], vec![0, 1], vec![0]);
        let sparse = tricluster(vec![0, 1, 5], vec![0, 1, 5], vec![0, 5]);
        assert!(v.is_valid(&dense));
        assert!(!v.is_valid(&sparse));
        // cross-check against the exact density
        assert!(v.density(&sparse) < 0.5);
    }

    #[test]
    fn minsup_gate() {
        let v = NoacValidity::new(
            &ctx(),
            &NoacParams { delta: 0.0, min_density: 0.0, min_support: 2 },
        );
        assert!(v.is_valid(&tricluster(vec![0, 1], vec![0, 1], vec![0, 5])));
        assert!(!v.is_valid(&tricluster(vec![0, 1], vec![0, 1], vec![0])));
    }

    #[test]
    fn empty_cluster_invalid_under_density() {
        let v = NoacValidity::new(
            &ctx(),
            &NoacParams { delta: 0.0, min_density: 0.1, min_support: 0 },
        );
        assert!(!v.is_valid(&tricluster(vec![], vec![0], vec![0])));
    }
}
