//! Distributed multimodal clustering — the paper's §4.1 contribution:
//! three chained MapReduce stages computing cumuli, assembling clusters,
//! and deduplicating with an exact support-density threshold.
//!
//! The stage logic (Algorithms 2–7) exists in exactly one backend-generic
//! form in [`crate::exec::stages`]; this module is the Hadoop-flavoured
//! entry point ([`run_mmc`]) that runs it on [`crate::exec::HadoopSim`]
//! and reports the per-stage statistics of Table 4. The former
//! `mmc::stages` Mapper/Reducer structs were replaced by the stage
//! functions `exec::stages::{s1_map, s1_combine, s1_reduce, s2_map,
//! s2_reduce}` plus the stage-3 `group_reduce` round (see
//! docs/ARCHITECTURE.md for the migration map).

pub mod app;

pub use app::{run_mmc, MmcConfig, MmcResult};
