//! Cross-algorithm integration tests: the online miner, the basic
//! offline algorithm, and the three-stage M/R pipeline must produce the
//! same pattern sets on every dataset family, with and without injected
//! task retries.

use std::time::Duration;

use tricluster::core::pattern::Cluster;
use tricluster::coordinator::{measure_both, ExpConfig};
use tricluster::datasets::{
    bibsonomy, imdb, movielens, synthetic::{k1, k2, k3}, BibsonomyParams,
    ImdbParams, MovielensParams,
};
use tricluster::mmc::{run_mmc, MmcConfig};
use tricluster::oac::{mine_basic, mine_online, BasicOutcome, Constraints};

fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
    cs.sort_by(|a, b| a.components.cmp(&b.components));
    cs
}

fn assert_same(a: &[Cluster], b: &[Cluster]) {
    assert_eq!(a.len(), b.len(), "cluster counts differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.components, y.components);
        assert_eq!(x.support, y.support);
    }
}

fn mr_cfg() -> MmcConfig {
    MmcConfig { map_tasks: 8, reduce_tasks: 8, ..MmcConfig::default() }
}

#[test]
fn online_equals_mr_on_imdb() {
    let ctx = imdb(&ImdbParams {
        movies: 50,
        tag_universe: 120,
        target_triples: 600,
        seed: 3,
    });
    let online = sorted(mine_online(&ctx.inner, &Constraints::none()));
    let mr = run_mmc(&ctx.inner, &mr_cfg()).unwrap();
    assert_same(&mr.clusters, &online);
    assert!(!online.is_empty());
}

#[test]
fn online_equals_mr_on_movielens_4ary() {
    let ctx = movielens(&MovielensParams::with_tuples(5_000));
    let online = sorted(mine_online(&ctx, &Constraints::none()));
    let mr = run_mmc(&ctx, &mr_cfg()).unwrap();
    assert_same(&mr.clusters, &online);
}

#[test]
fn online_equals_mr_on_bibsonomy_sample() {
    let ctx = bibsonomy(&BibsonomyParams::scaled(4_000)).inner;
    let online = sorted(mine_online(&ctx, &Constraints::none()));
    let mr = run_mmc(&ctx, &mr_cfg()).unwrap();
    assert_same(&mr.clusters, &online);
}

#[test]
fn online_equals_basic_on_k2() {
    let ctx = k2(6);
    let online = sorted(mine_online(&ctx.inner, &Constraints::none()));
    match mine_basic(&ctx, 0.0, Duration::from_secs(60)) {
        BasicOutcome::Done { clusters, .. } => {
            let basic = sorted(clusters);
            assert_eq!(basic.len(), online.len());
            for (a, b) in basic.iter().zip(&online) {
                assert_eq!(a.components, b.components);
            }
        }
        BasicOutcome::TimedOut { .. } => panic!("basic timed out on tiny K2"),
    }
}

#[test]
fn duplicates_invariant_across_all_synthetic_families() {
    // the paper's K1–K3 robustness claim, end to end
    for (name, ctx) in [
        ("k1", k1(8).inner),
        ("k2", k2(6).inner),
        ("k3", k3(5)),
    ] {
        let clean = run_mmc(&ctx, &mr_cfg()).unwrap();
        let noisy = run_mmc(
            &ctx,
            &MmcConfig { fault_prob: 0.7, seed: 99, ..mr_cfg() },
        )
        .unwrap();
        assert_same(&clean.clusters, &noisy.clusters);
        eprintln!("{name}: {} clusters invariant under retries", clean.clusters.len());
    }
}

#[test]
fn theta_filter_equivalence_between_online_and_mr() {
    // support-density threshold must filter identically in both paths
    let ctx = k1(7).inner;
    let theta = 0.9;
    let online = sorted(mine_online(
        &ctx,
        &Constraints { min_density: theta, min_support: 0 },
    ));
    let mr = run_mmc(&ctx, &MmcConfig { theta, ..mr_cfg() }).unwrap();
    assert_same(&mr.clusters, &online);
}

#[test]
fn measure_both_agrees_on_counts() {
    let cfg = ExpConfig { full: false, nodes: 4, theta: 0.0, runs: 1, seed: 7 };
    let ctx = movielens(&MovielensParams::with_tuples(2_000));
    let m = measure_both(&ctx, &cfg).unwrap();
    assert_eq!(m.mr.clusters.len(), m.online_clusters);
}

#[test]
fn support_counts_bounded_by_tuples() {
    let ctx = movielens(&MovielensParams::with_tuples(3_000));
    let mr = run_mmc(&ctx, &mr_cfg()).unwrap();
    let total: usize = mr.clusters.iter().map(|c| c.support).sum();
    assert_eq!(total, ctx.len(), "every tuple generates exactly one cluster");
    for c in &mr.clusters {
        // support never exceeds the cluster volume
        assert!(c.support as f64 <= c.volume() + 1e-9);
    }
}
