//! Bench: cluster scaling — the identical cumuli → assembly →
//! dedup+density workload on the simulated N-node `ClusterSim` backend,
//! swept over nodes × straggler rate × speculation. Writes
//! `BENCH_cluster.json` (repo root): simulated-makespan speedup curves
//! mirroring the paper's scalability figures, with distribution itself
//! (placement, stragglers, speculation) as the variable.
//!
//! Uses the per-record cost model, so every number is a deterministic
//! function of the workload and the seed — machine-independent, which is
//! what lets `ci/check_bench.rs` pin the trajectory against
//! `ci/bench_baseline.json` (monotone speedup 1→8 nodes with speculation
//! on, speedup floors, optional absolute makespans).
//!
//! Doubles as an acceptance gate: every configuration is checked against
//! the online-miner reference cluster set, so a divergence fails the
//! process. `TRICLUSTER_BENCH_FULL=1` for the paper-sized context.

use std::collections::BTreeMap;

use tricluster::core::pattern::{diff_cluster_sets, sort_clusters, Cluster};
use tricluster::datasets::{movielens, MovielensParams};
use tricluster::exec::{run_pipeline, ExecTuning};
use tricluster::oac::{mine_online, Constraints};
use tricluster::util::json::Json;

/// Simulated per-record task cost (ms) — the deterministic cost model.
const COST_MS_PER_RECORD: f64 = 0.002;

/// Fixed per-phase task count: the sweep pins granularity so the task
/// duration multiset AND the per-task straggler fates are identical at
/// every node count — the curves then isolate distribution (the
/// adaptive-task-count path is exercised by the equivalence tests and
/// `experiment --id cluster-scaling` instead).
const TASKS: usize = 64;

const NODE_COUNTS: [usize; 4] = [1, 2, 4, 8];
const STRAGGLER_RATES: [f64; 3] = [0.0, 0.1, 0.3];

fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
    sort_clusters(&mut cs);
    cs
}

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn main() {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let tuples = if full { 200_000 } else { 20_000 };
    let ctx = movielens(&MovielensParams::with_tuples(tuples));
    let reference = sorted(mine_online(&ctx, &Constraints::none()));
    eprintln!(
        "cluster_scaling bench (full={full}): {} tuples, nodes {:?} x stragglers {:?} x spec",
        ctx.len(),
        NODE_COUNTS,
        STRAGGLER_RATES
    );

    let mut entries: Vec<Json> = Vec::new();
    for &stragglers in &STRAGGLER_RATES {
        for speculation in [true, false] {
            let mut base = f64::NAN; // 1-node makespan of this series
            let mut prev = f64::INFINITY;
            for &nodes in &NODE_COUNTS {
                let tune = ExecTuning {
                    nodes,
                    straggler_prob: stragglers,
                    speculation,
                    cost_ms_per_record: Some(COST_MS_PER_RECORD),
                    tasks: TASKS,
                    adaptive_tasks: false,
                    seed: 0xC1_05_7E,
                    ..ExecTuning::default()
                };
                let backend = tune.cluster_backend().expect("cluster backend");
                let clusters =
                    sorted(run_pipeline(&backend, &ctx, 0.0, false).expect("pipeline"));
                if let Some(diff) = diff_cluster_sets(&reference, &clusters) {
                    panic!(
                        "cluster diverged from mine_online (nodes={nodes}, \
                         stragglers={stragglers}, spec={speculation}): {diff}"
                    );
                }
                let makespan = backend.sim_makespan_ms();
                if nodes == NODE_COUNTS[0] {
                    base = makespan;
                }
                let speedup = base / makespan;
                let stats = backend.take_stats();
                let spec_launched: usize = stats.iter().map(|s| s.spec_launched).sum();
                let spec_wins: usize = stats.iter().map(|s| s.spec_wins).sum();
                let failures: usize = stats.iter().map(|s| s.failures).sum();
                eprintln!(
                    "  nodes={nodes} stragglers={stragglers:.2} spec={}: \
                     makespan {makespan:9.1} ms  speedup {speedup:5.2}x  \
                     (spec {spec_launched}/{spec_wins})",
                    if speculation { "on " } else { "off" }
                );
                // the headline acceptance property, enforced at the source:
                // with speculation on, adding nodes never slows the cluster
                if speculation && makespan > prev * 1.02 {
                    panic!(
                        "non-monotone speedup with speculation on: {makespan} ms at \
                         {nodes} nodes > {prev} ms at fewer (stragglers={stragglers})"
                    );
                }
                prev = makespan;
                let mut o = BTreeMap::new();
                o.insert("nodes".to_string(), num(nodes as f64));
                o.insert("stragglers".to_string(), num(stragglers));
                o.insert("speculation".to_string(), Json::Bool(speculation));
                o.insert("sim_makespan_ms".to_string(), num(makespan));
                o.insert("speedup_vs_1node".to_string(), num(speedup));
                o.insert("spec_launched".to_string(), num(spec_launched as f64));
                o.insert("spec_wins".to_string(), num(spec_wins as f64));
                o.insert("failures".to_string(), num(failures as f64));
                o.insert("clusters".to_string(), num(clusters.len() as f64));
                entries.push(Json::Obj(o));
            }
        }
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("cluster_scaling".into()));
    doc.insert("full".to_string(), Json::Bool(full));
    doc.insert("tuples".to_string(), num(ctx.len() as f64));
    doc.insert("cost_ms_per_record".to_string(), num(COST_MS_PER_RECORD));
    doc.insert(
        "nodes".to_string(),
        Json::Arr(NODE_COUNTS.iter().map(|&n| num(n as f64)).collect()),
    );
    doc.insert("entries".to_string(), Json::Arr(entries));
    std::fs::write("BENCH_cluster.json", Json::Obj(doc).to_string())
        .expect("write BENCH_cluster.json");
    eprintln!(
        "wrote BENCH_cluster.json (all configurations agreed with mine_online; \
         speedup monotone 1→8 nodes with speculation on)"
    );
}
