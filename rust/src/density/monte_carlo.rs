//! Monte-Carlo density estimation — the approximate engine the paper
//! proposes as future work (§7).
//!
//! ρ̂ = (1/S) Σ_s 1[(g_s, m_s, b_s) ∈ I] with the S coordinates sampled
//! uniformly from the cluster cuboid X×Y×Z. Unbiased; std error
//! ≤ 1/(2√S). Two backends: host hash-membership (any context size) and
//! the AOT `mc_g{T}_s{S}` artifact (single-tile contexts, exercising the
//! same PJRT path as the Pallas kernels).

use anyhow::Result;

use crate::core::context::TriContext;
use crate::core::pattern::Cluster;
use crate::density::tiling::DenseTiles;
use crate::density::DensityEngine;
use crate::runtime::{McExecutable, Runtime};
use crate::util::rng::Rng;

/// Sampled density estimation: `samples` uniform probes per cluster.
pub struct MonteCarloEngine {
    /// Uniform probes drawn per cluster.
    pub samples: usize,
    rng: Rng,
    /// Optional AOT backend (used when the whole context fits one tile).
    artifact: Option<McExecutable>,
    tiles: Option<DenseTiles>,
}

impl MonteCarloEngine {
    /// Host-only engine (no AOT artifact), seeded.
    pub fn host(samples: usize, seed: u64) -> Self {
        Self { samples, rng: Rng::new(seed), artifact: None, tiles: None }
    }

    /// Use the AOT mc artifact; sample count is fixed by the artifact.
    pub fn with_artifact(rt: &Runtime, name: &str, seed: u64) -> Result<Self> {
        let exe = rt.mc(name)?;
        Ok(Self {
            samples: exe.samples,
            rng: Rng::new(seed),
            artifact: Some(exe),
            tiles: None,
        })
    }

    fn estimate_host(&mut self, ctx: &TriContext, c: &Cluster) -> f64 {
        let (xs, ys, zs) = (&c.components[0], &c.components[1], &c.components[2]);
        if xs.is_empty() || ys.is_empty() || zs.is_empty() {
            return 0.0;
        }
        let mut hit = 0usize;
        for _ in 0..self.samples {
            let g = xs[self.rng.usize_below(xs.len())];
            let m = ys[self.rng.usize_below(ys.len())];
            let b = zs[self.rng.usize_below(zs.len())];
            if ctx.contains(g, m, b) {
                hit += 1;
            }
        }
        hit as f64 / self.samples as f64
    }

    fn estimate_artifact(&mut self, ctx: &TriContext, c: &Cluster) -> Result<f64> {
        let exe = self.artifact.as_ref().unwrap();
        let t = exe.tile;
        let (g, m, b) = ctx.sizes();
        anyhow::ensure!(
            g <= t && m <= t && b <= t,
            "mc artifact path requires a single-tile context"
        );
        if self.tiles.is_none() {
            self.tiles = Some(DenseTiles::build(ctx, t));
        }
        let (xs, ys, zs) = (&c.components[0], &c.components[1], &c.components[2]);
        if xs.is_empty() || ys.is_empty() || zs.is_empty() {
            return Ok(0.0);
        }
        let mut coords = Vec::with_capacity(exe.samples * 3);
        for _ in 0..exe.samples {
            coords.push(xs[self.rng.usize_below(xs.len())] as i32);
            coords.push(ys[self.rng.usize_below(ys.len())] as i32);
            coords.push(zs[self.rng.usize_below(zs.len())] as i32);
        }
        let tile = self.tiles.as_ref().unwrap().tile(0, 0, 0);
        Ok(exe.run(tile, &coords)? as f64)
    }
}

impl DensityEngine for MonteCarloEngine {
    fn name(&self) -> &'static str {
        if self.artifact.is_some() {
            "monte-carlo-xla"
        } else {
            "monte-carlo"
        }
    }

    fn densities(&mut self, ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64> {
        clusters
            .iter()
            .map(|c| {
                if self.artifact.is_some() {
                    self.estimate_artifact(ctx, c).expect("mc artifact")
                } else {
                    self.estimate_host(ctx, c)
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;
    use crate::datasets::synthetic::{k1, k2};

    #[test]
    fn dense_block_estimates_one() {
        let ctx = k2(4);
        let mut mc = MonteCarloEngine::host(500, 42);
        let c = tricluster(vec![0, 1, 2, 3], vec![0, 1, 2, 3], vec![0, 1, 2, 3]);
        assert_eq!(mc.densities(&ctx, &[c]), vec![1.0]);
    }

    #[test]
    fn estimate_within_mc_error() {
        let n = 10;
        let ctx = k1(n); // density (n³-n)/n³ = 0.999… for the full cuboid
        let ids: Vec<u32> = (0..n as u32).collect();
        let c = tricluster(ids.clone(), ids.clone(), ids);
        let mut mc = MonteCarloEngine::host(2_000, 7);
        let d = mc.densities(&ctx, &[c])[0];
        let truth = (n * n * n - n) as f64 / (n * n * n) as f64;
        assert!((d - truth).abs() < 0.05, "d={d} truth={truth}");
    }

    #[test]
    fn empty_cluster_is_zero() {
        let ctx = k2(3);
        let mut mc = MonteCarloEngine::host(100, 1);
        let c = tricluster(vec![], vec![0], vec![0]);
        assert_eq!(mc.densities(&ctx, &[c]), vec![0.0]);
    }
}
