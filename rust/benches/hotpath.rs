//! Micro-benchmarks of the Layer-3 hot paths, with throughput targets
//! from EXPERIMENTS.md §Perf:
//!   * online OAC ingest (prime-store add)        — target ≥ 1M tuples/s
//!   * record codec (shuffle serialisation)       — target ≥ 10M rec/s
//!   * shuffle sort+group                          — reported
//!   * dedup fingerprinting                        — reported
//!   * density engines per cluster                 — reported

use tricluster::core::tuple::NTuple;
use tricluster::datasets::{movielens, MovielensParams};
use tricluster::hadoop::record::Record;
use tricluster::oac::{dedup_and_filter, Constraints, OnlineMiner};
use tricluster::util::stats::{measure_ms, Summary};

fn report(name: &str, unit_per_run: f64, unit: &str, samples: &[f64]) {
    let s = Summary::of(samples);
    let rate = unit_per_run / (s.median / 1e3);
    println!(
        "{name:<28} median {m:>9.2} ms  (p95 {p:>9.2})  => {rate:>12.0} {unit}/s",
        m = s.median,
        p = s.p95,
    );
}

fn main() {
    let n = 200_000usize;
    let ctx = movielens(&MovielensParams::with_tuples(n));
    let tuples = ctx.tuples().to_vec();

    // 1) online ingest
    let samples = measure_ms(1, 5, || {
        let mut miner = OnlineMiner::new(4);
        miner.add_batch(&tuples);
        std::hint::black_box(miner.len());
    });
    report("online ingest (4-ary)", n as f64, "tuples", &samples);

    // 2) materialise + dedup (naive path vs memoized §Perf path)
    let mut miner = OnlineMiner::new(4);
    miner.add_batch(&tuples);
    let samples = measure_ms(1, 5, || {
        let m = miner.materialize_all();
        let out = dedup_and_filter(m, &Constraints::none());
        std::hint::black_box(out.len());
    });
    report("materialize + dedup (naive)", n as f64, "tuples", &samples);
    let samples = measure_ms(1, 5, || {
        let out = miner.dedup_and_filter(&Constraints::none());
        std::hint::black_box(out.len());
    });
    report("dedup (memoized sets)", n as f64, "tuples", &samples);

    // 3) record codec roundtrip
    let samples = measure_ms(1, 5, || {
        let mut buf = Vec::with_capacity(tuples.len() * 20);
        for t in &tuples {
            t.encode(&mut buf);
        }
        let mut slice = buf.as_slice();
        let mut count = 0usize;
        while !slice.is_empty() {
            std::hint::black_box(NTuple::decode(&mut slice));
            count += 1;
        }
        assert_eq!(count, tuples.len());
    });
    report("record codec roundtrip", n as f64, "records", &samples);

    // 4) shuffle sort+group over encoded pairs
    let pairs: Vec<(Vec<u8>, Vec<u8>)> = tuples
        .iter()
        .map(|t| (t.subrelation(0).to_bytes(), t.get(0).to_bytes()))
        .collect();
    let samples = measure_ms(1, 5, || {
        let mut p = pairs.clone();
        p.sort_unstable();
        let mut groups = 0usize;
        let mut i = 0;
        while i < p.len() {
            let mut j = i + 1;
            while j < p.len() && p[j].0 == p[i].0 {
                j += 1;
            }
            groups += 1;
            i = j;
        }
        std::hint::black_box(groups);
    });
    report("shuffle sort+group", n as f64, "pairs", &samples);

    // 5) XLA density engine, if artifacts are present
    if tricluster::runtime::artifacts_available() {
        use tricluster::density::{DensityEngine, ExactEngine, XlaEngine};
        let rt = tricluster::runtime::Runtime::load(
            &tricluster::runtime::default_artifact_dir(),
        )
        .unwrap();
        let tri = tricluster::datasets::synthetic::k1(48);
        let clusters = tricluster::oac::mine_online(
            &tri.inner,
            &tricluster::oac::Constraints::none(),
        );
        let mut xla = XlaEngine::new(&rt, 48, clusters.len()).unwrap();
        let samples = measure_ms(1, 5, || {
            std::hint::black_box(xla.densities(&tri, &clusters).len());
        });
        report("density xla (145 clusters)", clusters.len() as f64, "clusters", &samples);
        let samples = measure_ms(1, 3, || {
            std::hint::black_box(ExactEngine.densities(&tri, &clusters).len());
        });
        report("density exact (145 clusters)", clusters.len() as f64, "clusters", &samples);
    }

    println!("\ntargets (EXPERIMENTS.md §Perf): ingest ≥ 1M tuples/s, codec ≥ 10M rec/s");
}
