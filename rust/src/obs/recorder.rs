//! The global [`Recorder`]: per-thread metric shards and their
//! deterministic merge.
//!
//! Every recording thread owns ONE shard (counters + gauges +
//! histograms + trace-event buffer) behind a mutex only that thread
//! locks on the hot path — contention exists solely against snapshot /
//! trace readers, which are rare. Dead threads' shards (the scoped
//! `util::pool` workers live only for one parallel call) are garbage
//! collected into a `retired` accumulator on the next read, so a long
//! run with thousands of short-lived workers never scans thousands of
//! shards.
//!
//! Merge rules (deterministic for any thread interleaving): counters
//! and histogram buckets ADD (commutative), gauges take the MAX
//! (gauges are high-water marks — e.g. the router's peak queue depth).

use std::cell::OnceCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::hash::FxHashMap;

use super::span::TraceEvent;

/// Log2 histogram bucket count: bucket 0 holds value 0, bucket `b ≥ 1`
/// holds values in `[2^(b-1), 2^b)`, up to `b = 64`.
pub const HIST_BUCKETS: usize = 65;

/// Bucket index of `v` in a log2 histogram.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// A log2-bucketed histogram (counts per power-of-two bucket, plus
/// exact count/sum/min/max).
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    /// Observations recorded.
    pub count: u64,
    /// Sum of observed values.
    pub sum: u64,
    /// Smallest observed value (`u64::MAX` while empty).
    pub min: u64,
    /// Largest observed value.
    pub max: u64,
    /// Per-bucket counts, `HIST_BUCKETS` long (see [`bucket_of`]).
    pub buckets: Vec<u64>,
}

impl Default for Hist {
    fn default() -> Self {
        Self { count: 0, sum: 0, min: u64::MAX, max: 0, buckets: vec![0; HIST_BUCKETS] }
    }
}

impl Hist {
    fn observe(&mut self, v: u64) {
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.buckets[bucket_of(v)] += 1;
    }

    fn merge(&mut self, other: &Hist) {
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// Mean observed value (0 while empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile (`q` in 0..=1): the UPPER bound of the
    /// bucket holding the q-th observation — log2-resolution, good
    /// enough for latency reporting.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return if b == 0 { 0 } else { (1u64 << b).saturating_sub(1).min(self.max) };
            }
        }
        self.max
    }
}

/// One thread's private slice of the recorder.
#[derive(Debug, Default)]
struct Shard {
    counters: FxHashMap<String, u64>,
    gauges: FxHashMap<String, f64>,
    hists: FxHashMap<String, Hist>,
    events: Vec<TraceEvent>,
}

impl Shard {
    fn clear(&mut self) {
        self.counters.clear();
        self.gauges.clear();
        self.hists.clear();
        self.events.clear();
    }

    fn merge_from(&mut self, other: &mut Shard) {
        for (k, v) in other.counters.drain() {
            *self.counters.entry(k).or_insert(0) += v;
        }
        for (k, v) in other.gauges.drain() {
            let g = self.gauges.entry(k).or_insert(f64::MIN);
            if v > *g {
                *g = v;
            }
        }
        for (k, h) in other.hists.drain() {
            self.hists.entry(k).or_default().merge(&h);
        }
        self.events.append(&mut other.events);
    }
}

/// Merged, key-sorted view of every shard at one instant.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    /// Monotonic counters, summed across threads.
    pub counters: BTreeMap<String, u64>,
    /// Gauges (per-thread last-write, max across threads).
    pub gauges: BTreeMap<String, f64>,
    /// Log2 histograms, bucket-wise summed across threads.
    pub hists: BTreeMap<String, Hist>,
}

impl Snapshot {
    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }
}

/// The global sink behind [`crate::obs`]'s free functions: thread-local
/// shards, a retired-shard accumulator, and the trace epoch.
pub struct Recorder {
    next_tid: AtomicU32,
    epoch: OnceLock<Instant>,
    shards: Mutex<Vec<(u32, Arc<Mutex<Shard>>)>>,
    /// Data of threads that have exited, merged on gc.
    retired: Mutex<Shard>,
}

static RECORDER: OnceLock<Recorder> = OnceLock::new();

/// The process-wide recorder instance.
pub fn recorder() -> &'static Recorder {
    RECORDER.get_or_init(|| Recorder {
        next_tid: AtomicU32::new(0),
        epoch: OnceLock::new(),
        shards: Mutex::new(Vec::new()),
        retired: Mutex::new(Shard::default()),
    })
}

thread_local! {
    /// This thread's shard handle (`tid`, shard), registered globally on
    /// first use and kept alive by the registry after the thread dies.
    static LOCAL: OnceCell<(u32, Arc<Mutex<Shard>>)> = const { OnceCell::new() };
}

impl Recorder {
    /// Pin the trace-timestamp epoch (idempotent).
    pub fn touch_epoch(&self) {
        self.epoch.get_or_init(Instant::now);
    }

    /// Microseconds since the epoch.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.get_or_init(Instant::now).elapsed().as_micros() as u64
    }

    fn with_local<R>(&self, f: impl FnOnce(u32, &mut Shard) -> R) -> R {
        LOCAL.with(|cell| {
            let (tid, shard) = cell.get_or_init(|| {
                let tid = self.next_tid.fetch_add(1, Ordering::Relaxed);
                let shard = Arc::new(Mutex::new(Shard::default()));
                self.shards.lock().unwrap().push((tid, Arc::clone(&shard)));
                (tid, shard)
            });
            f(*tid, &mut shard.lock().unwrap())
        })
    }

    pub(super) fn counter(&self, name: &str, delta: u64) {
        self.with_local(|_, s| match s.counters.get_mut(name) {
            Some(v) => *v += delta,
            None => {
                s.counters.insert(name.to_string(), delta);
            }
        });
    }

    pub(super) fn gauge(&self, name: &str, value: f64) {
        self.with_local(|_, s| match s.gauges.get_mut(name) {
            Some(v) => *v = value,
            None => {
                s.gauges.insert(name.to_string(), value);
            }
        });
    }

    pub(super) fn observe(&self, name: &str, value: u64) {
        self.with_local(|_, s| match s.hists.get_mut(name) {
            Some(h) => h.observe(value),
            None => {
                s.hists.entry(name.to_string()).or_default().observe(value);
            }
        });
    }

    /// Buffer one trace event on the calling thread's shard, returning
    /// the thread's stable `tid`.
    pub(super) fn push_event(&self, mut ev: TraceEvent) -> u32 {
        self.with_local(|tid, s| {
            ev.tid = tid;
            s.events.push(ev);
            tid
        })
    }

    /// Fold shards of dead threads (registry holds the only Arc) into
    /// `retired`, under the registry lock the caller already holds.
    fn gc(&self, shards: &mut Vec<(u32, Arc<Mutex<Shard>>)>) {
        let mut retired = self.retired.lock().unwrap();
        shards.retain(|(_, arc)| {
            if Arc::strong_count(arc) > 1 {
                return true;
            }
            retired.merge_from(&mut arc.lock().unwrap());
            false
        });
    }

    /// Merged snapshot of every shard (live + retired).
    pub fn snapshot(&self) -> Snapshot {
        let mut shards = self.shards.lock().unwrap();
        self.gc(&mut shards);
        let mut snap = Snapshot::default();
        let retired = self.retired.lock().unwrap();
        let mut fold = |s: &Shard| {
            for (k, v) in &s.counters {
                *snap.counters.entry(k.clone()).or_insert(0) += v;
            }
            for (k, v) in &s.gauges {
                let g = snap.gauges.entry(k.clone()).or_insert(f64::MIN);
                if *v > *g {
                    *g = *v;
                }
            }
            for (k, h) in &s.hists {
                snap.hists.entry(k.clone()).or_default().merge(h);
            }
        };
        fold(&retired);
        drop(retired);
        for (_, arc) in shards.iter() {
            fold(&arc.lock().unwrap());
        }
        snap
    }

    /// Drain buffered trace events: retired threads first, then live
    /// shards in ascending `tid` order (per-thread event order — and so
    /// per-`tid` `B`/`E` balance — is preserved).
    pub fn take_trace(&self) -> Vec<TraceEvent> {
        let mut shards = self.shards.lock().unwrap();
        self.gc(&mut shards);
        let mut out: Vec<TraceEvent> =
            std::mem::take(&mut self.retired.lock().unwrap().events);
        let mut live: Vec<_> = shards.iter().collect();
        live.sort_by_key(|(tid, _)| *tid);
        for (_, arc) in live {
            out.append(&mut arc.lock().unwrap().events);
        }
        out
    }

    /// Clear every shard (live + retired). Counters, gauges,
    /// histograms, and buffered events all drop; `tid`s and the epoch
    /// persist.
    pub fn reset(&self) {
        let mut shards = self.shards.lock().unwrap();
        self.gc(&mut shards);
        self.retired.lock().unwrap().clear();
        for (_, arc) in shards.iter() {
            arc.lock().unwrap().clear();
        }
    }
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Recorder")
            .field("shards", &self.shards.lock().unwrap().len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), 64);
    }

    #[test]
    fn hist_merge_and_quantile() {
        let mut a = Hist::default();
        let mut b = Hist::default();
        for v in [1u64, 2, 3] {
            a.observe(v);
        }
        for v in [100u64, 200] {
            b.observe(v);
        }
        a.merge(&b);
        assert_eq!(a.count, 5);
        assert_eq!(a.sum, 306);
        assert_eq!(a.min, 1);
        assert_eq!(a.max, 200);
        assert!((a.mean() - 61.2).abs() < 1e-9);
        // p50 lands in bucket 2 ([2,4)) → upper bound 3
        assert_eq!(a.quantile(0.5), 3);
        // p100 is clamped to the exact max
        assert_eq!(a.quantile(1.0), 200);
        let empty = Hist::default();
        assert_eq!(empty.quantile(0.5), 0);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn dead_thread_shards_are_gc_ed_not_lost() {
        let _g = crate::obs::tests::lock();
        crate::obs::reset();
        crate::obs::enable();
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| crate::obs::counter("t.gc", 7));
            }
        });
        // the three worker threads are dead; their shards must survive
        // the gc as retired data
        let snap = crate::obs::snapshot();
        assert_eq!(snap.counters["t.gc"], 21);
        // and a second snapshot (post-gc) still sees them
        let snap2 = crate::obs::snapshot();
        assert_eq!(snap2.counters["t.gc"], 21);
        crate::obs::disable();
        crate::obs::reset();
    }
}
