//! Formal contexts: triadic, polyadic (N-ary), and many-valued.
//!
//! `K = (G, M, B, I)` (paper §2), its N-ary generalisation
//! `K_N = (A_1, …, A_N, I)` (§3.1), and the many-valued triadic context
//! `K_V = (G, M, B, W, I, V)` (§3.2).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::core::interner::Interner;
use crate::core::tuple::NTuple;
use crate::util::hash::{FxHashMap, FxHashSet};

/// Process-wide revision source for [`PolyContext::revision`]. Every
/// successful insert into ANY context draws a fresh stamp, so two
/// contexts can only share a stamp by cloning — which makes "equal
/// revision ⇒ identical incidence relation" hold globally, the property
/// the density engine's row-table cache relies on.
static REVISION: AtomicU64 = AtomicU64::new(1);

/// An N-ary formal context over interned entities.
#[derive(Debug, Clone)]
pub struct PolyContext {
    /// One interner per modality (|interners| = arity).
    pub interners: Vec<Interner>,
    /// The incidence relation I (deduplicated, insertion order kept).
    tuples: Vec<NTuple>,
    seen: FxHashSet<NTuple>,
    /// Globally-unique stamp of the last mutation (0 = never mutated).
    /// Interner growth without a tuple insert cannot affect derived row
    /// tables (extents are widened by actual tuples), so stamping on
    /// tuple insert alone is sufficient for cache invalidation.
    revision: u64,
}

impl PolyContext {
    /// Empty context over `arity` modalities.
    pub fn new(arity: usize) -> Self {
        Self {
            interners: (0..arity).map(|_| Interner::new()).collect(),
            tuples: Vec::new(),
            seen: FxHashSet::default(),
            revision: 0,
        }
    }

    /// Context with capacity hints for bulk loads (dataset generators,
    /// TSV ingest) where sizes are known upfront: `per_modality` entities
    /// per interner and `tuples` incidences — the tuple store and its
    /// dedup set dominate, so both are pre-sized too.
    pub fn with_capacity(arity: usize, per_modality: usize, tuples: usize) -> Self {
        Self {
            interners: (0..arity).map(|_| Interner::with_capacity(per_modality)).collect(),
            tuples: Vec::with_capacity(tuples),
            seen: FxHashSet::with_capacity_and_hasher(tuples, Default::default()),
            revision: 0,
        }
    }

    /// Revision stamp of the incidence relation: 0 for a context that
    /// never saw an insert, otherwise a globally-unique value refreshed
    /// on every successful [`PolyContext::add_ids`]. Equal stamps imply
    /// identical relations (see [`REVISION`]); consumers key derived
    /// structures (the exact density engine's row tables) on it to skip
    /// rebuilds on unchanged contexts.
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Number of modalities (3 = triadic).
    pub fn arity(&self) -> usize {
        self.interners.len()
    }

    /// Cardinality |A_k| of modality k.
    pub fn modality_size(&self, k: usize) -> usize {
        self.interners[k].len()
    }

    /// Number of distinct tuples.
    pub fn len(&self) -> usize {
        self.tuples.len()
    }

    /// True when no tuple was added.
    pub fn is_empty(&self) -> bool {
        self.tuples.is_empty()
    }

    /// All tuples, in first-insertion order.
    pub fn tuples(&self) -> &[NTuple] {
        &self.tuples
    }

    /// True when `t` is in the relation.
    pub fn contains(&self, t: &NTuple) -> bool {
        self.seen.contains(t)
    }

    /// Insert a tuple of already-interned ids; ignores exact duplicates
    /// (I is a set). Returns true if newly inserted.
    pub fn add_ids(&mut self, ids: &[u32]) -> bool {
        debug_assert_eq!(ids.len(), self.arity());
        let t = NTuple::new(ids);
        if self.seen.insert(t) {
            self.tuples.push(t);
            self.revision = REVISION.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Intern names and insert the tuple.
    pub fn add_named(&mut self, names: &[&str]) -> bool {
        assert_eq!(names.len(), self.arity());
        let ids: Vec<u32> = names
            .iter()
            .enumerate()
            .map(|(k, n)| self.interners[k].intern(n))
            .collect();
        self.add_ids(&ids)
    }

    /// Density of the full relation: |I| / Π|A_k|.
    pub fn density(&self) -> f64 {
        let vol: f64 =
            (0..self.arity()).map(|k| self.modality_size(k) as f64).product();
        if vol == 0.0 {
            0.0
        } else {
            self.len() as f64 / vol
        }
    }

    /// Resolve a pattern component to names (for report output).
    pub fn names(&self, k: usize, ids: &[u32]) -> Vec<String> {
        ids.iter().map(|&i| self.interners[k].name(i).to_string()).collect()
    }
}

/// Triadic context (arity-3 specialisation with the paper's G/M/B naming).
#[derive(Debug, Clone)]
pub struct TriContext {
    /// The underlying 3-ary [`PolyContext`].
    pub inner: PolyContext,
}

impl TriContext {
    /// Empty triadic context.
    pub fn new() -> Self {
        Self { inner: PolyContext::new(3) }
    }

    /// Triadic context with capacity hints (see
    /// [`PolyContext::with_capacity`]).
    pub fn with_capacity(per_modality: usize, triples: usize) -> Self {
        Self { inner: PolyContext::with_capacity(3, per_modality, triples) }
    }

    /// Insert `(g, m, b)` by ids; false if it was already present.
    pub fn add(&mut self, g: u32, m: u32, b: u32) -> bool {
        self.inner.add_ids(&[g, m, b])
    }

    /// Intern the names and insert the triple; false if already present.
    pub fn add_named(&mut self, g: &str, m: &str, b: &str) -> bool {
        self.inner.add_named(&[g, m, b])
    }

    /// All triples, in first-insertion order.
    pub fn triples(&self) -> &[NTuple] {
        self.inner.tuples()
    }

    /// Number of distinct triples.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    /// True when no triple was added.
    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// True when `(g, m, b)` is in the relation.
    pub fn contains(&self, g: u32, m: u32, b: u32) -> bool {
        self.inner.contains(&NTuple::triple(g, m, b))
    }

    /// Revision stamp of the relation (see [`PolyContext::revision`]).
    pub fn revision(&self) -> u64 {
        self.inner.revision()
    }

    /// Modality cardinalities `(|G|, |M|, |B|)`.
    pub fn sizes(&self) -> (usize, usize, usize) {
        (
            self.inner.modality_size(0),
            self.inner.modality_size(1),
            self.inner.modality_size(2),
        )
    }
}

impl Default for TriContext {
    fn default() -> Self {
        Self::new()
    }
}

/// Many-valued triadic context `K_V = (G, M, B, W, I, V)`: each incidence
/// triple carries a value `V(g,m,b) ∈ W = ℝ` (paper §3.2). The quaternary
/// functional constraint (one value per triple) is enforced on insert.
#[derive(Debug, Clone, Default)]
pub struct ManyValuedTriContext {
    /// The binary presence relation (values stored separately).
    pub context: TriContext,
    values: FxHashMap<NTuple, f64>,
}

impl ManyValuedTriContext {
    /// Empty many-valued context.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert `(g, m, b) ↦ v`. Re-inserting the same triple keeps the FIRST
    /// value (functional relation; duplicates arise only from M/R retries
    /// and must not change V).
    pub fn add(&mut self, g: u32, m: u32, b: u32, v: f64) -> bool {
        let t = NTuple::triple(g, m, b);
        if self.context.add(g, m, b) {
            self.values.insert(t, v);
            true
        } else {
            false
        }
    }

    /// The value of `(g, m, b)`, if the triple is present.
    pub fn value(&self, g: u32, m: u32, b: u32) -> Option<f64> {
        self.values.get(&NTuple::triple(g, m, b)).copied()
    }

    /// Number of distinct triples.
    pub fn len(&self) -> usize {
        self.context.len()
    }

    /// True when no triple was added.
    pub fn is_empty(&self) -> bool {
        self.context.is_empty()
    }

    /// All triples, in first-insertion order.
    pub fn triples(&self) -> &[NTuple] {
        self.context.triples()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_example_context() {
        // users-items-labels example from paper Table 1
        let mut k = TriContext::new();
        assert!(k.add_named("u2", "i1", "l1"));
        assert!(k.add_named("u2", "i2", "l1"));
        assert!(k.add_named("u2", "i1", "l2"));
        assert!(k.add_named("u2", "i2", "l2"));
        assert!(!k.add_named("u2", "i1", "l1")); // dedup
        assert_eq!(k.len(), 4);
        assert_eq!(k.sizes(), (1, 2, 2));
        assert!((k.inner.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn poly_density() {
        let mut k = PolyContext::new(4);
        k.add_named(&["a", "x", "p", "q"]);
        k.add_named(&["b", "x", "p", "q"]);
        // |A| = 2·1·1·1 = 2, |I| = 2 → density 1
        assert_eq!(k.density(), 1.0);
        k.add_named(&["a", "y", "p", "q"]);
        // now 2·2·1·1 = 4, |I| = 3
        assert_eq!(k.density(), 0.75);
    }

    #[test]
    fn many_valued_keeps_first_value() {
        let mut k = ManyValuedTriContext::new();
        assert!(k.add(0, 0, 0, 5.0));
        assert!(!k.add(0, 0, 0, 9.0)); // duplicate triple
        assert_eq!(k.value(0, 0, 0), Some(5.0));
        assert_eq!(k.value(1, 0, 0), None);
    }

    #[test]
    fn revision_stamps_only_successful_inserts() {
        let mut k = TriContext::new();
        assert_eq!(k.revision(), 0, "fresh context is revision 0");
        k.add(1, 2, 3);
        let r1 = k.revision();
        assert_ne!(r1, 0);
        k.add(1, 2, 3); // duplicate: relation unchanged, stamp kept
        assert_eq!(k.revision(), r1);
        k.add(4, 5, 6);
        assert_ne!(k.revision(), r1, "new triple must bump the stamp");
        // clones share content AND stamp; diverging mutations diverge it
        let mut other = k.clone();
        assert_eq!(other.revision(), k.revision());
        other.add(7, 8, 9);
        assert_ne!(other.revision(), k.revision());
    }

    #[test]
    fn contains_matches_membership() {
        let mut k = TriContext::new();
        k.add(1, 2, 3);
        assert!(k.contains(1, 2, 3));
        assert!(!k.contains(3, 2, 1));
    }
}
