//! Algorithm 8 (paper §4.3): the generic OAC triclustering driver with a
//! pluggable prime operator and validity check.
//!
//! "To get a specific version of the algorithm one only needs to add an
//! appropriate implementation of the prime operator and optional validity
//! check. A tricluster mined from one triple does not depend on
//! triclusters mined from other triples, so, in case of parallel
//! implementation, each triple is processed in an individual thread."

use crate::core::pattern::Cluster;
use crate::core::tuple::NTuple;
use crate::oac::post::{dedup_and_filter, Constraints};
use crate::util::pool;

/// Pluggable prime operator: given the generating triple, produce each
/// tricluster component (`applyPrimeOperator` of Alg. 8). δ-operators
/// (§3.2) need the whole triple, hence the full-tuple signature.
pub trait TriOperator: Sync {
    /// oSet — extent from (m, b) [plus the generating value for δ].
    fn extent(&self, t: &NTuple) -> Vec<u32>;
    /// aSet — intent from (g, b).
    fn intent(&self, t: &NTuple) -> Vec<u32>;
    /// cSet — modus from (g, m).
    fn modus(&self, t: &NTuple) -> Vec<u32>;
}

/// Pluggable validity check (Alg. 8 line 7).
pub trait Validity: Sync {
    /// True when `c` should be kept.
    fn is_valid(&self, c: &Cluster) -> bool;
}

/// Accept-everything validity.
pub struct AlwaysValid;

impl Validity for AlwaysValid {
    fn is_valid(&self, _c: &Cluster) -> bool {
        true
    }
}

/// Run Algorithm 8 sequentially (`workers == 1`) or with per-triple
/// thread-level parallelism (`workers > 1`, §6). Clusters failing the
/// validity check are dropped; survivors are deduplicated with support
/// accumulation and filtered by `constraints`.
pub fn mine<O: TriOperator, V: Validity>(
    triples: &[NTuple],
    op: &O,
    validity: &V,
    constraints: &Constraints,
    workers: usize,
) -> Vec<Cluster> {
    // per-triple independent work — the parallelisation the paper exploits
    let mined: Vec<Option<(Cluster, NTuple)>> =
        pool::parallel_map(triples.len(), workers, 64, |i| {
            let t = triples[i];
            let mut c = Cluster::new(vec![
                op.extent(&t),
                op.intent(&t),
                op.modus(&t),
            ]);
            c.support = 1;
            validity.is_valid(&c).then_some((c, t))
        });
    dedup_and_filter(mined.into_iter().flatten().collect(), constraints)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::context::TriContext;
    use crate::util::hash::FxHashMap;

    /// Binary prime operator backed by fiber indexes — the OAC-prime
    /// instance of Alg. 8 (used here for testing; production paths use
    /// `OnlineMiner`).
    struct PrimeOp {
        mb: FxHashMap<(u32, u32), Vec<u32>>,
        gb: FxHashMap<(u32, u32), Vec<u32>>,
        gm: FxHashMap<(u32, u32), Vec<u32>>,
    }

    impl PrimeOp {
        fn build(ctx: &TriContext) -> Self {
            let mut mb: FxHashMap<(u32, u32), Vec<u32>> = FxHashMap::default();
            let mut gb: FxHashMap<(u32, u32), Vec<u32>> = FxHashMap::default();
            let mut gm: FxHashMap<(u32, u32), Vec<u32>> = FxHashMap::default();
            for t in ctx.triples() {
                let (g, m, b) = (t.get(0), t.get(1), t.get(2));
                mb.entry((m, b)).or_default().push(g);
                gb.entry((g, b)).or_default().push(m);
                gm.entry((g, m)).or_default().push(b);
            }
            Self { mb, gb, gm }
        }
    }

    impl TriOperator for PrimeOp {
        fn extent(&self, t: &NTuple) -> Vec<u32> {
            self.mb[&(t.get(1), t.get(2))].clone()
        }

        fn intent(&self, t: &NTuple) -> Vec<u32> {
            self.gb[&(t.get(0), t.get(2))].clone()
        }

        fn modus(&self, t: &NTuple) -> Vec<u32> {
            self.gm[&(t.get(0), t.get(1))].clone()
        }
    }

    fn sample_ctx() -> TriContext {
        let mut ctx = TriContext::new();
        for (g, m, b) in [(0, 0, 0), (0, 1, 0), (0, 0, 1), (0, 1, 1), (1, 2, 2)] {
            ctx.add(g, m, b);
        }
        ctx
    }

    #[test]
    fn sequential_mines_expected_clusters() {
        let ctx = sample_ctx();
        let op = PrimeOp::build(&ctx);
        let out = mine(ctx.triples(), &op, &AlwaysValid, &Constraints::none(), 1);
        // 4 merged into one + 1 singleton
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].support, 4);
        assert_eq!(out[1].components[0], vec![1]);
    }

    #[test]
    fn parallel_equals_sequential() {
        let ctx = sample_ctx();
        let op = PrimeOp::build(&ctx);
        let seq = mine(ctx.triples(), &op, &AlwaysValid, &Constraints::none(), 1);
        let par = mine(ctx.triples(), &op, &AlwaysValid, &Constraints::none(), 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
    }

    struct MinExtent(usize);

    impl Validity for MinExtent {
        fn is_valid(&self, c: &Cluster) -> bool {
            c.components[0].len() >= self.0
        }
    }

    #[test]
    fn validity_check_filters_before_dedup() {
        let ctx = sample_ctx();
        let op = PrimeOp::build(&ctx);
        let out = mine(ctx.triples(), &op, &MinExtent(2), &Constraints::none(), 1);
        assert!(out.is_empty()); // all extents are singletons here
    }
}
