//! The `HadoopSim` backend: adapts the mini-Hadoop engine
//! ([`crate::hadoop::job`]) to the [`Backend`] contract. A `map_reduce`
//! round runs as ONE fused job — typed records encoded through
//! [`crate::hadoop::record::Record`], hash partitioning, byte-sorted
//! shuffle with optional DFS materialisation, fault injection, counters —
//! and its [`JobStats`] is retained for the virtual cluster clock
//! (Table 4's per-stage breakdown).

use std::marker::PhantomData;
use std::sync::Mutex;

use anyhow::Result;

use super::backend::{group_pairs, Backend, Data, Key};
use crate::hadoop::dfs::{Dfs, DfsConfig};
use crate::hadoop::job::{
    run_job, run_job_with_combiner, Combiner, Emitter, JobConfig, JobStats, Mapper,
    Reducer,
};
use crate::util::pool;
use crate::util::stats::Timer;

/// Closure-to-[`Mapper`] adapter (input arrives as `((), I)` records).
struct FnMapper<I, K, V, F> {
    f: F,
    _types: PhantomData<fn(&I) -> (K, V)>,
}

impl<I, K, V, F> Mapper for FnMapper<I, K, V, F>
where
    I: Data,
    K: Key,
    V: Data,
    F: Fn(&I) -> Vec<(K, V)> + Sync,
{
    type InK = ();
    type InV = I;
    type OutK = K;
    type OutV = V;

    fn map(&self, _key: (), value: I, emit: &mut Emitter<K, V>) {
        for (k, v) in (self.f)(&value) {
            emit.emit(k, v);
        }
    }
}

/// Identity mapper over pre-keyed `((), (K, V))` records — the map phase
/// of a fused `group_reduce` round.
struct PairMapper<K, V> {
    _types: PhantomData<fn(K) -> V>,
}

impl<K, V> Mapper for PairMapper<K, V>
where
    K: Key,
    V: Data,
{
    type InK = ();
    type InV = (K, V);
    type OutK = K;
    type OutV = V;

    fn map(&self, _key: (), pair: (K, V), emit: &mut Emitter<K, V>) {
        emit.emit(pair.0, pair.1);
    }
}

/// Closure-to-[`Reducer`] adapter (outputs travel as `(O, ())` records).
struct FnReducer<K, V, O, F> {
    f: F,
    _types: PhantomData<fn(&K, V) -> O>,
}

impl<K, V, O, F> Reducer for FnReducer<K, V, O, F>
where
    K: Key,
    V: Data,
    O: Data,
    F: Fn(&K, Vec<V>) -> Vec<O> + Sync,
{
    type InK = K;
    type InV = V;
    type OutK = O;
    type OutV = ();

    fn reduce(&self, key: K, values: Vec<V>, emit: &mut Emitter<O, ()>) {
        for o in (self.f)(&key, values) {
            emit.emit(o, ());
        }
    }
}

/// Closure-to-[`Combiner`] adapter.
struct FnCombiner<K, V, F> {
    f: F,
    _types: PhantomData<fn(&K) -> V>,
}

impl<K, V, F> Combiner for FnCombiner<K, V, F>
where
    K: Key,
    V: Data,
    F: Fn(&K, Vec<V>) -> Vec<V> + Sync,
{
    type K = K;
    type V = V;

    fn combine(&self, key: &K, values: Vec<V>) -> Vec<V> {
        (self.f)(key, values)
    }
}

/// Hadoop-style backend: one fused job per `map_reduce` round.
pub struct HadoopSim {
    /// Job template; each round clones it with `name = "<name>-<label>"`.
    cfg: JobConfig,
    dfs: Dfs,
    stats: Mutex<Vec<JobStats>>,
}

impl HadoopSim {
    /// Backend over the given job config and DFS.
    pub fn new(cfg: JobConfig, dfs: Dfs) -> Self {
        Self { cfg, dfs, stats: Mutex::new(Vec::new()) }
    }

    /// Default-tuned instance (in-memory DFS-less shuffle).
    pub fn with_defaults() -> Self {
        let cfg = JobConfig { name: "exec".into(), use_dfs: false, ..JobConfig::default() };
        Self::new(cfg, Dfs::new(DfsConfig::default()))
    }

    /// Drain the per-round [`JobStats`] collected so far, in round order.
    pub fn take_stats(&self) -> Vec<JobStats> {
        std::mem::take(&mut *self.stats.lock().unwrap())
    }
}

impl Backend for HadoopSim {
    fn name(&self) -> &'static str {
        "hadoop"
    }

    /// Map-only job: split into map tasks, no shuffle. Task timings are
    /// recorded so makespans stay comparable.
    fn map_partitions<I, O, F>(&self, label: &str, input: Vec<I>, f: F) -> Result<Vec<O>>
    where
        I: Data,
        O: Data,
        F: Fn(&I) -> Vec<O> + Sync,
    {
        let n = input.len();
        let tasks = self.cfg.map_tasks.max(1).min(n.max(1));
        let per = n.div_ceil(tasks).max(1);
        let splits: Vec<&[I]> = input.chunks(per).collect();
        let outs: Vec<(Vec<O>, f64)> =
            pool::parallel_map(splits.len(), self.cfg.executor_threads, 1, |t| {
                let timer = Timer::start();
                let mut out = Vec::new();
                for item in splits[t] {
                    out.extend(f(item));
                }
                (out, timer.elapsed_ms())
            });
        let mut stats =
            JobStats { name: format!("{}-{label}", self.cfg.name), ..Default::default() };
        let mut result = Vec::new();
        for (o, ms) in outs {
            stats.map_task_ms.push(ms);
            result.extend(o);
        }
        self.stats.lock().unwrap().push(stats);
        Ok(result)
    }

    /// Degenerate shuffle-only round (no job accounting); the fused
    /// `map_reduce` below is the measured path.
    fn group_by_key<K, V>(&self, _label: &str, pairs: Vec<(K, V)>) -> Result<Vec<(K, Vec<V>)>>
    where
        K: Key,
        V: Data,
    {
        Ok(group_pairs(pairs))
    }

    fn reduce<K, V, O, F>(&self, _label: &str, groups: Vec<(K, Vec<V>)>, f: F) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        F: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let mut out = Vec::new();
        for (k, vs) in groups {
            out.extend(f(&k, vs));
        }
        Ok(out)
    }

    /// The fused path: one `hadoop::job` run per round, with the optional
    /// map-side combiner materialised (shuffle-byte savings show up in
    /// the retained [`JobStats`] counters).
    fn map_reduce<I, K, V, O, MF, CF, RF>(
        &self,
        label: &str,
        input: Vec<I>,
        map: MF,
        combine: Option<CF>,
        reduce: RF,
    ) -> Result<Vec<O>>
    where
        I: Data,
        K: Key,
        V: Data,
        O: Data,
        MF: Fn(&I) -> Vec<(K, V)> + Sync,
        CF: Fn(&K, Vec<V>) -> Vec<V> + Sync,
        RF: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let cfg = JobConfig {
            name: format!("{}-{label}", self.cfg.name),
            ..self.cfg.clone()
        };
        // the fused engine replaces the default trait's per-phase spans
        // with ONE job span carrying the shuffle volume
        let mut span = crate::span!("exec.hadoop.{label}");
        span.records_in(input.len() as u64);
        let input: Vec<((), I)> = input.into_iter().map(|v| ((), v)).collect();
        let mapper = FnMapper { f: map, _types: PhantomData };
        let reducer = FnReducer { f: reduce, _types: PhantomData };
        let (out, stats) = match combine {
            Some(cf) => {
                let comb = FnCombiner { f: cf, _types: PhantomData };
                run_job_with_combiner(&cfg, &mapper, Some(&comb), &reducer, input, &self.dfs)?
            }
            None => run_job(&cfg, &mapper, &reducer, input, &self.dfs)?,
        };
        span.records_out(out.len() as u64);
        span.bytes(stats.shuffle_bytes);
        crate::obs::counter("exec.hadoop.jobs", 1);
        self.stats.lock().unwrap().push(stats);
        Ok(out.into_iter().map(|(o, _unit)| o).collect())
    }

    /// Fused shuffle → reduce over pre-keyed pairs: one job with the
    /// identity [`PairMapper`], so the round still produces [`JobStats`]
    /// (task timings, shuffle bytes, counters).
    fn group_reduce<K, V, O, RF>(
        &self,
        label: &str,
        pairs: Vec<(K, V)>,
        reduce: RF,
    ) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        RF: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let cfg = JobConfig {
            name: format!("{}-{label}", self.cfg.name),
            ..self.cfg.clone()
        };
        let mut span = crate::span!("exec.hadoop.{label}");
        span.records_in(pairs.len() as u64);
        let input: Vec<((), (K, V))> = pairs.into_iter().map(|p| ((), p)).collect();
        let mapper = PairMapper { _types: PhantomData };
        let reducer = FnReducer { f: reduce, _types: PhantomData };
        let (out, stats) = run_job(&cfg, &mapper, &reducer, input, &self.dfs)?;
        span.records_out(out.len() as u64);
        span.bytes(stats.shuffle_bytes);
        crate::obs::counter("exec.hadoop.jobs", 1);
        self.stats.lock().unwrap().push(stats);
        Ok(out.into_iter().map(|(o, _unit)| o).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::no_combine;
    use super::*;

    fn word_count(backend: &HadoopSim) -> Vec<(String, u64)> {
        let input: Vec<String> = vec!["a b a".into(), "b c".into(), "a".into()];
        let mut out = backend
            .map_reduce(
                "wc",
                input,
                |line: &String| {
                    line.split_whitespace().map(|w| (w.to_string(), 1u64)).collect()
                },
                no_combine::<String, u64>(),
                |w: &String, ones: Vec<u64>| vec![(w.clone(), ones.iter().sum())],
            )
            .unwrap();
        out.sort();
        out
    }

    #[test]
    fn fused_round_matches_wordcount() {
        let backend = HadoopSim::with_defaults();
        let out = word_count(&backend);
        assert_eq!(
            out,
            vec![("a".to_string(), 3), ("b".to_string(), 2), ("c".to_string(), 1)]
        );
        let stats = backend.take_stats();
        assert_eq!(stats.len(), 1);
        assert!(stats[0].shuffle_bytes > 0);
        assert!(backend.take_stats().is_empty(), "stats drained");
    }

    #[test]
    fn fn_adapters_emit_through_the_engine_emitter() {
        // unit-test the closure adapters directly via the engine's test
        // emitter (the same harness the old per-stage structs used)
        let mapper = FnMapper {
            f: |&x: &u32| vec![(x % 2, x)],
            _types: PhantomData,
        };
        let mut emit = Emitter::new_for_test();
        mapper.map((), 7u32, &mut emit);
        assert_eq!(emit.into_pairs(), vec![(1u32, 7u32)]);

        let reducer = FnReducer {
            f: |k: &u32, vs: Vec<u32>| vec![*k + vs.len() as u32],
            _types: PhantomData,
        };
        let mut emit = Emitter::new_for_test();
        reducer.reduce(3u32, vec![1, 2], &mut emit);
        assert_eq!(emit.into_pairs(), vec![(5u32, ())]);
    }

    #[test]
    fn map_only_round_records_task_timings() {
        let backend = HadoopSim::with_defaults();
        let doubled: Vec<u32> = backend
            .map_partitions("x2", (0..100u32).collect(), |&x| vec![x * 2])
            .unwrap();
        assert_eq!(doubled, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        let stats = backend.take_stats();
        assert_eq!(stats.len(), 1);
        assert!(!stats[0].map_task_ms.is_empty());
        assert!(stats[0].reduce_task_ms.is_empty());
    }
}
