//! The multimodal clustering pipeline on the Spark-like engine — the
//! paper's §7 expectation, executable. This is now just the
//! backend-generic stage functions ([`crate::exec::stages`]) bound to
//! [`crate::exec::SparkSim`]: the same Algorithms 2–7, with each stage
//! running as ONE fused RDD lineage (narrow map → wide shuffle → narrow
//! reduce, all in memory). Exactly three wide shuffles run; stage
//! boundaries hand a `Vec` between the backend-generic stage functions,
//! which stands in for Spark's driver-side stage barrier.

use crate::core::context::PolyContext;
use crate::core::pattern::Cluster;
use crate::exec::{run_pipeline, SparkSim};
use crate::spark::rdd::SparkContext;

/// Result mirror of `mmc::MmcResult` for the Spark-like engine.
pub struct SparkMmcResult {
    /// The final cluster set.
    pub clusters: Vec<Cluster>,
    /// Total wall time, ms.
    pub wall_ms: f64,
}

/// Run the pipeline. `theta` is the density threshold of Alg. 7.
pub fn run_mmc_spark(
    sc: &SparkContext,
    ctx: &PolyContext,
    theta: f64,
) -> SparkMmcResult {
    let timer = crate::util::stats::Timer::start();
    let clusters = run_pipeline(&SparkSim::new(sc), ctx, theta, false)
        .expect("the in-memory spark-sim backend is infallible");
    SparkMmcResult { clusters, wall_ms: timer.elapsed_ms() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synthetic::{k1, k2, k3};
    use crate::mmc::{run_mmc, MmcConfig};

    fn sc() -> SparkContext {
        SparkContext::new(8, crate::util::pool::default_workers())
    }

    #[test]
    fn spark_matches_hadoop_on_k2() {
        let ctx = k2(5).inner;
        let spark = run_mmc_spark(&sc(), &ctx, 0.0);
        let hadoop = run_mmc(&ctx, &MmcConfig::default()).unwrap();
        assert_eq!(spark.clusters.len(), hadoop.clusters.len());
        for (a, b) in spark.clusters.iter().zip(&hadoop.clusters) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
    }

    #[test]
    fn spark_matches_hadoop_on_k1_with_theta() {
        let ctx = k1(6).inner;
        let spark = run_mmc_spark(&sc(), &ctx, 0.9);
        let hadoop =
            run_mmc(&ctx, &MmcConfig { theta: 0.9, ..MmcConfig::default() }).unwrap();
        assert_eq!(spark.clusters.len(), hadoop.clusters.len());
    }

    #[test]
    fn spark_k3_single_cluster() {
        let spark = run_mmc_spark(&sc(), &k3(5), 0.0);
        assert_eq!(spark.clusters.len(), 1);
        assert_eq!(spark.clusters[0].support, 625);
    }

    #[test]
    fn stage_log_has_three_shuffles() {
        let ctx = k2(4).inner;
        let s = sc();
        let _ = run_mmc_spark(&s, &ctx, 0.0);
        let log = s.stage_log.lock().unwrap();
        let wide = log.iter().filter(|(l, _)| l.contains("shuffle")).count();
        assert_eq!(wide, 3);
    }
}
