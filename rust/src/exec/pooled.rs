//! The `Pooled` backend: language-level parallelism (paper §6) on the
//! scoped worker pool of [`crate::util::pool`] — the same substrate the
//! parallel NOAC and the serving layer's drain waves run on.
//!
//! Map and reduce phases are chunked dynamic-scheduled parallel loops;
//! the shuffle is a serial hash grouping (mirroring the serving router,
//! where only the per-shard concat sits on the serial path). Results are
//! deterministic for every worker count: chunk outputs are concatenated
//! in index order and groups are enumerated in key order.

use std::sync::Mutex;

use anyhow::Result;

use super::backend::{group_pairs, Backend, Data, Key};
use crate::util::pool;

/// Thread-pool backend over `util::pool`.
#[derive(Debug, Clone)]
pub struct Pooled {
    /// Worker threads for the map and reduce phases.
    pub workers: usize,
}

impl Pooled {
    /// Backend over `workers` pool threads (min 1).
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// Chunk size for `n` items: enough chunks to balance skew (~8 per
    /// worker), capped so tiny inputs stay single-chunk-per-item.
    fn chunk(&self, n: usize, cap: usize) -> usize {
        (n / (self.workers * 8)).clamp(1, cap)
    }
}

impl Backend for Pooled {
    fn name(&self) -> &'static str {
        "pool"
    }

    fn map_partitions<I, O, F>(&self, _label: &str, input: Vec<I>, f: F) -> Result<Vec<O>>
    where
        I: Data,
        O: Data,
        F: Fn(&I) -> Vec<O> + Sync,
    {
        let n = input.len();
        let chunk = self.chunk(n, 1024);
        let outs: Vec<Vec<O>> =
            pool::parallel_map(n, self.workers, chunk, |i| f(&input[i]));
        Ok(outs.into_iter().flatten().collect())
    }

    fn group_by_key<K, V>(&self, _label: &str, pairs: Vec<(K, V)>) -> Result<Vec<(K, Vec<V>)>>
    where
        K: Key,
        V: Data,
    {
        Ok(group_pairs(pairs))
    }

    fn reduce<K, V, O, F>(&self, _label: &str, groups: Vec<(K, Vec<V>)>, f: F) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        F: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let n = groups.len();
        let chunk = self.chunk(n, 64);
        // hand each task exclusive ownership of its group (the rdd idiom)
        let slots: Vec<Mutex<Option<(K, Vec<V>)>>> =
            groups.into_iter().map(|g| Mutex::new(Some(g))).collect();
        let outs: Vec<Vec<O>> = pool::parallel_map(n, self.workers, chunk, |i| {
            let (k, vs) = slots[i].lock().unwrap().take().expect("taken once");
            f(&k, vs)
        });
        Ok(outs.into_iter().flatten().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::no_combine;
    use super::*;

    fn histogram(workers: usize) -> Vec<(u32, u32)> {
        let input: Vec<u32> = (0..5_000).collect();
        Pooled::new(workers)
            .map_reduce(
                "hist",
                input,
                |&x: &u32| vec![(x % 13, 1u32)],
                no_combine::<u32, u32>(),
                |k: &u32, vs: Vec<u32>| vec![(*k, vs.iter().sum())],
            )
            .unwrap()
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let baseline = histogram(1);
        assert_eq!(baseline.len(), 13);
        assert_eq!(baseline.iter().map(|&(_, c)| c).sum::<u32>(), 5_000);
        for workers in [2, 3, 8] {
            assert_eq!(histogram(workers), baseline, "workers={workers}");
        }
    }

    #[test]
    fn empty_input() {
        let out: Vec<(u32, u32)> = Pooled::new(4)
            .map_reduce(
                "empty",
                Vec::<u32>::new(),
                |&x: &u32| vec![(x, x)],
                no_combine::<u32, u32>(),
                |k: &u32, _vs: Vec<u32>| vec![(*k, 0)],
            )
            .unwrap();
        assert!(out.is_empty());
    }
}
