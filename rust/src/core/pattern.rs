//! Multimodal clusters (triclusters for N=3): the output patterns.
//!
//! A pattern is a tuple of entity-id sets, one per modality, plus the
//! bookkeeping the evaluation needs: how many generating tuples produced
//! it (the paper's exact density numerator in the third reduce) and the
//! volume.

use crate::util::hash::set_fingerprint;

/// A multimodal cluster `(X_1, …, X_N)`; components are sorted id vectors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Cluster {
    /// One sorted, deduplicated id set per modality.
    pub components: Vec<Vec<u32>>,
    /// Number of distinct generating tuples that produced this cluster
    /// (filled by dedup / the third reduce).
    pub support: usize,
}

impl Cluster {
    /// Cluster over the given components (each sorted + deduped;
    ///  `support` starts at 1).
    pub fn new(mut components: Vec<Vec<u32>>) -> Self {
        for c in components.iter_mut() {
            c.sort_unstable();
            c.dedup();
        }
        Self { components, support: 1 }
    }

    /// Cluster over components that are ALREADY sorted and deduplicated
    /// (debug-asserted) — the §Perf constructor for materialised cumuli
    /// (the arena and the stage-1 reduce both emit sorted sets), skipping
    /// [`Cluster::new`]'s re-sort of every component.
    pub fn from_sorted(components: Vec<Vec<u32>>) -> Self {
        debug_assert!(
            components.iter().all(|c| c.windows(2).all(|w| w[0] < w[1])),
            "from_sorted requires strictly sorted, deduplicated components"
        );
        Self { components, support: 1 }
    }

    /// Number of modalities.
    pub fn arity(&self) -> usize {
        self.components.len()
    }

    /// Cuboid volume Π|X_k| as f64 (may exceed u64 for wide patterns).
    pub fn volume(&self) -> f64 {
        self.components.iter().map(|c| c.len() as f64).product()
    }

    /// Paper's M/R density: generating-tuple count over volume
    /// (Algorithm 7). A lower bound on the true cuboid density.
    pub fn support_density(&self) -> f64 {
        let v = self.volume();
        if v == 0.0 {
            0.0
        } else {
            self.support as f64 / v
        }
    }

    /// Content fingerprint for duplicate elimination: clusters with equal
    /// components collide regardless of generating triple or element order.
    pub fn fingerprint(&self) -> u64 {
        combine_set_fingerprints(
            self.arity(),
            self.components.iter().map(|c| set_fingerprint(c)),
        )
    }

    /// Minimal cardinality over all modalities (minsup constraint, §4.3).
    pub fn min_cardinality(&self) -> usize {
        self.components.iter().map(Vec::len).min().unwrap_or(0)
    }
}

/// Triadic convenience constructor: (extent, intent, modus).
pub fn tricluster(extent: Vec<u32>, intent: Vec<u32>, modus: Vec<u32>) -> Cluster {
    Cluster::new(vec![extent, intent, modus])
}

/// Fold per-component set fingerprints into one cluster content
/// fingerprint — THE hashing scheme shared by [`Cluster::fingerprint`],
/// the online miner's memoized dedup, and the basic algorithm's
/// no-materialisation dedup. Keep every dedup path on this helper so a
/// future tuning of the scheme cannot silently diverge between them.
pub fn combine_set_fingerprints(
    arity: usize,
    set_fps: impl Iterator<Item = u64>,
) -> u64 {
    let mut acc = 0xABCD_EF01_2345_6789u64 ^ (arity as u64);
    for fp in set_fps {
        acc = acc.rotate_left(17).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ fp;
    }
    acc
}

/// Sort a cluster set into its canonical (component-lexicographic) order.
pub fn sort_clusters(clusters: &mut [Cluster]) {
    clusters.sort_by(|a, b| a.components.cmp(&b.components));
}

/// First difference between two canonically-ordered cluster sets, or
/// `None` when they are identical — THE equivalence predicate every
/// backend/shard gate shares (exec unit tests, the backend-equivalence
/// property test, `benches/backend_matrix.rs`, and the `backends`
/// experiment). Components and supports are compared; support density is
/// derived from both, so it cannot diverge independently.
pub fn diff_cluster_sets(a: &[Cluster], b: &[Cluster]) -> Option<String> {
    if a.len() != b.len() {
        return Some(format!("{} vs {} clusters", a.len(), b.len()));
    }
    for (x, y) in a.iter().zip(b) {
        if x.components != y.components {
            return Some(format!(
                "components differ: {:?} vs {:?}",
                x.components, y.components
            ));
        }
        if x.support != y.support {
            return Some(format!(
                "support differs on {:?}: {} vs {}",
                x.components, x.support, y.support
            ));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::assert_prop;

    #[test]
    fn components_sorted_deduped() {
        let c = Cluster::new(vec![vec![3, 1, 3], vec![2], vec![5, 4]]);
        assert_eq!(c.components[0], vec![1, 3]);
        assert_eq!(c.components[2], vec![4, 5]);
    }

    #[test]
    fn from_sorted_preserves_components() {
        let c = Cluster::from_sorted(vec![vec![1, 3], vec![2], vec![4, 5]]);
        assert_eq!(c, Cluster::new(vec![vec![3, 1], vec![2], vec![5, 4]]));
        assert_eq!(c.support, 1);
    }

    #[test]
    fn volume_and_density() {
        let mut c = tricluster(vec![0, 1], vec![0, 1, 2], vec![0]);
        assert_eq!(c.volume(), 6.0);
        c.support = 3;
        assert!((c.support_density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fingerprint_ignores_order_not_content() {
        let a = tricluster(vec![1, 2], vec![3], vec![4]);
        let b = tricluster(vec![2, 1], vec![3], vec![4]);
        let c = tricluster(vec![1, 2], vec![4], vec![3]); // swapped modalities
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn empty_component_zero_volume() {
        let c = tricluster(vec![], vec![1], vec![2]);
        assert_eq!(c.volume(), 0.0);
        assert_eq!(c.support_density(), 0.0);
        assert_eq!(c.min_cardinality(), 0);
    }

    #[test]
    fn prop_fingerprint_stable_under_shuffle() {
        assert_prop(128, |g| {
            let xs = g.id_set(50);
            let ys = g.id_set(50);
            let zs = g.id_set(50);
            let a = tricluster(xs.clone(), ys.clone(), zs.clone());
            let mut xs2 = xs;
            xs2.reverse();
            let b = tricluster(xs2, ys, zs);
            if a.fingerprint() == b.fingerprint() {
                Ok(())
            } else {
                Err("fingerprint depends on order".into())
            }
        });
    }
}
