//! Query API over a compacted cluster index: top-k by density,
//! membership lookup, and aggregate stats.
//!
//! A [`QueryEngine`] borrows one compacted snapshot (`&[Cluster]`) and
//! builds a `(modality, entity) → clusters` inverted index once, so the
//! membership query the north-star cares about ("clusters containing
//! entity e in modality m" — the recommendation lookup) is a single hash
//! probe instead of a scan over every cluster's components.

use crate::core::pattern::Cluster;
use crate::util::hash::FxHashMap;

/// Aggregate statistics of a compacted index.
#[derive(Debug, Clone, PartialEq)]
pub struct IndexStats {
    /// Clusters in the snapshot.
    pub clusters: usize,
    /// Σ support (= tuples ingested, when no constraints filter).
    pub total_support: usize,
    /// Mean support-density.
    pub mean_density: f64,
    /// Largest support-density.
    pub max_density: f64,
    /// Largest single-modality component cardinality.
    pub max_component: usize,
}

/// Read-only query surface over one compacted snapshot.
#[derive(Debug)]
pub struct QueryEngine<'a> {
    clusters: &'a [Cluster],
    /// (modality, entity id) → indices into `clusters`.
    member: FxHashMap<(u8, u32), Vec<u32>>,
}

impl<'a> QueryEngine<'a> {
    /// Build the inverted membership index over one snapshot.
    pub fn new(clusters: &'a [Cluster]) -> Self {
        let mut span = crate::span!("serve.query.build");
        span.records_in(clusters.len() as u64);
        let mut member: FxHashMap<(u8, u32), Vec<u32>> = FxHashMap::default();
        // upper bound on distinct (modality, entity) pairs — a pair is
        // counted once per containing cluster, so overlapping snapshots
        // over-reserve; this trades transient memory for zero rehashes
        member.reserve(
            clusters
                .iter()
                .map(|c| c.components.iter().map(Vec::len).sum::<usize>())
                .sum(),
        );
        for (i, c) in clusters.iter().enumerate() {
            for (m, comp) in c.components.iter().enumerate() {
                for &e in comp {
                    member.entry((m as u8, e)).or_default().push(i as u32);
                }
            }
        }
        Self { clusters, member }
    }

    /// Clusters in the snapshot.
    pub fn len(&self) -> usize {
        self.clusters.len()
    }

    /// True when the snapshot has no clusters.
    pub fn is_empty(&self) -> bool {
        self.clusters.is_empty()
    }

    /// The k densest clusters (support-density, ties broken by support
    /// then components, so the ranking is total and deterministic).
    /// Selects the top k in O(n) before sorting only those k.
    pub fn top_k_by_density(&self, k: usize) -> Vec<&'a Cluster> {
        let _span = crate::span!("serve.query.top_k");
        let cs = self.clusters;
        let mut idx: Vec<usize> = (0..cs.len()).collect();
        let k = k.min(idx.len());
        if k == 0 {
            return Vec::new();
        }
        let mut rank = |&a: &usize, &b: &usize| {
            cs[b].support_density()
                .total_cmp(&cs[a].support_density())
                .then(cs[b].support.cmp(&cs[a].support))
                .then(cs[a].components.cmp(&cs[b].components))
        };
        if k < idx.len() {
            idx.select_nth_unstable_by(k - 1, &mut rank);
            idx.truncate(k);
        }
        idx.sort_unstable_by(&mut rank);
        idx.into_iter().map(|i| &cs[i]).collect()
    }

    /// Every cluster whose modality-`m` component contains `entity`, in
    /// index order.
    pub fn containing(&self, modality: usize, entity: u32) -> Vec<&'a Cluster> {
        let _span = crate::span!("serve.query.containing");
        let cs = self.clusters;
        match self.member.get(&(modality as u8, entity)) {
            Some(ids) => ids.iter().map(|&i| &cs[i as usize]).collect(),
            None => Vec::new(),
        }
    }

    /// Support and density of the clusters containing `(modality,
    /// entity)` — the per-entity serving stats.
    pub fn entity_stats(&self, modality: usize, entity: u32) -> Option<IndexStats> {
        let hits = self.containing(modality, entity);
        if hits.is_empty() {
            None
        } else {
            Some(stats_of(hits.iter().copied()))
        }
    }

    /// Aggregate stats over the whole snapshot (no intermediate
    /// collection — the stats fold streams over the clusters).
    pub fn stats(&self) -> IndexStats {
        stats_of(self.clusters.iter())
    }
}

fn stats_of<'c>(clusters: impl Iterator<Item = &'c Cluster>) -> IndexStats {
    let mut n = 0usize;
    let mut total_support = 0usize;
    let mut mean_density = 0.0;
    let mut max_density = 0.0f64;
    let mut max_component = 0usize;
    for c in clusters {
        n += 1;
        total_support += c.support;
        let d = c.support_density();
        mean_density += d;
        max_density = max_density.max(d);
        max_component =
            max_component.max(c.components.iter().map(Vec::len).max().unwrap_or(0));
    }
    if n > 0 {
        mean_density /= n as f64;
    }
    IndexStats { clusters: n, total_support, mean_density, max_density, max_component }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;

    fn fixture() -> Vec<Cluster> {
        // densities: a = 1.0 (support 4 / volume 4), b = 0.5 (2/4),
        // c = 1.0 (1/1)
        let mut a = tricluster(vec![0], vec![0, 1], vec![0, 1]);
        a.support = 4;
        let mut b = tricluster(vec![1, 2], vec![0], vec![0, 1]);
        b.support = 2;
        let mut c = tricluster(vec![5], vec![5], vec![5]);
        c.support = 1;
        vec![a, b, c]
    }

    #[test]
    fn top_k_orders_by_density_then_support() {
        let cs = fixture();
        let q = QueryEngine::new(&cs);
        let top = q.top_k_by_density(2);
        assert_eq!(top.len(), 2);
        // both density-1.0 clusters lead; support 4 beats support 1
        assert_eq!(top[0].components[0], vec![0]);
        assert_eq!(top[1].components[0], vec![5]);
        // k larger than the index is clamped
        assert_eq!(q.top_k_by_density(10).len(), 3);
    }

    #[test]
    fn membership_lookup() {
        let cs = fixture();
        let q = QueryEngine::new(&cs);
        // entity 0 in modality 1 appears in clusters a and b
        let hits = q.containing(1, 0);
        assert_eq!(hits.len(), 2);
        // entity 2 in modality 0 appears only in b
        let hits = q.containing(0, 2);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].support, 2);
        // absent entity
        assert!(q.containing(2, 99).is_empty());
        assert!(q.entity_stats(2, 99).is_none());
    }

    #[test]
    fn stats_aggregate() {
        let cs = fixture();
        let q = QueryEngine::new(&cs);
        let s = q.stats();
        assert_eq!(s.clusters, 3);
        assert_eq!(s.total_support, 7);
        assert_eq!(s.max_density, 1.0);
        assert!((s.mean_density - (1.0 + 0.5 + 1.0) / 3.0).abs() < 1e-12);
        assert_eq!(s.max_component, 2);
        let es = q.entity_stats(0, 5).unwrap();
        assert_eq!(es.clusters, 1);
        assert_eq!(es.total_support, 1);
    }
}
