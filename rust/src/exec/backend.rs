//! The [`Backend`] trait — the execution substrate contract.
//!
//! A backend runs one map → shuffle → reduce round over typed records.
//! The algorithm layer ([`crate::exec::stages`]) is written once against
//! this trait; the five implementations differ only in *how* the round is
//! executed:
//!
//! | backend                | map phase            | shuffle              | reduce phase         |
//! |------------------------|----------------------|----------------------|----------------------|
//! | [`Sequential`]         | in-order loop        | hash group + sort    | in-order loop        |
//! | [`Pooled`]             | `util::pool` chunks  | hash group + sort    | `util::pool` chunks  |
//! | [`HadoopSim`]          | map tasks + faults   | DFS-materialised     | reduce tasks         |
//! | [`SparkSim`]           | narrow RDD op        | in-memory wide op    | narrow RDD op        |
//! | [`ClusterSim`]         | placed sim tasks     | hash group + barrier | placed sim tasks     |
//!
//! `ClusterSim` additionally simulates multi-node placement, stragglers,
//! failures, and speculative execution on a virtual clock (see
//! [`crate::exec::cluster_sim`]).
//!
//! Record bounds are the union of what the engines need: the Hadoop-style
//! engine serialises everything through [`crate::hadoop::record::Record`],
//! the Spark-like engine hash-partitions keys, and the deterministic
//! group order relies on `Ord`.
//!
//! [`Sequential`]: crate::exec::Sequential
//! [`Pooled`]: crate::exec::Pooled
//! [`HadoopSim`]: crate::exec::HadoopSim
//! [`SparkSim`]: crate::exec::SparkSim
//! [`ClusterSim`]: crate::exec::ClusterSim

use anyhow::Result;

use crate::hadoop::record::Record;
use crate::util::hash::FxHashMap;

/// Any value that can travel through a backend: serialisable for the
/// Hadoop-style shuffle, and shareable across worker threads.
pub trait Data: Record + Send + Sync + Clone + 'static {}

impl<T: Record + Send + Sync + Clone + 'static> Data for T {}

/// A shuffle key: [`Data`] plus hashing (Spark-style partitioning) and a
/// total order (deterministic group enumeration).
pub trait Key: Data + std::hash::Hash + Eq + Ord {}

impl<T: Data + std::hash::Hash + Eq + Ord> Key for T {}

/// A typed `None` for [`Backend::map_reduce`]'s combiner slot.
pub fn no_combine<K, V>() -> Option<fn(&K, Vec<V>) -> Vec<V>> {
    None
}

/// Group a pair list by key, deterministically: values keep their input
/// order within a key, groups are sorted by key. Shared by the in-memory
/// backends (the Hadoop engine groups by encoded-byte sort instead).
pub fn group_pairs<K: Key, V>(pairs: Vec<(K, V)>) -> Vec<(K, Vec<V>)> {
    let mut groups: FxHashMap<K, Vec<V>> = FxHashMap::default();
    for (k, v) in pairs {
        groups.entry(k).or_default().push(v);
    }
    let mut out: Vec<(K, Vec<V>)> = groups.into_iter().collect();
    out.sort_unstable_by(|a, b| a.0.cmp(&b.0));
    out
}

/// Fast-path grouping for pairs whose keys are ALREADY in ascending
/// order (equal keys adjacent): one O(n) adjacent-run scan — no hash
/// map, no O(n log n) key sort. Produces exactly what [`group_pairs`]
/// would: groups in key order, values in input order within a key.
///
/// Caller contract: `pairs` is sorted by key (checked with
/// `debug_assert!`). [`sorted_by_key`] is the cheap runtime test.
pub fn group_pairs_presorted<K: Key, V>(pairs: Vec<(K, V)>) -> Vec<(K, Vec<V>)> {
    debug_assert!(sorted_by_key(&pairs), "group_pairs_presorted needs sorted keys");
    let mut out: Vec<(K, Vec<V>)> = Vec::new();
    for (k, v) in pairs {
        if out.last().is_some_and(|(last, _)| *last == k) {
            out.last_mut().expect("just checked").1.push(v);
        } else {
            out.push((k, vec![v]));
        }
    }
    out
}

/// O(n) check whether a pair list is already key-sorted (the
/// [`group_pairs_presorted`] precondition).
pub fn sorted_by_key<K: Key, V>(pairs: &[(K, V)]) -> bool {
    pairs.windows(2).all(|w| w[0].0 <= w[1].0)
}

/// A pluggable execution substrate: three primitives (`map_partitions`,
/// `group_by_key`, `reduce`) plus the composed `map_reduce` round the
/// stage functions call. Engines with a fused job pipeline (HadoopSim)
/// override `map_reduce`; the rest inherit the composition.
///
/// # Example
///
/// One map → shuffle → reduce round (word count) on the reference
/// backend; swapping [`Sequential`] for any other implementation
/// produces the identical result:
///
/// ```
/// use tricluster::exec::{no_combine, Backend, Sequential};
///
/// let lines: Vec<String> = vec!["a b a".into(), "b".into()];
/// let counts: Vec<(String, u64)> = Sequential
///     .map_reduce(
///         "wc",
///         lines,
///         |line: &String| {
///             line.split_whitespace().map(|w| (w.to_string(), 1u64)).collect()
///         },
///         no_combine::<String, u64>(),
///         |word: &String, ones: Vec<u64>| vec![(word.clone(), ones.iter().sum())],
///     )
///     .unwrap();
/// assert_eq!(counts, vec![("a".into(), 2), ("b".into(), 2)]);
/// ```
pub trait Backend {
    /// Short backend id (`seq` / `pool` / `hadoop` / `spark`).
    fn name(&self) -> &'static str;

    /// Elementwise flat-map over the dataset (the map phase / a narrow
    /// transformation). Output order is deterministic for a fixed
    /// backend and config, but only Sequential/Pooled/HadoopSim preserve
    /// input order; SparkSim returns partition-major order. Callers that
    /// need stream order (the serve router) must run on an
    /// order-preserving backend.
    fn map_partitions<I, O, F>(&self, label: &str, input: Vec<I>, f: F) -> Result<Vec<O>>
    where
        I: Data,
        O: Data,
        F: Fn(&I) -> Vec<O> + Sync;

    /// The shuffle: group pairs by key. Group enumeration order is
    /// backend-specific (in-memory backends sort by key; the engine
    /// adapters follow partition order), so reduce logic must not depend
    /// on it — pipeline outputs are canonicalised by a final sort.
    fn group_by_key<K, V>(&self, label: &str, pairs: Vec<(K, V)>) -> Result<Vec<(K, Vec<V>)>>
    where
        K: Key,
        V: Data;

    /// Per-group reduce (the reduce phase). Output order follows group
    /// order.
    fn reduce<K, V, O, F>(&self, label: &str, groups: Vec<(K, Vec<V>)>, f: F) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        F: Fn(&K, Vec<V>) -> Vec<O> + Sync;

    /// One full map → shuffle → reduce round.
    ///
    /// `combine` is the optional map-side combiner (Hadoop's
    /// `setCombinerClass`): it must be safe to apply 0..n times per key
    /// group. The composed default applies it zero times — map-side
    /// combining is a *physical* optimisation that only the fused
    /// HadoopSim engine materialises (and measures, via shuffle-byte
    /// counters); results are identical either way.
    fn map_reduce<I, K, V, O, MF, CF, RF>(
        &self,
        label: &str,
        input: Vec<I>,
        map: MF,
        combine: Option<CF>,
        reduce: RF,
    ) -> Result<Vec<O>>
    where
        I: Data,
        K: Key,
        V: Data,
        O: Data,
        MF: Fn(&I) -> Vec<(K, V)> + Sync,
        CF: Fn(&K, Vec<V>) -> Vec<V> + Sync,
        RF: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let _ = combine;
        let mut round = crate::span!("exec.{}.{label}", self.name());
        round.records_in(input.len() as u64);
        let pairs = {
            let mut s = crate::span!("exec.{}.{label}-map", self.name());
            s.records_in(input.len() as u64);
            let pairs = self.map_partitions(&format!("{label}-map"), input, map)?;
            s.records_out(pairs.len() as u64);
            pairs
        };
        let groups = {
            let mut s = crate::span!("exec.{}.{label}-shuffle", self.name());
            s.records_in(pairs.len() as u64);
            let groups = self.group_by_key(&format!("{label}-shuffle"), pairs)?;
            s.records_out(groups.len() as u64);
            groups
        };
        let mut s = crate::span!("exec.{}.{label}-reduce", self.name());
        s.records_in(groups.len() as u64);
        let out = self.reduce(&format!("{label}-reduce"), groups, reduce)?;
        s.records_out(out.len() as u64);
        drop(s);
        round.records_out(out.len() as u64);
        Ok(out)
    }

    /// A shuffle → reduce round over PRE-KEYED pairs (no map phase): the
    /// input moves straight into the shuffle, so no backend pays an
    /// identity-map clone. Already-key-sorted input (detected with one
    /// O(n) scan) skips the hash-group + O(n log n) key sort entirely
    /// via [`group_pairs_presorted`]. Fused engines (HadoopSim) override
    /// this with an identity-mapper job to keep their per-round
    /// accounting.
    fn group_reduce<K, V, O, RF>(
        &self,
        label: &str,
        pairs: Vec<(K, V)>,
        reduce: RF,
    ) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        RF: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let mut round = crate::span!("exec.{}.{label}", self.name());
        round.records_in(pairs.len() as u64);
        let groups = {
            let mut s = crate::span!("exec.{}.{label}-shuffle", self.name());
            s.records_in(pairs.len() as u64);
            let groups = if sorted_by_key(&pairs) {
                crate::obs::counter("exec.shuffle.presorted_fast_path", 1);
                group_pairs_presorted(pairs)
            } else {
                self.group_by_key(&format!("{label}-shuffle"), pairs)?
            };
            s.records_out(groups.len() as u64);
            groups
        };
        let mut s = crate::span!("exec.{}.{label}-reduce", self.name());
        s.records_in(groups.len() as u64);
        let out = self.reduce(&format!("{label}-reduce"), groups, reduce)?;
        s.records_out(out.len() as u64);
        drop(s);
        round.records_out(out.len() as u64);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_pairs_sorts_keys_and_keeps_value_order() {
        let pairs = vec![(2u32, 10u32), (1, 20), (2, 30), (1, 40)];
        let grouped = group_pairs(pairs);
        assert_eq!(grouped, vec![(1, vec![20, 40]), (2, vec![10, 30])]);
    }

    #[test]
    fn no_combine_is_none() {
        assert!(no_combine::<u32, u32>().is_none());
    }

    #[test]
    fn presorted_grouping_matches_group_pairs() {
        let pairs = vec![(1u32, 20u32), (1, 40), (2, 10), (2, 30), (5, 1)];
        assert!(sorted_by_key(&pairs));
        assert_eq!(group_pairs_presorted(pairs.clone()), group_pairs(pairs));
    }

    #[test]
    fn presorted_grouping_keeps_value_order_and_handles_edges() {
        assert_eq!(
            group_pairs_presorted(Vec::<(u32, u32)>::new()),
            Vec::<(u32, Vec<u32>)>::new()
        );
        assert_eq!(group_pairs_presorted(vec![(3u32, 9u32)]), vec![(3, vec![9])]);
    }

    #[test]
    fn sortedness_check_detects_unsorted() {
        assert!(sorted_by_key(&[(1u32, 0u32), (1, 1), (2, 2)]));
        assert!(!sorted_by_key(&[(2u32, 0u32), (1, 1)]));
        assert!(sorted_by_key(&[] as &[(u32, u32)]));
    }

    #[test]
    fn default_group_reduce_fast_path_agrees_with_slow_path() {
        use crate::exec::Sequential;
        let sorted_in = vec![(1u32, 1u32), (1, 2), (2, 3)];
        let shuffled = vec![(2u32, 3u32), (1, 1), (1, 2)];
        let sum = |k: &u32, vs: Vec<u32>| vec![(*k, vs.iter().sum::<u32>())];
        let fast = Sequential.group_reduce("t", sorted_in, sum).unwrap();
        let slow = Sequential.group_reduce("t", shuffled, sum).unwrap();
        assert_eq!(fast, vec![(1, 3), (2, 3)]);
        assert_eq!(fast, slow);
    }
}
