//! FxHash (the rustc hash): fast non-cryptographic hashing for the hot
//! prime-set dictionaries and shuffle partitioner. `std`'s SipHash is
//! safe-by-default but ~3-4x slower on the small fixed-width keys
//! ((u32, u32) pairs, entity ids) that dominate OAC-triclustering.

use std::hash::{BuildHasherDefault, Hash, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The rustc-FxHash mixing function.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// `BuildHasher` for FxHash-keyed collections.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
/// `HashMap` keyed by FxHash (the repo's default map).
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
/// `HashSet` keyed by FxHash (the repo's default set).
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;

/// Hash any `Hash` value with FxHash — used for tricluster dedup keys and
/// the M/R partitioner.
pub fn fxhash<T: Hash>(value: &T) -> u64 {
    let mut h = FxHasher::default();
    value.hash(&mut h);
    h.finish()
}

/// SplitMix64 finalizer — a full-avalanche 64-bit mixer. Public because
/// the §Perf probe dictionary (`crate::oac::primes`) hashes its packed
/// subrelation keys through it in a branch-free batch loop.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Lane width of [`set_fingerprint_batched`]. Eight independent u64
/// accumulator pairs fill two AVX2 registers; the per-lane loop body has
/// no cross-lane dependency, so the compiler can vectorise it.
const FP_LANES: usize = 8;

/// Order-independent 64-bit combination for set fingerprints: the dedup
/// key of a tricluster must not depend on element order. Each element is
/// avalanched independently (so no id maps to an absorbing value) and the
/// sums are bound to the set length through a second mix.
pub fn set_fingerprint(ids: &[u32]) -> u64 {
    let mut sum: u64 = 0;
    let mut xor: u64 = 0;
    for &id in ids {
        let e = mix64(id as u64 + 1);
        sum = sum.wrapping_add(e);
        xor ^= e.rotate_left(23);
    }
    mix64(sum ^ (ids.len() as u64)).wrapping_add(xor)
}

/// [`set_fingerprint`] restructured into [`FP_LANES`] independent
/// accumulator lanes so the mixing loop autovectorises — the §Perf
/// kernel under the parallel dedup's per-set fingerprint pass.
///
/// Bit-for-bit equal to [`set_fingerprint`] for every input: both
/// accumulators are commutative-associative (wrapping add, xor), so
/// splitting them across lanes and recombining cannot change the result
/// (property-tested in `rust/tests/proptests.rs` and below).
pub fn set_fingerprint_batched(ids: &[u32]) -> u64 {
    let mut sums = [0u64; FP_LANES];
    let mut xors = [0u64; FP_LANES];
    let mut blocks = ids.chunks_exact(FP_LANES);
    for block in &mut blocks {
        for lane in 0..FP_LANES {
            let e = mix64(block[lane] as u64 + 1);
            sums[lane] = sums[lane].wrapping_add(e);
            xors[lane] ^= e.rotate_left(23);
        }
    }
    let mut sum: u64 = 0;
    let mut xor: u64 = 0;
    for lane in 0..FP_LANES {
        sum = sum.wrapping_add(sums[lane]);
        xor ^= xors[lane];
    }
    for &id in blocks.remainder() {
        let e = mix64(id as u64 + 1);
        sum = sum.wrapping_add(e);
        xor ^= e.rotate_left(23);
    }
    mix64(sum ^ (ids.len() as u64)).wrapping_add(xor)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(fxhash(&(1u32, 2u32)), fxhash(&(1u32, 2u32)));
        assert_ne!(fxhash(&(1u32, 2u32)), fxhash(&(2u32, 1u32)));
    }

    #[test]
    fn map_basic() {
        let mut m: FxHashMap<(u32, u32), u32> = FxHashMap::default();
        m.insert((1, 2), 3);
        assert_eq!(m.get(&(1, 2)), Some(&3));
    }

    #[test]
    fn fingerprint_order_independent() {
        assert_eq!(set_fingerprint(&[1, 2, 3]), set_fingerprint(&[3, 1, 2]));
        assert_ne!(set_fingerprint(&[1, 2, 3]), set_fingerprint(&[1, 2, 4]));
        assert_ne!(set_fingerprint(&[1, 2]), set_fingerprint(&[1, 2, 2]));
    }

    #[test]
    fn batched_fingerprint_equals_scalar() {
        // every remainder length around the lane width, plus empty
        for n in 0..40usize {
            let ids: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
            assert_eq!(
                set_fingerprint(&ids),
                set_fingerprint_batched(&ids),
                "len {n}"
            );
        }
    }

    #[test]
    fn spread_over_buckets() {
        // partitioner sanity: ids 0..1000 spread across 10 buckets
        let mut buckets = [0usize; 10];
        for i in 0..1000u32 {
            buckets[(fxhash(&i) % 10) as usize] += 1;
        }
        assert!(buckets.iter().all(|&b| b > 50), "{buckets:?}");
    }
}
