//! Bench: regenerate paper Table 4 — MovieLens scaling series +
//! BibSonomy with the per-stage breakdown and cluster counts.

use tricluster::coordinator::{experiments, ExpConfig};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let cfg = ExpConfig { full, nodes: 10, theta: 0.0, runs: 1, seed: 42 };
    eprintln!("table4 bench (full={full}) ...");
    let report = experiments::table4(&cfg)?;
    println!("{}", report.render());
    println!();
    println!("paper reference (ms): ML100k online 89,931 vs M/R 16,348 (8,724/5,292/2,332)");
    println!("  ML1M online 958,345 vs M/R 217,694; Bibsonomy online >6h vs M/R ~1h");
    println!("  #clusters: ML100k 89,932 | ML1M 942,757 | Bibsonomy 486,221");
    println!("shape: M/R 4-6x faster at scale; stages 2+3 dominate; #clusters ≈ #tuples for ML");
    let csv = report.write_csv()?;
    eprintln!("(csv: {})", csv.display());
    Ok(())
}
