//! CI gate: check the perf trajectory in `BENCH_cluster.json` (and, when
//! present, `BENCH_backends.json`) against `ci/bench_baseline.json`.
//!
//! Run after the benches (the CI `bench-regression` step does):
//!
//! ```text
//! cargo bench --bench cluster_scaling
//! cargo bench --bench check_bench            # uses ci/bench_baseline.json
//! cargo bench --bench check_bench -- --baseline other.json
//! cargo bench --bench check_bench -- --pin   # rewrite baseline from current
//! ```
//!
//! What it enforces (exit 1 on violation):
//!
//! 1. **Monotone speedup** — for every straggler rate, the
//!    speculation-on simulated makespan is non-increasing from 1→8
//!    nodes (within `monotone_tolerance`). This is machine-independent:
//!    the cluster bench uses the per-record cost model.
//! 2. **Baseline entries** — each `entries[]` item pins one
//!    `(nodes, stragglers, speculation)` point: current
//!    `sim_makespan_ms` must not exceed `max_sim_makespan_ms ×
//!    (1 + tolerance)` (default tolerance 0.25, i.e. a >25% makespan
//!    regression fails), and `speedup_vs_1node` must not fall below
//!    `min_speedup_vs_1node`.
//! 3. **Backend agreement** — every `BENCH_backends.json` series entry
//!    for one dataset reports the same cluster count (belt-and-braces on
//!    top of the in-process equivalence assertion).
//! 4. **Serve-cluster placement trajectory** — when
//!    `BENCH_serve_cluster.json` is present: every entry reports the
//!    same cluster count (equivalence held under churn + re-placement),
//!    locality moved strictly fewer drain-path MiB than round-robin,
//!    and `locality_speedup_vs_rr` is at least the baseline's
//!    `serve_cluster.min_locality_speedup_vs_rr` floor. The query-plane
//!    section of the same JSON is gated too: `cache_matches_uncached`
//!    must not be present-and-false (the result cache answered
//!    bit-identically to the uncached backend), and
//!    `cached_query_speedup` must clear
//!    `serve_cluster.min_cached_query_speedup` (wall-clock ratio, so
//!    the floor is deliberately loose; skipped on older JSONs that
//!    predate the query-plane section). The multi-tenant section is
//!    gated by a CEILING: `fairness_spread` (max/min per-tenant
//!    service-ms per accepted tuple, deterministic) must not exceed
//!    `serve_cluster.max_fairness_spread` (skipped on older JSONs that
//!    predate the tenant section; `--pin` re-pins it to 110% of
//!    observed).
//! 5. **Hot-path kernels** — when `BENCH_hotpath.json` is present:
//!    sequential ingest throughput must not fall below
//!    `hotpath.min_ingest_tuples_per_s`, merge-based parallel ingest
//!    must be at least `hotpath.min_parallel_vs_sequential` × the
//!    sequential rate (the "parallel ≥ sequential" acceptance gate —
//!    skipped when the bench machine had fewer than 2 workers, where
//!    the parallel path IS the sequential fallback and the ratio is
//!    noise), the partitioned parallel dedup must be at least
//!    `hotpath.min_dedup_parallel_ratio` × the sequential dedup oracle
//!    (same <2-worker skip), and the in-bench equivalence verdicts
//!    (`parallel_matches_sequential`, `bitset_matches_scalar`,
//!    `batched_matches_scalar`, `dedup_parallel_matches_sequential`,
//!    `compressed_matches_scalar`, `dense_over_bitset_cap`) must be
//!    true.
//! 6. **Observability overhead** — when `BENCH_hotpath.json` carries the
//!    obs section: ingest with telemetry DISABLED must stay within
//!    `hotpath.min_obs_disabled_ratio` of the no-telemetry build of the
//!    same kernel (0.97 by policy — the "one relaxed load per batch"
//!    promise of `obs::enabled()`), and with telemetry ENABLED within
//!    `hotpath.min_obs_enabled_ratio` (0.5 — spans are per batch, never
//!    per tuple, so full tracing may not halve ingest throughput).
//! 7. **Persistence restore ratio** — when `BENCH_persist.json` is
//!    present: `binary_restore_vs_json` (page-adoption restore vs JSON
//!    parse + re-mine, same machine, same compacted state) must clear
//!    `persist.min_binary_restore_ratio`, and `restore_equivalent` must
//!    not be present-and-false (both arms reproduced the live index).
//!
//! `--pin` rewrites the baseline from the current `BENCH_cluster.json`
//! (max makespans = observed, speedup floors = 80% of observed) and,
//! when present, `BENCH_serve_cluster.json` (locality-vs-rr floor = 90%
//! of observed), `BENCH_hotpath.json` (ingest floor = 30% of
//! observed — wall-clock rates are machine-dependent, unlike the
//! simulated makespans; the parallel-vs-sequential and
//! dedup-parallel floors stay pinned at 1.0 by policy), and
//! `BENCH_persist.json` (restore-ratio floor = 90% of observed), so a
//! session with a toolchain can tighten the committed baseline.

use std::collections::BTreeMap;
use std::process::exit;

use tricluster::util::cli::Args;
use tricluster::util::json::Json;

fn load(path: &str) -> Option<Json> {
    let text = std::fs::read_to_string(path).ok()?;
    match Json::parse(&text) {
        Ok(doc) => Some(doc),
        Err(e) => {
            eprintln!("check_bench: {path} is not valid JSON: {e}");
            exit(1);
        }
    }
}

fn f(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

fn key_of(nodes: f64, stragglers: f64, speculation: bool) -> String {
    format!(
        "nodes={} stragglers={:.2} spec={}",
        nodes,
        stragglers,
        if speculation { "on" } else { "off" }
    )
}

fn main() {
    let args = Args::from_env();
    let baseline_path = args.get_or("baseline", "ci/bench_baseline.json");
    let cluster_path = args.get_or("cluster", "BENCH_cluster.json");
    let backends_path = args.get_or("backends", "BENCH_backends.json");
    let serve_cluster_path =
        args.get_or("serve-cluster", "BENCH_serve_cluster.json");
    let hotpath_path = args.get_or("hotpath", "BENCH_hotpath.json");
    let persist_path = args.get_or("persist", "BENCH_persist.json");

    let Some(cluster) = load(cluster_path) else {
        // bare `cargo bench` runs targets in name order, so this checker
        // can run before cluster_scaling has written its JSON: skip
        // unless the caller (CI) demands the gate with --require
        if args.has("require") {
            eprintln!(
                "check_bench: {cluster_path} not found — run `cargo bench --bench \
                 cluster_scaling` first"
            );
            exit(1);
        }
        eprintln!(
            "check_bench: {cluster_path} not found — skipping (pass -- --require \
             to make this fatal, as CI does)"
        );
        return;
    };
    let entries = cluster.get("entries").and_then(Json::as_arr).unwrap_or(&[]);
    if entries.is_empty() {
        eprintln!("check_bench: {cluster_path} has no entries");
        exit(1);
    }

    if args.has("pin") {
        pin(
            baseline_path,
            entries,
            load(serve_cluster_path).as_ref(),
            load(hotpath_path).as_ref(),
            load(persist_path).as_ref(),
        );
        return;
    }

    let Some(baseline) = load(baseline_path) else {
        eprintln!("check_bench: baseline {baseline_path} not found");
        exit(1);
    };
    let tolerance =
        baseline.get("tolerance").and_then(Json::as_f64).unwrap_or(0.25);
    let monotone_tol = baseline
        .get("monotone_tolerance")
        .and_then(Json::as_f64)
        .unwrap_or(0.02);
    let require_monotone = baseline
        .get("require_monotone_speedup")
        .and_then(Json::as_bool)
        .unwrap_or(true);
    let mut failures: Vec<String> = Vec::new();

    // 1. monotone speedup per (stragglers, speculation=on) series
    if require_monotone {
        let mut series: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
        for e in entries {
            if e.get("speculation").and_then(Json::as_bool) != Some(true) {
                continue;
            }
            series
                .entry(format!("{:.4}", f(e, "stragglers")))
                .or_default()
                .push((f(e, "nodes"), f(e, "sim_makespan_ms")));
        }
        for (stragglers, mut points) in series {
            points.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in points.windows(2) {
                let ((n0, m0), (n1, m1)) = (w[0], w[1]);
                if m1 > m0 * (1.0 + monotone_tol) {
                    failures.push(format!(
                        "speedup not monotone at stragglers={stragglers}: {m1:.1} ms \
                         @ {n1} nodes > {m0:.1} ms @ {n0} nodes (spec on)"
                    ));
                }
            }
        }
    }

    // 2. pinned baseline entries
    let pins = baseline.get("entries").and_then(Json::as_arr).unwrap_or(&[]);
    let mut checked = 0usize;
    for pin in pins {
        if pin.get("bench").and_then(Json::as_str) != Some("cluster_scaling") {
            continue;
        }
        let (nodes, stragglers) = (f(pin, "nodes"), f(pin, "stragglers"));
        let speculation =
            pin.get("speculation").and_then(Json::as_bool).unwrap_or(true);
        let key = key_of(nodes, stragglers, speculation);
        let Some(cur) = entries.iter().find(|e| {
            f(e, "nodes") == nodes
                && (f(e, "stragglers") - stragglers).abs() < 1e-9
                && e.get("speculation").and_then(Json::as_bool) == Some(speculation)
        }) else {
            failures.push(format!("baseline entry {key} missing from {cluster_path}"));
            continue;
        };
        checked += 1;
        let max_ms = f(pin, "max_sim_makespan_ms");
        if max_ms.is_finite() {
            let cur_ms = f(cur, "sim_makespan_ms");
            if cur_ms > max_ms * (1.0 + tolerance) {
                failures.push(format!(
                    "{key}: sim_makespan_ms {cur_ms:.1} regressed >{:.0}% over \
                     baseline {max_ms:.1}",
                    tolerance * 100.0
                ));
            }
        }
        let min_speedup = f(pin, "min_speedup_vs_1node");
        if min_speedup.is_finite() {
            let cur_speedup = f(cur, "speedup_vs_1node");
            if cur_speedup < min_speedup {
                failures.push(format!(
                    "{key}: speedup_vs_1node {cur_speedup:.2} fell below the \
                     baseline floor {min_speedup:.2}"
                ));
            }
        }
    }

    // 3. backend agreement (when the backend matrix ran)
    if let Some(backends) = load(backends_path) {
        let mut per_dataset: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        for e in backends.get("series").and_then(Json::as_arr).unwrap_or(&[]) {
            let ds = e
                .get("dataset")
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string();
            per_dataset.entry(ds).or_default().push(f(e, "clusters"));
        }
        for (ds, counts) in per_dataset {
            if counts.windows(2).any(|w| w[0] != w[1]) {
                failures.push(format!(
                    "backend matrix disagreement on {ds}: cluster counts {counts:?}"
                ));
            }
        }
    } else {
        eprintln!("check_bench: {backends_path} absent — skipping backend agreement");
    }

    // 4. serve-cluster placement trajectory (when that bench ran)
    if let Some(serve) = load(serve_cluster_path) {
        let entries = serve.get("entries").and_then(Json::as_arr).unwrap_or(&[]);
        if entries.is_empty() {
            failures.push(format!("{serve_cluster_path} has no entries"));
        }
        let counts: Vec<f64> = entries.iter().map(|e| f(e, "clusters")).collect();
        if counts.windows(2).any(|w| w[0] != w[1]) {
            failures.push(format!(
                "serve-cluster equivalence broke: cluster counts {counts:?} \
                 differ across placement/churn configurations"
            ));
        }
        let clean = |placement: &str| {
            entries.iter().find(|e| {
                e.get("placement").and_then(Json::as_str) == Some(placement)
                    && f(e, "churn") == 0.0
            })
        };
        if let (Some(rr), Some(loc)) = (clean("rr"), clean("locality")) {
            if f(loc, "shuffle_mib") >= f(rr, "shuffle_mib") {
                failures.push(format!(
                    "locality moved {:.2} MiB, not fewer than rr's {:.2} MiB",
                    f(loc, "shuffle_mib"),
                    f(rr, "shuffle_mib")
                ));
            }
        } else {
            failures.push(
                "serve-cluster bench is missing the churn-free rr/locality entries"
                    .to_string(),
            );
        }
        let ratio = f(&serve, "locality_speedup_vs_rr");
        let floor = baseline
            .get("serve_cluster")
            .and_then(|s| s.get("min_locality_speedup_vs_rr"))
            .and_then(Json::as_f64);
        if let Some(min) = floor {
            if ratio.is_nan() || ratio < min {
                failures.push(format!(
                    "locality_speedup_vs_rr {ratio:.3} fell below the baseline \
                     floor {min:.3}"
                ));
            }
        }
        // query plane: the cache must be transparent and must pay for
        // itself
        if serve.get("cache_matches_uncached").and_then(Json::as_bool) == Some(false)
        {
            failures.push(
                "serve-cluster cache_matches_uncached is false: the result \
                 cache changed a query answer"
                    .to_string(),
            );
        }
        let cq = f(&serve, "cached_query_speedup");
        if let Some(min) = baseline
            .get("serve_cluster")
            .and_then(|s| s.get("min_cached_query_speedup"))
            .and_then(Json::as_f64)
        {
            if cq.is_nan() {
                eprintln!(
                    "check_bench: serve-cluster has no cached_query_speedup — \
                     older bench JSON; skipping the cached-query floor"
                );
            } else if cq < min {
                failures.push(format!(
                    "cached_query_speedup {cq:.3} fell below the baseline \
                     floor {min:.3}"
                ));
            }
        }
        // multi-tenant fairness: spread is max/min per-tenant service-ms
        // per accepted tuple (1.0 = perfectly fair), gated by a CEILING —
        // the one deliberately inverted gate in this file
        let spread = f(&serve, "fairness_spread");
        if let Some(max) = baseline
            .get("serve_cluster")
            .and_then(|s| s.get("max_fairness_spread"))
            .and_then(Json::as_f64)
        {
            if spread.is_nan() {
                eprintln!(
                    "check_bench: serve-cluster has no fairness_spread — older \
                     bench JSON; skipping the fairness ceiling"
                );
            } else if spread > max {
                failures.push(format!(
                    "fairness_spread {spread:.3} exceeded the baseline ceiling \
                     {max:.3}: one tenant is paying disproportionately for its \
                     neighbours"
                ));
            }
        }
    } else {
        eprintln!(
            "check_bench: {serve_cluster_path} absent — skipping serve-cluster gate"
        );
    }

    // 5. hot-path kernel floors (when the hotpath bench ran)
    if let Some(hot) = load(hotpath_path) {
        // absent keys pass (older bench JSONs predate the newer verdicts);
        // a key that is present and false always fails
        for verdict in [
            "parallel_matches_sequential",
            "bitset_matches_scalar",
            "batched_matches_scalar",
            "dedup_parallel_matches_sequential",
            "compressed_matches_scalar",
            "dense_over_bitset_cap",
        ] {
            if hot.get(verdict).and_then(Json::as_bool) == Some(false) {
                failures.push(format!("hotpath equivalence verdict {verdict} is false"));
            }
        }
        let hot_base = baseline.get("hotpath");
        let seq_rate = f(&hot, "ingest_seq_tuples_per_s");
        if let Some(min) = hot_base
            .and_then(|h| h.get("min_ingest_tuples_per_s"))
            .and_then(Json::as_f64)
        {
            if seq_rate.is_nan() || seq_rate < min {
                failures.push(format!(
                    "hotpath ingest {seq_rate:.0} tuples/s fell below the baseline \
                     floor {min:.0}"
                ));
            }
        }
        let ratio = f(&hot, "parallel_vs_sequential");
        let bench_workers = f(&hot, "workers");
        if let Some(min) = hot_base
            .and_then(|h| h.get("min_parallel_vs_sequential"))
            .and_then(Json::as_f64)
        {
            if bench_workers < 2.0 {
                // single-core runner: par_add_batch takes the sequential
                // fallback, so the ratio is pure timing noise around 1.0
                // — nothing to gate
                eprintln!(
                    "check_bench: hotpath ran with {bench_workers} worker(s) — \
                     skipping the parallel-vs-sequential floor"
                );
            } else if ratio.is_nan() || ratio < min {
                failures.push(format!(
                    "hotpath parallel ingest at {ratio:.3}x sequential fell below \
                     the baseline floor {min:.3}x"
                ));
            }
        }
        let dedup_ratio = f(&hot, "dedup_par_vs_seq");
        if let Some(min) = hot_base
            .and_then(|h| h.get("min_dedup_parallel_ratio"))
            .and_then(Json::as_f64)
        {
            if bench_workers < 2.0 {
                eprintln!(
                    "check_bench: hotpath ran with {bench_workers} worker(s) — \
                     skipping the dedup-parallel floor"
                );
            } else if dedup_ratio.is_nan() {
                eprintln!(
                    "check_bench: hotpath has no dedup_par_vs_seq — older bench \
                     JSON; skipping the dedup-parallel floor"
                );
            } else if dedup_ratio < min {
                failures.push(format!(
                    "hotpath parallel dedup at {dedup_ratio:.3}x sequential fell \
                     below the baseline floor {min:.3}x"
                ));
            }
        }
        // 6. observability overhead vs the no-telemetry build
        for (field, floor_key) in [
            ("obs_disabled_vs_baseline", "min_obs_disabled_ratio"),
            ("obs_enabled_vs_baseline", "min_obs_enabled_ratio"),
        ] {
            let Some(min) = hot_base
                .and_then(|h| h.get(floor_key))
                .and_then(Json::as_f64)
            else {
                continue;
            };
            let ratio = f(&hot, field);
            if ratio.is_nan() {
                eprintln!(
                    "check_bench: hotpath has no {field} — obs section did not \
                     run; skipping the {floor_key} floor"
                );
            } else if ratio < min {
                failures.push(format!(
                    "hotpath {field} {ratio:.3} fell below the baseline floor \
                     {min:.3} (telemetry overhead regression)"
                ));
            }
        }
    } else {
        eprintln!("check_bench: {hotpath_path} absent — skipping hot-path gate");
    }

    // 7. persistence restore ratio (when the persist bench ran)
    if let Some(persist) = load(persist_path) {
        if persist.get("restore_equivalent").and_then(Json::as_bool) == Some(false) {
            failures.push(
                "persist restore_equivalent is false: a restore diverged from \
                 the live index"
                    .to_string(),
            );
        }
        let ratio = f(&persist, "binary_restore_vs_json");
        if let Some(min) = baseline
            .get("persist")
            .and_then(|p| p.get("min_binary_restore_ratio"))
            .and_then(Json::as_f64)
        {
            if ratio.is_nan() || ratio < min {
                failures.push(format!(
                    "binary_restore_vs_json {ratio:.3} fell below the baseline \
                     floor {min:.3}: page-adoption restore lost its edge over \
                     JSON parse + re-mine"
                ));
            }
        }
    } else {
        eprintln!("check_bench: {persist_path} absent — skipping persist gate");
    }

    if failures.is_empty() {
        println!(
            "check_bench: OK — {} cluster entries, {checked} baseline pins, \
             monotone speedup held",
            entries.len()
        );
    } else {
        for fail in &failures {
            eprintln!("check_bench: FAIL: {fail}");
        }
        exit(1);
    }
}

/// `--pin`: rewrite the baseline from the current bench output.
fn pin(
    baseline_path: &str,
    entries: &[Json],
    serve_cluster: Option<&Json>,
    hotpath: Option<&Json>,
    persist: Option<&Json>,
) {
    let mut pins: Vec<Json> = Vec::new();
    for e in entries {
        let mut o = BTreeMap::new();
        o.insert("bench".to_string(), Json::Str("cluster_scaling".into()));
        o.insert("nodes".to_string(), Json::Num(f(e, "nodes")));
        o.insert("stragglers".to_string(), Json::Num(f(e, "stragglers")));
        o.insert(
            "speculation".to_string(),
            Json::Bool(e.get("speculation").and_then(Json::as_bool).unwrap_or(true)),
        );
        o.insert(
            "max_sim_makespan_ms".to_string(),
            Json::Num(f(e, "sim_makespan_ms")),
        );
        o.insert(
            "min_speedup_vs_1node".to_string(),
            Json::Num((f(e, "speedup_vs_1node") * 0.8 * 100.0).floor() / 100.0),
        );
        pins.push(Json::Obj(o));
    }
    let mut doc = BTreeMap::new();
    doc.insert("tolerance".to_string(), Json::Num(0.25));
    doc.insert("monotone_tolerance".to_string(), Json::Num(0.02));
    doc.insert("require_monotone_speedup".to_string(), Json::Bool(true));
    doc.insert("entries".to_string(), Json::Arr(pins));
    match serve_cluster.map(|s| f(s, "locality_speedup_vs_rr")) {
        Some(ratio) if ratio.is_finite() => {
            let mut sc = BTreeMap::new();
            sc.insert(
                "min_locality_speedup_vs_rr".to_string(),
                Json::Num((ratio * 0.9 * 1000.0).floor() / 1000.0),
            );
            // wall-clock ratio: pin at 90% of observed when the
            // query-plane section ran, else carry the committed floor
            let cq = serve_cluster.map(|s| f(s, "cached_query_speedup"));
            match cq {
                Some(cq) if cq.is_finite() => {
                    sc.insert(
                        "min_cached_query_speedup".to_string(),
                        Json::Num((cq * 0.9 * 1000.0).floor() / 1000.0),
                    );
                }
                _ => {
                    if let Some(old) = load(baseline_path)
                        .as_ref()
                        .and_then(|b| b.get("serve_cluster"))
                        .and_then(|s| s.get("min_cached_query_speedup"))
                    {
                        sc.insert(
                            "min_cached_query_speedup".to_string(),
                            old.clone(),
                        );
                    }
                }
            }
            // fairness is gated by a CEILING: pin at 110% of observed
            // when the tenant section ran, else carry the committed one
            match serve_cluster.map(|s| f(s, "fairness_spread")) {
                Some(spread) if spread.is_finite() => {
                    sc.insert(
                        "max_fairness_spread".to_string(),
                        Json::Num((spread * 1.1 * 1000.0).ceil() / 1000.0),
                    );
                }
                _ => {
                    if let Some(old) = load(baseline_path)
                        .as_ref()
                        .and_then(|b| b.get("serve_cluster"))
                        .and_then(|s| s.get("max_fairness_spread"))
                    {
                        sc.insert("max_fairness_spread".to_string(), old.clone());
                    }
                }
            }
            doc.insert("serve_cluster".to_string(), Json::Obj(sc));
        }
        _ => {
            // serve_cluster bench did not run: KEEP the committed floor
            // instead of silently deleting the gate from the baseline
            let old_baseline = load(baseline_path);
            if let Some(old) =
                old_baseline.as_ref().and_then(|b| b.get("serve_cluster"))
            {
                doc.insert("serve_cluster".to_string(), old.clone());
            }
        }
    }
    match hotpath.map(|h| f(h, "ingest_seq_tuples_per_s")) {
        Some(rate) if rate.is_finite() => {
            let mut hp = BTreeMap::new();
            // wall-clock rate: pin LOOSELY (30% of observed) — unlike the
            // simulated makespans this number moves with the CI machine
            hp.insert(
                "min_ingest_tuples_per_s".to_string(),
                Json::Num((rate * 0.3).floor()),
            );
            // policy, not measurement: parallel ingest must never lose
            hp.insert("min_parallel_vs_sequential".to_string(), Json::Num(1.0));
            // same policy for the partitioned parallel dedup
            hp.insert("min_dedup_parallel_ratio".to_string(), Json::Num(1.0));
            // policy floors for the obs overhead too: disabled telemetry
            // stays within 3% of the no-telemetry build, enabled within 2x
            hp.insert("min_obs_disabled_ratio".to_string(), Json::Num(0.97));
            hp.insert("min_obs_enabled_ratio".to_string(), Json::Num(0.5));
            doc.insert("hotpath".to_string(), Json::Obj(hp));
        }
        _ => {
            let old_baseline = load(baseline_path);
            if let Some(old) = old_baseline.as_ref().and_then(|b| b.get("hotpath")) {
                doc.insert("hotpath".to_string(), old.clone());
            }
        }
    }
    match persist.map(|p| f(p, "binary_restore_vs_json")) {
        Some(ratio) if ratio.is_finite() => {
            // ratio of two wall-clock runs on the same machine: pin at
            // 90% of observed
            let mut pe = BTreeMap::new();
            pe.insert(
                "min_binary_restore_ratio".to_string(),
                Json::Num((ratio * 0.9 * 1000.0).floor() / 1000.0),
            );
            doc.insert("persist".to_string(), Json::Obj(pe));
        }
        _ => {
            let old_baseline = load(baseline_path);
            if let Some(old) = old_baseline.as_ref().and_then(|b| b.get("persist")) {
                doc.insert("persist".to_string(), old.clone());
            }
        }
    }
    std::fs::write(baseline_path, Json::Obj(doc).to_string())
        .expect("write baseline");
    println!(
        "check_bench: pinned {baseline_path} from current BENCH_cluster.json \
         (+ BENCH_serve_cluster.json when present)"
    );
}
