//! Experiment reports: paper-style tables rendered to the terminal and
//! CSV files under `target/reports/` for EXPERIMENTS.md.

use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::table;

/// A titled table with a header row.
#[derive(Debug, Clone)]
pub struct Report {
    /// Report title (rendered as the table caption).
    pub title: String,
    /// Header row followed by data rows.
    pub rows: Vec<Vec<String>>,
}

impl Report {
    /// New report holding only the header row.
    pub fn new(title: &str, header: Vec<String>) -> Self {
        Self { title: title.into(), rows: vec![header] }
    }

    /// Append one data row.
    pub fn push(&mut self, row: Vec<String>) {
        self.rows.push(row);
    }

    /// Render for the terminal.
    pub fn render(&self) -> String {
        format!("## {}\n{}", self.title, table::render(&self.rows))
    }

    /// Write a CSV copy under `target/reports/<slug>.csv`.
    pub fn write_csv(&self) -> Result<PathBuf> {
        let slug: String = self
            .title
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' }
            })
            .collect::<String>()
            .split('-')
            .filter(|s| !s.is_empty())
            .collect::<Vec<_>>()
            .join("-");
        let dir = PathBuf::from("target/reports");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{slug}.csv"));
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("create {}", path.display()))?;
        for row in &self.rows {
            let escaped: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            writeln!(f, "{}", escaped.join(","))?;
        }
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;

    #[test]
    fn render_and_csv() {
        let mut r = Report::new(
            "Table 3: runtime, ms",
            vec!["Method".into(), "IMDB".into()],
        );
        r.push(row!["Online OAC", 368]);
        let s = r.render();
        assert!(s.contains("## Table 3"));
        assert!(s.contains("Online OAC"));
        let path = r.write_csv().unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("Method,IMDB"));
        assert!(content.contains("Online OAC,368"));
    }
}
