//! Experiment/run configuration files — a small INI-style format
//! (sections, `key = value`, `#` comments) so deployments can pin
//! cluster and experiment settings without shell flags:
//!
//! ```ini
//! [cluster]
//! nodes = 10
//! fault_prob = 0.05
//! replication = 3
//!
//! [experiment]
//! full = true
//! theta = 0.1
//! runs = 5
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::experiments::ExpConfig;
use crate::mmc::MmcConfig;

/// Parsed configuration: `section.key` → raw string value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse INI-style text (`[section]` + `key = value`) into flat
    ///  `section.key` entries.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = if section.is_empty() {
                    k.trim().to_string()
                } else {
                    format!("{section}.{}", k.trim())
                };
                values.insert(key, v.trim().to_string());
            } else {
                anyhow::bail!("line {}: expected `key = value`", lineno + 1);
            }
        }
        Ok(Self { values })
    }

    /// Read and [`Self::parse`] a config file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    /// Raw value of `section.key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// Value of `key` parsed as `T`, if present and well-formed.
    pub fn parse_key<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// Boolean value of `key` (`true/1/yes/on` vs `false/0/no/off`).
    pub fn bool_key(&self, key: &str) -> Option<bool> {
        match self.get(key)? {
            "true" | "1" | "yes" | "on" => Some(true),
            "false" | "0" | "no" | "off" => Some(false),
            _ => None,
        }
    }

    /// Build an `ExpConfig`, starting from defaults.
    pub fn exp_config(&self) -> ExpConfig {
        let d = ExpConfig::default();
        ExpConfig {
            full: self.bool_key("experiment.full").unwrap_or(d.full),
            nodes: self.parse_key("cluster.nodes").unwrap_or(d.nodes),
            theta: self.parse_key("experiment.theta").unwrap_or(d.theta),
            runs: self.parse_key("experiment.runs").unwrap_or(d.runs),
            seed: self.parse_key("experiment.seed").unwrap_or(d.seed),
        }
    }

    /// Build an `MmcConfig`, starting from defaults.
    pub fn mmc_config(&self) -> MmcConfig {
        let d = MmcConfig::default();
        let nodes: Option<usize> = self.parse_key("cluster.nodes");
        MmcConfig {
            theta: self.parse_key("experiment.theta").unwrap_or(d.theta),
            map_tasks: self
                .parse_key("cluster.map_tasks")
                .or(nodes.map(|n| n * 4))
                .unwrap_or(d.map_tasks),
            reduce_tasks: self
                .parse_key("cluster.reduce_tasks")
                .or(nodes.map(|n| n * 4))
                .unwrap_or(d.reduce_tasks),
            executor_threads: self
                .parse_key("cluster.executor_threads")
                .unwrap_or(d.executor_threads),
            fault_prob: self.parse_key("cluster.fault_prob").unwrap_or(d.fault_prob),
            seed: self.parse_key("experiment.seed").unwrap_or(d.seed),
            use_dfs: self.bool_key("cluster.use_dfs").unwrap_or(d.use_dfs),
            replication: self.parse_key("cluster.replication").unwrap_or(d.replication),
            combiner: self.bool_key("cluster.combiner").unwrap_or(d.combiner),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# cluster shape
[cluster]
nodes = 12
fault_prob = 0.05
replication = 3
combiner = yes

[experiment]
full = true
theta = 0.25   # density threshold
runs = 5
";

    #[test]
    fn parses_sections_and_comments() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("cluster.nodes"), Some("12"));
        assert_eq!(c.parse_key::<f64>("experiment.theta"), Some(0.25));
        assert_eq!(c.bool_key("experiment.full"), Some(true));
        assert_eq!(c.bool_key("cluster.combiner"), Some(true));
        assert_eq!(c.get("missing"), None);
    }

    #[test]
    fn builds_typed_configs() {
        let c = Config::parse(SAMPLE).unwrap();
        let exp = c.exp_config();
        assert!(exp.full);
        assert_eq!(exp.nodes, 12);
        assert_eq!(exp.runs, 5);
        let mmc = c.mmc_config();
        assert_eq!(mmc.map_tasks, 48); // nodes * 4
        assert!((mmc.fault_prob - 0.05).abs() < 1e-12);
        assert!(mmc.combiner);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = Config::parse("").unwrap();
        let exp = c.exp_config();
        assert_eq!(exp.nodes, ExpConfig::default().nodes);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[open\n").is_err());
        assert!(Config::parse("not a kv\n").is_err());
    }
}
