//! The `QueryBackend` trait: one read API over the epoch snapshot
//! plane, with in-process and simulated-remote implementations.
//!
//! Mirrors the worker-backend shape the exec layer uses (one trait, a
//! local and a simulated-remote impl, equivalence property-tested):
//! every backend answers the same four queries — `top_k`, `containing`,
//! `entity_stats`, `stats` — and reports the epoch it answered at.
//! [`LocalBackend`] loads the primary's [`SnapshotCell`];
//! [`crate::serve::SimRemoteBackend`] reads a replica node's applied
//! snapshot, so its epoch may trail the primary by at most the retained
//! window (see [`crate::serve::replica`]).
//!
//! Both share a [`QueryCache`]: results keyed by `(epoch, query)`,
//! invalidated wholesale when the observed epoch bumps (the snapshot is
//! immutable within an epoch, so a cached answer can never go stale
//! before the epoch does). Hits/misses are counted as
//! `serve.cache.hit` / `serve.cache.miss`.

use std::sync::Arc;

use crate::core::pattern::Cluster;
use crate::serve::epoch::{EpochSnapshot, IndexStats, SnapshotCell};
use crate::util::hash::FxHashMap;

/// A cacheable query, as issued against one epoch.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum QueryKey {
    /// `top_k(k)`.
    TopK(usize),
    /// `containing(modality, entity)`.
    Containing(u8, u32),
    /// `entity_stats(modality, entity)`.
    EntityStats(u8, u32),
    /// Whole-index `stats()`.
    Stats,
}

/// A cached answer (owned, so a hit is a clone — no snapshot borrow
/// outlives the cache entry).
#[derive(Debug, Clone)]
pub(crate) enum Answer {
    Clusters(Vec<Cluster>),
    Ids(Vec<u32>),
    Stats(Option<IndexStats>),
}

/// `(epoch, query)`-keyed result cache with epoch-bump invalidation.
///
/// The epoch is not part of the map key: [`Self::roll`] clears the map
/// whenever the observed epoch changes, so every entry in the map is
/// for the current epoch by construction (and the map never accumulates
/// dead epochs).
#[derive(Debug)]
pub struct QueryCache {
    enabled: bool,
    epoch: u64,
    map: FxHashMap<QueryKey, Answer>,
    hits: u64,
    misses: u64,
}

impl QueryCache {
    pub(crate) fn new(enabled: bool) -> Self {
        Self { enabled, epoch: 0, map: FxHashMap::default(), hits: 0, misses: 0 }
    }

    /// Point the cache at `epoch`, dropping every entry if it changed.
    fn roll(&mut self, epoch: u64) {
        if self.epoch != epoch {
            self.epoch = epoch;
            self.map.clear();
        }
    }

    fn lookup(&mut self, key: &QueryKey) -> Option<Answer> {
        if !self.enabled {
            return None;
        }
        match self.map.get(key) {
            Some(a) => {
                self.hits += 1;
                crate::obs::counter("serve.cache.hit", 1);
                Some(a.clone())
            }
            None => {
                self.misses += 1;
                crate::obs::counter("serve.cache.miss", 1);
                None
            }
        }
    }

    fn store(&mut self, key: QueryKey, answer: &Answer) {
        if self.enabled {
            self.map.insert(key, answer.clone());
        }
    }

    /// `(hits, misses)` since construction.
    pub(crate) fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

/// Answer `key` from `snap`, through `cache` (roll → lookup → compute →
/// store). One code path for every backend, so cache-on, cache-off,
/// local, and remote answers are computed identically.
fn answer(snap: &EpochSnapshot, cache: &mut QueryCache, key: QueryKey) -> Answer {
    cache.roll(snap.epoch());
    if let Some(hit) = cache.lookup(&key) {
        return hit;
    }
    let fresh = match key {
        QueryKey::TopK(k) => {
            Answer::Clusters(snap.top_k_by_density(k).into_iter().cloned().collect())
        }
        QueryKey::Containing(m, e) => Answer::Ids(snap.containing(m as usize, e).to_vec()),
        QueryKey::EntityStats(m, e) => Answer::Stats(snap.entity_stats(m as usize, e)),
        QueryKey::Stats => Answer::Stats(Some(snap.stats())),
    };
    cache.store(key, &fresh);
    fresh
}

/// The uniform read API over the query plane.
///
/// `&mut self` on the query methods is for the backend's own cache and
/// routing state — backends never mutate the snapshot, and many
/// backends can read one [`SnapshotCell`] concurrently.
pub trait QueryBackend {
    /// Human-readable backend name (for logs and test labels).
    fn name(&self) -> &'static str;

    /// The snapshot this backend currently answers from.
    fn snapshot(&self) -> Arc<EpochSnapshot>;

    /// The epoch this backend currently answers at.
    fn epoch(&self) -> u64 {
        self.snapshot().epoch()
    }

    /// The k densest clusters (owned; see
    /// [`EpochSnapshot::top_k_by_density`] for the ranking).
    fn top_k(&mut self, k: usize) -> Vec<Cluster>;

    /// Ids of clusters containing `(modality, entity)`, resolvable
    /// against [`Self::snapshot`] at the same epoch.
    fn containing(&mut self, modality: usize, entity: u32) -> Vec<u32>;

    /// Per-entity serving stats (None if the entity is in no cluster).
    fn entity_stats(&mut self, modality: usize, entity: u32) -> Option<IndexStats>;

    /// Aggregate stats over the backend's current snapshot.
    fn stats(&mut self) -> IndexStats;

    /// `(cache hits, cache misses)` this backend has served.
    fn cache_stats(&self) -> (u64, u64);
}

/// In-process backend: answers straight from the primary's
/// [`SnapshotCell`] — epoch always equals the last published one.
#[derive(Debug)]
pub struct LocalBackend {
    cell: Arc<SnapshotCell>,
    cache: QueryCache,
}

impl LocalBackend {
    /// Backend over `cell` with the result cache enabled.
    pub fn new(cell: Arc<SnapshotCell>) -> Self {
        Self::with_cache(cell, true)
    }

    /// Backend over `cell` with the cache explicitly on or off.
    pub fn with_cache(cell: Arc<SnapshotCell>, cache: bool) -> Self {
        Self { cell, cache: QueryCache::new(cache) }
    }

    fn answer(&mut self, key: QueryKey) -> Answer {
        let snap = self.cell.load();
        answer(&snap, &mut self.cache, key)
    }
}

impl QueryBackend for LocalBackend {
    fn name(&self) -> &'static str {
        "local"
    }

    fn snapshot(&self) -> Arc<EpochSnapshot> {
        self.cell.load()
    }

    fn top_k(&mut self, k: usize) -> Vec<Cluster> {
        match self.answer(QueryKey::TopK(k)) {
            Answer::Clusters(cs) => cs,
            _ => unreachable!("top_k answers are clusters"),
        }
    }

    fn containing(&mut self, modality: usize, entity: u32) -> Vec<u32> {
        match self.answer(QueryKey::Containing(modality as u8, entity)) {
            Answer::Ids(ids) => ids,
            _ => unreachable!("containing answers are ids"),
        }
    }

    fn entity_stats(&mut self, modality: usize, entity: u32) -> Option<IndexStats> {
        match self.answer(QueryKey::EntityStats(modality as u8, entity)) {
            Answer::Stats(s) => s,
            _ => unreachable!("entity_stats answers are stats"),
        }
    }

    fn stats(&mut self) -> IndexStats {
        match self.answer(QueryKey::Stats) {
            Answer::Stats(Some(s)) => s,
            _ => unreachable!("stats answers are stats"),
        }
    }

    fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }
}

/// Shared with [`crate::serve::replica`]: the remote backend reuses the
/// same answer path over its replica's applied snapshot.
pub(crate) fn answer_via(
    snap: &EpochSnapshot,
    cache: &mut QueryCache,
    key: QueryKey,
) -> Answer {
    answer(snap, cache, key)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;

    fn cell_with(clusters: Vec<Cluster>, epoch: u64) -> Arc<SnapshotCell> {
        let cell = Arc::new(SnapshotCell::new());
        cell.publish(EpochSnapshot::build(epoch, clusters, 0));
        cell
    }

    fn fixture() -> Vec<Cluster> {
        let mut a = tricluster(vec![0], vec![0, 1], vec![0, 1]);
        a.support = 4;
        let mut b = tricluster(vec![1, 2], vec![0], vec![0, 1]);
        b.support = 2;
        vec![a, b]
    }

    #[test]
    fn local_backend_answers_match_snapshot() {
        let cell = cell_with(fixture(), 1);
        let mut be = LocalBackend::new(Arc::clone(&cell));
        assert_eq!(be.epoch(), 1);
        let top = be.top_k(1);
        assert_eq!(top[0].support, 4);
        assert_eq!(be.containing(1, 0), vec![0, 1]);
        assert_eq!(be.stats().total_support, 6);
        assert!(be.entity_stats(0, 9).is_none());
    }

    #[test]
    fn cache_hits_on_repeat_and_invalidates_on_epoch_bump() {
        let cell = cell_with(fixture(), 1);
        let mut be = LocalBackend::new(Arc::clone(&cell));
        let first = be.top_k(2);
        let second = be.top_k(2);
        assert_eq!(first, second, "hit must be bit-equal to miss");
        assert_eq!(be.cache_stats(), (1, 1));
        // new epoch: the cached entry must not survive
        cell.publish(EpochSnapshot::build(2, fixture()[..1].to_vec(), 0));
        let third = be.top_k(2);
        assert_eq!(third.len(), 1);
        assert_eq!(be.cache_stats(), (1, 2));
    }

    #[test]
    fn cache_off_backend_answers_identically() {
        let cell = cell_with(fixture(), 1);
        let mut on = LocalBackend::new(Arc::clone(&cell));
        let mut off = LocalBackend::with_cache(cell, false);
        assert_eq!(on.top_k(2), off.top_k(2));
        assert_eq!(on.containing(2, 1), off.containing(2, 1));
        assert_eq!(off.cache_stats(), (0, 0), "disabled cache counts nothing");
    }
}
