//! Deterministic PRNGs and samplers.
//!
//! No `rand` crate is available offline, so this module is the randomness
//! substrate for the whole repo: dataset generators, fault injection,
//! Monte-Carlo density sampling, and the property-testing harness all draw
//! from here. Everything is seedable and reproducible across runs.

/// SplitMix64 — tiny, fast seeder/stream generator (Steele et al., 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main generator (Blackman & Vigna, 2018).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 as the reference implementation prescribes.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    #[inline]
    /// Uniform in `[0, n)` as usize.
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform in an inclusive range.
    pub fn range(&mut self, lo: u64, hi_inclusive: u64) -> u64 {
        lo + self.below(hi_inclusive - lo + 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = crate::util::hash::FxHashSet::default();
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.usize_below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Standard normal via Box–Muller (one value; mate discarded).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

/// Bounded Zipf sampler over `{0, .., n-1}` with exponent `s`, using the
/// rejection-inversion method of Hörmann & Derflinger (1996). Used for the
/// BibSonomy-like and tri-frames generators where tag/frame popularity is
/// heavy-tailed.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dense: Option<Vec<f64>>, // small-n exact CDF fallback
}

impl Zipf {
    /// Zipf(s) sampler over `{0, .., n-1}` (dense CDF for small n,
    ///  rejection sampling otherwise).
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1);
        if n <= 64 {
            // Exact CDF for small supports.
            let mut cdf = Vec::with_capacity(n as usize);
            let mut acc = 0.0;
            for k in 1..=n {
                acc += (k as f64).powf(-s);
                cdf.push(acc);
            }
            let total = acc;
            for c in cdf.iter_mut() {
                *c /= total;
            }
            return Self { n, s, h_x1: 0.0, h_n: 0.0, dense: Some(cdf) };
        }
        let h = |x: f64, s: f64| -> f64 {
            if (s - 1.0).abs() < 1e-12 {
                (x).ln()
            } else {
                (x.powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        Self {
            n,
            s,
            h_x1: h(1.5, s) - 1.0,
            h_n: h(n as f64 + 0.5, s),
            dense: None,
        }
    }

    /// Draw one rank (0 = most popular).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if let Some(cdf) = &self.dense {
            let u = rng.f64();
            let idx = cdf.partition_point(|&c| c < u);
            return (idx as u64).min(self.n - 1);
        }
        let s = self.s;
        let h_inv = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-12 {
                x.exp()
            } else {
                (1.0 + (1.0 - s) * x).powf(1.0 / (1.0 - s))
            }
        };
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-12 {
                x.ln()
            } else {
                (x.powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n as f64);
            if k - x <= 0.0 || u >= h(k + 0.5) - k.powf(-s) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Rng::new(1);
        let mean: f64 = (0..20_000).map(|_| rng.f64()).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(9);
        let idx = rng.sample_indices(50, 20);
        let mut dedup = idx.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn zipf_small_support_is_monotone() {
        let mut rng = Rng::new(11);
        let z = Zipf::new(10, 1.2);
        let mut counts = [0usize; 10];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[9]);
    }

    #[test]
    fn zipf_large_support_in_range_and_skewed() {
        let mut rng = Rng::new(13);
        let z = Zipf::new(100_000, 1.1);
        let mut head = 0usize;
        for _ in 0..10_000 {
            let v = z.sample(&mut rng);
            assert!(v < 100_000);
            if v < 100 {
                head += 1;
            }
        }
        // heavy tail: the first 0.1% of the support draws >30% of the mass
        assert!(head > 3_000, "head={head}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(17);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.06, "var={var}");
    }
}
