//! Distributed multimodal clustering — the paper's §4.1 contribution:
//! three chained MapReduce stages computing cumuli, assembling clusters,
//! and deduplicating with an exact support-density threshold.

pub mod app;
pub mod stages;

pub use app::{run_mmc, MmcConfig, MmcResult};
