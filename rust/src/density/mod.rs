//! Density engines — the post-processing hot spot of OAC-triclustering.
//!
//! The paper names "approximate tricluster density estimation (e.g.,
//! employing the Monte Carlo approach)" as one of the two hardest
//! problems of the method (§7). This module provides three engines with
//! one interface and an ablation bench comparing them (A2):
//!
//! * [`ExactEngine`]   — exact counting: per-(g, m) `u64` bitset rows +
//!   popcount (64 cells per word-AND), degrading to roaring-style
//!   compressed rows ([`CompressedRows`], `O(|I|)` memory) when the flat
//!   table trips its byte cap, with the scalar hash-membership probe
//!   (`O(volume)`/cluster) as oracle and small-workload path; the built
//!   row table is cached across calls, keyed by the context revision;
//! * [`XlaEngine`]     — the AOT JAX/Pallas kernel: dense 64³ tiles ×
//!                       batched cluster masks on the MXU (via PJRT);
//! * [`MonteCarloEngine`] — unbiased sampling, `O(samples)`/cluster,
//!                       optionally through the AOT mc artifact.

pub mod compressed;
pub mod exact;
pub mod monte_carlo;
pub mod tiling;
pub mod xla_engine;

pub use compressed::{densities_compressed, CompressedRows};
pub use exact::{count_bitset, densities_bitset, densities_scalar, ExactEngine};
pub use monte_carlo::MonteCarloEngine;
pub use tiling::{bit_mask, bit_mask_count_range, BitRows, DenseTiles};
pub use xla_engine::XlaEngine;

use crate::core::context::TriContext;
use crate::core::pattern::Cluster;

/// A density engine maps clusters to exact or estimated cuboid densities
/// over the given context.
pub trait DensityEngine {
    /// Short engine id (`exact` / `mc` / `xla`).
    fn name(&self) -> &'static str;

    /// Densities ρ(c) = |cuboid ∩ I| / volume for each cluster.
    fn densities(&mut self, ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64>;
}
