//! The observability invariant, tested end to end: telemetry only
//! OBSERVES. For any random polyadic context, enabling the recorder
//! changes nothing about what `oac::mine_online` or any of the five
//! `exec::` backends mine — components, supports, densities are
//! bit-identical with tracing on or off. And for a fixed seed the span
//! MULTISET (names, per-thread nesting, counts) is deterministic run to
//! run, so traces are diffable artefacts, not noise.
//!
//! The recorder is a process-global, so every test here serialises on
//! one lock and restores the disabled state through an RAII guard.

mod common;

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

use common::{assert_same, random_ctx, sorted};
use tricluster::core::pattern::Cluster;
use tricluster::exec::{run_named, ExecTuning, BACKENDS};
use tricluster::oac::{mine_online, Constraints};
use tricluster::obs;
use tricluster::util::proptest_lite::{assert_prop, Gen};

/// Tests that touch the global recorder must not interleave.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poison| poison.into_inner())
}

/// However a test exits (including by panic), leave the recorder
/// disabled and empty for whoever runs next.
struct ObsOff;
impl Drop for ObsOff {
    fn drop(&mut self) {
        obs::disable();
        obs::reset();
    }
}

/// Random context → mine with the recorder off, then again with it on
/// (online miner + all five backends) → exact cluster-set equality.
#[test]
fn prop_results_identical_with_telemetry_on() {
    let _g = lock();
    let _off = ObsOff;
    assert_prop(16, |g: &mut Gen| {
        let arity = 3 + g.usize_below(2);
        let universe = 2 + g.u32_below(6);
        let n_tuples = 1 + g.usize_below(150);
        let ctx = random_ctx(g, arity, universe, n_tuples);
        let theta = if g.bool(0.5) { 0.0 } else { g.f64() * 0.5 };
        let cons = Constraints { min_density: theta, min_support: 0 };
        let tune = ExecTuning {
            workers: 1 + g.usize_below(3),
            tasks: 1 + g.usize_below(6),
            nodes: 1 + g.usize_below(4),
            node_slots: 1 + g.usize_below(3),
            straggler_prob: if g.bool(0.5) { g.f64() } else { 0.0 },
            speculation: g.bool(0.5),
            cost_ms_per_record: if g.bool(0.5) { Some(0.01) } else { None },
            parallel_ingest: g.bool(0.5),
            seed: 0x0B5 ^ n_tuples as u64,
            ..ExecTuning::default()
        };

        obs::disable();
        obs::reset();
        let ref_online = sorted(mine_online(&ctx, &cons));
        let mut ref_backends: Vec<Vec<Cluster>> = Vec::new();
        for backend in BACKENDS {
            let run = run_named(backend, &ctx, theta, &tune)
                .map_err(|e| format!("{backend} (off): {e}"))?;
            ref_backends.push(sorted(run.clusters));
        }

        obs::enable();
        let on_online = sorted(mine_online(&ctx, &cons));
        assert_same(&ref_online, &on_online, "mine_online")?;
        for (i, backend) in BACKENDS.iter().enumerate() {
            let run = run_named(backend, &ctx, theta, &tune)
                .map_err(|e| format!("{backend} (on): {e}"))?;
            assert_same(
                &ref_backends[i],
                &sorted(run.clusters),
                &format!("{backend} (arity {arity}, {n_tuples} tuples, θ={theta:.3})"),
            )?;
        }
        // the enabled arm must actually have recorded something
        if obs::snapshot().counters.is_empty() {
            return Err("recorder enabled but no counters landed".to_string());
        }
        obs::disable();
        obs::reset();
        Ok(())
    });
}

/// Reconstruct the span-path multiset from the raw B/E stream: per-tid
/// stacks give each `B` its nesting path (`outer/inner`), and every `E`
/// must match its thread's top of stack. Tids are deliberately dropped
/// from the key — pool workers get fresh tids per run; only the path
/// content is stable.
fn span_paths(events: &[obs::TraceEvent]) -> BTreeMap<String, usize> {
    let mut stacks: BTreeMap<u32, Vec<String>> = BTreeMap::new();
    let mut paths: BTreeMap<String, usize> = BTreeMap::new();
    for ev in events {
        let stack = stacks.entry(ev.tid).or_default();
        if ev.begin {
            stack.push(ev.name.clone());
            *paths.entry(stack.join("/")).or_insert(0) += 1;
        } else {
            let top = stack.pop().unwrap_or_else(|| {
                panic!("E {:?} without a B on tid {}", ev.name, ev.tid)
            });
            assert_eq!(top, ev.name, "unbalanced span nesting on tid {}", ev.tid);
        }
    }
    for (tid, stack) in stacks {
        assert!(stack.is_empty(), "tid {tid} left open spans: {stack:?}");
    }
    paths
}

/// Fixed seed + the per-record cost model → two ClusterSim runs produce
/// the identical span-path multiset (timestamps differ, structure does
/// not), with the expected taxonomy present and B/E balanced per tid.
#[test]
fn span_tree_deterministic_for_fixed_seed() {
    let _g = lock();
    let _off = ObsOff;
    let ctx = tricluster::datasets::synthetic::k1(6).inner;
    let tune = ExecTuning {
        workers: 3,
        tasks: 5,
        nodes: 3,
        node_slots: 2,
        straggler_prob: 0.3,
        speculation: true,
        cost_ms_per_record: Some(0.01),
        seed: 0xDE7,
        ..ExecTuning::default()
    };
    let runs: Vec<BTreeMap<String, usize>> = (0..2)
        .map(|_| {
            obs::reset();
            obs::enable();
            let run = run_named("cluster", &ctx, 0.0, &tune).unwrap();
            assert!(!run.clusters.is_empty());
            let events = obs::take_trace();
            obs::disable();
            span_paths(&events)
        })
        .collect();
    assert_eq!(
        runs[0], runs[1],
        "span multiset must be deterministic for a fixed seed"
    );
    assert!(
        runs[0].keys().any(|p| p.starts_with("exec.run.cluster")),
        "missing the exec.run root span: {:?}",
        runs[0].keys().collect::<Vec<_>>()
    );
    assert!(
        runs[0].keys().any(|p| p.contains(".task")),
        "missing per-task spans: {:?}",
        runs[0].keys().collect::<Vec<_>>()
    );
}
