//! Zero-dependency observability plane: counters, gauges, log2
//! histograms, and hierarchical spans behind a global no-op-by-default
//! handle.
//!
//! The paper's core claims are measurements — per-stage M/R cost,
//! scalability under distribution — so the stack needs to SEE where a
//! makespan went. This module is the single telemetry substrate every
//! layer reports through:
//!
//! * **Recorder** ([`recorder`]): counters, gauges, and log2-bucketed
//!   histograms accumulated in per-thread shards (one uncontended mutex
//!   per thread, merged deterministically at snapshot time — the same
//!   shard-then-merge discipline as [`crate::util::pool::parallel_fold`];
//!   counter addition commutes, so totals are identical for any thread
//!   interleaving).
//! * **Spans** ([`span`]): RAII guards capturing wall time, records
//!   in/out, and bytes, with parent/child nesting per thread. Every
//!   span emits a `B`/`E` pair in Chrome `trace_event` format
//!   (`chrome://tracing` / Perfetto loadable — see
//!   docs/ARCHITECTURE.md §Observability) plus a call counter and a
//!   duration histogram in the metrics snapshot.
//! * **Export** ([`export`]): JSON metrics snapshot
//!   (`schema: tricluster-metrics-v1`), Chrome-trace JSONL, and a
//!   stderr text table ([`export::render_table`]).
//!
//! # Cost discipline
//!
//! When disabled (the default), every entry point is ONE relaxed atomic
//! load and a branch — [`enabled`] — and the [`span!`] macro skips even
//! the name formatting. Instrumentation is placed at batch/chunk/task
//! granularity, never per tuple, so the hot ingest kernel is untouched
//! either way; `benches/hotpath.rs` measures both modes and
//! `ci/check_bench.rs` gates the disabled-mode overhead at ≤ 3%.
//!
//! # Determinism
//!
//! Enabling telemetry never changes results (property-tested in
//! `rust/tests/obs_equivalence.rs`): the recorder only observes. For a
//! fixed seed the span MULTISET — names, per-thread nesting, counts —
//! is deterministic too; only timestamps and durations vary run to run.
//!
//! # Example
//!
//! ```
//! use tricluster::obs;
//! obs::reset();
//! obs::enable();
//! {
//!     let mut s = tricluster::span!("demo.work");
//!     s.records_in(3);
//!     obs::counter("demo.widgets", 3);
//! }
//! let snap = obs::snapshot();
//! assert_eq!(snap.counters["demo.widgets"], 3);
//! assert_eq!(snap.counters["demo.work.calls"], 1);
//! assert_eq!(obs::take_trace().len(), 2); // balanced B + E
//! obs::disable();
//! obs::reset();
//! ```

pub mod export;
pub mod recorder;
pub mod span;

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

pub use recorder::{Hist, Snapshot};
pub use span::{Span, TraceEvent};

/// The one global switch. Relaxed is enough: telemetry has no ordering
/// relationship with the data it observes.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// True when the global recorder is on. This is the single branch the
/// instrumented hot paths pay when telemetry is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the global recorder on (also pins the trace-timestamp epoch on
/// first use).
pub fn enable() {
    recorder::recorder().touch_epoch();
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn the global recorder off. Already-open spans still close their
/// `B`/`E` pairs, so traces stay balanced.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Clear every counter, gauge, histogram, and buffered trace event.
/// Do not call while spans are open (their `E` events would orphan).
pub fn reset() {
    recorder::recorder().reset();
}

/// Add `delta` to counter `name` (no-op when disabled).
#[inline]
pub fn counter(name: &str, delta: u64) {
    if enabled() {
        recorder::recorder().counter(name, delta);
    }
}

/// Set gauge `name` to `value` for this thread; the snapshot keeps the
/// MAX across threads, so gauges are high-water marks (no-op when
/// disabled).
#[inline]
pub fn gauge(name: &str, value: f64) {
    if enabled() {
        recorder::recorder().gauge(name, value);
    }
}

/// Record `value` into the log2-bucketed histogram `name` (no-op when
/// disabled). Durations go in as microseconds by convention (`*.us`).
#[inline]
pub fn observe(name: &str, value: u64) {
    if enabled() {
        recorder::recorder().observe(name, value);
    }
}

/// Microseconds since the recorder epoch — the trace timestamp clock.
#[inline]
pub fn now_us() -> u64 {
    recorder::recorder().now_us()
}

/// Merged view of every shard's counters/gauges/histograms.
pub fn snapshot() -> Snapshot {
    recorder::recorder().snapshot()
}

/// Drain every buffered trace event (grouped by thread, per-thread
/// order preserved — `B`/`E` pairs stay balanced per `tid`).
pub fn take_trace() -> Vec<TraceEvent> {
    recorder::recorder().take_trace()
}

/// Wall-clock stopwatch — THE clock primitive of the crate (spans,
/// benches, and the experiment harness all time through it;
/// `util::stats` re-exports it for its older call sites).
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Time since `start`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since `start`, ms.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Run `f` under a span named `name`, returning its result and the
/// elapsed milliseconds. The milliseconds are measured whether or not
/// the recorder is enabled — this is the one-off-timer replacement for
/// the experiment harness (`let t = Timer::start(); ...; t.elapsed_ms()`
/// blocks fold onto it), with the span riding along for free when
/// telemetry is on.
pub fn time_ms<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let span =
        if enabled() { Span::begin(name.to_string()) } else { Span::disabled() };
    let t = Timer::start();
    let out = f();
    let ms = t.elapsed_ms();
    drop(span);
    (out, ms)
}

/// Open a [`Span`](crate::obs::Span) guard: `let mut s =
/// span!("exec.{}-map", label);`. When the recorder is disabled this is
/// one branch — the format arguments are never evaluated.
#[macro_export]
macro_rules! span {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        if $crate::obs::enabled() {
            $crate::obs::Span::begin(format!($fmt $(, $arg)*))
        } else {
            $crate::obs::Span::disabled()
        }
    };
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// Tests that enable the GLOBAL recorder must serialise; everything
    /// obs-touching in this crate's unit tests goes through this lock.
    pub(crate) fn lock() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        let m = LOCK.get_or_init(|| Mutex::new(()));
        m.lock().unwrap_or_else(|poison| poison.into_inner())
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = lock();
        disable();
        reset();
        counter("t.never", 5);
        observe("t.never.us", 10);
        gauge("t.never.g", 1.0);
        let _s = crate::span!("t.never.span");
        drop(_s);
        let snap = snapshot();
        assert!(snap.counters.is_empty());
        assert!(snap.gauges.is_empty());
        assert!(snap.hists.is_empty());
        assert!(take_trace().is_empty());
    }

    #[test]
    fn counters_merge_across_threads() {
        let _g = lock();
        reset();
        enable();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        counter("t.merge", 2);
                    }
                });
            }
        });
        counter("t.merge", 1);
        let snap = snapshot();
        assert_eq!(snap.counters["t.merge"], 801);
        disable();
        reset();
    }

    #[test]
    fn gauge_keeps_max_and_hist_buckets() {
        let _g = lock();
        reset();
        enable();
        gauge("t.queue", 3.0);
        gauge("t.queue", 7.0);
        gauge("t.queue", 5.0);
        for v in [0u64, 1, 2, 3, 1024] {
            observe("t.vals", v);
        }
        let snap = snapshot();
        assert_eq!(snap.gauges["t.queue"], 7.0);
        let h = &snap.hists["t.vals"];
        assert_eq!(h.count, 5);
        assert_eq!(h.sum, 1030);
        assert_eq!(h.min, 0);
        assert_eq!(h.max, 1024);
        // 0→bucket 0, 1→1, 2..3→2, 1024→11
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 1);
        assert_eq!(h.buckets[2], 2);
        assert_eq!(h.buckets[11], 1);
        disable();
        reset();
    }

    #[test]
    fn spans_nest_and_balance() {
        let _g = lock();
        reset();
        enable();
        {
            let mut outer = crate::span!("t.outer");
            outer.records_in(10);
            {
                let mut inner = crate::span!("t.inner");
                inner.records_out(4);
                inner.bytes(64);
            }
        }
        let events = take_trace();
        assert_eq!(events.len(), 4);
        // same thread: B(outer) B(inner) E(inner) E(outer)
        assert!(events[0].begin && events[0].name == "t.outer");
        assert!(events[1].begin && events[1].name == "t.inner");
        assert!(!events[2].begin && events[2].name == "t.inner");
        assert!(!events[3].begin && events[3].name == "t.outer");
        assert_eq!(events[2].records_out, 4);
        assert_eq!(events[2].bytes, 64);
        assert_eq!(events[3].records_in, 10);
        assert!(events[3].ts_us >= events[0].ts_us);
        let snap = snapshot();
        assert_eq!(snap.counters["t.outer.calls"], 1);
        assert_eq!(snap.hists["t.inner.us"].count, 1);
        disable();
        reset();
    }

    #[test]
    fn time_ms_measures_even_when_disabled() {
        let _g = lock();
        disable();
        reset();
        let (out, ms) = time_ms("t.timed", || 41 + 1);
        assert_eq!(out, 42);
        assert!(ms >= 0.0);
        assert!(snapshot().counters.is_empty());
    }
}
