//! # tricluster — Triclustering in a Big Data Setting
//!
//! A production-style reproduction of Egurnov, Ignatov & Tochilkin,
//! *"Triclustering in Big Data Setting"* (2020): prime OAC-triclustering,
//! its multimodal (N-ary) generalisation, the three-stage MapReduce
//! algorithm, and parallel many-valued (NOAC) triclustering — implemented
//! as a three-layer Rust + JAX/Pallas stack (see DESIGN.md).
//!
//! Layer 3 (this crate) owns the full pipeline: mini-Hadoop M/R engine,
//! online/basic OAC algorithms, the 3-stage multimodal clustering, NOAC,
//! dataset generators, density engines, and the PJRT runtime that executes
//! the AOT-compiled JAX/Pallas density kernels from `artifacts/`.

pub mod coordinator;
pub mod core;
pub mod datasets;
pub mod density;
pub mod hadoop;
pub mod mmc;
pub mod noac;
pub mod oac;
pub mod runtime;
pub mod spark;
pub mod util;
