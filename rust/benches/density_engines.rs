//! Bench: ablation A2 — density engines (exact hash counting vs the
//! XLA/Pallas tile kernel vs Monte-Carlo), the §7 "hardest problem".

use tricluster::coordinator::ablations;

fn main() -> anyhow::Result<()> {
    eprintln!("density engine bench ...");
    let report = ablations::density_engines()?;
    println!("{}", report.render());
    report.write_csv()?;
    Ok(())
}
