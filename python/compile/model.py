"""Layer-2 JAX graphs for the triclustering density engine.

These are the compute graphs the Rust coordinator executes through PJRT
(rust/src/runtime). Each function here is jitted by aot.py, calls the
Layer-1 Pallas kernels where there is kernel-shaped work, and is lowered
ONCE to HLO text under artifacts/. Python never runs on the request path.

Graphs:
  * density_graph  — counts + volumes for a batch of cluster masks over one
                     incidence tile (Table 3/4 post-processing hot spot,
                     ablation A2).
  * delta_graph    — δ-band masks + per-fiber cardinalities for NOAC
                     (§3.2/§6; cardinalities feed the minsup constraint).
  * mc_graph       — Monte-Carlo density estimate from sampled coordinates
                     (§7 proposed extension; engine `density::MonteCarlo`).
"""

import jax.numpy as jnp

from .kernels import density as density_kernel
from .kernels import delta as delta_kernel


def density_graph(tensor, xmask, ymask, zmask):
    """Counts (Pallas, MXU) and volumes (XLA-fused reductions) per cluster.

    Returns (counts f32[K], volumes f32[K]). Density over a multi-tile
    context is assembled host-side: ρ = Σ_tiles counts / volumes_full.
    """
    counts = density_kernel.density_counts(tensor, xmask, ymask, zmask)
    volumes = (xmask.sum(axis=1) * ymask.sum(axis=1) * zmask.sum(axis=1))
    return counts, volumes


def delta_graph(delta, values, present, centers):
    """δ-band masks (Pallas, VPU) plus per-fiber cardinalities.

    Returns (masks f32[K,L], cards f32[K]); cards = |δ-prime set| per fiber,
    consumed by NOAC's minimal-cardinality (minsup) validity check so the
    coordinator needs a single device round-trip per slab.
    """
    masks = delta_kernel.delta_masks(delta, values, present, centers)
    cards = masks.sum(axis=1)
    return masks, cards


def mc_graph(tensor, coords):
    """Monte-Carlo density estimate ρ̂ = mean(T[coords]) (f32[])."""
    vals = tensor[coords[:, 0], coords[:, 1], coords[:, 2]]
    return (jnp.mean(vals),)
