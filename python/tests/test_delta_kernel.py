"""Pallas δ-band kernel vs oracle (paper §3.2 many-valued triclustering)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import delta, ref


def run_kernel(d, v, p, c):
    return np.asarray(delta.delta_masks(
        jnp.array([d], dtype=jnp.float32), jnp.array(v), jnp.array(p),
        jnp.array(c)))


@settings(max_examples=25, deadline=None)
@given(
    k=st.sampled_from([1, 4, 8, 64]),
    nblk=st.integers(1, 4),
    d=st.floats(0.0, 250.0),
    scale=st.floats(1.0, 500.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_delta_matches_ref_hypothesis(k, nblk, d, scale, seed):
    rng = np.random.default_rng(seed)
    l = delta.L_BLOCK * nblk
    v = (rng.normal(size=(k, l)) * scale).astype(np.float32)
    p = (rng.random((k, l)) < 0.5).astype(np.float32)
    c = (rng.normal(size=(k,)) * scale).astype(np.float32)
    got = run_kernel(d, v, p, c)
    want = np.asarray(ref.delta_ref(v, p, c, d))
    np.testing.assert_array_equal(got, want)


def test_delta_zero_keeps_exact_matches_only():
    # δ=0 recovers the binary prime operator on W={0,1} (paper §3.2).
    v = np.array([[1.0, 2.0, 1.0, 3.0]] * 64, np.float32)
    v = np.pad(v, ((0, 0), (0, delta.L_BLOCK - 4)), constant_values=99.0)
    p = np.ones_like(v)
    c = np.ones(64, np.float32)
    got = run_kernel(0.0, v, p, c)
    assert got[:, 0].all() and got[:, 2].all()
    assert not got[:, 1].any() and not got[:, 3].any()


def test_absent_elements_never_selected():
    rng = np.random.default_rng(7)
    v = np.zeros((8, delta.L_BLOCK), np.float32)  # all within any δ
    p = (rng.random(v.shape) < 0.3).astype(np.float32)
    c = np.zeros(8, np.float32)
    got = run_kernel(1e9, v, p, c)
    np.testing.assert_array_equal(got, p)


def test_band_boundary_inclusive():
    v = np.full((1, delta.L_BLOCK), 10.0, np.float32)
    p = np.ones_like(v)
    c = np.array([0.0], np.float32)
    assert run_kernel(10.0, v, p, c).all()   # |10-0| <= 10 inclusive
    assert not run_kernel(9.999, v, p, c).any()


def test_l_not_multiple_of_block_raises():
    v = np.zeros((4, 100), np.float32)
    with pytest.raises(ValueError):
        run_kernel(1.0, v, v, np.zeros(4, np.float32))
