//! The serving layer: a sharded, incrementally-updatable, queryable
//! triclustering index — ingest → shard → merge → query.
//!
//! The paper's central observation is that OAC tuples are processed
//! independently: Alg. 1 is one-pass and embarrassingly partitionable.
//! This module turns that from a batch property into a SERVICE
//! architecture (the ROADMAP north star — serve heavy query traffic
//! while the stream keeps arriving):
//!
//! * [`router`] — hash-routes incoming batches to shards with bounded
//!   in-flight batching/backpressure on [`crate::util::pool`];
//! * [`shard`] — each shard runs an incremental [`crate::oac::OnlineMiner`]
//!   over its partition and exposes epoch-tagged deltas;
//! * [`merge`] — the compactor unions per-shard partial cumuli by
//!   subrelation key (the §4.1 first reduce, made incremental) into a
//!   globally-correct index, deduplicated with the partitioned-parallel
//!   [`crate::oac::online::dedup_generated_parallel`] (bit-for-bit
//!   equal to the sequential [`crate::oac::online::dedup_generated`]
//!   the online miner keeps as its oracle);
//! * [`query`] — top-k by density, membership lookup, aggregate stats;
//! * [`snapshot`] — JSON snapshot/restore for restart recovery;
//! * [`cluster`] — the service placed on a simulated N-node cluster:
//!   shard placement via [`crate::exec::Placement`], shuffle-cost
//!   accounting, and node churn with snapshot replay.
//!
//! Correctness invariant (unit- and property-tested): for any shard
//! count, batch chunking, and compaction schedule, the compacted index
//! equals single-miner [`crate::oac::mine_online`] output — same
//! components, supports, and densities.

pub mod cluster;
pub mod merge;
pub mod query;
pub mod router;
pub mod shard;
pub mod snapshot;

pub use cluster::{ServeSim, ServeSimConfig, ServeSimStats};
pub use merge::Compactor;
pub use query::{IndexStats, QueryEngine};
pub use router::{Router, RouterStats};
pub use shard::{Shard, ShardDelta};

use std::path::Path;

use crate::core::pattern::Cluster;
use crate::core::tuple::NTuple;
use crate::oac::post::Constraints;
use crate::util::pool;

/// Configuration of a [`TriclusterService`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Relation arity (3 for triadic contexts, up to
    /// [`crate::core::tuple::MAX_ARITY`]).
    pub arity: usize,
    /// Number of shards (each one an incremental miner).
    pub shards: usize,
    /// Router high-water mark, in queued tuples: crossing it triggers a
    /// parallel drain wave (backpressure).
    pub max_pending: usize,
    /// Worker threads for drain waves (one task per shard per wave).
    pub workers: usize,
    /// Constraints applied when materialising the cluster index.
    pub constraints: Constraints,
}

impl ServeConfig {
    /// Config with backpressure/worker defaults.
    pub fn new(arity: usize, shards: usize) -> Self {
        Self {
            arity,
            shards: shards.max(1),
            max_pending: 64 * 1024,
            workers: pool::default_workers(),
            constraints: Constraints::none(),
        }
    }

    /// Set the constraints applied at index materialisation.
    pub fn with_constraints(mut self, constraints: Constraints) -> Self {
        self.constraints = constraints;
        self
    }
}

/// Live service stats (router + compactor counters).
#[derive(Debug, Clone)]
pub struct ServiceStats {
    /// Shard count.
    pub shards: usize,
    /// Tuples accepted by the router so far.
    pub tuples: usize,
    /// Tuples queued but not yet mined.
    pub pending: usize,
    /// Backpressure drain waves.
    pub drains: usize,
    /// Distinct subrelation keys in the global merged index.
    pub distinct_keys: usize,
    /// Generating tuples merged into the global index.
    pub merged: usize,
    /// Cluster count of the last compaction (None if never compacted or
    /// dirty).
    pub clusters: Option<usize>,
    /// Last compacted epoch per shard.
    pub epochs: Vec<u64>,
    /// Tuples mined by each shard (load-balance view).
    pub shard_sizes: Vec<usize>,
}

/// The sharded incremental triclustering service.
///
/// Typical loop: `ingest` batches as they arrive (the router drains under
/// backpressure automatically), `compact` at serving points, then `query`
/// the compacted index. `snapshot_to`/`restore_from` persist across
/// restarts.
#[derive(Debug)]
pub struct TriclusterService {
    cfg: ServeConfig,
    pub(crate) router: Router,
    compactor: Compactor,
}

impl TriclusterService {
    /// Service with fresh shards and an empty global index.
    pub fn new(cfg: ServeConfig) -> Self {
        let router = Router::new(cfg.arity, cfg.shards, cfg.max_pending, cfg.workers);
        let compactor = Compactor::new(cfg.shards);
        Self { cfg, router, compactor }
    }

    /// The configuration this service runs under.
    pub fn cfg(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Route one batch into the shard queues (drains under backpressure).
    pub fn ingest(&mut self, batch: &[NTuple]) {
        self.router.submit(batch);
    }

    /// Force-drain every shard queue (e.g. end of stream).
    pub fn flush(&mut self) {
        self.router.drain();
    }

    /// Flush, then merge every shard's pending delta into the global
    /// index. After `compact`, `clusters`/`query` reflect every ingested
    /// tuple.
    pub fn compact(&mut self) {
        let mut span = crate::span!("serve.compact");
        self.router.drain();
        self.compactor.pull(self.router.shards_mut());
        span.records_out(self.compactor.generated_len() as u64);
    }

    /// The compacted cluster index under the configured constraints.
    /// (Tuples ingested after the last `compact` are not reflected.)
    pub fn clusters(&mut self) -> &[Cluster] {
        self.compactor.clusters(&self.cfg.constraints)
    }

    /// A query engine over the compacted index.
    pub fn query(&mut self) -> QueryEngine<'_> {
        let constraints = self.cfg.constraints.clone();
        QueryEngine::new(self.compactor.clusters(&constraints))
    }

    /// Live router + compactor counters.
    pub fn stats(&self) -> ServiceStats {
        let r = self.router.stats();
        ServiceStats {
            shards: self.router.num_shards(),
            tuples: r.tuples,
            pending: self.router.pending(),
            drains: r.drains,
            distinct_keys: self.compactor.distinct_keys(),
            merged: self.compactor.generated_len(),
            clusters: self.compactor.cached_len(),
            epochs: self.compactor.epochs().to_vec(),
            shard_sizes: self.router.shards().iter().map(Shard::len).collect(),
        }
    }

    /// Write a restart-recovery snapshot (flushes queued tuples first).
    pub fn snapshot_to(&mut self, path: &Path) -> anyhow::Result<()> {
        snapshot::save(self, path)
    }

    /// Rebuild a service from a snapshot written by [`Self::snapshot_to`].
    pub fn restore_from(path: &Path) -> anyhow::Result<Self> {
        snapshot::load(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oac::mine_online;

    fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
        cs.sort_by(|a, b| a.components.cmp(&b.components));
        cs
    }

    #[test]
    fn sharded_equals_sequential_on_k1() {
        let ctx = crate::datasets::synthetic::k1(8).inner;
        let reference = sorted(mine_online(&ctx, &Constraints::none()));
        for shards in [1, 2, 4, 7] {
            let mut svc = TriclusterService::new(ServeConfig::new(3, shards));
            for chunk in ctx.tuples().chunks(97) {
                svc.ingest(chunk);
            }
            svc.compact();
            let got = sorted(svc.clusters().to_vec());
            assert_eq!(got.len(), reference.len(), "shards={shards}");
            for (a, b) in got.iter().zip(&reference) {
                assert_eq!(a.components, b.components);
                assert_eq!(a.support, b.support);
            }
        }
    }

    #[test]
    fn constraints_applied_at_materialisation() {
        let ctx = crate::datasets::synthetic::k2(4).inner;
        let cons = Constraints { min_density: 0.5, min_support: 2 };
        let reference = sorted(mine_online(&ctx, &cons));
        let mut svc = TriclusterService::new(
            ServeConfig::new(3, 3).with_constraints(cons),
        );
        svc.ingest(ctx.tuples());
        svc.compact();
        let got = sorted(svc.clusters().to_vec());
        assert_eq!(got.len(), reference.len());
    }

    #[test]
    fn query_after_compact_sees_all_tuples() {
        let ctx = crate::datasets::synthetic::k2(3).inner; // 3 dense blocks
        let mut svc = TriclusterService::new(ServeConfig::new(3, 4));
        svc.ingest(ctx.tuples());
        svc.compact();
        let q = svc.query();
        assert_eq!(q.len(), 3);
        let top = q.top_k_by_density(1);
        assert!((top[0].support_density() - 1.0).abs() < 1e-12);
        // block 0 contains entity 0 in every modality
        assert_eq!(q.containing(0, 0).len(), 1);
        // entity of block 1 (offset 3) is in the second block's cluster only
        assert_eq!(q.containing(1, 3).len(), 1);
        let stats = svc.stats();
        assert_eq!(stats.tuples, ctx.len());
        assert_eq!(stats.pending, 0);
        assert_eq!(stats.clusters, Some(3));
    }

    #[test]
    fn stats_track_pending_and_compaction() {
        let mut svc = TriclusterService::new(ServeConfig::new(3, 2));
        svc.ingest(&[NTuple::triple(0, 0, 0), NTuple::triple(1, 1, 1)]);
        let s = svc.stats();
        assert_eq!(s.tuples, 2);
        assert_eq!(s.pending, 2, "below watermark: still queued");
        assert_eq!(s.clusters, None, "never compacted");
        svc.compact();
        let s = svc.stats();
        assert_eq!(s.pending, 0);
        assert_eq!(s.merged, 2);
        svc.clusters();
        assert_eq!(svc.stats().clusters, Some(2));
    }
}
