//! Exact density by hash-membership counting (the reference engine).

use crate::core::context::TriContext;
use crate::core::pattern::Cluster;
use crate::density::DensityEngine;

#[derive(Default)]
/// Exact per-cluster density over the raw tuple set (the reference
///  the sampled and compiled engines are validated against).
pub struct ExactEngine;

impl DensityEngine for ExactEngine {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn densities(&mut self, ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64> {
        clusters
            .iter()
            .map(|c| {
                let vol = c.volume();
                if vol == 0.0 {
                    return 0.0;
                }
                let mut hit = 0u64;
                for &g in &c.components[0] {
                    for &m in &c.components[1] {
                        for &b in &c.components[2] {
                            if ctx.contains(g, m, b) {
                                hit += 1;
                            }
                        }
                    }
                }
                hit as f64 / vol
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;
    use crate::datasets::synthetic::k2;

    #[test]
    fn dense_block_is_one() {
        let ctx = k2(3);
        let mut e = ExactEngine;
        let c = tricluster(vec![0, 1, 2], vec![0, 1, 2], vec![0, 1, 2]);
        assert_eq!(e.densities(&ctx, &[c]), vec![1.0]);
    }

    #[test]
    fn cross_block_is_sparse() {
        let ctx = k2(3);
        let mut e = ExactEngine;
        // spanning two blocks: only the two diagonal blocks hit → 2·27 of
        // 6³ = 216 cells
        let c = tricluster(
            vec![0, 1, 2, 3, 4, 5],
            vec![0, 1, 2, 3, 4, 5],
            vec![0, 1, 2, 3, 4, 5],
        );
        let d = e.densities(&ctx, &[c])[0];
        assert!((d - 54.0 / 216.0).abs() < 1e-12);
    }
}
