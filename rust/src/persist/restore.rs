//! Folding a replayed segment sequence into one restorable image.
//!
//! Replay hands [`fold`] the decoded payloads in sequence order. A
//! **full** segment replaces each shard's tuple history and cumuli
//! outright; a **delta** segment appends its raw per-key values and new
//! tuples on top (the values carry multiplicity, exactly as
//! [`crate::serve::ShardDelta`] exported them). After the last segment
//! the accumulated cumuli are sealed — sorted and deduplicated — so the
//! image feeds [`crate::oac::primes::PrimeStore::adopt`] directly: bulk
//! page adoption, no per-tuple re-ingest. A log of pure deltas folds
//! from the empty base, so incremental checkpoints alone are restorable.

use std::collections::BTreeMap;

use crate::core::pattern::Cluster;
use crate::core::tuple::{NTuple, SubRelation};

use super::segment::{SegmentConfig, SegmentKind, SegmentPayload};
use super::SegmentError;

/// One shard's restored state: sealed cumuli ready for bulk adoption.
#[derive(Debug, Clone)]
pub struct ShardImage {
    /// The shard's ingest epoch at the last folded segment.
    pub epoch: u64,
    /// Full generating-tuple history, in ingest order.
    pub tuples: Vec<NTuple>,
    /// Cumuli as `⟨subrelation, strictly sorted values⟩`.
    pub cumuli: Vec<(SubRelation, Vec<u32>)>,
}

/// The folded log: everything needed to rebuild a service.
#[derive(Debug, Clone)]
pub struct LogImage {
    /// Relation arity.
    pub arity: usize,
    /// Service epoch of the last folded segment.
    pub epoch: u64,
    /// Segments folded (torn tails excluded).
    pub segments: usize,
    /// Encoded bytes decoded during replay.
    pub bytes: u64,
    /// Service configuration from the last folded segment.
    pub config: SegmentConfig,
    /// Per-shard restored state.
    pub shards: Vec<ShardImage>,
    /// The cluster index from the last segment that carried one (deltas
    /// may omit it) — an integrity cross-check for the restored miner.
    pub clusters: Vec<Cluster>,
}

/// Fold decoded payloads (sequence order) into one [`LogImage`].
/// `bytes` is the total encoded size replay read, carried through for
/// restore-throughput accounting.
pub fn fold(payloads: Vec<SegmentPayload>, bytes: u64) -> Result<LogImage, SegmentError> {
    let first = payloads
        .first()
        .ok_or_else(|| SegmentError::corrupt("empty segment log"))?;
    let arity = first.arity;
    let n_shards = first.shards.len();
    // per-shard accumulator; BTreeMap keeps key order deterministic
    let mut epochs = vec![0u64; n_shards];
    let mut tuples: Vec<Vec<NTuple>> = vec![Vec::new(); n_shards];
    let mut cumuli: Vec<BTreeMap<SubRelation, Vec<u32>>> =
        vec![BTreeMap::new(); n_shards];
    let mut clusters = Vec::new();
    let (mut epoch, mut config) = (first.epoch, first.config.clone());
    for p in &payloads {
        if p.arity != arity || p.shards.len() != n_shards {
            return Err(SegmentError::corrupt(format!(
                "segment {} disagrees with the log head (arity {} vs {arity}, \
                 shards {} vs {n_shards})",
                p.seq,
                p.arity,
                p.shards.len()
            )));
        }
        epoch = p.epoch;
        config = p.config.clone();
        for (s, rec) in p.shards.iter().enumerate() {
            match p.kind {
                SegmentKind::Full => {
                    epochs[s] = rec.epoch;
                    tuples[s] = rec.tuples.clone();
                    cumuli[s] = rec
                        .cumuli
                        .iter()
                        .map(|(sub, values)| (*sub, values.clone()))
                        .collect();
                }
                SegmentKind::Delta => {
                    epochs[s] = rec.epoch;
                    tuples[s].extend_from_slice(&rec.tuples);
                    for (sub, values) in &rec.cumuli {
                        cumuli[s].entry(*sub).or_default().extend_from_slice(values);
                    }
                }
            }
        }
        if !p.clusters.is_empty() {
            clusters = p.clusters.clone();
        }
    }
    let shards = epochs
        .into_iter()
        .zip(tuples)
        .zip(cumuli)
        .map(|((epoch, tuples), cumuli)| {
            // seal: delta appends carry multiplicity, adoption wants
            // strictly sorted contents
            let cumuli = cumuli
                .into_iter()
                .map(|(sub, mut values)| {
                    values.sort_unstable();
                    values.dedup();
                    (sub, values)
                })
                .collect();
            ShardImage { epoch, tuples, cumuli }
        })
        .collect();
    Ok(LogImage {
        arity,
        epoch,
        segments: payloads.len(),
        bytes,
        config,
        shards,
        clusters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::segment::ShardRecord;

    fn config() -> SegmentConfig {
        SegmentConfig { max_pending: 1024, workers: 2, min_density: 0.0, min_support: 1 }
    }

    fn payload(kind: SegmentKind, epoch: u64, shards: Vec<ShardRecord>) -> SegmentPayload {
        SegmentPayload {
            seq: 0,
            epoch,
            kind,
            arity: 3,
            config: config(),
            shards,
            clusters: Vec::new(),
            interners: Vec::new(),
        }
    }

    #[test]
    fn delta_appends_full_replaces() {
        let t1 = NTuple::triple(1, 2, 3);
        let t2 = NTuple::triple(1, 2, 5);
        let full = payload(
            SegmentKind::Full,
            1,
            vec![ShardRecord {
                epoch: 1,
                tuples: vec![t1],
                cumuli: vec![(t1.subrelation(2), vec![3])],
            }],
        );
        let delta = payload(
            SegmentKind::Delta,
            2,
            vec![ShardRecord {
                epoch: 2,
                tuples: vec![t2],
                // raw append with multiplicity: 3 shows up again
                cumuli: vec![(t1.subrelation(2), vec![5, 3])],
            }],
        );
        let image = fold(vec![full.clone(), delta], 100).unwrap();
        assert_eq!(image.epoch, 2);
        assert_eq!(image.segments, 2);
        assert_eq!(image.bytes, 100);
        assert_eq!(image.shards[0].tuples, vec![t1, t2]);
        // sealed: sorted, deduplicated
        assert_eq!(image.shards[0].cumuli, vec![(t1.subrelation(2), vec![3, 5])]);
        // a later FULL wipes the delta contribution
        let refresh = payload(
            SegmentKind::Full,
            3,
            vec![ShardRecord {
                epoch: 3,
                tuples: vec![t2],
                cumuli: vec![(t1.subrelation(2), vec![5])],
            }],
        );
        let image = fold(
            vec![full, payload(SegmentKind::Delta, 2, vec![ShardRecord::default()]), refresh],
            0,
        )
        .unwrap();
        assert_eq!(image.shards[0].tuples, vec![t2]);
        assert_eq!(image.shards[0].cumuli, vec![(t1.subrelation(2), vec![5])]);
    }

    #[test]
    fn pure_delta_log_folds_from_empty_base() {
        let t = NTuple::triple(7, 8, 9);
        let delta = payload(
            SegmentKind::Delta,
            1,
            vec![ShardRecord {
                epoch: 1,
                tuples: vec![t],
                cumuli: vec![(t.subrelation(0), vec![7])],
            }],
        );
        let image = fold(vec![delta], 0).unwrap();
        assert_eq!(image.shards[0].tuples, vec![t]);
        assert_eq!(image.shards[0].cumuli, vec![(t.subrelation(0), vec![7])]);
    }

    #[test]
    fn shape_mismatch_and_empty_log_are_corrupt() {
        assert!(matches!(fold(Vec::new(), 0), Err(SegmentError::Corrupt { .. })));
        let one = payload(SegmentKind::Full, 1, vec![ShardRecord::default()]);
        let two = payload(
            SegmentKind::Delta,
            2,
            vec![ShardRecord::default(), ShardRecord::default()],
        );
        assert!(matches!(fold(vec![one, two], 0), Err(SegmentError::Corrupt { .. })));
    }

    #[test]
    fn last_nonempty_cluster_index_wins() {
        let mut a = payload(SegmentKind::Full, 1, vec![ShardRecord::default()]);
        a.clusters = vec![Cluster::from_sorted(vec![vec![1], vec![2], vec![3]])];
        let b = payload(SegmentKind::Delta, 2, vec![ShardRecord::default()]);
        let image = fold(vec![a.clone(), b], 0).unwrap();
        assert_eq!(image.clusters, a.clusters);
    }
}
