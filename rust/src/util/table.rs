//! ASCII table rendering for experiment reports — the bench harness prints
//! the same rows the paper's tables show.

/// Render rows as a boxed, column-aligned table. First row is the header.
pub fn render(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let sep = {
        let mut s = String::from("+");
        for w in &widths {
            s.push_str(&"-".repeat(w + 2));
            s.push('+');
        }
        s
    };
    let mut out = String::new();
    out.push_str(&sep);
    out.push('\n');
    for (ri, row) in rows.iter().enumerate() {
        out.push('|');
        for (i, w) in widths.iter().enumerate() {
            let cell = row.get(i).map(String::as_str).unwrap_or("");
            let pad = w - cell.chars().count();
            out.push(' ');
            out.push_str(cell);
            out.push_str(&" ".repeat(pad + 1));
            out.push('|');
        }
        out.push('\n');
        if ri == 0 {
            out.push_str(&sep);
            out.push('\n');
        }
    }
    out.push_str(&sep);
    out
}

/// Convenience: build a row from displayable items.
#[macro_export]
macro_rules! row {
    ($($x:expr),* $(,)?) => {
        vec![$(format!("{}", $x)),*]
    };
}

/// Format milliseconds the way the paper's tables do (thousands separator).
pub fn fmt_ms(ms: f64) -> String {
    let v = ms.round() as i64;
    let mut s = v.abs().to_string();
    let mut grouped = String::new();
    let bytes = s.as_bytes();
    let n = bytes.len();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (n - i) % 3 == 0 {
            grouped.push(',');
        }
        grouped.push(c);
    }
    s = grouped;
    if v < 0 {
        format!("-{s}")
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = render(&[
            row!["Method", "ms"],
            row!["Online", 368],
            row!["MapReduce", 7124],
        ]);
        assert!(t.contains("| Method    | ms   |"), "{t}");
        assert!(t.lines().all(|l| l.chars().count() == t.lines().next().unwrap().chars().count()));
    }

    #[test]
    fn fmt_ms_groups_thousands() {
        assert_eq!(fmt_ms(368.4), "368");
        assert_eq!(fmt_ms(7124.0), "7,124");
        assert_eq!(fmt_ms(3651072.0), "3,651,072");
        assert_eq!(fmt_ms(-1234.0), "-1,234");
    }
}
