//! `tricluster` — CLI for the Triclustering-in-Big-Data reproduction.
//!
//! Subcommands:
//!   info                    platform, artifacts, dataset inventory
//!   generate                write a dataset to TSV
//!   online                  online OAC-prime / multimodal clustering
//!   mr                      three-stage MapReduce multimodal clustering
//!   noac                    many-valued δ-triclustering (seq/parallel)
//!   density                 density engines over a dataset's clusters
//!   serve-sim               drive the sharded serving layer over streams
//!   experiment              regenerate a paper table/figure

use anyhow::Result;

use tricluster::coordinator::{ablations, experiments, ExpConfig};
use tricluster::core::io;
use tricluster::datasets;
use tricluster::density::{DensityEngine, ExactEngine, MonteCarloEngine, XlaEngine};
use tricluster::mmc::{run_mmc, MmcConfig};
use tricluster::noac::{mine_noac, NoacParams};
use tricluster::oac::{mine_online, Constraints};
use tricluster::util::cli::Args;
use tricluster::util::stats::Timer;
use tricluster::util::table::fmt_ms;

const USAGE: &str = "\
tricluster — OAC multimodal triclustering in a big-data setting

USAGE: tricluster <command> [--flag value]...

COMMANDS
  info
  generate   --dataset <name> --out <file.tsv>
  online     --dataset <name> [--min-density R] [--min-support N] [--show N]
  mr         --dataset <name> [--theta R] [--nodes N] [--fault-prob P]
             [--backend seq|pool|hadoop|spark|cluster] [--workers N]
             [--stragglers P] [--speculation on|off]
             [--placement rr|locality|least] [--node-slots N]
             [--churn P] [--restart-ms MS]
             [--shuffle-ms-per-mib MS] [--shuffle-bytes B]
             [--metrics-out f.json] [--trace-out f.jsonl]
  noac       [--triples N] [--delta D] [--rho R] [--minsup N] [--workers N]
  density    [--edge N] [--engine exact|xla|mc] [--bitset-cap BYTES]
  serve-sim  [--datasets a,b] [--shards N] [--batch N] [--compact-every N]
             [--top K] [--min-density R] [--min-support N] [--snapshot PATH]
             [--snapshot-format segment|json] [--segment-dir DIR]
             [--resident-mib N]
             [--nodes N] [--placement rr|locality|least] [--churn P]
             [--node-slots S] [--source-skew A] [--restart-ms MS]
             [--pipeline on|off] [--replicas N] [--retained N]
             [--query-mix N] [--cache on|off] [--client-node N]
             [--tenants T] [--workload uniform|skew|drift|burst] [--quota N]
             [--metrics-out f.json] [--trace-out f.jsonl]
             (--nodes places shards on a simulated cluster: shuffle costs,
              churn, replay; --replicas adds read replicas fed by delta
              streaming, staleness bounded by --retained; --query-mix N
              drives N seeded queries through the epoch-snapshot query
              plane, --cache toggling the (epoch, query) result cache;
              --tenants T > 1 multiplexes T independent tenant contexts
              onto the shared pool, each fed by a seeded --workload
              generator, ingress capped at --quota tuples/wave, with the
              fairness spread and per-tenant equivalence reported;
              --snapshot writes a binary segment log to PATH (a dir) —
              or legacy JSON to a file with --snapshot-format json;
              --segment-dir journals every compaction delta for replay
              recovery, --resident-mib caps resident arena pages, cold
              pages spilling to disk so contexts larger than RAM stream
              through)
  experiment --id table3|table4|fig2|table5|backends|cluster-scaling|
                  serve-cluster|skew|faults|engines|memory
             [--full] [--config f.ini] [--nodes N] [--runs N] [--workers N]
             [--metrics-out f.json] [--trace-out f.jsonl]

TELEMETRY: --metrics-out writes a JSON metrics snapshot, --trace-out a
Chrome-trace JSONL (chrome://tracing / ui.perfetto.dev). Either flag turns
the recorder on and prints a metrics table to stderr. Works on any command.

DATASETS: imdb k1 k2 k3 ml100k ml250k ml500k ml1m bibsonomy
";

fn main() -> Result<()> {
    let args = Args::from_env();
    // --metrics-out / --trace-out turn the telemetry plane on for the
    // whole run; the export happens even when the command errors, so a
    // failed run still leaves its trace behind
    let telemetry = args.get("metrics-out").is_some() || args.get("trace-out").is_some();
    if telemetry {
        tricluster::obs::enable();
    }
    let result = match args.command.as_deref() {
        Some("info") => info(),
        Some("generate") => generate(&args),
        Some("online") => online(&args),
        Some("mr") => mr(&args),
        Some("noac") => noac(&args),
        Some("density") => density(&args),
        Some("serve-sim") => serve_sim(&args),
        Some("experiment") => experiment(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    if telemetry {
        obs_export(&args)?;
    }
    result
}

/// Write the `--trace-out` / `--metrics-out` artefacts and print the
/// metrics table to stderr (stdout stays clean for the command output).
fn obs_export(args: &Args) -> Result<()> {
    use tricluster::obs::{self, export};
    let snap = obs::snapshot();
    if let Some(path) = args.get("trace-out") {
        let events = obs::take_trace();
        export::write_trace(std::path::Path::new(path), &events)?;
        eprintln!(
            "trace: {path} ({} events; load in chrome://tracing or ui.perfetto.dev)",
            events.len()
        );
    }
    if let Some(path) = args.get("metrics-out") {
        export::write_metrics(std::path::Path::new(path), &snap)?;
        eprintln!("metrics: {path}");
    }
    eprint!("{}", export::render_table(&snap));
    Ok(())
}

fn load(args: &Args) -> Result<tricluster::core::context::PolyContext> {
    let name = args.get_or("dataset", "imdb");
    datasets::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}; see `tricluster info`"))
}

fn info() -> Result<()> {
    println!("tricluster {}", env!("CARGO_PKG_VERSION"));
    println!("datasets: imdb k1 k2 k3 ml100k ml250k ml500k ml1m bibsonomy");
    if tricluster::runtime::artifacts_available() {
        let rt =
            tricluster::runtime::Runtime::load(&tricluster::runtime::default_artifact_dir())?;
        println!("PJRT platform: {}", rt.platform());
        println!("artifacts ({}):", rt.manifest.artifacts.len());
        for a in &rt.manifest.artifacts {
            println!("  {:<18} graph={:<8} file={}", a.name, a.graph, a.file.display());
        }
        if let Some(v) = rt.manifest.density_vmem_bytes {
            println!("density kernel VMEM/step: {:.2} MiB", v / (1 << 20) as f64);
        }
    } else {
        println!("artifacts: NOT BUILT (run `make artifacts`)");
    }
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let ctx = load(args)?;
    let out = std::path::PathBuf::from(args.get_or("out", "dataset.tsv"));
    io::write_poly_tsv(&out, &ctx)?;
    println!("wrote {} tuples (arity {}) to {}", ctx.len(), ctx.arity(), out.display());
    Ok(())
}

fn online(args: &Args) -> Result<()> {
    let ctx = load(args)?;
    let cons = Constraints {
        min_density: args.parse_or("min-density", 0.0),
        min_support: args.parse_or("min-support", 0),
    };
    let t = Timer::start();
    let clusters = mine_online(&ctx, &cons);
    let ms = t.elapsed_ms();
    println!("online OAC: {} tuples -> {} clusters in {} ms",
             ctx.len(), clusters.len(), fmt_ms(ms));
    for c in clusters.iter().take(args.parse_or("show", 3)) {
        println!("{}", io::format_cluster(&ctx, c));
    }
    Ok(())
}

fn mr(args: &Args) -> Result<()> {
    let ctx = load(args)?;
    let nodes: usize = args.parse_or("nodes", 10);
    let backend = args.get_or("backend", "hadoop");
    if backend == "cluster" {
        // the simulated N-node cluster: placement, stragglers, failures,
        // speculation — reported from its own virtual clock
        let tune = tricluster::exec::ExecTuning {
            workers: args.parse_or("workers", tricluster::util::pool::default_workers()),
            nodes,
            node_slots: args.parse_or("node-slots", 2),
            straggler_prob: args.parse_or("stragglers", 0.0),
            fault_prob: args.parse_or("fault-prob", 0.0),
            speculation: match args.get_or("speculation", "on") {
                "on" | "true" | "1" => true,
                "off" | "false" | "0" => false,
                other => anyhow::bail!("--speculation {other:?} (expected on|off)"),
            },
            placement: args.get_or("placement", "least").to_string(),
            seed: args.parse_or("seed", 0x5EED),
            churn_prob: args.parse_or("churn", 0.0),
            churn_restart_ms: args.parse_or("restart-ms", 50.0),
            shuffle_ms_per_mib: args.parse_or("shuffle-ms-per-mib", 0.0),
            shuffle_bytes_per_record: args.parse_or("shuffle-bytes", 64.0),
            ..tricluster::exec::ExecTuning::default()
        };
        let backend = tune.cluster_backend()?;
        let t = Timer::start();
        let clusters = tricluster::exec::run_pipeline(
            &backend,
            &ctx,
            args.parse_or("theta", 0.0),
            false,
        )?;
        let wall_ms = t.elapsed_ms();
        let stats = backend.take_stats();
        let (spec, wins, fails, stragglers) = stats.iter().fold(
            (0usize, 0usize, 0usize, 0usize),
            |(s, w, f, g), st| {
                (s + st.spec_launched, w + st.spec_wins, f + st.failures, g + st.stragglers)
            },
        );
        let shuffle_mib: f64 = stats.iter().map(|st| st.shuffle_mib).sum();
        let churn_kills: usize = stats.iter().map(|st| st.churn_kills).sum();
        println!(
            "cluster-sim [{} nodes x{} slots, {} placement]: {} tuples -> {} clusters in {} ms",
            tune.nodes,
            tune.node_slots,
            tune.placement,
            ctx.len(),
            clusters.len(),
            fmt_ms(wall_ms)
        );
        println!(
            "  simulated makespan: {} ms over {} phases",
            fmt_ms(backend.sim_makespan_ms()),
            stats.len()
        );
        for st in &stats {
            println!(
                "    {:<10} {:>3} tasks  {:>9} ms  skew {:.2}",
                st.label,
                st.tasks,
                fmt_ms(st.sim_phase_ms),
                st.skew
            );
        }
        println!(
            "  stragglers: {stragglers}  speculative: {spec} launched / {wins} won  failures: {fails}"
        );
        if shuffle_mib > 0.0 || churn_kills > 0 {
            println!(
                "  shuffle: {shuffle_mib:.2} MiB moved  churn: {churn_kills} attempts killed"
            );
        }
        for c in clusters.iter().take(args.parse_or("show", 3)) {
            println!("{}", io::format_cluster(&ctx, c));
        }
        return Ok(());
    }
    if backend != "hadoop" {
        // the unified exec:: layer runs the identical stage functions on
        // the selected substrate; `hadoop` keeps the stats-rich run_mmc
        // path below
        if args.get("fault-prob").is_some() {
            eprintln!("note: --fault-prob simulates Hadoop task retries; ignored for --backend {backend}");
        }
        let tune = tricluster::exec::ExecTuning {
            workers: args.parse_or("workers", tricluster::util::pool::default_workers()),
            tasks: (nodes * 4).max(8),
            // --ingest kernel|mr: stage 1 via the merge-based parallel
            // ingest kernel (seq/pool only) or the generic M/R round
            parallel_ingest: match args.get_or("ingest", "kernel") {
                "kernel" => true,
                "mr" => false,
                other => anyhow::bail!("--ingest {other:?} (expected kernel|mr)"),
            },
            ..tricluster::exec::ExecTuning::default()
        };
        let run = tricluster::exec::run_named(
            backend,
            &ctx,
            args.parse_or("theta", 0.0),
            &tune,
        )?;
        println!(
            "3-stage pipeline [{}]: {} tuples -> {} clusters in {} ms (x{} workers)",
            run.backend,
            ctx.len(),
            run.clusters.len(),
            fmt_ms(run.wall_ms),
            tune.workers
        );
        for c in run.clusters.iter().take(args.parse_or("show", 3)) {
            println!("{}", io::format_cluster(&ctx, c));
        }
        return Ok(());
    }
    let cfg = MmcConfig {
        theta: args.parse_or("theta", 0.0),
        fault_prob: args.parse_or("fault-prob", 0.0),
        map_tasks: nodes * 4,
        reduce_tasks: nodes * 4,
        executor_threads: args
            .parse_or("workers", tricluster::util::pool::default_workers()),
        ..MmcConfig::default()
    };
    let res = run_mmc(&ctx, &cfg)?;
    println!("3-stage M/R: {} tuples -> {} clusters", ctx.len(), res.clusters.len());
    println!("  wall: {} ms  (stages: {} / {} / {})",
             fmt_ms(res.wall_ms),
             fmt_ms(res.stages[0].wall_ms),
             fmt_ms(res.stages[1].wall_ms),
             fmt_ms(res.stages[2].wall_ms));
    println!("  virtual {}-node makespan: {} ms   shuffle: {} KiB",
             nodes, fmt_ms(res.makespan_ms(nodes)), res.shuffle_bytes() / 1024);
    for c in res.clusters.iter().take(args.parse_or("show", 3)) {
        println!("{}", io::format_cluster(&ctx, c));
    }
    Ok(())
}

fn noac(args: &Args) -> Result<()> {
    let n: usize = args.parse_or("triples", 10_000);
    let params = NoacParams {
        delta: args.parse_or("delta", 100.0),
        min_density: args.parse_or("rho", 0.8),
        min_support: args.parse_or("minsup", 2),
    };
    let workers: usize =
        args.parse_or("workers", tricluster::util::pool::default_workers());
    let ctx = datasets::triframes(&datasets::TriframesParams::with_triples(n));
    let t = Timer::start();
    let seq = mine_noac(&ctx, &params, n, 1);
    let seq_ms = t.elapsed_ms();
    let t = Timer::start();
    let par = mine_noac(&ctx, &params, n, workers);
    let par_ms = t.elapsed_ms();
    assert_eq!(seq.len(), par.len());
    println!(
        "NOAC({}, {}, {}) {}k: regular {} ms, parallel(x{}) {} ms, {} triclusters",
        params.delta, params.min_density, params.min_support,
        n / 1000, fmt_ms(seq_ms), workers, fmt_ms(par_ms), seq.len()
    );
    Ok(())
}

fn density(args: &Args) -> Result<()> {
    let edge: usize = args.parse_or("edge", 48);
    let tri = datasets::synthetic::k1(edge);
    let clusters = mine_online(&tri.inner, &Constraints::none());
    let engine = args.get_or("engine", "exact");
    let t = Timer::start();
    let d = match engine {
        "exact" => {
            // --bitset-cap N overrides the flat row-table byte cap; a
            // tiny cap forces the compressed rung (CI trace check)
            let mut e = match args.get("bitset-cap") {
                Some(_) => ExactEngine::with_bitset_cap(args.parse_or("bitset-cap", 0)),
                None => ExactEngine::default(),
            };
            e.densities(&tri, &clusters)
        }
        "mc" => MonteCarloEngine::host(1024, 7).densities(&tri, &clusters),
        "xla" => {
            let rt = tricluster::runtime::Runtime::load(
                &tricluster::runtime::default_artifact_dir(),
            )?;
            XlaEngine::new(&rt, edge, clusters.len())?.densities(&tri, &clusters)
        }
        other => anyhow::bail!("unknown engine {other:?}"),
    };
    println!(
        "{engine}: {} clusters in {} ms; ρ range [{:.4}, {:.4}]",
        d.len(),
        fmt_ms(t.elapsed_ms()),
        d.iter().cloned().fold(f64::INFINITY, f64::min),
        d.iter().cloned().fold(0.0, f64::max)
    );
    Ok(())
}

/// Parse every serve-related flag into the ONE shared
/// [`tricluster::serve::ServeConfigBuilder`]: the in-process path
/// finishes it with `.build()`, the cluster path with `.build_sim()`,
/// so flag → config wiring lives in exactly one place.
fn serve_builder(
    args: &Args,
    arity: usize,
    default_compact_every: usize,
) -> Result<tricluster::serve::ServeConfigBuilder> {
    use tricluster::exec::cluster_sim::ChurnConfig;
    let pipeline = match args.get_or("pipeline", "on") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("--pipeline {other:?} (expected on|off)"),
    };
    let mut builder = tricluster::serve::ServeConfig::builder()
        .arity(arity)
        .shards(args.parse_or("shards", 4))
        .constraints(Constraints {
            min_density: args.parse_or("min-density", 0.0),
            min_support: args.parse_or("min-support", 0),
        })
        .nodes(args.parse_or("nodes", 4))
        .slots_per_node(args.parse_or("node-slots", 2))
        .placement(args.get_or("placement", "least"))
        .batch(args.parse_or::<usize>("batch", 4096).max(1))
        .compact_every(args.parse_or("compact-every", default_compact_every))
        .source_skew(args.parse_or("source-skew", 1.5))
        .churn(ChurnConfig {
            kill_prob: args.parse_or("churn", 0.0),
            restart_ms: args.parse_or("restart-ms", 50.0),
        })
        .pipeline(pipeline)
        .replicas(args.parse_or("replicas", 0))
        .retained(args.parse_or("retained", 2))
        .seed(args.parse_or("seed", 0x5EED))
        .tenants(args.parse_or("tenants", 1))
        .resident_mib(args.parse_or("resident-mib", 0));
    if args.get("quota").is_some() {
        builder = builder.quota(args.parse_or("quota", usize::MAX));
    }
    if let Some(dir) = args.get("segment-dir") {
        builder = builder.segment_dir(dir);
    }
    let format = args.get_or("snapshot-format", "segment");
    builder = builder.snapshot_format(
        tricluster::serve::SnapshotFormat::parse(format).ok_or_else(|| {
            anyhow::anyhow!("--snapshot-format {format:?} (expected segment|json)")
        })?,
    );
    Ok(builder)
}

/// `--cache on|off` (default on): toggles the `(epoch, query)` result
/// cache on the query backends the mix runs through.
fn cache_flag(args: &Args) -> Result<bool> {
    match args.get_or("cache", "on") {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => anyhow::bail!("--cache {other:?} (expected on|off)"),
    }
}

/// Drive a seeded query mix through one backend: top-k, membership,
/// entity-stats, and whole-index stats in rotation. The digest folds
/// every answer, so two backends at the same epoch print the same
/// value — a quick CLI-level equivalence check.
fn run_query_mix(
    backend: &mut dyn tricluster::serve::QueryBackend,
    queries: usize,
    seed: u64,
    arity: usize,
) -> f64 {
    let mut rng = tricluster::util::rng::Rng::new(seed);
    let mut digest = 0.0f64;
    for _ in 0..queries {
        match rng.below(4) {
            0 => digest += backend.top_k(1 + rng.usize_below(8)).len() as f64,
            1 => {
                let hits =
                    backend.containing(rng.usize_below(arity), rng.below(16) as u32);
                digest += hits.len() as f64;
            }
            2 => {
                digest += backend
                    .entity_stats(rng.usize_below(arity), rng.below(16) as u32)
                    .map_or(0.0, |s| s.mean_density);
            }
            _ => digest += backend.stats().mean_density,
        }
    }
    digest
}

/// Print one backend's query-mix result line (digest, epoch, cache
/// hit rate).
fn report_query_mix(
    label: &str,
    backend: &mut dyn tricluster::serve::QueryBackend,
    queries: usize,
    seed: u64,
    arity: usize,
) {
    let t = Timer::start();
    let digest = run_query_mix(backend, queries, seed, arity);
    let ms = t.elapsed_ms();
    let (hits, misses) = backend.cache_stats();
    println!(
        "  query-mix [{label}]: {queries} queries in {} ms at epoch {} \
         (digest {digest:.4}; cache {hits} hits / {misses} misses)",
        fmt_ms(ms),
        backend.epoch()
    );
}

fn serve_sim(args: &Args) -> Result<()> {
    use tricluster::serve::TriclusterService;

    let names = args.get("dataset").unwrap_or_else(|| args.get_or("datasets", "k1,ml100k"));
    let shards: usize = args.parse_or("shards", 4);
    let batch: usize = args.parse_or::<usize>("batch", 4096).max(1);
    let compact_every: usize = args.parse_or("compact-every", 16);
    let top: usize = args.parse_or("top", 5);
    if args.get("nodes").is_some() {
        return serve_sim_cluster(args, names);
    }

    for name in names.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let ctx = datasets::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}; see `tricluster info`"))?;
        println!(
            "== serve-sim {name}: {} tuples (arity {}) over {shards} shards, batch {batch} ==",
            ctx.len(),
            ctx.arity()
        );
        let mut svc =
            TriclusterService::new(serve_builder(args, ctx.arity(), 16)?.build()?);
        let t = Timer::start();
        let mut compactions = 0usize;
        for (i, chunk) in ctx.tuples().chunks(batch).enumerate() {
            svc.ingest(chunk);
            if compact_every > 0 && (i + 1) % compact_every == 0 {
                svc.compact();
                compactions += 1;
            }
        }
        svc.compact();
        compactions += 1;
        let total_ms = t.elapsed_ms();
        let stats = svc.stats();
        println!(
            "  ingest+compact: {} ms  ({:.0} tuples/s, {} drains, {compactions} compactions)",
            fmt_ms(total_ms),
            stats.tuples as f64 / (total_ms / 1e3),
            stats.drains
        );
        println!(
            "  index: {} clusters, {} merged tuples, {} cumulus keys, epochs {:?}",
            svc.clusters().len(),
            stats.merged,
            stats.distinct_keys,
            svc.stats().epochs
        );
        let t = Timer::start();
        let q = svc.query();
        let built_ms = t.elapsed_ms();
        println!("  top-{top} by density (query engine built in {} ms):", fmt_ms(built_ms));
        let top_clusters = q.top_k_by_density(top);
        for &c in &top_clusters {
            println!("    {}", io::format_cluster(&ctx, c));
        }
        if let Some(best) = top_clusters.first() {
            if let Some(&e) = best.components[0].first() {
                let hits = q.containing(0, e);
                println!(
                    "  membership: entity {:?} (modality 0) appears in {} clusters",
                    ctx.interners[0].name(e),
                    hits.len()
                );
            }
        }
        let query_mix: usize = args.parse_or("query-mix", 0);
        if query_mix > 0 {
            let mut backend = tricluster::serve::LocalBackend::with_cache(
                svc.snapshot_cell(),
                cache_flag(args)?,
            );
            report_query_mix(
                "local",
                &mut backend,
                query_mix,
                args.parse_or("seed", 0x5EED),
                ctx.arity(),
            );
        }
        if let Some(path) = args.get("snapshot") {
            let path = std::path::PathBuf::from(path);
            svc.snapshot_to(&path)?;
            let mut restored = TriclusterService::restore_from(&path)?;
            anyhow::ensure!(
                restored.clusters().len() == svc.clusters().len(),
                "snapshot roundtrip changed the index"
            );
            println!("  snapshot: {} (restore verified)", path.display());
        }
        println!();
    }
    Ok(())
}

/// `serve-sim --nodes N`: the serving layer placed on a simulated
/// cluster — shard placement policies, shuffle costs, seeded churn with
/// snapshot replay (`serve::cluster::ServeSim`) — plus the epoch-
/// snapshot query plane (`--replicas` / `--query-mix` / `--cache`).
fn serve_sim_cluster(args: &Args, names: &str) -> Result<()> {
    use tricluster::serve::cluster::ServeSim;
    use tricluster::serve::{LocalBackend, QueryEngine, SimRemoteBackend};

    let top: usize = args.parse_or("top", 5);
    if args.get("snapshot").is_some() {
        eprintln!(
            "note: --snapshot is not supported with --nodes (serve-sim on the \
             simulated cluster recovers from in-simulation snapshots instead); \
             run without --nodes to write one"
        );
    }
    if args.parse_or::<usize>("tenants", 1) > 1 {
        return serve_sim_tenants(args, names);
    }
    for name in names.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let ctx = datasets::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}; see `tricluster info`"))?;
        let cfg = serve_builder(args, ctx.arity(), 4)?.build_sim()?;
        let (nodes, shards, placement) =
            (cfg.nodes, cfg.shards, cfg.placement.clone());
        let segment_dir = cfg.segment_dir.clone();
        let mut sim = ServeSim::new(cfg)?;
        let t = Timer::start();
        sim.run(ctx.tuples());
        let wall_ms = t.elapsed_ms();
        let clusters = sim.clusters().len();
        let stats = sim.stats().clone();
        println!(
            "== serve-sim {name} on {nodes} nodes [{placement}]: {} tuples over {shards} shards ==",
            ctx.len()
        );
        println!(
            "  simulated makespan: {} ms over {} waves ({} compactions; wall {} ms)",
            fmt_ms(sim.sim_makespan_ms()),
            stats.waves,
            stats.compactions,
            fmt_ms(wall_ms)
        );
        println!(
            "  shuffle: {:.2} MiB drain + {:.2} MiB recovery  churn: {} kills, {} tuples replayed, {} migrations",
            stats.shuffle_mib, stats.recovery_mib, stats.kills, stats.replayed_tuples,
            stats.migrations
        );
        println!(
            "  index: {clusters} clusters  placement: {:?}  mined/node: {:?}",
            sim.assignment(),
            stats.per_node_records
        );
        if let Some(dir) = &segment_dir {
            // the run journalled every compaction delta; restoring the
            // log must reproduce the live index EXACTLY — the CI trace
            // gate leans on this exit-code check
            let mut restored =
                tricluster::serve::TriclusterService::restore_from(dir)?;
            anyhow::ensure!(
                restored.clusters().len() == clusters,
                "segment-log restore diverged from the live index \
                 ({} restored vs {clusters} live)",
                restored.clusters().len()
            );
            println!(
                "  segment log: {} (cold restore verified: {clusters} clusters)",
                dir.display()
            );
        }
        if let Some(set) = sim.replica_set() {
            let set = set.read().expect("replica set poisoned");
            println!(
                "  replicas: {:?} (retained window {}; {} publishes, {:.2} MiB \
                 streamed, max staleness {} epochs)",
                set.nodes(),
                set.retained(),
                stats.replica_publishes,
                stats.replica_mib,
                stats.replica_max_staleness
            );
        }
        let snap = sim.snapshot();
        let q = QueryEngine::from_snapshot(snap);
        println!("  top-{top} by density (epoch {}):", q.epoch());
        for c in q.top_k_by_density(top) {
            println!("    {}", io::format_cluster(&ctx, c));
        }
        let query_mix: usize = args.parse_or("query-mix", 0);
        if query_mix > 0 {
            let cache = cache_flag(args)?;
            let seed: u64 = args.parse_or("seed", 0x5EED);
            let mut local = LocalBackend::with_cache(sim.snapshot_cell(), cache);
            report_query_mix("local", &mut local, query_mix, seed, ctx.arity());
            let client: usize = args.parse_or("client-node", 0);
            if let Some(set) = sim.replica_set() {
                let mut remote = SimRemoteBackend::with_cache(set, client, cache)
                    .expect("replica_set is Some, so replicas exist");
                let label = format!(
                    "replica@node{} for client {client}",
                    remote.replica_node()
                );
                report_query_mix(&label, &mut remote, query_mix, seed, ctx.arity());
            }
        }
        println!();
    }
    Ok(())
}

/// `serve-sim --nodes N --tenants T`: T independent tenant contexts
/// multiplexed onto one shared simulated pool
/// (`serve::tenant::MultiTenantSim`), each fed a seeded `--workload`
/// stream (`uniform` deals the dataset round-robin; `skew` / `drift` /
/// `burst` come from `tricluster::workload` generators). `--churn P`
/// schedules placement-correlated node-set kills. Reports per-tenant
/// counters, the pool fairness spread, and — when no `--quota` caps
/// ingress — asserts every tenant's index equals its solo
/// `mine_online`.
fn serve_sim_tenants(args: &Args, names: &str) -> Result<()> {
    use tricluster::core::tuple::NTuple;
    use tricluster::serve::MultiTenantSim;
    use tricluster::workload::{
        correlated_kills, BurstMix, DriftingStream, Op, SkewedStream,
    };

    let workload = args.get_or("workload", "uniform");
    if !matches!(workload, "uniform" | "skew" | "drift" | "burst") {
        anyhow::bail!("--workload {workload:?} (expected uniform|skew|drift|burst)");
    }
    let batch: usize = args.parse_or::<usize>("batch", 4096).max(1);
    let compact_every: usize = args.parse_or::<usize>("compact-every", 4).max(1);
    let seed: u64 = args.parse_or("seed", 0x5EED);
    let cons = Constraints {
        min_density: args.parse_or("min-density", 0.0),
        min_support: args.parse_or("min-support", 0),
    };
    for name in names.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let ctx = datasets::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}; see `tricluster info`"))?;
        let pool = serve_builder(args, ctx.arity(), 4)?.build_pool()?;
        let (tenants, nodes, placement) =
            (pool.tenants.len(), pool.nodes, pool.placement.clone());
        let mut sim = MultiTenantSim::new(pool)?;
        let per_tenant = (ctx.len() / tenants).max(1);
        let arity = ctx.arity();
        let streams: Vec<Vec<NTuple>> = (0..tenants)
            .map(|t| {
                let tseed = seed ^ (t as u64).wrapping_mul(0x9E37_79B9);
                match workload {
                    "skew" => SkewedStream {
                        tuples: per_tenant,
                        universe: 64,
                        exponent: 1.5,
                        arity,
                    }
                    .generate(tseed),
                    "drift" => DriftingStream {
                        tuples: per_tenant,
                        universe: 32,
                        segments: 4,
                        shift: 16,
                        arity,
                    }
                    .generate(tseed),
                    "burst" => BurstMix {
                        waves: 8,
                        steady_batch: per_tenant / 12 + 1,
                        burst_batch: per_tenant / 3 + 1,
                        burst_every: 3,
                        queries_per_wave: 0,
                        universe: 64,
                        arity,
                    }
                    .generate(tseed)
                    .into_iter()
                    .filter_map(|op| match op {
                        Op::Ingest(tuples) => Some(tuples),
                        Op::Query(_) => None,
                    })
                    .flatten()
                    .collect(),
                    // "uniform": round-robin deal of the real dataset
                    _ => ctx
                        .tuples()
                        .iter()
                        .skip(t)
                        .step_by(tenants)
                        .copied()
                        .collect(),
                }
            })
            .collect();
        let churn: f64 = args.parse_or("churn", 0.0);
        let waves = streams
            .iter()
            .map(|s| s.len().div_ceil(batch))
            .max()
            .unwrap_or(0);
        let kills = if churn > 0.0 && nodes > 1 {
            let events = ((waves as f64 * churn).ceil() as usize).max(1);
            correlated_kills(sim.assignment(0), nodes, 2.min(nodes), events, waves, seed)
        } else {
            Vec::new()
        };
        let t = Timer::start();
        sim.run(&streams, batch, compact_every, &kills);
        let wall_ms = t.elapsed_ms();
        let stats = sim.stats().clone();
        println!(
            "== serve-sim {name}: {tenants} tenants on {nodes} nodes \
             [{placement}], workload {workload} =="
        );
        println!(
            "  simulated makespan: {} ms over {} waves (wall {} ms)  \
             fairness spread: {:.3}",
            fmt_ms(sim.sim_makespan_ms()),
            stats.waves,
            fmt_ms(wall_ms),
            sim.fairness_spread()
        );
        println!(
            "  pool: {:.2} MiB shuffled  {} kills  {} tuples replayed  \
             mined/node {:?}",
            stats.shuffle_mib, stats.kills, stats.replayed_tuples,
            stats.per_node_records
        );
        for t in 0..tenants {
            let clusters = sim.clusters(t).len();
            println!(
                "  tenant {t}: {} accepted / {} throttled, {} compactions, \
                 {clusters} clusters at epoch {}",
                stats.accepted[t],
                stats.throttled[t],
                stats.compactions[t],
                sim.snapshot(t).epoch()
            );
            if args.get("quota").is_none() {
                // per-tenant equivalence: the shared pool must serve each
                // tenant exactly what a solo miner would produce
                let mut solo = tricluster::core::context::PolyContext::new(arity);
                for tuple in &streams[t] {
                    solo.add_ids(tuple.as_slice());
                }
                let reference = mine_online(&solo, &cons);
                anyhow::ensure!(
                    clusters == reference.len(),
                    "tenant {t}: pool index diverged from solo mine_online"
                );
            }
        }
        if args.get("quota").is_none() {
            println!("  per-tenant equivalence vs solo mine_online: OK");
        }
        println!();
    }
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    // --config file.ini provides defaults; CLI flags override
    let file_cfg = match args.get("config") {
        Some(path) => {
            tricluster::coordinator::Config::load(std::path::Path::new(path))?
                .exp_config()
        }
        None => ExpConfig::default(),
    };
    let cfg = ExpConfig {
        full: args.has("full") || file_cfg.full,
        nodes: args.parse_or("nodes", file_cfg.nodes),
        theta: args.parse_or("theta", file_cfg.theta),
        runs: args.parse_or("runs", file_cfg.runs),
        seed: args.parse_or("seed", file_cfg.seed),
    };
    let id = args.get_or("id", "table3");
    let report = match id {
        "table3" => experiments::table3(&cfg)?,
        "table4" => experiments::table4(&cfg)?,
        "fig2" => experiments::fig2(&cfg)?,
        "table5" | "fig3" => experiments::table5(
            &cfg,
            args.parse_or("workers", tricluster::util::pool::default_workers().max(2)),
        )?,
        "backends" => experiments::backends(
            &cfg,
            args.parse_or("workers", tricluster::util::pool::default_workers()),
        )?,
        "cluster-scaling" => experiments::cluster_scaling(
            &cfg,
            args.parse_or("stragglers", 0.1),
        )?,
        "serve-cluster" => experiments::serve_cluster(
            &cfg,
            args.parse_or("churn", 0.2),
        )?,
        "skew" => ablations::partition_skew(cfg.nodes)?,
        "faults" => ablations::fault_injection()?,
        "engines" => ablations::density_engines()?,
        "memory" | "spark" => ablations::dfs_vs_memory()?,
        other => anyhow::bail!("unknown experiment {other:?}"),
    };
    println!("{}", report.render());
    let csv = report.write_csv()?;
    println!("(csv: {})", csv.display());
    Ok(())
}
