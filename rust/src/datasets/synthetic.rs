//! The paper's synthetic contexts (§5.1):
//!
//! * `K₁` — dense 60³ cuboid minus the main diagonal (215,940 triples);
//! * `K₂` — three non-overlapping 50³ cuboids (375,000 triples);
//! * `K₃` — dense 4-ary 30⁴ cuboid (810,000 tuples; assembles exactly ONE
//!   multimodal cluster `(A₁, A₂, A₃, A₄)`).
//!
//! All generators take the edge size as a parameter so tests can run
//! scaled-down instances with identical structure.

use crate::core::context::{PolyContext, TriContext};

/// `K₁(n)`: `G = M = B = {0..n}`, `I = G×M×B \ {(i,i,i)}`.
/// Paper instance: `n = 60` → 215,940 triples.
pub fn k1(n: usize) -> TriContext {
    let mut ctx = TriContext::with_capacity(n, n * n * n);
    intern_range(&mut ctx.inner, n, n, n);
    for g in 0..n as u32 {
        for m in 0..n as u32 {
            for b in 0..n as u32 {
                if !(g == m && m == b) {
                    ctx.add(g, m, b);
                }
            }
        }
    }
    ctx
}

/// `K₂(n)`: three disjoint `n³` blocks. Paper instance: `n = 50` →
/// 375,000 triples, exactly 3 final triclusters of density 1.
pub fn k2(n: usize) -> TriContext {
    let mut ctx = TriContext::with_capacity(3 * n, 3 * n * n * n);
    intern_range(&mut ctx.inner, 3 * n, 3 * n, 3 * n);
    for blk in 0..3u32 {
        let off = blk * n as u32;
        for g in 0..n as u32 {
            for m in 0..n as u32 {
                for b in 0..n as u32 {
                    ctx.add(off + g, off + m, off + b);
                }
            }
        }
    }
    ctx
}

/// `K₃(n)`: dense 4-dimensional cuboid `A₁×A₂×A₃×A₄`, `|A_k| = n`.
/// Paper instance: `n = 30` → 810,000 tuples. The worst case for the
/// reducers (maximal input, maximal duplicates) yet exactly one cluster.
pub fn k3(n: usize) -> PolyContext {
    let mut ctx = PolyContext::with_capacity(4, n, n * n * n * n);
    for k in 0..4 {
        for i in 0..n {
            ctx.interners[k].intern(&format!("a{k}_{i}"));
        }
    }
    for a in 0..n as u32 {
        for b in 0..n as u32 {
            for c in 0..n as u32 {
                for d in 0..n as u32 {
                    ctx.add_ids(&[a, b, c, d]);
                }
            }
        }
    }
    ctx
}

fn intern_range(ctx: &mut PolyContext, g: usize, m: usize, b: usize) {
    for i in 0..g {
        ctx.interners[0].intern(&format!("g{i}"));
    }
    for i in 0..m {
        ctx.interners[1].intern(&format!("m{i}"));
    }
    for i in 0..b {
        ctx.interners[2].intern(&format!("b{i}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k1_counts_match_paper_formula() {
        let ctx = k1(10);
        assert_eq!(ctx.len(), 1000 - 10);
        assert_eq!(ctx.sizes(), (10, 10, 10));
        assert!(!ctx.contains(3, 3, 3));
        assert!(ctx.contains(3, 3, 4));
    }

    #[test]
    fn k1_paper_size() {
        // the actual 60³ instance the paper uses
        let ctx = k1(60);
        assert_eq!(ctx.len(), 215_940);
    }

    #[test]
    fn k2_three_blocks() {
        let ctx = k2(5);
        assert_eq!(ctx.len(), 3 * 125);
        assert_eq!(ctx.sizes(), (15, 15, 15));
        assert!(ctx.contains(0, 0, 0));
        assert!(ctx.contains(5, 5, 5));
        assert!(!ctx.contains(0, 5, 0)); // cross-block absent
    }

    #[test]
    fn k3_dense() {
        let ctx = k3(5);
        assert_eq!(ctx.len(), 625);
        assert_eq!(ctx.arity(), 4);
        assert_eq!(ctx.density(), 1.0);
    }
}
