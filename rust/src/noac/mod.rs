//! NOAC: many-valued (numeric) OAC triclustering with δ-operators
//! (paper §3.2, §4.3, §6).
//!
//! For a generating triple `(g̃, m̃, b̃)` with value `v₀ = V(g̃, m̃, b̃)`,
//! the δ-prime sets keep the fiber elements whose value is within δ of
//! v₀. The generic Algorithm-8 driver (`oac::generic`) supplies the
//! mining loop; this module provides the δ-operator (backed by fiber
//! indexes), the NOAC validity checks (ρ_min over binary presence,
//! minsup per modality), and the sequential/parallel entry points the
//! Table-5 sweep measures.

pub mod delta;
pub mod validity;

pub use delta::DeltaOperator;
pub use validity::NoacValidity;

use crate::core::context::ManyValuedTriContext;
use crate::core::pattern::Cluster;
use crate::oac::generic;
use crate::oac::post::Constraints;

/// NOAC parameters as the paper writes them: `NOAC(δ, ρ_min, minsup)`.
#[derive(Debug, Clone, Copy)]
pub struct NoacParams {
    /// δ: the value tolerance of the δ-prime operators.
    pub delta: f64,
    /// ρ_min: minimal density over the binary presence relation.
    pub min_density: f64,
    /// minsup: minimal cardinality per modality component.
    pub min_support: usize,
}

impl NoacParams {
    /// The two Table-5 settings.
    pub fn table5_strict() -> Self {
        Self { delta: 100.0, min_density: 0.8, min_support: 2 }
    }

    /// The paper's loose Table-5 setting: `NOAC(100, 0.5, 0)`.
    pub fn table5_loose() -> Self {
        Self { delta: 100.0, min_density: 0.5, min_support: 0 }
    }
}

/// Run NOAC over the first `limit` triples (the Table-5 sweep prefix),
/// with `workers` threads (1 = the paper's "regular" version).
pub fn mine_noac(
    ctx: &ManyValuedTriContext,
    params: &NoacParams,
    limit: usize,
    workers: usize,
) -> Vec<Cluster> {
    let triples = &ctx.triples()[..limit.min(ctx.len())];
    let op = DeltaOperator::build(ctx, params.delta);
    let validity = NoacValidity::new(ctx, params);
    // Constraints are enforced inside the validity check exactly as
    // Alg. 8 does (line 7, *before* dedup); the post-filter would use
    // support-density which is NOT the NOAC density measure.
    generic::mine(triples, &op, &validity, &Constraints::none(), workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::triframes::{triframes, TriframesParams};

    fn ctx_with(values: &[(u32, u32, u32, f64)]) -> ManyValuedTriContext {
        let mut ctx = ManyValuedTriContext::new();
        for &(g, m, b, v) in values {
            ctx.add(g, m, b, v);
        }
        ctx
    }

    #[test]
    fn delta_zero_recovers_binary_prime() {
        // all values equal → δ = 0 behaves exactly like OAC-prime (§3.2)
        let ctx = ctx_with(&[
            (0, 0, 0, 1.0),
            (0, 1, 0, 1.0),
            (0, 0, 1, 1.0),
            (0, 1, 1, 1.0),
        ]);
        let params = NoacParams { delta: 0.0, min_density: 0.0, min_support: 0 };
        let out = mine_noac(&ctx, &params, usize::MAX, 1);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].components[1], vec![0, 1]);
        assert_eq!(out[0].components[2], vec![0, 1]);
    }

    #[test]
    fn delta_band_splits_clusters() {
        // same incidence, but one triple's value is far away → the δ-set
        // around the distant triple excludes the others
        let ctx = ctx_with(&[
            (0, 0, 0, 10.0),
            (0, 1, 0, 12.0),
            (0, 2, 0, 500.0),
        ]);
        let params = NoacParams { delta: 5.0, min_density: 0.0, min_support: 0 };
        let out = mine_noac(&ctx, &params, usize::MAX, 1);
        // triples at 10 and 12 merge intents {0,1}; the 500 one stands alone
        assert_eq!(out.len(), 2);
        let big = out.iter().find(|c| c.components[1].len() == 2).unwrap();
        assert_eq!(big.components[1], vec![0, 1]);
        let lone = out.iter().find(|c| c.components[1] == vec![2]).unwrap();
        assert_eq!(lone.components[0], vec![0]);
    }

    #[test]
    fn parallel_equals_sequential_on_triframes() {
        let ctx = triframes(&TriframesParams::with_triples(2_000));
        let params = NoacParams::table5_loose();
        let seq = mine_noac(&ctx, &params, 2_000, 1);
        let par = mine_noac(&ctx, &params, 2_000, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!(a.components, b.components);
        }
    }

    #[test]
    fn strict_params_yield_fewer_clusters() {
        let ctx = triframes(&TriframesParams::with_triples(5_000));
        let strict = mine_noac(&ctx, &NoacParams::table5_strict(), 5_000, 1);
        let loose = mine_noac(&ctx, &NoacParams::table5_loose(), 5_000, 1);
        assert!(strict.len() <= loose.len(), "{} > {}", strict.len(), loose.len());
    }

    #[test]
    fn limit_prefixes_stream() {
        let ctx = triframes(&TriframesParams::with_triples(3_000));
        let params = NoacParams::table5_loose();
        let small = mine_noac(&ctx, &params, 1_000, 1);
        // mining a prefix must not error and produces some clusters
        assert!(small.len() <= mine_noac(&ctx, &params, 3_000, 1).len() + small.len());
    }
}
