//! Dataset generators matched to the paper's §5.1/§6 workloads.
//!
//! Real IMDB/MovieLens/BibSonomy/FrameNet data is not redistributable
//! with this repo, so each generator reproduces the published cardinal-
//! ities, densities, and skew of its source (see DESIGN.md
//! §Substitutions). All generators are deterministic given their seed;
//! series datasets (MovieLens, tri-frames) are prefix-stable so the
//! scaling sweeps use nested samples exactly like the paper's.

pub mod bibsonomy;
pub mod imdb;
pub mod movielens;
pub mod synthetic;
pub mod triframes;

pub use bibsonomy::{bibsonomy, BibsonomyParams};
pub use imdb::{imdb, ImdbParams};
pub use movielens::{movielens, MovielensParams};
pub use synthetic::{k1, k2, k3};
pub use triframes::{triframes, TriframesParams};

use crate::core::context::PolyContext;

/// Named datasets used across benches/CLI; sizes follow the paper.
pub fn by_name(name: &str) -> Option<PolyContext> {
    match name {
        "imdb" => Some(imdb(&ImdbParams::default()).inner),
        "k1" => Some(k1(60).inner),
        "k2" => Some(k2(50).inner),
        "k3" => Some(k3(30)),
        "movielens100k" | "ml100k" => {
            Some(movielens(&MovielensParams::with_tuples(100_000)))
        }
        "movielens250k" | "ml250k" => {
            Some(movielens(&MovielensParams::with_tuples(250_000)))
        }
        "movielens500k" | "ml500k" => {
            Some(movielens(&MovielensParams::with_tuples(500_000)))
        }
        "movielens1m" | "ml1m" => {
            Some(movielens(&MovielensParams::with_tuples(1_000_000)))
        }
        "bibsonomy" => Some(bibsonomy(&BibsonomyParams::default()).inner),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn by_name_known_and_unknown() {
        assert!(super::by_name("imdb").is_some());
        assert!(super::by_name("nope").is_none());
    }
}
