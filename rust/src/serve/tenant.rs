//! Multi-tenant serving: many independent triclustering contexts on one
//! shared simulated node pool.
//!
//! One context per process is a demo; a service hosts many. A
//! [`MultiTenantSim`] runs N tenants — each with its OWN arity,
//! constraints (θ), shard set, compactor, and epoch-snapshot cell — over
//! ONE pool of simulated nodes, so neighbours contend for slots, network,
//! and placement but NEVER for state:
//!
//! * **Isolation is structural.** A tenant's shards and compactor are
//!   private; nothing a neighbour ingests can reach them. The invariant
//!   this buys (property-tested in `rust/tests/workload_invariants.rs`):
//!   for ANY tenant mix, workload, and churn schedule, each tenant's
//!   compacted index equals that tenant's solo
//!   [`crate::oac::mine_online`], and its results are bit-identical with
//!   or without neighbours — load can slow a tenant, never perturb it.
//! * **Quotas bound ingress.** Each tenant accepts at most
//!   [`TenantSpec::quota`] tuples per ingest wave; the overflow is
//!   counted as throttled, not silently dropped mid-stream (the
//!   acceptance rule is a deterministic prefix, so tests can reconstruct
//!   exactly which tuples a throttled tenant indexed).
//! * **Placement balances tenants.** Shards are placed by
//!   [`Placement::place_tenant`] — the tenant-salted arm of the same
//!   pluggable trait that places M/R tasks, serve shards, and replicas —
//!   so round-robin stripes tenants across the pool while locality still
//!   chases each tenant's measured data affinity.
//! * **Fairness is measured, not assumed.** Every scheduled cost is
//!   charged to its tenant; [`MultiTenantSim::fairness_spread`] is the
//!   max/min ratio of per-accepted-tuple service cost across tenants
//!   (1.0 = perfectly fair pool). It is exported as the
//!   `serve.tenant.fairness_spread` gauge, benched in
//!   `benches/serve_cluster.rs`, and ceiling-gated by
//!   `ci/check_bench.rs` (`serve_cluster.max_fairness_spread`).
//! * **Failures are correlated.** [`Self::kill_nodes`] takes down a node
//!   SET in one event — feed it [`crate::workload::correlated_kills`]
//!   for placement-correlated sets — and every tenant shard on a victim
//!   is rebuilt for real from its compacted snapshot plus the retained
//!   window, exactly like [`super::cluster::ServeSim`]'s recovery.
//! * **Durability is per tenant.** With
//!   [`TenantPoolConfig::segment_dir`] set, each tenant journals its
//!   compaction deltas to a private [`crate::persist`] segment log under
//!   `<dir>/t{t}`; kill recovery then restores the compacted prefix by
//!   page-level adoption ([`Shard::restore`]) instead of re-mining it,
//!   and [`TenantPoolConfig::resident_mib`] caps each tenant's resident
//!   arena pages (cold chains spill beside its log).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::core::pattern::Cluster;
use crate::core::tuple::NTuple;
use crate::exec::cluster_sim::ShuffleModel;
use crate::exec::placement::{by_name, NodeView, Placement, TaskMeta};
use crate::oac::post::Constraints;
use crate::persist::{
    LogImage, SegmentConfig, SegmentKind, SegmentLog, SegmentPayload, ShardRecord,
};
use crate::util::hash::fxhash;
use crate::util::rng::Rng;
use crate::workload::KillEvent;

use super::epoch::{EpochSnapshot, SnapshotCell};
use super::merge::Compactor;
use super::shard::{Shard, ShardDelta};

/// One tenant of a [`MultiTenantSim`]: its own context shape, θ, shard
/// count, and ingest quota.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Display name (CLI/bench reports).
    pub name: String,
    /// Relation arity of this tenant's context.
    pub arity: usize,
    /// Constraints (θ = `min_density`, plus `min_support`) applied when
    /// materialising THIS tenant's index.
    pub constraints: Constraints,
    /// Shards (incremental miners) for this tenant.
    pub shards: usize,
    /// Ingest quota: tuples accepted per wave — the deterministic PREFIX
    /// of each wave; the rest is counted throttled. `usize::MAX` =
    /// unlimited. The config builder rejects an explicit 0
    /// ([`super::ServeConfigError::ZeroQuota`]); constructing a
    /// zero-quota spec directly is allowed for adversarial tests (the
    /// tenant indexes nothing and its neighbours must not notice).
    pub quota: usize,
}

impl TenantSpec {
    /// A tenant with serve defaults: 2 shards, no constraints, unlimited
    /// quota.
    pub fn new(name: &str, arity: usize) -> Self {
        Self {
            name: name.to_string(),
            arity,
            constraints: Constraints::none(),
            shards: 2,
            quota: usize::MAX,
        }
    }
}

/// The shared node pool a tenant mix runs on.
#[derive(Debug, Clone)]
pub struct TenantPoolConfig {
    /// Simulated nodes shared by every tenant.
    pub nodes: usize,
    /// Worker slots per node.
    pub slots_per_node: usize,
    /// Placement policy name (`rr` | `locality` | `least`) — resolved to
    /// the shared [`Placement`] trait, applied through
    /// [`Placement::place_tenant`].
    pub placement: String,
    /// Simulated mining cost per tuple, ms (also the replay cost after a
    /// kill).
    pub mine_ms_per_record: f64,
    /// Simulated route-split cost per tuple, ms.
    pub route_ms_per_record: f64,
    /// Network cost of moving route bins between non-colocated nodes.
    pub shuffle: ShuffleModel,
    /// Downtime after a kill, ms.
    pub restart_ms: f64,
    /// Seed for source-arrival draws.
    pub seed: u64,
    /// Segment-log root: each tenant `t` journals its compaction deltas
    /// under `<dir>/t{t}` and kills recover by page-level adoption from
    /// that log (same binary format as [`crate::persist`]). `None` keeps
    /// the pool purely in-memory.
    pub segment_dir: Option<PathBuf>,
    /// Resident arena budget in MiB, split across each tenant's shards
    /// (cold page chains spill to disk past it). `0` = unlimited.
    pub resident_mib: usize,
    /// The tenant mix.
    pub tenants: Vec<TenantSpec>,
}

impl TenantPoolConfig {
    /// Pool defaults matching [`super::cluster::ServeSimConfig::new`]'s
    /// cost model, with no tenants yet (push specs via [`Self::tenant`]).
    pub fn new(nodes: usize) -> Self {
        Self {
            nodes: nodes.max(1),
            slots_per_node: 2,
            placement: "least".into(),
            mine_ms_per_record: 0.002,
            route_ms_per_record: 0.0005,
            shuffle: ShuffleModel { bytes_per_record: 64.0, ms_per_mib: 20.0 },
            restart_ms: 40.0,
            seed: 0x5EED,
            segment_dir: None,
            resident_mib: 0,
            tenants: Vec::new(),
        }
    }

    /// Add one tenant to the mix.
    pub fn tenant(mut self, spec: TenantSpec) -> Self {
        self.tenants.push(spec);
        self
    }
}

/// Counters of one [`MultiTenantSim`] run (per-tenant vectors index by
/// tenant id).
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    /// Ingest waves executed (pool-wide).
    pub waves: usize,
    /// Tuples accepted per tenant.
    pub accepted: Vec<usize>,
    /// Tuples refused by the quota per tenant.
    pub throttled: Vec<usize>,
    /// Compactions per tenant.
    pub compactions: Vec<usize>,
    /// Simulated ms charged to each tenant (route + mine + shuffle +
    /// recovery).
    pub service_ms: Vec<f64>,
    /// MiB moved for route bins mined on a different node (pool-wide).
    pub shuffle_mib: f64,
    /// Nodes killed (one per victim, so a correlated set of 3 counts 3).
    pub kills: usize,
    /// Tuples replayed rebuilding shards after kills.
    pub replayed_tuples: usize,
    /// Tuples mined per node — the tenant-balance picture placement
    /// produced.
    pub per_node_records: Vec<usize>,
}

/// Per-tenant serving state: private shards, compactor, and snapshot
/// cell on the shared pool.
#[derive(Debug)]
struct TenantState {
    spec: TenantSpec,
    shards: Vec<Shard>,
    compactor: Compactor,
    /// shard → node.
    assignment: Vec<usize>,
    /// Per-shard finish time of the latest mining/recovery task.
    mine_done: Vec<f64>,
    /// shard × node input provenance (MiB) — feeds locality affinity.
    input_bytes: Vec<Vec<f64>>,
    /// Per-shard generated-tuple watermark at the last compaction.
    compacted_len: Vec<usize>,
    /// Per-shard epoch at the last compaction.
    epoch_at_compact: Vec<u64>,
    /// This tenant's publication cell.
    cell: Arc<SnapshotCell>,
    /// Compactions so far — the epoch stamped on the next publication.
    epoch: u64,
    /// This tenant's private segment log (`<segment_dir>/t{t}`): one
    /// delta segment per compaction, replayed for page-level adoption
    /// after a kill. `None` when the pool is in-memory, or after a flush
    /// failure downgraded this tenant to the replay path.
    log: Option<SegmentLog>,
}

/// Many independent tenants on one shared simulated node pool: real
/// per-tenant mining and compaction, simulated contention.
///
/// # Example
///
/// ```
/// use tricluster::core::tuple::NTuple;
/// use tricluster::serve::tenant::{MultiTenantSim, TenantPoolConfig, TenantSpec};
///
/// let cfg = TenantPoolConfig::new(2)
///     .tenant(TenantSpec::new("a", 3))
///     .tenant(TenantSpec::new("b", 3));
/// let mut sim = MultiTenantSim::new(cfg).unwrap();
/// let stream: Vec<NTuple> =
///     (0..200u32).map(|i| NTuple::triple(i % 5, i % 4, i % 3)).collect();
/// sim.ingest(0, &stream);
/// sim.ingest(1, &stream);
/// sim.compact_all();
/// assert_eq!(sim.clusters(0).len(), sim.clusters(1).len());
/// assert!(sim.fairness_spread() >= 1.0);
/// ```
pub struct MultiTenantSim {
    cfg: TenantPoolConfig,
    placement: Box<dyn Placement>,
    tenants: Vec<TenantState>,
    /// Simulated time each node×slot frees up (shared pool).
    lanes: Vec<Vec<f64>>,
    /// Cumulative simulated work per node.
    busy: Vec<f64>,
    /// End of the latest scheduled work (pool makespan).
    horizon: f64,
    /// Source-arrival draws (one per wave).
    rng: Rng,
    stats: TenantStats,
}

impl MultiTenantSim {
    /// Build the pool; fails on an unknown placement name or an empty
    /// tenant mix.
    pub fn new(cfg: TenantPoolConfig) -> Result<Self> {
        let placement = by_name(&cfg.placement)?;
        if cfg.tenants.is_empty() {
            anyhow::bail!("tenant pool needs at least one tenant");
        }
        let nodes = cfg.nodes.max(1);
        let mut sim = Self {
            tenants: Vec::with_capacity(cfg.tenants.len()),
            lanes: vec![vec![0.0; cfg.slots_per_node.max(1)]; nodes],
            busy: vec![0.0; nodes],
            horizon: 0.0,
            rng: Rng::new(cfg.seed),
            stats: TenantStats {
                accepted: vec![0; cfg.tenants.len()],
                throttled: vec![0; cfg.tenants.len()],
                compactions: vec![0; cfg.tenants.len()],
                service_ms: vec![0.0; cfg.tenants.len()],
                per_node_records: vec![0; nodes],
                ..TenantStats::default()
            },
            placement,
            cfg,
        };
        // initial placement: tenant-salted, sequential with virtual load
        // updates so greedy policies spread (same discipline as ServeSim)
        let mut virt = vec![0.0f64; nodes];
        for (t, spec) in sim.cfg.tenants.clone().iter().enumerate() {
            let n_shards = spec.shards.max(1);
            let mut assignment = vec![0usize; n_shards];
            for (s, slot) in assignment.iter_mut().enumerate() {
                let views: Vec<NodeView> = virt
                    .iter()
                    .enumerate()
                    .map(|(id, &b)| NodeView { id, free_at_ms: b, busy_ms: b })
                    .collect();
                let meta = TaskMeta::new(s, s as u64, 1.0);
                let node =
                    sim.placement.place_tenant(t, &meta, &views).min(nodes - 1);
                *slot = node;
                virt[node] += 1.0;
            }
            // each tenant journals under its own sub-directory so logs
            // never interleave — isolation extends to durability
            let log = match sim.cfg.segment_dir.as_ref() {
                Some(dir) => Some(
                    SegmentLog::create(&dir.join(format!("t{t}")))
                        .map_err(|e| anyhow::anyhow!("tenant {t} segment log: {e}"))?,
                ),
                None => None,
            };
            let mut shards: Vec<Shard> =
                (0..n_shards).map(|s| Shard::new(s, spec.arity)).collect();
            if sim.cfg.resident_mib > 0 {
                let pages =
                    crate::oac::primes::resident_pages(sim.cfg.resident_mib, n_shards);
                let spill = sim
                    .cfg
                    .segment_dir
                    .as_ref()
                    .map(|d| d.join(format!("t{t}")).join("spill"));
                for shard in &mut shards {
                    shard.set_resident_budget(pages, spill.clone());
                }
            }
            sim.tenants.push(TenantState {
                shards,
                compactor: Compactor::new(n_shards),
                assignment,
                mine_done: vec![0.0; n_shards],
                input_bytes: vec![vec![0.0; nodes]; n_shards],
                compacted_len: vec![0; n_shards],
                epoch_at_compact: vec![0; n_shards],
                cell: Arc::new(SnapshotCell::new()),
                epoch: 0,
                spec: spec.clone(),
                log,
            });
        }
        Ok(sim)
    }

    /// Tenant count.
    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// The configuration this pool runs under.
    pub fn cfg(&self) -> &TenantPoolConfig {
        &self.cfg
    }

    /// Counters so far.
    pub fn stats(&self) -> &TenantStats {
        &self.stats
    }

    /// Tenant `t`'s current shard → node assignment.
    pub fn assignment(&self, t: usize) -> &[usize] {
        &self.tenants[t].assignment
    }

    /// Simulated pool makespan so far.
    pub fn sim_makespan_ms(&self) -> f64 {
        self.horizon
    }

    /// One ingest wave for tenant `t`: the quota prefix is accepted,
    /// routed to the tenant's shards, and mined on their assigned nodes;
    /// the overflow is throttled. Returns the accepted count.
    pub fn ingest(&mut self, t: usize, wave: &[NTuple]) -> usize {
        let mut span = crate::span!("serve.tenant.ingest");
        span.records_in(wave.len() as u64);
        self.stats.waves += 1;
        let quota = self.tenants[t].spec.quota;
        let take = wave.len().min(quota);
        self.stats.accepted[t] += take;
        self.stats.throttled[t] += wave.len() - take;
        crate::obs::counter("serve.tenant.ingested", take as u64);
        if wave.len() > take {
            crate::obs::counter("serve.tenant.throttled", (wave.len() - take) as u64);
        }
        if take == 0 {
            return 0;
        }
        let accepted = &wave[..take];
        let nodes = self.lanes.len();
        let source = self.rng.usize_below(nodes);

        // route-split on the arrival node, charged to this tenant
        let route_cost = accepted.len() as f64 * self.cfg.route_ms_per_record;
        let route_done = self.schedule(source, 0.0, route_cost);
        self.stats.service_ms[t] += route_cost;

        // one mining task per touched shard on its assigned node
        let n_shards = self.tenants[t].shards.len();
        let mut bins: Vec<Vec<NTuple>> = vec![Vec::new(); n_shards];
        for tuple in accepted {
            bins[(fxhash(tuple) % n_shards as u64) as usize].push(*tuple);
        }
        for (s, bin) in bins.into_iter().enumerate() {
            if bin.is_empty() {
                continue;
            }
            let tenant = &mut self.tenants[t];
            let node = tenant.assignment[s];
            let mib = self.cfg.shuffle.mib(bin.len());
            tenant.input_bytes[s][source] += mib;
            let moved_mib = if source != node { mib } else { 0.0 };
            self.stats.shuffle_mib += moved_mib;
            self.stats.per_node_records[node] += bin.len();
            // REAL mining — the correctness path
            tenant.shards[s].ingest(&bin);
            let cost = bin.len() as f64 * self.cfg.mine_ms_per_record
                + moved_mib * self.cfg.shuffle.ms_per_mib;
            self.stats.service_ms[t] += cost;
            let at = route_done.max(tenant.mine_done[s]);
            let finish = self.schedule(node, at, cost);
            self.tenants[t].mine_done[s] = finish;
        }
        span.records_out(take as u64);
        take
    }

    /// Merge tenant `t`'s pending shard deltas, advance its snapshot
    /// watermarks, and publish its next epoch snapshot.
    pub fn compact(&mut self, t: usize) {
        let _span = crate::span!("serve.tenant.compact");
        let slots = self.cfg.slots_per_node;
        let tenant = &mut self.tenants[t];
        // pull, journalled: the same deltas the compactor folds become
        // one delta segment in this tenant's log, so a later kill can
        // adopt the compacted prefix instead of re-mining it
        let deltas: Vec<ShardDelta> =
            tenant.shards.iter_mut().map(Shard::take_delta).collect();
        let mut drop_log = false;
        if let Some(log) = tenant.log.as_mut() {
            let mut payload = SegmentPayload {
                seq: 0,
                epoch: tenant.epoch + 1,
                kind: SegmentKind::Delta,
                arity: tenant.spec.arity,
                config: SegmentConfig {
                    max_pending: 0,
                    workers: slots,
                    min_density: tenant.spec.constraints.min_density,
                    min_support: tenant.spec.constraints.min_support,
                },
                shards: deltas
                    .iter()
                    .map(|d| ShardRecord {
                        epoch: d.epoch,
                        tuples: d.tuples.clone(),
                        cumuli: d.appends.clone(),
                    })
                    .collect(),
                clusters: Vec::new(),
                interners: Vec::new(),
            };
            if log.append(&mut payload).is_err() {
                // durability degrades, service does not: fall back to
                // in-memory recovery for the rest of the run
                crate::obs::counter("persist.segment.flush_fail", 1);
                drop_log = true;
            }
        }
        if drop_log {
            tenant.log = None;
        }
        for delta in &deltas {
            tenant.compactor.apply(delta);
        }
        for s in 0..tenant.shards.len() {
            tenant.compacted_len[s] = tenant.shards[s].len();
            tenant.epoch_at_compact[s] = tenant.shards[s].epoch();
        }
        tenant.epoch += 1;
        let snap = tenant.compactor.snapshot(&tenant.spec.constraints, tenant.epoch);
        tenant.cell.publish(snap);
        self.stats.compactions[t] += 1;
        crate::obs::counter("serve.tenant.compactions", 1);
        if crate::obs::enabled() {
            crate::obs::gauge("serve.tenant.fairness_spread", self.fairness_spread());
            crate::obs::gauge("serve.tenant.tenants", self.tenants.len() as f64);
        }
    }

    /// [`Self::compact`] for every tenant, in tenant order.
    pub fn compact_all(&mut self) {
        for t in 0..self.tenants.len() {
            self.compact(t);
        }
    }

    /// Drive whole per-tenant streams through the shared pool: waves of
    /// `batch` tuples are dealt round-robin across tenants (tenant 0's
    /// wave w, tenant 1's wave w, …), [`KillEvent`]s land at the start
    /// of their wave, every tenant compacts every `compact_every` of its
    /// own waves and once more at end of stream.
    pub fn run(
        &mut self,
        streams: &[Vec<NTuple>],
        batch: usize,
        compact_every: usize,
        kills: &[KillEvent],
    ) {
        assert_eq!(streams.len(), self.tenants.len(), "one stream per tenant");
        let batch = batch.max(1);
        let every = compact_every.max(1);
        let waves = streams
            .iter()
            .map(|s| s.len().div_ceil(batch))
            .max()
            .unwrap_or(0);
        let mut kill_iter = kills.iter().peekable();
        for w in 0..waves {
            while let Some(k) = kill_iter.peek() {
                if k.wave > w {
                    break;
                }
                let victims = kill_iter.next().expect("peeked").victims.clone();
                self.kill_nodes(&victims, self.horizon);
            }
            for t in 0..streams.len() {
                let lo = w * batch;
                if lo >= streams[t].len() {
                    continue;
                }
                let hi = (lo + batch).min(streams[t].len());
                self.ingest(t, &streams[t][lo..hi]);
                if (w + 1) % every == 0 {
                    self.compact(t);
                }
            }
        }
        for t in 0..streams.len() {
            self.compact(t);
        }
    }

    /// Kill a correlated node SET at simulated instant `at`: every
    /// victim's slots refuse work for the restart window, and every
    /// tenant shard on a victim is re-placed and REALLY rebuilt from its
    /// compacted snapshot plus the retained in-flight window (the same
    /// recovery [`super::cluster::ServeSim`] performs, here across every
    /// tenant at once — a correlated failure hits the whole pool).
    pub fn kill_nodes(&mut self, victims: &[usize], at: f64) {
        let nodes = self.lanes.len();
        let restart = self.cfg.restart_ms.max(0.0);
        let mut hit = Vec::new();
        for &v in victims {
            if v < nodes && !hit.contains(&v) {
                hit.push(v);
                for lane in &mut self.lanes[v] {
                    *lane = lane.max(at) + restart;
                }
            }
        }
        if hit.is_empty() {
            return;
        }
        self.stats.kills += hit.len();
        crate::obs::counter("serve.tenant.kills", hit.len() as u64);
        for t in 0..self.tenants.len() {
            let tenant_hit = (0..self.tenants[t].shards.len())
                .any(|s| hit.contains(&self.tenants[t].assignment[s]));
            if !tenant_hit {
                continue;
            }
            // one replay per hit tenant per kill event: the log's real
            // encoded bytes are fetched ONCE, however many of this
            // tenant's shards died, and charged to this tenant
            let log_image: Option<LogImage> = self.tenants[t]
                .log
                .as_ref()
                .and_then(|log| SegmentLog::replay(log.dir()).ok());
            if let Some(image) = &log_image {
                self.stats.service_ms[t] += image.bytes as f64 / (1024.0 * 1024.0)
                    * self.cfg.shuffle.ms_per_mib;
            }
            for s in 0..self.tenants[t].shards.len() {
                if !hit.contains(&self.tenants[t].assignment[s]) {
                    continue;
                }
                let arity = self.tenants[t].spec.arity;
                let history = self.tenants[t].shards[s].ingested_tuples();
                let (compacted, window) =
                    history.split_at(self.tenants[t].compacted_len[s]);
                // page-level adoption of the compacted prefix from the
                // tenant's segment log (its delta per compaction folds to
                // exactly that prefix); the first pull is discarded — the
                // tenant's global index already holds it
                let adopted = log_image.as_ref().and_then(|image| {
                    let state = image.shards.get(s)?;
                    let mut shard =
                        Shard::restore(s, arity, 0, &state.tuples, state.cumuli.clone())
                            .ok()?;
                    let _ = shard.take_delta();
                    Some(shard)
                });
                let from_log = adopted.is_some();
                let mut fresh = match adopted {
                    Some(shard) => shard,
                    None => {
                        // REAL replay: re-mine the compacted prefix (delta
                        // discarded — the global index already holds it)
                        let mut fresh = Shard::new(s, arity);
                        if !compacted.is_empty() {
                            fresh.ingest(compacted);
                            let _ = fresh.take_delta();
                        }
                        fresh
                    }
                };
                if self.cfg.resident_mib > 0 {
                    let n_shards = self.tenants[t].shards.len();
                    fresh.set_resident_budget(
                        crate::oac::primes::resident_pages(
                            self.cfg.resident_mib,
                            n_shards,
                        ),
                        self.cfg
                            .segment_dir
                            .as_ref()
                            .map(|d| d.join(format!("t{t}")).join("spill")),
                    );
                }
                fresh.set_epoch(self.tenants[t].epoch_at_compact[s]);
                if !window.is_empty() {
                    fresh.ingest(window);
                }
                self.tenants[t].shards[s] = fresh;
                self.stats.replayed_tuples += history.len();
                // re-place with the tenant-salted policy (it may pick a
                // victim — rr does — and then waits out the restart)
                let views: Vec<NodeView> = self
                    .lanes
                    .iter()
                    .enumerate()
                    .map(|(id, ls)| NodeView {
                        id,
                        free_at_ms: ls.iter().cloned().fold(f64::INFINITY, f64::min),
                        busy_ms: self.busy[id],
                    })
                    .collect();
                let est = (history.len() as f64 * self.cfg.mine_ms_per_record).max(1.0);
                let meta = TaskMeta {
                    affinity: self.affinity_of(t, s),
                    ..TaskMeta::new(s, s as u64, est)
                };
                let dest =
                    self.placement.place_tenant(t, &meta, &views).min(nodes - 1);
                self.tenants[t].assignment[s] = dest;
                // log-based recovery already charged the fetch ONCE at
                // the log's real encoded size; only the fallback moves
                // the estimated history bytes per shard
                let mib = if from_log {
                    0.0
                } else {
                    self.cfg.shuffle.mib(history.len())
                };
                let cost = mib * self.cfg.shuffle.ms_per_mib
                    + history.len() as f64 * self.cfg.mine_ms_per_record;
                self.stats.service_ms[t] += cost;
                let finish = self.schedule(dest, at, cost);
                self.tenants[t].mine_done[s] =
                    self.tenants[t].mine_done[s].max(finish);
            }
        }
    }

    /// Tenant `t`'s compacted cluster index under ITS constraints (call
    /// after [`Self::compact`] / [`Self::run`]).
    pub fn clusters(&mut self, t: usize) -> &[Cluster] {
        let tenant = &mut self.tenants[t];
        tenant.compactor.clusters(&tenant.spec.constraints)
    }

    /// Tenant `t`'s current epoch snapshot (epoch 0 and empty before its
    /// first compaction).
    pub fn snapshot(&self, t: usize) -> Arc<EpochSnapshot> {
        self.tenants[t].cell.load()
    }

    /// Tenant `t`'s publication cell (share with query threads).
    pub fn snapshot_cell(&self, t: usize) -> Arc<SnapshotCell> {
        Arc::clone(&self.tenants[t].cell)
    }

    /// Max/min ratio of per-accepted-tuple service cost across tenants
    /// with any accepted traffic (1.0 = perfectly fair, or fewer than
    /// two active tenants). Published as the
    /// `serve.tenant.fairness_spread` gauge at every compaction and
    /// ceiling-gated in CI.
    pub fn fairness_spread(&self) -> f64 {
        fairness_spread(&self.stats.service_ms, &self.stats.accepted)
    }

    /// Node holding the largest measured share of tenant `t` shard `s`'s
    /// input so far (None before any input).
    fn affinity_of(&self, t: usize, s: usize) -> Option<usize> {
        let bytes = &self.tenants[t].input_bytes[s];
        let (node, &max) = bytes
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))?;
        (max > 0.0).then_some(node)
    }

    /// Put `cost` ms of work on `node`'s earliest slot, no earlier than
    /// `ready`; returns the finish time.
    fn schedule(&mut self, node: usize, ready: f64, cost: f64) -> f64 {
        let slot = (0..self.lanes[node].len())
            .min_by(|&a, &b| {
                self.lanes[node][a].partial_cmp(&self.lanes[node][b]).unwrap()
            })
            .expect("nodes have slots");
        let start = self.lanes[node][slot].max(ready);
        let finish = start + cost;
        self.lanes[node][slot] = finish;
        self.busy[node] += cost;
        self.horizon = self.horizon.max(finish);
        finish
    }
}

impl std::fmt::Debug for MultiTenantSim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MultiTenantSim")
            .field("cfg", &self.cfg)
            .field("placement", &self.placement.name())
            .field("stats", &self.stats)
            .finish()
    }
}

/// Max/min per-accepted-tuple service cost across tenants with accepted
/// traffic — the pool-fairness figure (1.0 = fair; large = one tenant
/// pays far more per tuple than another). Tenants with no accepted
/// tuples are excluded (a zero-quota tenant consumes no service);
/// fewer than two active tenants is defined as 1.0.
pub fn fairness_spread(service_ms: &[f64], accepted: &[usize]) -> f64 {
    let shares: Vec<f64> = service_ms
        .iter()
        .zip(accepted)
        .filter(|&(_, &n)| n > 0)
        .map(|(&ms, &n)| ms / n as f64)
        .collect();
    if shares.len() < 2 {
        return 1.0;
    }
    let max = shares.iter().cloned().fold(f64::MIN, f64::max);
    let min = shares.iter().cloned().fold(f64::MAX, f64::min);
    if min <= 0.0 {
        return 1.0;
    }
    max / min
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oac::mine_online;

    fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
        cs.sort_by(|a, b| a.components.cmp(&b.components));
        cs
    }

    fn stream(n: usize, universe: u64, seed: u64) -> crate::core::context::PolyContext {
        assert!(universe * universe * universe > n as u64);
        let mut ctx = crate::core::context::PolyContext::new(3);
        let mut rng = Rng::new(seed);
        while ctx.len() < n {
            ctx.add_ids(&[
                rng.below(universe) as u32,
                rng.below(universe) as u32,
                rng.below(universe) as u32,
            ]);
        }
        ctx
    }

    fn pool(tenants: usize) -> TenantPoolConfig {
        let mut cfg = TenantPoolConfig::new(3);
        for t in 0..tenants {
            cfg = cfg.tenant(TenantSpec::new(&format!("t{t}"), 3));
        }
        cfg
    }

    #[test]
    fn each_tenant_equals_its_solo_mine_online() {
        let ctxs = [stream(300, 8, 1), stream(400, 9, 2), stream(200, 7, 3)];
        let mut sim = MultiTenantSim::new(pool(3)).unwrap();
        let streams: Vec<Vec<NTuple>> =
            ctxs.iter().map(|c| c.tuples().to_vec()).collect();
        sim.run(&streams, 64, 2, &[]);
        for (t, ctx) in ctxs.iter().enumerate() {
            let reference = sorted(mine_online(ctx, &Constraints::none()));
            let got = sorted(sim.clusters(t).to_vec());
            assert_eq!(got.len(), reference.len(), "tenant {t}");
            for (a, b) in got.iter().zip(&reference) {
                assert_eq!(a.components, b.components);
                assert_eq!(a.support, b.support);
            }
            assert_eq!(sim.snapshot(t).len(), reference.len());
        }
        assert!(sim.fairness_spread() >= 1.0);
        assert!(sim.sim_makespan_ms() > 0.0);
    }

    #[test]
    fn quota_throttles_the_prefix_rule() {
        let ctx = stream(200, 8, 4);
        let mut cfg = pool(2);
        cfg.tenants[0].quota = 10;
        let mut sim = MultiTenantSim::new(cfg).unwrap();
        let streams = vec![ctx.tuples().to_vec(), ctx.tuples().to_vec()];
        sim.run(&streams, 50, 1, &[]);
        // 4 waves × 10 accepted for tenant 0; tenant 1 takes everything
        assert_eq!(sim.stats().accepted[0], 40);
        assert_eq!(sim.stats().throttled[0], 160);
        assert_eq!(sim.stats().accepted[1], 200);
        assert_eq!(sim.stats().throttled[1], 0);
        // the accepted prefix is deterministic: tenant 0's index equals
        // mining exactly the first 10 tuples of each 50-tuple wave
        let mut expect = crate::core::context::PolyContext::new(3);
        for wave in ctx.tuples().chunks(50) {
            for t in &wave[..10] {
                expect.add_ids(t.as_slice());
            }
        }
        let reference = sorted(mine_online(&expect, &Constraints::none()));
        let got = sorted(sim.clusters(0).to_vec());
        assert_eq!(got.len(), reference.len());
    }

    #[test]
    fn zero_quota_tenant_indexes_nothing_and_disturbs_nobody() {
        let ctx = stream(300, 8, 5);
        let solo = {
            let mut sim = MultiTenantSim::new(pool(1)).unwrap();
            sim.run(&[ctx.tuples().to_vec()], 64, 2, &[]);
            sorted(sim.clusters(0).to_vec())
        };
        let mut cfg = pool(2);
        cfg.tenants[1].quota = 0;
        let mut sim = MultiTenantSim::new(cfg).unwrap();
        sim.run(&[ctx.tuples().to_vec(), ctx.tuples().to_vec()], 64, 2, &[]);
        assert!(sim.clusters(1).is_empty(), "zero quota indexes nothing");
        assert_eq!(sim.stats().accepted[1], 0);
        assert_eq!(sorted(sim.clusters(0).to_vec()).len(), solo.len());
        assert_eq!(sim.fairness_spread(), 1.0, "one active tenant");
    }

    #[test]
    fn correlated_kills_rebuild_every_tenant_on_the_victims() {
        let ctxs = [stream(400, 9, 6), stream(400, 9, 7)];
        let streams: Vec<Vec<NTuple>> =
            ctxs.iter().map(|c| c.tuples().to_vec()).collect();
        let mut sim = MultiTenantSim::new(pool(2)).unwrap();
        // placement-correlated: the two hottest nodes die together twice
        let kills = crate::workload::correlated_kills(
            sim.assignment(0),
            3,
            2,
            2,
            7,
            99,
        );
        sim.run(&streams, 64, 2, &kills);
        assert_eq!(sim.stats().kills, 4, "two events × two victims");
        assert!(sim.stats().replayed_tuples > 0, "kills replay state");
        for (t, ctx) in ctxs.iter().enumerate() {
            let reference = sorted(mine_online(ctx, &Constraints::none()));
            let got = sorted(sim.clusters(t).to_vec());
            assert_eq!(got.len(), reference.len(), "tenant {t} exact after kills");
        }
    }

    #[test]
    fn segment_backed_pool_recovers_exactly_from_per_tenant_logs() {
        let ctxs = [stream(400, 9, 10), stream(300, 8, 11)];
        let streams: Vec<Vec<NTuple>> =
            ctxs.iter().map(|c| c.tuples().to_vec()).collect();
        let dir = std::env::temp_dir().join("tricluster_tenant_segment_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = pool(2);
        cfg.segment_dir = Some(dir.clone());
        cfg.resident_mib = 1;
        let mut sim = MultiTenantSim::new(cfg).unwrap();
        let kills = crate::workload::correlated_kills(
            sim.assignment(0),
            3,
            2,
            2,
            7,
            99,
        );
        sim.run(&streams, 64, 2, &kills);
        assert!(sim.stats().kills > 0, "kills must land for this to test recovery");
        for (t, ctx) in ctxs.iter().enumerate() {
            let reference = sorted(mine_online(ctx, &Constraints::none()));
            let got = sorted(sim.clusters(t).to_vec());
            assert_eq!(got.len(), reference.len(), "tenant {t} exact via adoption");
            for (a, b) in got.iter().zip(&reference) {
                assert_eq!(a.components, b.components);
                assert_eq!(a.support, b.support);
            }
        }
        // every tenant journalled under its own sub-log
        for t in 0..2 {
            let sub = dir.join(format!("t{t}"));
            assert!(
                std::fs::read_dir(&sub).unwrap().count() > 0,
                "tenant {t} wrote segments"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn per_tenant_constraints_are_independent() {
        let ctx = stream(300, 6, 8);
        let tight = Constraints { min_density: 1.0, min_support: 1 };
        let mut cfg = pool(2);
        cfg.tenants[1].constraints = tight.clone();
        let mut sim = MultiTenantSim::new(cfg).unwrap();
        sim.run(&[ctx.tuples().to_vec(), ctx.tuples().to_vec()], 97, 3, &[]);
        let loose = sorted(mine_online(&ctx, &Constraints::none()));
        let dense = sorted(mine_online(&ctx, &tight));
        assert_eq!(sim.clusters(0).len(), loose.len());
        assert_eq!(sim.clusters(1).len(), dense.len());
        assert!(dense.len() < loose.len(), "θ=1.0 must filter");
    }

    #[test]
    fn pool_is_deterministic_for_a_seed() {
        let ctx = stream(300, 8, 9);
        let run = || {
            let mut sim = MultiTenantSim::new(pool(2)).unwrap();
            sim.run(
                &[ctx.tuples().to_vec(), ctx.tuples().to_vec()],
                64,
                2,
                &crate::workload::correlated_kills(&[0, 1, 2, 0], 3, 2, 1, 5, 3),
            );
            (sim.sim_makespan_ms(), sim.fairness_spread(), sim.stats().clone())
        };
        let (a_ms, a_fair, a_stats) = run();
        let (b_ms, b_fair, b_stats) = run();
        assert_eq!(a_ms.to_bits(), b_ms.to_bits());
        assert_eq!(a_fair.to_bits(), b_fair.to_bits());
        assert_eq!(a_stats.shuffle_mib.to_bits(), b_stats.shuffle_mib.to_bits());
        assert_eq!(a_stats.accepted, b_stats.accepted);
    }

    #[test]
    fn empty_mix_and_unknown_placement_are_errors() {
        assert!(MultiTenantSim::new(TenantPoolConfig::new(2)).is_err());
        let mut cfg = pool(1);
        cfg.placement = "yarn".into();
        assert!(MultiTenantSim::new(cfg).is_err());
    }
}
