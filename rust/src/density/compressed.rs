//! Compressed per-(g, m) rows — the roaring-style array/bitmap/run
//! hybrid that keeps dense contexts on an exact vectorised kernel after
//! the flat [`crate::density::tiling::BitRows`] table trips its byte cap
//! ([`crate::density::exact::BITSET_MAX_BYTES`]).
//!
//! `BitRows` spends `|G|·|M|·⌈|B|/64⌉·8` bytes whether or not a `(g, m)`
//! pair has any triple — on wide-id contexts (MovieLens-scale) that grid
//! explodes while the relation itself stays modest. `CompressedRows`
//! stores one container per NON-EMPTY `(g, m)` row, each encoded in
//! whichever of three shapes is smallest for its contents:
//!
//! * **Array**  — sorted `b` ids, 4 B each (sparse scattered rows);
//! * **Bitmap** — packed `u64` words up to the row's own max `b`
//!   (dense scattered rows);
//! * **Runs**   — sorted `(start, len)` ranges (dense contiguous rows —
//!   the paper's K1/K2 block regime collapses to ONE run per row).
//!
//! Build memory is `O(|I|)` (one sortable record per triple), so unlike
//! the flat table the build cannot be rejected: the exact engine's
//! dispatch ladder is bitset → compressed → scalar and a dense context
//! never regresses to the `O(volume)` scalar probe loop. Counting stays
//! exact — every container arm computes the same integer hit count, so
//! densities are bit-identical to [`densities_scalar`]
//! (property-tested in `rust/tests/proptests.rs`).
//!
//! [`densities_scalar`]: crate::density::exact::densities_scalar

use crate::core::context::TriContext;
use crate::core::pattern::Cluster;
use crate::density::tiling::{bit_mask, bit_mask_count_range};
use crate::util::hash::FxHashMap;

/// One compressed row: the `b` memberships of a single `(g, m)` pair.
#[derive(Debug, Clone)]
enum Container {
    /// Sorted distinct `b` ids.
    Array(Vec<u32>),
    /// Packed bit words over `[0, words·64)` of the row's own span.
    Bitmap(Vec<u64>),
    /// Sorted disjoint `(start, len)` runs of consecutive ids, `len ≥ 1`.
    Runs(Vec<(u32, u32)>),
}

impl Container {
    /// Encode a sorted, deduplicated, non-empty id slice as whichever
    /// container costs the fewest bytes (ties prefer runs, then array —
    /// the shapes with the cheapest count loops).
    fn choose(bs: &[u32]) -> Container {
        debug_assert!(!bs.is_empty() && bs.windows(2).all(|w| w[0] < w[1]));
        let span_words = bs[bs.len() - 1] as usize / 64 + 1;
        let n_runs = 1 + bs.windows(2).filter(|w| w[1] != w[0] + 1).count();
        let run_bytes = 8 * n_runs;
        let array_bytes = 4 * bs.len();
        let bitmap_bytes = 8 * span_words;
        if run_bytes <= array_bytes && run_bytes <= bitmap_bytes {
            let mut runs = Vec::with_capacity(n_runs);
            let (mut start, mut len) = (bs[0], 1u32);
            for w in bs.windows(2) {
                if w[1] == w[0] + 1 {
                    len += 1;
                } else {
                    runs.push((start, len));
                    start = w[1];
                    len = 1;
                }
            }
            runs.push((start, len));
            Container::Runs(runs)
        } else if array_bytes <= bitmap_bytes {
            Container::Array(bs.to_vec())
        } else {
            let mut words = vec![0u64; span_words];
            for &b in bs {
                words[b as usize / 64] |= 1u64 << (b % 64);
            }
            Container::Bitmap(words)
        }
    }

    /// Hits of this row against a modus bit mask wide enough for every
    /// `b` in the table ([`CompressedRows::words`] words). Each arm is an
    /// exact integer count.
    fn count(&self, mask: &[u64]) -> u64 {
        match self {
            Container::Array(bs) => bs
                .iter()
                .map(|&b| (mask[b as usize / 64] >> (b % 64)) & 1)
                .sum(),
            Container::Bitmap(words) => words
                .iter()
                .zip(mask)
                .map(|(w, m)| (w & m).count_ones() as u64)
                .sum(),
            Container::Runs(runs) => runs
                .iter()
                .map(|&(start, len)| bit_mask_count_range(mask, start, len))
                .sum(),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Container::Array(bs) => 4 * bs.len(),
            Container::Bitmap(words) => 8 * words.len(),
            Container::Runs(runs) => 8 * runs.len(),
        }
    }
}

/// Compressed row table of a whole context: one [`Container`] per
/// non-empty `(g, m)` pair, grouped by `g` so a cluster probes the map
/// once per extent id and binary-searches the (sorted) row list per
/// intent id — the same probe discipline as the scalar oracle, so
/// duplicate or unsorted cluster components count identically.
#[derive(Debug)]
pub struct CompressedRows {
    /// `g` → index range `[lo, hi)` into `row_ms` / `containers`.
    by_g: FxHashMap<u32, (u32, u32)>,
    /// Sorted distinct `m` of each g's rows, grouped contiguously by g.
    row_ms: Vec<u32>,
    /// Parallel to `row_ms`.
    containers: Vec<Container>,
    /// Mask words covering the widest `b` in the table.
    words: usize,
}

impl CompressedRows {
    /// Build from a context. `O(|I| log |I|)` time, `O(|I|)` memory —
    /// never rejected, unlike the flat row table.
    pub fn build(ctx: &TriContext) -> Self {
        // one sortable record per triple: (g, m) packed high, b low —
        // after the sort, rows are contiguous and their bs ascend
        let mut recs: Vec<(u64, u32)> = ctx
            .triples()
            .iter()
            .map(|t| (((t.get(0) as u64) << 32) | t.get(1) as u64, t.get(2)))
            .collect();
        recs.sort_unstable();
        let mut by_g: FxHashMap<u32, (u32, u32)> = FxHashMap::default();
        let mut row_ms: Vec<u32> = Vec::new();
        let mut containers: Vec<Container> = Vec::new();
        let mut max_b = 0u32;
        let mut bs: Vec<u32> = Vec::new();
        let mut i = 0usize;
        while i < recs.len() {
            let gm = recs[i].0;
            bs.clear();
            while i < recs.len() && recs[i].0 == gm {
                bs.push(recs[i].1);
                i += 1;
            }
            // context tuples are deduplicated, so bs is sorted + distinct
            max_b = max_b.max(bs[bs.len() - 1]);
            let g = (gm >> 32) as u32;
            let m = gm as u32;
            let at = row_ms.len() as u32;
            by_g
                .entry(g)
                .and_modify(|range| range.1 = at + 1)
                .or_insert((at, at + 1));
            row_ms.push(m);
            containers.push(Container::choose(&bs));
        }
        let words = if containers.is_empty() { 1 } else { max_b as usize / 64 + 1 };
        Self { by_g, row_ms, containers, words }
    }

    /// Mask words wide enough for every `b` in the table.
    pub fn words(&self) -> usize {
        self.words
    }

    /// Non-empty `(g, m)` rows.
    pub fn n_rows(&self) -> usize {
        self.containers.len()
    }

    /// Payload bytes across all containers (telemetry; excludes the
    /// per-row index).
    pub fn bytes(&self) -> usize {
        self.containers.iter().map(Container::bytes).sum()
    }

    /// Exact densities of `clusters` against this table — bit-identical
    /// to the scalar oracle (integer hit count over the same cells,
    /// identical final division).
    pub fn densities(&self, clusters: &[Cluster]) -> Vec<f64> {
        let mut mask: Vec<u64> = Vec::new();
        clusters
            .iter()
            .map(|c| {
                let vol = c.volume();
                if vol == 0.0 {
                    return 0.0;
                }
                bit_mask(&c.components[2], self.words, &mut mask);
                let mut hit = 0u64;
                for &g in &c.components[0] {
                    let Some(&(lo, hi)) = self.by_g.get(&g) else {
                        continue;
                    };
                    let ms = &self.row_ms[lo as usize..hi as usize];
                    let cs = &self.containers[lo as usize..hi as usize];
                    for &m in &c.components[1] {
                        if let Ok(at) = ms.binary_search(&m) {
                            hit += cs[at].count(&mask);
                        }
                    }
                }
                hit as f64 / vol
            })
            .collect()
    }
}

/// Build + count in one call — the engine's compressed dispatch arm and
/// the bench's standalone kernel entry.
pub fn densities_compressed(ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64> {
    CompressedRows::build(ctx).densities(clusters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;
    use crate::datasets::synthetic::{k1, k2};
    use crate::density::exact::densities_scalar;

    #[test]
    fn container_choice_and_counts() {
        // one solid run
        let run = Container::choose(&(10..90).collect::<Vec<u32>>());
        assert!(matches!(run, Container::Runs(ref r) if r == &vec![(10, 80)]));
        // scattered sparse ids over a wide span → array
        let arr = Container::choose(&[1, 500, 9000]);
        assert!(matches!(arr, Container::Array(_)));
        // dense scattered (every other id) → bitmap beats 32 runs/ids
        let alt: Vec<u32> = (0..64).map(|i| i * 2).collect();
        let bmp = Container::choose(&alt);
        assert!(matches!(bmp, Container::Bitmap(_)));
        // all three count identically against the same mask
        let ids: Vec<u32> = vec![3, 4, 5, 6, 64, 66, 130];
        let mut mask = Vec::new();
        bit_mask(&[4, 5, 66, 129, 130], 3, &mut mask);
        for c in [
            Container::Array(ids.clone()),
            Container::choose(&ids),
        ] {
            assert_eq!(c.count(&mask), 4, "{c:?}");
        }
    }

    #[test]
    fn compressed_matches_scalar_on_blocks() {
        use crate::oac::{mine_online, Constraints};
        for ctx in [k1(6), k2(4)] {
            let mut clusters = mine_online(&ctx.inner, &Constraints::none());
            // out-of-extent ids and a zero-volume cluster must behave
            // exactly like the oracle (zero hits, 0.0)
            clusters.push(tricluster(vec![0, 90], vec![1, 80], vec![0, 63, 200]));
            clusters.push(tricluster(vec![], vec![0], vec![0]));
            assert_eq!(
                densities_compressed(&ctx, &clusters),
                densities_scalar(&ctx, &clusters)
            );
        }
    }

    #[test]
    fn wide_ids_stay_cheap() {
        // a far-flung (g, m) pair explodes the flat grid but costs one
        // row here
        let mut ctx = TriContext::new();
        ctx.add(0, 0, 0);
        ctx.add(2_000_000, 3_000_000, 5);
        let rows = CompressedRows::build(&ctx);
        assert_eq!(rows.n_rows(), 2);
        assert!(rows.bytes() < 64);
        let c = tricluster(vec![0, 2_000_000], vec![0, 3_000_000], vec![0, 5]);
        assert_eq!(
            rows.densities(std::slice::from_ref(&c)),
            densities_scalar(&ctx, std::slice::from_ref(&c))
        );
    }

    #[test]
    fn empty_context_counts_zero() {
        let ctx = TriContext::new();
        let c = tricluster(vec![0], vec![0], vec![0]);
        assert_eq!(densities_compressed(&ctx, &[c]), vec![0.0]);
    }
}
