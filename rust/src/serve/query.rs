//! Query API over a compacted cluster index: top-k by density,
//! membership lookup, and aggregate stats.
//!
//! Since the epoch-snapshot redesign, the index itself lives in
//! [`EpochSnapshot`] (see [`crate::serve::epoch`]) and [`QueryEngine`]
//! is an OWNED thin wrapper over one `Arc<EpochSnapshot>` — it no
//! longer borrows the service, so holding an engine never blocks
//! ingest or compaction. Prefer [`crate::serve::QueryBackend`] for new
//! code (it adds caching and replica routing); `QueryEngine` remains
//! the direct, zero-policy view, and is what the equivalence suites
//! compare every backend against.
//!
//! Membership lookups ([`QueryEngine::containing`]) return borrowed
//! `&[u32]` cluster ids from the snapshot's inverted index —
//! allocation-free — with [`QueryEngine::resolve`] mapping an id back
//! to its cluster.

use std::sync::Arc;

use crate::core::pattern::Cluster;
use crate::serve::epoch::EpochSnapshot;

pub use crate::serve::epoch::IndexStats;

/// Read-only query surface over one epoch snapshot (owned — cheap to
/// construct from a service via [`crate::serve::TriclusterService::snapshot`],
/// and independent of the service's lifetime once constructed).
#[derive(Debug)]
pub struct QueryEngine {
    snap: Arc<EpochSnapshot>,
}

impl QueryEngine {
    /// Build an engine over a borrowed cluster slice.
    ///
    /// Deprecated shim (pre-epoch API): clones the slice into a
    /// detached epoch-0 snapshot. Migrate to
    /// [`crate::serve::TriclusterService::snapshot`] +
    /// [`Self::from_snapshot`] (or [`EpochSnapshot::build`] directly)
    /// to share the already-published index instead of copying it —
    /// see the ARCHITECTURE.md migration map.
    pub fn new(clusters: &[Cluster]) -> Self {
        let mut span = crate::span!("serve.query.build");
        span.records_in(clusters.len() as u64);
        Self { snap: EpochSnapshot::build(0, clusters.to_vec(), 0) }
    }

    /// Engine over an already-published snapshot (no copying — shares
    /// the `Arc`).
    pub fn from_snapshot(snap: Arc<EpochSnapshot>) -> Self {
        Self { snap }
    }

    /// The underlying snapshot.
    pub fn snapshot(&self) -> &Arc<EpochSnapshot> {
        &self.snap
    }

    /// The epoch this engine answers at.
    pub fn epoch(&self) -> u64 {
        self.snap.epoch()
    }

    /// Clusters in the snapshot.
    pub fn len(&self) -> usize {
        self.snap.len()
    }

    /// True when the snapshot has no clusters.
    pub fn is_empty(&self) -> bool {
        self.snap.is_empty()
    }

    /// The k densest clusters (support-density, ties broken by support
    /// then components — total and deterministic; see
    /// [`EpochSnapshot::top_k_by_density`]).
    pub fn top_k_by_density(&self, k: usize) -> Vec<&Cluster> {
        self.snap.top_k_by_density(k)
    }

    /// Ids of every cluster whose modality-`m` component contains
    /// `entity`, in index order — allocation-free (borrows the
    /// snapshot's inverted index). Resolve ids with [`Self::resolve`].
    pub fn containing(&self, modality: usize, entity: u32) -> &[u32] {
        self.snap.containing(modality, entity)
    }

    /// The cluster behind an id returned by [`Self::containing`].
    pub fn resolve(&self, id: u32) -> &Cluster {
        self.snap.resolve(id)
    }

    /// Support and density of the clusters containing `(modality,
    /// entity)` — the per-entity serving stats.
    pub fn entity_stats(&self, modality: usize, entity: u32) -> Option<IndexStats> {
        self.snap.entity_stats(modality, entity)
    }

    /// Aggregate stats over the whole snapshot (no intermediate
    /// collection — the stats fold streams over the clusters).
    pub fn stats(&self) -> IndexStats {
        self.snap.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;

    fn fixture() -> Vec<Cluster> {
        // densities: a = 1.0 (support 4 / volume 4), b = 0.5 (2/4),
        // c = 1.0 (1/1)
        let mut a = tricluster(vec![0], vec![0, 1], vec![0, 1]);
        a.support = 4;
        let mut b = tricluster(vec![1, 2], vec![0], vec![0, 1]);
        b.support = 2;
        let mut c = tricluster(vec![5], vec![5], vec![5]);
        c.support = 1;
        vec![a, b, c]
    }

    #[test]
    fn top_k_orders_by_density_then_support() {
        let cs = fixture();
        let q = QueryEngine::new(&cs);
        let top = q.top_k_by_density(2);
        assert_eq!(top.len(), 2);
        // both density-1.0 clusters lead; support 4 beats support 1
        assert_eq!(top[0].components[0], vec![0]);
        assert_eq!(top[1].components[0], vec![5]);
        // k larger than the index is clamped
        assert_eq!(q.top_k_by_density(10).len(), 3);
    }

    #[test]
    fn membership_lookup() {
        let cs = fixture();
        let q = QueryEngine::new(&cs);
        // entity 0 in modality 1 appears in clusters a and b
        let hits = q.containing(1, 0);
        assert_eq!(hits.len(), 2);
        // entity 2 in modality 0 appears only in b — ids resolve back
        let hits = q.containing(0, 2);
        assert_eq!(hits.len(), 1);
        assert_eq!(q.resolve(hits[0]).support, 2);
        // absent entity
        assert!(q.containing(2, 99).is_empty());
        assert!(q.entity_stats(2, 99).is_none());
    }

    #[test]
    fn stats_aggregate() {
        let cs = fixture();
        let q = QueryEngine::new(&cs);
        let s = q.stats();
        assert_eq!(s.clusters, 3);
        assert_eq!(s.total_support, 7);
        assert_eq!(s.max_density, 1.0);
        assert!((s.mean_density - (1.0 + 0.5 + 1.0) / 3.0).abs() < 1e-12);
        assert_eq!(s.max_component, 2);
        let es = q.entity_stats(0, 5).unwrap();
        assert_eq!(es.clusters, 1);
        assert_eq!(es.total_support, 1);
    }

    #[test]
    fn engine_from_snapshot_shares_the_published_index() {
        let snap = EpochSnapshot::build(7, fixture(), 7);
        let q = QueryEngine::from_snapshot(Arc::clone(&snap));
        assert_eq!(q.epoch(), 7);
        assert_eq!(q.len(), 3);
        assert!(Arc::ptr_eq(q.snapshot(), &snap), "no copy");
    }
}
