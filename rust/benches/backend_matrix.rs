//! Bench: the backend matrix — the identical cumuli → assembly →
//! dedup+density workload on every `exec::` backend × worker counts,
//! turning the paper's Tables 3–5 regime comparison into one sweep.
//! Writes `BENCH_backends.json` (repo root) so the perf trajectory is
//! machine-readable across PRs.
//!
//! Doubles as an acceptance gate: every run is checked against the
//! online-miner reference cluster set (components AND supports), so a
//! backend regression fails the process — CI smoke-runs the quick mode.
//! `TRICLUSTER_BENCH_FULL=1` for the paper-sized contexts.

use std::collections::BTreeMap;

use tricluster::core::context::PolyContext;
use tricluster::core::pattern::{diff_cluster_sets, sort_clusters, Cluster};
use tricluster::datasets::{movielens, synthetic, MovielensParams};
use tricluster::exec::{run_named, ExecTuning, BACKENDS};
use tricluster::oac::{mine_online, Constraints};
use tricluster::util::json::Json;

fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
    sort_clusters(&mut cs);
    cs
}

fn assert_matches(reference: &[Cluster], got: &[Cluster], label: &str) {
    if let Some(diff) = diff_cluster_sets(reference, got) {
        panic!("{label}: backend diverged from mine_online: {diff}");
    }
}

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn main() {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let datasets: Vec<(&str, PolyContext)> = if full {
        vec![
            ("K1-40", synthetic::k1(40).inner),
            ("MovieLens200k", movielens(&MovielensParams::with_tuples(200_000))),
        ]
    } else {
        vec![
            ("K1-12", synthetic::k1(12).inner),
            ("MovieLens20k", movielens(&MovielensParams::with_tuples(20_000))),
        ]
    };
    let max_workers = tricluster::util::pool::default_workers();
    let mut worker_counts = vec![1usize, 2, 4, max_workers];
    worker_counts.sort_unstable();
    worker_counts.dedup();
    eprintln!(
        "backend_matrix bench (full={full}): {} datasets × {:?} workers × {:?}",
        datasets.len(),
        worker_counts,
        BACKENDS
    );

    let mut series: Vec<Json> = Vec::new();
    for (name, ctx) in &datasets {
        let reference = sorted(mine_online(ctx, &Constraints::none()));
        let mut seq_ms = f64::NAN;
        for &workers in &worker_counts {
            for backend in BACKENDS {
                // the sequential backend has no worker knob: run it once
                if backend == "seq" && workers != worker_counts[0] {
                    continue;
                }
                let tune = ExecTuning {
                    workers,
                    tasks: (workers * 4).max(8),
                    ..ExecTuning::default()
                };
                let run = run_named(backend, ctx, 0.0, &tune).expect("backend run");
                assert_matches(
                    &reference,
                    &run.clusters,
                    &format!("{name}/{backend}/x{workers}"),
                );
                if backend == "seq" {
                    seq_ms = run.wall_ms;
                }
                let speedup = seq_ms / run.wall_ms;
                eprintln!(
                    "  {name:<14} {backend:<7} x{workers}: {:8.1} ms  ({} clusters, {:.2}x vs seq)",
                    run.wall_ms,
                    run.clusters.len(),
                    speedup
                );
                let mut o = BTreeMap::new();
                o.insert("dataset".to_string(), Json::Str(name.to_string()));
                o.insert("backend".to_string(), Json::Str(backend.to_string()));
                o.insert("workers".to_string(), num(workers as f64));
                o.insert("wall_ms".to_string(), num(run.wall_ms));
                o.insert("clusters".to_string(), num(run.clusters.len() as f64));
                o.insert("tuples".to_string(), num(ctx.len() as f64));
                o.insert("speedup_vs_seq".to_string(), num(speedup));
                series.push(Json::Obj(o));
            }
        }
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("backend_matrix".into()));
    doc.insert("full".to_string(), Json::Bool(full));
    doc.insert(
        "backends".to_string(),
        Json::Arr(BACKENDS.iter().map(|b| Json::Str(b.to_string())).collect()),
    );
    doc.insert(
        "workers".to_string(),
        Json::Arr(worker_counts.iter().map(|&w| num(w as f64)).collect()),
    );
    doc.insert(
        "cores".to_string(),
        num(tricluster::util::pool::default_workers() as f64),
    );
    doc.insert("series".to_string(), Json::Arr(series));
    let json = Json::Obj(doc);
    std::fs::write("BENCH_backends.json", json.to_string())
        .expect("write BENCH_backends.json");
    eprintln!("wrote BENCH_backends.json (all backends agreed with mine_online)");
}
