"""Layer-2 graphs + AOT lowering: shapes, numerics, and HLO-text validity."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_density_graph_counts_and_volumes():
    rng = np.random.default_rng(0)
    t = (rng.random((32, 32, 32)) < 0.2).astype(np.float32)
    x = (rng.random((32, 32)) < 0.5).astype(np.float32)
    counts, vols = model.density_graph(
        jnp.array(t), jnp.array(x), jnp.array(x), jnp.array(x))
    np.testing.assert_allclose(np.asarray(counts),
                               np.asarray(ref.density_ref(t, x, x, x)))
    np.testing.assert_allclose(np.asarray(vols),
                               np.asarray(ref.volumes_ref(x, x, x)))


def test_delta_graph_cards_match_mask_sums():
    rng = np.random.default_rng(1)
    v = (rng.normal(size=(64, 128)) * 50).astype(np.float32)
    p = (rng.random((64, 128)) < 0.5).astype(np.float32)
    c = (rng.normal(size=(64,)) * 50).astype(np.float32)
    masks, cards = model.delta_graph(
        jnp.array([20.0], dtype=jnp.float32), jnp.array(v), jnp.array(p),
        jnp.array(c))
    np.testing.assert_allclose(np.asarray(cards), np.asarray(masks).sum(1))
    np.testing.assert_array_equal(np.asarray(masks),
                                  np.asarray(ref.delta_ref(v, p, c, 20.0)))


def test_mc_graph_estimates_density():
    rng = np.random.default_rng(2)
    t = (rng.random((64, 64, 64)) < 0.37).astype(np.float32)
    coords = rng.integers(0, 64, size=(1024, 3)).astype(np.int32)
    (rho,) = model.mc_graph(jnp.array(t), jnp.array(coords))
    want = np.asarray(ref.mc_density_ref(t, coords))
    np.testing.assert_allclose(np.asarray(rho), want, rtol=1e-6)
    # statistical sanity: 1024 samples of a 0.37-dense tensor
    assert abs(float(rho) - 0.37) < 0.08


def test_hlo_text_lowering_roundtrips_all_variants():
    # Every variant must lower to parseable, non-trivial HLO text with an
    # ENTRY computation and a tuple root (return_tuple=True convention).
    for name, fn, arg_specs, io in aot.variants():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "tuple(" in text, name
        for inp in io["inputs"]:
            assert len(inp["shape"]) >= 0  # manifest structurally sound


def test_manifest_matches_artifacts_on_disk():
    mpath = os.path.join(ART, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    assert manifest["return_tuple"] is True
    for name, io in manifest["artifacts"].items():
        path = os.path.join(ART, io["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, name
    # perf model recorded for DESIGN §Perf
    assert manifest["perf_model"]["density_vmem_bytes_per_step"] < 16 * 2**20


def test_density_artifact_is_reproducible_and_numerically_anchored():
    """The on-disk artifact equals a fresh lowering of the same graph, and
    that graph's numerics match the oracle for the AOT geometry.

    (End-to-end execution of the artifact *file* happens on the Rust side:
    rust/tests/runtime_integration.rs loads artifacts/*.hlo.txt through the
    PJRT CPU client and re-checks these numbers — that is the product path.)
    """
    from jax._src.lib import xla_client as xc
    mpath = os.path.join(ART, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built (run `make artifacts`)")
    rng = np.random.default_rng(3)
    t = (rng.random((64, 64, 64)) < 0.15).astype(np.float32)
    x = (rng.random((32, 64)) < 0.5).astype(np.float32)

    lowered = jax.jit(model.density_graph).lower(
        *(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in (t, x, x, x)))
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")),
        use_tuple_args=False, return_tuple=True)
    with open(os.path.join(ART, "density_g64_k32.hlo.txt")) as f:
        assert f.read() == comp.as_hlo_text()  # artifact is reproducible

    counts, _ = model.density_graph(
        jnp.array(t), jnp.array(x), jnp.array(x), jnp.array(x))
    np.testing.assert_allclose(np.asarray(counts),
                               np.asarray(ref.density_ref(t, x, x, x)))
