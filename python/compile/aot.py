"""AOT lowering: jax/Pallas Layer-1/2 graphs → HLO text artifacts.

Interchange format is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts`:
    cd python && python -m compile.aot --out-dir ../artifacts

Writes one .hlo.txt per (graph, shape variant) plus manifest.json that the
Rust runtime (rust/src/runtime/artifacts.rs) reads to know the calling
convention of each artifact.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import density as density_kernel

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple calling conv)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Every artifact variant the Rust side may load. Keyed by artifact name;
# fn(variant-params) -> (jitted fn, example arg specs, io description).
def variants():
    out = []

    # density tiles: the workhorse 64³ tile with two cluster-batch sizes,
    # and a 32³ tile for small contexts (IMDB-scale) to cut padding waste.
    for (g, k) in [(64, 32), (64, 128), (32, 32)]:
        name = f"density_g{g}_k{k}"
        args = [spec((g, g, g)), spec((k, g)), spec((k, g)), spec((k, g))]
        out.append((name, model.density_graph, args, {
            "graph": "density",
            "inputs": [
                {"name": "tensor", "shape": [g, g, g], "dtype": "f32"},
                {"name": "xmask", "shape": [k, g], "dtype": "f32"},
                {"name": "ymask", "shape": [k, g], "dtype": "f32"},
                {"name": "zmask", "shape": [k, g], "dtype": "f32"},
            ],
            "outputs": [
                {"name": "counts", "shape": [k], "dtype": "f32"},
                {"name": "volumes", "shape": [k], "dtype": "f32"},
            ],
            "tile": g, "k": k,
        }))

    # δ slabs for NOAC: 64 fibers × 128 padded length.
    for (kf, l) in [(64, 128), (64, 512)]:
        name = f"delta_k{kf}_l{l}"
        args = [spec((1,)), spec((kf, l)), spec((kf, l)), spec((kf,))]
        out.append((name, model.delta_graph, args, {
            "graph": "delta",
            "inputs": [
                {"name": "delta", "shape": [1], "dtype": "f32"},
                {"name": "values", "shape": [kf, l], "dtype": "f32"},
                {"name": "present", "shape": [kf, l], "dtype": "f32"},
                {"name": "centers", "shape": [kf], "dtype": "f32"},
            ],
            "outputs": [
                {"name": "masks", "shape": [kf, l], "dtype": "f32"},
                {"name": "cards", "shape": [kf], "dtype": "f32"},
            ],
            "k": kf, "l": l,
        }))

    # Monte-Carlo density estimator over a 64³ tile, 1024 samples.
    g, s = 64, 1024
    out.append((f"mc_g{g}_s{s}", model.mc_graph,
                [spec((g, g, g)), spec((s, 3), I32)], {
        "graph": "mc",
        "inputs": [
            {"name": "tensor", "shape": [g, g, g], "dtype": "f32"},
            {"name": "coords", "shape": [s, 3], "dtype": "i32"},
        ],
        "outputs": [{"name": "rho_hat", "shape": [], "dtype": "f32"}],
        "tile": g, "samples": s,
    }))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="compat: single-file target; writes the default "
                         "density artifact there in addition to --out-dir")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "return_tuple": True, "artifacts": {}}
    for name, fn, arg_specs, io in variants():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        io["file"] = f"{name}.hlo.txt"
        manifest["artifacts"][name] = io
        print(f"wrote {path} ({len(text)} chars)")

    # Static perf model for DESIGN/EXPERIMENTS §Perf.
    manifest["perf_model"] = {
        "density_vmem_bytes_per_step": density_kernel.vmem_bytes(),
        "density_mxu_macs_per_step": density_kernel.mxu_flops(),
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")

    if args.out:
        lowered = jax.jit(model.density_graph).lower(
            spec((64, 64, 64)), spec((32, 64)), spec((32, 64)), spec((32, 64)))
        with open(args.out, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
