//! Online multimodal OAC-prime clustering — paper Alg. 1 and its N-ary
//! generalisation (§3.1).
//!
//! One pass, `O(|I|)` time and memory: each incoming tuple updates N
//! cumulus sets and records one generated cluster as N set "pointers".
//! Duplicate elimination and constraint filtering happen in
//! post-processing (`post.rs`), as the paper prescribes, so no patterns
//! are lost mid-stream.

use crate::core::pattern::Cluster;
use crate::core::tuple::{NTuple, SubRelation};
use crate::oac::primes::{PrimeStore, SetArena, SetIds};
use std::path::PathBuf;

/// A generated (not yet materialised) cluster: the N set ids plus the
/// generating tuple. Both halves are inline/`Copy` — the per-tuple hot
/// path records a generated cluster without touching the heap.
#[derive(Debug, Clone, Copy)]
pub struct Generated {
    /// The N cumulus-set ids, one per dropped modality.
    pub set_ids: SetIds,
    /// The tuple that generated this cluster.
    pub tuple: NTuple,
}

/// Streaming state of the online algorithm.
#[derive(Debug)]
pub struct OnlineMiner {
    primes: PrimeStore,
    generated: Vec<Generated>,
}

impl OnlineMiner {
    /// Empty miner over `arity` modalities.
    pub fn new(arity: usize) -> Self {
        Self { primes: PrimeStore::new(arity), generated: Vec::new() }
    }

    /// Rebuild a miner from a persisted image: the exported cumuli are
    /// bulk-adopted ([`PrimeStore::adopt`] — sealed caches, no re-sort,
    /// no per-tuple re-mine) and the generated log is replayed by
    /// resolving each historical tuple's keys with
    /// [`PrimeStore::probe`]. `Err` carries a description when a tuple
    /// fails to resolve — the image is internally inconsistent (its
    /// tuple log references keys its cumuli don't contain).
    pub fn from_image(
        arity: usize,
        tuples: &[NTuple],
        cumuli: Vec<(SubRelation, Vec<u32>)>,
    ) -> Result<Self, String> {
        let primes = PrimeStore::adopt(arity, cumuli);
        let mut generated = Vec::with_capacity(tuples.len());
        for &tuple in tuples {
            let set_ids = primes
                .probe(&tuple)
                .ok_or_else(|| "tuple log references a missing cumulus key".to_string())?;
            generated.push(Generated { set_ids, tuple });
        }
        Ok(Self { primes, generated })
    }

    /// Export every cumulus as `⟨subrelation, sorted contents⟩` in
    /// canonical key order (seals the arena) — what segments persist;
    /// [`Self::from_image`] is the inverse.
    pub fn cumuli(&mut self) -> Vec<(SubRelation, Vec<u32>)> {
        self.primes.cumuli()
    }

    /// Cap the arena's resident pages; cold page chains spill to disk
    /// under `spill_dir` once ingest exceeds the budget (see
    /// [`crate::oac::primes::SetArena::set_resident_budget`]).
    pub fn set_resident_budget(&mut self, pages: usize, spill_dir: Option<PathBuf>) {
        self.primes.set_resident_budget(pages, spill_dir);
    }

    /// Alg. 1 `Add`: process a batch `J ⊆ I`. The span is per BATCH —
    /// the per-tuple loop never touches the telemetry plane (the
    /// `obs_overhead` bench gate holds the disabled cost to one atomic
    /// load per batch).
    pub fn add_batch(&mut self, batch: &[NTuple]) {
        let mut span = crate::span!("oac.ingest.batch");
        span.records_in(batch.len() as u64);
        self.generated.reserve(batch.len());
        // batched probe pipeline; bit-identical to per-tuple `add`
        let ids = self.primes.add_batch(batch);
        self.generated.extend(
            ids.into_iter()
                .zip(batch)
                .map(|(set_ids, &tuple)| Generated { set_ids, tuple }),
        );
    }

    /// [`Self::add_batch`] on `workers` threads via the merge-based
    /// [`PrimeStore::par_add_batch`]; the resulting state — set ids,
    /// dictionaries, arena contents, generated order — is bit-for-bit
    /// identical to the sequential ingest for any worker count.
    pub fn par_add_batch(&mut self, batch: &[NTuple], workers: usize) {
        let ids = self.primes.par_add_batch(batch, workers);
        self.generated.reserve(batch.len());
        self.generated.extend(
            ids.into_iter()
                .zip(batch)
                .map(|(set_ids, &tuple)| Generated { set_ids, tuple }),
        );
    }

    /// Generated clusters so far (= tuples processed).
    pub fn len(&self) -> usize {
        self.generated.len()
    }

    /// True before the first tuple.
    pub fn is_empty(&self) -> bool {
        self.generated.is_empty()
    }

    /// The prime-set store backing the cumuli.
    pub fn primes(&self) -> &PrimeStore {
        &self.primes
    }

    /// Every generated cluster, in ingest order.
    pub fn generated(&self) -> &[Generated] {
        &self.generated
    }

    /// Materialise every generated cluster (components sorted/deduped).
    /// `support` is 1 per generated cluster here; post-processing merges
    /// duplicates and accumulates it.
    pub fn materialize_all(&self) -> Vec<(Cluster, NTuple)> {
        self.generated
            .iter()
            .map(|g| {
                let comps: Vec<Vec<u32>> = g
                    .set_ids
                    .iter()
                    .map(|&id| self.primes.arena.materialize(id))
                    .collect();
                // arena materialisation is already sorted + deduped
                (Cluster::from_sorted(comps), g.tuple)
            })
            .collect()
    }

    /// Deduplicate + filter WITHOUT materialising every generated
    /// cluster: each prime set's content is materialised and
    /// fingerprinted exactly once (sets are shared by many generating
    /// tuples — in K1, n³ tuples share ~3n² sets), cluster fingerprints
    /// combine the per-set content fingerprints, and only one
    /// representative per fingerprint group is materialised. Perf-pass
    /// optimisation; equivalence with `materialize_all` + post-processing
    /// is covered by tests and the M/R cross-checks.
    pub fn dedup_and_filter(
        &mut self,
        constraints: &crate::oac::post::Constraints,
    ) -> Vec<Cluster> {
        // seal first: the dedup touches every shared set twice
        // (fingerprint pass + representative materialisation), and every
        // later call over unchanged state becomes pure memcpys
        let mut span = crate::span!("oac.dedup");
        span.records_in(self.generated.len() as u64);
        self.primes.arena.ensure_sorted_all();
        let (workers, partitions) = dedup_degree(self.generated.len());
        let out = dedup_generated_parallel(
            &self.primes.arena,
            &self.generated,
            constraints,
            workers,
            partitions,
        );
        span.records_out(out.len() as u64);
        out
    }
}

/// Generated-cluster count below which [`dedup_degree`] stays sequential:
/// four pool fan-outs (set fps, cluster fps, grouping, materialisation)
/// cost more than the dedup itself on small batches.
const PAR_DEDUP_MIN: usize = 4096;

/// Auto-sized `(workers, partitions)` for [`dedup_generated_parallel`]:
/// `(1, 1)` under [`PAR_DEDUP_MIN`] generated clusters, otherwise the
/// machine's parallelism with the partition count capped (partitions
/// beyond the worker count only add routing traffic).
pub fn dedup_degree(n_generated: usize) -> (usize, usize) {
    if n_generated < PAR_DEDUP_MIN {
        (1, 1)
    } else {
        let workers = crate::util::pool::default_workers();
        (workers, workers.min(16))
    }
}

/// Fingerprint-dedup + constraint filtering over an explicit
/// `(arena, generated)` state — the algorithm behind
/// [`OnlineMiner::dedup_and_filter`], factored out so the serve layer's
/// compactor ([`crate::serve::merge`]) runs the IDENTICAL dedup over its
/// globally-merged cumuli and the sharded-equals-sequential invariant is
/// structural, not re-implemented.
pub fn dedup_generated(
    arena: &SetArena,
    generated: &[Generated],
    constraints: &crate::oac::post::Constraints,
) -> Vec<Cluster> {
    use crate::core::pattern::combine_set_fingerprints;
    use crate::util::hash::{set_fingerprint, FxHashMap};
    let n_sets = arena.len();
    let mut set_fp: Vec<u64> = vec![0; n_sets];
    let mut set_done: Vec<bool> = vec![false; n_sets];
    let mut by_fp: FxHashMap<u64, usize> = FxHashMap::default();
    // one scratch buffer for every first-touch materialisation (the hot
    // per-triple loop allocates nothing per lookup)
    let mut scratch: Vec<u32> = Vec::new();
    // group index → (representative set ids, generating tuples)
    let mut groups: Vec<(crate::oac::primes::SetIds, Vec<NTuple>)> = Vec::new();
    for g in generated {
        let fp = combine_set_fingerprints(
            g.set_ids.len(),
            g.set_ids.iter().map(|&id| {
                let i = id as usize;
                if !set_done[i] {
                    arena.materialize_into(id, &mut scratch);
                    set_fp[i] = set_fingerprint(&scratch);
                    set_done[i] = true;
                }
                set_fp[i]
            }),
        );
        match by_fp.get(&fp) {
            Some(&gi) => groups[gi].1.push(g.tuple),
            None => {
                by_fp.insert(fp, groups.len());
                groups.push((g.set_ids, vec![g.tuple]));
            }
        }
    }
    groups
        .into_iter()
        .filter_map(|(set_ids, mut gens)| {
            gens.sort_unstable();
            gens.dedup();
            let comps: Vec<Vec<u32>> =
                set_ids.iter().map(|&id| arena.materialize(id)).collect();
            let mut c = Cluster::from_sorted(comps);
            c.support = gens.len();
            constraints.satisfied_by(&c).then_some(c)
        })
        .collect()
}

/// [`dedup_generated`] fanned out on `util::pool` — the §Perf round-2
/// dedup. Four chunked phases: (1) per-set content fingerprints over the
/// whole arena (lane-batched
/// [`crate::util::hash::set_fingerprint_batched`]; `materialize_into`
/// takes `&self`, so workers share the arena read-only); (2) per-cluster
/// fingerprints; (3) hash-partitioned first-seen grouping
/// ([`crate::util::pool::group_indices`] — equal fingerprints land in
/// one partition, the merge orders groups by unique first index);
/// (4) one representative materialised + filtered per group, in group
/// order.
///
/// Determinism contract: output is bit-for-bit identical to the
/// sequential [`dedup_generated`] — which stays as the oracle — for ANY
/// `workers`/`partitions` combination (property-tested in
/// `rust/tests/proptests.rs`). Each phase either reproduces the
/// sequential scan order exactly (groups by first occurrence, members in
/// ingest order) or computes order-independent values.
pub fn dedup_generated_parallel(
    arena: &SetArena,
    generated: &[Generated],
    constraints: &crate::oac::post::Constraints,
    workers: usize,
    partitions: usize,
) -> Vec<Cluster> {
    use crate::core::pattern::combine_set_fingerprints;
    use crate::util::hash::set_fingerprint_batched;
    use crate::util::pool;
    let workers = workers.max(1);
    let partitions = partitions.max(1);
    crate::obs::counter("oac.dedup.partitions", partitions as u64);
    if generated.is_empty() {
        return Vec::new();
    }
    let mut span = crate::span!("oac.dedup.group");
    span.records_in(generated.len() as u64);
    // (1) content fingerprint of every arena set. The sequential oracle
    // fingerprints only first-touched sets; computing all of them is the
    // same work here (every set is referenced by the tuple that
    // allocated it) and turns the memoization into a flat indexed pass.
    let n_sets = arena.len();
    let set_chunk = n_sets.div_ceil(workers * 4).max(64);
    let set_chunks = n_sets.div_ceil(set_chunk);
    let set_fp: Vec<u64> = pool::parallel_map(set_chunks, workers, 1, |ci| {
        let lo = ci * set_chunk;
        let hi = ((ci + 1) * set_chunk).min(n_sets);
        let mut scratch: Vec<u32> = Vec::new();
        (lo..hi)
            .map(|id| {
                arena.materialize_into(id as crate::oac::primes::SetId, &mut scratch);
                set_fingerprint_batched(&scratch)
            })
            .collect::<Vec<u64>>()
    })
    .into_iter()
    .flatten()
    .collect();
    // (2) cluster fingerprints, chunked over the generated stream
    let gen_chunk = generated.len().div_ceil(workers * 4).max(1024);
    let gen_chunks = generated.len().div_ceil(gen_chunk);
    let cluster_fp: Vec<u64> = pool::parallel_map(gen_chunks, workers, 1, |ci| {
        let lo = ci * gen_chunk;
        let hi = ((ci + 1) * gen_chunk).min(generated.len());
        generated[lo..hi]
            .iter()
            .map(|g| {
                combine_set_fingerprints(
                    g.set_ids.len(),
                    g.set_ids.iter().map(|&id| set_fp[id as usize]),
                )
            })
            .collect::<Vec<u64>>()
    })
    .into_iter()
    .flatten()
    .collect();
    // (3) first-seen fingerprint groups, hash-partitioned
    let groups = pool::group_indices(&cluster_fp, partitions, workers);
    crate::obs::counter("oac.dedup.groups", groups.len() as u64);
    // (4) materialise + filter one representative per group; group order
    // equals the sequential first-seen order, members the ingest order
    let out: Vec<Option<Cluster>> = pool::parallel_map(groups.len(), workers, 1, |gi| {
        let (first, members) = &groups[gi];
        let mut gens: Vec<NTuple> = members.iter().map(|&i| generated[i].tuple).collect();
        gens.sort_unstable();
        gens.dedup();
        let comps: Vec<Vec<u32>> = generated[*first]
            .set_ids
            .iter()
            .map(|&id| arena.materialize(id))
            .collect();
        let mut c = Cluster::from_sorted(comps);
        c.support = gens.len();
        constraints.satisfied_by(&c).then_some(c)
    });
    let out: Vec<Cluster> = out.into_iter().flatten().collect();
    span.records_out(out.len() as u64);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triples(ts: &[(u32, u32, u32)]) -> Vec<NTuple> {
        ts.iter().map(|&(g, m, b)| NTuple::triple(g, m, b)).collect()
    }

    #[test]
    fn table1_merging_example() {
        // The motivating example (§1): triples over users-items-labels;
        // after all four triples the cluster generated by any of them is
        // ({u2}, {i1,i2}, {l1,l2}).
        let mut miner = OnlineMiner::new(3);
        miner.add_batch(&triples(&[(0, 0, 0), (0, 1, 0), (0, 1, 1), (0, 0, 1)]));
        let mats = miner.materialize_all();
        assert_eq!(mats.len(), 4);
        for (c, _) in &mats {
            assert_eq!(c.components[0], vec![0]);
            assert_eq!(c.components[1], vec![0, 1]);
            assert_eq!(c.components[2], vec![0, 1]);
        }
    }

    #[test]
    fn pointer_semantics_late_update() {
        // A tricluster generated EARLY must reflect triples added LATER
        // (pointers, not copies — Alg. 1 line 5).
        let mut miner = OnlineMiner::new(3);
        miner.add_batch(&triples(&[(0, 0, 0)]));
        let before = miner.materialize_all();
        assert_eq!(before[0].0.components[2], vec![0]);
        miner.add_batch(&triples(&[(0, 0, 5)]));
        let after = miner.materialize_all();
        // the first generated cluster's modus now includes 5
        assert_eq!(after[0].0.components[2], vec![0, 5]);
    }

    #[test]
    fn incremental_equals_batch() {
        // one-pass property: feeding J in any chunking yields the same
        // final state
        let data = triples(&[(0, 0, 0), (1, 0, 0), (0, 1, 1), (2, 2, 2), (1, 1, 0)]);
        let mut a = OnlineMiner::new(3);
        a.add_batch(&data);
        let mut b = OnlineMiner::new(3);
        for chunk in data.chunks(2) {
            b.add_batch(chunk);
        }
        let ma: Vec<_> = a.materialize_all();
        let mb: Vec<_> = b.materialize_all();
        assert_eq!(ma.len(), mb.len());
        for ((ca, ta), (cb, tb)) in ma.iter().zip(mb.iter()) {
            assert_eq!(ca, cb);
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn memoized_dedup_equals_materialize_all_path() {
        use crate::oac::post::{self, Constraints};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let data: Vec<NTuple> = (0..500)
            .map(|_| {
                NTuple::triple(
                    rng.below(8) as u32,
                    rng.below(8) as u32,
                    rng.below(8) as u32,
                )
            })
            .collect();
        let mut miner = OnlineMiner::new(3);
        miner.add_batch(&data);
        for cons in [
            Constraints::none(),
            Constraints { min_density: 0.5, min_support: 0 },
            Constraints { min_density: 0.0, min_support: 2 },
        ] {
            let slow = post::dedup_and_filter(miner.materialize_all(), &cons);
            let fast = miner.dedup_and_filter(&cons);
            assert_eq!(slow.len(), fast.len());
            for (a, b) in slow.iter().zip(&fast) {
                assert_eq!(a.components, b.components);
                assert_eq!(a.support, b.support);
            }
        }
    }

    #[test]
    fn parallel_ingest_state_equals_sequential() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        let data: Vec<NTuple> = (0..6000)
            .map(|_| {
                NTuple::triple(
                    rng.below(12) as u32,
                    rng.below(12) as u32,
                    rng.below(12) as u32,
                )
            })
            .collect();
        let mut seq = OnlineMiner::new(3);
        seq.add_batch(&data);
        let mut par = OnlineMiner::new(3);
        par.par_add_batch(&data, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.generated().iter().zip(par.generated()) {
            assert_eq!(a.set_ids, b.set_ids);
            assert_eq!(a.tuple, b.tuple);
        }
        let cons = crate::oac::post::Constraints::none();
        let (sa, pa) = (seq.dedup_and_filter(&cons), par.dedup_and_filter(&cons));
        assert_eq!(sa.len(), pa.len());
        for (a, b) in sa.iter().zip(&pa) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
    }

    #[test]
    fn parallel_dedup_equals_sequential_oracle() {
        use crate::oac::post::Constraints;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let data: Vec<NTuple> = (0..800)
            .map(|_| {
                NTuple::triple(
                    rng.below(6) as u32,
                    rng.below(6) as u32,
                    rng.below(6) as u32,
                )
            })
            .collect();
        let mut miner = OnlineMiner::new(3);
        miner.add_batch(&data);
        let cons = Constraints { min_density: 0.0, min_support: 2 };
        let seq = dedup_generated(&miner.primes.arena, &miner.generated, &cons);
        for (workers, partitions) in [(1, 1), (1, 4), (3, 1), (4, 4), (2, 16)] {
            let par = dedup_generated_parallel(
                &miner.primes.arena,
                &miner.generated,
                &cons,
                workers,
                partitions,
            );
            assert_eq!(seq.len(), par.len(), "w={workers} p={partitions}");
            for (a, b) in seq.iter().zip(&par) {
                assert_eq!(a.components, b.components, "w={workers} p={partitions}");
                assert_eq!(a.support, b.support);
            }
        }
    }

    #[test]
    fn four_ary_generation() {
        let mut miner = OnlineMiner::new(4);
        miner.add_batch(&[
            NTuple::new(&[0, 0, 0, 0]),
            NTuple::new(&[1, 0, 0, 0]),
        ]);
        let m = miner.materialize_all();
        // cum over dropped-0 subrelation (0,0,0) = {0,1}
        assert_eq!(m[0].0.components[0], vec![0, 1]);
        assert_eq!(m[0].0.components[1], vec![0]);
    }
}
