//! Integration tests for the PJRT runtime: the AOT artifacts built by
//! `make artifacts` are loaded from disk, compiled on the CPU PJRT
//! client, executed with concrete inputs, and checked against
//! Rust-computed oracles. This is the product path — the same code the
//! density engines use.
//!
//! All tests skip (pass vacuously with a note) when artifacts are absent
//! so `cargo test` stays green before `make artifacts`.

use tricluster::core::pattern::tricluster;
use tricluster::core::context::TriContext;
use tricluster::datasets::synthetic::{k1, k2};
use tricluster::density::{DensityEngine, ExactEngine, MonteCarloEngine, XlaEngine};
use tricluster::runtime::{artifacts_available, default_artifact_dir, Runtime};
use tricluster::util::rng::Rng;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn runtime() -> Runtime {
    Runtime::load(&default_artifact_dir()).expect("load runtime")
}

#[test]
fn density_artifact_matches_bruteforce_on_random_tile() {
    require_artifacts!();
    let rt = runtime();
    let exe = rt.density("density_g64_k32").unwrap();
    let (t, k) = (exe.tile, exe.k);
    let mut rng = Rng::new(0xA11CE);
    let tensor: Vec<f32> =
        (0..t * t * t).map(|_| if rng.chance(0.2) { 1.0 } else { 0.0 }).collect();
    let mask = |rng: &mut Rng| -> Vec<f32> {
        (0..k * t).map(|_| if rng.chance(0.5) { 1.0 } else { 0.0 }).collect()
    };
    let (xm, ym, zm) = (mask(&mut rng), mask(&mut rng), mask(&mut rng));
    let (counts, volumes) = exe.run(&tensor, &xm, &ym, &zm).unwrap();

    for j in 0..k {
        let mut want = 0f64;
        for g in 0..t {
            if xm[j * t + g] == 0.0 {
                continue;
            }
            for m in 0..t {
                if ym[j * t + m] == 0.0 {
                    continue;
                }
                for b in 0..t {
                    want += (tensor[(g * t + m) * t + b] * zm[j * t + b]) as f64;
                }
            }
        }
        assert_eq!(counts[j] as f64, want, "cluster {j}");
        let vol: f64 = (xm[j * t..(j + 1) * t].iter().sum::<f32>()
            * ym[j * t..(j + 1) * t].iter().sum::<f32>()
            * zm[j * t..(j + 1) * t].iter().sum::<f32>()) as f64;
        assert_eq!(volumes[j] as f64, vol, "volume {j}");
    }
}

#[test]
fn xla_engine_equals_exact_engine_single_tile() {
    require_artifacts!();
    let rt = runtime();
    let ctx = k1(48);
    let clusters = tricluster::oac::mine_online(
        &ctx.inner,
        &tricluster::oac::Constraints::none(),
    );
    let exact = ExactEngine.densities(&ctx, &clusters);
    let mut xla = XlaEngine::new(&rt, 48, clusters.len()).unwrap();
    let got = xla.densities(&ctx, &clusters);
    assert_eq!(exact.len(), got.len());
    for (i, (a, b)) in exact.iter().zip(&got).enumerate() {
        assert!((a - b).abs() < 1e-6, "cluster {i}: exact={a} xla={b}");
    }
}

#[test]
fn xla_engine_equals_exact_engine_multi_tile() {
    require_artifacts!();
    let rt = runtime();
    // K2(50) spans 150 ids per modality → 3×3×3 grid of 64³ tiles
    let ctx = k2(50);
    let clusters = vec![
        tricluster((0..50).collect(), (0..50).collect(), (0..50).collect()),
        tricluster((50..100).collect(), (50..100).collect(), (50..100).collect()),
        tricluster((100..150).collect(), (100..150).collect(), (100..150).collect()),
        // a cross-block cluster straddling tile boundaries
        tricluster((30..80).collect(), (30..80).collect(), (30..80).collect()),
    ];
    let exact = ExactEngine.densities(&ctx, &clusters);
    let mut xla = XlaEngine::new(&rt, 150, clusters.len()).unwrap();
    let got = xla.densities(&ctx, &clusters);
    for (i, (a, b)) in exact.iter().zip(&got).enumerate() {
        assert!((a - b).abs() < 1e-6, "cluster {i}: exact={a} xla={b}");
    }
    assert_eq!(exact[0], 1.0);
    assert!(exact[3] < 0.5); // straddling cluster is sparse
}

#[test]
fn delta_artifact_matches_band_oracle() {
    require_artifacts!();
    let rt = runtime();
    let exe = rt.delta("delta_k64_l128").unwrap();
    let (k, l) = (exe.k, exe.l);
    let mut rng = Rng::new(0xDE17A);
    let values: Vec<f32> =
        (0..k * l).map(|_| (rng.f64() * 1000.0) as f32).collect();
    let present: Vec<f32> =
        (0..k * l).map(|_| if rng.chance(0.4) { 1.0 } else { 0.0 }).collect();
    let centers: Vec<f32> = (0..k).map(|_| (rng.f64() * 1000.0) as f32).collect();
    let delta = 75.0f32;
    let (masks, cards) = exe.run(delta, &values, &present, &centers).unwrap();
    for j in 0..k {
        let mut card = 0.0f32;
        for i in 0..l {
            let want = if present[j * l + i] == 1.0
                && (values[j * l + i] - centers[j]).abs() <= delta
            {
                1.0
            } else {
                0.0
            };
            assert_eq!(masks[j * l + i], want, "fiber {j} elem {i}");
            card += want;
        }
        assert_eq!(cards[j], card, "fiber {j} cardinality");
    }
}

#[test]
fn mc_artifact_estimates_density() {
    require_artifacts!();
    let rt = runtime();
    let ctx = k1(64); // exactly one tile
    let ids: Vec<u32> = (0..64).collect();
    let c = tricluster(ids.clone(), ids.clone(), ids);
    let mut mc = MonteCarloEngine::with_artifact(&rt, "mc_g64_s1024", 3).unwrap();
    let d = mc.densities(&ctx, &[c])[0];
    let truth = (64f64.powi(3) - 64.0) / 64f64.powi(3);
    assert!((d - truth).abs() < 0.05, "d={d} truth={truth}");
}

#[test]
fn mc_host_and_artifact_agree_statistically() {
    require_artifacts!();
    let rt = runtime();
    let mut ctx = TriContext::new();
    let mut rng = Rng::new(5);
    for _ in 0..20_000 {
        ctx.add(
            rng.below(64) as u32,
            rng.below(64) as u32,
            rng.below(64) as u32,
        );
    }
    let ids: Vec<u32> = (0..64).collect();
    let c = tricluster(ids.clone(), ids.clone(), ids);
    let exact = ExactEngine.densities(&ctx, &[c.clone()])[0];
    let host = MonteCarloEngine::host(4096, 11).densities(&ctx, &[c.clone()])[0];
    let art = MonteCarloEngine::with_artifact(&rt, "mc_g64_s1024", 11)
        .unwrap()
        .densities(&ctx, &[c])[0];
    assert!((host - exact).abs() < 0.03, "host={host} exact={exact}");
    assert!((art - exact).abs() < 0.05, "artifact={art} exact={exact}");
}

#[test]
fn manifest_perf_model_within_vmem_budget() {
    require_artifacts!();
    let rt = runtime();
    // DESIGN §Hardware-Adaptation: one kernel step must fit in 16 MiB VMEM
    let vmem = rt.manifest.density_vmem_bytes.expect("perf model present");
    assert!(vmem < 16.0 * (1u64 << 20) as f64, "vmem={vmem}");
    let macs = rt.manifest.density_mxu_macs.expect("mxu macs");
    assert!(macs >= 8.0 * 64.0 * 64.0 * 64.0);
}
