//! Dense tiling of a triadic context: the HBM→VMEM schedule of the
//! Layer-1 kernel, realised host-side.
//!
//! A context with modality sizes (|G|, |M|, |B|) is cut into T³-cell
//! cuboid tiles (T = the artifact's tile edge). Each tile is a row-major
//! f32 0/1 tensor; cluster masks are sliced per tile the same way. The
//! kernel's counts then sum over tiles.

use crate::core::context::TriContext;

/// Dense f32 tiles of a context for a fixed tile edge `t`.
pub struct DenseTiles {
    /// Tile edge (elements per axis).
    pub t: usize,
    /// number of tiles along (G, M, B)
    pub grid: (usize, usize, usize),
    /// tiles indexed [gi][mi][bi], each t³ row-major, laid out flat
    tiles: Vec<Vec<f32>>,
}

impl DenseTiles {
    /// Build tiles from a context. Memory: `grid_volume × t³ × 4` bytes —
    /// callers must ensure the modality sizes are tile-friendly (the
    /// engines fall back to exact counting otherwise).
    pub fn build(ctx: &TriContext, t: usize) -> Self {
        // modality extents: interner sizes are authoritative when names
        // were interned; raw-id contexts (tests, generators) may exceed
        // them, so take the max over the actual triples too
        let (mut g, mut m, mut b) = ctx.sizes();
        for tr in ctx.triples() {
            g = g.max(tr.get(0) as usize + 1);
            m = m.max(tr.get(1) as usize + 1);
            b = b.max(tr.get(2) as usize + 1);
        }
        let grid = (g.div_ceil(t).max(1), m.div_ceil(t).max(1), b.div_ceil(t).max(1));
        let n_tiles = grid.0 * grid.1 * grid.2;
        let mut tiles = vec![vec![0f32; t * t * t]; n_tiles];
        for tr in ctx.triples() {
            let (g, m, b) =
                (tr.get(0) as usize, tr.get(1) as usize, tr.get(2) as usize);
            let (gi, mi, bi) = (g / t, m / t, b / t);
            let idx = (gi * grid.1 + mi) * grid.2 + bi;
            let (go, mo, bo) = (g % t, m % t, b % t);
            tiles[idx][(go * t + mo) * t + bo] = 1.0;
        }
        Self { t, grid, tiles }
    }

    /// The dense tile at grid position `(gi, mi, bi)`, row-major.
    pub fn tile(&self, gi: usize, mi: usize, bi: usize) -> &[f32] {
        &self.tiles[(gi * self.grid.1 + mi) * self.grid.2 + bi]
    }

    /// Total number of tiles.
    pub fn n_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Total bytes held by the dense tiles.
    pub fn bytes(&self) -> usize {
        self.tiles.len() * self.t * self.t * self.t * 4
    }
}

/// Per-(g, m) bitset rows over the third modality: row `(g, m)` holds
/// one bit per `b`, packed into `u64` words — the exact engine's
/// vectorised membership table. Where [`DenseTiles`] is the f32 HBM→VMEM
/// schedule of the compiled kernel, `BitRows` is its host-side integer
/// twin: a cluster's density numerator becomes
/// `popcount(row[g][m] & modus_mask)` summed over the (g, m) grid — 64
/// membership probes per word-AND instead of one hash probe per cell.
pub struct BitRows {
    /// `u64` words per row (= ⌈|B| / 64⌉).
    words: usize,
    /// Row-major `(g · m_extent + m) · words` table.
    rows: Vec<u64>,
    /// Modality extents the table was built for.
    extent: (usize, usize, usize),
}

impl BitRows {
    /// Build the row table for a context, or `None` when it would exceed
    /// `max_bytes` (the caller falls back to scalar counting). Extents
    /// are the interner sizes widened by the actual triples, exactly
    /// like [`DenseTiles::build`].
    pub fn build(ctx: &TriContext, max_bytes: usize) -> Option<Self> {
        let (mut g, mut m, mut b) = ctx.sizes();
        for tr in ctx.triples() {
            g = g.max(tr.get(0) as usize + 1);
            m = m.max(tr.get(1) as usize + 1);
            b = b.max(tr.get(2) as usize + 1);
        }
        let words = b.div_ceil(64).max(1);
        let total = g.checked_mul(m)?.checked_mul(words)?;
        if total == 0 || total.checked_mul(8)? > max_bytes {
            return None;
        }
        let mut rows = vec![0u64; total];
        for tr in ctx.triples() {
            let (gg, mm, bb) =
                (tr.get(0) as usize, tr.get(1) as usize, tr.get(2) as usize);
            rows[(gg * m + mm) * words + bb / 64] |= 1u64 << (bb % 64);
        }
        Some(Self { words, rows, extent: (g, m, b) })
    }

    /// Words per row.
    pub fn words(&self) -> usize {
        self.words
    }

    /// Bytes held by the row table.
    pub fn bytes(&self) -> usize {
        self.rows.len() * 8
    }

    /// The bit row of `(g, m)`, or `None` when either id lies outside
    /// the built extents (no triple there — zero hits by definition).
    #[inline]
    pub fn row(&self, g: u32, m: u32) -> Option<&[u64]> {
        let (ge, me, _) = self.extent;
        let (g, m) = (g as usize, m as usize);
        if g >= ge || m >= me {
            return None;
        }
        let at = (g * me + m) * self.words;
        Some(&self.rows[at..at + self.words])
    }
}

/// Slice a sorted id set into a `u64` bit mask over `[0, words·64)`
/// (ids past the word window are dropped — they cannot hit any row).
pub fn bit_mask(ids: &[u32], words: usize, out: &mut Vec<u64>) {
    out.clear();
    out.resize(words, 0);
    for &id in ids {
        let w = id as usize / 64;
        if w < words {
            out[w] |= 1u64 << (id % 64);
        }
    }
}

/// Popcount of `mask` restricted to the id range `[start, start + len)`
/// — the run-container count loop of
/// [`crate::density::compressed::CompressedRows`]. Ids past the mask
/// window contribute nothing (same drop rule as [`bit_mask`]).
pub fn bit_mask_count_range(mask: &[u64], start: u32, len: u32) -> u64 {
    let s = start as usize;
    let e = s + len as usize; // usize: cannot overflow for u32 inputs
    let first = s / 64;
    let last = e.div_ceil(64).min(mask.len());
    let mut hit = 0u64;
    for w in first..last {
        let mut word = mask[w];
        let lo = w * 64;
        let hi = lo + 64;
        if lo < s {
            word &= !0u64 << (s - lo); // s - lo < 64: only the first word
        }
        if hi > e {
            word &= !0u64 >> (hi - e); // hi - e < 64: only the last word
        }
        hit += word.count_ones() as u64;
    }
    hit
}

/// Slice a global id set into a per-tile 0/1 mask of width `t` for tile
/// index `ti` (ids in `[ti·t, (ti+1)·t)`).
pub fn tile_mask(ids: &[u32], ti: usize, t: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), t);
    out.fill(0.0);
    let lo = (ti * t) as u32;
    let hi = lo + t as u32;
    // ids are sorted (Cluster invariant): binary search the window
    let start = ids.partition_point(|&x| x < lo);
    for &id in &ids[start..] {
        if id >= hi {
            break;
        }
        out[(id - lo) as usize] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synthetic::k2;

    #[test]
    fn tiles_cover_context() {
        let ctx = k2(3); // 9×9×9
        let tiles = DenseTiles::build(&ctx, 4);
        assert_eq!(tiles.grid, (3, 3, 3));
        let total: f32 = (0..3)
            .flat_map(|gi| (0..3).flat_map(move |mi| (0..3).map(move |bi| (gi, mi, bi))))
            .map(|(gi, mi, bi)| tiles.tile(gi, mi, bi).iter().sum::<f32>())
            .sum();
        assert_eq!(total as usize, ctx.len());
    }

    #[test]
    fn tile_cell_addressing() {
        let mut ctx = TriContext::new();
        ctx.add(5, 6, 7);
        let tiles = DenseTiles::build(&ctx, 4);
        // (5,6,7) lives in tile (1,1,1) at offsets (1,2,3)
        let t = tiles.tile(1, 1, 1);
        assert_eq!(t[(1 * 4 + 2) * 4 + 3], 1.0);
        assert_eq!(t.iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn bit_rows_membership() {
        let mut ctx = TriContext::new();
        ctx.add(5, 6, 7);
        ctx.add(5, 6, 70); // second word of the same row
        ctx.add(0, 0, 0);
        let rows = BitRows::build(&ctx, usize::MAX).expect("fits");
        assert_eq!(rows.words(), 2); // b extent 71 → 2 words
        let r = rows.row(5, 6).expect("in extent");
        assert_eq!(r[0], 1u64 << 7);
        assert_eq!(r[1], 1u64 << (70 - 64));
        assert_eq!(rows.row(0, 0).unwrap()[0], 1);
        // out-of-extent ids resolve to no row, not a panic
        assert!(rows.row(99, 0).is_none());
        assert!(rows.row(0, 99).is_none());
    }

    #[test]
    fn bit_rows_respect_byte_cap() {
        let mut ctx = TriContext::new();
        ctx.add(1000, 1000, 0);
        // 1001×1001 rows × 1 word × 8 B ≈ 8 MB > 1 KB cap
        assert!(BitRows::build(&ctx, 1024).is_none());
        assert!(BitRows::build(&ctx, usize::MAX).is_some());
    }

    #[test]
    fn bit_mask_windows() {
        let ids = vec![0u32, 3, 64, 70, 200];
        let mut m = Vec::new();
        bit_mask(&ids, 2, &mut m);
        assert_eq!(m.len(), 2);
        assert_eq!(m[0], (1u64 << 0) | (1u64 << 3));
        assert_eq!(m[1], (1u64 << 0) | (1u64 << 6));
        // id 200 is outside the window: dropped
        bit_mask(&[1], 1, &mut m);
        assert_eq!(m, vec![2u64]);
    }

    #[test]
    fn count_range_matches_per_bit_scan() {
        let ids = vec![0u32, 3, 63, 64, 70, 127, 128, 190];
        let mut mask = Vec::new();
        bit_mask(&ids, 3, &mut mask);
        let oracle = |start: u32, len: u32| -> u64 {
            (start..start.saturating_add(len))
                .filter(|&b| (b as usize) < 192 && ids.contains(&b))
                .count() as u64
        };
        for start in [0u32, 1, 3, 62, 64, 100, 128, 191, 192, 500] {
            for len in [0u32, 1, 2, 63, 64, 65, 128, 1000] {
                assert_eq!(
                    bit_mask_count_range(&mask, start, len),
                    oracle(start, len),
                    "start={start} len={len}"
                );
            }
        }
        // u32::MAX range must not overflow
        assert_eq!(bit_mask_count_range(&mask, 0, u32::MAX), 8);
        assert_eq!(bit_mask_count_range(&mask, u32::MAX, u32::MAX), 0);
    }

    #[test]
    fn tile_mask_windows() {
        let ids = vec![0u32, 3, 4, 7, 12];
        let mut m = vec![0f32; 4];
        tile_mask(&ids, 0, 4, &mut m);
        assert_eq!(m, vec![1.0, 0.0, 0.0, 1.0]);
        tile_mask(&ids, 1, 4, &mut m);
        assert_eq!(m, vec![1.0, 0.0, 0.0, 1.0]);
        tile_mask(&ids, 3, 4, &mut m);
        assert_eq!(m, vec![1.0, 0.0, 0.0, 0.0]);
    }
}
