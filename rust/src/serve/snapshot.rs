//! Snapshot / restore of the serving layer, for restart recovery.
//!
//! Two arms share this module:
//!
//! * **Segment** (default): [`save_segments`] compacts the service and
//!   appends one full binary segment — tuple history, cumulus page
//!   frames, and the cluster index — to a [`crate::persist::SegmentLog`]
//!   directory. [`load_segments`] replays the log and rebuilds each
//!   shard by BULK PAGE ADOPTION ([`super::Shard::restore`]): cumuli
//!   become arena pages directly and tuples are resolved by probe, so
//!   restore skips the per-tuple mining work entirely — an order of
//!   magnitude faster than the JSON path on large contexts (measured by
//!   `benches/persist.rs`). The stored cluster index is cross-checked
//!   against the restored compaction.
//! * **JSON** (debug fallback, `--snapshot-format json`): the original
//!   human-inspectable document via [`crate::util::json`]. It stores
//!   each shard's ingest history plus its epoch — NOT the derived
//!   cumuli — and restore replays the history through a fresh service,
//!   reproducing the exact state by the one-pass property of Alg. 1.
//!
//! The arms are interconvertible: restoring one and snapshotting the
//! other yields a bit-identical cluster index (round-trip tested in
//! `rust/tests/persist_roundtrip.rs`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::core::tuple::NTuple;
use crate::oac::post::Constraints;
use crate::persist::{
    SegmentConfig, SegmentKind, SegmentLog, SegmentPayload, ShardRecord,
};
use crate::util::json::Json;

use super::{ServeConfig, Shard, TriclusterService};

const VERSION: f64 = 1.0;

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn tuple_json(t: &NTuple) -> Json {
    Json::Arr(t.as_slice().iter().map(|&e| num(e as f64)).collect())
}

/// Serialise a (flushed) service to a JSON document.
pub fn to_json(svc: &TriclusterService) -> Json {
    let cfg = svc.cfg();
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("version".into(), num(VERSION));
    obj.insert("arity".into(), num(cfg.arity as f64));
    obj.insert("shards".into(), num(cfg.shards as f64));
    obj.insert("max_pending".into(), num(cfg.max_pending as f64));
    obj.insert("workers".into(), num(cfg.workers as f64));
    let mut cons = std::collections::BTreeMap::new();
    cons.insert("min_density".into(), num(cfg.constraints.min_density));
    cons.insert("min_support".into(), num(cfg.constraints.min_support as f64));
    obj.insert("constraints".into(), Json::Obj(cons));
    let shard_state: Vec<Json> = svc
        .router
        .shards()
        .iter()
        .map(|shard| {
            let mut s = std::collections::BTreeMap::new();
            s.insert("epoch".into(), num(shard.epoch() as f64));
            s.insert(
                "tuples".into(),
                Json::Arr(shard.ingested_tuples().iter().map(tuple_json).collect()),
            );
            Json::Obj(s)
        })
        .collect();
    obj.insert("shard_state".into(), Json::Arr(shard_state));
    Json::Obj(obj)
}

/// Rebuild a service from a snapshot document: replay each shard's
/// history directly into its shard (bypassing the router hash — the
/// snapshot already fixed the placement), restore epochs, and compact.
pub fn from_json(doc: &Json) -> Result<TriclusterService> {
    let version = doc.get("version").and_then(Json::as_f64).context("version")?;
    anyhow::ensure!(version == VERSION, "unsupported snapshot version {version}");
    let arity = doc.get("arity").and_then(Json::as_usize).context("arity")?;
    anyhow::ensure!(
        (2..=crate::core::tuple::MAX_ARITY).contains(&arity),
        "snapshot arity {arity} out of range"
    );
    let shards = doc.get("shards").and_then(Json::as_usize).context("shards")?;
    let max_pending =
        doc.get("max_pending").and_then(Json::as_usize).context("max_pending")?;
    let workers = doc.get("workers").and_then(Json::as_usize).context("workers")?;
    let cons = doc.get("constraints").context("constraints")?;
    let constraints = Constraints {
        min_density: cons.get("min_density").and_then(Json::as_f64).context("min_density")?,
        min_support: cons.get("min_support").and_then(Json::as_usize).context("min_support")?,
    };
    let cfg = ServeConfig {
        max_pending,
        workers,
        constraints,
        ..ServeConfig::new(arity, shards)
    };
    let mut svc = TriclusterService::new(cfg);

    let shard_state =
        doc.get("shard_state").and_then(Json::as_arr).context("shard_state")?;
    anyhow::ensure!(
        shard_state.len() == shards,
        "snapshot has {} shard entries for {} shards",
        shard_state.len(),
        shards
    );
    for (i, state) in shard_state.iter().enumerate() {
        let epoch = state.get("epoch").and_then(Json::as_f64).context("epoch")? as u64;
        let tuples_json =
            state.get("tuples").and_then(Json::as_arr).context("tuples")?;
        let mut tuples = Vec::with_capacity(tuples_json.len());
        for t in tuples_json {
            let elems = t.as_arr().context("tuple must be an array")?;
            anyhow::ensure!(
                elems.len() == arity,
                "tuple arity {} does not match snapshot arity {arity}",
                elems.len()
            );
            let ids: Vec<u32> = elems
                .iter()
                .map(|e| {
                    e.as_f64()
                        .filter(|f| f.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(f))
                        .map(|f| f as u32)
                        .context("tuple element must be a u32")
                })
                .collect::<Result<_>>()?;
            tuples.push(NTuple::new(&ids));
        }
        let shard = &mut svc.router.shards_mut()[i];
        shard.ingest(&tuples);
        shard.set_epoch(epoch);
    }
    svc.compact();
    Ok(svc)
}

/// Flush + write a service snapshot to `path`.
pub fn save(svc: &mut TriclusterService, path: &Path) -> Result<()> {
    svc.flush(); // queued tuples must be inside shards to be captured
    let doc = to_json(svc);
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("write snapshot {}", path.display()))?;
    Ok(())
}

/// Read a snapshot written by [`save`] and rebuild the service.
pub fn load(path: &Path) -> Result<TriclusterService> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read snapshot {}", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parse snapshot {}: {e}", path.display()))?;
    from_json(&doc).with_context(|| format!("restore {}", path.display()))
}

/// Build the full-segment payload for a COMPACTED service: per-shard
/// tuple history + sealed cumuli, the published cluster index, and the
/// config header. `seq` is stamped by [`SegmentLog::append`].
pub fn full_payload(svc: &mut TriclusterService) -> SegmentPayload {
    let cfg = svc.cfg().clone();
    let shards = svc
        .router
        .shards_mut()
        .iter_mut()
        .map(|shard| ShardRecord {
            epoch: shard.epoch(),
            tuples: shard.ingested_tuples(),
            cumuli: shard.export_cumuli(),
        })
        .collect();
    let clusters = svc.clusters().to_vec();
    SegmentPayload {
        seq: 0,
        epoch: svc.snapshot().epoch(),
        kind: SegmentKind::Full,
        arity: cfg.arity,
        config: SegmentConfig {
            max_pending: cfg.max_pending,
            workers: cfg.workers,
            min_density: cfg.constraints.min_density,
            min_support: cfg.constraints.min_support,
        },
        shards,
        clusters,
        interners: Vec::new(),
    }
}

/// Compact + append one full binary segment to the log at `dir`
/// (created if absent; an existing log gains a new serving point —
/// replay keeps the newest full segment).
pub fn save_segments(svc: &mut TriclusterService, dir: &Path) -> Result<()> {
    svc.compact(); // queued tuples AND unpulled deltas must be captured
    let mut log = SegmentLog::open(dir)
        .with_context(|| format!("open segment log {}", dir.display()))?;
    let mut payload = full_payload(svc);
    log.append(&mut payload)
        .with_context(|| format!("append segment to {}", dir.display()))?;
    Ok(())
}

/// Replay the segment log at `dir` and rebuild the service by bulk page
/// adoption — no per-tuple re-ingest. The restored compaction is
/// cross-checked against the cluster index stored in the log.
pub fn load_segments(dir: &Path) -> Result<TriclusterService> {
    let image = SegmentLog::replay(dir)
        .with_context(|| format!("replay segment log {}", dir.display()))?;
    let cfg = ServeConfig {
        max_pending: image.config.max_pending,
        workers: image.config.workers,
        constraints: Constraints {
            min_density: image.config.min_density,
            min_support: image.config.min_support,
        },
        segment_dir: Some(dir.to_path_buf()),
        ..ServeConfig::new(image.arity, image.shards.len())
    };
    let mut svc = TriclusterService::new(cfg);
    for (i, state) in image.shards.into_iter().enumerate() {
        svc.router.shards_mut()[i] =
            Shard::restore(i, image.arity, state.epoch, &state.tuples, state.cumuli)
                .map_err(|e| anyhow::anyhow!("restore {}: {e}", dir.display()))?;
    }
    svc.compact();
    if !image.clusters.is_empty() {
        let restored = svc.clusters().len();
        anyhow::ensure!(
            restored == image.clusters.len(),
            "restore {}: rebuilt index has {restored} clusters, the log \
             recorded {}",
            dir.display(),
            image.clusters.len()
        );
    }
    Ok(svc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{movielens, MovielensParams};

    fn sorted_components(svc: &mut TriclusterService) -> Vec<(Vec<Vec<u32>>, usize)> {
        let mut out: Vec<(Vec<Vec<u32>>, usize)> = svc
            .clusters()
            .iter()
            .map(|c| (c.components.clone(), c.support))
            .collect();
        out.sort();
        out
    }

    #[test]
    fn roundtrip_preserves_index_and_epochs() {
        let ctx = movielens(&MovielensParams::with_tuples(1_500));
        let mut svc = TriclusterService::new(super::super::ServeConfig::new(4, 3));
        for chunk in ctx.tuples().chunks(256) {
            svc.ingest(chunk);
        }
        svc.compact();
        let before = sorted_components(&mut svc);
        let epochs_before: Vec<u64> =
            svc.router.shards().iter().map(|s| s.epoch()).collect();

        let doc = to_json(&svc);
        let mut restored = from_json(&doc).unwrap();
        let after = sorted_components(&mut restored);
        assert_eq!(before, after);
        let epochs_after: Vec<u64> =
            restored.router.shards().iter().map(|s| s.epoch()).collect();
        assert_eq!(epochs_before, epochs_after);
    }

    #[test]
    fn save_flushes_pending_and_load_restores(){
        let dir = std::env::temp_dir().join("tricluster_serve_snapshot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let ctx = movielens(&MovielensParams::with_tuples(600));
        let mut svc = TriclusterService::new(super::super::ServeConfig::new(4, 2));
        svc.ingest(ctx.tuples()); // stays queued below the watermark
        save(&mut svc, &path).unwrap();
        svc.compact();
        let before = sorted_components(&mut svc);
        let mut restored = load(&path).unwrap();
        assert_eq!(before, sorted_components(&mut restored));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_documents() {
        assert!(from_json(&Json::parse("{}").unwrap()).is_err());
        let wrong_version = r#"{"version": 99, "arity": 3}"#;
        assert!(from_json(&Json::parse(wrong_version).unwrap()).is_err());
        // a tuple narrower than the declared arity must be rejected, not
        // silently mined into wrong cumulus keys
        let mismatched = r#"{"version": 1, "arity": 3, "shards": 1,
            "max_pending": 10, "workers": 1,
            "constraints": {"min_density": 0, "min_support": 0},
            "shard_state": [{"epoch": 1, "tuples": [[1, 2]]}]}"#;
        assert!(from_json(&Json::parse(mismatched).unwrap()).is_err());
        // non-integer entity ids too
        let fractional = r#"{"version": 1, "arity": 3, "shards": 1,
            "max_pending": 10, "workers": 1,
            "constraints": {"min_density": 0, "min_support": 0},
            "shard_state": [{"epoch": 1, "tuples": [[1, 2, 3.5]]}]}"#;
        assert!(from_json(&Json::parse(fractional).unwrap()).is_err());
    }
}
