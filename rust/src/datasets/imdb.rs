//! IMDB-like tricontext generator (paper §5.1 / Table 2).
//!
//! The paper's dataset: Top-250 movies × tags × genres, 3,818 triples,
//! density 0.00087. The real tag assignments are not redistributable, so
//! this generator produces a deterministic synthetic context matched on
//! |G| = 250, triple count ≈ 3.8k, and the tag/genre Zipf structure:
//! each movie draws 1–4 genres and a handful of keyword tags; a triple
//! (movie, tag, genre) is emitted for every tag×genre combination of the
//! movie — exactly the "movie has genre and is assigned tag" relation.

use crate::core::context::TriContext;
use crate::util::rng::{Rng, Zipf};

/// A few dozen real Top-250 titles so printed patterns read like the
/// paper's §5.2 output; the remaining movies get synthetic titles.
const TITLES: &[&str] = &[
    "The Shawshank Redemption (1994)",
    "The Godfather (1972)",
    "The Dark Knight (2008)",
    "12 Angry Men (1957)",
    "Schindler's List (1993)",
    "Pulp Fiction (1994)",
    "The Lord of the Rings: The Return of the King (2003)",
    "One Flew Over the Cuckoo's Nest (1975)",
    "Star Wars: Episode V - The Empire Strikes Back (1980)",
    "Forrest Gump (1994)",
    "Inception (2010)",
    "The Matrix (1999)",
    "Goodfellas (1990)",
    "Seven Samurai (1954)",
    "Se7en (1995)",
    "City of God (2002)",
    "Life Is Beautiful (1997)",
    "The Silence of the Lambs (1991)",
    "Spirited Away (2001)",
    "Saving Private Ryan (1998)",
    "Apocalypse Now (1979)",
    "Full Metal Jacket (1987)",
    "Platoon (1986)",
    "Toy Story (1995)",
    "Toy Story 2 (1999)",
    "WALL-E (2008)",
    "Into the Wild (2007)",
    "The Gold Rush (1925)",
    "Casablanca (1942)",
    "Psycho (1960)",
];

const GENRES: &[&str] = &[
    "Drama", "Action", "Adventure", "Comedy", "Crime", "Sci-Fi", "Thriller",
    "Animation", "Family", "Fantasy", "Mystery", "Romance", "War", "Western",
    "Horror", "Biography", "History", "Music", "Film-Noir", "Sport",
];

const TAG_STEMS: &[&str] = &[
    "Nurse", "Patient", "Asylum", "Rebel", "Basketball", "Princess", "Toy",
    "Friend", "Rescue", "Love", "Alaska", "Vietnam", "Prison", "Escape",
    "Mafia", "Heist", "Robot", "Space", "War", "Journey", "Betrayal",
    "Revenge", "Dream", "Memory", "Island", "Train", "Boxing", "Chess",
    "Desert", "Ocean", "Winter", "Gold", "Detective", "Murder", "Trial",
    "Jury", "Samurai", "Sheriff", "Bounty", "Alien",
];

/// Generation parameters (defaults match Table 2).
#[derive(Debug, Clone)]
pub struct ImdbParams {
    /// Distinct movies.
    pub movies: usize,
    /// Distinct keyword tags to draw from.
    pub tag_universe: usize,
    /// Triples to aim for.
    pub target_triples: usize,
    /// Stream seed.
    pub seed: u64,
}

impl Default for ImdbParams {
    fn default() -> Self {
        Self { movies: 250, tag_universe: 900, target_triples: 3818, seed: 0x124DB }
    }
}

/// Generate the IMDB-like context.
pub fn imdb(params: &ImdbParams) -> TriContext {
    let mut ctx = TriContext::new();
    let mut rng = Rng::new(params.seed);

    // intern movies
    for i in 0..params.movies {
        let title = if i < TITLES.len() {
            TITLES[i].to_string()
        } else {
            format!("Movie #{:03} ({})", i + 1, 1920 + (i * 7) % 100)
        };
        ctx.inner.interners[0].intern(&title);
    }
    // intern tags (stem + qualifier for the long tail)
    for i in 0..params.tag_universe {
        let name = if i < TAG_STEMS.len() {
            TAG_STEMS[i].to_string()
        } else {
            format!("{}-{}", TAG_STEMS[i % TAG_STEMS.len()], i / TAG_STEMS.len())
        };
        ctx.inner.interners[1].intern(&name);
    }
    for g in GENRES {
        ctx.inner.interners[2].intern(g);
    }

    let tag_zipf = Zipf::new(params.tag_universe as u64, 1.05);
    let genre_zipf = Zipf::new(GENRES.len() as u64, 0.9);

    // movies in a round-robin until the target triple count is reached,
    // so every movie appears and the count is exact.
    let mut movie = 0u32;
    while ctx.len() < params.target_triples {
        // 1-4 genres, 2-8 tags per movie visit
        let n_genres = 1 + rng.usize_below(4).min(3);
        let n_tags = 2 + rng.usize_below(7);
        let genres: Vec<u32> =
            (0..n_genres).map(|_| genre_zipf.sample(&mut rng) as u32).collect();
        let tags: Vec<u32> =
            (0..n_tags).map(|_| tag_zipf.sample(&mut rng) as u32).collect();
        'outer: for &t in &tags {
            for &g in &genres {
                ctx.add(movie, t, g);
                if ctx.len() >= params.target_triples {
                    break 'outer;
                }
            }
        }
        movie = (movie + 1) % params.movies as u32;
    }
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_table2_shape() {
        let ctx = imdb(&ImdbParams::default());
        assert_eq!(ctx.len(), 3818);
        let (g, m, b) = ctx.sizes();
        assert_eq!(g, 250);
        assert!(m <= 900);
        assert_eq!(b, 20);
        // Table 2 density 0.00087 — ours within the same order of magnitude
        let density = ctx.inner.density();
        assert!(density > 2e-4 && density < 3e-3, "density={density}");
    }

    #[test]
    fn deterministic() {
        let a = imdb(&ImdbParams::default());
        let b = imdb(&ImdbParams::default());
        assert_eq!(a.triples(), b.triples());
    }

    #[test]
    fn small_instance() {
        let ctx = imdb(&ImdbParams {
            movies: 20,
            tag_universe: 50,
            target_triples: 200,
            seed: 7,
        });
        assert_eq!(ctx.len(), 200);
        assert_eq!(ctx.sizes().0, 20);
    }
}
