//! One shard of the serving layer: an incremental [`OnlineMiner`] over a
//! hash-partition of the stream, exposing epoch-tagged deltas.
//!
//! The paper's Alg. 1 processes OAC tuples independently, so a shard can
//! mine its partition with no coordination; cross-shard correctness is
//! restored by the compactor ([`crate::serve::merge`]), which unions
//! per-shard partial cumuli by subrelation key. A shard therefore plays
//! the role of one stage-1 map task of the §4.1 MapReduce — but long
//! lived and incremental: every ingested batch bumps its epoch, and
//! `take_delta` exports exactly the state added since the previous pull,
//! already combined map-side (one `(key, values)` group per touched
//! subrelation, mirroring Hadoop's combiner / Spark's `reduceByKey`).

use crate::core::pattern::Cluster;
use crate::core::tuple::{NTuple, SubRelation};
use crate::oac::post::Constraints;
use crate::oac::OnlineMiner;
use crate::util::hash::FxHashMap;

/// Everything a shard learned between two `take_delta` calls.
#[derive(Debug, Clone)]
pub struct ShardDelta {
    /// Which shard produced this delta.
    pub shard: usize,
    /// The shard epoch this delta brings the consumer up to.
    pub epoch: u64,
    /// New generating tuples, in ingest order.
    pub tuples: Vec<NTuple>,
    /// Map-side-combined cumulus appends: for every subrelation key
    /// touched since the last pull, the entity values appended to its
    /// cumulus (with multiplicity — the global arena dedups on
    /// materialisation, exactly like [`crate::oac::primes::SetArena`]).
    /// Sorted by key so delta application is deterministic.
    pub appends: Vec<(SubRelation, Vec<u32>)>,
}

impl ShardDelta {
    /// True when the delta carries no new tuples.
    pub fn is_empty(&self) -> bool {
        self.tuples.is_empty()
    }
}

/// A shard: id + incremental miner + export watermark.
#[derive(Debug)]
pub struct Shard {
    id: usize,
    miner: OnlineMiner,
    epoch: u64,
    /// How many of `miner.generated()` have been exported in deltas.
    exported: usize,
}

impl Shard {
    /// Fresh shard `id` over `arity` modalities.
    pub fn new(id: usize, arity: usize) -> Self {
        Self { id, miner: OnlineMiner::new(arity), epoch: 0, exported: 0 }
    }

    /// Rebuild a shard from a persisted image by bulk adoption: the
    /// cumuli become arena pages directly and each historical tuple is
    /// resolved against them by probe — no per-tuple re-ingest (this is
    /// what makes binary restore an order of magnitude faster than
    /// replaying the tuple log through [`Self::ingest`]). `cumuli`
    /// values must be strictly sorted (the persist fold seals them).
    /// Fails when a tuple references a key absent from the image — an
    /// inconsistent snapshot, surfaced instead of mis-adopted.
    pub fn restore(
        id: usize,
        arity: usize,
        epoch: u64,
        tuples: &[NTuple],
        cumuli: Vec<(SubRelation, Vec<u32>)>,
    ) -> Result<Self, String> {
        let miner = OnlineMiner::from_image(arity, tuples, cumuli)
            .map_err(|e| format!("shard {id}: {e}"))?;
        Ok(Self { id, miner, epoch, exported: 0 })
    }

    /// Drain this shard's cumuli as `⟨subrelation, sorted values⟩` —
    /// the full-segment payload ([`Self::restore`]'s inverse). Seals the
    /// arena first, so the export is canonical.
    pub fn export_cumuli(&mut self) -> Vec<(SubRelation, Vec<u32>)> {
        self.miner.cumuli()
    }

    /// Cap this shard's resident arena at `pages` pages, spilling cold
    /// page chains to `spill_dir` (temp dir when `None`); `0` lifts the
    /// cap. See [`crate::oac::primes::SetArena::set_resident_budget`].
    pub fn set_resident_budget(&mut self, pages: usize, spill_dir: Option<std::path::PathBuf>) {
        self.miner.set_resident_budget(pages, spill_dir);
    }

    /// This shard's id (= its routing index).
    pub fn id(&self) -> usize {
        self.id
    }

    /// Monotone ingest epoch (number of non-empty batches absorbed).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Tuples ingested so far (generated clusters, one per tuple).
    pub fn len(&self) -> usize {
        self.miner.len()
    }

    /// True before the first ingested tuple.
    pub fn is_empty(&self) -> bool {
        self.miner.is_empty()
    }

    /// The underlying incremental miner.
    pub fn miner(&self) -> &OnlineMiner {
        &self.miner
    }

    /// Alg. 1 `Add` on this partition; empty batches do not advance the
    /// epoch.
    pub fn ingest(&mut self, batch: &[NTuple]) {
        if batch.is_empty() {
            return;
        }
        self.miner.add_batch(batch);
        self.epoch += 1;
    }

    /// [`Self::ingest`] via the merge-based parallel ingest kernel
    /// ([`crate::oac::primes::PrimeStore::par_add_batch`]) — the router's
    /// drain waves hand each shard its share of the worker pool, so a
    /// deployment with few shards and many cores still saturates. The
    /// resulting shard state is bit-identical to sequential `ingest`.
    pub fn ingest_par(&mut self, batch: &[NTuple], workers: usize) {
        if batch.is_empty() {
            return;
        }
        self.miner.par_add_batch(batch, workers);
        self.epoch += 1;
    }

    /// Export the epoch-tagged delta since the last pull and advance the
    /// watermark. Appends are grouped per subrelation key (map-side
    /// combine) so the compactor probes its global key dictionary once
    /// per distinct key instead of N times per tuple.
    pub fn take_delta(&mut self) -> ShardDelta {
        let gens = &self.miner.generated()[self.exported..];
        let mut tuples = Vec::with_capacity(gens.len());
        let mut combined: FxHashMap<SubRelation, Vec<u32>> = FxHashMap::default();
        for g in gens {
            let t = g.tuple;
            tuples.push(t);
            for k in 0..t.arity() {
                combined.entry(t.subrelation(k)).or_default().push(t.get(k));
            }
        }
        self.exported = self.miner.generated().len();
        let mut appends: Vec<(SubRelation, Vec<u32>)> = combined.into_iter().collect();
        appends.sort_unstable();
        ShardDelta { shard: self.id, epoch: self.epoch, tuples, appends }
    }

    /// Shard-local view: clusters over THIS partition only (partial —
    /// cumuli here miss contributions routed to sibling shards; the
    /// compactor's output is the globally-correct index). Runs the
    /// miner's dedup, which auto-parallelises past
    /// [`crate::oac::online::dedup_degree`]'s threshold.
    pub fn local_clusters(&mut self, constraints: &Constraints) -> Vec<Cluster> {
        self.miner.dedup_and_filter(constraints)
    }

    /// The shard's full ingest history, in order (for snapshots: replaying
    /// it through a fresh shard reproduces the exact miner state — the
    /// one-pass property of Alg. 1).
    pub fn ingested_tuples(&self) -> Vec<NTuple> {
        self.miner.generated().iter().map(|g| g.tuple).collect()
    }

    /// Restore bookkeeping after a snapshot replay (the replay arrives as
    /// one batch, but the snapshot remembers the original epoch).
    pub(crate) fn set_epoch(&mut self, epoch: u64) {
        self.epoch = epoch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triples(ts: &[(u32, u32, u32)]) -> Vec<NTuple> {
        ts.iter().map(|&(g, m, b)| NTuple::triple(g, m, b)).collect()
    }

    #[test]
    fn epochs_advance_per_nonempty_batch() {
        let mut s = Shard::new(0, 3);
        assert_eq!(s.epoch(), 0);
        s.ingest(&triples(&[(0, 0, 0), (1, 0, 0)]));
        s.ingest(&[]);
        s.ingest(&triples(&[(0, 1, 1)]));
        assert_eq!(s.epoch(), 2);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn delta_is_incremental_and_combined() {
        let mut s = Shard::new(0, 3);
        s.ingest(&triples(&[(0, 0, 0), (1, 0, 0)]));
        let d1 = s.take_delta();
        assert_eq!(d1.epoch, 1);
        assert_eq!(d1.tuples, triples(&[(0, 0, 0), (1, 0, 0)]));
        // both tuples share the dropped-0 subrelation (0,0): one combined
        // group with both extents
        let sub = NTuple::triple(0, 0, 0).subrelation(0);
        let group = d1.appends.iter().find(|(k, _)| *k == sub).expect("shared key");
        assert_eq!(group.1, vec![0, 1]);
        // second pull only sees what came after the first
        s.ingest(&triples(&[(2, 2, 2)]));
        let d2 = s.take_delta();
        assert_eq!(d2.tuples, triples(&[(2, 2, 2)]));
        assert_eq!(d2.epoch, 2);
        // nothing new → empty delta
        assert!(s.take_delta().is_empty());
    }

    #[test]
    fn parallel_ingest_matches_sequential_shard() {
        let data: Vec<NTuple> = (0..5000u32)
            .map(|i| NTuple::triple(i % 9, i % 7, i % 5))
            .collect();
        let mut seq = Shard::new(0, 3);
        seq.ingest(&data);
        let mut par = Shard::new(0, 3);
        par.ingest_par(&data, 4);
        assert_eq!(seq.epoch(), par.epoch());
        assert_eq!(seq.len(), par.len());
        let (ds, dp) = (seq.take_delta(), par.take_delta());
        assert_eq!(ds.tuples, dp.tuples);
        assert_eq!(ds.appends, dp.appends);
        // empty batches do not advance the epoch on either path
        par.ingest_par(&[], 4);
        assert_eq!(par.epoch(), 1);
    }

    #[test]
    fn restore_by_adoption_matches_ingest() {
        let data = triples(&[(0, 0, 0), (1, 0, 0), (0, 1, 1), (1, 1, 0), (2, 0, 1)]);
        let mut live = Shard::new(3, 3);
        live.ingest(&data);
        live.ingest(&data[..2]); // duplicates: generated history keeps them
        let image_cumuli = live.export_cumuli();
        let history = live.ingested_tuples();
        let mut restored =
            Shard::restore(3, 3, live.epoch(), &history, image_cumuli).unwrap();
        assert_eq!(restored.id(), 3);
        assert_eq!(restored.epoch(), live.epoch());
        assert_eq!(restored.len(), live.len());
        // the restored shard exports the SAME delta stream a replayed
        // shard would: same tuples, same combined appends
        let (dl, dr) = (live.take_delta(), restored.take_delta());
        assert_eq!(dl.tuples, dr.tuples);
        let ca = live.local_clusters(&Constraints::none());
        let cb = restored.local_clusters(&Constraints::none());
        assert_eq!(ca.len(), cb.len());
        for (x, y) in ca.iter().zip(&cb) {
            assert_eq!(x.components, y.components);
            assert_eq!(x.support, y.support);
        }
        // a tuple the cumuli never saw → inconsistent image, typed error
        let bad = Shard::restore(0, 3, 1, &triples(&[(9, 9, 9)]), Vec::new());
        assert!(bad.is_err());
    }

    #[test]
    fn replay_reproduces_state() {
        let data = triples(&[(0, 0, 0), (1, 0, 0), (0, 1, 1), (1, 1, 0)]);
        let mut a = Shard::new(0, 3);
        for chunk in data.chunks(2) {
            a.ingest(chunk);
        }
        let mut b = Shard::new(0, 3);
        b.ingest(&a.ingested_tuples());
        let ca = a.local_clusters(&Constraints::none());
        let cb = b.local_clusters(&Constraints::none());
        assert_eq!(ca.len(), cb.len());
        for (x, y) in ca.iter().zip(&cb) {
            assert_eq!(x.components, y.components);
            assert_eq!(x.support, y.support);
        }
    }
}
