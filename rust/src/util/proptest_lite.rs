//! Property-based testing substrate (no proptest crate offline).
//!
//! A `Gen` wraps the repo PRNG with size-aware generators; `check` runs a
//! property over many random cases and, on failure, retries the same seed
//! with shrunken size parameters to report a small counterexample. Used by
//! the coordinator-invariant tests (routing/partitioning, batching,
//! dedup/merge idempotence, prime-set state).

use crate::util::rng::Rng;

/// Configuration of a property run.
#[derive(Clone, Debug)]
pub struct Config {
    /// Random cases to run.
    pub cases: usize,
    /// Base seed (case i uses a derived stream).
    pub seed: u64,
    /// Upper bound for size-scaled generators.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Generator context for one case: PRNG + target size.
pub struct Gen {
    /// The case's PRNG.
    pub rng: Rng,
    /// The case's target size.
    pub size: usize,
}

impl Gen {
    /// Uniform in `[0, n)` (n clamped to ≥ 1).
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.rng.usize_below(n.max(1))
    }

    /// Uniform in `[0, n)` (n clamped to ≥ 1).
    pub fn u32_below(&mut self, n: u32) -> u32 {
        self.rng.below(n.max(1) as u64) as u32
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Bernoulli trial.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// A length scaled by the case size (0..=size).
    pub fn len(&mut self) -> usize {
        self.rng.usize_below(self.size + 1)
    }

    /// Vector of generated items with size-scaled length.
    pub fn vec<T>(&mut self, mut item: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.len();
        (0..n).map(|_| item(self)).collect()
    }

    /// Distinct sorted ids in `0..universe`, size-scaled count.
    pub fn id_set(&mut self, universe: u32) -> Vec<u32> {
        let n = self.len().min(universe as usize);
        let mut ids = self.rng.sample_indices(universe as usize, n);
        ids.sort_unstable();
        ids.into_iter().map(|i| i as u32).collect()
    }
}

/// Outcome of a failed property with its reproduction info.
#[derive(Debug)]
pub struct Failure {
    /// Index of the failing case.
    pub case: usize,
    /// Seed that reproduces it.
    pub seed: u64,
    /// Size the failure shrank to.
    pub size: usize,
    /// The property's failure message.
    pub message: String,
}

/// Run `prop` over `cfg.cases` random cases. The property returns
/// `Err(message)` to signal failure. On failure, smaller sizes are probed
/// first to produce the most shrunken failing report.
pub fn check<F>(cfg: &Config, mut prop: F) -> Result<(), Failure>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // sizes ramp up so early failures are small
        let size = 1 + (cfg.max_size - 1) * case / cfg.cases.max(1);
        let case_seed = cfg.seed ^ ((case as u64) << 32) ^ case as u64;
        let mut g = Gen { rng: Rng::new(case_seed), size };
        if let Err(message) = prop(&mut g) {
            // shrink pass: same seed, progressively smaller sizes
            let mut best = Failure { case, seed: case_seed, size, message };
            let mut s = size / 2;
            while s >= 1 {
                let mut g = Gen { rng: Rng::new(case_seed), size: s };
                if let Err(m) = prop(&mut g) {
                    best = Failure { case, seed: case_seed, size: s, message: m };
                    s /= 2;
                } else {
                    break;
                }
            }
            return Err(best);
        }
    }
    Ok(())
}

/// Assert-style wrapper for tests.
#[track_caller]
pub fn assert_prop<F>(cases: usize, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let cfg = Config { cases, ..Config::default() };
    if let Err(f) = check(&cfg, prop) {
        panic!(
            "property failed (case {}, seed {:#x}, size {}): {}",
            f.case, f.seed, f.size, f.message
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        assert_prop(64, |g| {
            let v = g.vec(|g| g.u32_below(100));
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            if s.len() <= v.len() {
                Ok(())
            } else {
                Err("dedup grew".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let cfg = Config { cases: 200, max_size: 64, ..Config::default() };
        let res = check(&cfg, |g| {
            let v = g.vec(|g| g.u32_below(10));
            if v.len() < 5 {
                Ok(())
            } else {
                Err(format!("len={}", v.len()))
            }
        });
        let f = res.expect_err("must fail");
        // the shrink pass should report a smaller size than max
        assert!(f.size < 64, "size={}", f.size);
    }

    #[test]
    fn id_set_is_sorted_distinct_in_range() {
        assert_prop(64, |g| {
            let ids = g.id_set(40);
            let sorted = ids.windows(2).all(|w| w[0] < w[1]);
            let in_range = ids.iter().all(|&i| i < 40);
            if sorted && in_range {
                Ok(())
            } else {
                Err(format!("bad id_set {ids:?}"))
            }
        });
    }
}
