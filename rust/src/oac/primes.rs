//! Prime-set / cumulus dictionaries — the state of the online algorithm.
//!
//! Paper Alg. 1 keeps three hash dictionaries (PrimesOA, PrimesOC,
//! PrimesAC) mapping entity pairs to prime sets; triclusters hold
//! *pointers* into those dictionaries so a later triple updating a set is
//! visible to every tricluster sharing it. The N-ary generalisation
//! (§3.1) keys by `SubRelation` and the sets are cumuli.
//!
//! Here "pointer" = arena index (`SetId`); the arena owns the sets and
//! materialisation resolves ids → sorted contents once, at the end.
//!
//! §Perf (the Layer-3 hot path — see docs/ARCHITECTURE.md):
//!
//! * [`SetIds`] stores the N per-tuple pointers inline (`[SetId; MAX_ARITY]`)
//!   — `PrimeStore::add` allocates NOTHING per tuple;
//! * all N packed subrelation keys of a tuple are built in one
//!   prefix/suffix pass ([`pack_keys_into`]) instead of re-packing the
//!   element buffer once per modality;
//! * [`SetArena`] is a flat paged arena (one shared `u32` pool, fixed-size
//!   pages chained per set, freed pages recycled) with a per-set cached
//!   sorted/deduped view: `ensure_sorted_all` folds the unsorted page tail
//!   into the cache (a sorted merge, not a full re-sort), after which
//!   `materialize`/`materialize_into` are a memcpy — the dedup, the serve
//!   compactor, and the query path all re-materialise the same cumuli
//!   repeatedly and hit this cache;
//! * [`PrimeStore::par_add_batch`] ingests a batch on `util::pool`
//!   workers into thread-local stores and merges them deterministically
//!   (set-id remap in first-touch order), bit-for-bit equal to
//!   sequential ingest — the paper's "triples are processed
//!   independently" claim applied to the single-node engine;
//! * round 2: the packed dictionaries are open-addressing [`ProbeDict`]s
//!   and [`PrimeStore::add_batch`] stages [`PROBE_WIDTH`] tuples at a
//!   time — key packing and hashing run as branch-free loops over flat
//!   `u128`/`u64` slices (autovectorisable), and only the final probe /
//!   allocate pass walks sequentially, preserving first-touch order.
//!   The scalar [`PrimeStore::add`] loop is kept as the property-test
//!   oracle.

use std::path::PathBuf;
use std::sync::Arc;

use crate::core::tuple::{NTuple, SubRelation, MAX_ARITY};
use crate::util::hash::{mix64, FxHashMap};
use crate::util::pool;

/// Index of a prime set / cumulus in the arena.
pub type SetId = u32;

/// The N cumulus-set ids of one generated cluster, stored inline —
/// no per-tuple heap allocation on the ingest hot path (arity ≤
/// [`MAX_ARITY`] by construction).
#[derive(Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct SetIds {
    ids: [SetId; MAX_ARITY],
    len: u8,
}

impl SetIds {
    /// Append the next modality's set id (panics past [`MAX_ARITY`]).
    #[inline]
    pub fn push(&mut self, id: SetId) {
        self.ids[self.len as usize] = id;
        self.len += 1;
    }

    /// The ids as a slice, one per modality.
    #[inline]
    pub fn as_slice(&self) -> &[SetId] {
        &self.ids[..self.len as usize]
    }

    /// Number of modalities.
    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    /// True before the first `push`.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Iterator over the ids.
    pub fn iter(&self) -> std::slice::Iter<'_, SetId> {
        self.as_slice().iter()
    }

    /// Map every id through a local→global remap table (the parallel
    /// ingest merge).
    #[inline]
    fn remapped(&self, remap: &[SetId]) -> SetIds {
        let mut out = SetIds::default();
        for &id in self.as_slice() {
            out.push(remap[id as usize]);
        }
        out
    }
}

impl std::ops::Index<usize> for SetIds {
    type Output = SetId;

    fn index(&self, i: usize) -> &SetId {
        &self.as_slice()[i]
    }
}

impl<'a> IntoIterator for &'a SetIds {
    type Item = &'a SetId;
    type IntoIter = std::slice::Iter<'a, SetId>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl std::fmt::Debug for SetIds {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SetIds{:?}", self.as_slice())
    }
}

/// Elements per arena page (`u32` slots). Public because the binary
/// segment format ([`crate::persist`]) frames cumulus values in
/// page-sized runs — the on-disk layout mirrors the arena's.
pub const PAGE: usize = 8;
/// Null page index.
const NO_PAGE: u32 = u32::MAX;

/// Per-shard resident-page budget for a process-wide `mib` budget split
/// across `shards` arenas (`mib == 0` = unlimited, spill tier off). The
/// floor of 8 pages keeps a pathological budget from thrashing every
/// single page allocation through the spill file.
pub fn resident_pages(mib: usize, shards: usize) -> usize {
    if mib == 0 {
        return 0;
    }
    ((mib << 20) / 4 / PAGE / shards.max(1)).max(8)
}

/// Per-set bookkeeping inside the arena.
#[derive(Debug, Clone)]
struct SetMeta {
    /// First page of the unsorted append tail (`NO_PAGE` when empty).
    head: u32,
    /// Last page of the tail (undefined when `head == NO_PAGE`).
    tail: u32,
    /// Elements in the tail — appended since the last `ensure_sorted`.
    pending: u32,
    /// Cached sorted + deduplicated view of everything sealed so far.
    sorted: Vec<u32>,
    /// Last-touch stamp (page-granular LRU clock) — orders spill victims.
    touch: u64,
    /// Cold runs spilled to the shared spill file: `(byte offset, value
    /// count)`, raw little-endian `u32`s. Reloaded (and cleared) on the
    /// next `ensure_sorted`; read in place by `materialize_into`.
    spilled: Vec<(u64, u32)>,
}

impl SetMeta {
    fn new() -> Self {
        Self {
            head: NO_PAGE,
            tail: NO_PAGE,
            pending: 0,
            sorted: Vec::new(),
            touch: 0,
            spilled: Vec::new(),
        }
    }

    /// Values parked in the spill file for this set.
    fn spilled_len(&self) -> usize {
        self.spilled.iter().map(|&(_, n)| n as usize).sum()
    }
}

/// The append-only cold-page spill file behind one arena lineage.
/// Clones of a spilling arena share it through an `Arc` (runs are
/// immutable once written); the file is unlinked when the last clone
/// drops.
#[derive(Debug)]
struct SpillFile {
    file: std::fs::File,
    path: PathBuf,
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Process-unique suffix source for spill file names (no timestamps —
/// the repo's determinism discipline forbids wall-clock naming).
static SPILL_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

#[cfg(unix)]
fn spill_write_at(f: &std::fs::File, off: u64, bytes: &[u8]) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    f.write_all_at(bytes, off)
}

#[cfg(unix)]
fn spill_read_at(f: &std::fs::File, off: u64, bytes: &mut [u8]) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    f.read_exact_at(bytes, off)
}

#[cfg(not(unix))]
fn spill_write_at(_: &std::fs::File, _: u64, _: &[u8]) -> std::io::Result<()> {
    Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "spill needs pread/pwrite"))
}

#[cfg(not(unix))]
fn spill_read_at(_: &std::fs::File, _: u64, _: &mut [u8]) -> std::io::Result<()> {
    Err(std::io::Error::new(std::io::ErrorKind::Unsupported, "spill needs pread/pwrite"))
}

/// Arena of grow-only entity-id sets, addressed by `SetId`.
///
/// Appends may contain duplicates when the input stream replays tuples
/// (M/R task retries); materialisation dedups, preserving set semantics
/// without paying a per-insert hash probe on the hot path.
///
/// Storage is a flat paged pool: every set's appends land in fixed-size
/// pages carved from ONE shared `u32` vector (no per-set `Vec` growth on
/// the hot path), chained per set. `ensure_sorted` folds a set's page
/// tail into its cached sorted view and recycles the pages through a
/// free list, so a long-lived arena (the serve compactor) converges to
/// compact sorted storage between compactions.
#[derive(Debug, Default, Clone)]
pub struct SetArena {
    /// The page pool; page `p` occupies `pool[p*PAGE .. (p+1)*PAGE]`.
    pool: Vec<u32>,
    /// Per-page link to the next page of the same set (`NO_PAGE` at tail).
    next: Vec<u32>,
    /// Recycled pages, reused before the pool grows.
    free: Vec<u32>,
    sets: Vec<SetMeta>,
    /// Resident-page budget; 0 = unlimited (spill tier off). When the
    /// pool would grow past it, cold page chains spill to disk first.
    budget_pages: usize,
    /// Directory for the lazily created spill file (`None` = temp dir).
    spill_dir: Option<PathBuf>,
    /// The spill file, created on the first sweep that needs it.
    spill: Option<Arc<SpillFile>>,
    /// Bytes appended to the spill file so far (next run's offset).
    spill_len: u64,
    /// LRU clock: bumped once per page-chain touch, stamped into
    /// `SetMeta::touch` — page-granular, so the per-push hot path pays
    /// one predictable branch when the budget is off.
    clock: u64,
    /// Set currently being appended to — never a spill victim (its
    /// `pending` count must not change under `push`'s feet).
    guard: SetId,
}

impl SetArena {
    /// Allocate a fresh empty set, returning its id.
    pub fn alloc(&mut self) -> SetId {
        self.sets.push(SetMeta::new());
        (self.sets.len() - 1) as SetId
    }

    /// Turn the cold-page spill tier on: the pool stops growing past
    /// `pages` resident pages — further allocations first spill the
    /// least-recently-touched page chains to a spill file under
    /// `spill_dir` (temp dir when `None`) and recycle their pages.
    /// `pages == 0` turns the tier off. Spilled contents reload
    /// transparently on `ensure_sorted` / `materialize` touch
    /// (`oac.arena.{spill,reload}` count both sides in pages).
    pub fn set_resident_budget(&mut self, pages: usize, spill_dir: Option<PathBuf>) {
        self.budget_pages = pages;
        self.spill_dir = spill_dir;
    }

    /// The configured resident budget (pages; 0 = unlimited).
    pub fn resident_budget(&self) -> usize {
        self.budget_pages
    }

    fn alloc_page(&mut self) -> u32 {
        if let Some(p) = self.free.pop() {
            self.next[p as usize] = NO_PAGE;
            return p;
        }
        if self.budget_pages != 0 && self.pool.len() / PAGE >= self.budget_pages {
            self.spill_sweep();
            if let Some(p) = self.free.pop() {
                self.next[p as usize] = NO_PAGE;
                return p;
            }
        }
        let p = (self.pool.len() / PAGE) as u32;
        self.pool.resize(self.pool.len() + PAGE, 0);
        self.next.push(NO_PAGE);
        p
    }

    /// Open (or create) the shared spill file. On failure the budget is
    /// cleared — ingest streams on in memory rather than aborting — and
    /// `oac.arena.spill_fail` records the downgrade.
    fn spill_handle(&mut self) -> Option<Arc<SpillFile>> {
        if let Some(sf) = &self.spill {
            return Some(Arc::clone(sf));
        }
        let dir = self.spill_dir.clone().unwrap_or_else(std::env::temp_dir);
        let seq = SPILL_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let path = dir.join(format!(
            "tricluster-spill-{}-{seq}.bin",
            std::process::id()
        ));
        let created = std::fs::create_dir_all(&dir)
            .and_then(|_| {
                std::fs::OpenOptions::new()
                    .read(true)
                    .write(true)
                    .create(true)
                    .truncate(true)
                    .open(&path)
            });
        match created {
            Ok(file) => {
                let sf = Arc::new(SpillFile { file, path });
                self.spill = Some(Arc::clone(&sf));
                Some(sf)
            }
            Err(_) => {
                self.budget_pages = 0;
                crate::obs::counter("oac.arena.spill_fail", 1);
                None
            }
        }
    }

    /// Spill the least-recently-touched page chains until ~¼ of the
    /// budget is free (or candidates run out). Emits `oac.arena.spill`
    /// (pages moved) and the `oac.arena.page_residency` watermark — the
    /// LRU stamp below which chains were evicted this sweep.
    fn spill_sweep(&mut self) {
        let guard = self.guard;
        let mut cand: Vec<(u64, SetId)> = self
            .sets
            .iter()
            .enumerate()
            .filter(|&(i, m)| i as SetId != guard && m.pending > 0)
            .map(|(i, m)| (m.touch, i as SetId))
            .collect();
        cand.sort_unstable();
        let target = (self.budget_pages / 4).max(1);
        let mut freed = 0usize;
        let mut watermark = 0u64;
        for (stamp, id) in cand {
            if freed >= target || self.budget_pages == 0 {
                break;
            }
            freed += self.spill_set(id);
            watermark = stamp;
        }
        if freed > 0 {
            crate::obs::counter("oac.arena.spill", freed as u64);
            if crate::obs::enabled() {
                crate::obs::gauge("oac.arena.page_residency", watermark as f64);
            }
        }
    }

    /// Move one set's pending page chain to the spill file and recycle
    /// its pages; returns pages freed (0 on a disabled/failed spill).
    fn spill_set(&mut self, id: SetId) -> usize {
        let pending = self.sets[id as usize].pending as usize;
        if pending == 0 {
            return 0;
        }
        let mut vals = Vec::with_capacity(pending);
        self.gather_pending(&self.sets[id as usize], &mut vals);
        let Some(sf) = self.spill_handle() else { return 0 };
        let mut bytes = Vec::with_capacity(vals.len() * 4);
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let off = self.spill_len;
        if spill_write_at(&sf.file, off, &bytes).is_err() {
            self.budget_pages = 0;
            crate::obs::counter("oac.arena.spill_fail", 1);
            return 0;
        }
        self.spill_len += bytes.len() as u64;
        let m = &mut self.sets[id as usize];
        m.spilled.push((off, pending as u32));
        m.pending = 0;
        let mut page = m.head;
        m.head = NO_PAGE;
        m.tail = NO_PAGE;
        let mut freed = 0usize;
        while page != NO_PAGE {
            let nxt = self.next[page as usize];
            self.free.push(page);
            page = nxt;
            freed += 1;
        }
        freed
    }

    /// Append every spilled run of `m` to `out`, in spill order.
    ///
    /// # Panics
    /// On spill-file I/O failure — the data exists nowhere else, so a
    /// failed read is unrecoverable data loss, not a recoverable state.
    fn reload_spilled(&self, m: &SetMeta, out: &mut Vec<u32>) {
        if m.spilled.is_empty() {
            return;
        }
        let sf = self.spill.as_ref().expect("spilled runs imply a spill file");
        let mut pages = 0usize;
        for &(off, n) in &m.spilled {
            let mut bytes = vec![0u8; n as usize * 4];
            spill_read_at(&sf.file, off, &mut bytes).expect("spill file read");
            out.extend(
                bytes
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().expect("4-byte chunk"))),
            );
            pages += (n as usize).div_ceil(PAGE);
        }
        crate::obs::counter("oac.arena.reload", pages as u64);
    }

    #[inline]
    /// Append `value` to set `id` (duplicates dedup on materialise).
    pub fn push(&mut self, id: SetId, value: u32) {
        let slot = self.sets[id as usize].pending as usize % PAGE;
        if slot == 0 {
            if self.budget_pages != 0 {
                self.clock += 1;
                self.sets[id as usize].touch = self.clock;
                self.guard = id;
            }
            let page = self.alloc_page();
            let m = &mut self.sets[id as usize];
            if m.head == NO_PAGE {
                m.head = page;
            } else {
                self.next[m.tail as usize] = page;
            }
            m.tail = page;
        }
        let m = &mut self.sets[id as usize];
        self.pool[m.tail as usize * PAGE + slot] = value;
        m.pending += 1;
    }

    /// Number of allocated sets.
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    /// Pages ever carved from the shared pool (monotone; freed pages are
    /// recycled, not returned) — telemetry reads this at batch
    /// granularity so the per-push hot path stays recorder-free.
    pub fn pages(&self) -> usize {
        self.pool.len() / PAGE
    }

    /// Pages currently parked on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// True before the first allocation.
    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Upper bound on set `id`'s cardinality (sealed uniques + possibly
    /// duplicated tail appends) — the capacity hint for materialisation.
    pub fn set_len_bound(&self, id: SetId) -> usize {
        let m = &self.sets[id as usize];
        m.sorted.len() + m.pending as usize + m.spilled_len()
    }

    /// Copy the unsorted page tail of `m` into `out`, in append order.
    fn gather_pending(&self, m: &SetMeta, out: &mut Vec<u32>) {
        let mut page = m.head;
        let mut remaining = m.pending as usize;
        while remaining > 0 {
            let take = remaining.min(PAGE);
            let base = page as usize * PAGE;
            out.extend_from_slice(&self.pool[base..base + take]);
            remaining -= take;
            page = self.next[page as usize];
        }
    }

    /// Sorted, deduplicated contents.
    pub fn materialize(&self, id: SetId) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.set_len_bound(id));
        self.materialize_into(id, &mut v);
        v
    }

    /// [`Self::materialize`] into a caller-owned buffer (clear + fill).
    /// When the set's sorted cache is current (no appends since the last
    /// [`Self::ensure_sorted`]) this is a straight memcpy; otherwise the
    /// tail is gathered and sorted in the buffer. Hot per-triple loops
    /// (the online dedup, the basic algorithm) reuse one buffer across
    /// lookups instead of allocating a fresh `Vec` per set.
    pub fn materialize_into(&self, id: SetId, out: &mut Vec<u32>) {
        out.clear();
        let m = &self.sets[id as usize];
        out.reserve(m.sorted.len() + m.pending as usize + m.spilled_len());
        out.extend_from_slice(&m.sorted);
        if m.pending == 0 && m.spilled.is_empty() {
            // §Perf fast path: the cached sorted view is current
            crate::obs::counter("oac.arena.cache_hit", 1);
            return;
        }
        crate::obs::counter("oac.arena.cache_miss", 1);
        self.reload_spilled(m, out);
        self.gather_pending(m, out);
        out.sort_unstable();
        out.dedup();
    }

    /// Fold set `id`'s unsorted tail into its cached sorted view (a
    /// sorted merge of cache + sorted tail, NOT a full re-sort) and
    /// recycle the tail pages. After this, materialisation of `id` is a
    /// memcpy until the next `push`.
    pub fn ensure_sorted(&mut self, id: SetId) {
        {
            let m = &self.sets[id as usize];
            if m.pending == 0 && m.spilled.is_empty() {
                return;
            }
        }
        let m = &self.sets[id as usize];
        let mut tail = Vec::with_capacity(m.pending as usize + m.spilled_len());
        self.reload_spilled(m, &mut tail);
        self.gather_pending(m, &mut tail);
        tail.sort_unstable();
        tail.dedup();
        let mut page = {
            let m = &mut self.sets[id as usize];
            if m.sorted.is_empty() {
                m.sorted = tail;
            } else {
                m.sorted = merge_sorted(&m.sorted, &tail);
            }
            m.spilled.clear();
            let head = m.head;
            m.head = NO_PAGE;
            m.tail = NO_PAGE;
            m.pending = 0;
            head
        };
        while page != NO_PAGE {
            let nxt = self.next[page as usize];
            self.free.push(page);
            page = nxt;
        }
    }

    /// [`Self::ensure_sorted`] for every set — the seal step dedup /
    /// compaction runs once per call site, so the double materialisation
    /// inside the dedup (fingerprint pass + representative pass) and
    /// every later query-path materialisation are memcpys.
    pub fn ensure_sorted_all(&mut self) {
        let track = crate::obs::enabled();
        let free_before = self.free.len();
        let dirty = if track {
            self.sets
                .iter()
                .filter(|m| m.pending > 0 || !m.spilled.is_empty())
                .count()
        } else {
            0
        };
        for id in 0..self.sets.len() {
            self.ensure_sorted(id as SetId);
        }
        if track {
            crate::obs::counter("oac.arena.sort_merge", dirty as u64);
            crate::obs::counter(
                "oac.arena.page_recycle",
                (self.free.len() - free_before) as u64,
            );
        }
    }

    /// Append a whole slice to set `id`, copying page-sized runs instead
    /// of one element at a time — the parallel-ingest merge's hot loop
    /// (the merge is the sequential part of `par_add_batch`, so its
    /// per-element overhead directly caps the parallel speedup).
    fn push_slice(&mut self, id: SetId, mut vals: &[u32]) {
        while !vals.is_empty() {
            let slot = self.sets[id as usize].pending as usize % PAGE;
            if slot == 0 {
                if self.budget_pages != 0 {
                    self.clock += 1;
                    self.sets[id as usize].touch = self.clock;
                    self.guard = id;
                }
                let page = self.alloc_page();
                let m = &mut self.sets[id as usize];
                if m.head == NO_PAGE {
                    m.head = page;
                } else {
                    self.next[m.tail as usize] = page;
                }
                m.tail = page;
            }
            let take = vals.len().min(PAGE - slot);
            let m = &mut self.sets[id as usize];
            let base = m.tail as usize * PAGE + slot;
            self.pool[base..base + take].copy_from_slice(&vals[..take]);
            m.pending += take as u32;
            vals = &vals[take..];
        }
    }

    /// Append the (unsealed) raw contents of `src_id` in `src` onto
    /// `dst`, preserving append order — the parallel-ingest merge.
    pub(crate) fn extend_raw_from(&mut self, dst: SetId, src: &SetArena, src_id: SetId) {
        let m = &src.sets[src_id as usize];
        debug_assert!(m.sorted.is_empty(), "merge sources are never sealed");
        debug_assert!(m.spilled.is_empty(), "merge sources are never budgeted");
        let mut page = m.head;
        let mut remaining = m.pending as usize;
        while remaining > 0 {
            let take = remaining.min(PAGE);
            let base = page as usize * PAGE;
            self.push_slice(dst, &src.pool[base..base + take]);
            remaining -= take;
            page = src.next[page as usize];
        }
    }

    /// Adopt an already sorted+deduplicated set wholesale: the vector
    /// becomes the set's sealed cache directly — no pages, no re-sort.
    /// This is the restore path's bulk adoption: a decoded segment's
    /// page frames land here without per-tuple re-ingest.
    pub fn adopt_sorted(&mut self, contents: Vec<u32>) -> SetId {
        debug_assert!(
            contents.windows(2).all(|w| w[0] < w[1]),
            "adopted sets must be sorted and deduplicated"
        );
        let id = self.alloc();
        self.sets[id as usize].sorted = contents;
        id
    }
}

/// Merge two sorted, deduplicated slices into one sorted, deduplicated
/// vector.
fn merge_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                out.push(a[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(b[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// Pack up to 4 entity ids into a `u128` key, 32 bits each, low-to-high.
/// The ONE packing rule shared by the tuple-side fast path
/// ([`pack_keys_into`]) and the subrelation-side lookup
/// ([`PrimeStore::get`]).
#[inline]
fn pack_elems(elems: &[u32]) -> u128 {
    debug_assert!(elems.len() <= 4, "packed keys hold ≤ 4 elements");
    let mut key: u128 = 0;
    let mut shift = 0;
    for &e in elems {
        key |= (e as u128) << shift;
        shift += 32;
    }
    key
}

/// Pack ALL N k-dropped subrelation keys of `t` in one prefix/suffix
/// pass — §Perf: the old per-modality repacking rebuilt an element
/// buffer per k (`O(N²)` writes per tuple); this is `O(N)`. Valid for
/// original arity ≤ 5 (≤ 4 packed 32-bit elements per key); key `k`
/// equals `pack_elems` of the tuple with position `k` dropped.
#[inline]
fn pack_keys_into(t: &NTuple, keys: &mut [u128; MAX_ARITY]) {
    let s = t.as_slice();
    let n = s.len();
    debug_assert!(n <= 5, "packed keys hold ≤ 4 elements");
    // prefix: elements 0..k stay at slots 0..k
    let mut prefix: u128 = 0;
    for k in 0..n {
        keys[k] = prefix;
        if k + 1 < n {
            prefix |= (s[k] as u128) << (32 * k);
        }
    }
    // suffix: elements k+1..n shift down one slot to k..n-1
    let mut suffix: u128 = 0;
    for k in (0..n).rev() {
        keys[k] |= suffix;
        if k > 0 {
            suffix |= (s[k] as u128) << (32 * (k - 1));
        }
    }
}

/// Tuples per probe batch in [`PrimeStore::add_batch`]: keys and hashes
/// for this many tuples are staged in flat fixed-width buffers so the
/// pack and hash loops have no per-iteration branching (8 tuples × up to
/// 5 keys each fills the SIMD pipeline without spilling L1).
const PROBE_WIDTH: usize = 8;

/// Sentinel value marking an empty [`ProbeDict`] slot. A real arena can
/// never hand out `u32::MAX` set ids (the pool would exceed address
/// space long before), so values double as occupancy flags and the probe
/// loop needs no separate control bytes.
const EMPTY_SLOT: SetId = SetId::MAX;

/// Open-addressing dictionary from packed subrelation keys (`u128`) to
/// set ids — the probe structure behind the §Perf batch ingest.
///
/// Linear probing over power-of-two capacity, grown at ¾ load. Compared
/// to the previous `FxHashMap<u128, SetId>` the win is not the probe
/// itself but the *batched* entry: hashes for a whole
/// [`PROBE_WIDTH`]-tuple block are precomputed in one flat branch-free
/// loop ([`ProbeDict::hash`] is pure arithmetic), so the dependent
/// hash→probe chain of the map API disappears from the hot loop.
#[derive(Debug, Clone)]
struct ProbeDict {
    /// Keys, parallel to `vals`; meaningful only where `vals` is occupied.
    keys: Vec<u128>,
    /// Set ids, `EMPTY_SLOT` = free.
    vals: Vec<SetId>,
    /// Capacity − 1 (capacity is a power of two).
    mask: usize,
    /// Occupied slots.
    len: usize,
}

impl ProbeDict {
    fn new() -> Self {
        let cap = 64;
        Self { keys: vec![0; cap], vals: vec![EMPTY_SLOT; cap], mask: cap - 1, len: 0 }
    }

    /// Hash a packed key: both 64-bit halves through the SplitMix64
    /// finalizer. Branch-free — the batched ingest hashes whole key
    /// blocks with this in a vectorisable loop.
    #[inline]
    fn hash(key: u128) -> u64 {
        mix64(key as u64 ^ mix64((key >> 64) as u64).rotate_left(1))
    }

    /// Probe for `key` with its precomputed hash.
    #[inline]
    fn get_hashed(&self, h: u64, key: u128) -> Option<SetId> {
        let mut i = h as usize & self.mask;
        loop {
            let v = self.vals[i];
            if v == EMPTY_SLOT {
                return None;
            }
            if self.keys[i] == key {
                return Some(v);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Insert a key known to be absent (callers probe first), growing at
    /// ¾ load. `h` must be `Self::hash(key)`.
    #[inline]
    fn insert_hashed(&mut self, h: u64, key: u128, val: SetId) {
        if (self.len + 1) * 4 > self.keys.len() * 3 {
            self.grow();
        }
        let mut i = h as usize & self.mask;
        while self.vals[i] != EMPTY_SLOT {
            debug_assert_ne!(self.keys[i], key, "insert of a present key");
            i = (i + 1) & self.mask;
        }
        self.keys[i] = key;
        self.vals[i] = val;
        self.len += 1;
    }

    fn grow(&mut self) {
        let cap = self.keys.len() * 2;
        let old_keys = std::mem::replace(&mut self.keys, vec![0; cap]);
        let old_vals = std::mem::replace(&mut self.vals, vec![EMPTY_SLOT; cap]);
        self.mask = cap - 1;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if v != EMPTY_SLOT {
                let mut i = Self::hash(k) as usize & self.mask;
                while self.vals[i] != EMPTY_SLOT {
                    i = (i + 1) & self.mask;
                }
                self.keys[i] = k;
                self.vals[i] = v;
            }
        }
    }

    fn get(&self, key: u128) -> Option<SetId> {
        self.get_hashed(Self::hash(key), key)
    }

    fn insert(&mut self, key: u128, val: SetId) {
        self.insert_hashed(Self::hash(key), key, val);
    }

    fn len(&self) -> usize {
        self.len
    }

    /// Iterate occupied `(key, id)` entries (arbitrary order — the one
    /// consumer, `cumuli`, sorts its output canonically).
    fn iter(&self) -> impl Iterator<Item = (u128, SetId)> + '_ {
        self.keys
            .iter()
            .zip(&self.vals)
            .filter(|&(_, &v)| v != EMPTY_SLOT)
            .map(|(&k, &v)| (k, v))
    }
}

/// Tuples per parallel-ingest chunk below which spawning workers costs
/// more than it saves.
const PAR_MIN_CHUNK: usize = 2048;

/// The cumulus dictionaries for an N-ary context: one map per modality,
/// keyed by the subrelation with that modality dropped.
///
/// §Perf: for arity ≤ 5 the subrelation key is packed into a `u128`
/// (one FxHash word-mix instead of hashing a 26-byte struct); wider
/// relations fall back to `SubRelation` keys.
#[derive(Debug)]
pub struct PrimeStore {
    arity: usize,
    /// fast path (arity ≤ 5): dicts[k]: packed subrelation → set id
    packed: Vec<ProbeDict>,
    /// general path: dicts[k]: subrelation → set id
    general: Vec<FxHashMap<SubRelation, SetId>>,
    /// The arena holding every prime set's contents.
    pub arena: SetArena,
}

impl PrimeStore {
    /// Empty store over `arity` modalities.
    pub fn new(arity: usize) -> Self {
        let fast = arity <= 5;
        Self {
            arity,
            packed: if fast {
                (0..arity).map(|_| ProbeDict::new()).collect()
            } else {
                Vec::new()
            },
            general: if fast {
                Vec::new()
            } else {
                (0..arity).map(|_| FxHashMap::default()).collect()
            },
            arena: SetArena::default(),
        }
    }

    /// Number of modalities.
    pub fn arity(&self) -> usize {
        self.arity
    }

    /// Process one tuple (Alg. 1 lines 2–4 generalised): for each
    /// modality k, append `e_k` to the cumulus of the k-dropped
    /// subrelation. Returns the N set ids — the "pointers" stored in the
    /// generated cluster — inline, with no per-tuple allocation.
    pub fn add(&mut self, t: &NTuple) -> SetIds {
        debug_assert_eq!(t.arity(), self.arity);
        if !self.packed.is_empty() {
            self.add_fast(t, |_, _| {})
        } else {
            let mut ids = SetIds::default();
            for k in 0..self.arity {
                let sub = t.subrelation(k);
                let id = match self.general[k].get(&sub) {
                    Some(&id) => id,
                    None => {
                        let id = self.arena.alloc();
                        self.general[k].insert(sub, id);
                        id
                    }
                };
                self.arena.push(id, t.get(k));
                ids.push(id);
            }
            ids
        }
    }

    /// The packed-key `add`, reporting each freshly allocated key to
    /// `on_alloc` — the creation log the parallel-ingest merge replays
    /// to renumber local ids in deterministic first-touch order. The
    /// sequential `add` passes a no-op closure (inlined away).
    #[inline]
    fn add_fast(&mut self, t: &NTuple, mut on_alloc: impl FnMut(u8, u128)) -> SetIds {
        let mut keys = [0u128; MAX_ARITY];
        pack_keys_into(t, &mut keys);
        let mut ids = SetIds::default();
        for k in 0..self.arity {
            let h = ProbeDict::hash(keys[k]);
            let id = match self.packed[k].get_hashed(h, keys[k]) {
                Some(id) => id,
                None => {
                    let id = self.arena.alloc();
                    self.packed[k].insert_hashed(h, keys[k], id);
                    on_alloc(k as u8, keys[k]);
                    id
                }
            };
            self.arena.push(id, t.get(k));
            ids.push(id);
        }
        ids
    }

    /// [`Self::add`] over a whole batch through the batched probe
    /// pipeline. Per [`PROBE_WIDTH`]-tuple block: (1) pack every
    /// subrelation key into one flat `u128` buffer ([`pack_keys_into`]
    /// per tuple, no branching on dictionary state); (2) hash the whole
    /// buffer in one branch-free arithmetic loop (the autovectorisable
    /// part); (3) resolve sequentially against the dictionaries with the
    /// precomputed hashes, preserving allocation order. Bit-for-bit
    /// identical to calling [`Self::add`] per tuple (the scalar loop is
    /// the property-test oracle in `rust/tests/proptests.rs`).
    pub fn add_batch(&mut self, batch: &[NTuple]) -> Vec<SetIds> {
        let mut out = Vec::with_capacity(batch.len());
        self.add_batch_into(batch, &mut out, |_, _| {});
        out
    }

    /// [`Self::add_batch`] appending into a caller buffer and reporting
    /// fresh allocations to `on_alloc` (the parallel-ingest creation
    /// log). Falls back to the scalar loop on the general key path.
    fn add_batch_into(
        &mut self,
        batch: &[NTuple],
        out: &mut Vec<SetIds>,
        mut on_alloc: impl FnMut(u8, u128),
    ) {
        if self.packed.is_empty() {
            out.extend(batch.iter().map(|t| self.add(t)));
            return;
        }
        let arity = self.arity;
        let mut keys = [0u128; PROBE_WIDTH * MAX_ARITY];
        let mut hashes = [0u64; PROBE_WIDTH * MAX_ARITY];
        for block in batch.chunks(PROBE_WIDTH) {
            for (t, tuple) in block.iter().enumerate() {
                let slot = &mut keys[t * MAX_ARITY..(t + 1) * MAX_ARITY];
                pack_keys_into(tuple, slot.try_into().expect("MAX_ARITY window"));
            }
            // stale entries past `block.len() * MAX_ARITY` (or past the
            // tuple arity within a window) are hashed too — harmless,
            // and keeping the loop bound flat is what lets it vectorise
            for (h, &key) in hashes.iter_mut().zip(keys.iter()) {
                *h = ProbeDict::hash(key);
            }
            for (t, tuple) in block.iter().enumerate() {
                let mut ids = SetIds::default();
                for k in 0..arity {
                    let at = t * MAX_ARITY + k;
                    let id = match self.packed[k].get_hashed(hashes[at], keys[at]) {
                        Some(id) => id,
                        None => {
                            let id = self.arena.alloc();
                            self.packed[k].insert_hashed(hashes[at], keys[at], id);
                            on_alloc(k as u8, keys[at]);
                            id
                        }
                    };
                    self.arena.push(id, tuple.get(k));
                    ids.push(id);
                }
                out.push(ids);
            }
        }
    }

    /// [`Self::add`] for a whole batch on `workers` threads, with an
    /// auto-sized chunk (≥ [`PAR_MIN_CHUNK`], ~4 chunks per worker).
    ///
    /// The batch is cut into contiguous chunks ingested into thread-local
    /// stores, then merged in chunk order: each local store's creation
    /// log replays against the global dictionaries (first-touch order —
    /// chunk 0's new keys precede chunk 1's, exactly as a sequential scan
    /// would allocate them) and local arena contents append in chunk
    /// order. The result — per-tuple [`SetIds`], dictionaries, arena
    /// contents — is bit-for-bit identical to calling [`Self::add`] on
    /// every tuple in order, for ANY worker count and chunk size
    /// (property-tested in `rust/tests/proptests.rs`).
    ///
    /// The merge is cheap when cumuli are shared (distinct keys ≪
    /// tuples — the paper's dense K1/K2 regime); on near-unique streams
    /// it degrades toward a second sequential pass, which is why the
    /// caller-facing knob ([`crate::exec::ExecTuning::parallel_ingest`])
    /// exists.
    pub fn par_add_batch(&mut self, batch: &[NTuple], workers: usize) -> Vec<SetIds> {
        let chunk = batch.len().div_ceil(workers.max(1) * 4).max(PAR_MIN_CHUNK);
        self.par_add_batch_chunked(batch, workers, chunk)
    }

    /// [`Self::par_add_batch`] with an explicit chunk size (exposed so
    /// the equivalence property tests can sweep degenerate chunkings).
    /// Falls back to sequential `add` when there is nothing to win:
    /// one worker, a single chunk, or the general (arity > 5) key path.
    pub fn par_add_batch_chunked(
        &mut self,
        batch: &[NTuple],
        workers: usize,
        chunk: usize,
    ) -> Vec<SetIds> {
        let chunk = chunk.max(1);
        // telemetry is batch/chunk-granularity ONLY: the per-tuple `add`
        // loop below never touches the recorder, which is what the
        // `obs_overhead` bench gate measures against
        let mut span = crate::span!("oac.ingest.par_batch");
        span.records_in(batch.len() as u64);
        let pages_before = self.arena.pages();
        if self.packed.is_empty() || workers <= 1 || batch.len() <= chunk {
            let mut out: Vec<SetIds> = Vec::with_capacity(batch.len());
            self.add_batch_into(batch, &mut out, |_, _| {});
            crate::obs::counter(
                "oac.arena.page_alloc",
                (self.arena.pages() - pages_before) as u64,
            );
            return out;
        }
        let arity = self.arity;
        let chunks: Vec<&[NTuple]> = batch.chunks(chunk).collect();
        crate::obs::counter("oac.ingest.chunks", chunks.len() as u64);
        let locals = pool::parallel_map(chunks.len(), workers, 1, |ci| {
            let mut cspan = crate::span!("oac.ingest.chunk");
            cspan.records_in(chunks[ci].len() as u64);
            let mut store = PrimeStore::new(arity);
            let mut log: Vec<(u8, u128)> = Vec::new();
            let mut ids = Vec::with_capacity(chunks[ci].len());
            store.add_batch_into(chunks[ci], &mut ids, |k, key| log.push((k, key)));
            (store, log, ids)
        });
        // Deterministic merge, chunk-index order (parallel_map returns
        // results in index order regardless of scheduling).
        let mut out = Vec::with_capacity(batch.len());
        for (local, log, ids) in locals {
            let mut remap: Vec<SetId> = Vec::with_capacity(log.len());
            for (k, key) in log {
                let id = match self.packed[k as usize].get(key) {
                    Some(id) => id,
                    None => {
                        let id = self.arena.alloc();
                        self.packed[k as usize].insert(key, id);
                        id
                    }
                };
                remap.push(id);
            }
            for (local_id, &global_id) in remap.iter().enumerate() {
                self.arena.extend_raw_from(global_id, &local.arena, local_id as SetId);
            }
            out.extend(ids.iter().map(|sid| sid.remapped(&remap)));
        }
        crate::obs::counter(
            "oac.arena.page_alloc",
            (self.arena.pages() - pages_before) as u64,
        );
        span.records_out(out.len() as u64);
        out
    }

    /// Look up the cumulus id for a subrelation (None if never touched).
    pub fn get(&self, sub: &SubRelation) -> Option<SetId> {
        let k = sub.dropped();
        if !self.packed.is_empty() {
            self.packed[k].get(pack_elems(sub.as_slice()))
        } else {
            self.general[k].get(sub).copied()
        }
    }

    /// Number of distinct subrelation keys across all modalities.
    pub fn total_keys(&self) -> usize {
        if !self.packed.is_empty() {
            self.packed.iter().map(ProbeDict::len).sum()
        } else {
            self.general.iter().map(FxHashMap::len).sum()
        }
    }

    /// Export every cumulus as `⟨subrelation, sorted deduped contents⟩`,
    /// canonically ordered by key — exactly the stage-1 output of
    /// [`crate::exec::stages::stage1_cumuli`], so the merge-based
    /// parallel ingest doubles as a stage-1 kernel
    /// ([`crate::exec::stages::stage1_cumuli_ingest`]). Seals the arena
    /// first, so every materialisation is a memcpy.
    pub fn cumuli(&mut self) -> Vec<(SubRelation, Vec<u32>)> {
        self.arena.ensure_sorted_all();
        let arity = self.arity;
        let mut out = Vec::with_capacity(self.total_keys());
        if !self.packed.is_empty() {
            for (k, dict) in self.packed.iter().enumerate() {
                for (key, id) in dict.iter() {
                    let mut kept = [0u32; MAX_ARITY];
                    for (i, slot) in kept[..arity - 1].iter_mut().enumerate() {
                        *slot = (key >> (32 * i)) as u32;
                    }
                    out.push((
                        SubRelation::from_parts(&kept[..arity - 1], k),
                        self.arena.materialize(id),
                    ));
                }
            }
        } else {
            for dict in &self.general {
                for (&sub, &id) in dict.iter() {
                    out.push((sub, self.arena.materialize(id)));
                }
            }
        }
        out.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Rebuild a store from exported cumuli by bulk adoption — the
    /// inverse of [`Self::cumuli`] and the segment-restore fast path:
    /// each set's sorted contents become its sealed cache directly, with
    /// no per-tuple re-ingest and no re-sort. The rebuilt store answers
    /// [`Self::get`] / [`Self::cumuli`] identically to the original
    /// (set ids may differ; all observable state is id-independent).
    pub fn adopt(arity: usize, cumuli: impl IntoIterator<Item = (SubRelation, Vec<u32>)>) -> Self {
        let mut store = Self::new(arity);
        for (sub, contents) in cumuli {
            let id = store.arena.adopt_sorted(contents);
            let k = sub.dropped();
            if !store.packed.is_empty() {
                store.packed[k].insert(pack_elems(sub.as_slice()), id);
            } else {
                store.general[k].insert(sub, id);
            }
        }
        store
    }

    /// Resolve the N cumulus ids a tuple's ingest *would have* touched,
    /// without mutating anything — the restore path replays the
    /// generated-record log against an adopted store with this. `None`
    /// means some key is missing, i.e. the persisted image is
    /// inconsistent with its own tuple log.
    pub fn probe(&self, t: &NTuple) -> Option<SetIds> {
        debug_assert_eq!(t.arity(), self.arity);
        let mut ids = SetIds::default();
        if !self.packed.is_empty() {
            let mut keys = [0u128; MAX_ARITY];
            pack_keys_into(t, &mut keys);
            for k in 0..self.arity {
                let h = ProbeDict::hash(keys[k]);
                ids.push(self.packed[k].get_hashed(h, keys[k])?);
            }
        } else {
            for k in 0..self.arity {
                ids.push(*self.general[k].get(&t.subrelation(k))?);
            }
        }
        Some(ids)
    }

    /// Forward to [`SetArena::set_resident_budget`] — the out-of-core
    /// ingest knob (`--resident-mib` divides down to per-shard pages).
    pub fn set_resident_budget(&mut self, pages: usize, spill_dir: Option<PathBuf>) {
        self.arena.set_resident_budget(pages, spill_dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_sets_accumulate() {
        // Table 1: (u2,i1,l1),(u2,i2,l1),(u2,i1,l2),(u2,i2,l2)
        let mut ps = PrimeStore::new(3);
        let t = |g, m, b| NTuple::triple(g, m, b);
        let ids1 = ps.add(&t(0, 0, 0));
        let _ = ps.add(&t(0, 1, 0));
        let _ = ps.add(&t(0, 0, 1));
        let _ = ps.add(&t(0, 1, 1));
        // the modus set PrimesOA[u2, i1] should now be {l1, l2}
        assert_eq!(ps.arena.materialize(ids1[2]), vec![0, 1]);
        // the intent set PrimesOC[u2, l1] is {i1, i2}
        assert_eq!(ps.arena.materialize(ids1[1]), vec![0, 1]);
        // the extent set PrimesAC[i1, l1] is {u2}
        assert_eq!(ps.arena.materialize(ids1[0]), vec![0]);
    }

    #[test]
    fn duplicate_tuples_do_not_change_materialized_sets() {
        let mut ps = PrimeStore::new(3);
        let t = NTuple::triple(1, 2, 3);
        let a = ps.add(&t);
        let b = ps.add(&t); // replayed (task retry)
        assert_eq!(a, b);
        assert_eq!(ps.arena.materialize(a[0]), vec![1]);
        assert_eq!(ps.arena.materialize(a[2]), vec![3]);
    }

    #[test]
    fn four_ary_cumuli() {
        let mut ps = PrimeStore::new(4);
        ps.add(&NTuple::new(&[0, 1, 2, 3]));
        let ids = ps.add(&NTuple::new(&[4, 1, 2, 3]));
        // cum(i, 0) over subrelation (1,2,3) = {0, 4}
        assert_eq!(ps.arena.materialize(ids[0]), vec![0, 4]);
        assert_eq!(ps.total_keys(), 1 + 2 + 2 + 2);
    }

    #[test]
    fn materialize_into_reuses_buffer() {
        let mut ps = PrimeStore::new(3);
        let ids = ps.add(&NTuple::triple(0, 0, 0));
        ps.add(&NTuple::triple(5, 0, 0));
        ps.add(&NTuple::triple(5, 0, 0)); // duplicate append
        let mut buf = vec![99, 98, 97]; // stale contents must be cleared
        ps.arena.materialize_into(ids[0], &mut buf);
        assert_eq!(buf, vec![0, 5]);
        assert_eq!(ps.arena.materialize(ids[0]), buf);
    }

    #[test]
    fn get_by_subrelation() {
        let mut ps = PrimeStore::new(3);
        let t = NTuple::triple(5, 6, 7);
        let ids = ps.add(&t);
        assert_eq!(ps.get(&t.subrelation(1)), Some(ids[1]));
        assert_eq!(ps.get(&NTuple::triple(9, 9, 9).subrelation(0)), None);
    }

    #[test]
    fn packed_keys_match_the_subrelation_packing_rule() {
        // pack_keys_into must agree with pack_elems over the subrelation
        // slice for EVERY modality — this is the add/get key contract.
        for t in [
            NTuple::triple(7, 8, 9),
            NTuple::triple(0, 0, 0),
            NTuple::new(&[1, 2, 3, 4]),
            NTuple::new(&[9, 0, 7, 0, 5]),
        ] {
            let mut keys = [0u128; MAX_ARITY];
            pack_keys_into(&t, &mut keys);
            for k in 0..t.arity() {
                assert_eq!(
                    keys[k],
                    pack_elems(t.subrelation(k).as_slice()),
                    "key mismatch at k={k} for {t:?}"
                );
            }
        }
    }

    #[test]
    fn paged_sets_survive_page_boundaries_and_sealing() {
        let mut a = SetArena::default();
        let s = a.alloc();
        // 3 pages' worth, descending, with duplicates
        for v in (0..20u32).rev() {
            a.push(s, v);
            a.push(s, v);
        }
        assert_eq!(a.materialize(s), (0..20).collect::<Vec<u32>>());
        a.ensure_sorted(s);
        // sealed: memcpy fast path returns the same contents
        assert_eq!(a.materialize(s), (0..20).collect::<Vec<u32>>());
        // appends after sealing re-enter the tail and merge on demand
        a.push(s, 5); // duplicate of sealed content
        a.push(s, 100);
        assert_eq!(a.materialize(s), {
            let mut v: Vec<u32> = (0..20).collect();
            v.push(100);
            v
        });
        a.ensure_sorted_all();
        assert_eq!(a.set_len_bound(s), 21);
    }

    #[test]
    fn freed_pages_are_recycled() {
        let mut a = SetArena::default();
        let s1 = a.alloc();
        for v in 0..(3 * PAGE as u32) {
            a.push(s1, v);
        }
        let pool_pages = a.pool.len() / PAGE;
        a.ensure_sorted(s1); // releases 3 pages
        let s2 = a.alloc();
        for v in 0..(2 * PAGE as u32) {
            a.push(s2, v);
        }
        // the new set reuses freed pages: the pool did not grow
        assert_eq!(a.pool.len() / PAGE, pool_pages);
        assert_eq!(a.materialize(s2), (0..(2 * PAGE as u32)).collect::<Vec<u32>>());
        assert_eq!(a.materialize(s1), (0..(3 * PAGE as u32)).collect::<Vec<u32>>());
    }

    #[test]
    fn probe_dict_survives_growth_and_collisions() {
        let mut d = ProbeDict::new();
        // enough keys to force several grows; adjacent keys collide in
        // the low bits before mixing, exercising linear probing
        for i in 0..500u128 {
            assert_eq!(d.get(i), None);
            d.insert(i, i as SetId);
        }
        assert_eq!(d.len(), 500);
        for i in 0..500u128 {
            assert_eq!(d.get(i), Some(i as SetId), "key {i}");
        }
        assert_eq!(d.get(1000), None);
        let mut entries: Vec<(u128, SetId)> = d.iter().collect();
        entries.sort_unstable();
        assert_eq!(entries.len(), 500);
        assert!(entries.iter().enumerate().all(|(i, &(k, v))| k == i as u128 && v == i as SetId));
    }

    #[test]
    fn add_batch_equals_scalar_add_loop() {
        // block remainders (len % PROBE_WIDTH ≠ 0), shared keys, and a
        // 4-ary store all must match the scalar oracle exactly
        for arity in [3usize, 4] {
            let data: Vec<NTuple> = (0..203u32)
                .map(|i| {
                    let e = [i % 5, i % 3, i % 7, i % 2];
                    NTuple::new(&e[..arity])
                })
                .collect();
            let mut seq = PrimeStore::new(arity);
            let seq_ids: Vec<SetIds> = data.iter().map(|t| seq.add(t)).collect();
            let mut bat = PrimeStore::new(arity);
            let bat_ids = bat.add_batch(&data);
            assert_eq!(bat_ids, seq_ids, "arity {arity}");
            assert_eq!(bat.total_keys(), seq.total_keys());
            assert_eq!(bat.cumuli(), seq.cumuli(), "arity {arity}");
        }
    }

    #[test]
    fn par_add_batch_equals_sequential_small() {
        let data: Vec<NTuple> = (0..300u32)
            .map(|i| NTuple::triple(i % 5, i % 3, i % 7))
            .collect();
        let mut seq = PrimeStore::new(3);
        let seq_ids: Vec<SetIds> = data.iter().map(|t| seq.add(t)).collect();
        for workers in [2, 3, 4] {
            for chunk in [1, 7, 64, 300] {
                let mut par = PrimeStore::new(3);
                let par_ids = par.par_add_batch_chunked(&data, workers, chunk);
                assert_eq!(par_ids, seq_ids, "w={workers} c={chunk}");
                assert_eq!(par.total_keys(), seq.total_keys());
                assert_eq!(par.arena.len(), seq.arena.len());
                for id in 0..seq.arena.len() {
                    assert_eq!(
                        par.arena.materialize(id as SetId),
                        seq.arena.materialize(id as SetId),
                        "set {id} w={workers} c={chunk}"
                    );
                }
            }
        }
    }

    #[test]
    fn par_add_batch_general_arity_falls_back() {
        // arity 6 uses SubRelation keys: parallel ingest degrades to the
        // sequential path but must stay correct
        let data: Vec<NTuple> = (0..64u32)
            .map(|i| NTuple::new(&[i % 2, i % 3, i % 2, i % 3, i % 2, i % 3]))
            .collect();
        let mut seq = PrimeStore::new(6);
        let seq_ids: Vec<SetIds> = data.iter().map(|t| seq.add(t)).collect();
        let mut par = PrimeStore::new(6);
        let par_ids = par.par_add_batch_chunked(&data, 4, 8);
        assert_eq!(par_ids, seq_ids);
        assert_eq!(par.total_keys(), seq.total_keys());
    }

    #[test]
    fn cumuli_export_reconstructs_subrelations() {
        let mut ps = PrimeStore::new(3);
        let data = [
            NTuple::triple(0, 0, 0),
            NTuple::triple(0, 1, 0),
            NTuple::triple(2, 1, 0),
        ];
        for t in &data {
            ps.add(t);
        }
        let cumuli = ps.cumuli();
        assert_eq!(cumuli.len(), ps.total_keys());
        // every exported key must resolve back through `get` to a set
        // with exactly the exported contents
        for (sub, contents) in &cumuli {
            let id = ps.get(sub).expect("exported key resolves");
            assert_eq!(&ps.arena.materialize(id), contents);
        }
        // and the cumulus of the shared dropped-2 key (0,*,0)... spot-check
        let sub = NTuple::triple(0, 1, 0).subrelation(0);
        let (_, c) = cumuli.iter().find(|(s, _)| *s == sub).expect("key present");
        assert_eq!(*c, vec![0, 2]);
    }

    #[test]
    fn spill_budget_preserves_contents() {
        // A 4-page budget over 32 sets of 3 pages each forces heavy
        // spilling; every set must still materialise bit-identically to
        // an unbudgeted arena.
        let mut tight = SetArena::default();
        tight.set_resident_budget(4, None);
        let mut roomy = SetArena::default();
        let n_sets = 32usize;
        let per_set = 3 * PAGE as u32;
        for s in 0..n_sets {
            tight.alloc();
            roomy.alloc();
            for v in 0..per_set {
                // earlier sets go cold as later ones fill — LRU victims
                let val = (s as u32 * 7 + v * 13) % 97;
                tight.push(s as SetId, val);
                roomy.push(s as SetId, val);
            }
        }
        assert!(
            tight.pages() <= 4 + 3, // budget + at most one chain in flight
            "budgeted arena grew to {} pages",
            tight.pages()
        );
        for s in 0..n_sets {
            assert_eq!(
                tight.materialize(s as SetId),
                roomy.materialize(s as SetId),
                "set {s} diverged under spill"
            );
        }
        // sealing folds spilled runs back in and clears them
        tight.ensure_sorted_all();
        for s in 0..n_sets {
            assert_eq!(
                tight.materialize(s as SetId),
                roomy.materialize(s as SetId),
                "set {s} diverged after seal"
            );
        }
    }

    #[test]
    fn spilled_ingest_equals_unbudgeted_store() {
        let mut tight = PrimeStore::new(3);
        tight.set_resident_budget(8, None);
        let mut roomy = PrimeStore::new(3);
        for i in 0..400u32 {
            let t = NTuple::triple(i % 23, (i / 3) % 17, i % 11);
            tight.add(&t);
            roomy.add(&t);
        }
        assert_eq!(tight.cumuli(), roomy.cumuli());
    }

    #[test]
    fn adopt_rebuilds_equivalent_store() {
        let mut live = PrimeStore::new(3);
        for i in 0..200u32 {
            live.add(&NTuple::triple(i % 13, (i / 2) % 7, i % 5));
        }
        let exported = live.cumuli();
        let mut adopted = PrimeStore::adopt(3, exported.clone());
        assert_eq!(adopted.arity(), 3);
        assert_eq!(adopted.total_keys(), live.total_keys());
        assert_eq!(adopted.cumuli(), exported);
        // probe resolves every historical tuple without mutating
        let keys_before = adopted.total_keys();
        for i in 0..200u32 {
            let t = NTuple::triple(i % 13, (i / 2) % 7, i % 5);
            let ids = adopted.probe(&t).expect("historical tuple resolves");
            assert_eq!(ids.len(), 3);
        }
        assert_eq!(adopted.total_keys(), keys_before);
        // a never-ingested tuple probes to None
        assert!(adopted.probe(&NTuple::triple(99, 99, 99)).is_none());
    }

    #[test]
    fn adopt_general_arity_roundtrip() {
        let mut live = PrimeStore::new(MAX_ARITY);
        for i in 0..60u32 {
            let t = NTuple::new(&[i % 5, i % 4, i % 3, i % 2, i % 7, i % 6]);
            live.add(&t);
        }
        let exported = live.cumuli();
        let mut adopted = PrimeStore::adopt(MAX_ARITY, exported.clone());
        assert_eq!(adopted.cumuli(), exported);
    }
}
