//! The `ClusterSim` backend: an N-node cluster simulator behind the
//! [`Backend`] trait — the repo's first engine where *distribution
//! itself* (task placement, stragglers, speculative execution) is a
//! first-class, testable variable.
//!
//! Every map/reduce phase executes its task closures for real (on
//! [`crate::util::pool`], so outputs are exact and input-order
//! preserving) while a deterministic discrete-event simulation replays
//! the tasks onto `nodes × slots_per_node` simulated worker slots:
//!
//! * **Placement** — a pluggable [`Placement`] policy (round-robin,
//!   locality-aware by shuffle-key partition, least-loaded greedy list
//!   scheduling) picks the node for each task.
//! * **Stragglers / heterogeneity** — per-task slowdown draws and
//!   optional per-node slowdown spread stretch simulated durations.
//! * **Failures** — a failed first attempt wastes half its duration on
//!   its node, then is rescheduled on the least-loaded node (retries
//!   never fail again, so `failure_prob = 1.0` stays terminating).
//! * **Speculation** — a task whose projected duration exceeds
//!   `speculation_factor ×` the running median of realized task
//!   durations gets a duplicate attempt on the least-loaded *other*
//!   slot; the attempts race, first result wins, the loser is cancelled
//!   (its slot is released at the winner's finish time) and only the
//!   winner's output is delivered — duplicate results are deduplicated
//!   by task id, so the backend-equivalence invariant holds under any
//!   fault/straggler schedule. The running median is primed with the
//!   median *estimated* cost of the phase's tasks (a JobTracker knows
//!   its input-split sizes), so even the first scheduled task can be
//!   rescued; backup attempts do not re-draw the straggler fate — the
//!   detector just excluded that cause, and this is what makes
//!   node-count sweeps monotone under any straggler schedule.
//! * **Adaptive task counts** — each phase picks its task count from
//!   the input size and the previous phase's measured cost skew
//!   ([`super::placement::adaptive_task_count`]), threading granularity
//!   through `exec::stages` without the stage functions knowing.
//!
//! * **Shuffle cost** ([`ShuffleModel`]) — every phase records where its
//!   output records landed (the winning attempt's node, sizes MEASURED
//!   per task); the next phase's tasks then pay `bytes moved × per-byte
//!   latency` for the fraction of their input that is NOT already on
//!   their node. The first phase reads node-local input splits and pays
//!   nothing (data-local map scheduling). Every attempt fetches — a
//!   failed or speculative attempt re-fetches its input, exactly like a
//!   re-executed Hadoop task. Xu et al.'s iterative-MapReduce FCA
//!   measurements (PAPERS.md) are the motivation: at scale the shuffle
//!   volume, not the compute, dominates — with the model off (the
//!   default) the simulation reduces bit-exactly to the PR 3 behaviour.
//! * **Node churn** ([`ChurnConfig`]) — per phase, each node draws a
//!   seeded kill fate; a killed node goes down at a deterministic
//!   mid-phase instant and restarts `restart_ms` later. An attempt whose
//!   execution window crosses its node's kill instant is killed (work
//!   lost, like a failure), then rescheduled on the earliest slot of
//!   another node; an attempt is churn-killed at most once — later
//!   retries and speculative backups ride out downtime windows by
//!   waiting for the restart. Churn draws come from a SEPARATE salted
//!   RNG stream, so enabling churn never perturbs the straggler/failure
//!   schedule.
//!
//! All randomness comes from a seeded [`crate::util::rng::Rng`] with a
//! fixed number of draws per task in task-index order, so for a FIXED
//! task count the straggler/failure schedule is identical across node
//! counts and placement policies. Note that adaptive task counts (the
//! default) derive granularity from `nodes × slots`, which changes the
//! task set itself across node counts — sweeps that must be comparable
//! point to point pin the task count and disable adaptivity, as
//! `benches/cluster_scaling.rs` (`BENCH_cluster.json`) does.
//! With a [`CostModel::PerRecord`] cost model the whole simulation is
//! bit-deterministic machine to machine; with [`CostModel::Measured`]
//! task costs are real wall times (the schedule structure still only
//! depends on the seed).
//!
//! The shuffle between phases is modelled as a barrier: every slot
//! advances to the phase makespan before the next phase schedules
//! (Hadoop's map→reduce barrier). Grouping compute is charged zero
//! simulated time; the DATA MOTION of the shuffle is charged to the
//! consuming task via the [`ShuffleModel`] above (zero when off, so
//! speedup curves can still isolate compute distribution).

use std::sync::Mutex;

use anyhow::Result;

use super::backend::{group_pairs, Backend, Data, Key};
use super::placement::{adaptive_task_count, NodeView, Placement, TaskMeta};
use crate::util::hash::fxhash;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

/// How a task's simulated base cost is derived.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CostModel {
    /// Real wall time of the task closure on this machine.
    Measured,
    /// `records × ms` — machine-independent, bit-deterministic; used by
    /// the scaling bench and the CI baseline check.
    PerRecord(f64),
}

/// The shuffle-cost model: `bytes moved × per-byte latency` between
/// non-colocated producer and consumer tasks. Record counts are MEASURED
/// per task (a JobTracker reads its map-output index files); the byte
/// size per record and the network latency are configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShuffleModel {
    /// Estimated wire size of one shuffled record, bytes.
    pub bytes_per_record: f64,
    /// Transfer latency per MiB moved between two DIFFERENT nodes, ms
    /// (intra-node exchange is free). 0.0 disables the model.
    pub ms_per_mib: f64,
}

impl ShuffleModel {
    /// Network cost disabled — the PR 3 compute-only simulation.
    pub fn off() -> Self {
        Self { bytes_per_record: 0.0, ms_per_mib: 0.0 }
    }

    /// True when moving bytes costs simulated time.
    pub fn is_active(&self) -> bool {
        self.ms_per_mib > 0.0 && self.bytes_per_record > 0.0
    }

    /// MiB on the wire for `records` records.
    pub fn mib(&self, records: usize) -> f64 {
        records as f64 * self.bytes_per_record / (1u64 << 20) as f64
    }
}

impl Default for ShuffleModel {
    fn default() -> Self {
        Self::off()
    }
}

/// Seeded node churn: kill/restart mid-phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnConfig {
    /// Per-phase probability that EACH node is killed during the phase.
    pub kill_prob: f64,
    /// Downtime before a killed node's slots accept work again, ms.
    pub restart_ms: f64,
}

impl ChurnConfig {
    /// No churn (the default).
    pub fn off() -> Self {
        Self { kill_prob: 0.0, restart_ms: 0.0 }
    }

    /// True when nodes can die.
    pub fn is_active(&self) -> bool {
        self.kill_prob > 0.0
    }
}

impl Default for ChurnConfig {
    fn default() -> Self {
        Self::off()
    }
}

/// Tuning for the simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Simulated nodes.
    pub nodes: usize,
    /// Worker slots per node (a node's local pool).
    pub slots_per_node: usize,
    /// Probability a task attempt straggles (duration × `straggler_factor`).
    pub straggler_prob: f64,
    /// Slowdown multiplier for a straggling attempt.
    pub straggler_factor: f64,
    /// Probability the FIRST attempt of a task fails mid-flight.
    pub failure_prob: f64,
    /// Launch speculative duplicates for detected stragglers.
    pub speculation: bool,
    /// Straggler detection threshold: projected duration vs running
    /// median of realized task durations.
    pub speculation_factor: f64,
    /// Per-node heterogeneity: node `i` runs at `1 + spread·i/(nodes-1)`
    /// slowdown (0.0 = homogeneous, keeps node-count sweeps monotone).
    pub node_slowdown_spread: f64,
    /// Simulated cost of a task.
    pub cost: CostModel,
    /// Fixed task count per phase when `adaptive_tasks` is off.
    pub tasks: usize,
    /// Pick per-phase task counts from input size + previous skew.
    pub adaptive_tasks: bool,
    /// Network cost of moving shuffled bytes between nodes.
    pub shuffle: ShuffleModel,
    /// Seeded node kill/restart mid-phase.
    pub churn: ChurnConfig,
    /// REAL executor threads that run the task closures.
    pub workers: usize,
    /// Seed for the straggler/failure/churn schedules.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        let workers = pool::default_workers();
        Self {
            nodes: 4,
            slots_per_node: 2,
            straggler_prob: 0.0,
            straggler_factor: 6.0,
            failure_prob: 0.0,
            speculation: true,
            speculation_factor: 1.5,
            node_slowdown_spread: 0.0,
            cost: CostModel::Measured,
            tasks: 16,
            adaptive_tasks: true,
            shuffle: ShuffleModel::off(),
            churn: ChurnConfig::off(),
            workers,
            seed: 0x5EED,
        }
    }
}

/// Per-phase simulation outcome, drained via [`ClusterSim::take_stats`].
#[derive(Debug, Clone)]
pub struct ClusterStats {
    /// Phase label (`s1-map`, `s3-reduce`, ...).
    pub label: String,
    /// Tasks the phase was split into.
    pub tasks: usize,
    /// Records processed by the phase.
    pub records: usize,
    /// Simulated phase makespan (barrier to barrier), ms.
    pub sim_phase_ms: f64,
    /// max/mean of base task costs — fed to the next phase's adaptive
    /// task count.
    pub skew: f64,
    /// Attempts that drew the straggler slowdown.
    pub stragglers: usize,
    /// Speculative duplicates launched.
    pub spec_launched: usize,
    /// Speculative duplicates that won their race.
    pub spec_wins: usize,
    /// First attempts that failed and were rescheduled.
    pub failures: usize,
    /// Shuffled MiB fetched from remote nodes this phase (every
    /// attempt's fetch counts — retries and backups re-fetch).
    pub shuffle_mib: f64,
    /// Attempts killed by node churn this phase.
    pub churn_kills: usize,
}

/// One task entering the simulator.
struct SimTask {
    /// Locality key (input-split index or key-hash partition).
    partition: u64,
    /// Base cost before node slowdown / straggler multipliers, ms.
    base_ms: f64,
    /// Input records — sized against the previous phase's output for the
    /// shuffle-cost model.
    records: usize,
    /// Output records — where they land feeds the NEXT phase's shuffle.
    out_records: usize,
}

/// Simulation state carried across phases (the cluster's clock).
struct SimState {
    /// Accumulated simulated makespan over all phases so far, ms.
    makespan_ms: f64,
    /// Previous phase's measured skew (max/mean of base task costs).
    prev_skew: f64,
    /// Previous phase's output records per node (the winning attempt's
    /// node) — the data layout the next phase shuffles against. Empty
    /// before the first phase: input splits are node-local.
    prev_out: Vec<f64>,
    /// Phase counter — salts the per-phase RNG stream.
    round: u64,
    stats: Vec<ClusterStats>,
}

/// The simulated-cluster backend (fifth entry of [`super::BACKENDS`]).
pub struct ClusterSim {
    cfg: ClusterConfig,
    placement: Box<dyn Placement>,
    state: Mutex<SimState>,
}

/// Insert into an ascending-sorted vec (running-median bookkeeping).
fn insert_sorted(xs: &mut Vec<f64>, x: f64) {
    let at = xs.partition_point(|&y| y < x);
    xs.insert(at, x);
}

fn median_sorted(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        None
    } else {
        Some(xs[xs.len() / 2])
    }
}

impl ClusterSim {
    /// Simulator over `cfg` with the given placement policy.
    pub fn new(cfg: ClusterConfig, placement: Box<dyn Placement>) -> Self {
        Self {
            cfg,
            placement,
            state: Mutex::new(SimState {
                makespan_ms: 0.0,
                prev_skew: 1.0,
                prev_out: Vec::new(),
                round: 0,
                stats: Vec::new(),
            }),
        }
    }

    /// Default-tuned homogeneous 4-node cluster with least-loaded
    /// placement.
    pub fn with_defaults() -> Self {
        Self::new(ClusterConfig::default(), Box::new(super::placement::LeastLoaded))
    }

    /// The configuration this simulator runs under.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Total simulated makespan accumulated since construction, ms.
    pub fn sim_makespan_ms(&self) -> f64 {
        self.state.lock().unwrap().makespan_ms
    }

    /// Drain per-phase stats collected so far, in phase order.
    pub fn take_stats(&self) -> Vec<ClusterStats> {
        std::mem::take(&mut self.state.lock().unwrap().stats)
    }

    /// Task count for a phase over `items` input items.
    fn task_count(&self, items: usize, prev_skew: f64) -> usize {
        if self.cfg.adaptive_tasks {
            adaptive_task_count(
                items,
                self.cfg.nodes.max(1) * self.cfg.slots_per_node.max(1),
                prev_skew,
            )
        } else {
            self.cfg.tasks.clamp(1, items.max(1))
        }
    }

    /// Simulated slowdown of node `i` (1.0 when homogeneous).
    fn node_slowdown(&self, i: usize) -> f64 {
        if self.cfg.nodes <= 1 || self.cfg.node_slowdown_spread <= 0.0 {
            1.0
        } else {
            1.0 + self.cfg.node_slowdown_spread * i as f64 / (self.cfg.nodes - 1) as f64
        }
    }

    /// Replay `tasks` onto the simulated cluster: placement, stragglers,
    /// failures, node churn, shuffle fetches, speculation,
    /// first-result-wins. Advances the global clock by the phase
    /// makespan (barrier semantics) and records a [`ClusterStats`]
    /// entry.
    fn simulate_phase(&self, label: &str, tasks: &[SimTask]) {
        let nodes = self.cfg.nodes.max(1);
        let slots = self.cfg.slots_per_node.max(1);
        let mut state = self.state.lock().unwrap();
        state.round += 1;
        let round = state.round;
        // where the PREVIOUS phase's output landed: the data layout this
        // phase's tasks fetch their input against
        let prev_out = std::mem::take(&mut state.prev_out);
        let mut stats = ClusterStats {
            label: label.to_string(),
            tasks: tasks.len(),
            records: 0,
            sim_phase_ms: 0.0,
            skew: 1.0,
            stragglers: 0,
            spec_launched: 0,
            spec_wins: 0,
            failures: 0,
            shuffle_mib: 0.0,
            churn_kills: 0,
        };
        if tasks.is_empty() {
            state.prev_out = vec![0.0; nodes];
            state.stats.push(stats);
            return;
        }
        // per-phase RNG with a FIXED number of draws per task in task
        // order, so the schedule is identical across node counts and
        // placement policies
        let mut rng =
            Rng::new(self.cfg.seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        // churn fates draw from a SEPARATE salted stream (two draws per
        // node, phase order), so enabling churn never perturbs the
        // per-task straggler/failure schedule above
        let windows: Vec<Option<(f64, f64)>> = if self.cfg.churn.is_active() {
            let mut crng = Rng::new(
                self.cfg.seed
                    ^ round.wrapping_mul(0xA24B_AED4_963E_E407)
                    ^ 0x4348_5552_4E21,
            );
            let est_total: f64 = tasks.iter().map(|t| t.base_ms).sum();
            let est_span = (est_total / (nodes * slots) as f64).max(1e-6);
            (0..nodes)
                .map(|_| {
                    let kill = crng.chance(self.cfg.churn.kill_prob);
                    let frac = crng.f64();
                    if kill {
                        let at = frac * est_span;
                        Some((at, at + self.cfg.churn.restart_ms.max(0.0)))
                    } else {
                        None
                    }
                })
                .collect()
        } else {
            vec![None; nodes]
        };
        // push a start time out of a node's downtime window
        let delay_past_window = |node: usize, t: f64| -> f64 {
            match windows[node] {
                Some((kill, up)) if t >= kill && t < up => up,
                _ => t,
            }
        };
        // the kill instant, when running [start, start+dur] on `node`
        // crosses it
        let crossing_kill = |node: usize, start: f64, dur: f64| -> Option<(f64, f64)> {
            windows[node].filter(|&(kill, _)| start < kill && start + dur > kill)
        };
        // fraction of a task's input NOT already on `node` (0 when the
        // shuffle model is off or this is the first phase: map tasks
        // read node-local splits)
        let shuffle = self.cfg.shuffle;
        let prev_total: f64 = prev_out.iter().sum();
        let remote_frac = |node: usize| -> f64 {
            if !shuffle.is_active() || prev_total <= 0.0 {
                0.0
            } else {
                1.0 - prev_out.get(node).copied().unwrap_or(0.0) / prev_total
            }
        };
        // lane[node][slot] = simulated time the slot frees up (phase-local)
        let mut lanes: Vec<Vec<f64>> = vec![vec![0.0; slots]; nodes];
        let mut busy: Vec<f64> = vec![0.0; nodes];
        // running median of task durations, primed with the median
        // ESTIMATED cost so detection works from the very first task
        let mut realized: Vec<f64> = Vec::with_capacity(tasks.len() + 1);
        {
            let mut est: Vec<f64> = tasks.iter().map(|t| t.base_ms).collect();
            est.sort_by(|a, b| a.partial_cmp(b).unwrap());
            realized.push(est[est.len() / 2]);
        }
        let mut phase_end = 0.0f64;

        let views = |lanes: &[Vec<f64>], busy: &[f64]| -> Vec<NodeView> {
            lanes
                .iter()
                .enumerate()
                .map(|(id, ls)| NodeView {
                    id,
                    free_at_ms: ls.iter().cloned().fold(f64::INFINITY, f64::min),
                    busy_ms: busy[id],
                })
                .collect()
        };
        // earliest slot overall, optionally excluding one (node, slot)
        let earliest_slot = |lanes: &[Vec<f64>],
                             exclude: Option<(usize, usize)>|
         -> Option<(usize, usize, f64)> {
            let mut best: Option<(usize, usize, f64)> = None;
            for (n, ls) in lanes.iter().enumerate() {
                for (s, &free) in ls.iter().enumerate() {
                    if exclude == Some((n, s)) {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some((_, _, b)) => free < b,
                    };
                    if better {
                        best = Some((n, s, free));
                    }
                }
            }
            best
        };

        // where each task's output lands (the winning attempt's node) —
        // becomes `prev_out` for the next phase's shuffle accounting
        let mut out_node: Vec<f64> = vec![0.0; nodes];
        for (i, task) in tasks.iter().enumerate() {
            // fixed draw schedule: 3 draws per task in task order,
            // branch-independent — so the straggler/failure fates are
            // identical across node counts and placement policies
            let straggle1 = rng.chance(self.cfg.straggler_prob);
            let fail = rng.chance(self.cfg.failure_prob);
            let straggle2 = rng.chance(self.cfg.straggler_prob);

            let meta = TaskMeta::new(i, task.partition, task.base_ms);
            let node = self.placement.place(&meta, &views(&lanes, &busy)).min(nodes - 1);
            let slot = (0..slots)
                .min_by(|&a, &b| lanes[node][a].partial_cmp(&lanes[node][b]).unwrap())
                .unwrap();
            let mut start = delay_past_window(node, lanes[node][slot]);
            let mult1 = if straggle1 { self.cfg.straggler_factor } else { 1.0 };
            let mut active = (node, slot);
            let mut attempt_mult = mult1;
            let fetch = shuffle.mib(task.records) * remote_frac(node);
            stats.shuffle_mib += fetch;
            let mut dur =
                task.base_ms * self.node_slowdown(node) * mult1 + fetch * shuffle.ms_per_mib;
            if straggle1 {
                stats.stragglers += 1;
            }
            let first_attempt_start = start;
            if fail {
                // first attempt dies halfway; its slot is released then,
                // and the retry goes to the earliest slot anywhere
                // (re-fetching its shuffled input)
                stats.failures += 1;
                let abort = start + 0.5 * dur;
                lanes[node][slot] = abort;
                busy[node] += 0.5 * dur;
                let (rn, rs, free) =
                    earliest_slot(&lanes, None).expect("cluster has slots");
                let mult_r = if straggle2 { self.cfg.straggler_factor } else { 1.0 };
                if straggle2 {
                    stats.stragglers += 1;
                }
                active = (rn, rs);
                attempt_mult = mult_r;
                start = delay_past_window(rn, abort.max(free));
                let fetch_r = shuffle.mib(task.records) * remote_frac(rn);
                stats.shuffle_mib += fetch_r;
                dur = task.base_ms * self.node_slowdown(rn) * mult_r
                    + fetch_r * shuffle.ms_per_mib;
            }
            // node churn: an attempt whose execution window crosses its
            // node's kill instant dies there (work lost), keeps its
            // straggler fate, and is rescheduled on the earliest slot of
            // another node; an attempt is churn-killed at most once —
            // later downtime windows only delay it
            if let Some((kill_at, _)) = crossing_kill(active.0, start, dur) {
                stats.churn_kills += 1;
                busy[active.0] += (kill_at - start).max(0.0);
                let up = windows[active.0].expect("crossing implies a window").1;
                lanes[active.0][active.1] = up;
                let mut best: Option<(usize, usize, f64)> = None;
                for (n, ls) in lanes.iter().enumerate() {
                    if n == active.0 && nodes > 1 {
                        continue; // prefer a surviving node
                    }
                    for (s, &free) in ls.iter().enumerate() {
                        let better = match best {
                            None => true,
                            Some((_, _, b)) => free < b,
                        };
                        if better {
                            best = Some((n, s, free));
                        }
                    }
                }
                let (rn, rs, free) = best.expect("cluster has slots");
                active = (rn, rs);
                start = delay_past_window(rn, kill_at.max(free));
                let fetch_c = shuffle.mib(task.records) * remote_frac(rn);
                stats.shuffle_mib += fetch_c;
                dur = task.base_ms * self.node_slowdown(rn) * attempt_mult
                    + fetch_c * shuffle.ms_per_mib;
                if let Some((_, up_r)) = crossing_kill(rn, start, dur) {
                    start = up_r; // ride out the downtime
                }
            }
            let finish = start + dur;
            // straggler detection: projected duration vs the running
            // median of realized durations (scheduling order stands in
            // for completion order at this simulation granularity)
            let mut completion = finish;
            let mut winner_node = active.0;
            let median = median_sorted(&realized);
            let backup = if self.cfg.speculation {
                median.filter(|&m| m > 0.0 && dur > self.cfg.speculation_factor * m)
            } else {
                None
            };
            if let Some(m) = backup {
                if let Some((bn, bs, bfree)) = earliest_slot(&lanes, Some(active)) {
                    stats.spec_launched += 1;
                    let detect = start + self.cfg.speculation_factor * m;
                    let mut bstart = delay_past_window(bn, detect.max(bfree));
                    // backups never re-draw the straggler fate (the
                    // detector just excluded that cause) and are never
                    // churn-killed — they wait out downtime windows
                    let bfetch = shuffle.mib(task.records) * remote_frac(bn);
                    let bdur =
                        task.base_ms * self.node_slowdown(bn) + bfetch * shuffle.ms_per_mib;
                    if let Some((_, up_b)) = crossing_kill(bn, bstart, bdur) {
                        bstart = up_b;
                    }
                    let bfinish = bstart + bdur;
                    completion = finish.min(bfinish);
                    if bfinish < finish {
                        // backup wins: original attempt cancelled at the
                        // winner's finish — first-result-wins, the
                        // loser's (identical) output is dropped
                        stats.spec_wins += 1;
                        stats.shuffle_mib += bfetch;
                        winner_node = bn;
                        lanes[active.0][active.1] = completion;
                        busy[active.0] += completion - start;
                        lanes[bn][bs] = bfinish;
                        busy[bn] += bdur;
                    } else {
                        // original wins: backup cancelled at the winner's
                        // finish — or never started at all, leaving its
                        // slot untouched
                        lanes[active.0][active.1] = finish;
                        busy[active.0] += dur;
                        let bused = (completion - bstart).max(0.0);
                        if bused > 0.0 {
                            stats.shuffle_mib += bfetch;
                            lanes[bn][bs] = bstart + bused;
                            busy[bn] += bused;
                        }
                    }
                } else {
                    lanes[active.0][active.1] = finish;
                    busy[active.0] += dur;
                }
            } else {
                lanes[active.0][active.1] = finish;
                busy[active.0] += dur;
            }
            out_node[winner_node] += task.out_records as f64;
            insert_sorted(&mut realized, completion - first_attempt_start);
            phase_end = phase_end.max(completion);
        }

        let total: f64 = tasks.iter().map(|t| t.base_ms).sum();
        let max = tasks.iter().map(|t| t.base_ms).fold(0.0, f64::max);
        let mean = total / tasks.len() as f64;
        stats.skew = if mean > 0.0 { max / mean } else { 1.0 };
        stats.sim_phase_ms = phase_end;
        // materialised view: the telemetry plane receives the SAME
        // per-phase figures the drained [`ClusterStats`] carry (the
        // struct stays the programmatic API and works with obs off;
        // `--metrics-out` sees the simulation without a second ledger)
        if crate::obs::enabled() {
            use crate::obs::{counter, gauge, observe};
            counter("exec.cluster.phases", 1);
            counter("exec.cluster.tasks", stats.tasks as u64);
            counter("exec.cluster.stragglers", stats.stragglers as u64);
            counter("exec.cluster.spec_launched", stats.spec_launched as u64);
            counter("exec.cluster.spec_wins", stats.spec_wins as u64);
            counter("exec.cluster.failures", stats.failures as u64);
            counter("exec.cluster.churn_kills", stats.churn_kills as u64);
            counter(
                "exec.cluster.shuffle_kib",
                (stats.shuffle_mib * 1024.0).round() as u64,
            );
            observe("exec.cluster.phase_sim_ms", stats.sim_phase_ms.round() as u64);
            gauge("exec.cluster.sim_makespan_ms", state.makespan_ms + phase_end);
            gauge("exec.cluster.phase_skew", stats.skew);
            for (n, &recs) in out_node.iter().enumerate() {
                if recs > 0.0 {
                    counter(&format!("exec.cluster.node{n}.out_records"), recs as u64);
                }
            }
        }
        state.prev_skew = stats.skew;
        state.prev_out = out_node;
        state.makespan_ms += phase_end; // barrier: next phase starts here
        state.stats.push(stats);
    }

    fn prev_skew(&self) -> f64 {
        self.state.lock().unwrap().prev_skew
    }

    /// Attach record counts to the latest stats entry (executed outside
    /// the simulate lock).
    fn note_records(&self, records: usize) {
        if let Some(last) = self.state.lock().unwrap().stats.last_mut() {
            last.records = records;
        }
    }

    fn base_cost(&self, measured_ms: f64, records: usize) -> f64 {
        match self.cfg.cost {
            CostModel::Measured => measured_ms.max(1e-6),
            CostModel::PerRecord(ms) => (records as f64 * ms).max(1e-6),
        }
    }
}

impl Backend for ClusterSim {
    fn name(&self) -> &'static str {
        "cluster"
    }

    /// Map phase: input split into adaptively-many tasks, each executed
    /// for real (outputs concatenated in split order — input order is
    /// preserved) and replayed onto the simulated cluster. A map task's
    /// locality key is its input-split index.
    fn map_partitions<I, O, F>(&self, label: &str, input: Vec<I>, f: F) -> Result<Vec<O>>
    where
        I: Data,
        O: Data,
        F: Fn(&I) -> Vec<O> + Sync,
    {
        let n = input.len();
        if n == 0 {
            self.simulate_phase(label, &[]);
            return Ok(Vec::new());
        }
        let t_count = self.task_count(n, self.prev_skew());
        let per = n.div_ceil(t_count).max(1);
        let splits: Vec<&[I]> = input.chunks(per).collect();
        let outs: Vec<(Vec<O>, f64)> =
            pool::parallel_map(splits.len(), self.cfg.workers, 1, |t| {
                let mut tspan = crate::span!("exec.cluster.{label}.task");
                tspan.records_in(splits[t].len() as u64);
                let timer = Timer::start();
                let mut out = Vec::new();
                for item in splits[t] {
                    out.extend(f(item));
                }
                let ms = timer.elapsed_ms();
                tspan.records_out(out.len() as u64);
                (out, ms)
            });
        let tasks: Vec<SimTask> = outs
            .iter()
            .enumerate()
            .map(|(t, (out, ms))| SimTask {
                partition: t as u64,
                base_ms: self.base_cost(*ms, splits[t].len()),
                records: splits[t].len(),
                out_records: out.len(),
            })
            .collect();
        self.simulate_phase(label, &tasks);
        self.note_records(n);
        Ok(outs.into_iter().flat_map(|(o, _)| o).collect())
    }

    /// The shuffle: deterministic in-memory grouping (sorted by key).
    /// Simulated as a barrier — grouping COMPUTE is charged zero
    /// simulated time; the data motion is charged to the consuming
    /// phase's tasks by the [`ShuffleModel`] (zero when off, so
    /// node-count sweeps can isolate compute distribution).
    fn group_by_key<K, V>(&self, _label: &str, pairs: Vec<(K, V)>) -> Result<Vec<(K, Vec<V>)>>
    where
        K: Key,
        V: Data,
    {
        Ok(group_pairs(pairs))
    }

    /// Reduce phase: groups chunked into tasks; a reduce task's locality
    /// key is the hash partition of its first key (so locality-aware
    /// placement co-locates a partition's reduce work).
    fn reduce<K, V, O, F>(&self, label: &str, groups: Vec<(K, Vec<V>)>, f: F) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        F: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let n = groups.len();
        if n == 0 {
            self.simulate_phase(label, &[]);
            return Ok(Vec::new());
        }
        let t_count = self.task_count(n, self.prev_skew());
        let per = n.div_ceil(t_count).max(1);
        let mut buckets: Vec<Vec<(K, Vec<V>)>> = Vec::with_capacity(t_count);
        let mut metas: Vec<(u64, usize)> = Vec::with_capacity(t_count); // (partition, records)
        let mut it = groups.into_iter();
        loop {
            let chunk: Vec<(K, Vec<V>)> = it.by_ref().take(per).collect();
            if chunk.is_empty() {
                break;
            }
            let partition = fxhash(&chunk[0].0);
            let records = chunk.iter().map(|(_, vs)| vs.len()).sum();
            metas.push((partition, records));
            buckets.push(chunk);
        }
        // hand each task exclusive ownership of its bucket
        let slots: Vec<Mutex<Option<Vec<(K, Vec<V>)>>>> =
            buckets.into_iter().map(|b| Mutex::new(Some(b))).collect();
        let outs: Vec<(Vec<O>, f64)> =
            pool::parallel_map(slots.len(), self.cfg.workers, 1, |t| {
                let bucket = slots[t].lock().unwrap().take().expect("taken once");
                let mut tspan = crate::span!("exec.cluster.{label}.task");
                tspan.records_in(bucket.iter().map(|(_, vs)| vs.len() as u64).sum());
                let timer = Timer::start();
                let mut out = Vec::new();
                for (k, vs) in bucket {
                    out.extend(f(&k, vs));
                }
                let ms = timer.elapsed_ms();
                tspan.records_out(out.len() as u64);
                (out, ms)
            });
        let total_records: usize = metas.iter().map(|&(_, r)| r).sum();
        let tasks: Vec<SimTask> = outs
            .iter()
            .zip(&metas)
            .map(|((out, ms), &(partition, records))| SimTask {
                partition,
                base_ms: self.base_cost(*ms, records),
                records,
                out_records: out.len(),
            })
            .collect();
        self.simulate_phase(label, &tasks);
        self.note_records(total_records);
        Ok(outs.into_iter().flat_map(|(o, _)| o).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::no_combine;
    use super::super::placement::{by_name, LeastLoaded, LocalityAware, RoundRobin};
    use super::*;

    fn sim(cfg: ClusterConfig) -> ClusterSim {
        ClusterSim::new(cfg, Box::new(LeastLoaded))
    }

    fn deterministic_cfg() -> ClusterConfig {
        ClusterConfig {
            nodes: 4,
            workers: 2,
            cost: CostModel::PerRecord(0.01),
            seed: 7,
            ..ClusterConfig::default()
        }
    }

    fn word_count(backend: &ClusterSim) -> Vec<(String, u64)> {
        let input: Vec<String> =
            vec!["a b a".into(), "b c".into(), "a".into(), "c c b".into()];
        let mut out = backend
            .map_reduce(
                "wc",
                input,
                |line: &String| {
                    line.split_whitespace().map(|w| (w.to_string(), 1u64)).collect()
                },
                no_combine::<String, u64>(),
                |w: &String, ones: Vec<u64>| vec![(w.clone(), ones.iter().sum())],
            )
            .unwrap();
        out.sort();
        out
    }

    #[test]
    fn round_matches_wordcount_and_records_stats() {
        let backend = sim(deterministic_cfg());
        let out = word_count(&backend);
        assert_eq!(
            out,
            vec![("a".to_string(), 3), ("b".to_string(), 3), ("c".to_string(), 3)]
        );
        let stats = backend.take_stats();
        assert_eq!(stats.len(), 2, "map phase + reduce phase");
        assert!(stats.iter().all(|s| s.sim_phase_ms > 0.0));
        assert!(backend.sim_makespan_ms() > 0.0);
        assert!(backend.take_stats().is_empty(), "stats drained");
    }

    #[test]
    fn map_preserves_input_order() {
        let backend = sim(deterministic_cfg());
        let out: Vec<u32> = backend
            .map_partitions("x2", (0..500u32).collect(), |&x| vec![x * 2])
            .unwrap();
        assert_eq!(out, (0..500).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn per_record_simulation_is_deterministic() {
        let run = || {
            let backend = sim(ClusterConfig {
                straggler_prob: 0.3,
                failure_prob: 0.2,
                ..deterministic_cfg()
            });
            word_count(&backend);
            backend.sim_makespan_ms()
        };
        let a = run();
        assert!(a > 0.0);
        assert_eq!(a.to_bits(), run().to_bits(), "same seed, same makespan");
    }

    #[test]
    fn failures_and_stragglers_leave_output_unchanged() {
        let clean = word_count(&sim(deterministic_cfg()));
        let noisy_backend = sim(ClusterConfig {
            straggler_prob: 1.0,
            failure_prob: 1.0,
            ..deterministic_cfg()
        });
        assert_eq!(word_count(&noisy_backend), clean);
        let stats = noisy_backend.take_stats();
        assert!(stats.iter().any(|s| s.failures > 0), "failures injected");
        assert!(stats.iter().any(|s| s.stragglers > 0), "stragglers injected");
    }

    #[test]
    fn speculation_wins_races_and_shortens_makespan() {
        let heavy = |speculation| {
            let backend = sim(ClusterConfig {
                straggler_prob: 0.4,
                straggler_factor: 20.0,
                speculation,
                adaptive_tasks: false,
                tasks: 32,
                ..deterministic_cfg()
            });
            let out: Vec<u32> = backend
                .map_partitions("spec", (0..4096u32).collect(), |&x| vec![x])
                .unwrap();
            assert_eq!(out.len(), 4096);
            let stats = backend.take_stats();
            (backend.sim_makespan_ms(), stats)
        };
        let (with_spec, stats_on) = heavy(true);
        let (without, stats_off) = heavy(false);
        let launched: usize = stats_on.iter().map(|s| s.spec_launched).sum();
        let wins: usize = stats_on.iter().map(|s| s.spec_wins).sum();
        assert!(launched > 0, "stragglers must trigger speculation");
        assert!(wins > 0, "some backups must win the race");
        assert_eq!(
            stats_off.iter().map(|s| s.spec_launched).sum::<usize>(),
            0,
            "speculation off launches nothing"
        );
        assert!(
            with_spec < without,
            "speculation must cut the straggler tail: {with_spec} !< {without}"
        );
    }

    #[test]
    fn more_nodes_never_slow_the_simulated_cluster() {
        let makespan = |nodes| {
            let backend = sim(ClusterConfig {
                nodes,
                straggler_prob: 0.1,
                ..deterministic_cfg()
            });
            word_count(&backend);
            backend.sim_makespan_ms()
        };
        let mut prev = f64::INFINITY;
        for nodes in [1, 2, 4, 8] {
            let m = makespan(nodes);
            assert!(
                m <= prev * 1.001,
                "makespan must be monotone non-increasing: {m} at {nodes} nodes > {prev}"
            );
            prev = m;
        }
    }

    #[test]
    fn every_placement_policy_produces_identical_output() {
        let mk = |placement: Box<dyn crate::exec::placement::Placement>| {
            let backend = ClusterSim::new(
                ClusterConfig { straggler_prob: 0.2, ..deterministic_cfg() },
                placement,
            );
            word_count(&backend)
        };
        let reference = mk(Box::new(LeastLoaded));
        assert_eq!(mk(Box::new(RoundRobin)), reference);
        assert_eq!(mk(Box::new(LocalityAware)), reference);
        assert_eq!(mk(by_name("locality").unwrap()), reference);
    }

    #[test]
    fn shuffle_cost_charges_remote_fetches_only_after_the_first_phase() {
        let clean = word_count(&sim(deterministic_cfg()));
        let free_makespan = {
            let b = sim(deterministic_cfg());
            word_count(&b);
            b.sim_makespan_ms()
        };
        let backend = sim(ClusterConfig {
            shuffle: ShuffleModel { bytes_per_record: 65_536.0, ms_per_mib: 10.0 },
            ..deterministic_cfg()
        });
        assert_eq!(word_count(&backend), clean, "network cost never changes output");
        let stats = backend.take_stats();
        // the map phase reads node-local input splits: nothing fetched
        assert_eq!(stats[0].shuffle_mib, 0.0, "map phase is data-local");
        // the reduce phase fetches the map output it is not colocated with
        assert!(stats[1].shuffle_mib > 0.0, "reduce phase must fetch remotely");
        assert!(
            backend.sim_makespan_ms() > free_makespan,
            "moving bytes must cost simulated time"
        );
    }

    #[test]
    fn shuffle_simulation_is_bit_deterministic() {
        let run = || {
            let backend = sim(ClusterConfig {
                straggler_prob: 0.3,
                failure_prob: 0.2,
                shuffle: ShuffleModel { bytes_per_record: 4_096.0, ms_per_mib: 5.0 },
                ..deterministic_cfg()
            });
            word_count(&backend);
            backend.sim_makespan_ms()
        };
        assert_eq!(run().to_bits(), run().to_bits());
    }

    #[test]
    fn churn_kills_attempts_but_output_survives() {
        let clean = word_count(&sim(deterministic_cfg()));
        // single node + certain kill: the node's slots are busy
        // back-to-back from t=0, so the kill instant (drawn inside the
        // estimated span) always lands inside some attempt's window
        let run = || {
            let backend = sim(ClusterConfig {
                nodes: 1,
                churn: ChurnConfig { kill_prob: 1.0, restart_ms: 100.0 },
                ..deterministic_cfg()
            });
            let out = backend
                .map_partitions("churned", (0..4096u32).collect(), |&x| vec![x])
                .unwrap();
            let kills: usize =
                backend.take_stats().iter().map(|s| s.churn_kills).sum();
            (out, kills, backend.sim_makespan_ms())
        };
        let (out, kills, ms) = run();
        assert_eq!(out, (0..4096).collect::<Vec<_>>());
        assert!(kills > 0, "a certain kill on a saturated node must hit an attempt");
        assert_eq!(ms.to_bits(), run().2.to_bits(), "churn schedule is seeded");
        // multi-node churn with failures + stragglers still reproduces
        // the exact word count
        let noisy = sim(ClusterConfig {
            straggler_prob: 0.5,
            failure_prob: 0.5,
            churn: ChurnConfig { kill_prob: 0.7, restart_ms: 25.0 },
            ..deterministic_cfg()
        });
        assert_eq!(word_count(&noisy), clean);
    }

    #[test]
    fn churn_off_draws_nothing_and_costs_nothing() {
        let a = {
            let b = sim(ClusterConfig { straggler_prob: 0.3, ..deterministic_cfg() });
            word_count(&b);
            b.sim_makespan_ms()
        };
        let b = {
            let b = sim(ClusterConfig {
                straggler_prob: 0.3,
                churn: ChurnConfig { kill_prob: 0.0, restart_ms: 1_000.0 },
                shuffle: ShuffleModel::off(),
                ..deterministic_cfg()
            });
            word_count(&b);
            b.sim_makespan_ms()
        };
        assert_eq!(a.to_bits(), b.to_bits(), "disabled models are bit-invisible");
    }

    #[test]
    fn adaptive_task_count_reacts_to_previous_skew() {
        let backend = sim(deterministic_cfg());
        assert_eq!(backend.task_count(10_000, 1.0), 16); // 4 nodes × 2 slots × 2
        assert_eq!(backend.task_count(10_000, 4.0), 64); // skew → finer tasks
        assert_eq!(backend.task_count(3, 4.0), 3);
        let fixed = sim(ClusterConfig { adaptive_tasks: false, ..deterministic_cfg() });
        assert_eq!(fixed.task_count(10_000, 4.0), 16);
    }

    #[test]
    fn empty_input_round_is_a_no_op() {
        let backend = sim(deterministic_cfg());
        let out: Vec<(u32, u32)> = backend
            .map_reduce(
                "empty",
                Vec::<u32>::new(),
                |&x: &u32| vec![(x, x)],
                no_combine::<u32, u32>(),
                |k: &u32, _vs: Vec<u32>| vec![(*k, 0)],
            )
            .unwrap();
        assert!(out.is_empty());
        assert_eq!(backend.sim_makespan_ms(), 0.0);
    }
}
