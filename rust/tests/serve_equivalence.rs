//! The serve layer's load-bearing invariant, tested end to end: for ANY
//! context, shard count, batch chunking, compaction schedule, and
//! constraint set, the compacted service index equals single-miner
//! `oac::mine_online` output — same components, same supports, same
//! densities. Plus snapshot-roundtrip preservation on real generators.

mod common;

use common::{assert_same, churn, random_ctx, sorted};
use tricluster::datasets::{movielens, synthetic, MovielensParams};
use tricluster::oac::{mine_online, Constraints};
use tricluster::serve::cluster::{ServeSim, ServeSimConfig};
use tricluster::serve::{ServeConfig, TriclusterService};
use tricluster::util::proptest_lite::{assert_prop, Gen};

/// Random context → random service schedule → exact index equality.
#[test]
fn prop_sharded_equals_sequential() {
    assert_prop(96, |g: &mut Gen| {
        // small entity universes force heavy cumulus sharing across
        // shards — the regime where partial-cumulus merging can go wrong
        let arity = 3 + g.usize_below(2);
        let universe = 2 + g.u32_below(9);
        let n_tuples = 1 + g.usize_below(300);
        let ctx = random_ctx(g, arity, universe, n_tuples);
        let constraints = if g.bool(0.5) {
            Constraints::none()
        } else {
            Constraints {
                min_density: if g.bool(0.5) { 0.0 } else { g.f64() },
                min_support: g.usize_below(3),
            }
        };
        let reference = sorted(mine_online(&ctx, &constraints));

        let shards = 1 + g.usize_below(6);
        let batch = 1 + g.usize_below(64);
        let compact_every = 1 + g.usize_below(8);
        let mut cfg = ServeConfig::new(arity, shards)
            .with_constraints(constraints.clone());
        // sometimes force mid-stream backpressure drains too
        if g.bool(0.3) {
            cfg.max_pending = 1 + g.usize_below(32);
        }
        let mut svc = TriclusterService::new(cfg);
        for (i, chunk) in ctx.tuples().chunks(batch).enumerate() {
            svc.ingest(chunk);
            if (i + 1) % compact_every == 0 {
                svc.compact();
            }
        }
        svc.compact();
        let got = sorted(svc.clusters().to_vec());
        assert_same(
            &got,
            &reference,
            &format!(
                "arity={arity} universe={universe} tuples={} shards={shards} \
                 batch={batch} compact_every={compact_every}",
                ctx.len()
            ),
        )
    });
}

/// The same invariant on the paper's structured generators (dense blocks
/// and near-diagonal contexts stress duplicate-heavy dedup).
#[test]
fn structured_families_match() {
    for (name, ctx) in [
        ("k1", synthetic::k1(7).inner),
        ("k2", synthetic::k2(5).inner),
        ("ml", movielens(&MovielensParams::with_tuples(3_000))),
    ] {
        let reference = sorted(mine_online(&ctx, &Constraints::none()));
        let mut svc =
            TriclusterService::new(ServeConfig::new(ctx.arity(), 4));
        for chunk in ctx.tuples().chunks(111) {
            svc.ingest(chunk);
        }
        svc.compact();
        let got = sorted(svc.clusters().to_vec());
        assert_same(&got, &reference, name).unwrap();
        // support conservation: every tuple generates exactly one cluster
        let total: usize = got.iter().map(|c| c.support).sum();
        assert_eq!(total, ctx.len(), "{name}: support mass conserved");
    }
}

/// Random context → random serve-on-cluster schedule WITH randomized
/// node churn (seeded kills land mid-drain, between a wave's route and
/// mine phases): shards are re-placed, the last compacted snapshot is
/// replayed for real, and the in-flight window re-delivered — the
/// compacted index must still equal single-miner `mine_online` for any
/// placement policy, kill rate, restart delay, rebalance mode, and
/// pipelining mode.
#[test]
fn prop_churned_serve_cluster_equals_sequential() {
    assert_prop(48, |g: &mut Gen| {
        let universe = 2 + g.u32_below(9);
        let n_tuples = 50 + g.usize_below(400);
        let ctx = random_ctx(g, 3, universe, n_tuples);
        let reference = sorted(mine_online(&ctx, &Constraints::none()));

        let shards = 1 + g.usize_below(6);
        let nodes = 1 + g.usize_below(4);
        let placement = ["rr", "locality", "least"][g.usize_below(3)];
        let mut cfg = ServeSimConfig::new(3, shards, nodes);
        cfg.placement = placement.into();
        cfg.slots_per_node = 1 + g.usize_below(3);
        cfg.batch = 8 + g.usize_below(64);
        cfg.route_chunk = 4 + g.usize_below(32);
        cfg.compact_every = 1 + g.usize_below(4);
        cfg.source_skew = g.f64() * 2.5;
        cfg.churn = churn(0.2 + g.f64() * 0.6, g.f64() * 100.0);
        cfg.rebalance = g.bool(0.7);
        cfg.pipeline = g.bool(0.5);
        cfg.seed = g.rng.next_u64();
        let mut sim = ServeSim::new(cfg).map_err(|e| e.to_string())?;
        sim.run(ctx.tuples());
        let kills = sim.stats().kills;
        let got = sorted(sim.clusters().to_vec());
        assert_same(
            &got,
            &reference,
            &format!(
                "churned serve-cluster: {placement} shards={shards} nodes={nodes} \
                 tuples={} kills={kills}",
                ctx.len()
            ),
        )
    });
}

/// Boundary sweep on the serve path: {empty stream, single tuple,
/// all-duplicate stream, dense block} × {θ=0.0, θ=1.0} through ingest →
/// compact must equal `mine_online` over the deduplicated context —
/// compaction of nothing, of one tuple, and of 300 copies of one tuple
/// all hit the same watermark/merge machinery as the big streams.
#[test]
fn edge_sweep_serve_path_at_boundary_thetas() {
    use tricluster::core::context::PolyContext;
    use tricluster::core::tuple::NTuple;

    let one = NTuple::triple(2, 5, 9);
    let streams: [(&str, Vec<NTuple>); 4] = [
        ("empty", Vec::new()),
        ("single", vec![one]),
        ("all-duplicate", vec![one; 300]),
        ("k1", synthetic::k1(4).inner.tuples().to_vec()),
    ];
    for (sname, stream) in &streams {
        // the logical relation behind the stream (dedup is the service's
        // job; the reference context dedups by construction)
        let mut ctx = PolyContext::new(3);
        for t in stream {
            ctx.add_ids(t.as_slice());
        }
        for theta in [0.0, 1.0] {
            let constraints = Constraints { min_density: theta, min_support: 0 };
            let reference = sorted(mine_online(&ctx, &constraints));
            for shards in [1, 4] {
                let cfg = ServeConfig::new(3, shards)
                    .with_constraints(constraints.clone());
                let mut svc = TriclusterService::new(cfg);
                for chunk in stream.chunks(7) {
                    svc.ingest(chunk);
                    svc.compact(); // compact every wave, incl. empty deltas
                }
                svc.compact();
                let got = sorted(svc.clusters().to_vec());
                assert_same(
                    &got,
                    &reference,
                    &format!("serve {sname}, θ={theta}, shards={shards}"),
                )
                .unwrap();
                if *sname == "all-duplicate" {
                    assert_eq!(got.len(), 1);
                    assert_eq!(got[0].support, 1, "dupes must count once");
                }
            }
        }
    }
}

/// Duplicate deliveries (at-least-once upstream) must not change the
/// index: same-tuple replays land on the same shard and dedup in
/// materialisation, exactly like M/R task retries.
#[test]
fn duplicate_delivery_is_idempotent() {
    let ctx = synthetic::k2(4).inner;
    let reference = sorted(mine_online(&ctx, &Constraints::none()));
    let mut svc = TriclusterService::new(ServeConfig::new(3, 3));
    svc.ingest(ctx.tuples());
    svc.ingest(ctx.tuples()); // full replay
    svc.compact();
    let got = sorted(svc.clusters().to_vec());
    assert_eq!(got.len(), reference.len());
    for (a, b) in got.iter().zip(&reference) {
        assert_eq!(a.components, b.components);
        // replayed generating tuples are counted once
        assert_eq!(a.support, b.support);
    }
}

#[test]
fn snapshot_roundtrip_on_movielens() {
    let ctx = movielens(&MovielensParams::with_tuples(2_000));
    let mut svc = TriclusterService::new(ServeConfig::new(4, 4));
    for chunk in ctx.tuples().chunks(333) {
        svc.ingest(chunk);
    }
    svc.compact();
    let dir = std::env::temp_dir().join("tricluster_serve_equivalence");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ml.json");
    svc.snapshot_to(&path).unwrap();
    let mut restored = TriclusterService::restore_from(&path).unwrap();
    let a = sorted(svc.clusters().to_vec());
    let b = sorted(restored.clusters().to_vec());
    assert_same(&a, &b, "snapshot roundtrip").unwrap();
    std::fs::remove_file(&path).ok();
}

/// Heavily skewed streams (hot users/movies) still balance across the
/// service: no shard ends up with everything, and the result is exact.
#[test]
fn skewed_stream_spreads_and_matches() {
    let ctx = movielens(&MovielensParams::with_tuples(5_000));
    let reference = sorted(mine_online(&ctx, &Constraints::none()));
    let mut svc = TriclusterService::new(ServeConfig::new(4, 4));
    svc.ingest(ctx.tuples());
    svc.compact();
    let stats = svc.stats();
    assert_eq!(stats.merged, ctx.len());
    assert_eq!(stats.shard_sizes.iter().sum::<usize>(), ctx.len());
    // whole-tuple hashing spreads even a zipf-skewed stream: no shard
    // holds more than half the mass at 4 shards
    for (i, &size) in stats.shard_sizes.iter().enumerate() {
        assert!(size > 0, "shard {i} starved");
        assert!(size < ctx.len() / 2, "shard {i} overloaded: {size}");
    }
    let got = sorted(svc.clusters().to_vec());
    assert_same(&got, &reference, "skewed movielens").unwrap();
}
