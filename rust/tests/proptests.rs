//! Property-based tests over coordinator invariants (proptest_lite):
//! random contexts in, algebraic invariants out — the shuffle/partition
//! routing, dedup idempotence, density bounds, duplicate tolerance, and
//! online/M-R equivalence on arbitrary relations.

use tricluster::core::context::PolyContext;
use tricluster::core::pattern::Cluster;
use tricluster::core::tuple::NTuple;
use tricluster::mmc::{run_mmc, MmcConfig};
use tricluster::oac::primes::{PrimeStore, SetIds};
use tricluster::oac::{mine_online, Constraints, OnlineMiner};
use tricluster::util::proptest_lite::{assert_prop, Gen};

/// Random N-ary context with ≤ `universe` ids per modality.
fn gen_context(g: &mut Gen, arity: usize, universe: u32) -> PolyContext {
    let mut ctx = PolyContext::new(arity);
    let n = 1 + g.len() * 4;
    for _ in 0..n {
        let ids: Vec<u32> =
            (0..arity).map(|_| g.u32_below(universe)).collect();
        ctx.add_ids(&ids);
    }
    ctx
}

fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
    cs.sort_by(|a, b| a.components.cmp(&b.components));
    cs
}

#[test]
fn prop_online_equals_mr_on_random_triadic_contexts() {
    assert_prop(40, |g| {
        let ctx = gen_context(g, 3, 12);
        let online = sorted(mine_online(&ctx, &Constraints::none()));
        let cfg = MmcConfig {
            map_tasks: 1 + g.usize_below(6),
            reduce_tasks: 1 + g.usize_below(6),
            ..MmcConfig::default()
        };
        let mr = run_mmc(&ctx, &cfg).map_err(|e| e.to_string())?;
        if mr.clusters.len() != online.len() {
            return Err(format!(
                "counts differ: mr={} online={}",
                mr.clusters.len(),
                online.len()
            ));
        }
        for (a, b) in mr.clusters.iter().zip(&online) {
            if a.components != b.components || a.support != b.support {
                return Err(format!("cluster mismatch: {a:?} vs {b:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mr_output_invariant_under_task_retries() {
    assert_prop(30, |g| {
        let ctx = gen_context(g, 3, 10);
        let base = run_mmc(&ctx, &MmcConfig::default()).map_err(|e| e.to_string())?;
        let noisy = run_mmc(
            &ctx,
            &MmcConfig {
                fault_prob: g.f64(),
                seed: g.u32_below(u32::MAX) as u64,
                ..MmcConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        if base.clusters.len() != noisy.clusters.len() {
            return Err("retry changed cluster count".into());
        }
        for (a, b) in base.clusters.iter().zip(&noisy.clusters) {
            if a.components != b.components || a.support != b.support {
                return Err("retry changed a cluster".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_every_tuple_generates_exactly_one_cluster() {
    assert_prop(40, |g| {
        let arity = 3 + g.usize_below(2);
        let ctx = gen_context(g, arity, 8);
        let out = mine_online(&ctx, &Constraints::none());
        let total: usize = out.iter().map(|c| c.support).sum();
        if total != ctx.len() {
            return Err(format!("supports {total} != tuples {}", ctx.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_generating_tuple_lies_inside_its_cluster() {
    assert_prop(40, |g| {
        let ctx = gen_context(g, 3, 10);
        let mut miner = OnlineMiner::new(3);
        miner.add_batch(ctx.tuples());
        for (c, t) in miner.materialize_all() {
            for k in 0..3 {
                if !c.components[k].contains(&t.get(k)) {
                    return Err(format!("{t:?} not inside component {k} of {c:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_support_density_in_unit_interval_and_support_le_volume() {
    assert_prop(40, |g| {
        let ctx = gen_context(g, 3, 10);
        for c in mine_online(&ctx, &Constraints::none()) {
            let rho = c.support_density();
            if !(0.0..=1.0 + 1e-12).contains(&rho) {
                return Err(format!("ρ={rho} out of range"));
            }
            if c.support as f64 > c.volume() + 1e-9 {
                return Err("support exceeds volume".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_constraints_are_monotone() {
    // tighter constraints can only shrink the output
    assert_prop(30, |g| {
        let ctx = gen_context(g, 3, 10);
        let loose = mine_online(
            &ctx,
            &Constraints { min_density: 0.2, min_support: 1 },
        );
        let tight = mine_online(
            &ctx,
            &Constraints { min_density: 0.6, min_support: 2 },
        );
        if tight.len() > loose.len() {
            return Err(format!("{} > {}", tight.len(), loose.len()));
        }
        Ok(())
    });
}

/// The parallel-ingest contract: for ANY arity-3/4 batch, worker count,
/// chunk size, and split into consecutive par-ingested sub-batches, the
/// merged store is bit-for-bit the sequential store — identical per-tuple
/// set ids, identical dictionaries, identical cumuli.
#[test]
fn prop_par_add_batch_equals_sequential_bit_for_bit() {
    assert_prop(32, |g| {
        let arity = 3 + g.usize_below(2);
        let universe = 2 + g.u32_below(10);
        let n = 1 + g.len() * 16;
        let tuples: Vec<NTuple> = (0..n)
            .map(|_| {
                let ids: Vec<u32> =
                    (0..arity).map(|_| g.u32_below(universe)).collect();
                NTuple::new(&ids)
            })
            .collect();
        let mut seq = PrimeStore::new(arity);
        let seq_ids: Vec<SetIds> = tuples.iter().map(|t| seq.add(t)).collect();

        let workers = 1 + g.usize_below(5);
        let chunk = 1 + g.usize_below(48);
        // split into two consecutive parallel batches: the merge must
        // also be correct INCREMENTALLY, against a non-empty store
        let split = g.usize_below(n + 1);
        let mut par = PrimeStore::new(arity);
        let mut par_ids =
            par.par_add_batch_chunked(&tuples[..split], workers, chunk);
        par_ids.extend(par.par_add_batch_chunked(&tuples[split..], workers, chunk));

        if par_ids != seq_ids {
            return Err(format!(
                "set ids diverged (arity={arity} n={n} w={workers} c={chunk} \
                 split={split})"
            ));
        }
        if par.total_keys() != seq.total_keys() {
            return Err("distinct key counts diverged".into());
        }
        if par.cumuli() != seq.cumuli() {
            return Err("exported cumuli diverged".into());
        }
        Ok(())
    });
}

/// End-to-end: a miner fed through parallel ingest yields the identical
/// deduplicated, constraint-filtered cluster set.
#[test]
fn prop_parallel_miner_equals_sequential_clusters() {
    assert_prop(24, |g| {
        let ctx = gen_context(g, 3, 9);
        let workers = 2 + g.usize_below(4);
        let cons = Constraints {
            min_density: if g.bool(0.5) { 0.0 } else { g.f64() * 0.5 },
            min_support: g.usize_below(3),
        };
        let mut seq = OnlineMiner::new(3);
        seq.add_batch(ctx.tuples());
        let mut par = OnlineMiner::new(3);
        par.par_add_batch(ctx.tuples(), workers);
        let (a, b) = (seq.dedup_and_filter(&cons), par.dedup_and_filter(&cons));
        if a.len() != b.len() {
            return Err(format!("counts differ: {} vs {}", a.len(), b.len()));
        }
        for (x, y) in a.iter().zip(&b) {
            if x.components != y.components || x.support != y.support {
                return Err(format!("cluster mismatch: {x:?} vs {y:?}"));
            }
        }
        Ok(())
    });
}

/// The bitset density kernel is exact: equal to the scalar hash-probe
/// oracle on random contexts and clusters, including clusters whose ids
/// reach past the context extents.
#[test]
fn prop_bitset_density_equals_scalar_oracle() {
    use tricluster::core::context::TriContext;
    use tricluster::density::{densities_bitset, densities_scalar};
    assert_prop(24, |g| {
        let mut ctx = TriContext::new();
        let universe = 2 + g.u32_below(90); // up to 2 words over modality B
        for _ in 0..(1 + g.len() * 8) {
            ctx.add(
                g.u32_below(universe),
                g.u32_below(universe),
                g.u32_below(universe),
            );
        }
        let mut clusters = mine_online(&ctx.inner, &Constraints::none());
        // adversarial extras: out-of-extent ids and an empty component
        clusters.push(tricluster::core::pattern::tricluster(
            g.id_set(universe + 100),
            g.id_set(universe + 100),
            g.id_set(universe + 100),
        ));
        clusters.push(tricluster::core::pattern::tricluster(
            vec![],
            vec![0],
            vec![universe],
        ));
        let scalar = densities_scalar(&ctx, &clusters);
        let Some(bits) = densities_bitset(&ctx, &clusters, 1 << 30) else {
            return Err("row table unexpectedly over the cap".into());
        };
        if scalar != bits {
            return Err(format!("densities diverged: {scalar:?} vs {bits:?}"));
        }
        Ok(())
    });
}

/// The batched probe pipeline is exact: for ANY arity-3/4 tuple stream
/// (including a split across two batches, so the second probes a warm
/// dictionary), `add_batch` returns the same per-tuple set ids and
/// builds the same store as the scalar `add` loop.
#[test]
fn prop_batched_probe_equals_scalar_add() {
    assert_prop(24, |g| {
        let arity = 3 + g.usize_below(2);
        let universe = 2 + g.u32_below(10);
        let n = 1 + g.len() * 16;
        let tuples: Vec<NTuple> = (0..n)
            .map(|_| {
                let ids: Vec<u32> =
                    (0..arity).map(|_| g.u32_below(universe)).collect();
                NTuple::new(&ids)
            })
            .collect();
        let mut scalar = PrimeStore::new(arity);
        let scalar_ids: Vec<SetIds> = tuples.iter().map(|t| scalar.add(t)).collect();
        let split = g.usize_below(n + 1);
        let mut batched = PrimeStore::new(arity);
        let mut ids = batched.add_batch(&tuples[..split]);
        ids.extend(batched.add_batch(&tuples[split..]));
        if ids != scalar_ids {
            return Err(format!("set ids diverged (arity={arity} split={split})"));
        }
        if batched.total_keys() != scalar.total_keys() {
            return Err("distinct key counts diverged".into());
        }
        if batched.cumuli() != scalar.cumuli() {
            return Err("exported cumuli diverged".into());
        }
        Ok(())
    });
}

/// The partitioned parallel dedup is bit-for-bit the sequential oracle —
/// same clusters, same supports, same ORDER — for any worker count and
/// partition split, over random arities and constraints.
#[test]
fn prop_parallel_dedup_equals_sequential_bit_for_bit() {
    use tricluster::oac::{dedup_generated, dedup_generated_parallel};
    assert_prop(24, |g| {
        let arity = 3 + g.usize_below(2);
        let ctx = gen_context(g, arity, 2 + g.u32_below(8));
        let cons = Constraints {
            min_density: if g.bool(0.5) { 0.0 } else { g.f64() * 0.5 },
            min_support: g.usize_below(3),
        };
        let mut miner = OnlineMiner::new(arity);
        miner.add_batch(ctx.tuples());
        // seals the arena and runs the auto-sized parallel path
        let auto = miner.dedup_and_filter(&cons);
        let arena = &miner.primes().arena;
        let oracle = dedup_generated(arena, miner.generated(), &cons);
        let workers = 1 + g.usize_below(5);
        let partitions = 1 + g.usize_below(8);
        let par = dedup_generated_parallel(
            arena,
            miner.generated(),
            &cons,
            workers,
            partitions,
        );
        for (label, got) in [("auto", &auto), ("par", &par)] {
            if got.len() != oracle.len() {
                return Err(format!(
                    "{label}: counts differ {} vs {} (w={workers} p={partitions})",
                    got.len(),
                    oracle.len()
                ));
            }
            for (a, b) in got.iter().zip(&oracle) {
                if a.components != b.components || a.support != b.support {
                    return Err(format!(
                        "{label}: cluster/order mismatch (w={workers} p={partitions}): \
                         {a:?} vs {b:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The compressed (array/bitmap/run) density kernel is exact: equal to
/// the scalar hash-probe oracle on random contexts and clusters,
/// including clusters whose ids reach past the context extents.
#[test]
fn prop_compressed_density_equals_scalar_oracle() {
    use tricluster::core::context::TriContext;
    use tricluster::density::{densities_compressed, densities_scalar};
    assert_prop(24, |g| {
        let mut ctx = TriContext::new();
        let universe = 2 + g.u32_below(90);
        for _ in 0..(1 + g.len() * 8) {
            ctx.add(
                g.u32_below(universe),
                g.u32_below(universe),
                g.u32_below(universe),
            );
        }
        let mut clusters = mine_online(&ctx.inner, &Constraints::none());
        // adversarial extras: out-of-extent ids and an empty component
        clusters.push(tricluster::core::pattern::tricluster(
            g.id_set(universe + 100),
            g.id_set(universe + 100),
            g.id_set(universe + 100),
        ));
        clusters.push(tricluster::core::pattern::tricluster(
            vec![],
            vec![0],
            vec![universe],
        ));
        let scalar = densities_scalar(&ctx, &clusters);
        let compressed = densities_compressed(&ctx, &clusters);
        if scalar != compressed {
            return Err(format!(
                "densities diverged: {scalar:?} vs {compressed:?}"
            ));
        }
        Ok(())
    });
}

/// The exact engine's cached row table is reused while the context
/// revision is unchanged and rebuilt (still exact) after a mutation.
#[test]
fn exact_engine_row_cache_tracks_context_revision() {
    use tricluster::datasets::synthetic::k1;
    use tricluster::density::{densities_scalar, DensityEngine, ExactEngine};
    let mut ctx = k1(16);
    let clusters = mine_online(&ctx.inner, &Constraints::none());
    let mut e = ExactEngine::default();
    let d1 = e.densities(&ctx, &clusters);
    let rev = e.cached_revision().expect("row table cached");
    let d2 = e.densities(&ctx, &clusters);
    assert_eq!(d1, d2);
    assert_eq!(e.cached_revision(), Some(rev), "unchanged context reuses the table");
    // a successful insert bumps the revision: the stale table must not
    // serve the grown relation
    ctx.add(0, 0, 0);
    let d3 = e.densities(&ctx, &clusters);
    assert_ne!(e.cached_revision(), Some(rev), "mutation invalidates the cache");
    assert_eq!(d3, densities_scalar(&ctx, &clusters));
}

#[test]
fn prop_mr_insensitive_to_task_granularity() {
    // routing invariant: any (map_tasks, reduce_tasks) split produces the
    // same final pattern set
    assert_prop(25, |g| {
        let ctx = gen_context(g, 3, 10);
        let a = run_mmc(
            &ctx,
            &MmcConfig { map_tasks: 1, reduce_tasks: 1, ..MmcConfig::default() },
        )
        .map_err(|e| e.to_string())?;
        let b = run_mmc(
            &ctx,
            &MmcConfig {
                map_tasks: 1 + g.usize_below(16),
                reduce_tasks: 1 + g.usize_below(16),
                ..MmcConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        if a.clusters.len() != b.clusters.len() {
            return Err("granularity changed output".into());
        }
        for (x, y) in a.clusters.iter().zip(&b.clusters) {
            if x.components != y.components {
                return Err("granularity changed a cluster".into());
            }
        }
        Ok(())
    });
}
