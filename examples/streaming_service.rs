//! Walkthrough of the serving layer: stream a MovieLens-like rating feed
//! into a sharded `TriclusterService`, compact mid-stream, answer
//! queries through the epoch query plane, and survive a restart via
//! snapshot/restore.
//!
//! Run: `cargo run --release --example streaming_service`

use tricluster::core::io::format_cluster;
use tricluster::datasets::{movielens, MovielensParams};
use tricluster::oac::{mine_online, Constraints};
use tricluster::serve::{QueryBackend, ServeConfig, TriclusterService};

fn main() -> anyhow::Result<()> {
    // A 20k-tuple prefix of the deterministic MovieLens stream:
    // (user, movie, rating, month) with power-law user/movie skew.
    let ctx = movielens(&MovielensParams::with_tuples(20_000));
    println!(
        "stream: {} tuples, arity {} (users x movies x ratings x months)\n",
        ctx.len(),
        ctx.arity()
    );

    // --- ingest: batches hash-route to 4 shards, drains are automatic ---
    let mut svc = TriclusterService::new(
        ServeConfig::builder().arity(ctx.arity()).shards(4).build()?,
    );
    for (i, chunk) in ctx.tuples().chunks(2_048).enumerate() {
        svc.ingest(chunk);
        // compact every 4 batches: each compaction PUBLISHES an immutable
        // epoch snapshot, so the service stays queryable WHILE the stream
        // keeps arriving
        if (i + 1) % 4 == 0 {
            svc.compact();
            let s = svc.stats();
            println!(
                "after batch {:>2}: {:>6} tuples merged, {:>6} cumulus keys, epochs {:?}",
                i + 1,
                s.merged,
                s.distinct_keys,
                s.epochs
            );
        }
    }
    svc.compact();

    // --- query: an owned snapshot + a cached backend --------------------
    // The snapshot is epoch-stamped and immutable: hold it as long as
    // needed, later compactions never touch it.
    let snap = svc.snapshot();
    println!("\nepoch {} holds {} clusters; densest 3:", snap.epoch(), snap.len());
    for c in snap.top_k_by_density(3) {
        println!(
            "  {}  (support {}, rho {:.3})",
            format_cluster(&ctx, c),
            c.support,
            c.support_density()
        );
    }
    // membership is allocation-free: ids into the snapshot's index,
    // resolved on demand
    let hot_user = 0; // zipf makes user0 the most active
    let hits = snap.containing(0, hot_user);
    println!(
        "\nuser {:?} appears in {} clusters (first: support {})",
        ctx.interners[0].name(hot_user),
        hits.len(),
        snap.resolve(hits[0]).support
    );
    // the backend caches repeated queries; the cache drops itself when a
    // new epoch is published
    let mut backend = svc.backend();
    let _ = backend.top_k(3);
    let _ = backend.top_k(3);
    let (cache_hits, cache_misses) = backend.cache_stats();
    println!("backend cache: {cache_hits} hits / {cache_misses} misses");

    // --- the invariant the whole layer rests on ------------------------
    let reference = mine_online(&ctx, &Constraints::none());
    assert_eq!(snap.len(), reference.len());
    println!(
        "\nsharded index == sequential mine_online: {} clusters both ways",
        reference.len()
    );

    // --- restart recovery ----------------------------------------------
    let path = std::env::temp_dir().join("streaming_service_snapshot.json");
    svc.snapshot_to(&path)?;
    let restored = TriclusterService::restore_from(&path)?;
    assert_eq!(restored.snapshot().len(), reference.len());
    println!("snapshot -> restore verified at {}", path.display());
    std::fs::remove_file(&path).ok();
    Ok(())
}
