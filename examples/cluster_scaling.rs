//! Virtual-cluster scaling study: how the three-stage pipeline's
//! makespan shrinks as simulated nodes are added — the "performance gain
//! from using a distributed system and scalability" the abstract
//! promises, measured from real per-task timings replayed by the
//! LPT scheduler (hadoop::task).
//!
//! Run: `cargo run --release --example cluster_scaling [-- --tuples N]`

use tricluster::datasets::{movielens, MovielensParams};
use tricluster::mmc::{run_mmc, MmcConfig};
use tricluster::util::cli::Args;
use tricluster::util::table::fmt_ms;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n: usize = args.parse_or("tuples", 50_000);
    let ctx = movielens(&MovielensParams::with_tuples(n));
    println!("== virtual cluster scaling on MovieLens {n} tuples ==\n");

    let cfg = MmcConfig {
        map_tasks: 64,
        reduce_tasks: 64,
        ..MmcConfig::default()
    };
    let res = run_mmc(&ctx, &cfg)?;
    let t1 = res.makespan_ms(1);
    println!("nodes | makespan ms | speedup | efficiency");
    for r in [1, 2, 4, 8, 10, 16, 32, 64] {
        let tr = res.makespan_ms(r);
        let speedup = t1 / tr.max(1e-9);
        println!(
            "{r:>5} | {m:>11} | {speedup:>6.2}x | {eff:>6.1}%",
            m = fmt_ms(tr),
            eff = 100.0 * speedup / r as f64
        );
    }
    println!(
        "\n(64 tasks/stage: efficiency falls once nodes ≈ tasks — the JobTracker\n\
         granularity argument of §1: tasks must outnumber nodes.)"
    );
    Ok(())
}
