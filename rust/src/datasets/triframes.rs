//! Tri-frames-like many-valued context generator (paper §6).
//!
//! The paper's parallel-NOAC experiments use ~100k subject-verb-object
//! triples extracted from FrameNet 1.7, each weighted by its DepCC corpus
//! frequency. We generate (subject, verb, object) triples with Zipfian
//! verb/argument distributions and heavy-tailed frequencies as the
//! valuation V — the shape that makes δ = 100 a meaningful band.

use crate::core::context::ManyValuedTriContext;
use crate::util::rng::{Rng, Zipf};

#[derive(Debug, Clone)]
/// Generation parameters for the verb-frame stream (Table 5's data).
pub struct TriframesParams {
    /// Distinct subjects.
    pub subjects: usize,
    /// Distinct verbs.
    pub verbs: usize,
    /// Distinct objects.
    pub objects: usize,
    /// Triples to generate.
    pub triples: usize,
    /// Stream seed.
    pub seed: u64,
}

impl Default for TriframesParams {
    fn default() -> Self {
        Self {
            subjects: 3_000,
            verbs: 800,
            objects: 5_000,
            triples: 100_000,
            seed: 0xF8A3E5,
        }
    }
}

impl TriframesParams {
    /// The Table-5 sweep: first `n` triples of the same stream.
    pub fn with_triples(n: usize) -> Self {
        Self { triples: n, ..Self::default() }
    }
}

/// Generate the many-valued `(subject, verb, object)` context.
pub fn triframes(params: &TriframesParams) -> ManyValuedTriContext {
    let mut ctx = ManyValuedTriContext::new();
    for s in 0..params.subjects {
        ctx.context.inner.interners[0].intern(&format!("subj{s}"));
    }
    for v in 0..params.verbs {
        ctx.context.inner.interners[1].intern(&format!("verb{v}"));
    }
    for o in 0..params.objects {
        ctx.context.inner.interners[2].intern(&format!("obj{o}"));
    }

    let mut rng = Rng::new(params.seed);
    let subj_zipf = Zipf::new(params.subjects as u64, 1.0);
    let verb_zipf = Zipf::new(params.verbs as u64, 1.1);
    let obj_zipf = Zipf::new(params.objects as u64, 1.0);

    // Frame groups: synonymous verbs applied to shared argument sets form
    // small DENSE blocks with near-identical corpus counts — the patterns
    // NOAC's strict setting (ρ ≥ 0.8, minsup 2) exists to find. Plant one
    // such block roughly every 400 triples of the stream so their count
    // grows with the sweep prefix, as in the paper's Table 5.
    let plant_block = |ctx: &mut ManyValuedTriContext, rng: &mut Rng| {
        let ns = 2 + rng.usize_below(3);
        let nv = 2 + rng.usize_below(2);
        let no = 2 + rng.usize_below(3);
        let ss: Vec<u32> =
            (0..ns).map(|_| rng.below(params.subjects as u64) as u32).collect();
        let vs: Vec<u32> =
            (0..nv).map(|_| rng.below(params.verbs as u64) as u32).collect();
        let os: Vec<u32> =
            (0..no).map(|_| rng.below(params.objects as u64) as u32).collect();
        let base = 100.0 + (rng.below(40) * 25) as f64;
        for &s in &ss {
            for &v in &vs {
                for &o in &os {
                    let jitter = (rng.below(3) * 25) as f64;
                    ctx.add(s, v, o, base + jitter);
                }
            }
        }
    };

    let mut next_plant = 200;
    while ctx.len() < params.triples {
        if ctx.len() >= next_plant {
            plant_block(&mut ctx, &mut rng);
            next_plant += 400;
        }
        let s = subj_zipf.sample(&mut rng) as u32;
        let v = verb_zipf.sample(&mut rng) as u32;
        let o = obj_zipf.sample(&mut rng) as u32;
        // DepCC-style frequency: discrete power-law in [1, 1e5); verbs in
        // the Zipf head also tend to carry the highest counts, so couple
        // the scale to the verb rank. Corpus counts are heavily tied at
        // small values (many hapax/low-frequency frames share exact
        // counts), which is what makes a δ = 100 band meaningful — mimic
        // that by quantising the tail.
        let scale = 1.0 + 2_000.0 / (1.0 + v as f64);
        let raw = (scale * (1.0 / (1.0 - rng.f64())).powf(0.7)).min(99_999.0);
        let freq = if raw < 500.0 {
            ((raw / 25.0).floor() * 25.0).max(1.0)
        } else {
            raw.floor()
        };
        ctx.add(s, v, o, freq);
    }
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valued_triples() {
        let ctx = triframes(&TriframesParams::with_triples(5_000));
        assert_eq!(ctx.len(), 5_000);
        let t = ctx.triples()[0];
        let v = ctx.value(t.get(0), t.get(1), t.get(2)).unwrap();
        assert!(v >= 1.0 && v < 100_000.0);
    }

    #[test]
    fn prefix_property() {
        let a = triframes(&TriframesParams::with_triples(1_000));
        let b = triframes(&TriframesParams::with_triples(3_000));
        assert_eq!(&b.triples()[..1_000], a.triples());
    }

    #[test]
    fn frequencies_heavy_tailed() {
        let ctx = triframes(&TriframesParams::with_triples(20_000));
        let mut vals: Vec<f64> = ctx
            .triples()
            .iter()
            .map(|t| ctx.value(t.get(0), t.get(1), t.get(2)).unwrap())
            .collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = vals[vals.len() / 2];
        let p99 = vals[(vals.len() as f64 * 0.99) as usize];
        assert!(p99 > 10.0 * median, "median={median} p99={p99}");
    }
}
