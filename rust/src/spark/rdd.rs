//! A Spark-like partitioned dataset engine (paper §7: "Further
//! development of the proposed triclustering methods for large datasets
//! is possible with Apache Spark").
//!
//! Differences from the `hadoop` engine that matter for the comparison:
//! * **no DFS materialisation** between stages — intermediates stay in
//!   memory, narrow transformations fuse into one pass per partition;
//! * **narrow vs wide** transformations: `map`/`flat_map`/`filter` keep
//!   partitioning (pipelined, one task per partition), `group_by_key`
//!   is a wide transformation that shuffles in memory;
//! * per-partition task timings feed the same virtual cluster clock, so
//!   Hadoop-style and Spark-like makespans are directly comparable.
//!
//! This is an eager mini-engine (each op runs when called) — lineage
//! tracking and recompute-on-loss are out of scope; what we compare is
//! the data-movement model, which is where the paper's §7 expectation
//! lives.

use crate::util::hash::{fxhash, FxHashMap};
use crate::util::pool;
use crate::util::stats::Timer;

/// Execution context: partition count, executor threads, and the task
/// timing log shared by all ops of one job.
pub struct SparkContext {
    /// Partitions per RDD (wide ops re-partition to this count).
    pub partitions: usize,
    /// OS threads executing partition tasks.
    pub executor_threads: usize,
    /// (stage label, per-partition task ms)
    pub stage_log: std::sync::Mutex<Vec<(String, Vec<f64>)>>,
}

impl SparkContext {
    /// Context with `partitions` partitions (min 1) and
    ///  `executor_threads` threads.
    pub fn new(partitions: usize, executor_threads: usize) -> Self {
        Self {
            partitions: partitions.max(1),
            executor_threads: executor_threads.max(1),
            stage_log: std::sync::Mutex::new(Vec::new()),
        }
    }

    fn log(&self, label: &str, times: Vec<f64>) {
        self.stage_log.lock().unwrap().push((label.to_string(), times));
    }

    /// Virtual r-node makespan over all logged stages (barrier per
    /// stage, LPT within a stage) — comparable to `JobStats::makespan_ms`.
    pub fn makespan_ms(&self, r: usize) -> f64 {
        self.stage_log
            .lock()
            .unwrap()
            .iter()
            .map(|(_, t)| crate::hadoop::task::lpt_makespan(t, r))
            .sum()
    }

    /// Parallelize a vector into an RDD with hash-spread partitions.
    pub fn parallelize<T: Send>(&self, data: Vec<T>) -> Rdd<'_, T> {
        let n = self.partitions;
        let mut parts: Vec<Vec<T>> = (0..n).map(|_| Vec::new()).collect();
        for (i, x) in data.into_iter().enumerate() {
            parts[i % n].push(x);
        }
        Rdd { ctx: self, parts }
    }
}

/// A partitioned in-memory dataset bound to its context.
pub struct Rdd<'a, T> {
    ctx: &'a SparkContext,
    parts: Vec<Vec<T>>,
}

impl<'a, T: Send> Rdd<'a, T> {
    /// Number of partitions backing this RDD.
    pub fn num_partitions(&self) -> usize {
        self.parts.len()
    }

    /// Total elements across partitions.
    pub fn count(&self) -> usize {
        self.parts.iter().map(Vec::len).sum()
    }

    /// Narrow transformation: per-element map, pipelined per partition.
    pub fn map<U: Send, F>(self, label: &str, f: F) -> Rdd<'a, U>
    where
        F: Fn(T) -> U + Sync,
    {
        self.flat_map(label, move |x| std::iter::once(f(x)))
    }

    /// Narrow transformation: flat map.
    pub fn flat_map<U: Send, I, F>(self, label: &str, f: F) -> Rdd<'a, U>
    where
        I: IntoIterator<Item = U>,
        F: Fn(T) -> I + Sync,
    {
        let ctx = self.ctx;
        // hand each task exclusive ownership of its partition
        let slots: Vec<std::sync::Mutex<Option<Vec<T>>>> = self
            .parts
            .into_iter()
            .map(|p| std::sync::Mutex::new(Some(p)))
            .collect();
        let mut times = vec![0.0; slots.len()];
        let out: Vec<(Vec<U>, f64)> =
            pool::parallel_map(slots.len(), ctx.executor_threads, 1, |p| {
                let timer = Timer::start();
                let part = slots[p].lock().unwrap().take().expect("taken once");
                let items: Vec<U> = part.into_iter().flat_map(&f).collect();
                (items, timer.elapsed_ms())
            });
        let mut new_parts = Vec::with_capacity(out.len());
        for (p, (items, ms)) in out.into_iter().enumerate() {
            times[p] = ms;
            new_parts.push(items);
        }
        ctx.log(label, times);
        Rdd { ctx, parts: new_parts }
    }

    /// Narrow transformation: filter.
    pub fn filter<F>(self, label: &str, f: F) -> Rdd<'a, T>
    where
        F: Fn(&T) -> bool + Sync,
    {
        self.flat_map(label, move |x| if f(&x) { Some(x) } else { None })
    }

    /// Collect all elements (order: partition-major).
    pub fn collect(self) -> Vec<T> {
        self.parts.into_iter().flatten().collect()
    }
}

/// Fold a pair list into one `(k, v)` per distinct key under `f` — the
/// hash-merge both `reduce_by_key` stages (map-side combine and final
/// reduce) share.
fn merge_pairs<K, V, F>(pairs: Vec<(K, V)>, f: &F) -> Vec<(K, V)>
where
    K: std::hash::Hash + Eq,
    F: Fn(V, V) -> V,
{
    let mut acc: FxHashMap<K, V> = FxHashMap::default();
    for (k, v) in pairs {
        match acc.remove(&k) {
            Some(prev) => {
                acc.insert(k, f(prev, v));
            }
            None => {
                acc.insert(k, v);
            }
        }
    }
    acc.into_iter().collect()
}

impl<'a, K, V> Rdd<'a, (K, V)>
where
    K: Send + std::hash::Hash + Eq + Clone,
    V: Send,
{
    /// Wide transformation with MAP-SIDE COMBINING (Spark's
    /// `reduceByKey`): values are pre-merged per key inside each source
    /// partition before the shuffle, so at most one `(k, v)` per distinct
    /// key per source partition crosses the shuffle instead of every
    /// pair. `f` must be associative and commutative.
    ///
    /// Two stages are logged: `<label>.combine` (one task per source
    /// partition) and `<label>.reduce` (shuffle + one task per target
    /// partition), so ablations can attribute the shuffle savings.
    pub fn reduce_by_key<F>(self, label: &str, f: F) -> Rdd<'a, (K, V)>
    where
        F: Fn(V, V) -> V + Sync,
    {
        let ctx = self.ctx;
        let n = ctx.partitions;
        // map-side combine: one task per SOURCE partition
        let slots: Vec<std::sync::Mutex<Option<Vec<(K, V)>>>> = self
            .parts
            .into_iter()
            .map(|p| std::sync::Mutex::new(Some(p)))
            .collect();
        let combined: Vec<(Vec<(K, V)>, f64)> =
            pool::parallel_map(slots.len(), ctx.executor_threads, 1, |p| {
                let timer = Timer::start();
                let part = slots[p].lock().unwrap().take().expect("taken once");
                (merge_pairs(part, &f), timer.elapsed_ms())
            });
        let mut combine_times = Vec::with_capacity(combined.len());
        // shuffle write: route each combined pair to its target partition
        let timer = Timer::start();
        let mut buckets: Vec<Vec<(K, V)>> = (0..n).map(|_| Vec::new()).collect();
        for (pairs, ms) in combined {
            combine_times.push(ms);
            for (k, v) in pairs {
                let t = (fxhash(&k) % n as u64) as usize;
                buckets[t].push((k, v));
            }
        }
        let shuffle_ms = timer.elapsed_ms();
        ctx.log(&format!("{label}.combine"), combine_times);
        // shuffle read + final reduce: one task per TARGET partition
        let slots: Vec<std::sync::Mutex<Option<Vec<(K, V)>>>> = buckets
            .into_iter()
            .map(|b| std::sync::Mutex::new(Some(b)))
            .collect();
        let reduced: Vec<(Vec<(K, V)>, f64)> =
            pool::parallel_map(n, ctx.executor_threads, 1, |p| {
                let timer = Timer::start();
                let bucket = slots[p].lock().unwrap().take().expect("taken once");
                (merge_pairs(bucket, &f), timer.elapsed_ms())
            });
        let mut times = vec![shuffle_ms / n as f64; n];
        let mut parts = Vec::with_capacity(n);
        for (p, (items, ms)) in reduced.into_iter().enumerate() {
            times[p] += ms;
            parts.push(items);
        }
        ctx.log(&format!("{label}.reduce"), times);
        Rdd { ctx, parts }
    }

    /// Wide transformation: in-memory shuffle grouping values by key.
    /// One task per target partition (hash(key) % partitions).
    pub fn group_by_key(self, label: &str) -> Rdd<'a, (K, Vec<V>)> {
        let ctx = self.ctx;
        let n = ctx.partitions;
        // shuffle write: split every source partition by target
        let timer = Timer::start();
        let mut buckets: Vec<Vec<(K, V)>> = (0..n).map(|_| Vec::new()).collect();
        for part in self.parts {
            for (k, v) in part {
                let t = (fxhash(&k) % n as u64) as usize;
                buckets[t].push((k, v));
            }
        }
        let shuffle_ms = timer.elapsed_ms();
        // shuffle read + group: one task per target partition
        let slots: Vec<std::sync::Mutex<Option<Vec<(K, V)>>>> = buckets
            .into_iter()
            .map(|b| std::sync::Mutex::new(Some(b)))
            .collect();
        let grouped: Vec<(Vec<(K, Vec<V>)>, f64)> =
            pool::parallel_map(n, ctx.executor_threads, 1, |p| {
                let timer = Timer::start();
                let bucket = slots[p].lock().unwrap().take().expect("taken once");
                let mut groups: FxHashMap<K, Vec<V>> = FxHashMap::default();
                for (k, v) in bucket {
                    groups.entry(k).or_default().push(v);
                }
                (groups.into_iter().collect(), timer.elapsed_ms())
            });
        let mut times = vec![shuffle_ms / n as f64; n];
        let mut parts = Vec::with_capacity(n);
        for (p, (items, ms)) in grouped.into_iter().enumerate() {
            times[p] += ms;
            parts.push(items);
        }
        ctx.log(label, times);
        Rdd { ctx, parts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn narrow_ops_pipeline() {
        let ctx = SparkContext::new(4, 2);
        let out = ctx
            .parallelize((0..100u32).collect())
            .map("x2", |x| x * 2)
            .filter("even100", |&x| x < 100)
            .collect();
        let mut sorted = out;
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn group_by_key_groups_all() {
        let ctx = SparkContext::new(3, 2);
        let pairs: Vec<(u32, u32)> = (0..60).map(|i| (i % 5, i)).collect();
        let grouped = ctx.parallelize(pairs).group_by_key("g").collect();
        assert_eq!(grouped.len(), 5);
        for (k, vs) in grouped {
            assert_eq!(vs.len(), 12);
            assert!(vs.iter().all(|v| v % 5 == k));
        }
    }

    #[test]
    fn flat_map_expands() {
        let ctx = SparkContext::new(2, 1);
        let out = ctx
            .parallelize(vec![1u32, 2, 3])
            .flat_map("dup", |x| vec![x, x])
            .collect();
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn stage_log_feeds_makespan() {
        let ctx = SparkContext::new(8, 2);
        let _ = ctx
            .parallelize((0..1000u32).collect())
            .map("m", |x| (x % 7, x))
            .group_by_key("g")
            .collect();
        assert!(ctx.makespan_ms(1) >= ctx.makespan_ms(4) - 1e-9);
        assert_eq!(ctx.stage_log.lock().unwrap().len(), 2);
    }

    #[test]
    fn reduce_by_key_matches_group_by_key_fold() {
        let pairs: Vec<(u32, u64)> = (0..600).map(|i| (i % 13, i as u64)).collect();
        let ctx = SparkContext::new(4, 2);
        let mut reduced = ctx.parallelize(pairs.clone()).reduce_by_key("r", |a, b| a + b).collect();
        reduced.sort_unstable();
        let ctx2 = SparkContext::new(4, 2);
        let mut grouped: Vec<(u32, u64)> = ctx2
            .parallelize(pairs)
            .group_by_key("g")
            .collect()
            .into_iter()
            .map(|(k, vs)| (k, vs.into_iter().sum()))
            .collect();
        grouped.sort_unstable();
        assert_eq!(reduced, grouped);
    }

    #[test]
    fn reduce_by_key_single_pair_per_key() {
        let ctx = SparkContext::new(3, 2);
        let pairs: Vec<(u32, u32)> = (0..90).map(|i| (i % 4, 1)).collect();
        let out = ctx.parallelize(pairs).reduce_by_key("count", |a, b| a + b).collect();
        assert_eq!(out.len(), 4, "exactly one output pair per distinct key");
        assert!(out.iter().all(|&(k, c)| k < 4 && c > 0));
        let total: u32 = out.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 90);
    }

    #[test]
    fn reduce_by_key_max_and_stage_log() {
        let ctx = SparkContext::new(4, 2);
        let pairs = vec![(0u32, 5u32), (1, 2), (0, 9), (1, 1), (0, 3)];
        let mut out = ctx.parallelize(pairs).reduce_by_key("m", u32::max).collect();
        out.sort_unstable();
        assert_eq!(out, vec![(0, 9), (1, 2)]);
        // combine + reduce stages both logged for makespan attribution
        let log = ctx.stage_log.lock().unwrap();
        let labels: Vec<&str> = log.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["m.combine", "m.reduce"]);
    }

    #[test]
    fn strings_and_drops_are_sound() {
        // exercise the ptr::read move path with heap-owning elements
        let ctx = SparkContext::new(3, 2);
        let data: Vec<String> = (0..50).map(|i| format!("item-{i}")).collect();
        let out = ctx
            .parallelize(data)
            .map("len", |s| (s.len() as u32 % 3, s))
            .group_by_key("g")
            .flat_map("explode", |(_, vs)| vs)
            .collect();
        assert_eq!(out.len(), 50);
        assert!(out.iter().any(|s| s == "item-49"));
    }
}
