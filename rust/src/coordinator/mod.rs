//! The coordinator: experiment orchestration, ablations, reports, and
//! the CLI command surface of the `tricluster` binary.

pub mod ablations;
pub mod config;
pub mod experiments;
pub mod report;

pub use config::Config;
pub use experiments::{backends, fig2, measure_both, table3, table4, table5, ExpConfig};
pub use report::Report;
