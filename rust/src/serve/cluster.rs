//! serve-on-cluster: the serving layer placed on a simulated N-node
//! cluster — the paper's "distributed" claim (§4) carried from the batch
//! pipeline to the SERVICE.
//!
//! [`ServeSim`] fuses the two previously independent subsystems: the
//! sharded incremental service ([`super::shard`] + [`super::merge`])
//! supplies the REAL mining and compaction (so every correctness
//! invariant keeps holding), while the cluster layer supplies the
//! simulated placement and cost accounting:
//!
//! * **Shard placement** — each shard is pinned to a simulated node by a
//!   pluggable [`Placement`] policy (`rr` / `locality` / `least`, the
//!   same trait [`crate::exec::ClusterSim`] places M/R tasks with). The
//!   locality policy uses MEASURED input provenance
//!   ([`TaskMeta::affinity`]): the node that sourced most of the shard's
//!   bytes so far.
//! * **Shuffle cost** — each ingest wave is a two-phase drain:
//!   route-split tasks run on the node where their stream chunk ARRIVED
//!   (sources can be skewed), and the per-shard mining task then pays
//!   `bytes moved × per-MiB latency` ([`ShuffleModel`]) for every bin
//!   produced on a different node. Bin sizes are measured, not
//!   estimated — the real router hash decides them.
//! * **Node churn** ([`ChurnConfig`]) — a seeded kill between the route
//!   and mine phases of a wave takes a node down mid-drain. Its shards
//!   lose every tuple since the last compaction and are re-placed on a
//!   surviving node, which REPLAYS the last compacted snapshot (charged:
//!   snapshot fetch + rebuild compute) and re-ingests the retained
//!   in-flight window. The replay is performed for real — the rebuilt
//!   shard is a fresh [`Shard`] fed the compacted history then the
//!   window — so the compacted index still equals
//!   [`crate::oac::mine_online`] under any churn schedule
//!   (property-tested in `rust/tests/serve_equivalence.rs`).
//! * **Wave pipelining** — with `pipeline` on (the default, mirroring
//!   the real router's overlapped drain in [`super::router`]), wave
//!   `w+1`'s route-split may start as soon as wave `w`'s route-split is
//!   done, overlapping with wave `w`'s mining in simulated time; with it
//!   off every wave is a barrier.
//!
//! The communication-vs-balance trade-off this measures is the one
//! Arifuzzaman et al. report for distributed triangle counting
//! (PAPERS.md): under skewed sources, locality placement concentrates
//! mining where the data already is (minimum bytes moved, maximum
//! compute imbalance), round-robin does the opposite, and least-loaded
//! splits the difference. `benches/serve_cluster.rs` sweeps the three
//! policies × churn and gates the trajectory in CI.

use std::path::PathBuf;
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::core::pattern::Cluster;
use crate::core::tuple::NTuple;
use crate::exec::cluster_sim::{ChurnConfig, ShuffleModel};
use crate::exec::placement::{by_name, place_replicas, NodeView, Placement, TaskMeta};
use crate::oac::post::Constraints;
use crate::persist::{
    LogImage, SegmentConfig, SegmentKind, SegmentLog, SegmentPayload, ShardRecord,
};
use crate::util::hash::fxhash;
use crate::util::rng::Rng;

use super::backend::LocalBackend;
use super::epoch::{EpochSnapshot, SnapshotCell};
use super::merge::Compactor;
use super::replica::{ReplicaSet, SharedReplicas, SimRemoteBackend};
use super::shard::{Shard, ShardDelta};

/// Configuration of a [`ServeSim`].
#[derive(Debug, Clone)]
pub struct ServeSimConfig {
    /// Relation arity (3 for triadic contexts).
    pub arity: usize,
    /// Shard count (each shard is one incremental miner).
    pub shards: usize,
    /// Simulated nodes.
    pub nodes: usize,
    /// Worker slots per simulated node.
    pub slots_per_node: usize,
    /// Placement policy name (`rr` | `locality` | `least`).
    pub placement: String,
    /// Tuples per ingest wave (one drain).
    pub batch: usize,
    /// Tuples per route-split task within a wave.
    pub route_chunk: usize,
    /// Waves between compactions (the final [`ServeSim::run`] always
    /// compacts once more at end of stream).
    pub compact_every: usize,
    /// Simulated mining cost per tuple, ms (also the replay cost per
    /// tuple after a churn kill).
    pub mine_ms_per_record: f64,
    /// Simulated route-split cost per tuple, ms.
    pub route_ms_per_record: f64,
    /// Network cost of moving bins between non-colocated tasks.
    pub shuffle: ShuffleModel,
    /// Seeded node kill/restart mid-drain.
    pub churn: ChurnConfig,
    /// Source skew: stream chunk `c` arrives at node `i` with probability
    /// ∝ `1/(i+1)^source_skew` (0.0 = uniform arrivals; 1.5+ = one hot
    /// ingress node, the regime where placement policies diverge).
    pub source_skew: f64,
    /// Overlap wave `w+1`'s route-split with wave `w`'s mining in
    /// simulated time (the real router's drain does — see
    /// [`super::router`]).
    pub pipeline: bool,
    /// Re-place shards by the policy at every compaction (migrations pay
    /// snapshot transfer + rebuild compute).
    pub rebalance: bool,
    /// Constraints applied when materialising the cluster index.
    pub constraints: Constraints,
    /// Read replicas fed by delta streaming from the primary (0 = the
    /// query plane is primary-only). Placed by the same [`Placement`]
    /// policy, avoiding the node hosting the most shards.
    pub replicas: usize,
    /// Retained window, in epochs: the maximum delivery lag a replica
    /// may accumulate before queued snapshots are force-applied — the
    /// staleness bound (see [`crate::serve::replica::ReplicaSet`]).
    pub retained: u64,
    /// Seed for source-arrival and churn draws.
    pub seed: u64,
    /// Segment-log directory: every compaction appends a binary delta
    /// segment, churn recovery restores killed shards from the log by
    /// bulk page adoption, and replica delta MiB is charged from the
    /// REAL encoded segment bytes instead of the shuffle-model estimate.
    /// `None` keeps recovery in-memory (the pre-segment behaviour).
    pub segment_dir: Option<PathBuf>,
    /// Resident arena budget in MiB across shards; ingest past it spills
    /// cold pages ([`crate::oac::primes::SetArena`]) so contexts larger
    /// than RAM stream through instead of aborting. `0` = unlimited.
    pub resident_mib: usize,
}

impl ServeSimConfig {
    /// Defaults tuned for the quick CLI/bench paths: homogeneous costs,
    /// shuffle model on with commodity-network latency, churn off, no
    /// replicas.
    ///
    /// Prefer [`crate::serve::ServeConfig::builder`] for new code — it
    /// is the one construction path the CLI and benches share (see the
    /// ARCHITECTURE.md migration map); this constructor remains as the
    /// defaults source the builder itself delegates to.
    pub fn new(arity: usize, shards: usize, nodes: usize) -> Self {
        Self {
            arity,
            shards: shards.max(1),
            nodes: nodes.max(1),
            slots_per_node: 2,
            placement: "least".into(),
            batch: 4096,
            route_chunk: 1024,
            compact_every: 4,
            mine_ms_per_record: 0.002,
            route_ms_per_record: 0.0005,
            shuffle: ShuffleModel { bytes_per_record: 64.0, ms_per_mib: 20.0 },
            churn: ChurnConfig::off(),
            source_skew: 0.0,
            pipeline: true,
            rebalance: true,
            constraints: Constraints::none(),
            replicas: 0,
            retained: 2,
            seed: 0x5EED,
            segment_dir: None,
            resident_mib: 0,
        }
    }
}

/// Counters and simulated-cost totals of a [`ServeSim`] run.
#[derive(Debug, Clone, Default)]
pub struct ServeSimStats {
    /// Ingest waves (drains) executed.
    pub waves: usize,
    /// Tuples ingested.
    pub tuples: usize,
    /// Compactions executed.
    pub compactions: usize,
    /// MiB fetched by mining tasks from non-colocated route bins — the
    /// steady-state drain-path network cost a placement policy controls.
    pub shuffle_mib: f64,
    /// MiB of compacted snapshots fetched during churn recovery and
    /// rebalance migrations (kept separate from `shuffle_mib` so the
    /// policy comparison is not polluted by one-off recovery traffic).
    pub recovery_mib: f64,
    /// Nodes killed by churn.
    pub kills: usize,
    /// Tuples replayed from compacted snapshots + re-delivered windows
    /// after kills.
    pub replayed_tuples: usize,
    /// Shards moved to a different node by a compaction rebalance.
    pub migrations: usize,
    /// Epoch snapshots published to the replica set.
    pub replica_publishes: u64,
    /// MiB of compacted-delta traffic streamed to replicas (charged on
    /// the replica nodes, off the drain critical path).
    pub replica_mib: f64,
    /// Largest primary−replica epoch gap observed at any publication
    /// (must stay ≤ the configured retained window).
    pub replica_max_staleness: u64,
    /// Tuples mined per node (the winning assignment's node) — the
    /// compute-balance picture a placement policy produced.
    pub per_node_records: Vec<usize>,
}

/// The serving layer on a simulated N-node cluster: real sharded mining
/// and compaction, simulated placement, network, and churn.
///
/// # Example
///
/// ```
/// use tricluster::core::tuple::NTuple;
/// use tricluster::serve::cluster::{ServeSim, ServeSimConfig};
///
/// let stream: Vec<NTuple> =
///     (0..500u32).map(|i| NTuple::triple(i % 7, i % 5, i % 3)).collect();
/// let mut sim = ServeSim::new(ServeSimConfig::new(3, 4, 2)).unwrap();
/// sim.run(&stream);
/// assert!(!sim.clusters().is_empty());
/// assert!(sim.sim_makespan_ms() > 0.0);
/// ```
pub struct ServeSim {
    cfg: ServeSimConfig,
    placement: Box<dyn Placement>,
    shards: Vec<Shard>,
    compactor: Compactor,
    /// shard → node.
    assignment: Vec<usize>,
    /// Simulated time each node×slot frees up.
    lanes: Vec<Vec<f64>>,
    /// Cumulative simulated work per node.
    busy: Vec<f64>,
    /// Per-shard finish time of its latest mining/recovery task (a shard
    /// is sequential: wave w+1 mines after wave w).
    mine_done: Vec<f64>,
    /// When the previous wave's route-split finished / the wave fully
    /// finished — the two pipelining readiness modes.
    prev_route_done: f64,
    prev_wave_end: f64,
    /// shard × node: input bytes sourced from each node (measured
    /// provenance — feeds locality affinity).
    input_bytes: Vec<Vec<f64>>,
    /// Per-shard generated-tuple count at the last compaction (the
    /// snapshot watermark a churn recovery replays to).
    compacted_len: Vec<usize>,
    /// Per-shard epoch at the last compaction.
    epoch_at_compact: Vec<u64>,
    /// Per-shard tuples mined since the last compaction (rebalance cost
    /// estimate).
    recent_records: Vec<usize>,
    /// Cumulative source-weight table for skewed arrivals.
    source_cum: Vec<f64>,
    /// Source-arrival draws (one `f64` per route chunk).
    rng: Rng,
    /// Churn draws, on a SEPARATE salted stream so enabling churn never
    /// perturbs the source-arrival schedule (same design as
    /// [`crate::exec::ClusterSim`]'s churn stream).
    churn_rng: Rng,
    /// The primary's publication point: every compaction publishes the
    /// compacted index here as an immutable epoch snapshot.
    cell: Arc<SnapshotCell>,
    /// Replica shards (None when `cfg.replicas == 0`).
    replicas: Option<SharedReplicas>,
    /// Segment log receiving one delta segment per compaction (None
    /// without `cfg.segment_dir`).
    log: Option<SegmentLog>,
    /// Encoded bytes of the last compaction's delta segment — the REAL
    /// replica streaming cost [`Self::publish_epoch`] charges.
    last_delta_bytes: u64,
    stats: ServeSimStats,
}

impl ServeSim {
    /// Build the simulation; fails on an unknown placement name or an
    /// unwritable segment directory.
    pub fn new(cfg: ServeSimConfig) -> Result<Self> {
        let placement = by_name(&cfg.placement)?;
        let nodes = cfg.nodes.max(1);
        let n_shards = cfg.shards.max(1);
        // fresh log per run: stale segments from a previous run would
        // break rerun determinism (and the equivalence invariant)
        let log = match &cfg.segment_dir {
            Some(dir) => Some(
                SegmentLog::create(dir)
                    .map_err(|e| anyhow::anyhow!("segment log: {e}"))?,
            ),
            None => None,
        };
        let mut shards: Vec<Shard> =
            (0..n_shards).map(|s| Shard::new(s, cfg.arity)).collect();
        if cfg.resident_mib > 0 {
            let pages = crate::oac::primes::resident_pages(cfg.resident_mib, n_shards);
            let spill_dir = cfg.segment_dir.as_ref().map(|d| d.join("spill"));
            for shard in &mut shards {
                shard.set_resident_budget(pages, spill_dir.clone());
            }
        }
        let mut acc = 0.0;
        let source_cum: Vec<f64> = (0..nodes)
            .map(|i| {
                acc += (i as f64 + 1.0).powf(-cfg.source_skew.max(0.0));
                acc
            })
            .collect();
        let mut sim = Self {
            shards,
            compactor: Compactor::new(n_shards),
            assignment: vec![0; n_shards],
            lanes: vec![vec![0.0; cfg.slots_per_node.max(1)]; nodes],
            busy: vec![0.0; nodes],
            mine_done: vec![0.0; n_shards],
            prev_route_done: 0.0,
            prev_wave_end: 0.0,
            input_bytes: vec![vec![0.0; nodes]; n_shards],
            compacted_len: vec![0; n_shards],
            epoch_at_compact: vec![0; n_shards],
            recent_records: vec![0; n_shards],
            source_cum,
            rng: Rng::new(cfg.seed),
            churn_rng: Rng::new(cfg.seed ^ 0x4348_5552_4E21),
            cell: Arc::new(SnapshotCell::new()),
            replicas: None,
            log,
            last_delta_bytes: 0,
            stats: ServeSimStats {
                per_node_records: vec![0; nodes],
                ..ServeSimStats::default()
            },
            placement,
            cfg,
        };
        // initial placement: no provenance yet, so the policy sees only
        // virtual unit loads (placing sequentially so least-loaded
        // spreads instead of stacking everything on node 0)
        let mut virt = vec![0.0f64; nodes];
        for s in 0..n_shards {
            let views: Vec<NodeView> = virt
                .iter()
                .enumerate()
                .map(|(id, &b)| NodeView { id, free_at_ms: b, busy_ms: b })
                .collect();
            let meta = TaskMeta::new(s, s as u64, 1.0);
            let node = sim.placement.place(&meta, &views).min(nodes - 1);
            sim.assignment[s] = node;
            virt[node] += 1.0;
        }
        // replica placement: same policy, fed the per-node shard counts
        // so replicas avoid the primary-heavy node where the policy can
        if sim.cfg.replicas > 0 {
            let mut load = vec![0usize; nodes];
            for &node in &sim.assignment {
                load[node] += 1;
            }
            let replica_nodes = place_replicas(
                sim.placement.as_ref(),
                nodes,
                sim.cfg.replicas,
                &load,
            );
            sim.replicas = Some(Arc::new(RwLock::new(ReplicaSet::new(
                replica_nodes,
                nodes,
                sim.cfg.retained,
                sim.cfg.seed,
            ))));
        }
        Ok(sim)
    }

    /// The configuration this simulation runs under.
    pub fn cfg(&self) -> &ServeSimConfig {
        &self.cfg
    }

    /// Current shard → node assignment.
    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }

    /// Counters and simulated-cost totals so far.
    pub fn stats(&self) -> &ServeSimStats {
        &self.stats
    }

    /// Simulated makespan: the time the busiest node slot reaches once
    /// every scheduled task has run.
    pub fn sim_makespan_ms(&self) -> f64 {
        self.prev_wave_end
    }

    /// The compacted cluster index under the configured constraints
    /// (call after [`Self::compact`] / [`Self::run`]).
    pub fn clusters(&mut self) -> &[Cluster] {
        self.compactor.clusters(&self.cfg.constraints)
    }

    /// The primary's current epoch snapshot (epoch 0 and empty before
    /// the first compaction).
    pub fn snapshot(&self) -> Arc<EpochSnapshot> {
        self.cell.load()
    }

    /// The primary's publication cell — share it with query threads;
    /// they keep loading consistent snapshots while the sim ingests.
    pub fn snapshot_cell(&self) -> Arc<SnapshotCell> {
        Arc::clone(&self.cell)
    }

    /// An in-process query backend over the primary's cell (cache on).
    pub fn local_backend(&self) -> LocalBackend {
        LocalBackend::new(self.snapshot_cell())
    }

    /// A query backend for a client on `client_node`, routed to the
    /// nearest replica. None when the sim runs without replicas.
    pub fn remote_backend(&self, client_node: usize) -> Option<SimRemoteBackend> {
        SimRemoteBackend::new(self.replicas.clone()?, client_node)
    }

    /// The replica set (None when `cfg.replicas == 0`).
    pub fn replica_set(&self) -> Option<SharedReplicas> {
        self.replicas.clone()
    }

    /// Drive a whole stream: waves of `batch` tuples, compacting every
    /// `compact_every` waves and once more at end of stream (unless the
    /// last wave already compacted — a back-to-back double compaction
    /// would only re-run the rebalance on zero new data).
    pub fn run(&mut self, stream: &[NTuple]) {
        let batch = self.cfg.batch.max(1);
        let every = self.cfg.compact_every.max(1);
        let mut uncompacted = 0usize;
        for (i, wave) in stream.chunks(batch).enumerate() {
            self.ingest(wave);
            uncompacted += 1;
            if (i + 1) % every == 0 {
                self.compact();
                uncompacted = 0;
            }
        }
        if uncompacted > 0 {
            self.compact();
        }
    }

    /// One ingest wave: route-split on the (possibly skewed) source
    /// nodes, an optional churn kill between the phases, then one mining
    /// task per touched shard on its assigned node.
    pub fn ingest(&mut self, wave: &[NTuple]) {
        if wave.is_empty() {
            return;
        }
        let mut span = crate::span!("serve.sim.ingest");
        span.records_in(wave.len() as u64);
        self.stats.waves += 1;
        self.stats.tuples += wave.len();
        let nodes = self.lanes.len();
        let n_shards = self.shards.len();
        let ready = if self.cfg.pipeline {
            // the staging buffer frees once the previous wave is routed:
            // this wave's routing overlaps the previous wave's mining
            self.prev_route_done
        } else {
            self.prev_wave_end
        };

        // ---- phase 1: route-split, one task per chunk on its source ----
        // bins[chunk] = (source node, per-shard tuple bins)
        let mut chunk_bins: Vec<(usize, Vec<Vec<NTuple>>)> = Vec::new();
        let mut route_done = ready;
        for chunk in wave.chunks(self.cfg.route_chunk.max(1)) {
            let source = self.draw_source(nodes);
            let mut bins: Vec<Vec<NTuple>> = vec![Vec::new(); n_shards];
            for t in chunk {
                bins[(fxhash(t) % n_shards as u64) as usize].push(*t);
            }
            let cost = chunk.len() as f64 * self.cfg.route_ms_per_record;
            let finish = self.schedule(source, ready, cost);
            route_done = route_done.max(finish);
            chunk_bins.push((source, bins));
        }

        // ---- churn: a seeded kill lands between route and mine ----
        // (own RNG stream, two draws per wave — source arrivals are
        // identical across churn probabilities, so churned vs clean
        // runs differ only by the kills themselves)
        if self.cfg.churn.is_active() {
            let victim = self.churn_rng.usize_below(nodes);
            if self.churn_rng.chance(self.cfg.churn.kill_prob) {
                self.kill_node(victim, route_done);
            }
        }

        // ---- phase 2: one mining task per touched shard ----
        let mut wave_end = route_done;
        for s in 0..n_shards {
            let mut tuples: Vec<NTuple> = Vec::new();
            let mut moved_mib = 0.0;
            let node = self.assignment[s];
            for (source, bins) in &mut chunk_bins {
                let bin = std::mem::take(&mut bins[s]);
                if bin.is_empty() {
                    continue;
                }
                let mib = self.cfg.shuffle.mib(bin.len());
                self.input_bytes[s][*source] += mib;
                if *source != node {
                    moved_mib += mib;
                }
                tuples.extend(bin);
            }
            if tuples.is_empty() {
                continue;
            }
            // REAL mining — the correctness path
            self.shards[s].ingest(&tuples);
            self.recent_records[s] += tuples.len();
            self.stats.per_node_records[node] += tuples.len();
            self.stats.shuffle_mib += moved_mib;
            let cost = tuples.len() as f64 * self.cfg.mine_ms_per_record
                + moved_mib * self.cfg.shuffle.ms_per_mib;
            // mining waits for the wave's full route phase (the same
            // route→mine barrier the real drain has within one wave) and
            // for this shard's previous mining/recovery task
            let at = route_done.max(self.mine_done[s]);
            let finish = self.schedule(node, at, cost);
            self.mine_done[s] = finish;
            wave_end = wave_end.max(finish);
        }

        self.prev_route_done = route_done;
        self.prev_wave_end = self.prev_wave_end.max(wave_end);
    }

    /// Merge every shard's pending delta into the global index, advance
    /// the snapshot watermarks, and (when `rebalance` is on) re-place
    /// shards by the policy — a migration ships the compacted snapshot
    /// and rebuilds the miner on the destination.
    pub fn compact(&mut self) {
        let _span = crate::span!("serve.sim.compact");
        // explicit pull (instead of `Compactor::pull`) so the deltas can
        // be encoded as a binary segment BEFORE they are merged: the
        // encoded size is the real replica-streaming cost, and the log —
        // when configured — becomes the churn-recovery source
        let deltas: Vec<ShardDelta> =
            self.shards.iter_mut().map(Shard::take_delta).collect();
        self.last_delta_bytes = self.persist_deltas(&deltas);
        for delta in &deltas {
            self.compactor.apply(delta);
        }
        self.stats.compactions += 1;
        for s in 0..self.shards.len() {
            self.compacted_len[s] = self.shards[s].len();
            self.epoch_at_compact[s] = self.shards[s].epoch();
        }
        self.publish_epoch();
        // materialised view of [`ServeSimStats`]: cumulative totals are
        // republished as max-gauges each compaction, so the final metrics
        // snapshot carries the run's totals without a second ledger
        if crate::obs::enabled() {
            use crate::obs::gauge;
            let st = &self.stats;
            gauge("serve.sim.waves", st.waves as f64);
            gauge("serve.sim.tuples", st.tuples as f64);
            gauge("serve.sim.compactions", st.compactions as f64);
            gauge("serve.sim.shuffle_mib", st.shuffle_mib);
            gauge("serve.sim.recovery_mib", st.recovery_mib);
            gauge("serve.sim.kills", st.kills as f64);
            gauge("serve.sim.replayed_tuples", st.replayed_tuples as f64);
            gauge("serve.sim.migrations", st.migrations as f64);
            gauge("serve.sim.replica_mib", st.replica_mib);
            for (n, &r) in st.per_node_records.iter().enumerate() {
                gauge(&format!("serve.sim.node{n}.records"), r as f64);
            }
        }
        if !self.cfg.rebalance {
            for r in &mut self.recent_records {
                *r = 0;
            }
            return;
        }
        // re-place sequentially with virtual load updates, so greedy
        // policies spread instead of stacking on the instantaneous
        // minimum
        let nodes = self.lanes.len();
        let mut virt_busy = self.busy.clone();
        let mut virt_free: Vec<f64> = self
            .lanes
            .iter()
            .map(|ls| ls.iter().cloned().fold(f64::INFINITY, f64::min))
            .collect();
        // all of this compaction's migrations start from the same ready
        // floor — independent migrations to different nodes run in
        // parallel (same-node ones still queue on its slot lanes)
        let migrate_ready = self.prev_wave_end;
        let mut migrate_end = self.prev_wave_end;
        for s in 0..self.shards.len() {
            let est = (self.recent_records[s] as f64 * self.cfg.mine_ms_per_record)
                .max(1.0);
            let views: Vec<NodeView> = (0..nodes)
                .map(|id| NodeView {
                    id,
                    free_at_ms: virt_free[id],
                    busy_ms: virt_busy[id],
                })
                .collect();
            let meta = TaskMeta {
                affinity: self.affinity_of(s),
                ..TaskMeta::new(s, s as u64, est)
            };
            let node = self.placement.place(&meta, &views).min(nodes - 1);
            virt_busy[node] += est;
            virt_free[node] += est / self.cfg.slots_per_node.max(1) as f64;
            if node != self.assignment[s] {
                // migration: the destination fetches the compacted
                // snapshot and rebuilds the miner before serving
                self.stats.migrations += 1;
                let records = self.compacted_len[s];
                let mib = self.cfg.shuffle.mib(records);
                self.stats.recovery_mib += mib;
                let cost = mib * self.cfg.shuffle.ms_per_mib
                    + records as f64 * self.cfg.mine_ms_per_record;
                let finish = self.schedule(node, migrate_ready, cost);
                self.mine_done[s] = self.mine_done[s].max(finish);
                migrate_end = migrate_end.max(finish);
                self.assignment[s] = node;
            }
        }
        self.prev_wave_end = migrate_end;
        for r in &mut self.recent_records {
            *r = 0;
        }
    }

    /// Encode this compaction's deltas as ONE delta segment; returns the
    /// encoded size in bytes — the real (measured, not modelled) delta
    /// traffic [`Self::publish_epoch`] charges per replica. With a
    /// segment log configured the segment is also appended to disk; a
    /// write failure downgrades to in-memory recovery
    /// (`persist.segment.flush_fail`) instead of killing the drain.
    fn persist_deltas(&mut self, deltas: &[ShardDelta]) -> u64 {
        let mut payload = SegmentPayload {
            seq: 0,
            epoch: self.stats.compactions as u64 + 1,
            kind: SegmentKind::Delta,
            arity: self.cfg.arity,
            config: SegmentConfig {
                max_pending: 0,
                workers: self.cfg.slots_per_node,
                min_density: self.cfg.constraints.min_density,
                min_support: self.cfg.constraints.min_support,
            },
            shards: deltas
                .iter()
                .map(|d| ShardRecord {
                    epoch: d.epoch,
                    tuples: d.tuples.clone(),
                    cumuli: d.appends.clone(),
                })
                .collect(),
            clusters: Vec::new(),
            interners: Vec::new(),
        };
        match &mut self.log {
            Some(log) => match log.append(&mut payload) {
                Ok(bytes) => bytes,
                Err(_) => {
                    crate::obs::counter("persist.segment.flush_fail", 1);
                    self.log = None;
                    payload.encode().len() as u64
                }
            },
            None => payload.encode().len() as u64,
        }
    }

    /// Publish the freshly compacted index as an immutable epoch
    /// snapshot: swap it into the primary's [`SnapshotCell`], then
    /// stream it to the replica set. The delta traffic — the REAL
    /// encoded bytes of this compaction's delta segment — is charged on
    /// the replica nodes OFF the drain critical path — replication is
    /// asynchronous, which is exactly why replicas can trail the
    /// primary by up to the retained window.
    fn publish_epoch(&mut self) {
        let epoch = self.stats.compactions as u64;
        let snap = self.compactor.snapshot(&self.cfg.constraints, epoch);
        self.cell.publish(Arc::clone(&snap));
        let Some(replicas) = self.replicas.clone() else {
            return;
        };
        let mib = self.last_delta_bytes as f64 / (1024.0 * 1024.0);
        let ready = self.prev_wave_end;
        let mut set = replicas.write().expect("replica set poisoned");
        for r in 0..set.len() {
            let node = set.nodes()[r];
            // async apply: occupies a slot on the replica's node but
            // never extends `prev_wave_end` — queries may meanwhile be
            // answered from the replica's previous epoch
            self.schedule(node, ready, mib * self.cfg.shuffle.ms_per_mib);
            self.stats.replica_mib += mib;
        }
        set.publish(snap);
        self.stats.replica_publishes = set.publishes();
        self.stats.replica_max_staleness =
            self.stats.replica_max_staleness.max(set.max_staleness());
    }

    /// Node holding the largest measured share of shard `s`'s input so
    /// far (None before any input).
    fn affinity_of(&self, s: usize) -> Option<usize> {
        let bytes = &self.input_bytes[s];
        let (node, &max) = bytes
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))?;
        (max > 0.0).then_some(node)
    }

    /// Seeded skewed source-node draw (one `f64` per chunk).
    fn draw_source(&mut self, nodes: usize) -> usize {
        let total = *self.source_cum.last().expect("at least one node");
        let x = self.rng.f64() * total;
        self.source_cum.partition_point(|&c| c <= x).min(nodes - 1)
    }

    /// Put `cost` ms of work on `node`'s earliest slot, no earlier than
    /// `ready`; returns the finish time.
    fn schedule(&mut self, node: usize, ready: f64, cost: f64) -> f64 {
        let slot = (0..self.lanes[node].len())
            .min_by(|&a, &b| {
                self.lanes[node][a].partial_cmp(&self.lanes[node][b]).unwrap()
            })
            .expect("nodes have slots");
        let start = self.lanes[node][slot].max(ready);
        let finish = start + cost;
        self.lanes[node][slot] = finish;
        self.busy[node] += cost;
        finish
    }

    /// Kill `node` at simulated instant `at`: its slots refuse work for
    /// `restart_ms`, and every shard on it loses all state since the
    /// last compaction — each is re-placed and REALLY rebuilt from the
    /// compacted state plus the retained in-flight window. With a
    /// segment log the compacted state comes from REPLAYING THE LOG —
    /// bulk page adoption, the log fetched once per kill and charged at
    /// its real encoded size; without one (or when replay fails) the
    /// prefix is re-mined in memory, the pre-segment behaviour.
    fn kill_node(&mut self, node: usize, at: f64) {
        self.stats.kills += 1;
        let restart = self.cfg.churn.restart_ms.max(0.0);
        for lane in &mut self.lanes[node] {
            *lane = lane.max(at) + restart;
        }
        // fetch the segment log once: every shard recovering from this
        // kill adopts its pages out of the same replayed image
        let log_image: Option<LogImage> = self
            .log
            .as_ref()
            .and_then(|log| SegmentLog::replay(log.dir()).ok());
        if let Some(image) = &log_image {
            self.stats.recovery_mib += image.bytes as f64 / (1024.0 * 1024.0);
        }
        let nodes = self.lanes.len();
        for s in 0..self.shards.len() {
            if self.assignment[s] != node {
                continue;
            }
            // REAL replay: compacted prefix (whose contributions the
            // global index already holds — its re-derived delta is
            // discarded) then the re-delivered window (exported at the
            // next compaction as usual)
            let history = self.shards[s].ingested_tuples();
            let (compacted, window) = history.split_at(self.compacted_len[s]);
            let adopted = log_image.as_ref().and_then(|image| {
                let state = image.shards.get(s)?;
                let mut shard = Shard::restore(
                    s,
                    self.cfg.arity,
                    0,
                    &state.tuples,
                    state.cumuli.clone(),
                )
                .ok()?;
                let _ = shard.take_delta(); // the index already has it
                Some(shard)
            });
            let from_log = adopted.is_some();
            let mut fresh = match adopted {
                Some(shard) => shard,
                None => {
                    let mut shard = Shard::new(s, self.cfg.arity);
                    if !compacted.is_empty() {
                        shard.ingest(compacted);
                        let _ = shard.take_delta();
                    }
                    shard
                }
            };
            if self.cfg.resident_mib > 0 {
                fresh.set_resident_budget(
                    crate::oac::primes::resident_pages(
                        self.cfg.resident_mib,
                        self.shards.len(),
                    ),
                    self.cfg.segment_dir.as_ref().map(|d| d.join("spill")),
                );
            }
            fresh.set_epoch(self.epoch_at_compact[s]);
            if !window.is_empty() {
                fresh.ingest(window);
            }
            self.shards[s] = fresh;
            self.stats.replayed_tuples += history.len();
            // re-place on a surviving node (the policy may still pick the
            // dead node — rr does — in which case recovery waits out the
            // restart on its bumped lanes)
            let views: Vec<NodeView> = self
                .lanes
                .iter()
                .enumerate()
                .map(|(id, ls)| NodeView {
                    id,
                    free_at_ms: ls.iter().cloned().fold(f64::INFINITY, f64::min),
                    busy_ms: self.busy[id],
                })
                .collect();
            let meta = TaskMeta {
                affinity: self.affinity_of(s),
                ..TaskMeta::new(
                    s,
                    s as u64,
                    (history.len() as f64 * self.cfg.mine_ms_per_record).max(1.0),
                )
            };
            let dest = self.placement.place(&meta, &views).min(nodes - 1);
            self.assignment[s] = dest;
            // recovery cost on the destination: snapshot fetch + replay
            // compute; mining of the current wave's bin for this shard
            // queues behind it. Log-based recovery already charged the
            // fetch ONCE at the log's real encoded size, so only the
            // modelled fallback pays the per-shard estimate here.
            let mib =
                if from_log { 0.0 } else { self.cfg.shuffle.mib(history.len()) };
            self.stats.recovery_mib += mib;
            let cost = mib * self.cfg.shuffle.ms_per_mib
                + history.len() as f64 * self.cfg.mine_ms_per_record;
            let finish = self.schedule(dest, at, cost);
            self.mine_done[s] = self.mine_done[s].max(finish);
        }
    }
}

impl std::fmt::Debug for ServeSim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeSim")
            .field("cfg", &self.cfg)
            .field("placement", &self.placement.name())
            .field("assignment", &self.assignment)
            .field("stats", &self.stats)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oac::mine_online;

    fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
        cs.sort_by(|a, b| a.components.cmp(&b.components));
        cs
    }

    fn assert_matches_online(sim: &mut ServeSim, ctx: &crate::core::context::PolyContext) {
        let reference = sorted(mine_online(ctx, &Constraints::none()));
        let got = sorted(sim.clusters().to_vec());
        assert_eq!(got.len(), reference.len(), "cluster count");
        for (a, b) in got.iter().zip(&reference) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
    }

    /// Exactly `n` DISTINCT random triples over `universe³` cells
    /// (`PolyContext` is a set, so callers must pick `universe³ > n`;
    /// small universes force heavy cross-shard cumulus sharing).
    fn stream(n: usize, universe: u64) -> crate::core::context::PolyContext {
        assert!(universe * universe * universe > n as u64);
        let mut ctx = crate::core::context::PolyContext::new(3);
        let mut rng = Rng::new(99);
        while ctx.len() < n {
            ctx.add_ids(&[
                rng.below(universe) as u32,
                rng.below(universe) as u32,
                rng.below(universe) as u32,
            ]);
        }
        ctx
    }

    #[test]
    fn every_placement_matches_online_mining() {
        let ctx = stream(400, 8);
        for placement in ["rr", "locality", "least"] {
            let mut cfg = ServeSimConfig::new(3, 5, 3);
            cfg.placement = placement.into();
            cfg.batch = 97;
            cfg.source_skew = 1.5;
            let mut sim = ServeSim::new(cfg).unwrap();
            sim.run(ctx.tuples());
            assert_matches_online(&mut sim, &ctx);
            assert!(sim.sim_makespan_ms() > 0.0, "{placement}: work costs time");
            let mined: usize = sim.stats().per_node_records.iter().sum();
            assert_eq!(mined, ctx.len(), "{placement}: every tuple mined once");
        }
    }

    #[test]
    fn churn_replays_snapshots_and_keeps_the_index_exact() {
        let ctx = stream(960, 12);
        let mut cfg = ServeSimConfig::new(3, 4, 3);
        cfg.batch = 64; // many waves → many kill opportunities
        cfg.compact_every = 3;
        cfg.churn = ChurnConfig { kill_prob: 0.5, restart_ms: 40.0 };
        cfg.seed = 11;
        let mut sim = ServeSim::new(cfg).unwrap();
        sim.run(ctx.tuples());
        assert!(sim.stats().kills > 0, "p=0.5 over 15 waves must kill");
        assert!(sim.stats().replayed_tuples > 0, "kills replay state");
        assert_matches_online(&mut sim, &ctx);
    }

    #[test]
    fn locality_moves_fewer_bytes_than_round_robin_under_skew() {
        let ctx = stream(4000, 64);
        let run = |placement: &str| {
            let mut cfg = ServeSimConfig::new(3, 8, 4);
            cfg.placement = placement.into();
            cfg.slots_per_node = 8;
            // many short waves with frequent rebalances, so the measured
            // affinity converges onto the hot ingress node early (the
            // seeded draw schedule was verified to make node 0 dominate
            // well before the first rebalance)
            cfg.batch = 256;
            cfg.compact_every = 2;
            cfg.seed = 123;
            cfg.source_skew = 2.0; // node 0 sources most of the stream
            let mut sim = ServeSim::new(cfg).unwrap();
            sim.run(ctx.tuples());
            sim.stats().clone()
        };
        let rr = run("rr");
        let locality = run("locality");
        assert!(
            locality.shuffle_mib < rr.shuffle_mib,
            "locality must move fewer bytes: {} !< {}",
            locality.shuffle_mib,
            rr.shuffle_mib
        );
    }

    #[test]
    fn pipelined_waves_never_slow_the_drain() {
        let ctx = stream(3000, 64);
        let run = |pipeline: bool| {
            let mut cfg = ServeSimConfig::new(3, 4, 3);
            cfg.batch = 256;
            cfg.pipeline = pipeline;
            // round-robin: placement is independent of the simulated
            // clocks, so both runs schedule the identical task set on
            // the identical nodes and only the readiness times differ —
            // the one setting where earlier-ready ⇒ earlier-finish is a
            // theorem, not a heuristic
            cfg.placement = "rr".into();
            let mut sim = ServeSim::new(cfg).unwrap();
            sim.run(ctx.tuples());
            sim.sim_makespan_ms()
        };
        let overlapped = run(true);
        let barriered = run(false);
        assert!(
            overlapped <= barriered,
            "overlap must not lengthen the schedule: {overlapped} > {barriered}"
        );
    }

    #[test]
    fn simulation_is_deterministic_for_a_seed() {
        let ctx = stream(1000, 16);
        let run = || {
            let mut cfg = ServeSimConfig::new(3, 4, 3);
            cfg.source_skew = 1.0;
            cfg.churn = ChurnConfig { kill_prob: 0.3, restart_ms: 20.0 };
            let mut sim = ServeSim::new(cfg).unwrap();
            sim.run(ctx.tuples());
            (sim.sim_makespan_ms(), sim.stats().shuffle_mib, sim.stats().kills)
        };
        let (a_ms, a_mib, a_kills) = run();
        let (b_ms, b_mib, b_kills) = run();
        assert_eq!(a_ms.to_bits(), b_ms.to_bits());
        assert_eq!(a_mib.to_bits(), b_mib.to_bits());
        assert_eq!(a_kills, b_kills);
    }

    #[test]
    fn replicas_track_the_primary_within_the_retained_window() {
        use crate::serve::backend::QueryBackend;
        let ctx = stream(600, 10);
        let mut cfg = ServeSimConfig::new(3, 4, 3);
        cfg.batch = 64;
        cfg.compact_every = 2;
        cfg.replicas = 2;
        cfg.retained = 2;
        let mut sim = ServeSim::new(cfg).unwrap();
        sim.run(ctx.tuples());
        let stats = sim.stats().clone();
        assert!(stats.replica_publishes >= 4, "several compactions published");
        assert!(stats.replica_max_staleness <= 2, "staleness bound");
        assert!(stats.replica_mib > 0.0, "delta streaming costs bytes");
        // primary snapshot equals the compacted index at the last epoch
        assert_eq!(sim.snapshot().epoch(), stats.compactions as u64);
        assert_eq!(sim.snapshot().len(), sim.clusters().len());
        let mut remote = sim.remote_backend(0).expect("replicas configured");
        assert!(remote.epoch() + 2 >= stats.compactions as u64);
        assert!(remote.stats().clusters > 0, "replica serves a real index");
    }

    #[test]
    fn retained_zero_replicas_answer_identically_to_the_primary() {
        use crate::serve::backend::QueryBackend;
        let ctx = stream(400, 8);
        let mut cfg = ServeSimConfig::new(3, 3, 2);
        cfg.batch = 97;
        cfg.replicas = 1;
        cfg.retained = 0; // synchronous replication: always fresh
        let mut sim = ServeSim::new(cfg).unwrap();
        sim.run(ctx.tuples());
        let mut local = sim.local_backend();
        let mut remote = sim.remote_backend(1).expect("one replica");
        assert_eq!(local.epoch(), remote.epoch());
        assert_eq!(local.top_k(5), remote.top_k(5));
        assert_eq!(local.stats(), remote.stats());
    }

    #[test]
    fn unknown_placement_is_an_error() {
        let mut cfg = ServeSimConfig::new(3, 2, 2);
        cfg.placement = "yarn".into();
        assert!(ServeSim::new(cfg).is_err());
    }
}
