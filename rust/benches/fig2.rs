//! Bench: regenerate paper Figure 2 — performance curves for six
//! datasets (IMDB point + the MovieLens size series), reporting the
//! online/M-R speedup trend that grows with data size.

use tricluster::coordinator::{experiments, ExpConfig};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let cfg = ExpConfig { full, nodes: 10, theta: 0.0, runs: 1, seed: 42 };
    eprintln!("fig2 bench (full={full}) ...");
    let report = experiments::fig2(&cfg)?;
    println!("{}", report.render());
    println!();
    println!("paper shape: speedup < 1 on IMDB (overhead dominates), rising to ~5-6x at 1M");
    let csv = report.write_csv()?;
    eprintln!("(csv: {})", csv.display());
    Ok(())
}
