//! Artifact manifest: the calling conventions of the AOT modules,
//! written by python/compile/aot.py and parsed here with util::json.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one input/output of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Tensor name in the AOT signature.
    pub name: String,
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Element dtype (`f32`, `i32`, ...).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT module: file + io signature + geometry hints.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (`density_t64_k128`, ...).
    pub name: String,
    /// Source graph (`density`, `delta`, `mc`).
    pub graph: String,
    /// Path of the serialized module.
    pub file: PathBuf,
    /// Input tensor signature.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor signature.
    pub outputs: Vec<TensorSpec>,
    /// density tiles: edge size; delta: slab K; etc.
    pub tile: Option<usize>,
    /// delta: fiber-batch size K.
    pub k: Option<usize>,
    /// delta: padded fiber length L.
    pub l: Option<usize>,
    /// mc: samples per cluster.
    pub samples: Option<usize>,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Every artifact the manifest lists.
    pub artifacts: Vec<ArtifactSpec>,
    /// Compiler-reported density-kernel VMEM per step, bytes.
    pub density_vmem_bytes: Option<f64>,
    /// Compiler-reported density-kernel MXU MACs.
    pub density_mxu_macs: Option<f64>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .context("expected array of tensor specs")?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .context("tensor name")?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("tensor shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                dtype: t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse manifest: {e}"))?;
        anyhow::ensure!(
            j.get("format").and_then(Json::as_str) == Some("hlo-text"),
            "unsupported artifact format"
        );
        anyhow::ensure!(
            j.get("return_tuple").and_then(Json::as_bool) == Some(true),
            "artifacts must use the return_tuple calling convention"
        );
        let mut artifacts = Vec::new();
        for (name, spec) in
            j.get("artifacts").and_then(Json::as_obj).context("artifacts")?
        {
            let file = dir.join(
                spec.get("file").and_then(Json::as_str).context("file")?,
            );
            anyhow::ensure!(file.exists(), "missing artifact {}", file.display());
            let get_usize =
                |k: &str| spec.get(k).and_then(Json::as_usize);
            artifacts.push(ArtifactSpec {
                name: name.clone(),
                graph: spec
                    .get("graph")
                    .and_then(Json::as_str)
                    .context("graph")?
                    .to_string(),
                file,
                inputs: tensor_specs(spec.get("inputs").context("inputs")?)?,
                outputs: tensor_specs(spec.get("outputs").context("outputs")?)?,
                tile: get_usize("tile"),
                k: get_usize("k"),
                l: get_usize("l"),
                samples: get_usize("samples"),
            });
        }
        let perf = j.get("perf_model");
        Ok(Self {
            dir: dir.to_path_buf(),
            artifacts,
            density_vmem_bytes: perf
                .and_then(|p| p.get("density_vmem_bytes_per_step"))
                .and_then(Json::as_f64),
            density_mxu_macs: perf
                .and_then(|p| p.get("density_mxu_macs_per_step"))
                .and_then(Json::as_f64),
        })
    }

    /// The artifact named `name`, if the manifest lists it.
    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Pick the best density artifact for a context of edge `n` and `k`
    /// clusters per batch: smallest tile ≥ n if any, else the largest
    /// tile; prefer larger K for big batches.
    pub fn best_density(&self, n: usize, batch: usize) -> Option<&ArtifactSpec> {
        let mut cands: Vec<&ArtifactSpec> = self
            .artifacts
            .iter()
            .filter(|a| a.graph == "density")
            .collect();
        cands.sort_by_key(|a| (a.tile.unwrap_or(0), a.k.unwrap_or(0)));
        let fitting: Vec<&&ArtifactSpec> = cands
            .iter()
            .filter(|a| a.tile.unwrap_or(0) >= n)
            .collect();
        if fitting.is_empty() {
            // tiled execution with the largest tile, biggest K
            return cands
                .iter()
                .filter(|a| a.tile == cands.last().and_then(|c| c.tile))
                .max_by_key(|a| a.k.unwrap_or(0))
                .copied();
        }
        let tile = fitting[0].tile;
        fitting
            .into_iter()
            .filter(|a| a.tile == tile)
            .max_by_key(|a| {
                let k = a.k.unwrap_or(0);
                if k <= batch { (k, 0) } else { (0, usize::MAX - k) }
            })
            .copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 3);
        let d = m.find("density_g64_k32").expect("density artifact");
        assert_eq!(d.inputs[0].shape, vec![64, 64, 64]);
        assert_eq!(d.outputs[0].shape, vec![32]);
        assert!(m.density_vmem_bytes.unwrap() < 16.0 * (1 << 20) as f64);
    }

    #[test]
    fn best_density_picks_fitting_tile() {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        // a 20-wide context fits the 32-tile artifact
        assert_eq!(m.best_density(20, 32).unwrap().tile, Some(32));
        // a 64-wide context, batch 200 → 64-tile, k=128
        let a = m.best_density(64, 200).unwrap();
        assert_eq!(a.tile, Some(64));
        assert_eq!(a.k, Some(128));
        // a 500-wide context must still return something (tiled path)
        assert!(m.best_density(500, 8).is_some());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load(Path::new("/nonexistent-xyz")).is_err());
    }
}
